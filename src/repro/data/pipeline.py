"""Token data pipeline.

Design goals (1000-node posture):
* **Deterministic & elastic**: batch ``i`` is a pure function of (seed, i),
  independent of worker count — restarts and re-shards never replay or skip
  data differently.
* **Checkpointable**: iterator state is a single integer (next step index) +
  the config hash; stored inside the train checkpoint.
* **Sharded loading**: each host materializes only its ``(host_batch, seq)``
  slice; device placement happens in the launcher.
* **Prefetch**: a background thread keeps ``prefetch`` batches ready.

Storage: memory-mapped ``.bin`` token files (np.uint16/uint32) or a synthetic
deterministic stream (used by tests/examples; same interface).
"""
from __future__ import annotations

import dataclasses
import hashlib
import queue
import threading
from pathlib import Path
from typing import Iterator, Optional

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    seq_len: int = 512
    global_batch: int = 8
    vocab_size: int = 32000
    seed: int = 0
    # host sharding
    host_index: int = 0
    host_count: int = 1
    prefetch: int = 2

    @property
    def host_batch(self) -> int:
        assert self.global_batch % self.host_count == 0
        return self.global_batch // self.host_count

    def fingerprint(self) -> str:
        payload = f"{self.seq_len}|{self.global_batch}|{self.vocab_size}|{self.seed}"
        return hashlib.sha256(payload.encode()).hexdigest()[:16]


class TokenDataset:
    """A flat token stream; examples are seq_len+1 windows chosen by a
    deterministic pseudo-random permutation of window starts."""

    def __init__(self, tokens: np.ndarray, cfg: DataConfig):
        assert tokens.ndim == 1
        self.tokens = tokens
        self.cfg = cfg
        self.n_windows = (len(tokens) - 1) // (cfg.seq_len + 1)
        if self.n_windows <= 0:
            raise ValueError("dataset smaller than one window")

    @classmethod
    def from_bin(cls, path: str | Path, cfg: DataConfig, dtype=np.uint16):
        arr = np.memmap(path, dtype=dtype, mode="r")
        return cls(arr, cfg)

    def _window(self, idx: int) -> np.ndarray:
        w = idx % self.n_windows
        s = w * (self.cfg.seq_len + 1)
        return np.asarray(self.tokens[s:s + self.cfg.seq_len + 1], np.int32)

    def batch_at(self, step: int) -> np.ndarray:
        """The *host-local* slice of global batch ``step`` — deterministic in
        (seed, step) regardless of host_count."""
        cfg = self.cfg
        rng = np.random.Generator(np.random.Philox(key=cfg.seed, counter=step))
        idxs = rng.integers(0, self.n_windows, size=cfg.global_batch)
        lo = cfg.host_index * cfg.host_batch
        sel = idxs[lo:lo + cfg.host_batch]
        return np.stack([self._window(int(i)) for i in sel])


def synthetic_dataset(cfg: DataConfig, n_tokens: int = 1 << 20) -> TokenDataset:
    """Deterministic synthetic corpus (zipfian-ish unigram)."""
    rng = np.random.Generator(np.random.Philox(key=cfg.seed ^ 0xDA7A))
    ranks = np.arange(1, cfg.vocab_size + 1, dtype=np.float64)
    probs = 1.0 / ranks
    probs /= probs.sum()
    toks = rng.choice(cfg.vocab_size, size=n_tokens, p=probs).astype(np.int32)
    return TokenDataset(toks, cfg)


def make_batches(ds: TokenDataset, start_step: int = 0,
                 stop_step: Optional[int] = None) -> Iterator[tuple[int, np.ndarray]]:
    """Prefetching iterator yielding (step, host_batch_tokens).

    Resume by passing the checkpointed ``start_step``; the stream is
    identical to an uninterrupted run (fault-tolerance requirement).
    """
    cfg = ds.cfg
    q: queue.Queue = queue.Queue(maxsize=cfg.prefetch)
    stop = threading.Event()

    def worker():
        step = start_step
        while not stop.is_set() and (stop_step is None or step < stop_step):
            q.put((step, ds.batch_at(step)))
            step += 1
        q.put(None)

    t = threading.Thread(target=worker, daemon=True)
    t.start()
    try:
        while True:
            item = q.get()
            if item is None:
                return
            yield item
    finally:
        stop.set()
