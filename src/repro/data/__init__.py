"""Deterministic, shardable data pipeline with checkpointable iterator state."""
from repro.data.pipeline import (DataConfig, TokenDataset, make_batches,
                                 synthetic_dataset)

__all__ = ["DataConfig", "TokenDataset", "make_batches", "synthetic_dataset"]
