"""Checkpointing substrate.

Layout (one directory per step)::

    <root>/step_000123.tmp/   — being written
        manifest.json          — pytree structure, shapes, dtypes, extras
        arr_000000.npy ...     — one file per leaf (host-local full value)
    <root>/step_000123/        — atomically renamed when complete

Fault-tolerance properties:
* **Atomic publish** — a crash mid-save never corrupts the latest checkpoint;
  readers only ever see fully-written directories.
* **Async** — ``save_async`` snapshots device arrays to host then writes on a
  background thread; training continues immediately (overlap).
* **Elastic restore** — leaves are stored as *global* arrays; restore places
  them onto any mesh/sharding (device-count changes survive restarts).
* **Retention** — keep the last N checkpoints, always keep multiples of K.
* **Emergency save** — SIGTERM handler hook for preemption (see train.py).

On a real multi-host pod each host writes only the shards it owns
(process-local addressable shards); in this single-process container that
degenerates to full arrays, same layout.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from pathlib import Path
from typing import Optional

import jax
import numpy as np


def _flatten(state):
    leaves, treedef = jax.tree.flatten(state)
    return leaves, treedef


def save_state(root: str | Path, step: int, state, extras: Optional[dict] = None):
    """Synchronous sharded save with atomic publish."""
    root = Path(root)
    tmp = root / f"step_{step:09d}.tmp"
    final = root / f"step_{step:09d}"
    if final.exists():
        shutil.rmtree(final)
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir(parents=True)

    leaves, treedef = _flatten(state)
    manifest = {
        "step": step,
        "treedef": str(treedef),
        "n_leaves": len(leaves),
        "extras": extras or {},
        "leaves": [],
    }
    for i, leaf in enumerate(leaves):
        arr = np.asarray(jax.device_get(leaf))
        np.save(tmp / f"arr_{i:06d}.npy", arr)
        manifest["leaves"].append({
            "index": i, "shape": list(arr.shape), "dtype": str(arr.dtype)})
    (tmp / "manifest.json").write_text(json.dumps(manifest))
    os.replace(tmp, final)  # atomic publish
    return final


class CheckpointManager:
    """Async checkpoint writer with retention policy."""

    def __init__(self, root: str | Path, keep_last: int = 3,
                 keep_every: int = 0):
        self.root = Path(root)
        self.root.mkdir(parents=True, exist_ok=True)
        self.keep_last = keep_last
        self.keep_every = keep_every
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    def save_async(self, step: int, state, extras: Optional[dict] = None):
        """Snapshot to host memory now; write + publish in the background."""
        self.wait()  # one in-flight save at a time
        host_state = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), state)

        def work():
            try:
                save_state(self.root, step, host_state, extras)
                self._gc()
            except BaseException as e:  # noqa: BLE001
                self._error = e

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def save(self, step: int, state, extras: Optional[dict] = None):
        self.wait()
        save_state(self.root, step, state, extras)
        self._gc()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def _gc(self):
        steps = sorted(all_steps(self.root))
        doomed = steps[:-self.keep_last] if self.keep_last else []
        for s in doomed:
            if self.keep_every and s % self.keep_every == 0:
                continue
            shutil.rmtree(self.root / f"step_{s:09d}", ignore_errors=True)

    def latest_step(self) -> Optional[int]:
        return latest_step(self.root)

    def restore(self, state_like, step: Optional[int] = None,
                shardings=None):
        step = step if step is not None else self.latest_step()
        if step is None:
            return None, None
        return restore_state(self.root, step, state_like, shardings)


def all_steps(root: str | Path):
    root = Path(root)
    out = []
    for p in root.glob("step_*"):
        if p.suffix == ".tmp" or not p.is_dir():
            continue
        try:
            out.append(int(p.name.split("_")[1]))
        except ValueError:
            continue
    return out


def latest_step(root: str | Path) -> Optional[int]:
    steps = all_steps(root)
    return max(steps) if steps else None


def restore_state(root: str | Path, step: int, state_like,
                  shardings=None):
    """Restore into the structure of ``state_like`` (a pytree of arrays or
    ShapeDtypeStructs).  ``shardings``: optional matching pytree of
    NamedShardings for elastic placement onto the current mesh."""
    root = Path(root)
    d = root / f"step_{step:09d}"
    manifest = json.loads((d / "manifest.json").read_text())
    leaves_like, treedef = jax.tree.flatten(state_like)
    if manifest["n_leaves"] != len(leaves_like):
        raise ValueError(
            f"checkpoint has {manifest['n_leaves']} leaves, expected {len(leaves_like)}")
    shard_leaves = (jax.tree.flatten(shardings)[0] if shardings is not None
                    else [None] * len(leaves_like))
    out = []
    for i, (like, sh) in enumerate(zip(leaves_like, shard_leaves)):
        arr = np.load(d / f"arr_{i:06d}.npy")
        if tuple(arr.shape) != tuple(like.shape):
            raise ValueError(f"leaf {i}: shape {arr.shape} != {like.shape}")
        if sh is not None:
            out.append(jax.device_put(arr.astype(like.dtype), sh))
        else:
            out.append(jax.numpy.asarray(arr.astype(like.dtype)))
    return jax.tree.unflatten(treedef, out), manifest["extras"]
