"""Fault-tolerant checkpointing: async sharded save, atomic publish,
elastic restore (re-shard to any mesh)."""
from repro.checkpoint.manager import (CheckpointManager, latest_step,
                                      restore_state, save_state)

__all__ = ["CheckpointManager", "save_state", "restore_state", "latest_step"]
