"""Neural substrate layers: RMSNorm, RoPE, GQA/MLA attention (with KV and
sliding-window circular caches), SwiGLU MLP, top-k MoE (capacity + all_to_all
expert parallelism), Mamba-1 selective SSM (chunked scan).

Pure-JAX (no flax): params are plain pytrees built by the ``init_*``
functions; apply functions are shape-polymorphic over a leading batch axis.
Sharding is applied at the train/serve-step level (launch/steps.py) — these
layers only use ``shard_map`` internally where explicit collectives are
required (MoE dispatch).
"""
from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

Params = Any


# --------------------------------------------------------------------- utils
def _init(key, shape, scale=None, dtype=jnp.float32):
    fan_in = shape[0] if len(shape) else 1
    scale = scale if scale is not None else 1.0 / np.sqrt(max(1, fan_in))
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


def rms_norm(x, scale, eps=1e-6):
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * (1.0 + scale.astype(jnp.float32))).astype(dt)


def rope(x, positions, *, theta: float = 10000.0):
    """Rotary embedding. x: (..., S, H, hd); positions: (..., S)."""
    hd = x.shape[-1]
    half = hd // 2
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., :, None, None].astype(jnp.float32) * freq  # (...,S,1,half)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def swiglu(x, w_gate, w_up, w_down):
    g = jax.nn.silu(jnp.einsum("...d,df->...f", x, w_gate))
    u = jnp.einsum("...d,df->...f", x, w_up)
    return jnp.einsum("...f,fd->...d", g * u, w_down)


# ----------------------------------------------------------------- attention
def init_attention(key, d_model, n_heads, n_kv_heads, head_dim, dtype):
    k1, k2, k3, k4 = jax.random.split(key, 4)
    return {
        "wq": _init(k1, (d_model, n_heads, head_dim), dtype=dtype),
        "wk": _init(k2, (d_model, n_kv_heads, head_dim), dtype=dtype),
        "wv": _init(k3, (d_model, n_kv_heads, head_dim), dtype=dtype),
        "wo": _init(k4, (n_heads, head_dim, d_model),
                    scale=1.0 / np.sqrt(n_heads * head_dim), dtype=dtype),
    }


def _gqa_scores(q, k, n_rep):
    """q: (B,S,H,hd), k: (B,T,Hkv,hd) -> (B,S,H,T) with GQA head grouping.

    Grouped einsum (q reshaped to (..., Hkv, n_rep, hd)) instead of
    ``jnp.repeat``-ing K to H heads: the repeat materializes an n_rep x copy
    of the whole KV block every call — at decode time that is n_rep x the
    entire cache in HBM traffic per token (§Perf H-i2).  Reshapes on q are
    layout-free; KV stays at Hkv heads.
    """
    hd = q.shape[-1]
    if n_rep > 1:
        B, S, H, _ = q.shape
        qg = q.reshape(B, S, H // n_rep, n_rep, hd)
        s = jnp.einsum("bsgrk,btgk->bsgrt", qg, k,
                       preferred_element_type=jnp.float32)
        return s.reshape(B, S, H, k.shape[1]) / np.sqrt(hd)
    return jnp.einsum("bshk,bthk->bsht", q, k,
                      preferred_element_type=jnp.float32) / np.sqrt(hd)


def _gqa_out(p, v, n_rep):
    """p: (B,S,H,T), v: (B,T,Hkv,hd) -> (B,S,H,hd)."""
    if n_rep > 1:
        B, S, H, T = p.shape
        pg = p.reshape(B, S, H // n_rep, n_rep, T)
        o = jnp.einsum("bsgrt,btgk->bsgrk", pg, v)
        return o.reshape(B, S, H, v.shape[-1])
    return jnp.einsum("bsht,bthk->bshk", p, v)


def _softmax(scores, mask):
    scores = jnp.where(mask, scores.astype(jnp.float32), -1e30)
    return jax.nn.softmax(scores, axis=-1)


def attention(params, x, positions, *, n_rep: int, window: Optional[int],
              rope_theta: float = 10000.0, use_rope: bool = True,
              cache=None, decode: bool = False):
    """GQA attention with optional sliding window and KV cache.

    Train/prefill: x (B,S,D), causal (+window) mask; returns (out, new_cache)
    where new_cache is populated iff ``cache`` is given (prefill).
    Decode: x (B,1,D); ``cache`` = dict(k, v, pos_k, pos) with circular
    buffer of length W (window layers) or S_max (global layers).
    """
    B, S, D = x.shape
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, params["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, params["wv"])
    if use_rope:
        q = rope(q, positions, theta=rope_theta)
        k = rope(k, positions, theta=rope_theta)

    if not decode:
        kk, vv, pos_k = k, v, positions
        scores = _gqa_scores(q, kk, n_rep)
        causal = pos_k[:, None, :] <= positions[:, :, None]  # (B,S,T)
        mask = causal
        if window is not None:
            mask = mask & (pos_k[:, None, :] > positions[:, :, None] - window)
        out = _gqa_out(_softmax(scores, mask[:, :, None, :]), vv, n_rep)
        new_cache = None
        if cache is not None:  # prefill into the cache buffer
            C = cache["k"].shape[1]
            if window is not None and C < S:
                # Keep only the last C positions (circular layout by pos % C).
                sl = slice(S - C, S)
                kc, vc, pc = k[:, sl], v[:, sl], positions[:, sl]
                roll_to = (positions[:, S - C] % C)
                # Place so that slot = pos % C: roll right by pos0 % C.
                kc = jax.vmap(lambda a, r: jnp.roll(a, r, axis=0))(kc, roll_to)
                vc = jax.vmap(lambda a, r: jnp.roll(a, r, axis=0))(vc, roll_to)
                pc = jax.vmap(lambda a, r: jnp.roll(a, r, axis=0))(pc, roll_to)
            else:
                pad = C - S
                kc = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
                vc = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
                pc = jnp.pad(positions, ((0, 0), (0, pad)),
                             constant_values=jnp.iinfo(jnp.int32).max)
            new_cache = {"k": kc.astype(cache["k"].dtype),
                         "v": vc.astype(cache["v"].dtype), "pos_k": pc}
    else:
        # Single-token decode against the circular cache.
        C = cache["k"].shape[1]
        pos = positions[:, 0]  # (B,)
        slot = (pos % C).astype(jnp.int32)
        def upd(buf, new):
            return jax.vmap(
                lambda b, n, s: lax.dynamic_update_slice_in_dim(b, n, s,
                                                                axis=0)
            )(buf, new.astype(buf.dtype), slot)
        kc = upd(cache["k"], k)
        vc = upd(cache["v"], v)
        pc = jax.vmap(
            lambda b, n, s: lax.dynamic_update_slice_in_dim(b, n, s, axis=0)
        )(cache["pos_k"], pos[:, None], slot)
        # flash-decode: pin cache + scores to the sequence-sharded layout so
        # GSPMD computes partial softmax/PV per shard (tiny psums) instead of
        # resharding the cache to its preferred head layout every step.
        from repro.launch.shardctx import constrain
        kc = constrain(kc, "kv_sp")
        vc = constrain(vc, "kv_sp")
        pc = constrain(pc, "kvpos_sp")
        scores = constrain(_gqa_scores(q, kc, n_rep), "scores_sp")  # (B,1,H,C)
        valid = (pc <= pos[:, None])
        if window is not None:
            valid = valid & (pc > (pos[:, None] - window))
        out = _gqa_out(_softmax(scores, valid[:, None, None, :]), vc, n_rep)
        new_cache = {"k": kc, "v": vc, "pos_k": pc}

    proj = jnp.einsum("bshk,hkd->bsd", out.astype(x.dtype), params["wo"])
    return proj, new_cache


# ----------------------------------------------------------------------- MLA
def init_mla(key, d_model, n_heads, *, kv_lora, d_nope, d_rope, d_v, dtype):
    k1, k2, k3, k4, k5, k6 = jax.random.split(key, 6)
    return {
        "wq": _init(k1, (d_model, n_heads, d_nope + d_rope), dtype=dtype),
        "w_dkv": _init(k2, (d_model, kv_lora), dtype=dtype),
        "w_kr": _init(k3, (d_model, d_rope), dtype=dtype),
        "w_uk": _init(k4, (kv_lora, n_heads, d_nope), dtype=dtype),
        "w_uv": _init(k5, (kv_lora, n_heads, d_v), dtype=dtype),
        "wo": _init(k6, (n_heads, d_v, d_model),
                    scale=1.0 / np.sqrt(n_heads * d_v), dtype=dtype),
    }


def mla_attention(params, x, positions, *, d_nope: int, d_rope: int,
                  rope_theta: float = 10000.0, cache=None, decode=False):
    """DeepSeek-V2 Multi-head Latent Attention.

    Cache holds the *compressed* per-token state (c_kv, k_rope) — the MLA
    memory advantage.  Decode uses the absorbed form: W_uk folds into the
    query, W_uv folds into the output, so scores are rank-``kv_lora`` inner
    products against the compressed cache directly.
    """
    B, S, D = x.shape
    scale = 1.0 / np.sqrt(d_nope + d_rope).astype(np.float32)

    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"])
    q_n, q_r = q[..., :d_nope], q[..., d_nope:]
    q_r = rope(q_r, positions, theta=rope_theta)

    c_kv = jnp.einsum("bsd,dc->bsc", x, params["w_dkv"])  # (B,S,Ckv)
    k_r = rope(jnp.einsum("bsd,dr->bsr", x, params["w_kr"])[:, :, None, :],
               positions, theta=rope_theta)[:, :, 0, :]  # (B,S,dr)

    if not decode:
        k_n = jnp.einsum("bsc,chk->bshk", c_kv, params["w_uk"])
        v = jnp.einsum("bsc,chk->bshk", c_kv, params["w_uv"])
        scores = (jnp.einsum("bshk,bthk->bsht", q_n, k_n)
                  + jnp.einsum("bshr,btr->bsht", q_r, k_r)) * scale
        causal = positions[:, None, :] <= positions[:, :, None]
        p = _softmax(scores, causal[:, :, None, :])
        out = jnp.einsum("bsht,bthk->bshk", p, v).astype(x.dtype)
        new_cache = None
        if cache is not None:
            C = cache["c_kv"].shape[1]
            pad = C - S
            new_cache = {
                "c_kv": jnp.pad(c_kv, ((0, 0), (0, pad), (0, 0))).astype(cache["c_kv"].dtype),
                "k_rope": jnp.pad(k_r, ((0, 0), (0, pad), (0, 0))).astype(cache["k_rope"].dtype),
                "pos_k": jnp.pad(positions, ((0, 0), (0, pad)),
                                 constant_values=jnp.iinfo(jnp.int32).max),
            }
    else:
        C = cache["c_kv"].shape[1]
        pos = positions[:, 0]
        slot = (pos % C).astype(jnp.int32)
        def upd(buf, new):
            return jax.vmap(
                lambda b, n, s: lax.dynamic_update_slice_in_dim(b, n, s,
                                                                axis=0)
            )(buf, new.astype(buf.dtype), slot)
        ckv = upd(cache["c_kv"], c_kv)
        krc = upd(cache["k_rope"], k_r)
        pc = jax.vmap(
            lambda b, n, s: lax.dynamic_update_slice_in_dim(b, n, s, axis=0)
        )(cache["pos_k"], pos[:, None], slot)
        # Absorbed: q_abs (B,1,H,Ckv) = q_n @ W_uk^T
        q_abs = jnp.einsum("bshk,chk->bshc", q_n, params["w_uk"])
        scores = (jnp.einsum("bshc,btc->bsht", q_abs, ckv)
                  + jnp.einsum("bshr,btr->bsht", q_r, krc)) * scale
        valid = pc <= pos[:, None]
        p = _softmax(scores, valid[:, None, None, :])
        ctx = jnp.einsum("bsht,btc->bshc", p, ckv)  # compressed context
        out = jnp.einsum("bshc,chk->bshk", ctx, params["w_uv"]).astype(x.dtype)
        new_cache = {"c_kv": ckv, "k_rope": krc, "pos_k": pc}

    proj = jnp.einsum("bshk,hkd->bsd", out, params["wo"])
    return proj, new_cache


# ----------------------------------------------------------------------- MLP
def init_mlp(key, d_model, d_ff, dtype):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "w_gate": _init(k1, (d_model, d_ff), dtype=dtype),
        "w_up": _init(k2, (d_model, d_ff), dtype=dtype),
        "w_down": _init(k3, (d_ff, d_model), dtype=dtype),
    }


def mlp_apply(params, x):
    return swiglu(x, params["w_gate"], params["w_up"], params["w_down"])


# ----------------------------------------------------------------------- MoE
def init_moe(key, d_model, d_ff_expert, n_experts, n_shared, d_ff_shared, dtype):
    k1, k2, k3, k4, k5 = jax.random.split(key, 5)
    p = {
        "router": _init(k1, (d_model, n_experts), scale=0.02, dtype=jnp.float32),
        "w_gate": _init(k2, (n_experts, d_model, d_ff_expert), dtype=dtype),
        "w_up": _init(k3, (n_experts, d_model, d_ff_expert), dtype=dtype),
        "w_down": _init(k4, (n_experts, d_ff_expert, d_model), dtype=dtype),
    }
    if n_shared:
        p["shared"] = init_mlp(k5, d_model, n_shared * d_ff_shared, dtype)
    return p


def moe_apply(params, x, *, top_k: int, capacity_factor: float = 1.25,
              ep_axis: Optional[str] = None, ep_size: int = 1):
    """Top-k MoE with capacity-based dispatch.

    Local form (ep_axis=None): experts computed locally (smoke tests,
    single device).  EP form: called inside ``shard_map``; the expert axis is
    sharded over ``ep_axis`` and tokens move via ``all_to_all`` — the
    production TPU dispatch (DESIGN.md §6).

    x: (B, S, D) -> (B, S, D).
    """
    B, S, D = x.shape
    E = params["router"].shape[-1]
    T = B * S
    xt = x.reshape(T, D)

    logits = jnp.einsum("td,de->te", xt.astype(jnp.float32), params["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    gate, eidx = lax.top_k(probs, top_k)  # (T,k)
    gate = (gate / jnp.clip(gate.sum(-1, keepdims=True), 1e-9)).astype(x.dtype)

    # --- capacity-slot assignment (per local shard) -------------------------
    # O(T·k log) argsort-based ranking instead of the O(T·k·E) one-hot
    # cumsum: at kimi-k2 scale the one-hot would be ~1 GB per layer.
    C = int(np.ceil(T * top_k / E * capacity_factor))
    C = max(C, top_k)
    flat_e = eidx.reshape(-1)  # (T*k,)
    order = jnp.argsort(flat_e, stable=True)
    se = flat_e[order]
    first = jnp.searchsorted(se, jnp.arange(E, dtype=se.dtype), side="left")
    slot_sorted = jnp.arange(T * top_k, dtype=jnp.int32) - first[se].astype(jnp.int32)
    slot = jnp.zeros_like(slot_sorted).at[order].set(slot_sorted)
    keep = slot < C
    dest = jnp.where(keep, flat_e * C + slot, E * C)  # overflow -> dropped row

    # scatter tokens into (E*C+1, D) dispatch buffer
    buf = jnp.zeros((E * C + 1, D), x.dtype)
    src = jnp.repeat(xt, top_k, axis=0)  # (T*k, D)
    buf = buf.at[dest].set(src)  # last row collects dropped tokens
    buf = buf[: E * C].reshape(E, C, D)

    if ep_axis is not None and ep_size > 1:
        # (E, C, D) -> experts sharded: each shard keeps E/ep experts,
        # gathering that expert's slots from every peer.  The all_to_all is
        # kept SYMMETRIC (split_axis == concat_axis): its transpose is
        # another symmetric all_to_all of identical shape, so the VJP is
        # well-defined; the axis shuffle is a local transpose instead.
        buf = buf.reshape(ep_size, E // ep_size, C, D)
        buf = lax.all_to_all(buf, ep_axis, split_axis=0, concat_axis=0,
                             tiled=False)
        # [j, e, c] = peer j's slot c for my local expert e
        buf = buf.transpose(1, 0, 2, 3).reshape(E // ep_size, ep_size * C, D)

    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, params["w_gate"]))
    h = h * jnp.einsum("ecd,edf->ecf", buf, params["w_up"])
    out_buf = jnp.einsum("ecf,efd->ecd", h, params["w_down"])

    if ep_axis is not None and ep_size > 1:
        out_buf = out_buf.reshape(E // ep_size, ep_size, C, D)
        out_buf = out_buf.transpose(1, 0, 2, 3)  # (ep, E/ep, C, D)
        out_buf = lax.all_to_all(out_buf, ep_axis, split_axis=0,
                                 concat_axis=0, tiled=False)
        out_buf = out_buf.reshape(E, C, D)

    # gather back + weighted combine
    out_flat = out_buf.reshape(E * C, D)
    out_flat = jnp.concatenate([out_flat, jnp.zeros((1, D), x.dtype)], axis=0)
    tok_out = out_flat[dest].reshape(T, top_k, D)
    y = jnp.einsum("tkd,tk->td", tok_out, gate.astype(tok_out.dtype))
    # NOTE: the shared-expert MLP (if any) is applied *outside* this function
    # (model.py), at jit level, so it gets TP sharding instead of being
    # replicated across the EP shard_map region.
    return y.reshape(B, S, D)


# -------------------------------------------------------------------- Mamba1
def init_mamba(key, d_model, *, d_state, d_conv, expand, dt_rank, dtype):
    d_inner = expand * d_model
    ks = jax.random.split(key, 7)
    return {
        "in_proj": _init(ks[0], (d_model, 2 * d_inner), dtype=dtype),
        "conv_w": _init(ks[1], (d_conv, d_inner), scale=0.5, dtype=dtype),
        "conv_b": jnp.zeros((d_inner,), dtype),
        "x_proj": _init(ks[2], (d_inner, dt_rank + 2 * d_state), dtype=dtype),
        "dt_proj": _init(ks[3], (dt_rank, d_inner), dtype=dtype),
        "dt_bias": jnp.full((d_inner,), -4.0, dtype),  # softplus ~= small dt
        "A_log": jnp.log(jnp.broadcast_to(
            jnp.arange(1, d_state + 1, dtype=jnp.float32), (d_inner, d_state))).astype(dtype),
        "D": jnp.ones((d_inner,), dtype),
        "out_proj": _init(ks[4], (d_inner, d_model), dtype=dtype),
    }


def _ssm_chunk_scan(dA, dBx, h0, chunk: int):
    """Chunked linear scan h_t = dA_t * h_{t-1} + dBx_t over axis 1.

    dA, dBx: (B, S, Di, N); h0: (B, Di, N).  Returns (ys, h_final).
    Materializes only one (B, chunk, Di, N) block at a time (TPU adaptation
    of the fused Mamba GPU kernel — DESIGN.md §2).
    """
    B, S, Di, N = dA.shape
    n_chunks = S // chunk
    dA = dA.reshape(B, n_chunks, chunk, Di, N)
    dBx = dBx.reshape(B, n_chunks, chunk, Di, N)

    def outer(h, blk):
        a, bx = blk  # (B, chunk, Di, N)
        # within-chunk associative scan on (a, b) pairs
        def comb(lhs, rhs):
            return (lhs[0] * rhs[0], rhs[0] * lhs[1] + rhs[1])
        aa, bb = lax.associative_scan(comb, (a, bx), axis=1)
        hs = aa * h[:, None] + bb  # (B, chunk, Di, N)
        return hs[:, -1], hs

    h_last, ys = lax.scan(outer, h0, (dA.transpose(1, 0, 2, 3, 4),
                                      dBx.transpose(1, 0, 2, 3, 4)))
    ys = ys.transpose(1, 0, 2, 3, 4).reshape(B, S, Di, N)
    return ys, h_last


def mamba_apply(params, x, *, d_state: int, d_conv: int, chunk: int = 256,
                cache=None, decode=False):
    """Mamba-1 selective SSM. x: (B,S,D).

    Cache (decode): {"conv": (B, d_conv-1, Di), "h": (B, Di, N)}.
    """
    B, S, D = x.shape
    d_inner = params["in_proj"].shape[-1] // 2
    dt_rank = params["dt_proj"].shape[0]

    xz = jnp.einsum("bsd,de->bse", x, params["in_proj"])
    xin, z = xz[..., :d_inner], xz[..., d_inner:]

    # causal depthwise conv (kernel d_conv)
    if not decode:
        pad = jnp.zeros((B, d_conv - 1, d_inner), xin.dtype)
        xpad = jnp.concatenate([pad, xin], axis=1)
        conv = sum(xpad[:, i:i + S] * params["conv_w"][i]
                   for i in range(d_conv))
        new_conv_state = xpad[:, S:S + d_conv - 1] if S >= d_conv - 1 else None
        if cache is not None and new_conv_state is None:
            new_conv_state = jnp.concatenate([cache["conv"], xin], 1)[:, -(d_conv - 1):]
    else:
        hist = jnp.concatenate([cache["conv"], xin], axis=1)  # (B, d_conv, Di)
        conv = jnp.einsum("bki,ki->bi", hist, params["conv_w"])[:, None, :]
        new_conv_state = hist[:, 1:]
    conv = jax.nn.silu(conv + params["conv_b"])

    proj = jnp.einsum("bsi,ir->bsr", conv, params["x_proj"])
    dt_r = proj[..., :dt_rank]
    Bmat = proj[..., dt_rank:dt_rank + d_state]           # (B,S,N)
    Cmat = proj[..., dt_rank + d_state:]                   # (B,S,N)
    dt = jax.nn.softplus(jnp.einsum("bsr,ri->bsi", dt_r, params["dt_proj"])
                         + params["dt_bias"])              # (B,S,Di)
    A = -jnp.exp(params["A_log"].astype(jnp.float32))      # (Di,N)

    dA = jnp.exp(dt[..., None] * A)                        # (B,S,Di,N) f32
    # keep the recurrence in f32: mixed bf16/f32 leaves break
    # associative_scan's internal concatenate, and the state accumulates.
    dBx = ((dt * conv)[..., None] * Bmat[:, :, None, :]).astype(dA.dtype)

    if not decode:
        h0 = jnp.zeros((B, d_inner, d_state), dA.dtype)
        if S % chunk == 0 and S >= chunk:
            hs, h_last = _ssm_chunk_scan(dA, dBx, h0, chunk)
        else:
            def step(h, ab):
                a, bx = ab
                h = a * h + bx
                return h, h
            h_last, hs = lax.scan(step, h0, (dA.transpose(1, 0, 2, 3),
                                             dBx.transpose(1, 0, 2, 3)))
            hs = hs.transpose(1, 0, 2, 3)
        y = jnp.einsum("bsin,bsn->bsi", hs, Cmat)
    else:
        h = cache["h"] * dA[:, 0] + dBx[:, 0]
        y = jnp.einsum("bin,bn->bi", h, Cmat[:, 0])[:, None, :]
        h_last = h

    y = y + conv * params["D"]
    y = y * jax.nn.silu(z)
    out = jnp.einsum("bsi,id->bsd", y.astype(x.dtype), params["out_proj"])
    new_cache = None
    if cache is not None:
        new_cache = {"conv": new_conv_state.astype(cache["conv"].dtype),
                     "h": h_last.astype(cache["h"].dtype)}
    return out, new_cache
