"""Composable model definition covering all assigned architecture families:

dense decoder (GQA, optional sliding-window / local:global patterns),
MLA (DeepSeek), MoE (top-k + shared experts, optional expert parallelism),
Mamba-1 SSM, hybrid interleaves (Jamba), encoder–decoder (Whisper) and
stub-fronted multimodal backbones (InternVL, Whisper audio).

Depth heterogeneity is expressed as ``blocks = ((pattern, repeats), ...)``:
each *pattern* is a tuple of LayerSpec applied in order, and the pattern is
``lax.scan``-ned over ``repeats`` (one compile of the pattern per group — a
necessity at 61-layer/512-device scale).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.models import layers as L


@dataclasses.dataclass(frozen=True)
class LayerSpec:
    kind: str = "attn"          # 'attn' | 'mla' | 'mamba'
    window: Optional[int] = None  # None = global attention
    mlp: str = "dense"          # 'dense' | 'moe'
    cross_attn: bool = False    # enc-dec decoder layers


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int
    blocks: tuple  # ((pattern: tuple[LayerSpec, ...], repeats: int), ...)
    kind: str = "decoder"       # 'decoder' | 'encdec'
    n_enc_layers: int = 0
    # --- MoE ---
    n_experts: int = 0
    top_k: int = 0
    n_shared: int = 0
    d_ff_expert: int = 0
    capacity_factor: float = 1.25
    # --- MLA ---
    kv_lora: int = 0
    d_nope: int = 0
    d_rope: int = 0
    # --- SSM ---
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2
    dt_rank: int = 0
    # --- misc ---
    rope_theta: float = 10000.0
    use_rope: bool = True
    max_seq: int = 131072
    frontend: str = "none"      # 'none' | 'audio_stub' | 'vision_stub'
    frontend_len: int = 0
    tie_embeddings: bool = True
    param_dtype: str = "float32"
    compute_dtype: str = "float32"
    remat: str = "none"         # 'none' | 'full' | 'dots'
    moe_ep: bool = False        # expert parallelism over the 'model' mesh axis
    scan_unroll: int = 1        # 1=scan, 0=full unroll (cost measurement)
    # --- distribution knobs (§Perf hillclimb; see launch/steps.py) ---
    seq_parallel: bool = False  # Megatron-SP: shard saved hiddens' seq axis
    seq_shard_kv: bool = False  # flash-decode: shard cache seq over 'model'
                                # when KV heads don't divide the TP degree
    serve_params_tp_only: bool = False  # serving: weights TP-sharded and
                                # replicated over DP (no per-step FSDP
                                # all-gather; right when params/TP fit HBM)

    @property
    def n_layers(self) -> int:
        return sum(len(p) * r for p, r in self.blocks)

    @property
    def d_inner(self) -> int:
        return self.expand * self.d_model

    @property
    def dt_rank_eff(self) -> int:
        return self.dt_rank or max(1, self.d_model // 16)

    def param_count(self) -> tuple[int, int]:
        """(total, active) parameter counts — analytic, for 6ND roofline."""
        D, V = self.d_model, self.vocab_size
        emb = V * D
        total = emb if self.tie_embeddings else 2 * emb
        active = total
        for pattern, reps in self.blocks:
            for spec in pattern:
                t = a = 2 * D if spec.mlp != "none" else D  # norms
                if spec.kind == "attn":
                    t += D * (self.n_heads + 2 * self.n_kv_heads) * self.head_dim
                    t += self.n_heads * self.head_dim * D
                    a = t
                elif spec.kind == "mla":
                    t += D * self.n_heads * (self.d_nope + self.d_rope)
                    t += D * (self.kv_lora + self.d_rope)
                    t += self.kv_lora * self.n_heads * (self.d_nope + self.head_dim)
                    t += self.n_heads * self.head_dim * D
                    a = t
                elif spec.kind == "mamba":
                    di = self.d_inner
                    t += D * 2 * di + self.d_conv * di + di * (self.dt_rank_eff + 2 * self.d_state)
                    t += self.dt_rank_eff * di + di * D
                    a = t
                if spec.mlp == "dense":
                    t += 3 * D * self.d_ff
                    a = t
                else:
                    routed = 3 * D * self.d_ff_expert
                    t += self.n_experts * routed + D * self.n_experts
                    a += self.top_k * routed + D * self.n_experts
                    if self.n_shared:
                        sh = 3 * D * (self.n_shared * self.d_ff_expert)
                        t += sh
                        a += sh
                if spec.cross_attn:
                    ca = D * 2 * self.n_heads * self.head_dim * 2 + D
                    t += ca
                    a += ca
                total += t * reps
                active += a * reps
        # encoder (whisper): plain dense attention layers
        if self.kind == "encdec":
            per = 2 * D + D * 3 * self.n_heads * self.head_dim + \
                self.n_heads * self.head_dim * D + 3 * D * self.d_ff
            total += per * self.n_enc_layers
            active += per * self.n_enc_layers
        return total, active


# ------------------------------------------------------------------ init
def _init_layer(key, spec: LayerSpec, cfg: ModelConfig, dtype):
    ks = jax.random.split(key, 8)
    p = {"norm1": jnp.zeros((cfg.d_model,), dtype)}
    if spec.mlp != "none":
        p["norm2"] = jnp.zeros((cfg.d_model,), dtype)
    if spec.kind == "attn":
        p["attn"] = L.init_attention(ks[0], cfg.d_model, cfg.n_heads,
                                     cfg.n_kv_heads, cfg.head_dim, dtype)
    elif spec.kind == "mla":
        p["attn"] = L.init_mla(ks[0], cfg.d_model, cfg.n_heads,
                               kv_lora=cfg.kv_lora, d_nope=cfg.d_nope,
                               d_rope=cfg.d_rope, d_v=cfg.head_dim, dtype=dtype)
    elif spec.kind == "mamba":
        p["attn"] = L.init_mamba(ks[0], cfg.d_model, d_state=cfg.d_state,
                                 d_conv=cfg.d_conv, expand=cfg.expand,
                                 dt_rank=cfg.dt_rank_eff, dtype=dtype)
    if spec.mlp == "dense":
        p["mlp"] = L.init_mlp(ks[1], cfg.d_model, cfg.d_ff, dtype)
    elif spec.mlp == "moe":
        p["mlp"] = L.init_moe(ks[1], cfg.d_model, cfg.d_ff_expert,
                              cfg.n_experts, cfg.n_shared, cfg.d_ff_expert, dtype)
    if spec.cross_attn:
        p["normc"] = jnp.zeros((cfg.d_model,), dtype)
        p["cross"] = L.init_attention(ks[2], cfg.d_model, cfg.n_heads,
                                      cfg.n_heads, cfg.head_dim, dtype)
    return p


def init_params(key, cfg: ModelConfig):
    dtype = jnp.dtype(cfg.param_dtype)
    keys = jax.random.split(key, 8)
    params: dict[str, Any] = {
        "embed": L._init(keys[0], (cfg.vocab_size, cfg.d_model),
                         scale=0.02, dtype=dtype),
        "final_norm": jnp.zeros((cfg.d_model,), dtype),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = L._init(keys[1], (cfg.d_model, cfg.vocab_size),
                                    dtype=dtype)
    if not cfg.use_rope:
        params["pos_embed"] = L._init(keys[2], (cfg.max_seq, cfg.d_model),
                                      scale=0.02, dtype=dtype)

    def init_group(key, pattern, repeats):
        def one(k):
            kk = jax.random.split(k, len(pattern))
            return tuple(_init_layer(kk[i], spec, cfg, dtype)
                         for i, spec in enumerate(pattern))
        return jax.vmap(one)(jax.random.split(key, repeats))

    gkeys = jax.random.split(keys[3], len(cfg.blocks))
    params["groups"] = [init_group(gkeys[i], pattern, reps)
                        for i, (pattern, reps) in enumerate(cfg.blocks)]

    if cfg.kind == "encdec":
        enc_spec = LayerSpec(kind="attn", window=None, mlp="dense")
        ekeys = jax.random.split(keys[4], cfg.n_enc_layers)
        params["enc"] = {
            "groups": [jax.vmap(lambda k: (_init_layer(k, enc_spec, cfg, dtype),))(ekeys)],
            "final_norm": jnp.zeros((cfg.d_model,), dtype),
            "pos_embed": L._init(keys[5], (cfg.max_seq, cfg.d_model),
                                 scale=0.02, dtype=dtype),
        }
    return params


# ------------------------------------------------------------------ cache
def init_cache(cfg: ModelConfig, batch: int, s_max: int, dtype=jnp.bfloat16,
               enc_len: int = 0):
    """Decode cache pytree mirroring params['groups'] structure."""
    neg = jnp.iinfo(jnp.int32).max

    def layer_cache(spec: LayerSpec):
        if spec.kind == "mamba":
            c = {"conv": jnp.zeros((batch, cfg.d_conv - 1, cfg.d_inner), dtype),
                 "h": jnp.zeros((batch, cfg.d_inner, cfg.d_state), jnp.float32)}
        elif spec.kind == "mla":
            c = {"c_kv": jnp.zeros((batch, s_max, cfg.kv_lora), dtype),
                 "k_rope": jnp.zeros((batch, s_max, cfg.d_rope), dtype),
                 "pos_k": jnp.full((batch, s_max), neg, jnp.int32)}
        else:
            Ck = min(s_max, spec.window) if spec.window else s_max
            c = {"k": jnp.zeros((batch, Ck, cfg.n_kv_heads, cfg.head_dim), dtype),
                 "v": jnp.zeros((batch, Ck, cfg.n_kv_heads, cfg.head_dim), dtype),
                 "pos_k": jnp.full((batch, Ck), neg, jnp.int32)}
        if spec.cross_attn:
            c["ck"] = jnp.zeros((batch, enc_len, cfg.n_heads, cfg.head_dim), dtype)
            c["cv"] = jnp.zeros((batch, enc_len, cfg.n_heads, cfg.head_dim), dtype)
        return c

    def rep(tree, n):
        return jax.tree.map(lambda x: jnp.broadcast_to(x, (n,) + x.shape), tree)

    return [rep(tuple(layer_cache(s) for s in pattern), reps)
            for pattern, reps in cfg.blocks]


# ------------------------------------------------------------------ forward
def _apply_layer(lp, spec: LayerSpec, cfg: ModelConfig, x, positions,
                 cache=None, decode=False, enc_out=None, mesh=None):
    h = L.rms_norm(x, lp["norm1"])
    if spec.kind == "mamba":
        out, new_c = L.mamba_apply(lp["attn"], h, d_state=cfg.d_state,
                                   d_conv=cfg.d_conv, cache=cache, decode=decode)
    elif spec.kind == "mla":
        out, new_c = L.mla_attention(lp["attn"], h, positions,
                                     d_nope=cfg.d_nope, d_rope=cfg.d_rope,
                                     rope_theta=cfg.rope_theta,
                                     cache=cache, decode=decode)
    else:
        out, new_c = L.attention(lp["attn"], h, positions,
                                 n_rep=cfg.n_heads // cfg.n_kv_heads,
                                 window=spec.window, rope_theta=cfg.rope_theta,
                                 use_rope=cfg.use_rope, cache=cache,
                                 decode=decode)
    x = x + out

    if spec.cross_attn:
        h = L.rms_norm(x, lp["normc"])
        if decode:
            ck, cv = cache["ck"], cache["cv"]
            q = jnp.einsum("bsd,dhk->bshk", h, lp["cross"]["wq"])
            s = jnp.einsum("bshk,bthk->bsht", q, ck,
                           preferred_element_type=jnp.float32)
            s = s / np.sqrt(cfg.head_dim)
            p = jax.nn.softmax(s, axis=-1)
            o = jnp.einsum("bsht,bthk->bshk", p, cv).astype(h.dtype)
            out = jnp.einsum("bshk,hkd->bsd", o, lp["cross"]["wo"])
            new_c = dict(new_c or {}, ck=ck, cv=cv)
        else:
            q = jnp.einsum("bsd,dhk->bshk", h, lp["cross"]["wq"])
            ck = jnp.einsum("btd,dhk->bthk", enc_out, lp["cross"]["wk"])
            cv = jnp.einsum("btd,dhk->bthk", enc_out, lp["cross"]["wv"])
            s = jnp.einsum("bshk,bthk->bsht", q, ck,
                           preferred_element_type=jnp.float32)
            s = s / np.sqrt(cfg.head_dim)
            p = jax.nn.softmax(s, axis=-1)
            o = jnp.einsum("bsht,bthk->bshk", p, cv).astype(h.dtype)
            out = jnp.einsum("bshk,hkd->bsd", o, lp["cross"]["wo"])
            if new_c is not None:
                new_c = dict(new_c, ck=ck.astype(x.dtype), cv=cv.astype(x.dtype))
        x = x + out

    if spec.mlp != "none":
        h = L.rms_norm(x, lp["norm2"])
        if spec.mlp == "dense":
            out = L.mlp_apply(lp["mlp"], h)
        else:
            out = _moe(lp["mlp"], h, cfg, mesh)
            if "shared" in lp["mlp"]:
                out = out + L.mlp_apply(lp["mlp"]["shared"], h)
        x = x + out
    return x, new_c


def _moe(mp, h, cfg: ModelConfig, mesh):
    routed = {k: mp[k] for k in ("router", "w_gate", "w_up", "w_down")}
    if cfg.moe_ep and mesh is not None and "model" in mesh.axis_names:
        from jax.sharding import PartitionSpec as P
        ep = mesh.shape["model"]
        dp = tuple(a for a in mesh.axis_names if a != "model")
        fn = partial(L.moe_apply, top_k=cfg.top_k,
                     capacity_factor=cfg.capacity_factor,
                     ep_axis="model", ep_size=ep)
        from repro.launch.mesh import shard_map
        return shard_map(
            fn, mesh=mesh,
            in_specs=({"router": P(), "w_gate": P("model"), "w_up": P("model"),
                       "w_down": P("model")}, P(dp)),
            out_specs=P(dp),
            check_vma=False,
        )(routed, h)
    return L.moe_apply(routed, h, top_k=cfg.top_k,
                       capacity_factor=cfg.capacity_factor)


def _run_groups(groups_params, blocks, cfg, x, positions, caches=None,
                decode=False, enc_out=None, mesh=None, want_cache=False):
    """Scan each homogeneous (pattern × repeats) group."""
    new_caches = []
    for gi, (pattern, reps) in enumerate(blocks):
        gp = groups_params[gi]
        cache_g = caches[gi] if caches is not None else None

        def body(xc, inp):
            x = xc
            lps, cs = inp if cache_g is not None else (inp, None)
            ncs = []
            for pi, spec in enumerate(pattern):
                c = cs[pi] if cs is not None else None
                x, nc = _apply_layer(lps[pi], spec, cfg, x, positions,
                                     cache=c, decode=decode, enc_out=enc_out,
                                     mesh=mesh)
                ncs.append(nc)
            y = tuple(ncs) if (want_cache or decode) else None
            return x, y

        if cfg.seq_parallel:
            # Megatron-SP: pin the layer-boundary hidden (what remat saves
            # and the scan carries) to a sequence-sharded layout.
            from repro.launch.shardctx import constrain
            inner_body = body

            def body(xc, inp):  # noqa: F811
                x, y = inner_body(xc, inp)
                return constrain(x, "hidden_sp"), y

        if cfg.remat == "full":
            body = jax.checkpoint(body)
        elif cfg.remat == "dots":
            body = jax.checkpoint(
                body, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)

        xs = (gp, cache_g) if cache_g is not None else gp
        unroll = reps if cfg.scan_unroll == 0 else min(cfg.scan_unroll, reps)
        x, ys = lax.scan(body, x, xs, unroll=unroll)
        new_caches.append(ys)
    return x, (new_caches if (want_cache or decode) else None)


def forward(params, cfg: ModelConfig, tokens=None, *, embeds=None,
            positions=None, caches=None, mode: str = "train",
            enc_frames=None, mesh=None):
    """Forward pass.

    mode='train'   : full-sequence causal logits.
    mode='prefill' : as train, but fills and returns the decode cache.
    mode='decode'  : tokens (B,1) against ``caches``; positions (B,1).
    """
    cdt = jnp.dtype(cfg.compute_dtype)
    decode = mode == "decode"
    want_cache = mode == "prefill"

    parts = []
    if embeds is not None:  # vision stub prefix (B, Lv, D)
        parts.append(embeds.astype(cdt))
    if tokens is not None:
        parts.append(params["embed"].astype(cdt)[tokens])
    x = jnp.concatenate(parts, axis=1) if len(parts) > 1 else parts[0]
    from repro.launch.shardctx import constrain
    x = constrain(x, "hidden")
    B, S, _ = x.shape

    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    if not cfg.use_rope:
        pe = params["pos_embed"].astype(cdt)[positions]
        x = x + pe

    enc_out = None
    if cfg.kind == "encdec" and not decode:
        ef = enc_frames.astype(cdt)
        Te = ef.shape[1]
        epos = jnp.broadcast_to(jnp.arange(Te, dtype=jnp.int32), (B, Te))
        e = ef + params["enc"]["pos_embed"].astype(cdt)[epos]
        enc_blocks = (((LayerSpec(kind="attn", window=None, mlp="dense"),),
                       cfg.n_enc_layers),)
        # encoder is bidirectional: give every position visibility via a
        # window=None non-causal path — reuse attention with positions all
        # equal so the causal mask passes everywhere.
        e, _ = _run_groups(params["enc"]["groups"], enc_blocks, cfg, e,
                           jnp.zeros((B, Te), jnp.int32), mesh=mesh)
        enc_out = L.rms_norm(e, params["enc"]["final_norm"])

    x, new_caches = _run_groups(params["groups"], cfg.blocks, cfg, x,
                                positions, caches=caches, decode=decode,
                                enc_out=enc_out, mesh=mesh,
                                want_cache=want_cache)

    x = L.rms_norm(x, params["final_norm"])
    x = constrain(x, "hidden")
    head = (params["embed"].T if cfg.tie_embeddings
            else params["lm_head"]).astype(cdt)
    logits = jnp.einsum("bsd,dv->bsv", x, head)
    logits = constrain(logits, "logits")
    if decode or want_cache:
        return logits, new_caches
    return logits


def lm_loss(params, cfg: ModelConfig, batch, mesh=None):
    """Next-token cross entropy. batch['tokens']: (B, S+1) int32."""
    tokens = batch["tokens"]
    inputs, targets = tokens[:, :-1], tokens[:, 1:]
    kw = {}
    if cfg.frontend == "vision_stub":
        kw["embeds"] = batch["patch_embeds"]
    if cfg.kind == "encdec":
        kw["enc_frames"] = batch["audio_frames"]
    logits = forward(params, cfg, inputs, mesh=mesh, **kw)
    if cfg.frontend == "vision_stub":  # text logits follow the vision prefix
        logits = logits[:, -targets.shape[1]:]
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    mask = (targets >= 0).astype(jnp.float32)
    loss = jnp.sum((lse - ll) * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return loss
