"""Vectorized Metropolis sweeps (the paper's inner loop, Listing 2/4).

A sweep runs ``n_steps`` Metropolis iterations at a fixed temperature ``T``
for a whole *batch* of chains at once: ``x`` has shape ``(chains, dim)``.
This is the TPU adaptation of the CUDA one-thread-per-chain design — chains
are SIMD lanes, the accept/reject branch is a branchless masked select
(DESIGN.md §2).

Two implementations:

* :func:`sweep_full`  — paper-faithful: every proposal evaluates the full
  objective, O(dim) work per step per chain.
* :func:`sweep_delta` — beyond-paper: for decomposable objectives, maintains
  sum/product accumulators and applies an O(1) update per step.  Exactly
  equivalent in accepted-point trajectory for identical random streams
  (validated in tests up to float tolerance).

Both use three uniforms per step, exactly as the paper prescribes (coordinate
pick, replacement value, acceptance draw).
"""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.objectives.base import DecomposableSpec, Objective


def _proposal(key_d, key_u, x, lo, hi):
    """Paper's ComputeNeighbour: replace one random coordinate with a fresh
    uniform draw over that coordinate's box interval."""
    chains, dim = x.shape
    d = jax.random.randint(key_d, (chains,), 0, dim)
    u = jax.random.uniform(key_u, (chains,), dtype=x.dtype)
    newval = lo[d] + u * (hi[d] - lo[d])
    return d, newval


def _accept(key_a, f0, f1, T):
    """Metropolis criterion, branchless. Accepts downhill moves always
    (exp(+) >= 1 >= u) and uphill with probability exp(-df/T).

    ``T`` may be a scalar (one annealing job) or a ``(chains,)`` array —
    per-chain temperatures, used by the multi-tenant serving engine where
    co-batched chains belong to requests at different ladder depths."""
    u = jax.random.uniform(key_a, f0.shape, dtype=f0.dtype)
    # Clamp the exponent to avoid inf-inf NaNs under extreme df/T.
    ratio = jnp.exp(jnp.clip(-(f1 - f0) / T, -80.0, 80.0))
    return u <= ratio


@partial(jax.jit, static_argnames=("objective", "n_steps", "unroll"))
def sweep_full(key, x, fx, T, *, objective: Objective, n_steps: int,
               unroll: bool = False):
    """Paper-faithful Metropolis sweep with full objective evaluation.

    ``T``: scalar or (chains,) per-chain temperature array."""
    lo, hi = objective.bounds
    lo = lo.astype(x.dtype)
    hi = hi.astype(x.dtype)
    chains = x.shape[0]
    rows = jnp.arange(chains)

    def body(i, carry):
        key, x, fx = carry
        key, kd, ku, ka = jax.random.split(key, 4)
        d, newval = _proposal(kd, ku, x, lo, hi)
        x1 = x.at[rows, d].set(newval)
        f1 = objective(x1)
        acc = _accept(ka, fx, f1, T)
        x = jnp.where(acc[:, None], x1, x)
        fx = jnp.where(acc, f1, fx)
        return key, x, fx

    carry = (key, x, fx)
    if unroll:  # cost-measurement mode (see launch/dryrun.py)
        for i in range(n_steps):
            carry = body(i, carry)
        key, x, fx = carry
    else:
        key, x, fx = lax.fori_loop(0, n_steps, body, carry)
    return key, x, fx


@partial(jax.jit, static_argnames=("objective", "n_steps", "unroll"))
def sweep_delta(key, x, fx, T, *, objective: Objective, n_steps: int,
                unroll: bool = False):
    """O(1)-per-step sweep for decomposable objectives.

    Accumulators are refreshed (recomputed exactly) at sweep entry, so fp
    drift from incremental updates is bounded by one temperature level.
    ``T``: scalar or (chains,) per-chain temperature array.
    """
    spec: Optional[DecomposableSpec] = objective.decomposable
    assert spec is not None, f"{objective.name} has no decomposable structure"
    lo, hi = objective.bounds
    lo = lo.astype(x.dtype)
    hi = hi.astype(x.dtype)
    chains, dim = x.shape
    rows = jnp.arange(chains)

    S, (logP, sgnP) = spec.init_acc(x)
    fx = spec.value(S, (logP, sgnP), dim)  # refresh f from exact accumulators

    def term_at(xi, d):
        s, p = spec.terms(xi, d)
        return s, p

    def body(i, carry):
        key, x, fx, S, logP, sgnP = carry
        key, kd, ku, ka = jax.random.split(key, 4)
        d, newval = _proposal(kd, ku, x, lo, hi)
        xi_old = x[rows, d]
        s_old, p_old = term_at(xi_old, d)
        s_new, p_new = term_at(newval, d)
        S1 = S - s_old + s_new
        la_old = jnp.log(jnp.maximum(jnp.abs(p_old), 1e-30))
        la_new = jnp.log(jnp.maximum(jnp.abs(p_new), 1e-30))
        logP1 = logP - la_old + la_new
        sg = jnp.where(p_old < 0, -1.0, 1.0) * jnp.where(p_new < 0, -1.0, 1.0)
        sgnP1 = sgnP * sg.astype(sgnP.dtype)
        f1 = spec.value(S1, (logP1, sgnP1), dim)
        acc = _accept(ka, fx, f1, T)
        accc = acc[:, None]
        x = x.at[rows, d].set(jnp.where(acc, newval, xi_old))
        fx = jnp.where(acc, f1, fx)
        S = jnp.where(accc, S1, S)
        logP = jnp.where(accc, logP1, logP)
        sgnP = jnp.where(accc, sgnP1, sgnP)
        return key, x, fx, S, logP, sgnP

    carry = (key, x, fx, S, logP, sgnP)
    if unroll:  # cost-measurement mode
        for i in range(n_steps):
            carry = body(i, carry)
        key, x, fx, *_ = carry
    else:
        key, x, fx, *_ = lax.fori_loop(0, n_steps, body, carry)
    return key, x, fx
