"""Simulated annealing driver (paper §2): V0 sequential, V1 asynchronous,
V2 synchronous — all as one configurable engine.

The CUDA design launches one kernel per temperature level (V2) or one kernel
for the whole ladder (V1).  On TPU we compile the *entire* annealing ladder
into a single XLA program: ``lax.scan`` over the geometric temperature
ladder, each step being a Metropolis sweep + (optional) exchange collective.
This removes the per-level host round trip entirely (DESIGN.md §8.1).

Communication semantics are faithful to the paper:
* ``async`` (V1): zero communication until a single final champion reduce.
* ``sync``  (V2): one champion all-gather per temperature level.
* best-so-far tracking is purely local; the final reduce folds it in.
"""
from __future__ import annotations

import dataclasses
import math
from functools import partial
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.core import exchange as exch
from repro.core import metropolis
from repro.objectives.base import Objective


@dataclasses.dataclass(frozen=True)
class SAConfig:
    """Annealing schedule + parallelization configuration (paper notation)."""

    T0: float = 1000.0          # initial temperature
    T_min: float = 0.01         # target (stop) temperature
    rho: float = 0.99           # geometric cooling factor
    N: int = 100                # Markov chain length per level
    n_chains: int = 16384       # w: number of parallel chains (b*g in paper)
    exchange: str = "sync"      # 'async' (V1) | 'sync' (V2) | 'sos'
    exchange_period: int = 1    # levels between exchanges (1 = every level)
    seed: int = 0
    dtype: str = "float32"      # paper Table 7: fp32 default
    use_delta_eval: bool = False  # beyond-paper O(1) delta evaluation
    record_history: bool = True   # per-level champion trace (plots/benchmarks)
    unroll: bool = False          # unroll ladder+sweeps (cost measurement)

    @property
    def n_levels(self) -> int:
        """Number of executed temperature levels (paper's do/while loop)."""
        return max(1, int(math.ceil(math.log(self.T_min / self.T0)
                                    / math.log(self.rho))))

    @property
    def n_evals(self) -> int:
        """Total objective evaluations (paper's 'function evaluations')."""
        return self.n_levels * self.N * self.n_chains

    def ladder(self) -> np.ndarray:
        k = np.arange(self.n_levels)
        return (self.T0 * self.rho ** k).astype(self.dtype)


@dataclasses.dataclass
class SAResult:
    x_best: np.ndarray        # (dim,)
    f_best: float
    history_f: Optional[np.ndarray]  # per-level champion objective value
    n_evals: int
    config: SAConfig
    objective_name: str = ""


def _level_body(carry, xs, *, objective, cfg: SAConfig, axis_names):
    """One temperature level: Metropolis sweep of length N, then exchange."""
    T, lvl = xs
    key, x, fx, best_x, best_f = carry
    sweep = metropolis.sweep_delta if cfg.use_delta_eval else metropolis.sweep_full
    key, x, fx = sweep(key, x, fx, T, objective=objective, n_steps=cfg.N,
                       unroll=cfg.unroll)

    key, kx = jax.random.split(key)
    if cfg.exchange != "async":
        exchange_fn = exch.EXCHANGES[cfg.exchange]
        if cfg.exchange_period > 1:
            do_ex = (lvl % cfg.exchange_period) == 0
            x2, fx2 = exchange_fn(kx, x, fx, T, axis_names)
            x = jnp.where(do_ex, x2, x)
            fx = jnp.where(do_ex, fx2, fx)
        else:
            x, fx = exchange_fn(kx, x, fx, T, axis_names)

    # Local best-so-far tracking (no communication; the final reduce is global).
    xb, fb = exch.local_champion(x, fx)
    better = fb < best_f
    best_x = jnp.where(better, xb, best_x)
    best_f = jnp.where(better, fb, best_f)

    y = best_f if cfg.record_history else ()
    return (key, x, fx, best_x, best_f), y


def _run_ladder(key, x0, *, objective: Objective, cfg: SAConfig,
                axis_names: Optional[Sequence[str]] = None):
    """Run the full annealing ladder on a local block of chains.

    Callable directly (single device) or inside ``shard_map`` (chains axis
    sharded over the mesh; ``axis_names`` names the mesh axes to reduce over).
    """
    ladder = jnp.asarray(cfg.ladder())
    levels = jnp.arange(cfg.n_levels, dtype=jnp.int32)
    fx = objective(x0)
    best_x, best_f = exch.local_champion(x0, fx)
    body = partial(_level_body, objective=objective, cfg=cfg, axis_names=axis_names)
    carry0 = (key, x0, fx, best_x, best_f)
    (key, x, fx, best_x, best_f), hist = lax.scan(
        body, carry0, (ladder, levels),
        unroll=cfg.n_levels if cfg.unroll else 1)

    # Single final champion reduce (the paper V1's reduceMin; a refinement
    # no-op for V2).  Folds the carried best into the candidate set.
    xa = jnp.concatenate([x, best_x[None, :]], axis=0)
    fa = jnp.concatenate([fx, best_f[None]], axis=0)
    best_x, best_f = exch.global_champion(xa, fa, axis_names)
    return best_x, best_f, hist


def sa_minimize(objective: Objective, cfg: SAConfig,
                key: Optional[jax.Array] = None,
                x0: Optional[jnp.ndarray] = None,
                mesh: Optional[jax.sharding.Mesh] = None,
                mesh_axes: Optional[Sequence[str]] = None) -> SAResult:
    """Minimize ``objective`` with parallel SA.

    Without ``mesh``: all chains run on the local default device.
    With ``mesh``: chains are sharded over ``mesh_axes`` via ``shard_map``;
    the exchange becomes a hierarchical champion all-gather (DESIGN.md §2).
    """
    if key is None:
        key = jax.random.PRNGKey(cfg.seed)
    dtype = jnp.dtype(cfg.dtype)

    key, k0 = jax.random.split(key)
    if x0 is None:
        x0c = objective.sample_uniform(k0, (cfg.n_chains,)).astype(dtype)
    else:
        x0c = jnp.broadcast_to(jnp.asarray(x0, dtype), (cfg.n_chains, objective.dim))

    if mesh is None:
        run = jax.jit(partial(_run_ladder, objective=objective, cfg=cfg))
        best_x, best_f, hist = run(key, x0c)
    else:
        run = jax.jit(build_sharded_ladder(objective, cfg, mesh, mesh_axes))
        best_x, best_f, hist = run(key, x0c)

    has_hist = cfg.record_history and not isinstance(hist, tuple)
    return SAResult(
        x_best=np.asarray(best_x),
        f_best=float(best_f),
        history_f=np.asarray(hist) if has_hist else None,
        n_evals=cfg.n_evals,
        config=cfg,
        objective_name=objective.name,
    )


def build_sharded_ladder(objective: Objective, cfg: SAConfig,
                         mesh: jax.sharding.Mesh,
                         mesh_axes: Optional[Sequence[str]] = None):
    """The shard_map'd annealing program: chains sharded over ``mesh_axes``.

    Returned callable takes (key, x0_global) and is what the multi-pod
    dry-run lowers (launch/dryrun.py, SA production cell).
    """
    from jax.sharding import PartitionSpec as P

    axes = tuple(mesh_axes if mesh_axes is not None else mesh.axis_names)
    n_shards = int(np.prod([mesh.shape[a] for a in axes]))
    if cfg.n_chains % n_shards:
        raise ValueError(
            f"n_chains={cfg.n_chains} not divisible by mesh size {n_shards}")

    # Distributed V1 must stay communication-free mid-run: a per-level global
    # history would contradict it, so disable history there (DESIGN.md §8).
    cfg_l = cfg
    if cfg.exchange == "async" and cfg.record_history:
        cfg_l = dataclasses.replace(cfg, record_history=False)

    def sharded(key, x0c):
        # Per-shard independent streams: fold the shard index in.
        idx = lax.axis_index(axes)
        key_local = jax.random.fold_in(key, idx)
        bx, bf, hist = _run_ladder(key_local, x0c, objective=objective,
                                   cfg=cfg_l, axis_names=axes)
        return bx, bf, hist

    hist_spec = P() if cfg_l.record_history else ()
    from repro.launch.mesh import shard_map
    return shard_map(
        sharded, mesh=mesh,
        in_specs=(P(), P(axes)),
        out_specs=(P(), P(), hist_spec),
        check_vma=False,
    )
