"""Box-constrained Nelder–Mead simplex minimizer, pure ``lax.while_loop``.

Used by the hybrid SA→NM strategy (paper §4.2, Table 10).  Standard
coefficients (reflection α=1, expansion γ=2, contraction β=0.5, shrink σ=0.5)
with candidate points clipped to the box.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Callable

import jax
import jax.numpy as jnp
from jax import lax


@dataclasses.dataclass
class NMResult:
    x_best: jnp.ndarray
    f_best: float
    n_iters: int
    converged: bool


def _order(simplex, fvals):
    idx = jnp.argsort(fvals)
    return simplex[idx], fvals[idx]


@partial(jax.jit, static_argnames=("fn", "max_iters"))
def _nm_loop(x0, lo, hi, *, fn: Callable, max_iters: int,
             fatol: float, xatol: float):
    # Initial simplex: x0 plus per-coordinate perturbations (5% of the box,
    # guarded to be nonzero).
    step = 0.05 * (hi - lo)
    simplex = jnp.concatenate(
        [x0[None, :], jnp.clip(x0[None, :] + jnp.diag(step), lo, hi)], axis=0
    )  # (n+1, n)
    fvals = jax.vmap(fn)(simplex)
    simplex, fvals = _order(simplex, fvals)

    def cond(state):
        simplex, fvals, it = state
        fspread = fvals[-1] - fvals[0]
        xspread = jnp.max(jnp.abs(simplex[1:] - simplex[0]))
        return (it < max_iters) & ((fspread > fatol) | (xspread > xatol))

    def body(state):
        simplex, fvals, it = state
        c = jnp.mean(simplex[:-1], axis=0)  # centroid of the best n
        worst = simplex[-1]
        f_best, f_second, f_worst = fvals[0], fvals[-2], fvals[-1]

        xr = jnp.clip(c + (c - worst), lo, hi)  # reflection
        fr = fn(xr)

        xe = jnp.clip(c + 2.0 * (c - worst), lo, hi)  # expansion
        fe = fn(xe)

        xc = jnp.clip(c + 0.5 * (worst - c), lo, hi)  # contraction
        fc = fn(xc)

        # Decision tree, branchless.
        do_expand = fr < f_best
        new_pt_er = jnp.where(do_expand & (fe < fr), xe, xr)
        new_f_er = jnp.where(do_expand & (fe < fr), fe, fr)
        use_reflect_like = fr < f_second
        do_contract = (~use_reflect_like) & (fc < f_worst)

        accept_point = use_reflect_like | do_contract
        new_pt = jnp.where(use_reflect_like, new_pt_er, xc)
        new_f = jnp.where(use_reflect_like, new_f_er, fc)

        simplex_acc = simplex.at[-1].set(new_pt)
        fvals_acc = fvals.at[-1].set(new_f)

        # Shrink toward the best vertex when nothing was accepted.
        shrunk = jnp.clip(simplex[0][None, :] + 0.5 * (simplex - simplex[0]), lo, hi)
        fshrunk = jax.vmap(fn)(shrunk)

        simplex = jnp.where(accept_point, simplex_acc, shrunk)
        fvals = jnp.where(accept_point, fvals_acc, fshrunk)
        simplex, fvals = _order(simplex, fvals)
        return simplex, fvals, it + 1

    simplex, fvals, it = lax.while_loop(cond, body, (simplex, fvals, jnp.zeros((), jnp.int32)))
    fspread = fvals[-1] - fvals[0]
    xspread = jnp.max(jnp.abs(simplex[1:] - simplex[0]))
    converged = (fspread <= fatol) & (xspread <= xatol)
    return simplex[0], fvals[0], it, converged


def nelder_mead(objective, x0, max_iters: int = 4000,
                fatol: float = 1e-10, xatol: float = 1e-10) -> NMResult:
    """Minimize ``objective`` (an ``Objective``) starting from ``x0``."""
    lo, hi = objective.bounds
    x0 = jnp.asarray(x0)
    lo = lo.astype(x0.dtype)
    hi = hi.astype(x0.dtype)
    xb, fb, it, conv = _nm_loop(
        x0, lo, hi, fn=objective.fn, max_iters=max_iters,
        fatol=fatol, xatol=xatol,
    )
    return NMResult(x_best=xb, f_best=float(fb), n_iters=int(it),
                    converged=bool(conv))
