"""Chain-exchange (crossover) operators for parallel SA.

The paper's V2 performs a deterministic *minimum crossover* at every
temperature level: all chains restart from the globally best state.  On the
GPU this is a Thrust reduce; on the TPU mesh it is a per-shard ``argmin``
followed by a tiny ``all_gather`` of per-shard champions — only
``devices × (dim + 1)`` floats move over the interconnect, exactly the
paper's "only function values are exchanged among workers".

Strategies
----------
``async``  : no exchange until the very end (paper V1).
``sync``   : minimum crossover each ``period`` levels (paper V2, period=1).
``sos``    : Synchronous with Occasional Solution exchanges (Onbasoglu &
             Özdamar [23]) — stochastic crossover: a chain adopts the
             champion only if better, or with Metropolis probability at the
             current temperature; keeps chain diversity.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax


def local_champion(x, fx):
    """Best (x, f) among the local chains."""
    i = jnp.argmin(fx)
    return x[i], fx[i]


def global_champion(x, fx, axis_names=None):
    """Champion across local chains and (optionally) mesh axes.

    Inside ``shard_map`` with ``axis_names`` set, gathers one champion per
    shard and reduces replicatedly (identical result on all shards).
    """
    xb, fb = local_champion(x, fx)
    if axis_names:
        # Tiny collective: (devices, dim+1) floats.
        fall = lax.all_gather(fb, axis_names, tiled=False)  # (shards,)
        xall = lax.all_gather(xb, axis_names, tiled=False)  # (shards, dim)
        fall = fall.reshape(-1)
        xall = xall.reshape(-1, x.shape[-1])
        j = jnp.argmin(fall)
        xb, fb = xall[j], fall[j]
    return xb, fb


def exchange_sync(key, x, fx, T, axis_names=None):
    """Paper V2: every chain restarts from the global champion."""
    xb, fb = global_champion(x, fx, axis_names)
    x = jnp.broadcast_to(xb[None, :], x.shape)
    fx = jnp.full_like(fx, fb)
    return x, fx


def exchange_sos(key, x, fx, T, axis_names=None):
    """Stochastic crossover: adopt champion if better, else with Metropolis
    probability exp(-(fb - fx)/T).  (fb <= fx always ⇒ adopting is always
    'downhill'; diversity is kept by *not* forcing adoption: each chain
    adopts only with probability 1/2 when the champion is not strictly
    better than its own state by more than T.)"""
    xb, fb = global_champion(x, fx, axis_names)
    u = jax.random.uniform(key, fx.shape, dtype=fx.dtype)
    # Probability of adoption grows with the deficit (fx - fb)/T.
    p = 1.0 - jnp.exp(jnp.clip(-(fx - fb) / jnp.maximum(T, 1e-30), -80.0, 0.0))
    adopt = u <= p
    x = jnp.where(adopt[:, None], xb[None, :], x)
    fx = jnp.where(adopt, fb, fx)
    return x, fx


def exchange_none(key, x, fx, T, axis_names=None):
    return x, fx


# ------------------------------------------------------------------ segmented
# Multi-tenant serving (service/engine.py): chains from several independent
# requests are packed into one device batch, so the champion reduce must be
# *masked per request* — a tenant's chains may only ever see their own
# champion, never another job's.  ``seg`` assigns every chain its request id.

def segment_champion(x, fx, seg, num_segments: int):
    """Per-segment (per-request) champion: masked argmin over each tenant.

    Args:
      x: (chains, dim) states; fx: (chains,) values.
      seg: (chains,) int32 segment id per chain, in [0, num_segments).
      num_segments: static segment count (the slot-pool size bounds it).

    Returns (xb (num_segments, dim), fb (num_segments,), ib (num_segments,)):
    champion state/value/chain-index per segment.  Segments with no chains
    get ``fb = +inf`` and ``ib = chains`` (out of range — check before use).
    """
    n = fx.shape[0]
    fb = jnp.full((num_segments,), jnp.inf, fx.dtype).at[seg].min(fx)
    # First chain attaining its segment's min (deterministic tie-break).
    hit = fx == fb[seg]
    idx = jnp.where(hit, jnp.arange(n, dtype=jnp.int32), n)
    ib = jnp.full((num_segments,), n, jnp.int32).at[seg].min(idx)
    xb = x[jnp.minimum(ib, n - 1)]
    return xb, fb, ib


def exchange_sync_segmented(x, fx, seg, num_segments: int, adopt_mask=None):
    """Paper-V2 minimum crossover, tenant-isolated: every chain restarts
    from *its own request's* champion.  ``adopt_mask`` (chains,) lets the
    engine mix policies in one batch (False = async request / free slot:
    keep state untouched).

    Returns (x, fx, xb, fb): the exchanged chain state plus the per-segment
    champions, so callers can fold best-so-far without a second reduce."""
    xb, fb, ib = segment_champion(x, fx, seg, num_segments)
    valid = (ib < fx.shape[0])[seg]
    adopt = valid if adopt_mask is None else (valid & adopt_mask)
    x = jnp.where(adopt[:, None], xb[seg], x)
    fx = jnp.where(adopt, fb[seg], fx)
    return x, fx, xb, fb


EXCHANGES = {
    "async": exchange_none,
    "sync": exchange_sync,
    "sos": exchange_sos,
}
