"""Chain-exchange (crossover) operators for parallel SA.

The paper's V2 performs a deterministic *minimum crossover* at every
temperature level: all chains restart from the globally best state.  On the
GPU this is a Thrust reduce; on the TPU mesh it is a per-shard ``argmin``
followed by a tiny ``all_gather`` of per-shard champions — only
``devices × (dim + 1)`` floats move over the interconnect, exactly the
paper's "only function values are exchanged among workers".

Strategies
----------
``async``  : no exchange until the very end (paper V1).
``sync``   : minimum crossover each ``period`` levels (paper V2, period=1).
``sos``    : Synchronous with Occasional Solution exchanges (Onbasoglu &
             Özdamar [23]) — stochastic crossover: a chain adopts the
             champion only if better, or with Metropolis probability at the
             current temperature; keeps chain diversity.

Beyond the paper's family, the serving engine composes two *replica*
operators on the same segmented machinery (see docs/serving.md):

``pt_swap_segmented``    : parallel tempering — a deterministic even/odd
             Metropolis swap pass over a request's per-chain temperature
             ladder (Salazar & Toral's hybrid MC; the PT-RWM layout).
``pa_resample_segmented``: population annealing — Boltzmann-weighted
             multinomial resampling of a request's chain population at
             each temperature-level transition (Barash et al.).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.kernels import rng

#: Salts xor-ed into a request's RNG seed so the exchange-operator draws
#: (sos adoption / PT swap / PA resample) are independent of the sweep
#: kernel's (seed, chain, step) streams — all counter-based, so every
#: operator stays placement/preemption/width invariant.
SOS_SALT = np.uint32(0x5053D1B5)
PT_SALT = np.uint32(0x9E3779B9)
PA_SALT = np.uint32(0x7F4A7C15)

#: Per-chain workload-class codes threaded through the serving engine's
#: device program (one int8 per chain; pads and plain-sync/async chains
#: are PLAIN).
MCODE_PLAIN = 0
MCODE_SOS = 1
MCODE_PT = 2
MCODE_PA = 3

#: Fixed-point scale for PA resampling weights.  Integer cumulative sums
#: are exact and associative, so a tenant's inverse-CDF lookups are
#: bit-identical no matter which rows of a packed batch it occupies (a
#: float cumsum would leak other tenants' rounding into the comparison).
PA_WEIGHT_SCALE = 65536.0


def exchange_uniform(seed, salt, idx, step):
    """One counter-based uniform for an exchange operator: keyed on the
    request seed xor ``salt``, a logical index and the absolute ladder
    level — a stream family disjoint from the sweep kernel's draws."""
    _, u, _ = rng.draws3(jnp.asarray(seed, jnp.uint32) ^ salt, idx, step)
    return u


def local_champion(x, fx):
    """Best (x, f) among the local chains."""
    i = jnp.argmin(fx)
    return x[i], fx[i]


def global_champion(x, fx, axis_names=None):
    """Champion across local chains and (optionally) mesh axes.

    Inside ``shard_map`` with ``axis_names`` set, gathers one champion per
    shard and reduces replicatedly (identical result on all shards).
    """
    xb, fb = local_champion(x, fx)
    if axis_names:
        # Tiny collective: (devices, dim+1) floats.
        fall = lax.all_gather(fb, axis_names, tiled=False)  # (shards,)
        xall = lax.all_gather(xb, axis_names, tiled=False)  # (shards, dim)
        fall = fall.reshape(-1)
        xall = xall.reshape(-1, x.shape[-1])
        j = jnp.argmin(fall)
        xb, fb = xall[j], fall[j]
    return xb, fb


def exchange_sync(key, x, fx, T, axis_names=None):
    """Paper V2: every chain restarts from the global champion."""
    xb, fb = global_champion(x, fx, axis_names)
    x = jnp.broadcast_to(xb[None, :], x.shape)
    fx = jnp.full_like(fx, fb)
    return x, fx


def sos_adopt_prob(fx, fb, T):
    """SOS adoption probability for a chain at value ``fx`` offered the
    champion ``fb`` at temperature ``T`` (Onbasoglu–Özdamar semantics):

    - deficit ``d = fx - fb > T`` (champion strictly better by more than
      one temperature): adopt deterministically, ``p = 1``;
    - tie (``d = 0``): adopt with probability exactly ``1/2``;
    - within-T (``0 < d <= T``): interpolate, ``p = 1 - exp(-d/T)/2``
      (continuous in d, rising from 1/2 at a tie toward 1).

    The champion is a minimum over the population, so ``d >= 0`` always.
    """
    d = jnp.maximum(fx - fb, 0.0)
    t = jnp.maximum(T, 1e-30)
    p_within = 1.0 - 0.5 * jnp.exp(jnp.clip(-d / t, -80.0, 0.0))
    return jnp.where(d > t, jnp.ones_like(p_within), p_within)


def exchange_sos(key, x, fx, T, axis_names=None):
    """Stochastic crossover (SOS): adopt the champion deterministically when
    it is better by more than T, with probability 1/2 at a tie, and with an
    interpolated probability in between — keeps chain diversity by never
    forcing the whole population onto one state unless it dominates."""
    xb, fb = global_champion(x, fx, axis_names)
    u = jax.random.uniform(key, fx.shape, dtype=fx.dtype)
    adopt = u <= sos_adopt_prob(fx, fb, T)
    x = jnp.where(adopt[:, None], xb[None, :], x)
    fx = jnp.where(adopt, fb, fx)
    return x, fx


def exchange_none(key, x, fx, T, axis_names=None):
    return x, fx


# ------------------------------------------------------------------ segmented
# Multi-tenant serving (service/engine.py): chains from several independent
# requests are packed into one device batch, so the champion reduce must be
# *masked per request* — a tenant's chains may only ever see their own
# champion, never another job's.  ``seg`` assigns every chain its request id.

def segment_champion(x, fx, seg, num_segments: int):
    """Per-segment (per-request) champion: masked argmin over each tenant.

    Args:
      x: (chains, dim) states; fx: (chains,) values.
      seg: (chains,) int32 segment id per chain, in [0, num_segments).
      num_segments: static segment count (the slot-pool size bounds it).

    Returns (xb (num_segments, dim), fb (num_segments,), ib (num_segments,)):
    champion state/value/chain-index per segment.  Segments with no chains
    get ``fb = +inf`` and ``ib = chains`` (out of range — check before use).
    """
    n = fx.shape[0]
    fb = jnp.full((num_segments,), jnp.inf, fx.dtype).at[seg].min(fx)
    # First chain attaining its segment's min (deterministic tie-break).
    hit = fx == fb[seg]
    idx = jnp.where(hit, jnp.arange(n, dtype=jnp.int32), n)
    ib = jnp.full((num_segments,), n, jnp.int32).at[seg].min(idx)
    xb = x[jnp.minimum(ib, n - 1)]
    return xb, fb, ib


def exchange_sync_segmented(x, fx, seg, num_segments: int, adopt_mask=None):
    """Paper-V2 minimum crossover, tenant-isolated: every chain restarts
    from *its own request's* champion.  ``adopt_mask`` (chains,) lets the
    engine mix policies in one batch (False = async request / free slot:
    keep state untouched).

    Returns (x, fx, xb, fb): the exchanged chain state plus the per-segment
    champions, so callers can fold best-so-far without a second reduce."""
    xb, fb, ib = segment_champion(x, fx, seg, num_segments)
    valid = (ib < fx.shape[0])[seg]
    adopt = valid if adopt_mask is None else (valid & adopt_mask)
    x = jnp.where(adopt[:, None], xb[seg], x)
    fx = jnp.where(adopt, fb[seg], fx)
    return x, fx, xb, fb


def pt_swap_segmented(x, fx, t_rung, partner, pairlo, seed_c, lvl_abs, is_pt):
    """One deterministic even/odd parallel-tempering swap pass.

    Chains of a PT request each hold one rung of the request's temperature
    ladder; adjacent rungs propose a replica swap with the Metropolis
    acceptance ``min(1, exp((beta_l - beta_p)(f_l - f_p)))``.  The engine
    alternates even pairs (0,1)(2,3)… and odd pairs (1,2)(3,4)… by ladder
    level, precomputing *packed-row* partners host-side so the device pass
    is a pure gather.

    Args (all (chains,) unless noted):
      x: (chains, dim) states; fx: values.
      t_rung: per-chain rung temperature (any value for non-PT chains).
      partner: packed row index of this chain's swap partner for the
        current parity (self-row ⇒ no swap proposed).
      pairlo: logical ladder index of the *lower* rung of the pair (both
        partners carry the same value — keys one shared uniform so the
        accept decision is symmetric), uint32.
      seed_c: per-chain request seed (uint32).
      lvl_abs: absolute ladder level (uint32) — the RNG step counter.
      is_pt: bool mask; False rows pass through bitwise untouched.

    Returns (x, fx) with accepted pairs exchanged.  States swap, rung
    temperatures stay put (temperature-indexed replica layout) — so the
    sweep kernel's per-chain T never changes across swaps.
    """
    u = exchange_uniform(seed_c, PT_SALT, pairlo, lvl_abs)
    beta = 1.0 / jnp.maximum(t_rung, 1e-30)
    fp = fx[partner]
    log_a = (beta - beta[partner]) * (fx - fp)
    accept = u < jnp.exp(jnp.clip(log_a, -80.0, 0.0))
    swap = is_pt & (partner != jnp.arange(fx.shape[0], dtype=jnp.int32)) & accept
    # Gather from the pre-swap arrays only (fresh names, no aliasing).
    x_new = jnp.where(swap[:, None], x[partner], x)
    fx_new = jnp.where(swap, fp, fx)
    return x_new, fx_new


def pa_resample_segmented(x, fx, fb_seg, seg, seg_lo, seg_hi, dbeta_c,
                          seed_c, cidx, lvl_abs, is_pa):
    """Population-annealing resampling at a temperature-level transition.

    Each PA chain independently re-draws its ancestor from its own
    request's population with Boltzmann weight
    ``w_i ∝ exp(-dbeta (f_i - f_champion))`` where
    ``dbeta = 1/T_next - 1/T_cur`` (Barash et al.).  Weights are
    quantized to ``floor(w * PA_WEIGHT_SCALE)`` int32 before the cumsum:
    integer prefix sums are exact, so a tenant's inverse-CDF lookup is
    bit-identical regardless of which packed rows it occupies or what
    other tenants share the batch.  The champion row always carries the
    full-scale weight, so every segment's total is positive.

    Args:
      x: (chains, dim); fx: (chains,).
      fb_seg: (num_segments,) per-segment champion values (pre-resample).
      seg: (chains,) segment id; seg_lo/seg_hi: packed-row range
        [seg_lo, seg_hi) of each chain's own request (self-range
        [row, row+1) for non-PA rows).
      dbeta_c: (chains,) per-chain inverse-temperature increment (f32).
      seed_c / cidx / lvl_abs: RNG key material (uint32) — ``cidx`` is the
        *logical* chain index within the request, so the draw is invariant
        to where the request's rows land in the packed batch.
      is_pa: bool mask; False rows pass through bitwise untouched.

    Returns (x, fx) with each PA row replaced by its sampled ancestor.
    """
    # Quantized weights; masked rows weigh 0 so foreign tenants (and pads)
    # never enter a PA segment's CDF.  fb may be +inf on empty (pad)
    # segments, making the exponent NaN there — those rows are masked out.
    d = fx - fb_seg[seg]
    w = jnp.exp(jnp.clip(-dbeta_c * d, -80.0, 0.0))
    wq = jnp.where(is_pa, (w * PA_WEIGHT_SCALE).astype(jnp.int32), 0)
    cum = jnp.concatenate(
        [jnp.zeros((1,), jnp.int32), jnp.cumsum(wq)])          # (n+1,) exclusive
    tot = cum[seg_hi] - cum[seg_lo]                            # per-chain pop mass
    u = exchange_uniform(seed_c, PA_SALT, cidx, lvl_abs)
    tgt = cum[seg_lo] + jnp.clip(
        jnp.floor(u * tot.astype(fx.dtype)).astype(jnp.int32), 0,
        jnp.maximum(tot - 1, 0))
    anc = jnp.clip(jnp.searchsorted(cum, tgt, side="right") - 1,
                   seg_lo, jnp.maximum(seg_hi - 1, seg_lo))
    take = is_pa & (tot > 0)
    x_new = jnp.where(take[:, None], x[anc], x)
    fx_new = jnp.where(take, fx[anc], fx)
    return x_new, fx_new


def serving_exchange(x, fx, seg, num_segments, adopt, mcode, t_rung, T_exch,
                     partner, pairlo, seg_lo, seg_hi, dbeta_c, seed_c,
                     cidx, lvl_abs, live):
    """The engine's composite per-level exchange over a mixed-class batch.

    One traced program covers every workload class; each stage is masked
    so an all-False mask is a bitwise identity for the other tenants:

      1. segmented champion reduce (always — feeds best-so-far folding);
      2. champion adoption: ``sync`` (deterministic) and ``sos``
         (stochastic, :func:`sos_adopt_prob`) chains;
      3. parallel-tempering even/odd swap pass (PT chains);
      4. population-annealing Boltzmann resample (PA chains).

    ``T_exch`` is the per-chain *schedule* temperature (block ladder value
    for plain/sos/pa chains); ``cidx`` the per-chain logical chain index
    (uint32); ``live`` masks out chains of finished or padded blocks
    inside a fused macro-tick.

    Returns (x, fx, xb, fb) like :func:`exchange_sync_segmented`.
    """
    n = fx.shape[0]
    xb, fb, ib = segment_champion(x, fx, seg, num_segments)
    valid = (ib < n)[seg] & live

    is_sos = mcode == MCODE_SOS
    u_sos = exchange_uniform(seed_c, SOS_SALT, cidx, lvl_abs)
    sos_take = is_sos & (u_sos <= sos_adopt_prob(fx, fb[seg], T_exch))
    take = valid & (adopt | sos_take)
    x = jnp.where(take[:, None], xb[seg], x)
    fx = jnp.where(take, fb[seg], fx)

    x, fx = pt_swap_segmented(x, fx, t_rung, partner, pairlo, seed_c,
                              lvl_abs, (mcode == MCODE_PT) & live)
    x, fx = pa_resample_segmented(x, fx, fb, seg, seg_lo, seg_hi, dbeta_c,
                                  seed_c, cidx, lvl_abs,
                                  (mcode == MCODE_PA) & live)
    return x, fx, xb, fb


EXCHANGES = {
    "async": exchange_none,
    "sync": exchange_sync,
    "sos": exchange_sos,
}
