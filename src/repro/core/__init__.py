"""Core: the paper's contribution — parallel simulated annealing."""
from repro.core.annealing import SAConfig, SAResult, sa_minimize, build_sharded_ladder
from repro.core.hybrid import HybridResult, hybrid_minimize
from repro.core.neldermead import NMResult, nelder_mead

__all__ = [
    "SAConfig", "SAResult", "sa_minimize", "build_sharded_ladder",
    "HybridResult", "hybrid_minimize", "NMResult", "nelder_mead",
]
