"""Hybrid SA → Nelder–Mead strategy (paper §4.2, Table 10).

The annealing run is stopped *prematurely* (a much hotter ``T_min`` / smaller
eval budget than a pure-SA run would need) and its champion seeds a local
simplex minimization.  The paper shows this is orders of magnitude better in
both error and time.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax

from repro.core.annealing import SAConfig, SAResult, sa_minimize
from repro.core.neldermead import NMResult, nelder_mead
from repro.objectives.base import Objective


@dataclasses.dataclass
class HybridResult:
    sa: SAResult
    nm: NMResult

    # NM polishes the SA champion but can terminate on a worse simplex
    # (iteration cap, degenerate geometry); report the coherent (x, f)
    # pair from whichever stage actually won, never a mix of the two.
    @property
    def _winner(self):
        return self.nm if self.nm.f_best <= self.sa.f_best else self.sa

    @property
    def x_best(self):
        return self._winner.x_best

    @property
    def f_best(self) -> float:
        return self._winner.f_best


def hybrid_minimize(objective: Objective, sa_config: SAConfig,
                    key: Optional[jax.Array] = None,
                    nm_max_iters: int = 4000,
                    nm_fatol: float = 1e-12, nm_xatol: float = 1e-12,
                    mesh=None, mesh_axes=None) -> HybridResult:
    sa_res = sa_minimize(objective, sa_config, key=key, mesh=mesh,
                         mesh_axes=mesh_axes)
    nm_res = nelder_mead(objective, sa_res.x_best, max_iters=nm_max_iters,
                         fatol=nm_fatol, xatol=nm_xatol)
    return HybridResult(sa=sa_res, nm=nm_res)
