"""Distributed substrate: compression, monitoring, pipeline parallelism."""
from repro.distributed.compression import (compress_grads_tree,
                                           compressed_psum, init_residuals)
from repro.distributed.monitor import Heartbeat, StepTimer, StragglerMonitor
from repro.distributed.pipeline import (bubble_fraction, make_pipelined_fn,
                                        pipeline_apply)

__all__ = [
    "compressed_psum", "compress_grads_tree", "init_residuals",
    "Heartbeat", "StepTimer", "StragglerMonitor",
    "pipeline_apply", "make_pipelined_fn", "bubble_fraction",
]
