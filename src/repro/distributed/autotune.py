"""SA-driven sharding autotuner: the paper's optimizer pointed at the
framework's own distribution problem.

Search space (discrete, encoded into the SA box [0,1)^k — coordinate-wise
uniform proposals quantize to choice indices, so the paper's Metropolis
kernel applies unchanged):

  d0: dp_split   — how many of the ``chips`` go to DP (rest = TP); choices
                   are divisors of ``chips`` that also divide global batch.
  d1: remat      — none | dots | full  (activation-memory vs recompute)
  d2: ep         — MoE expert-parallel on/off (all_to_all vs replicated)
  d3: microbatch — 1|2|4|8 gradient-accumulation chunks
  d4: compress   — fp32 | bf16 | int8 gradient all-reduce payload

The objective is an analytic three-term roofline step-time estimate — the
same compute/memory/collective decomposition the dry-run extracts from
compiled HLO (launch/dryrun.py), so SA minimizes exactly the quantity §Perf
hillclimbs.  A model, not a measurement: validated against dry-run terms in
tests/test_autotune.py; exhaustive-search agreement is asserted there too.
"""
from __future__ import annotations

import dataclasses
import itertools

import jax.numpy as jnp
import numpy as np

from repro.models.model import ModelConfig
from repro.objectives.base import Objective

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9

REMAT_CHOICES = ("none", "dots", "full")
# extra fwd-flops multiplier: none=0, dots≈.3 (recompute non-dot), full=1
_REMAT_RECOMP = {"none": 0.0, "dots": 0.3, "full": 1.0}
# activation bytes kept per token per layer (fraction of no-remat)
_REMAT_ACT = {"none": 1.0, "dots": 0.35, "full": 0.08}
MB_CHOICES = (1, 2, 4, 8)
COMPRESS_CHOICES = ("fp32", "bf16", "int8")
_COMPRESS_BYTES = {"fp32": 4.0, "bf16": 2.0, "int8": 1.0}


@dataclasses.dataclass(frozen=True)
class TuneProblem:
    cfg: ModelConfig
    seq: int
    batch: int
    chips: int
    kind: str = "train"        # 'train' | 'prefill' | 'decode'

    def dp_choices(self) -> tuple[int, ...]:
        out = []
        for dp in range(1, self.chips + 1):
            if self.chips % dp == 0 and self.batch % dp == 0:
                out.append(dp)
        return tuple(out)

    def space(self) -> tuple[tuple[str, int], ...]:
        return (("dp", len(self.dp_choices())),
                ("remat", len(REMAT_CHOICES)),
                ("ep", 2),
                ("mb", len(MB_CHOICES)),
                ("compress", len(COMPRESS_CHOICES)))


def decode_point(prob: TuneProblem, x: np.ndarray) -> dict:
    """Map a box point in [0,1)^5 to a concrete decision dict."""
    dps = prob.dp_choices()
    idx = [min(int(xi * n), n - 1) for xi, (_, n) in zip(x, prob.space())]
    return {
        "dp": dps[idx[0]], "tp": prob.chips // dps[idx[0]],
        "remat": REMAT_CHOICES[idx[1]],
        "ep": bool(idx[2]) and prob.cfg.n_experts > 0,
        "microbatch": MB_CHOICES[idx[3]],
        "compress": COMPRESS_CHOICES[idx[4]],
    }


def _cost_terms(prob: TuneProblem, dp, remat_recomp, remat_act, ep, mb,
                comp_bytes):
    """Vectorized analytic roofline terms (all args jnp arrays)."""
    cfg = prob.cfg
    total, active = cfg.param_count()
    D = float(cfg.d_model)
    Ls = float(cfg.n_layers)
    tokens = float(prob.batch * prob.seq)
    tp = prob.chips / dp
    bytes_p = 2.0  # bf16 params/activations

    mult = 6.0 if prob.kind == "train" else 2.0
    model_flops = mult * float(active) * tokens
    # recompute applies to the forward third of 6ND
    flops = model_flops * (1.0 + remat_recomp * (2.0 / mult))
    compute_s = flops / (prob.chips * PEAK_FLOPS)

    # memory: params traversed (fwd+bwd+opt ~ 3x for train), activations
    # streamed in/out once, scaled by remat retention; KV cache for decode.
    p_traverse = 3.0 if prob.kind == "train" else 1.0
    act_bytes = tokens * D * Ls * 8.0 * bytes_p * remat_act
    mem_bytes = p_traverse * float(total) * bytes_p + act_bytes
    if prob.kind == "train":
        mem_bytes = mem_bytes + 3.0 * float(total) * 4.0  # fp32 opt state r/w
    memory_s = mem_bytes / (prob.chips * HBM_BW)

    # collectives
    #   TP: 2 all-reduces per layer of (tokens/dp, D) activations
    tp_bytes = jnp.where(tp > 1,
                         2.0 * Ls * (tokens / dp) * D * bytes_p * 2.0
                         * (tp - 1.0) / tp, 0.0)
    #   DP grad sync: ring reduce-scatter+all-gather of param bytes / tp
    dp_bytes = jnp.where(dp > 1,
                         2.0 * (float(total) / tp) * comp_bytes
                         * (dp - 1.0) / dp, 0.0)
    #   EP dispatch: top_k-routed activations all_to_all, 2x (fwd+bwd-ish)
    if cfg.n_experts:
        ep_bytes = jnp.where(ep,
                             4.0 * (tokens / prob.chips) * D * bytes_p
                             * float(cfg.top_k), 0.0)
        # without EP the routed FFN weights are replicated: pay a one-time
        # broadcast amortized as an extra DP-style sync on expert params
        moe_params = float(total - active)
        ep_bytes = ep_bytes + jnp.where(ep, 0.0, 2.0 * moe_params
                                        * comp_bytes * (dp - 1.0)
                                        / jnp.maximum(dp, 1.0))
    else:
        ep_bytes = jnp.zeros_like(tp_bytes)
    coll_bytes = tp_bytes + dp_bytes / mb + ep_bytes  # grad sync 1/mb-able
    collective_s = coll_bytes / (prob.chips * ICI_BW)

    # memory-capacity penalty: activations + params + opt must fit 16 GiB.
    hbm_cap = 16.0 * 2 ** 30
    state_bytes = (float(total) * (bytes_p + 12.0) / prob.chips  # p+opt fp32
                   + act_bytes / (prob.chips * mb))
    over = jnp.maximum(state_bytes / hbm_cap - 1.0, 0.0)
    penalty = over * 100.0  # strongly discourage OOM points

    # int8 compression numeric tax: tiny fixed penalty so it's only chosen
    # when the wire win is real.
    penalty = penalty + jnp.where(comp_bytes < 2.0, 1e-4, 0.0)
    return compute_s, memory_s, collective_s, penalty


def make_objective(prob: TuneProblem) -> Objective:
    """Step-time estimate as an SA Objective over the [0,1)^5 box."""
    dps = np.asarray(prob.dp_choices(), np.float64)
    n_dp = len(dps)
    recomp = np.asarray([_REMAT_RECOMP[r] for r in REMAT_CHOICES])
    act = np.asarray([_REMAT_ACT[r] for r in REMAT_CHOICES])
    mbs = np.asarray(MB_CHOICES, np.float64)
    cbytes = np.asarray([_COMPRESS_BYTES[c] for c in COMPRESS_CHOICES])

    def fn(x):
        x = jnp.asarray(x)
        i_dp = jnp.clip((x[..., 0] * n_dp).astype(jnp.int32), 0, n_dp - 1)
        i_rm = jnp.clip((x[..., 1] * 3).astype(jnp.int32), 0, 2)
        i_ep = jnp.clip((x[..., 2] * 2).astype(jnp.int32), 0, 1)
        i_mb = jnp.clip((x[..., 3] * 4).astype(jnp.int32), 0, 3)
        i_cp = jnp.clip((x[..., 4] * 3).astype(jnp.int32), 0, 2)
        dp = jnp.take(jnp.asarray(dps), i_dp)
        c, m, coll, pen = _cost_terms(
            prob, dp,
            jnp.take(jnp.asarray(recomp), i_rm),
            jnp.take(jnp.asarray(act), i_rm),
            i_ep.astype(bool), jnp.take(jnp.asarray(mbs), i_mb),
            jnp.take(jnp.asarray(cbytes), i_cp))
        # overlappable: compute hides the larger of (memory, collective)
        # partially; model 70% overlap of the non-dominant pair.
        hi = jnp.maximum(jnp.maximum(c, m), coll)
        rest = c + m + coll - hi
        return hi + 0.3 * rest + pen

    return Objective(name=f"autotune-{prob.cfg.name}", dim=5,
                     lower=np.zeros(5), upper=np.ones(5) - 1e-9, fn=fn)


def exhaustive_best(prob: TuneProblem) -> tuple[dict, float]:
    """Brute-force reference (small space) — used for validation."""
    obj = make_objective(prob)
    space = prob.space()
    best, best_f = None, np.inf
    grids = [np.arange(n) for _, n in space]
    for combo in itertools.product(*grids):
        x = np.array([(c + 0.5) / n for c, (_, n) in zip(combo, space)])
        f = float(obj(jnp.asarray(x)[None, :])[0])
        if f < best_f:
            best, best_f = x, f
    return decode_point(prob, best), best_f


def autotune(prob: TuneProblem, n_chains: int = 256, seed: int = 0,
             mesh=None) -> tuple[dict, float]:
    """Run synchronous parallel SA over the decision space."""
    import jax

    from repro.core import SAConfig, sa_minimize

    obj = make_objective(prob)
    cfg = SAConfig(T0=1.0, T_min=1e-3, rho=0.85, N=20, n_chains=n_chains,
                   exchange="sync", seed=seed, record_history=False)
    res = sa_minimize(obj, cfg, key=jax.random.PRNGKey(seed), mesh=mesh)
    return decode_point(prob, np.asarray(res.x_best)), float(res.f_best)
