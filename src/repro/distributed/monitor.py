"""Straggler / liveness monitoring for long-running training jobs.

On a real multi-pod deployment every host runs a ``Heartbeat`` thread that
appends (host, step, t) records to shared storage; the lead host's
``StragglerMonitor`` flags hosts whose step-time z-score exceeds a threshold
(slow HBM, thermal throttling, failing NIC) so the orchestrator can
drain+replace them before they stall the synchronous collective.  In this
single-process container the same code paths run with host_count=1 and are
unit-tested with synthetic timings.
"""
from __future__ import annotations

import dataclasses
import json
import time
from collections import defaultdict, deque
from pathlib import Path
from typing import Optional


@dataclasses.dataclass
class StepTimer:
    """EWMA step timing with deadline detection (single host)."""
    alpha: float = 0.1
    deadline_factor: float = 3.0
    _ewma: Optional[float] = None
    _last: Optional[float] = None

    def start(self):
        self._last = time.monotonic()

    def stop(self) -> float:
        dt = time.monotonic() - self._last
        self._ewma = dt if self._ewma is None else \
            (1 - self.alpha) * self._ewma + self.alpha * dt
        return dt

    @property
    def mean(self) -> Optional[float]:
        return self._ewma

    def exceeded_deadline(self, elapsed: float) -> bool:
        """True if an in-flight step has run deadline_factor × EWMA."""
        return self._ewma is not None and elapsed > self.deadline_factor * self._ewma


class Heartbeat:
    """Append-only heartbeat file per host (shared FS / object store)."""

    def __init__(self, root: str | Path, host: int):
        self.path = Path(root) / f"heartbeat_{host:05d}.jsonl"
        self.path.parent.mkdir(parents=True, exist_ok=True)
        self.host = host

    def beat(self, step: int, step_time: float):
        with self.path.open("a") as f:
            f.write(json.dumps({"host": self.host, "step": step,
                                "t": time.time(), "dt": step_time}) + "\n")


class StragglerMonitor:
    """Lead-host view: per-host step-time stats, straggler + dead detection."""

    def __init__(self, window: int = 32, zscore: float = 3.0,
                 dead_after_s: float = 120.0):
        self.window = window
        self.zscore = zscore
        self.dead_after_s = dead_after_s
        self.times: dict[int, deque] = defaultdict(lambda: deque(maxlen=window))
        self.last_seen: dict[int, float] = {}

    def record(self, host: int, step_time: float, now: Optional[float] = None):
        self.times[host].append(step_time)
        self.last_seen[host] = now if now is not None else time.time()

    def ingest(self, root: str | Path):
        for p in Path(root).glob("heartbeat_*.jsonl"):
            for line in p.read_text().splitlines():
                r = json.loads(line)
                self.record(r["host"], r["dt"], r["t"])

    def stragglers(self) -> list[int]:
        """Hosts whose mean step time is a z-score outlier vs the fleet."""
        import numpy as np
        means = {h: float(np.mean(t)) for h, t in self.times.items() if t}
        if len(means) < 3:
            return []
        vals = np.array(list(means.values()))
        mu, sd = vals.mean(), vals.std() + 1e-9
        return [h for h, m in means.items() if (m - mu) / sd > self.zscore]

    def dead(self, now: Optional[float] = None) -> list[int]:
        now = now if now is not None else time.time()
        return [h for h, t in self.last_seen.items()
                if now - t > self.dead_after_s]
