"""GPipe-style pipeline parallelism over the 'pod' mesh axis (optional
strategy, DESIGN.md §6).

The layer stack is split into ``n_stages`` contiguous stages; stage s lives
on pod s (weights sharded P('pod') on the stage axis inside shard_map).
Microbatches flow through stages with ``ppermute`` transfers; the classic
GPipe schedule runs M microbatches over S stages in (M + S - 1) ticks with
bubble fraction (S-1)/(M+S-1).

This module is deliberately model-agnostic: it pipelines any
``layer_fn(params_stage, x) -> x``.  An integration test drives a 2-stage ×
2-device CPU mesh; the dry-run exercises 2 pods × 256.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P


def _axis_size(axis: str):
    """``lax.axis_size`` with an older-jax fallback (psum of ones — the
    classic spelling; same traced value inside a mapped axis)."""
    if hasattr(lax, "axis_size"):
        return lax.axis_size(axis)
    return lax.psum(1, axis)


def pipeline_apply(layer_fn, stage_params, x_microbatches, *, axis: str = "pod"):
    """Run inside shard_map: stage_params holds THIS pod's stage weights;
    x_microbatches: (M, mb, ...) microbatch queue (replicated content).

    Returns the final-stage outputs for every microbatch (valid on the last
    stage; other stages return the in-flight values).
    """
    n_stages = _axis_size(axis)
    stage = lax.axis_index(axis)
    M = x_microbatches.shape[0]
    ticks = M + n_stages - 1

    perm = [(i, i + 1) for i in range(n_stages - 1)]  # stage i -> i+1

    def tick(carry, t):
        state, outputs = carry  # state: (mb, ...) current in-flight value
        # stage 0 injects microbatch t (when t < M); others use received state
        inject = x_microbatches[jnp.minimum(t, M - 1)]
        x_in = jnp.where(stage == 0, inject, state)
        y = layer_fn(stage_params, x_in)
        # shift: stage s sends y to s+1
        received = lax.ppermute(y, axis, perm)
        # last stage records its output for microbatch (t - (S-1))
        out_idx = t - (n_stages - 1)
        is_valid = (stage == n_stages - 1) & (out_idx >= 0)
        outputs = lax.cond(
            is_valid,
            lambda o: lax.dynamic_update_index_in_dim(
                o, y, jnp.maximum(out_idx, 0), axis=0),
            lambda o: o,
            outputs)
        return (received, outputs), None

    state0 = jnp.zeros_like(x_microbatches[0])
    outputs0 = jnp.zeros_like(x_microbatches)
    (state, outputs), _ = lax.scan(tick, (state0, outputs0),
                                   jnp.arange(ticks))
    return outputs


def make_pipelined_fn(layer_fn, mesh, *, axis: str = "pod",
                      stage_param_spec=P("pod"), x_spec=P()):
    """shard_map wrapper: stage weights sharded over ``axis``; microbatches
    replicated in, final outputs taken from the last stage."""
    def fn(stage_params, xs):
        out = pipeline_apply(layer_fn, stage_params, xs, axis=axis)
        # broadcast final-stage outputs to all stages for a replicated
        # return (mask + psum: ppermute can't fan out one source to many)
        n = _axis_size(axis)
        last = (lax.axis_index(axis) == n - 1).astype(out.dtype)
        return lax.psum(out * last, axis)

    from repro.launch.mesh import shard_map
    return shard_map(fn, mesh=mesh,
                     in_specs=(stage_param_spec, x_spec),
                     out_specs=x_spec, check_vma=False)


def bubble_fraction(n_stages: int, n_microbatches: int) -> float:
    return (n_stages - 1) / (n_microbatches + n_stages - 1)
