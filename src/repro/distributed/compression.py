"""Int8 error-feedback gradient compression for the DP all-reduce.

At 1000+ nodes the DP gradient all-reduce is the dominant inter-pod traffic.
We quantize per-tensor to int8 with a per-(tensor, shard) fp32 scale before
the collective and keep the quantization residual locally (*error feedback*),
adding it to the next step's gradient — the standard trick that preserves
convergence (1-bit Adam / EF-SGD lineage).

Usage (inside shard_map over the DP axes)::

    g_sum, new_residual = compressed_psum(g + residual, axis_names)

4x traffic reduction vs fp32 (2x vs bf16) on the wire.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax


def quantize_int8(x):
    """Symmetric per-tensor int8 quantization. Returns (q, scale)."""
    amax = jnp.max(jnp.abs(x))
    scale = jnp.where(amax > 0, amax / 127.0, 1.0).astype(jnp.float32)
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q, scale):
    return q.astype(jnp.float32) * scale


def compressed_psum(x, axis_names):
    """All-reduce ``x`` over ``axis_names`` with int8 payload + error feedback.

    Returns (approx_sum, residual): ``residual = x - dequant(quant(x))`` must
    be carried by the caller and added to next step's input.
    """
    q, scale = quantize_int8(x)
    deq = dequantize_int8(q, scale)
    residual = x - deq
    # int8 values summed in int32 to avoid overflow; scales vary per shard so
    # we psum the dequantized contribution expressed as (q * scale): do the
    # wire transfer as int8 all_gather of q + tiny scale gather, then local
    # weighted sum — collective payload is 1 byte/element + 4 bytes/shard.
    qg = lax.all_gather(q, axis_names, tiled=False)        # (shards, ...)
    sg = lax.all_gather(scale, axis_names, tiled=False)    # (shards,)
    approx = jnp.tensordot(sg.astype(jnp.float32),
                           qg.astype(jnp.float32), axes=1)
    return approx, residual


def compress_grads_tree(grads, residuals, axis_names):
    """Apply compressed_psum over a gradient pytree (mean over shards)."""
    import numpy as np

    def one(g, r):
        s, new_r = compressed_psum(g.astype(jnp.float32) + r, axis_names)
        return s, new_r

    flat_g, tdef = jax.tree.flatten(grads)
    flat_r = jax.tree.leaves(residuals)
    out, res = [], []
    for g, r in zip(flat_g, flat_r):
        s, nr = one(g, r)
        out.append(s)
        res.append(nr)
    return jax.tree.unflatten(tdef, out), jax.tree.unflatten(tdef, res)


def init_residuals(grads_shape):
    return jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads_shape)
