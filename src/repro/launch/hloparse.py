"""Post-optimization HLO text analysis for roofline terms.

XLA's ``cost_analysis()['bytes accessed']`` sums operand bytes of *every*
op including those inside fusion bodies — a pre-fusion figure that wildly
overestimates HBM traffic.  This module parses the optimized HLO text and
counts only **top-level buffers** (ENTRY + while-body computations), i.e.
what actually materializes between fusions:

  hbm_bytes  = Σ over top-level ops (output write + operand reads),
               skipping parameter/constant/tuple-plumbing lines;
  wire_bytes = per-collective-kind ICI traffic with a ring model:
               all-gather: out·(n-1)/n     all-reduce: 2·in·(n-1)/n
               reduce-scatter: in·(n-1)/n  all-to-all: in·(n-1)/n
               collective-permute: in

This is still an approximation of a real TPU compiler's fusion choices
(documented in EXPERIMENTS.md §Methodology), but it is *post-fusion* and
self-consistent across cells — the right property for identifying the
dominant roofline term and for before/after hillclimb deltas.
"""
from __future__ import annotations

import re
from collections import defaultdict

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

# Ops that force a buffer to materialize in HBM on a fusing compiler
# (XLA:TPU fuses elementwise/broadcast/convert/select chains into these).
# Everything NOT in this set is treated as fused (zero HBM traffic) — the
# optimistic-TPU model; the pre-fusion figure is recorded alongside.
_MATERIALIZING = {
    "dot", "convolution", "fusion", "reduce", "reduce-window", "sort",
    "gather", "scatter", "dynamic-slice", "dynamic-update-slice", "copy",
    "transpose", "concatenate", "pad", "slice", "reverse",
    "select-and-scatter", "rng", "rng-bit-generator", "cholesky",
    "triangular-solve", "all-gather", "all-reduce", "reduce-scatter",
    "all-to-all", "collective-permute", "custom-call",
}
_SKIP_READ_OPS = {"get-tuple-element", "tuple", "bitcast", "while",
                  "conditional", "call"}

_COMP_HDR = re.compile(r"^(ENTRY\s+)?%?([\w\.\-]+)\s*\((.*)\)\s*->\s*(.+?)\s*\{")
_OP_LINE = re.compile(
    r"^(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(\(?[\w\[\],\s\{\}\/]+?\)?)\s+([\w\-]+)\(")
_SHAPE = re.compile(r"(\w+)\[([\d,]*)\]")
_OPERAND = re.compile(r"%([\w\.\-]+)")
_CALLS = re.compile(r"(?:calls|to_apply)=%?([\w\.\-]+)")
_BODY = re.compile(r"(?:body|condition)=%?([\w\.\-]+)")
_REPL_GROUPS = re.compile(r"replica_groups=\{?\{([\d,]+)\}")

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

# Op kinds a fusing compiler melts into neighbours: a fusion whose body is
# made ONLY of these is treated as free (its consumers read its inputs'
# buffers directly).  XLA:CPU emits thousands of such micro-fusions that
# XLA:TPU would merge into the surrounding dot/reduce.
_ELEMENTWISE = {
    "parameter", "constant", "broadcast", "convert", "add", "subtract",
    "multiply", "divide", "select", "compare", "maximum", "minimum",
    "exponential", "exponential-minus-one", "tanh", "rsqrt", "sqrt", "log",
    "log-plus-one", "negate", "abs", "power", "and", "or", "xor", "not",
    "sign", "cosine", "sine", "clamp", "floor", "ceil", "round-nearest-afz",
    "round-nearest-even", "is-finite", "reshape", "bitcast", "iota",
    "remainder", "shift-left", "shift-right-logical", "shift-right-arithmetic",
    "popcnt", "count-leading-zeros", "atan2", "expm1", "log1p", "logistic",
    "cbrt", "erf", "real", "imag", "tuple",
}

# Pure layout/data-movement ops: XLA:TPU's layout assignment folds these
# into the producing/consuming dot or fusion (verified empirically: on the
# unrolled XLA:CPU HLO they account for ~88% of naive "materializing" bytes
# — counting them would model a TPU that never assigns layouts).  A fusion
# whose non-elementwise body ops are ONLY these is melted like an
# elementwise fusion; standalone instances are melted too (except `copy`,
# which XLA emits for buffer donation/aliasing — a real HBM write).
_LAYOUT_ONLY = {"transpose", "slice", "pad", "reverse"}


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE.findall(shape_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def parse_hlo_costs(hlo: str) -> dict:
    """Returns {'hbm_bytes': float, 'wire': {kind: bytes}, 'group_size': int}."""
    # 1) split into computations
    comps: dict[str, list[str]] = {}
    cur = None
    for raw in hlo.splitlines():
        line = raw.strip()
        m = _COMP_HDR.match(line)
        if m and line.endswith("{"):
            cur = m.group(2)
            comps[cur] = []
            continue
        if line.startswith("}"):
            cur = None
            continue
        if cur is not None and "=" in line:
            comps[cur].append(line)

    # 2) classify: computations referenced by calls=/to_apply= are fused/inner;
    #    while bodies are real (counted once — callers use unrolled programs).
    inner = set()
    for lines in comps.values():
        for line in lines:
            for m in _CALLS.finditer(line):
                inner.add(m.group(1))
    top = [c for c in comps if c not in inner]

    def _body_is_elementwise(cname: str) -> bool:
        for line in comps.get(cname, ()):
            m = _OP_LINE.match(line)
            if not m:
                continue
            op = m.group(3)
            if op not in _ELEMENTWISE and op not in _LAYOUT_ONLY:
                return False
        return True

    elementwise_fusions = {c for c in inner if _body_is_elementwise(c)}

    _INDEXED = {"scatter", "dynamic-update-slice", "gather", "dynamic-slice"}

    def _body_is_aliased_update(cname: str) -> bool:
        """Fusion whose only materializing body ops are indexed accesses
        (scatter/DUS: in-place aliased updates; gather/dynamic-slice: reads
        of just the indexed elements): the big operand buffer is NOT
        streamed; traffic is the touched elements + side inputs."""
        found = False
        for line in comps.get(cname, ()):
            m = _OP_LINE.match(line)
            if not m:
                continue
            op = m.group(3)
            if op in _INDEXED:
                found = True
            elif op == "concatenate":
                pass  # index-packing concats; accounted via output size
            elif op not in _ELEMENTWISE and op not in _LAYOUT_ONLY:
                return False
        return found

    aliased_fusions = {c for c in inner if _body_is_aliased_update(c)}

    hbm = 0.0
    wire: dict[str, float] = defaultdict(float)
    by_op: dict[str, float] = defaultdict(float)  # hbm census per op kind

    for cname in top:
        lines = comps[cname]
        sizes: dict[str, int] = {}
        # pre-pass: record every defined op's output bytes
        parsed = []
        for line in lines:
            m = _OP_LINE.match(line)
            if not m:
                continue
            name, shape_str, op = m.group(1), m.group(2), m.group(3)
            out_b = _shape_bytes(shape_str)
            sizes[name] = out_b
            parsed.append((name, shape_str, op, out_b, line))

        for name, shape_str, op, out_b, line in parsed:
            if not any(op == m or op.startswith(m + ".") for m in _MATERIALIZING):
                continue
            if op in _LAYOUT_ONLY:
                continue  # folded by TPU layout assignment
            aliased_update_fusion = False
            if op == "fusion":
                cm = _CALLS.search(line)
                if cm and cm.group(1) in elementwise_fusions:
                    continue  # melted into neighbours on a fusing compiler
                if cm and cm.group(1) in aliased_fusions:
                    aliased_update_fusion = True
            # operand reads
            call = line.split("(", 1)[1] if "(" in line else ""
            call = call.split(", calls=")[0].split(", to_apply=")[0]
            in_b = 0
            if op not in _SKIP_READ_OPS:
                seen = set()
                for om in _OPERAND.finditer(call):
                    o = om.group(1)
                    if o in sizes and o not in seen:
                        seen.add(o)
                        in_b += sizes[o]
            if op == "dynamic-update-slice":
                # XLA aliases input->output for DUS (donation): traffic is
                # the updated slice, not the whole buffer.  Count the update
                # operand (2nd) once for read and once for write.
                ops_ = [om.group(1) for om in _OPERAND.finditer(call)]
                upd_b = sizes.get(ops_[1], 0) if len(ops_) > 1 else 0
                hbm += 2 * upd_b
                by_op[op] += 2 * upd_b
                continue
            if op == "scatter":
                # Same in-place aliasing for scatter: traffic = indices read
                # + updates read + scattered-elements write (not the buffer).
                ops_ = [om.group(1) for om in _OPERAND.finditer(call)]
                idx_b = sizes.get(ops_[1], 0) if len(ops_) > 1 else 0
                upd_b = sizes.get(ops_[2], 0) if len(ops_) > 2 else 0
                hbm += idx_b + 2 * upd_b
                by_op[op] += idx_b + 2 * upd_b
                continue
            if aliased_update_fusion:
                # indexed-access fusion: traffic = side inputs (indices,
                # update values) + the touched elements; the big buffer
                # (largest operand) is aliased / sparsely read, not streamed.
                seen = set()
                opers = []
                for om_ in _OPERAND.finditer(call):
                    o = om_.group(1)
                    if o in sizes and o not in seen:
                        seen.add(o)
                        opers.append(sizes[o])
                big = max(opers) if opers else 0
                side = sum(opers) - big
                touched = out_b if out_b < big else 0  # gather-style output
                hbm += 2 * side + 2 * touched
                by_op["fusion-aliased-update"] += 2 * side + 2 * touched
                continue
            if op in ("gather", "dynamic-slice"):
                # indexed read: traffic = indices + gathered elements (the
                # output), not the source buffer.
                ops_ = [om.group(1) for om in _OPERAND.finditer(call)]
                idx_b = sum(sizes.get(o, 0) for o in ops_[1:])
                hbm += idx_b + 2 * out_b
                by_op[op] += idx_b + 2 * out_b
                continue
            coll = next((k for k in _COLLECTIVES if op.startswith(k)), None)
            if coll:
                g = _REPL_GROUPS.search(line)
                n = len(g.group(1).split(",")) if g else 2
                frac = (n - 1) / n if n > 1 else 0.0
                if coll == "all-gather":
                    wire[coll] += out_b * frac
                elif coll == "all-reduce":
                    wire[coll] += 2 * in_b * frac
                elif coll == "reduce-scatter":
                    wire[coll] += in_b * frac
                elif coll == "all-to-all":
                    wire[coll] += in_b * frac
                else:  # collective-permute
                    wire[coll] += in_b
            if op != "parameter":
                hbm += out_b
                by_op[op] += out_b
            hbm += in_b
            by_op[op] += in_b

    return {"hbm_bytes": hbm, "wire": dict(wire), "by_op": dict(by_op)}
