"""Batched serving driver: continuous-batching decode loop over a request
queue (the inference-side end-to-end driver).

Serving model (vLLM-style, TPU-simplified):
* a fixed decode batch of ``--batch`` slots, each slot holding one request's
  KV cache row;
* new requests are *prefilled* individually (right-padded batch of 1 here;
  chunked prefill on a real pod) and their caches spliced into free slots;
* one ``serve_step`` per tick advances every active slot by one token;
* finished slots (EOS or max_new) are immediately refilled from the queue —
  no tail latency from stragglers in the batch.

The same ``make_serve_step``/``make_prefill_step`` functions are what the
dry-run lowers at pod scale; this driver exercises them end-to-end on CPU.

Usage::

  PYTHONPATH=src python -m repro.launch.serve --preset smoke \
      --requests 8 --batch 4 --max-new 16
"""
from __future__ import annotations

import argparse
import time
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.launch.mesh import local_test_mesh
from repro.launch.train import preset_config
from repro.models import model as M


class SlotCache:
    """Decode-batch KV caches with per-slot splice (cache axis 0 is the
    scan'd layer group; axis 1 is batch)."""

    def __init__(self, cfg, batch, s_max, dtype):
        self.caches = M.init_cache(cfg, batch, s_max, dtype=dtype)

    def splice(self, row_caches, slot: int):
        def upd(full, row):
            # full: (reps, batch, ...); row: (reps, 1, ...)
            return jax.lax.dynamic_update_slice_in_dim(
                full, row.astype(full.dtype), slot, axis=1)
        self.caches = [jax.tree.map(upd, fg, rg)
                       for fg, rg in zip(self.caches, row_caches)]


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="smoke")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--s-max", type=int, default=128)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg, _, _ = preset_config(args.preset)
    mesh = local_test_mesh()
    key = jax.random.PRNGKey(args.seed)
    params = jax.jit(partial(M.init_params, cfg=cfg))(key)
    dtype = jnp.dtype(cfg.compute_dtype)

    # Request queue: deterministic synthetic prompts.
    rng = np.random.default_rng(args.seed)
    queue = [rng.integers(1, cfg.vocab_size, size=args.prompt_len)
             .astype(np.int32) for _ in range(args.requests)]

    prefill = jax.jit(lambda p, toks, c: M.forward(
        p, cfg, toks, caches=c, mode="prefill", mesh=mesh))
    decode = jax.jit(lambda p, c, tok, pos: M.forward(
        p, cfg, tok, positions=pos, caches=c, mode="decode", mesh=mesh))

    slots = SlotCache(cfg, args.batch, args.s_max, dtype)
    cur_tok = np.zeros((args.batch, 1), np.int32)
    cur_pos = np.zeros((args.batch,), np.int32)
    remaining = np.zeros((args.batch,), np.int32)  # tokens left; 0 = free
    outputs: list[list[int]] = [[] for _ in range(args.requests)]
    slot_req = [-1] * args.batch
    next_req = 0
    done = 0
    t0 = time.time()
    ticks = 0

    with mesh:
        while done < args.requests:
            # Fill free slots by prefilling queued requests (batch-1 prefill).
            for s in range(args.batch):
                if remaining[s] == 0 and next_req < len(queue):
                    prompt = queue[next_req][None, :]
                    row = M.init_cache(cfg, 1, args.s_max, dtype=dtype)
                    logits, row = prefill(params, jnp.asarray(prompt), row)
                    slots.splice(row, s)
                    cur_tok[s, 0] = int(jnp.argmax(logits[0, -1]))
                    cur_pos[s] = prompt.shape[1]
                    # prefill already produced one of the max_new tokens
                    remaining[s] = args.max_new - 1
                    slot_req[s] = next_req
                    outputs[next_req].append(int(cur_tok[s, 0]))
                    next_req += 1
                    if remaining[s] == 0:  # max_new == 1: done at prefill
                        done += 1

            if remaining.max() == 0:
                break
            # One decode tick for the whole batch.
            positions = jnp.broadcast_to(jnp.asarray(cur_pos)[:, None],
                                         (args.batch, 1)).astype(jnp.int32)
            logits, slots.caches = decode(params, slots.caches,
                                          jnp.asarray(cur_tok), positions)
            nxt = np.asarray(jnp.argmax(logits[:, -1], axis=-1), np.int32)
            ticks += 1
            for s in range(args.batch):
                if remaining[s] > 0:
                    outputs[slot_req[s]].append(int(nxt[s]))
                    cur_tok[s, 0] = nxt[s]
                    cur_pos[s] += 1
                    remaining[s] -= 1
                    if remaining[s] == 0:
                        done += 1

    wall = time.time() - t0
    total_new = sum(len(o) for o in outputs)
    print(f"[serve] {args.requests} requests, {total_new} tokens, "
          f"{ticks} decode ticks, {wall:.2f}s "
          f"({total_new/max(wall,1e-9):.1f} tok/s)")
    for i, o in enumerate(outputs):
        print(f"  req{i}: {o[:8]}{'...' if len(o) > 8 else ''}")
    return outputs


if __name__ == "__main__":
    main()
