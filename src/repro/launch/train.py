"""End-to-end training driver: data pipeline -> sharded train_step ->
checkpoint/restart -> monitoring.  Runs the same code path on the CPU
container (reduced config, mesh (n,1)) and a real TPU pod (full config,
production mesh); only flags differ.

Fault-tolerance behaviour (exercised by tests/test_train_integration.py):
* resume: ``--resume`` restores the latest checkpoint (params+opt+data step)
  and continues with the *identical* batch stream (deterministic pipeline);
* emergency save: SIGTERM/SIGINT triggers a final synchronous checkpoint
  before exit (preemption path on real clusters);
* straggler monitor: per-step deadline detection via EWMA (single-host here;
  heartbeat files on shared storage in multi-host deployments).

Usage::

  PYTHONPATH=src python -m repro.launch.train --preset smoke --steps 30
  PYTHONPATH=src python -m repro.launch.train --preset 100m --steps 300 \
      --ckpt-dir /tmp/ckpt --resume
  PYTHONPATH=src python -m repro.launch.train --arch stablelm-1.6b ...  # pod
"""
from __future__ import annotations

import argparse
import signal
import time
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.checkpoint.manager import CheckpointManager
from repro.configs import get_arch
from repro.data.pipeline import DataConfig, make_batches, synthetic_dataset
from repro.distributed.monitor import StepTimer
from repro.launch import steps as S
from repro.launch.mesh import local_test_mesh
from repro.models import model as M
from repro.optim import OptConfig, init_opt_state

PRESETS = {
    # name -> (ModelConfig kwargs, seq, batch)  (vocab kept modest for CPU)
    "smoke": (dict(d_model=128, n_heads=4, n_kv_heads=4, head_dim=32,
                   d_ff=512, vocab_size=512, n_layers=2), 128, 4),
    "20m": (dict(d_model=384, n_heads=6, n_kv_heads=6, head_dim=64,
                 d_ff=1536, vocab_size=8192, n_layers=6), 256, 4),
    "100m": (dict(d_model=768, n_heads=12, n_kv_heads=12, head_dim=64,
                  d_ff=3072, vocab_size=32768, n_layers=12), 512, 8),
}


def preset_config(name: str) -> tuple[M.ModelConfig, int, int]:
    kw, seq, batch = PRESETS[name]
    kw = dict(kw)  # PRESETS must survive repeated calls
    n_layers = kw.pop("n_layers")
    spec = M.LayerSpec(kind="attn", window=None, mlp="dense")
    cfg = M.ModelConfig(name=f"preset-{name}", blocks=(((spec,), n_layers),),
                        max_seq=seq, **kw)
    return cfg, seq, batch


def build_state(cfg: M.ModelConfig, ocfg: OptConfig, mesh, key):
    pshapes = jax.eval_shape(partial(M.init_params, cfg=cfg), key)
    pspecs = S.param_specs(pshapes, cfg, mesh)
    state_shapes = jax.eval_shape(
        lambda p: {"params": p, "opt": init_opt_state(p, ocfg)}, pshapes)
    sspecs = S.state_specs(state_shapes, pspecs)
    ssharding = jax.tree.map(lambda s: NamedSharding(mesh, s), sspecs)

    @partial(jax.jit, out_shardings=ssharding)
    def init(key):
        p = M.init_params(key, cfg)
        return {"params": p, "opt": init_opt_state(p, ocfg)}

    return init(key), ssharding, state_shapes


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="smoke", choices=sorted(PRESETS))
    ap.add_argument("--arch", default=None,
                    help="assigned arch id (full config; pod-scale)")
    ap.add_argument("--steps", type=int, default=30)
    ap.add_argument("--seq", type=int, default=None)
    ap.add_argument("--batch", type=int, default=None)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--model-parallel", type=int, default=1)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    if args.arch:
        cfg = get_arch(args.arch).model
        seq, batch = args.seq or 4096, args.batch or 256
    else:
        cfg, seq, batch = preset_config(args.preset)
        seq = args.seq or seq
        batch = args.batch or batch

    mesh = local_test_mesh(model=args.model_parallel)
    ocfg = OptConfig(lr=args.lr, total_steps=max(args.steps, 100),
                     warmup_steps=min(50, max(5, args.steps // 10)))

    key = jax.random.PRNGKey(args.seed)
    state, ssharding, state_shapes = build_state(cfg, ocfg, mesh, key)
    n_params = sum(int(np.prod(x.shape))
                   for x in jax.tree.leaves(state["params"]))
    print(f"[train] model={cfg.name} params={n_params/1e6:.1f}M "
          f"seq={seq} batch={batch} mesh={dict(mesh.shape)}")

    dcfg = DataConfig(seq_len=seq, global_batch=batch,
                      vocab_size=cfg.vocab_size, seed=args.seed)
    ds = synthetic_dataset(dcfg, n_tokens=max(1 << 18, 4 * batch * (seq + 1)))

    start_step = 0
    mgr = None
    if args.ckpt_dir:
        mgr = CheckpointManager(args.ckpt_dir, keep_last=3)
        if args.resume and mgr.latest_step() is not None:
            state, extras = mgr.restore(state_shapes, shardings=ssharding)
            start_step = int(extras["data_step"])
            print(f"[train] resumed at step {start_step}")

    train_step = jax.jit(S.make_train_step(cfg, ocfg, mesh, batch),
                         in_shardings=(ssharding, None),
                         out_shardings=(ssharding, None),
                         donate_argnums=(0,))

    # Emergency checkpoint on preemption (SIGTERM) / Ctrl-C.
    stop = {"now": False}

    def _sig(signum, frame):
        stop["now"] = True

    old_term = signal.signal(signal.SIGTERM, _sig)
    old_int = signal.signal(signal.SIGINT, _sig)

    timer = StepTimer()
    losses = []
    t_start = time.time()
    try:
        with mesh:
            for step, host_tokens in make_batches(ds, start_step, args.steps):
                timer.start()
                batch_data = {"tokens": jnp.asarray(host_tokens)}
                state, loss = train_step(state, batch_data)
                loss = float(loss)
                losses.append(loss)
                dt = timer.stop()
                if step % args.log_every == 0 or step == args.steps - 1:
                    tps = batch * seq / max(dt, 1e-9)
                    print(f"[train] step={step:5d} loss={loss:8.4f} "
                          f"dt={dt*1e3:7.1f}ms tok/s={tps:9.0f}")
                if mgr and args.ckpt_every and (step + 1) % args.ckpt_every == 0:
                    mgr.save_async(step + 1, state,
                                   extras={"data_step": step + 1,
                                           "loss": loss,
                                           "data_fingerprint": dcfg.fingerprint()})
                if stop["now"]:
                    print("[train] interrupt — emergency checkpoint")
                    if mgr:
                        mgr.save(step + 1, state,
                                 extras={"data_step": step + 1, "loss": loss,
                                         "emergency": True,
                                         "data_fingerprint": dcfg.fingerprint()})
                    break
    finally:
        signal.signal(signal.SIGTERM, old_term)
        signal.signal(signal.SIGINT, old_int)
        if mgr:
            mgr.wait()

    wall = time.time() - t_start
    print(f"[train] done: {len(losses)} steps in {wall:.1f}s "
          f"loss {losses[0]:.4f} -> {losses[-1]:.4f}")
    return losses


if __name__ == "__main__":
    main()
