"""Sharded train/prefill/serve steps + PartitionSpec rules for every arch.

Sharding policy (DESIGN.md §6):
  * FSDP: params/grads/opt-state sharded over ('pod','data') (storage axes);
  * TP  : q-heads / d_ff / vocab / experts over 'model' when divisible,
          KV heads replicated when Hkv < tp (Megatron-GQA convention);
  * EP  : MoE expert axis over 'model' with all_to_all dispatch;
  * SP  : long-context (batch=1) caches shard the sequence axis over DP axes.

All step functions are built by ``make_step`` and lowered either with real
arrays (examples/tests) or ShapeDtypeStructs (dry-run).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from jax.tree_util import DictKey

from repro.configs.common import SHAPES, ArchSpec
from repro.launch import shardctx
from repro.launch.mesh import dp_axes
from repro.models import model as M
from repro.optim import OptConfig, init_opt_state, opt_update, apply_updates


# ----------------------------------------------------------- spec assignment
def _fit(size: int, axes: tuple, mesh) -> Optional[Any]:
    """Largest prefix of ``axes`` whose product divides ``size``."""
    out = []
    prod = 1
    for a in axes:
        if size % (prod * mesh.shape[a]) == 0:
            out.append(a)
            prod *= mesh.shape[a]
    if not out:
        return None
    return tuple(out) if len(out) > 1 else out[0]


_BASE_NDIM = {
    "wq": 3, "wk": 3, "wv": 3, "wo": 3, "w_uk": 3, "w_uv": 3, "A_log": 2,
    "w_dkv": 2, "w_kr": 2, "router": 2, "in_proj": 2, "out_proj": 2,
    "x_proj": 2, "dt_proj": 2, "conv_w": 2, "conv_b": 1, "dt_bias": 1,
    "D": 1, "norm1": 1, "norm2": 1, "normc": 1, "final_norm": 1,
    "embed": 2, "lm_head": 2, "pos_embed": 2,
    "w_gate": 2, "w_up": 2, "w_down": 2,
}


def param_specs(params_shapes, cfg: M.ModelConfig, mesh):
    """PartitionSpec pytree mirroring the param pytree."""
    fsdp = dp_axes(mesh)
    tp = mesh.shape["model"]

    def tpm(size):  # 'model' when divisible
        return "model" if size % tp == 0 else None

    def spec_for(path, leaf):
        name = None
        for k in reversed(path):
            if isinstance(k, DictKey):
                name = k.key
                break
        shape = leaf.shape
        nd = len(shape)
        base = _BASE_NDIM.get(name, nd)
        is_moe = False
        if name in ("w_gate", "w_up", "w_down") and nd >= 3 \
                and cfg.n_experts and shape[nd - 3] == cfg.n_experts:
            base = 3
            is_moe = True
        lead = (None,) * (nd - base)
        t = shape[nd - base:] if base else ()

        def f(size):  # FSDP axes that fit
            return _fit(size, fsdp, mesh)

        if name in ("wq",):
            s = (f(t[0]), tpm(t[1]), None)
        elif name in ("wk", "wv"):
            s = (f(t[0]), tpm(t[1]), None)
        elif name == "wo":
            s = (tpm(t[0]), None, f(t[2]))
        elif name in ("w_uk", "w_uv"):
            s = (None, tpm(t[1]), None)
        elif name in ("w_dkv", "w_kr"):
            s = (f(t[0]), None)
        elif name == "router":
            s = (f(t[0]), None)
        elif name in ("w_gate", "w_up"):
            s = (tpm(t[0]), f(t[1]), None) if is_moe else (f(t[0]), tpm(t[1]))
        elif name == "w_down":
            s = (tpm(t[0]), None, f(t[2])) if is_moe else (tpm(t[0]), f(t[1]))
        elif name == "in_proj":
            s = (f(t[0]), tpm(t[1]))
        elif name == "out_proj":
            s = (tpm(t[0]), f(t[1]))
        elif name in ("x_proj",):
            s = (tpm(t[0]), None)
        elif name in ("dt_proj",):
            s = (None, tpm(t[1]))
        elif name == "conv_w":
            s = (None, tpm(t[1]))
        elif name in ("conv_b", "dt_bias", "D"):
            s = (tpm(t[0]),)
        elif name == "A_log":
            s = (tpm(t[0]), None)
        elif name == "embed":
            s = (tpm(t[0]), f(t[1]))
        elif name == "lm_head":
            s = (f(t[0]), tpm(t[1]))
        elif name == "pos_embed":
            s = (None, f(t[1]))
        else:  # norms and anything unknown: replicated
            s = (None,) * base
        return P(*(lead + tuple(s)))

    return jax.tree_util.tree_map_with_path(spec_for, params_shapes)


def _lookup(tree, keys):
    for k in keys:
        tree = tree[k]
    return tree


def state_specs(state_shapes, pspecs):
    """Specs for {'params':…, 'opt':…} train state (opt mirrors params;
    adafactor factored stats drop the corresponding param dim)."""
    def go(path, leaf):
        keys = [k.key if isinstance(k, DictKey) else k.idx for k in path]
        if keys[0] == "params":
            return _lookup(pspecs, keys[1:])
        assert keys[0] == "opt"
        if keys[1] == "step":
            return P()
        sub = keys[2:]
        if keys[1] == "m":
            return _lookup(pspecs, sub)
        # keys[1] == 'v': AdamW mirrors the param tree directly; Adafactor
        # nests {'v'} (vector-like) or {'vr','vc'} (factored) dicts.
        try:
            spec = _lookup(pspecs, sub)
            if isinstance(spec, P):
                return spec            # AdamW: v sharded exactly like p
        except (KeyError, TypeError, IndexError):
            pass
        last = sub[-1]
        if last == "v":
            return _lookup(pspecs, sub[:-1])
        base = tuple(_lookup(pspecs, sub[:-1]))
        if last == "vr":
            return P(*base[:-1])
        if last == "vc":
            return P(*(base[:-2] + base[-1:]))
        return P()

    return jax.tree_util.tree_map_with_path(go, state_shapes)


def cache_specs(cfg: M.ModelConfig, mesh, batch: int):
    """Specs mirroring init_cache. batch=1 -> sequence-parallel caches."""
    fsdp = dp_axes(mesh)
    tp = mesh.shape["model"]
    bspec = _fit(batch, fsdp, mesh)
    seq_par = bspec is None  # long-context: shard the sequence axis instead

    def layer_spec(spec: M.LayerSpec):
        if spec.kind == "mamba":
            c = {"conv": P(bspec, None, "model" if cfg.d_inner % tp == 0 else None),
                 "h": P(bspec, "model" if cfg.d_inner % tp == 0 else None, None)}
        elif spec.kind == "mla":
            sq = fsdp if seq_par else None
            if sq is None and cfg.seq_shard_kv:
                sq = "model"  # flash-decode layout: latent cache seq-sharded
            c = {"c_kv": P(bspec, sq, None),
                 "k_rope": P(bspec, sq, None),
                 "pos_k": P(bspec, sq)}
        else:
            kvs = "model" if cfg.n_kv_heads % tp == 0 else None
            sq = fsdp if seq_par else None
            if kvs is None and sq is None and cfg.seq_shard_kv \
                    and spec.window is None:
                # flash-decode layout: KV heads don't divide TP, so shard the
                # cache SEQUENCE over 'model' instead of replicating 16x.
                # GSPMD turns the softmax/PV reductions into tiny psums.
                sq = "model"
            c = {"k": P(bspec, sq, kvs, None),
                 "v": P(bspec, sq, kvs, None),
                 "pos_k": P(bspec, sq)}
        if spec.cross_attn:
            hs = "model" if cfg.n_heads % tp == 0 else None
            c["ck"] = P(bspec, None, hs, None)
            c["cv"] = P(bspec, None, hs, None)
        return c

    out = []
    for pattern, reps in cfg.blocks:
        out.append(tuple(
            jax.tree.map(lambda s: P(*((None,) + tuple(s))), layer_spec(sp),
                         is_leaf=lambda x: isinstance(x, P))
            for sp in pattern))
    return out


# ------------------------------------------------------------- input structs
def batch_struct(cfg: M.ModelConfig, seq: int, batch: int):
    """ShapeDtypeStructs for one training/prefill batch."""
    text = seq
    b = {}
    if cfg.frontend == "vision_stub":
        text = seq - cfg.frontend_len
        b["patch_embeds"] = jax.ShapeDtypeStruct(
            (batch, cfg.frontend_len, cfg.d_model), jnp.dtype(cfg.compute_dtype))
    if cfg.kind == "encdec":
        b["audio_frames"] = jax.ShapeDtypeStruct(
            (batch, cfg.frontend_len, cfg.d_model), jnp.dtype(cfg.compute_dtype))
    b["tokens"] = jax.ShapeDtypeStruct((batch, text + 1), jnp.int32)
    return b


def batch_specs(cfg: M.ModelConfig, mesh, batch: int):
    dp = _fit(batch, dp_axes(mesh), mesh)
    b = {"tokens": P(dp, None)}
    if cfg.frontend == "vision_stub":
        b["patch_embeds"] = P(dp, None, None)
    if cfg.kind == "encdec":
        b["audio_frames"] = P(dp, None, None)
    return b


def activation_policy(cfg, mesh, batch):
    dp = _fit(batch, dp_axes(mesh), mesh)
    pol = {
        "hidden": NamedSharding(mesh, P(dp, None, None)),
        "logits": NamedSharding(mesh, P(dp, None,
                                        "model" if cfg.vocab_size % mesh.shape["model"] == 0 else None)),
    }
    if cfg.seq_parallel:
        pol["hidden_sp"] = NamedSharding(mesh, P(dp, "model", None))
    if cfg.seq_shard_kv:
        pol["kv_sp"] = NamedSharding(mesh, P(dp, "model", None, None))
        pol["kvpos_sp"] = NamedSharding(mesh, P(dp, "model"))
        pol["scores_sp"] = NamedSharding(mesh, P(dp, None, None, "model"))
    return pol


# ------------------------------------------------------------------- steps
def make_train_step(cfg: M.ModelConfig, ocfg: OptConfig, mesh, batch: int):
    def train_step(state, batch_data):
        with shardctx.activation_sharding(activation_policy(cfg, mesh, batch)):
            loss, grads = jax.value_and_grad(M.lm_loss)(
                state["params"], cfg, batch_data, mesh)
        updates, opt = opt_update(grads, state["params"], state["opt"], ocfg)
        params = apply_updates(state["params"], updates)
        return {"params": params, "opt": opt}, loss

    return train_step


def make_prefill_step(cfg: M.ModelConfig, mesh, batch: int, s_max: int):
    def prefill_step(params, batch_data, caches):
        kw = {}
        if cfg.frontend == "vision_stub":
            kw["embeds"] = batch_data["patch_embeds"]
        if cfg.kind == "encdec":
            kw["enc_frames"] = batch_data["audio_frames"]
        with shardctx.activation_sharding(activation_policy(cfg, mesh, batch)):
            logits, caches = M.forward(params, cfg, batch_data["tokens"][:, :-1],
                                       caches=caches, mode="prefill",
                                       mesh=mesh, **kw)
        next_tok = jnp.argmax(logits[:, -1:], axis=-1)
        return next_tok.astype(jnp.int32), caches

    return prefill_step


def make_serve_step(cfg: M.ModelConfig, mesh, batch: int):
    def serve_step(params, caches, tokens, pos):
        positions = jnp.broadcast_to(pos[:, None], tokens.shape).astype(jnp.int32)
        with shardctx.activation_sharding(activation_policy(cfg, mesh, batch)):
            logits, caches = M.forward(params, cfg, tokens, positions=positions,
                                       caches=caches, mode="decode", mesh=mesh)
        next_tok = jnp.argmax(logits[:, -1], axis=-1)[:, None]
        return next_tok.astype(jnp.int32), caches

    return serve_step


# ------------------------------------------------------------ cell assembly
@dataclasses.dataclass
class Cell:
    """One (arch × shape × mesh) dry-run unit: jitted fn + abstract args."""
    arch_id: str
    shape_name: str
    kind: str
    fn: Any          # jitted
    args: tuple      # ShapeDtypeStructs
    model_cfg: M.ModelConfig


def _dryrun_model_cfg(spec: ArchSpec, shape_name: str, mesh,
                      overrides: Optional[dict] = None) -> M.ModelConfig:
    seq, batch, kind = SHAPES[shape_name]
    over = dict(
        param_dtype="bfloat16",
        compute_dtype="bfloat16",
        remat="dots" if kind == "train" else "none",
        moe_ep=bool(spec.model.n_experts) and batch >= 16,
    )
    over.update(overrides or {})
    return dataclasses.replace(spec.model, **over)


def build_cell(spec: ArchSpec, shape_name: str, mesh,
               ocfg: Optional[OptConfig] = None,
               overrides: Optional[dict] = None) -> Cell:
    """Construct the jitted step + abstract inputs for one cell."""
    seq, batch, kind = SHAPES[shape_name]
    cfg = _dryrun_model_cfg(spec, shape_name, mesh, overrides)
    if ocfg is None:
        big = cfg.param_count()[0] > 50e9
        ocfg = OptConfig(kind="adafactor" if big else "adamw",
                         moment_dtype="bfloat16" if big else "float32")

    # abstract params / state
    pshapes = jax.eval_shape(partial(M.init_params, cfg=cfg),
                             jax.random.PRNGKey(0))
    pspecs = param_specs(pshapes, cfg, mesh)
    if kind != "train" and cfg.serve_params_tp_only:
        # Serving layout: strip the FSDP axes so weights are TP-sharded and
        # DP-replicated — no per-step weight all-gather (§Perf H-i3).
        def _tp_only(spec):
            return P(*(a if a == "model" else None for a in spec))
        pspecs = jax.tree.map(_tp_only, pspecs,
                              is_leaf=lambda x: isinstance(x, P))
    psharding = jax.tree.map(lambda s: NamedSharding(mesh, s), pspecs)

    if kind == "train":
        state_shapes = jax.eval_shape(
            lambda p: {"params": p, "opt": init_opt_state(p, ocfg)}, pshapes)
        sspecs = state_specs(state_shapes, pspecs)
        ssharding = jax.tree.map(lambda s: NamedSharding(mesh, s), sspecs)
        bstruct = batch_struct(cfg, seq, batch)
        bsharding = jax.tree.map(lambda s: NamedSharding(mesh, s),
                                 batch_specs(cfg, mesh, batch))
        fn = jax.jit(make_train_step(cfg, ocfg, mesh, batch),
                     in_shardings=(ssharding, bsharding),
                     out_shardings=(ssharding, NamedSharding(mesh, P())),
                     donate_argnums=(0,))
        args = (state_shapes, bstruct)
    else:
        enc_len = cfg.frontend_len if cfg.kind == "encdec" else 0
        cshapes = jax.eval_shape(
            partial(M.init_cache, cfg, batch, seq,
                    dtype=jnp.dtype(cfg.compute_dtype), enc_len=enc_len))
        cspecs = cache_specs(cfg, mesh, batch)
        csharding = jax.tree.map(lambda s: NamedSharding(mesh, s), cspecs,
                                 is_leaf=lambda x: isinstance(x, P))
        if kind == "prefill":
            bstruct = batch_struct(cfg, seq, batch)
            bsharding = jax.tree.map(lambda s: NamedSharding(mesh, s),
                                     batch_specs(cfg, mesh, batch))
            fn = jax.jit(make_prefill_step(cfg, mesh, batch, seq),
                         in_shardings=(psharding, bsharding, csharding),
                         out_shardings=None,
                         donate_argnums=(2,))
            args = (pshapes, bstruct, cshapes)
        else:  # decode
            dp = _fit(batch, dp_axes(mesh), mesh)
            tok = jax.ShapeDtypeStruct((batch, 1), jnp.int32)
            pos = jax.ShapeDtypeStruct((batch,), jnp.int32)
            fn = jax.jit(make_serve_step(cfg, mesh, batch),
                         in_shardings=(psharding, csharding,
                                       NamedSharding(mesh, P(dp, None)),
                                       NamedSharding(mesh, P(dp))),
                         out_shardings=None,
                         donate_argnums=(1,))
            args = (pshapes, cshapes, tok, pos)

    return Cell(arch_id=spec.arch_id, shape_name=shape_name, kind=kind,
                fn=fn, args=args, model_cfg=cfg)
