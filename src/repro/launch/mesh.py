"""Production mesh construction (assignment spec).

``make_production_mesh`` is a FUNCTION (never a module-level constant) so
importing this module does not touch jax device state.  The dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` *before* any jax
import (see dryrun.py); smoke tests and benchmarks see the real single
device.
"""
from __future__ import annotations

from typing import Sequence

import jax
import numpy as np


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: (data=16, model=16) = 256 chips.
    Multi-pod: (pod=2, data=16, model=16) = 512 chips."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_mesh(shape: Sequence[int], axes: Sequence[str]):
    """Arbitrary mesh for tests/examples (e.g. (1,1) on CPU)."""
    try:
        return jax.make_mesh(
            tuple(shape), tuple(axes),
            axis_types=(jax.sharding.AxisType.Auto,) * len(axes))
    except (AttributeError, TypeError):
        # Older jax: no jax.sharding.AxisType / axis_types kwarg (Auto is
        # that jax's only behaviour anyway) — build the mesh without it.
        return jax.make_mesh(tuple(shape), tuple(axes))


def shard_map(fn, mesh, in_specs, out_specs, check_vma: bool = False):
    """``jax.shard_map`` with an older-jax fallback.

    Newer jax exposes ``jax.shard_map(..., check_vma=...)``; older
    releases only have ``jax.experimental.shard_map.shard_map`` whose
    equivalent knob is spelled ``check_rep``.  Every shard_map call in
    this repo goes through here so multi-device code runs on both.
    """
    if hasattr(jax, "shard_map"):
        return jax.shard_map(fn, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=check_vma)
    from jax.experimental.shard_map import shard_map as _shard_map
    return _shard_map(fn, mesh=mesh, in_specs=in_specs,
                      out_specs=out_specs, check_rep=check_vma)


def slot_pool_mesh(n_shards: int):
    """1-D mesh backing the serving engine's sharded slot pool.

    One mesh device = one engine shard (``repro/service/sharding.py``).
    Requires ``n_shards <= len(jax.devices())``; the service layer falls
    back to round-robin logical shards when oversubscribed (CPU tests
    without ``XLA_FLAGS=--xla_force_host_platform_device_count``).
    """
    return make_mesh((n_shards,), ("pool",))


def local_test_mesh(model: int = 1):
    """Mesh over whatever devices exist locally (CPU smoke/integration)."""
    n = len(jax.devices())
    return make_mesh((n // model, model), ("data", "model"))


def dp_axes(mesh) -> tuple:
    """Axes used for batch/FSDP sharding ('pod' folds into DP)."""
    return tuple(a for a in mesh.axis_names if a != "model")


def mesh_size(mesh) -> int:
    return int(np.prod(list(mesh.shape.values())))
