"""Activation-sharding context: lets the model apply
``with_sharding_constraint`` at key points without threading mesh/specs
through every layer signature.

steps.py installs a policy dict (name -> NamedSharding); model.py calls
``constrain(x, "hidden")`` etc.  Outside any policy (CPU smoke tests) it is
an identity.
"""
from __future__ import annotations

import contextlib
import threading

import jax

_tls = threading.local()


def current() -> dict:
    return getattr(_tls, "policy", None) or {}


@contextlib.contextmanager
def activation_sharding(policy: dict):
    prev = getattr(_tls, "policy", None)
    _tls.policy = policy
    try:
        yield
    finally:
        _tls.policy = prev


def constrain(x, name: str):
    s = current().get(name)
    if s is None:
        return x
    return jax.lax.with_sharding_constraint(x, s)
