import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST be the first two lines: jax locks the device count at first init.
"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell with
ShapeDtypeStruct inputs (no allocation) and extract roofline terms.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch gemma3-4b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod] [--out artifacts/dryrun]
  PYTHONPATH=src python -m repro.launch.dryrun --sa   # the SA production cell

Artifacts: one JSON per cell with memory_analysis, cost_analysis and the
collective-byte census parsed from the compiled HLO (§Roofline inputs).
"""
import argparse
import json
import re
import time
import traceback
from pathlib import Path

import jax
import numpy as np

# hardware constants (TPU v5e target)
PEAK_FLOPS = 197e12        # bf16 FLOP/s per chip
HBM_BW = 819e9             # bytes/s per chip
ICI_BW = 50e9              # bytes/s per link

_DTYPE_BYTES = {
    "f32": 4, "bf16": 2, "f16": 2, "f64": 8, "s32": 4, "u32": 4, "s8": 1,
    "u8": 1, "pred": 1, "s16": 2, "u16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8,
}

_COLL_RE = re.compile(
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?(?:\.\d+)?\s*\(")
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def collective_bytes(hlo_text: str) -> dict:
    """Sum operand bytes of every collective op in the HLO text."""
    out = {}
    for line in hlo_text.splitlines():
        line = line.strip()
        # result-shape = op-name(...)  — match op kind anywhere on the line
        m = re.search(r"=\s*(?:\([^)]*\)|\S+)\s+"
                      r"(all-gather|all-reduce|reduce-scatter|all-to-all|"
                      r"collective-permute)(?:-start)?", line)
        if not m:
            continue
        kind = m.group(1)
        # operand shapes: parse shapes on the RHS inside the call parens
        rhs = line.split("=", 1)[1]
        call = rhs[rhs.index("("):] if "(" in rhs else ""
        nbytes = 0
        for dt, dims in _SHAPE_RE.findall(call):
            if dt not in _DTYPE_BYTES:
                continue
            n = 1
            for d in dims.split(","):
                if d:
                    n *= int(d)
            nbytes += n * _DTYPE_BYTES[dt]
        out[kind] = out.get(kind, 0) + nbytes
    return out


def roofline_terms(flops: float, bytes_acc: float, coll: dict, n_chips: int):
    """NOTE: XLA's cost_analysis on an SPMD-partitioned module reports
    *per-device* quantities (verified empirically — see EXPERIMENTS.md
    §Methodology), so the terms divide by per-chip peaks only; this equals
    the assignment's global/(chips × peak) formula."""
    cbytes = float(sum(coll.values()))
    return {
        "compute_s": flops / PEAK_FLOPS,
        "memory_s": bytes_acc / HBM_BW,
        "collective_s": cbytes / ICI_BW,
        "collective_bytes": cbytes,
    }


def run_cell(arch_id: str, shape_name: str, *, multi_pod: bool,
             out_dir: Path, overrides=None, tag: str = "") -> dict:
    from repro.configs import get_arch
    from repro.launch.mesh import make_production_mesh, mesh_size
    from repro.launch.steps import build_cell

    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = mesh_size(mesh)
    spec = get_arch(arch_id)
    t0 = time.time()

    # 1) PRODUCTION program (scanned layer stacks): this is the artifact that
    #    must lower+compile — memory analysis comes from here.
    cell = build_cell(spec, shape_name, mesh, overrides=overrides)
    with mesh:
        lowered = cell.fn.lower(*cell.args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
    mem = compiled.memory_analysis()

    # 2) MEASUREMENT program (scan fully unrolled): XLA cost_analysis counts
    #    while-loop bodies ONCE, so the scanned program under-reports
    #    flops/bytes/collectives by ~n_layers. The unrolled variant gives the
    #    true per-step per-device cost. (Production keeps the scan for
    #    compile-time sanity at 512 devices; the unroll exists only here.)
    over2 = dict(overrides or {})
    over2["scan_unroll"] = 0
    cell2 = build_cell(spec, shape_name, mesh, overrides=over2)
    with mesh:
        compiled2 = cell2.fn.lower(*cell2.args).compile()
    t_measure = time.time() - t0 - t_lower - t_compile

    from repro.launch.hloparse import parse_hlo_costs
    cost = compiled2.cost_analysis()
    hlo = compiled2.as_text()
    parsed = parse_hlo_costs(hlo)
    coll = parsed["wire"]

    flops = float(cost.get("flops", 0.0))
    bytes_raw = float(cost.get("bytes accessed", 0.0))
    bytes_acc = parsed["hbm_bytes"]  # fusion-aware (hloparse.py)
    terms = roofline_terms(flops, bytes_acc, coll, n_chips)
    tot, act = cell.model_cfg.param_count()
    from repro.configs.common import SHAPES
    seq_len, batch, kind = SHAPES[shape_name]
    tokens = batch * (seq_len if kind != "decode" else 1)
    # 6ND for a train step (fwd+bwd), 2ND for inference FLOPs
    mult = 6 if kind == "train" else 2
    model_flops = mult * act * tokens

    rec = {
        "arch": arch_id, "shape": shape_name, "mesh": list(mesh.shape.values()),
        "multi_pod": multi_pod, "n_chips": n_chips, "kind": kind, "tag": tag,
        "params_total": tot, "params_active": act,
        "bytes_per_device": {
            "argument": getattr(mem, "argument_size_in_bytes", 0),
            "output": getattr(mem, "output_size_in_bytes", 0),
            "temp": getattr(mem, "temp_size_in_bytes", 0),
            "peak": getattr(mem, "peak_memory_in_bytes", 0),
        },
        "hlo_flops": flops, "hlo_bytes": bytes_acc,
        "hlo_bytes_raw_prefusion": bytes_raw,
        "hbm_by_op": parsed.get("by_op", {}),
        "collectives": coll,
        "roofline": terms,
        "model_flops": model_flops,
        "model_flops_per_chip": model_flops / n_chips,
        "useful_flops_frac": (model_flops / n_chips) / flops if flops else None,
        "lower_s": t_lower, "compile_s": t_compile, "measure_s": t_measure,
    }
    dom = max(("compute_s", "memory_s", "collective_s"),
              key=lambda k: terms[k])
    rec["bottleneck"] = dom.replace("_s", "")

    out_dir.mkdir(parents=True, exist_ok=True)
    suffix = ("multi" if multi_pod else "single") + (f"_{tag}" if tag else "")
    path = out_dir / f"{arch_id}__{shape_name}__{suffix}.json"
    path.write_text(json.dumps(rec, indent=1))
    print(f"[ok] {arch_id:24s} {shape_name:12s} {suffix:12s} "
          f"compute={terms['compute_s']:.3e}s memory={terms['memory_s']:.3e}s "
          f"coll={terms['collective_s']:.3e}s dom={rec['bottleneck']} "
          f"peak={rec['bytes_per_device']['peak']/2**30:.2f}GiB "
          f"(lower {t_lower:.0f}s compile {t_compile:.0f}s)")
    return rec


def _sa_measure(obj, base_cfg, mesh, levels: int, n_steps: int):
    """Compile a tiny fully-unrolled SA ladder and return (flops, bytes, coll)."""
    import dataclasses as dc

    import jax.numpy as jnp

    from repro.core import build_sharded_ladder

    tmin = {1: 0.5, 2: 0.25}[levels]
    cfg = dc.replace(base_cfg, T0=1.0, T_min=tmin, rho=0.5, N=n_steps,
                     record_history=False, unroll=True)
    assert cfg.n_levels == levels
    fn = jax.jit(build_sharded_ladder(obj, cfg, mesh))
    key = jax.ShapeDtypeStruct((2,), jnp.uint32)
    x0 = jax.ShapeDtypeStruct((cfg.n_chains, obj.dim), jnp.float32)
    with mesh:
        compiled = fn.lower(key, x0).compile()
    from repro.launch.hloparse import parse_hlo_costs
    cost = compiled.cost_analysis()
    parsed = parse_hlo_costs(compiled.as_text())
    return (float(cost.get("flops", 0.0)), parsed["hbm_bytes"], parsed["wire"])


def run_sa_cell(*, multi_pod: bool, out_dir: Path, n_chains: int = 1 << 22,
                dim: int = 512, exchange: str = "sync", tag: str = "",
                use_delta_eval: bool = False, n_steps: int = 100) -> dict:
    """The paper's own technique at production scale (DESIGN.md §4.1).

    Cost methodology: the production program nests fori_loop(N) inside
    scan(levels) — XLA cost_analysis counts each loop body once, so we
    compile three tiny *unrolled* variants (L,N) ∈ {(1,1),(1,2),(2,1)} and
    solve F(L,N) = S0 + L·S1 + L·N·b for the per-step/per-level/fixed parts,
    then extrapolate to the real (levels=1146, N=100) schedule.
    """
    from repro import objectives
    from repro.core import SAConfig, build_sharded_ladder
    from repro.launch.mesh import make_production_mesh, mesh_size

    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = mesh_size(mesh)
    obj = objectives.functions.schwefel(dim)
    cfg = SAConfig(T0=1000.0, T_min=0.01, rho=0.99, N=n_steps,
                   n_chains=n_chains, exchange=exchange,
                   use_delta_eval=use_delta_eval,
                   record_history=False)
    fn = jax.jit(build_sharded_ladder(obj, cfg, mesh))
    key = jax.ShapeDtypeStruct((2,), jax.numpy.uint32)
    x0 = jax.ShapeDtypeStruct((n_chains, dim), jax.numpy.float32)

    t0 = time.time()
    with mesh:
        lowered = fn.lower(key, x0)
        compiled = lowered.compile()
    t_all = time.time() - t0

    mem = compiled.memory_analysis()

    # loop-algebra cost measurement
    fa, ba, ca = _sa_measure(obj, cfg, mesh, 1, 1)
    fb, bb, cb = _sa_measure(obj, cfg, mesh, 1, 2)
    fc, bc, cc = _sa_measure(obj, cfg, mesh, 2, 1)
    L, N = cfg.n_levels, cfg.N

    def extrap(a, b_, c):
        step = max(b_ - a, 0.0)
        lvl = max(c - a - step, 0.0)
        fixed = max(a - lvl - step, 0.0)
        return fixed + L * lvl + L * N * step

    flops = extrap(fa, fb, fc)
    bytes_acc = extrap(ba, bb, bc)
    kinds = set(ca) | set(cb) | set(cc)
    coll = {k: extrap(ca.get(k, 0), cb.get(k, 0), cc.get(k, 0)) for k in kinds}
    terms = roofline_terms(flops, bytes_acc, coll, n_chips)
    rec = {
        "arch": f"sa-schwefel-{dim}", "shape": f"chains_{n_chains}",
        "mesh": list(mesh.shape.values()), "multi_pod": multi_pod,
        "n_chips": n_chips, "kind": "sa", "tag": tag,
        "exchange": exchange, "n_evals": cfg.n_evals,
        "delta_eval": use_delta_eval, "levels": L, "N": N,
        "bytes_per_device": {"peak": getattr(mem, "peak_memory_in_bytes", 0)},
        "hlo_flops": flops, "hlo_bytes": bytes_acc,
        "collectives": coll, "roofline": terms,
        "compile_s": t_all,
    }
    dom = max(("compute_s", "memory_s", "collective_s"), key=lambda k: terms[k])
    rec["bottleneck"] = dom.replace("_s", "")
    out_dir.mkdir(parents=True, exist_ok=True)
    suffix = ("multi" if multi_pod else "single") + (f"_{tag}" if tag else "")
    dl = "_delta" if use_delta_eval else ""
    path = out_dir / f"sa_schwefel{dim}__{exchange}{dl}__{suffix}.json"
    path.write_text(json.dumps(rec, indent=1))
    print(f"[ok] SA {exchange} chains={n_chains} dim={dim} {suffix} "
          f"compute={terms['compute_s']:.3e}s memory={terms['memory_s']:.3e}s "
          f"coll={terms['collective_s']:.3e}s dom={rec['bottleneck']}")
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--sa", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--out", default="artifacts/dryrun")
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()
    out_dir = Path(args.out)

    from repro.configs import ARCH_IDS, get_arch

    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    jobs = []
    if args.sa:
        for mp in meshes:
            jobs.append(("sa", None, mp))
    if args.all:
        for aid in ARCH_IDS:
            for shape_name, _ in get_arch(aid).shapes():
                for mp in meshes:
                    jobs.append((aid, shape_name, mp))
    elif args.arch:
        shapes = ([args.shape] if args.shape
                  else [s for s, _ in get_arch(args.arch).shapes()])
        for s in shapes:
            for mp in meshes:
                jobs.append((args.arch, s, mp))

    failures = []
    for aid, shape_name, mp in jobs:
        suffix = "multi" if mp else "single"
        if args.skip_existing and aid != "sa":
            p = out_dir / f"{aid}__{shape_name}__{suffix}.json"
            if p.exists():
                print(f"[skip] {aid} {shape_name} {suffix}")
                continue
        try:
            if aid == "sa":
                run_sa_cell(multi_pod=mp, out_dir=out_dir)
            else:
                run_cell(aid, shape_name, multi_pod=mp, out_dir=out_dir)
        except Exception as e:  # noqa: BLE001 - report and continue
            failures.append((aid, shape_name, mp, repr(e)))
            print(f"[FAIL] {aid} {shape_name} {suffix}: {e!r}")
            traceback.print_exc()
    if failures:
        print(f"\n{len(failures)} FAILURES:")
        for f in failures:
            print("  ", f)
        raise SystemExit(1)
    print("\nall dry-run cells passed")


if __name__ == "__main__":
    main()
