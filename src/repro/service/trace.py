"""Chrome/Perfetto ``trace_event`` tracing for the serving engine.

:class:`TraceBuilder` collects trace events the engine emits while
serving — per-tick phase spans (``schedule / admit / dispatch /
device_wait / materialize / retire``, one timeline row per shard plus an
aggregate row) and per-request lifecycle tracks (submit → admit →
per-level ticks → preempt / migrate / shrink → complete) — and renders
them as one Trace Event Format JSON document (``serve_sa --trace
out.json``).  Open the file at https://ui.perfetto.dev (or
``chrome://tracing``): a drain-under-load run becomes a visually
debuggable timeline instead of a pile of counters.

Layout conventions
------------------
* ``pid`` 0 is the engine process.  ``tid`` 0 carries fleet-wide phase
  spans (schedule/admit); ``tid`` ``shard_index + 1`` carries that
  shard's dispatch/device_wait/materialize/retire spans.  Metadata
  events name them.
* Request lifecycles are **async** events: category ``"request"``, id
  ``req_id`` — ``b`` at submit, ``n`` instants for admit / level /
  preempt / resume / migrate / shrink, ``e`` at the terminal.  Perfetto
  draws each request as one track spanning its queueing + residence.
* Decision instants (category ``"decision"``) mirror the structured
  event log (telemetry.py) so the two views cross-reference by tick.
* Timestamps are **microseconds** on the engine's monotonic epoch — the
  same clock every wall figure in the repo shares (engine.py ``_now``).

The emitted document validates against the checked-in schema
(``trace_schema.json``, next to this module): :func:`validate_trace`
enforces it in tests and CI, so the trace contract cannot drift
silently.  The validator implements the JSON-Schema subset the schema
uses (type / required / properties / items / enum / minimum) — no
third-party dependency.
"""
from __future__ import annotations

import json
from pathlib import Path
from typing import List, Optional

SCHEMA_PATH = Path(__file__).with_name("trace_schema.json")

_US = 1e6           # seconds -> trace microseconds


class TraceBuilder:
    """Accumulates Trace Event Format events (host-side, append-only)."""

    def __init__(self):
        self.events: List[dict] = []
        self._clock = None          # bound by the engine: epoch seconds
        self._named_tids = set()
        self._meta("process_name", {"name": "sa-serve-engine"}, tid=0)
        self._name_tid(0, "engine (schedule/admit)")

    # ------------------------------------------------------------- plumbing
    def bind_clock(self, clock) -> None:
        """Attach the engine's monotonic epoch clock (seconds)."""
        self._clock = clock

    def _now_us(self) -> float:
        return (self._clock() if self._clock is not None else 0.0) * _US

    def _meta(self, name: str, args: dict, tid: int) -> None:
        self.events.append({"ph": "M", "name": name, "pid": 0, "tid": tid,
                            "args": args})

    def _name_tid(self, tid: int, name: str) -> None:
        if tid not in self._named_tids:
            self._named_tids.add(tid)
            self._meta("thread_name", {"name": name}, tid=tid)

    def ensure_shard_track(self, shard_index: int) -> None:
        self._name_tid(shard_index + 1, f"shard {shard_index}")

    # ---------------------------------------------------------- phase spans
    def span(self, phase: str, t0: float, t1: float,
             shard: Optional[int] = None, tick: Optional[int] = None) -> None:
        """One complete ('X') phase span, [t0, t1] in epoch seconds."""
        tid = 0 if shard is None else shard + 1
        if shard is not None:
            self.ensure_shard_track(shard)
        ev = {"ph": "X", "name": phase, "cat": "tick", "pid": 0, "tid": tid,
              "ts": t0 * _US, "dur": max(t1 - t0, 0.0) * _US}
        if tick is not None:
            ev["args"] = {"tick": tick}
        self.events.append(ev)

    # ----------------------------------------------------- decision instants
    def instant(self, name: str, **args) -> None:
        """Thread-scoped instant mirroring one structured-log decision."""
        self.events.append({"ph": "i", "name": name, "cat": "decision",
                            "pid": 0, "tid": 0, "s": "t",
                            "ts": self._now_us(), "args": args})

    # ------------------------------------------------------ request lifecycle
    def _async(self, ph: str, req_id: int, name: str, args: dict) -> None:
        self.events.append({"ph": ph, "cat": "request", "id": int(req_id),
                            "name": name, "pid": 0, "tid": 0,
                            "ts": self._now_us(), "args": args})

    def request_begin(self, req_id: int, **args) -> None:
        self._async("b", req_id, f"req{req_id}", args)

    def request_instant(self, req_id: int, what: str, **args) -> None:
        self._async("n", req_id, what, args)

    def request_end(self, req_id: int, **args) -> None:
        self._async("e", req_id, f"req{req_id}", args)

    # -------------------------------------------------------------- document
    def to_json(self) -> dict:
        return {"traceEvents": list(self.events), "displayTimeUnit": "ms"}

    def dumps(self) -> str:
        return json.dumps(self.to_json(), sort_keys=True)


# ------------------------------------------------------------------ validation
def load_schema() -> dict:
    return json.loads(SCHEMA_PATH.read_text(encoding="utf-8"))


def _check(doc, schema, path: str, errors: List[str]) -> None:
    t = schema.get("type")
    if t:
        ok = {"object": dict, "array": list, "string": str,
              "boolean": bool, "null": type(None)}
        if t == "number":
            good = isinstance(doc, (int, float)) \
                and not isinstance(doc, bool)
        elif t == "integer":
            good = isinstance(doc, int) and not isinstance(doc, bool)
        else:
            good = isinstance(doc, ok[t])
        if not good:
            errors.append(f"{path}: expected {t}, got {type(doc).__name__}")
            return
    if "enum" in schema and doc not in schema["enum"]:
        errors.append(f"{path}: {doc!r} not in {schema['enum']}")
    if "minimum" in schema and isinstance(doc, (int, float)) \
            and not isinstance(doc, bool) and doc < schema["minimum"]:
        errors.append(f"{path}: {doc} < minimum {schema['minimum']}")
    if isinstance(doc, dict):
        for req in schema.get("required", ()):
            if req not in doc:
                errors.append(f"{path}: missing required key {req!r}")
        for key, sub in schema.get("properties", {}).items():
            if key in doc:
                _check(doc[key], sub, f"{path}.{key}", errors)
    if isinstance(doc, list) and "items" in schema:
        for i, item in enumerate(doc):
            _check(item, schema["items"], f"{path}[{i}]", errors)


def validate_trace(doc: dict, schema: Optional[dict] = None) -> List[str]:
    """Validate a trace document against the checked-in schema.

    Returns the list of violations (empty == valid).  Phase-span events
    additionally get a semantic check the schema language cannot express:
    every ``X`` event's duration must be non-negative and its phase name
    drawn from the tick taxonomy.
    """
    from repro.service.telemetry import TICK_PHASES

    schema = load_schema() if schema is None else schema
    errors: List[str] = []
    _check(doc, schema, "$", errors)
    for i, ev in enumerate(doc.get("traceEvents", [])):
        if not isinstance(ev, dict):
            continue
        if ev.get("ph") == "X":
            if ev.get("dur", 0) < 0:
                errors.append(f"$.traceEvents[{i}]: negative dur")
            if ev.get("cat") == "tick" and ev.get("name") not in TICK_PHASES:
                errors.append(
                    f"$.traceEvents[{i}]: unknown tick phase "
                    f"{ev.get('name')!r}")
    return errors
