"""CLI driver for the multi-tenant SA serving engine.

Generates a deterministic heterogeneous request mix (all four registry
objectives, several dims, several cooling schedules and priorities), serves
it through the continuous-batching engine, and reports throughput, slot
occupancy, and — with ``--check`` — every request's champion against its
standalone single-tenant run (placement invariance makes them bit-exact).

Usage::

  PYTHONPATH=src python -m repro.service.serve_sa --requests 32 --slots 8
  PYTHONPATH=src python -m repro.service.serve_sa --requests 8 --slots 4 \
      --chains-per-slot 16 --no-check        # quick smoke
"""
from __future__ import annotations

import argparse

import numpy as np

from repro.service.engine import (EngineConfig, SAServeEngine, run_standalone)
from repro.service.request import SARequest
from repro.service.scheduler import SchedulerConfig

#: The synthetic-load mix: (objective, dim) pairs cycled over, crossed with
#: a few cooling schedules — ≥3 objectives, ≥2 dims/schedules by design.
MIX_PROBLEMS = [
    ("rastrigin", 8), ("ackley", 16), ("schwefel", 8), ("griewank", 32),
    ("rastrigin", 32), ("ackley", 8), ("schwefel", 16), ("griewank", 16),
]
MIX_SCHEDULES = [
    dict(T0=100.0, T_min=0.5, rho=0.85, N=40),
    dict(T0=50.0, T_min=0.2, rho=0.90, N=25),
    dict(T0=200.0, T_min=1.0, rho=0.80, N=60),
]


def make_mix(n_requests: int, chains_per_slot: int, seed: int = 0,
             max_slots_per_req: int = 2) -> list:
    """Deterministic heterogeneous request list for load generation."""
    rng = np.random.default_rng(seed)
    reqs = []
    for i in range(n_requests):
        obj, dim = MIX_PROBLEMS[i % len(MIX_PROBLEMS)]
        sched = MIX_SCHEDULES[i % len(MIX_SCHEDULES)]
        n_slots_i = 1 + int(rng.integers(0, max_slots_per_req))
        reqs.append(SARequest(
            req_id=i, objective=obj, dim=dim,
            n_chains=n_slots_i * chains_per_slot,
            seed=seed * 1000 + i, priority=int(rng.integers(0, 3)),
            **sched))
    return reqs


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--requests", type=int, default=32)
    ap.add_argument("--slots", type=int, default=8)
    ap.add_argument("--chains-per-slot", type=int, default=32)
    ap.add_argument("--variant", default="delta", choices=["delta", "full"])
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--policy", default="priority",
                    choices=["priority", "fifo"])
    ap.add_argument("--max-slots-per-req", type=int, default=2)
    ap.add_argument("--check", dest="check", action="store_true",
                    default=True,
                    help="compare every champion vs a standalone run")
    ap.add_argument("--no-check", dest="check", action="store_false")
    args = ap.parse_args(argv)

    cfg = EngineConfig(
        n_slots=args.slots, chains_per_slot=args.chains_per_slot,
        variant=args.variant,
        scheduler=SchedulerConfig(policy=args.policy))
    engine = SAServeEngine(cfg)
    reqs = make_mix(args.requests, args.chains_per_slot, seed=args.seed,
                    max_slots_per_req=min(args.max_slots_per_req, args.slots))
    for r in reqs:
        engine.submit(r)

    results = engine.run()
    stats = engine.stats()
    print(f"[serve_sa] {stats['completed']}/{args.requests} requests in "
          f"{stats['ticks']} ticks, {stats['wall_s']:.2f}s | "
          f"{stats['requests_per_s']:.2f} req/s, "
          f"{stats['sweeps_per_s']:.1f} sweeps/s, "
          f"{stats['chain_steps_per_s']:.3g} chain-steps/s | "
          f"occupancy {stats['occupancy']:.1%}")

    by_id = {r.req_id: r for r in results}
    n_exact = 0
    for req in reqs:
        res = by_id[req.req_id]
        line = (f"  req{req.req_id:>3} {req.objective:<10} d={req.dim:<3} "
                f"f_best={res.f_best:+.5f} levels={res.levels_run} "
                f"wait={res.start_tick - res.submit_tick}t [{res.finish_reason}]")
        if args.check:
            solo = run_standalone(req, cfg)
            exact = (res.f_best == solo.f_best)
            n_exact += exact
            line += ("  == standalone" if exact
                     else f"  != standalone ({solo.f_best:+.5f})")
        print(line)
    if args.check:
        print(f"[serve_sa] {n_exact}/{len(reqs)} champions bit-exact vs "
              "standalone")
        if n_exact != len(reqs):
            raise SystemExit(1)
    return results


if __name__ == "__main__":
    main()
