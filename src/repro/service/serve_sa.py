"""CLI driver for the multi-tenant SA serving engine.

Generates a deterministic heterogeneous request mix (all four registry
objectives, several dims, several cooling schedules and priorities) and
serves it through the continuous-batching engine — either closed-loop
(the whole queue up front) or open-loop (``--arrivals poisson``: requests
stream in on a seeded Poisson timeline and queueing delay / time-to-first-
tick percentiles are reported).  With ``--check`` every request's champion
is compared against its standalone single-tenant run (placement invariance
makes them bit-exact); with ``--json`` the full per-request lifecycle
(tick-time and wall-time latencies) is emitted as one JSON document.

Usage::

  PYTHONPATH=src python -m repro.service.serve_sa --requests 32 --slots 8
  PYTHONPATH=src python -m repro.service.serve_sa --requests 8 --slots 4 \
      --chains-per-slot 16 --no-check        # quick smoke
  PYTHONPATH=src python -m repro.service.serve_sa --arrivals poisson \
      --rate 0.5 --requests 16 --slots 4 --chains-per-slot 16 --json
  XLA_FLAGS=--xla_force_host_platform_device_count=4 PYTHONPATH=src \
      python -m repro.service.serve_sa --devices 4 --slots 2 \
      --chains-per-slot 16 --arrivals poisson --rate 1.0   # sharded pool
"""
from __future__ import annotations

import argparse
import dataclasses
import json

import numpy as np

from repro.service.arrivals import ArrivalProcess, latency_summary
from repro.service.engine import (EngineConfig, SAServeEngine, run_standalone)
from repro.service.request import SARequest
from repro.service.scheduler import SchedulerConfig

#: The synthetic-load mix: (objective, dim) pairs cycled over, crossed with
#: a few cooling schedules — ≥3 objectives, ≥2 dims/schedules by design.
#: Spans the full registry (including the PR-5 exponential/salomon growth:
#: runtime kid dispatch serves them with zero new compiled programs).
MIX_PROBLEMS = [
    ("rastrigin", 8), ("ackley", 16), ("schwefel", 8), ("griewank", 32),
    ("exponential", 16), ("salomon", 8),
    ("rastrigin", 32), ("ackley", 8), ("schwefel", 16), ("griewank", 16),
]
MIX_SCHEDULES = [
    dict(T0=100.0, T_min=0.5, rho=0.85, N=40),
    dict(T0=50.0, T_min=0.2, rho=0.90, N=25),
    dict(T0=200.0, T_min=1.0, rho=0.80, N=60),
]
#: Permutation-family (QAP) load: built-in instances with their sizes, and
#: cooling schedules scaled to typical swap-move delta magnitudes (tens,
#: not thousands — QAP costs move by O(F*D) per exchange).
MIX_QAP_PROBLEMS = [("grid12", 12), ("syn10", 10)]
MIX_QAP_SCHEDULES = [
    dict(T0=50.0, T_min=0.5, rho=0.90, N=25),
    dict(T0=30.0, T_min=0.3, rho=0.88, N=20),
]

_EPILOG = """\
flag groups:
  load shape      --requests (mix size), --max-slots-per-req (request
                  footprint), --seed (mix generator: objectives, dims,
                  schedules, priorities are all derived from it),
                  --method sa | pt | pa | mixed (workload class of the
                  mix; 'mixed' rotates all three through the same slot
                  pool — see the workload-class section of
                  docs/serving.md),
                  --family continuous | qap | mixed (problem
                  representation of the mix: float32 coordinate states,
                  int32 QAP permutations, or both alternating in one
                  pool — see the problem-family section of
                  docs/serving.md).
  pool shape      --slots (pool size PER SHARD), --chains-per-slot (kernel
                  block size; multiple of 8 on TPU), --variant (delta =
                  O(1) incremental evaluation, full = paper-faithful
                  O(dim)), --devices (engine shards on the 1-D (pool,)
                  mesh: each shard owns --slots slots on its own device
                  and dispatches independent device programs; the
                  scheduler homes each request on the least-loaded shard
                  and rebalances by bit-exact cross-shard migration.  On
                  CPU, XLA_FLAGS=--xla_force_host_platform_device_count=N
                  provides N real host devices; with fewer physical
                  devices, logical shards share them round-robin),
                  --macro-k (temperature levels fused into one device
                  dispatch: K > 1 amortizes the host's per-launch pack /
                  transfer / collect cost over K ladder levels and keeps
                  chain state device-resident between launches via
                  donated double buffers.  Scheduling decisions land on
                  macro-tick boundaries only; the tick clock stays in
                  ladder-level units and every trajectory stays bit-exact
                  at any K — --check passes unchanged).
  admission       --policy priority (aged, default) | fifo.
  overload / SLO  --overload-policy none (default) | reject (drop a
                  request once it queues past --deadline ticks) | degrade
                  (admit with fewer chains when the pool is short, floor =
                  one slot, with the --deadline reject backstop) | preempt
                  (swap out the lowest-effective-priority active jobs —
                  bounded by --preemption-budget per tick — to admit an
                  urgent arrival; swapped jobs resume bit-exactly).
                  Per-request classes can override via SARequest.on_overload.
  arrivals        --arrivals batch (closed-loop, everything at t=0,
                  default) | poisson (open-loop at --rate requests/tick,
                  seeded by --arrival-seed — deterministic timeline) |
                  bursty (groups of --burst requests arrive together at
                  the same mean rate — the overload stressor) | diurnal
                  (sinusoidal intensity around --rate with --period /
                  --amplitude: the autoscaler's day/night envelope).
                  --max-ticks bounds the run either way.
  control plane   --autoscale attaches the closed-loop controller
                  (service/autoscaler.py): it samples backlog, occupancy
                  and completion-deadline headroom every
                  --scale-sample-every ticks and resizes the fleet
                  within [--min-shards, --max-shards] — scale-up before
                  predicted SLO misses (x--scale-headroom safety),
                  scale-down one shard after --scale-window consecutive
                  sub---scale-low-util samples, at most one change per
                  --scale-cooldown ticks.  --finish-deadline-factor F
                  attaches completion SLOs to the mix (finish within
                  F x ladder-length ticks of arrival); the scheduler
                  meets them by ladder truncation, never cutting below
                  --min-levels-frac x ladder.  Truncated runs replay
                  bit-exactly under --check (the truncation schedule is
                  re-applied standalone, like shrink schedules).
  elastic fleet   --drain-at T (drain one shard at tick T: no new
                  placements, jobs checkpoint-evacuate onto survivors,
                  shard retires once empty; --drain-shard picks which,
                  default the highest-index live shard), --resize T:N
                  (repeatable: resize the fleet to N live shards at tick
                  T, composing drain/add), --high/--low-watermark
                  (background rebalancing: move narrow jobs off shards
                  above high onto shards below low, hysteresis built in),
                  --proactive-degrade (+ --shrink-budget): shrink
                  *running* degrade-class jobs down to their min-chains
                  floor when the queue head fits nowhere.  All of these
                  reuse the bit-exact checkpoint/restore, so --check
                  still holds (shrunk jobs are replayed standalone with
                  the same width schedule).
  reporting       --check (default) re-runs every request standalone and
                  exits 1 unless all champions are bit-exact — the
                  placement-invariance oracle; --no-check skips it.
                  --json replaces the human report with one JSON document:
                  config, engine stats, p50/p99 queueing delay +
                  time-to-first-tick + latency (tick clock, deterministic
                  under fixed seeds) and per-request lifecycle records
                  (plus wall-clock latencies for operators).

  observability   --trace out.json (Chrome/Perfetto trace_event timeline:
                  per-phase tick spans per shard + request lifecycle
                  tracks), --events out.jsonl (deterministic scheduler-
                  decision log, byte-identical under fixed seeds),
                  --metrics out.prom (Prometheus text exposition).  Any
                  of the three enables the telemetry bundle: per-phase
                  tick timing with block_until_ready fencing, streaming
                  p50/p90/p99, and a metrics snapshot in --json.  Off by
                  default — zero overhead, and provably bit-exact when
                  on (--check passes either way).  See
                  docs/observability.md.

The tick clock is the engine's native time axis, measured in ladder
levels: one macro-tick advances it by --macro-k (one level per active
slot per unit).  Latency percentiles are therefore comparable across K.
See docs/serving.md.
"""


def make_mix(n_requests: int, chains_per_slot: int, seed: int = 0,
             max_slots_per_req: int = 2, method: str = "sa",
             family: str = "continuous",
             finish_deadline_factor: float = None,
             min_levels_frac: float = 0.5) -> list:
    """Deterministic heterogeneous request list for load generation.

    ``method`` picks the workload class for every request ('sa', 'pt',
    'pa') or 'mixed' for a deterministic sa/pt/pa rotation — the
    co-batching stressor: all three classes share slots, device programs
    and the bit-exactness oracle.  PA requests get an ESS-driven width
    schedule (pa_ess_ratio=0.5) so the self-shrink path is exercised.

    ``family`` picks the problem representation: 'continuous' (the six
    registry objectives, float32 coordinate states), 'qap' (built-in QAP
    instances, int32 permutation states; permutations are SA-only, so
    ``method`` must be 'sa'), or 'mixed' — alternating continuous/QAP
    requests co-resident in one slot pool, the cross-representation
    stressor.  QAP entries in a mixed load always run plain SA; the
    continuous entries still follow ``method``.

    ``finish_deadline_factor`` (when set) attaches a completion SLO to
    every request: ``finish_deadline = factor x n_levels`` ticks of
    end-to-end budget, with ``min_levels = max(1, min_levels_frac x
    n_levels)`` as the ladder-truncation floor — factor > 1 leaves slack
    for queueing; the scheduler truncates the ladder (never below the
    floor) when the slack runs out.
    """
    rng = np.random.default_rng(seed)
    reqs = []
    for i in range(n_requests):
        is_qap = family == "qap" or (family == "mixed" and i % 2 == 1)
        n_slots_i = 1 + int(rng.integers(0, max_slots_per_req))
        if is_qap:
            obj, dim = MIX_QAP_PROBLEMS[(i // 2) % len(MIX_QAP_PROBLEMS)] \
                if family == "mixed" else \
                MIX_QAP_PROBLEMS[i % len(MIX_QAP_PROBLEMS)]
            sched = MIX_QAP_SCHEDULES[i % len(MIX_QAP_SCHEDULES)]
            m, ess, fam = "sa", 0.0, "permutation"
        else:
            obj, dim = MIX_PROBLEMS[i % len(MIX_PROBLEMS)]
            sched = MIX_SCHEDULES[i % len(MIX_SCHEDULES)]
            m = ("sa", "pt", "pa")[i % 3] if method == "mixed" else method
            ess, fam = 0.5 if m == "pa" else 0.0, "continuous"
        req = SARequest(
            req_id=i, objective=obj, dim=dim,
            n_chains=n_slots_i * chains_per_slot,
            seed=seed * 1000 + i, priority=int(rng.integers(0, 3)),
            method=m, pa_ess_ratio=ess, family=fam,
            **sched)
        if finish_deadline_factor is not None:
            req = dataclasses.replace(
                req,
                finish_deadline=finish_deadline_factor * req.n_levels,
                min_levels=max(1, int(min_levels_frac * req.n_levels)))
        reqs.append(req)
    return reqs


def make_arrivals(reqs, kind: str, rate: float, seed: int,
                  burst: int = 4, period: float = 200.0,
                  amplitude: float = 0.8) -> ArrivalProcess:
    if kind == "poisson":
        return ArrivalProcess.poisson(reqs, rate=rate, seed=seed)
    if kind == "bursty":
        return ArrivalProcess.bursty(reqs, rate=rate, burst=burst, seed=seed)
    if kind == "diurnal":
        return ArrivalProcess.diurnal(reqs, rate=rate, period=period,
                                      amplitude=amplitude, seed=seed)
    return ArrivalProcess.batch(reqs)


def _jsonable(obj):
    """Map non-finite floats to None so --json is strict RFC 8259 JSON
    (bare NaN tokens break jq / JSON.parse / Go decoders)."""
    if isinstance(obj, dict):
        return {k: _jsonable(v) for k, v in obj.items()}
    if isinstance(obj, (list, tuple)):
        return [_jsonable(v) for v in obj]
    if isinstance(obj, float) and not np.isfinite(obj):
        return None
    return obj


def main(argv=None):
    ap = argparse.ArgumentParser(
        description=__doc__.splitlines()[0], epilog=_EPILOG,
        formatter_class=argparse.RawDescriptionHelpFormatter)
    ap.add_argument("--requests", type=int, default=32,
                    help="number of requests in the synthetic mix")
    ap.add_argument("--slots", type=int, default=8,
                    help="slot-pool size per shard (concurrent chain blocks)")
    ap.add_argument("--chains-per-slot", type=int, default=32,
                    help="chains per slot == kernel block size")
    ap.add_argument("--devices", type=int, default=1,
                    help="engine shards on the (pool,) device mesh; each "
                         "owns --slots slots (CPU-testable via XLA_FLAGS="
                         "--xla_force_host_platform_device_count)")
    ap.add_argument("--macro-k", type=int, default=1,
                    help="temperature levels fused per device dispatch "
                         "(macro-tick size; 1 = classic per-level launch). "
                         "Bit-exact at any value")
    ap.add_argument("--migration-budget", type=int, default=1,
                    help="max cross-shard moves per tick — drain "
                         "evacuation, head defrag and watermark "
                         "rebalancing share it (0 disables all three)")
    ap.add_argument("--drain-at", type=int, default=None,
                    help="tick at which to drain one shard (evacuate and "
                         "retire it mid-stream)")
    ap.add_argument("--drain-shard", type=int, default=None,
                    help="shard index for --drain-at (default: the "
                         "highest-index live shard at that tick)")
    ap.add_argument("--resize", action="append", default=None,
                    metavar="TICK:N",
                    help="resize the fleet to N live shards at TICK "
                         "(repeatable; composes drain/add)")
    ap.add_argument("--high-watermark", type=float, default=1.0,
                    help="shard utilization above which the background "
                         "rebalancer moves work off (1.0 disables)")
    ap.add_argument("--low-watermark", type=float, default=0.0,
                    help="shard utilization below which a shard may "
                         "receive rebalanced work (0.0 disables)")
    ap.add_argument("--proactive-degrade", action="store_true",
                    help="shrink running degrade-class jobs (down to "
                         "min_chains) when the queue head fits nowhere")
    ap.add_argument("--shrink-budget", type=int, default=1,
                    help="max proactive shrinks per tick")
    ap.add_argument("--autoscale", action="store_true",
                    help="attach the closed-loop autoscaler: sample "
                         "backlog/occupancy/deadline headroom every "
                         "--scale-sample-every ticks, resize the fleet "
                         "between --min-shards and --max-shards (scale up "
                         "before predicted completion-SLO misses, drain "
                         "the emptiest shard after --scale-window low-"
                         "utilization samples).  Decisions are tick-"
                         "aligned and deterministic; --check still holds")
    ap.add_argument("--min-shards", type=int, default=1,
                    help="autoscaler fleet floor")
    ap.add_argument("--max-shards", type=int, default=4,
                    help="autoscaler fleet ceiling")
    ap.add_argument("--scale-sample-every", type=int, default=8,
                    help="ticks between autoscaler control samples")
    ap.add_argument("--scale-headroom", type=float, default=1.25,
                    help="demand safety multiplier on scale-up")
    ap.add_argument("--scale-low-util", type=float, default=0.35,
                    help="utilization low watermark for scale-down")
    ap.add_argument("--scale-window", type=int, default=3,
                    help="consecutive low-utilization samples before a "
                         "scale-down (hysteresis)")
    ap.add_argument("--scale-cooldown", type=int, default=32,
                    help="min ticks between fleet-size changes")
    ap.add_argument("--finish-deadline-factor", type=float, default=None,
                    metavar="F",
                    help="attach a completion SLO to every mix request: "
                         "finish_deadline = F x its ladder length "
                         "(min_levels = --min-levels-frac x ladder; the "
                         "scheduler truncates the ladder, never below the "
                         "floor, to meet it)")
    ap.add_argument("--min-levels-frac", type=float, default=0.5,
                    help="ladder-truncation floor as a fraction of each "
                         "request's ladder length")
    ap.add_argument("--method", default="sa",
                    choices=["sa", "pt", "pa", "mixed"],
                    help="workload class for the synthetic mix: plain SA, "
                         "parallel tempering (chains hold rungs of the "
                         "request's temperature ladder with even/odd "
                         "replica swaps each level), population annealing "
                         "(per-level Boltzmann resampling, ESS-driven "
                         "width), or a deterministic sa/pt/pa rotation "
                         "co-batched in the same slot pool")
    ap.add_argument("--family", default="continuous",
                    choices=["continuous", "qap", "mixed"],
                    help="problem family of the synthetic mix: continuous "
                         "(float32 coordinate states, the six registry "
                         "objectives), qap (int32 permutation states over "
                         "the built-in QAP instances; SA-only, so --method "
                         "must stay sa), or mixed — alternating continuous "
                         "and QAP requests co-batched in one slot pool "
                         "(QAP entries always run plain SA)")
    ap.add_argument("--variant", default="delta", choices=["delta", "full"],
                    help="objective evaluation: O(1) delta or O(dim) full "
                         "(continuous family only; QAP always uses the "
                         "delta-evaluated swap sweep)")
    ap.add_argument("--seed", type=int, default=0,
                    help="request-mix generator seed")
    ap.add_argument("--policy", default="priority",
                    choices=["priority", "fifo"],
                    help="admission policy (priority is aged)")
    ap.add_argument("--max-slots-per-req", type=int, default=2,
                    help="largest request footprint in the mix, in slots")
    ap.add_argument("--overload-policy", default="none",
                    choices=["none", "reject", "degrade", "preempt"],
                    help="scheduler-wide overload policy (SLO admission "
                         "control); per-request on_overload overrides it")
    ap.add_argument("--deadline", type=float, default=None,
                    help="queueing-delay SLO in ticks for reject/degrade "
                         "(default: none — requests queue forever)")
    ap.add_argument("--preemption-budget", type=int, default=1,
                    help="max preemptions (swap-outs) per tick")
    ap.add_argument("--arrivals", default="batch",
                    choices=["batch", "poisson", "bursty", "diurnal"],
                    help="closed-loop batch, open-loop Poisson stream, "
                         "bursty overload stream, or a diurnal stream "
                         "(sinusoidal intensity around --rate: the "
                         "autoscaler's day/night envelope)")
    ap.add_argument("--rate", type=float, default=0.5,
                    help="offered load for open-loop arrivals, requests/tick")
    ap.add_argument("--burst", type=int, default=4,
                    help="burst size for --arrivals bursty")
    ap.add_argument("--period", type=float, default=200.0,
                    help="diurnal cycle length in ticks")
    ap.add_argument("--amplitude", type=float, default=0.8,
                    help="diurnal intensity swing in [0, 1] (peak = "
                         "(1+a) x rate, trough = (1-a) x rate)")
    ap.add_argument("--arrival-seed", type=int, default=0,
                    help="seed for the arrival timeline")
    ap.add_argument("--max-ticks", type=int, default=None,
                    help="hard tick budget (default: run to drain)")
    ap.add_argument("--json", dest="as_json", action="store_true",
                    help="emit one JSON document instead of the text report "
                         "(includes a metrics snapshot when telemetry is on)")
    ap.add_argument("--trace", default=None, metavar="OUT.json",
                    help="write a Chrome/Perfetto trace_event JSON of the "
                         "run (per-phase tick spans + request lifecycles); "
                         "enables telemetry")
    ap.add_argument("--events", default=None, metavar="OUT.jsonl",
                    help="write the deterministic scheduler-decision log "
                         "(one JSON record per line); enables telemetry")
    ap.add_argument("--metrics", default=None, metavar="OUT.prom",
                    help="write a Prometheus text exposition of the "
                         "metrics registry; enables telemetry")
    ap.add_argument("--check", dest="check", action="store_true",
                    default=True,
                    help="compare every champion vs a standalone run")
    ap.add_argument("--no-check", dest="check", action="store_false")
    args = ap.parse_args(argv)
    if args.family == "qap" and args.method != "sa":
        # Permutations have no temperature-rung replica layout: the
        # request validator rejects pt/pa on the permutation family, so
        # fail fast here with the flag-level explanation.
        ap.error("--family qap serves plain SA only (permutation requests "
                 "have no pt/pa replica layout); drop --method " +
                 args.method)
    if args.overload_policy in ("reject", "degrade") and args.deadline is None:
        # Without a deadline the expiry check can never fire, silently
        # degenerating to --overload-policy none.
        ap.error(f"--overload-policy {args.overload_policy} requires "
                 "--deadline (the queueing-delay SLO it enforces)")
    if args.drain_at is not None and args.devices < 2:
        ap.error("--drain-at needs --devices >= 2 (the survivors absorb "
                 "the drained shard's work)")
    if args.autoscale and not (args.min_shards <= args.devices
                               <= args.max_shards):
        ap.error(f"--autoscale needs --min-shards <= --devices <= "
                 f"--max-shards; got {args.min_shards} <= {args.devices} "
                 f"<= {args.max_shards}")
    resizes = []
    for spec in args.resize or []:
        try:
            t_str, n_str = spec.split(":")
            resizes.append((int(t_str), int(n_str)))
        except ValueError:
            ap.error(f"--resize expects TICK:N, got {spec!r}")
        if resizes[-1][1] < 1:
            ap.error(f"--resize target must be >= 1 shard, got {spec!r}")

    cfg = EngineConfig(
        n_slots=args.slots, chains_per_slot=args.chains_per_slot,
        n_devices=args.devices, variant=args.variant,
        macro_k=args.macro_k,
        migration_budget=args.migration_budget,
        scheduler=SchedulerConfig(policy=args.policy,
                                  overload=args.overload_policy,
                                  default_deadline=args.deadline,
                                  preemption_budget=args.preemption_budget,
                                  high_watermark=args.high_watermark,
                                  low_watermark=args.low_watermark,
                                  proactive_degrade=args.proactive_degrade,
                                  shrink_budget=args.shrink_budget))
    telemetry = None
    if args.trace or args.events or args.metrics:
        from repro.service.telemetry import EventLog, Telemetry
        from repro.service.trace import TraceBuilder
        telemetry = Telemetry(
            trace=TraceBuilder() if args.trace else None,
            events=EventLog() if args.events else None)
    engine = SAServeEngine(cfg, telemetry=telemetry)
    controller = None
    if args.autoscale:
        from repro.service.autoscaler import Autoscaler, AutoscalerConfig
        controller = Autoscaler(AutoscalerConfig(
            min_shards=args.min_shards, max_shards=args.max_shards,
            sample_every=args.scale_sample_every,
            headroom=args.scale_headroom, low_util=args.scale_low_util,
            window=args.scale_window, cooldown=args.scale_cooldown))
        engine.attach_controller(controller)
    # Scripted fleet changes land on the deterministic tick axis.
    for t, n in sorted(resizes):
        engine.schedule_op(t, lambda n=n: engine.resize(n))
    if args.drain_at is not None:
        def _drain():
            target = args.drain_shard if args.drain_shard is not None \
                else max(s.index for s in engine.live_shards)
            engine.drain(target)
        engine.schedule_op(args.drain_at, _drain)
    reqs = make_mix(args.requests, args.chains_per_slot, seed=args.seed,
                    max_slots_per_req=min(args.max_slots_per_req, args.slots),
                    method=args.method, family=args.family,
                    finish_deadline_factor=args.finish_deadline_factor,
                    min_levels_frac=args.min_levels_frac)
    arrivals = make_arrivals(reqs, args.arrivals, args.rate,
                             args.arrival_seed, burst=args.burst,
                             period=args.period, amplitude=args.amplitude)

    results = engine.run_stream(arrivals, max_ticks=args.max_ticks)
    stats = engine.stats()
    lat = latency_summary(results, ticks=engine.tick_count,
                          n_submitted=engine.n_submitted)

    if args.trace:
        with open(args.trace, "w", encoding="utf-8") as fh:
            fh.write(telemetry.trace.dumps())
        if not args.as_json:
            print(f"[serve_sa] trace: {len(telemetry.trace.events)} events "
                  f"-> {args.trace} (open at https://ui.perfetto.dev)")
    if args.events:
        with open(args.events, "w", encoding="utf-8") as fh:
            fh.write(telemetry.events.dumps())
        if not args.as_json:
            print(f"[serve_sa] events: {len(telemetry.events.records)} "
                  f"decision records -> {args.events}")
    if args.metrics:
        with open(args.metrics, "w", encoding="utf-8") as fh:
            fh.write(telemetry.registry.exposition())
        if not args.as_json:
            print(f"[serve_sa] metrics -> {args.metrics}")

    by_id = {r.req_id: r for r in results}
    # Requests with a terminal result, split by status; rejected requests
    # carry no solution to compare.
    served = [req for req in reqs
              if req.req_id in by_id and by_id[req.req_id].completed]
    rejected_ids = sorted(r.req_id for r in results if not r.completed)
    unserved = [req.req_id for req in reqs if req.req_id not in by_id]
    n_exact = 0
    mismatched = {}             # req_id -> report line
    if args.check:
        for req in served:
            res = by_id[req.req_id]
            # A degraded admission is bit-exact vs a standalone run at the
            # *admitted* chain count (same logical chain indices and RNG);
            # a job shrunk mid-flight (drain / proactive degrade) is
            # bit-exact vs a standalone run that replays the same width
            # schedule on the level axis, and a ladder-truncated job vs
            # one that replays the same truncation schedule (cuts move
            # only the ladder's end, so champions are prefix-exact).
            solo_req = req if res.admitted_chains >= req.n_chains else \
                dataclasses.replace(req, n_chains=res.admitted_chains)
            sched = [(lvl, to) for lvl, _frm, to in res.shrink_events]
            cuts = [(lvl, to) for lvl, _frm, to in res.truncate_events]
            solo = run_standalone(solo_req, cfg, shrink_schedule=sched,
                                  truncate_schedule=cuts)
            if res.f_best == solo.f_best:
                n_exact += 1
            else:
                mismatched[req.req_id] = (
                    f"req{req.req_id}: packed {res.f_best:+.5f}"
                    f" != standalone {solo.f_best:+.5f}")
    # The check must not pass vacuously: a truncated run (--max-ticks) that
    # served nothing is a coverage failure, not a success.  Rejection is a
    # terminal status, not a coverage hole.
    check_failed = args.check and (n_exact != len(served) or unserved)

    if args.as_json:
        doc = {
            "config": {
                "requests": args.requests, "slots": args.slots,
                "chains_per_slot": args.chains_per_slot,
                "devices": args.devices, "macro_k": args.macro_k,
                "migration_budget": args.migration_budget,
                "drain_at": args.drain_at, "drain_shard": args.drain_shard,
                "resize": sorted(resizes),
                "high_watermark": args.high_watermark,
                "low_watermark": args.low_watermark,
                "proactive_degrade": args.proactive_degrade,
                "shrink_budget": args.shrink_budget,
                "method": args.method, "family": args.family,
                "variant": args.variant, "policy": args.policy,
                "overload_policy": args.overload_policy,
                "deadline": args.deadline,
                "preemption_budget": args.preemption_budget,
                "seed": args.seed, "arrivals": args.arrivals,
                "rate": args.rate, "burst": args.burst,
                "period": args.period, "amplitude": args.amplitude,
                "arrival_seed": args.arrival_seed,
                "autoscale": args.autoscale,
                "min_shards": args.min_shards,
                "max_shards": args.max_shards,
                "finish_deadline_factor": args.finish_deadline_factor,
                "min_levels_frac": args.min_levels_frac,
            },
            "stats": stats,
            "latency": lat,
            "results": [r.to_dict()
                        for r in sorted(results, key=lambda r: r.req_id)],
        }
        if controller is not None:
            doc["autoscaler"] = {
                "samples": controller.samples,
                "decisions": [list(d) for d in controller.decisions],
            }
        if telemetry is not None:
            doc["metrics"] = telemetry.registry.snapshot()
        if args.check:
            doc["check"] = {"bit_exact": n_exact, "served": len(served),
                            "rejected_req_ids": rejected_ids,
                            "unserved_req_ids": unserved,
                            "mismatches": sorted(mismatched.values())}
        print(json.dumps(_jsonable(doc), indent=2, sort_keys=True,
                         allow_nan=False))
    else:
        print(f"[serve_sa] {stats['completed']}/{args.requests} requests in "
              f"{stats['ticks']} ticks, {stats['wall_s']:.2f}s | "
              f"{stats['requests_per_s']:.2f} req/s, "
              f"{stats['sweeps_per_s']:.1f} sweeps/s, "
              f"{stats['chain_steps_per_s']:.3g} chain-steps/s | "
              f"occupancy {stats['occupancy']:.1%}")
        if args.devices > 1 or stats["shards_retired"]:
            shard_util = " ".join(f"{u:.0%}" for u in
                                  stats["shard_occupancy"])
            print(f"[serve_sa] {stats['devices']} shards x {args.slots} "
                  f"slots (started with {args.devices}): per-shard "
                  f"utilization [{shard_util}], "
                  f"{stats['migrations']} migrations")
        if stats["shards_retired"] or stats["draining"] or stats["shrinks"]:
            retired = ", ".join(f"shard {i} at tick {t}"
                                for i, t in engine.retired_shards)
            print(f"[serve_sa] elastic fleet: {stats['shards_retired']} "
                  f"retired ({retired or 'none'}), {stats['draining']} "
                  f"still draining, {stats['shrinks']} proactive shrinks")
        if controller is not None:
            moves = " ".join(f"t{t}:{kind[0]}{a}->{b}"
                             for t, kind, a, b in controller.decisions)
            print(f"[serve_sa] autoscaler: {controller.samples} samples, "
                  f"{len(controller.decisions)} fleet changes "
                  f"[{moves or 'none'}]")
        if stats["truncations"]:
            print(f"[serve_sa] completion SLO: {stats['truncations']} "
                  f"ladder truncations across "
                  f"{sum(1 for r in results if r.truncated)} requests")
        if lat["incomplete"]:
            print(f"[serve_sa] {lat['incomplete']} requests still in flight "
                  f"or queued at the --max-ticks horizon (not rejected)")
        if args.arrivals != "batch":
            print(f"[serve_sa] open loop @ {args.rate} req/tick: "
                  f"queue delay p50/p99 = {lat['queue_delay_p50']:.1f}/"
                  f"{lat['queue_delay_p99']:.1f} ticks, "
                  f"ttft p50/p99 = {lat['ttft_p50']:.1f}/"
                  f"{lat['ttft_p99']:.1f} ticks, "
                  f"goodput {lat['goodput_req_per_tick']:.3f} req/tick")
        if args.overload_policy != "none" or stats["rejected"] \
                or stats["preemptions"]:
            print(f"[serve_sa] overload policy '{args.overload_policy}': "
                  f"{stats['rejected']} rejected, "
                  f"{stats['preemptions']} preemptions")
        for req in served:
            res = by_id[req.req_id]
            line = (f"  req{req.req_id:>3} {req.objective:<10} d={req.dim:<3} "
                    f"f_best={res.f_best:+.5f} levels={res.levels_run} "
                    f"wait={res.queue_delay_ticks:.1f}t "
                    f"[{res.finish_reason}]")
            if res.n_preemptions:
                line += f" preempted x{res.n_preemptions}"
            if res.n_migrations:
                line += f" migrated x{res.n_migrations}"
            if res.n_shrinks:
                line += (f" shrunk x{res.n_shrinks} "
                         f"({res.admitted_chains}->{res.granted_chains} "
                         "chains)")
            if res.truncated:
                line += (f" truncated x{res.n_truncations} "
                         f"({res.truncate_events[0][1]}->"
                         f"{res.truncate_events[-1][2]} levels)")
            elif res.degraded:
                line += (f" degraded {res.granted_chains}/"
                         f"{res.requested_chains} chains")
            if args.check:
                line += ("  != standalone" if req.req_id in mismatched
                         else "  == standalone")
            print(line)
        for rid in rejected_ids:
            res = by_id[rid]
            print(f"  req{rid:>3} {res.objective:<10} d={res.dim:<3} "
                  f"REJECTED at tick {res.finish_tick} "
                  f"(queued {res.finish_tick - res.submit_tick}t)")
        if args.check:
            tail = f" ({len(unserved)} never served)" if unserved else ""
            print(f"[serve_sa] {n_exact}/{len(served)} champions bit-exact "
                  f"vs standalone{tail}")
            for rid in sorted(mismatched):
                print("  " + mismatched[rid])

    if check_failed:
        raise SystemExit(1)
    return results


if __name__ == "__main__":
    main()
