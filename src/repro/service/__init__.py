"""Multi-tenant SA serving engine: continuous batching for annealing jobs.

The paper's synchronous SA (V2) is a single-job batch program.  This
subsystem turns it into a *serving* system in the vLLM/LightLLM mold: a
fixed pool of chain-block slots, an admission scheduler that packs a queue
of heterogeneous optimization requests into free slots, one engine tick =
one temperature level for every active slot, and immediate slot refill when
a request's ladder (or budget, or accuracy target) completes.

Layers
------
``request.py``   : :class:`SARequest` / :class:`RequestResult` schema,
                   SLO fields (deadline, min-chains, overload class),
                   lifecycle timestamps + derived latencies.
``slots.py``     : the slot pool — per-slot chain state + ownership —
                   and :class:`SwappedJob` preemption checkpoints.
``sharding.py``  : the sharded pool — one :class:`EngineShard` (private
                   slot pool + rid table) per device on the 1-D
                   ``(pool,)`` mesh.
``scheduler.py`` : priority-with-aging admission, bounded backfill,
                   the reject/degrade/preempt overload policies, and the
                   placement layer (home-shard choice, Russkov-style
                   cross-shard migration planning, drain evacuation,
                   watermark rebalancing, proactive-degrade shrinks).
``arrivals.py``  : open-loop arrival processes (seeded Poisson / bursty /
                   diurnal / trace / batch) + latency percentile summaries.
``autoscaler.py``: closed-loop fleet controller — samples backlog /
                   occupancy / completion headroom on a tick cadence,
                   grows ahead of predicted deadline misses, drains after
                   sustained idleness (hysteresis + cooldown).
``engine.py``    : the continuous-batching tick loop; per-slot objective id
                   (runtime — no recompile per objective), temperature,
                   seed and step cursor threaded to the Pallas kernel,
                   champion exchange masked per request (tenant isolation).
``serve_sa.py``  : CLI driver + synthetic heterogeneous load, closed- or
                   open-loop (``--arrivals poisson --rate ...``).
``telemetry.py`` : opt-in observability bundle — metrics registry
                   (counters/gauges/streaming histograms, Prometheus
                   text + JSON export), per-phase tick timers, the
                   deterministic decision event log, and the jax
                   compile-event counter.  Off by default: zero overhead,
                   bit-exact when on (docs/observability.md).
``trace.py``     : Chrome/Perfetto ``trace_event`` builder + checked-in
                   schema validation (``serve_sa --trace out.json``).

Usage::

    from repro.service import EngineConfig, SARequest, SAServeEngine

    engine = SAServeEngine(EngineConfig(n_slots=8, chains_per_slot=32))
    engine.submit(SARequest(req_id=0, objective="rastrigin", dim=8,
                            n_chains=64, T0=100.0, T_min=0.5, rho=0.9, N=40))
    engine.submit(SARequest(req_id=1, objective="ackley", dim=16,
                            n_chains=32, T0=50.0, T_min=0.2, rho=0.95, N=25))
    results = engine.run()          # both jobs co-annealed on one program
    print(engine.stats())           # req/s, sweeps/s, slot occupancy

Or from the shell::

    PYTHONPATH=src python -m repro.service.serve_sa --requests 32 --slots 8
"""
from repro.service.arrivals import ArrivalProcess, latency_summary
from repro.service.autoscaler import Autoscaler, AutoscalerConfig
from repro.service.engine import (EngineConfig, SAServeEngine, F_OPT,
                                  run_standalone)
from repro.service.request import (OVERLOAD_POLICIES, RequestResult,
                                   SARequest, SERVABLE, TERMINAL_REASONS)
from repro.service.scheduler import (AdmissionPlan, AdmissionScheduler,
                                     QueueEntry, SchedulerConfig, ShardView)
from repro.service.sharding import EngineShard, slot_pool_devices
from repro.service.slots import ActiveJob, SlotPool, SwappedJob
from repro.service.telemetry import (EventLog, MetricsRegistry, PhaseTimer,
                                     Telemetry, TICK_PHASES, compile_events)
from repro.service.trace import TraceBuilder, validate_trace

__all__ = [
    "EngineConfig", "SAServeEngine", "run_standalone", "F_OPT",
    "SARequest", "RequestResult", "SERVABLE", "OVERLOAD_POLICIES",
    "TERMINAL_REASONS",
    "AdmissionScheduler", "AdmissionPlan", "QueueEntry", "SchedulerConfig",
    "ShardView",
    "SlotPool", "ActiveJob", "SwappedJob",
    "EngineShard", "slot_pool_devices",
    "ArrivalProcess", "latency_summary",
    "Autoscaler", "AutoscalerConfig",
    "Telemetry", "MetricsRegistry", "PhaseTimer", "EventLog",
    "TICK_PHASES", "compile_events", "TraceBuilder", "validate_trace",
]
