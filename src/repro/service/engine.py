"""Continuous-batching SA serving engine.

The annealing analogue of a vLLM/LightLLM decode loop (launch/serve.py):

* a fixed pool of chain-block *slots* (slots.py) — the "decode batch";
* an admission scheduler (scheduler.py) packs queued requests into free
  slots — "prefill";
* one engine **tick** advances every active slot by one temperature level
  (one N-step Metropolis sweep at that slot's own temperature, then a
  champion exchange masked per request);
* a request whose ladder / budget / accuracy target completes frees its
  slots *immediately* and the next queued request takes them — no tail
  latency from stragglers sharing the batch.

Invariants
----------
* **One tick = one temperature level** for every active slot; a request's
  temperature ladder position is exactly its count of ticks in residence.
* **kid is runtime**: per-slot *objective id, temperature, RNG seed, step
  cursor and chain base* are runtime arrays threaded down to the kernel
  (one SMEM entry per block, indexed by ``program_id``) — none of them can
  cause recompilation.  Only *dimensionality and sweep length* remain
  compile-time constants, so active slots are grouped by ``(dim, N)`` each
  tick and dispatched as one device program per group: one compiled sweep
  program serves every registry objective, and growing ``SERVABLE`` never
  costs a recompile.  (Groups are additionally padded to power-of-two
  block counts to bound the number of compiled shapes.)
* **Tenant isolation**: champion reduces inside a packed group are
  segmented by request id — tenants never exchange states
  (core/exchange.py) — and placement-invariant RNG makes a request's
  trajectory bit-identical to its standalone single-tenant run.
* **Open-loop serving**: :meth:`SAServeEngine.run_stream` interleaves
  admission of an :class:`~repro.service.arrivals.ArrivalProcess` (e.g.
  seeded Poisson) with in-flight progress, stamping per-request lifecycle
  events (submit / admit / first-tick / preempted / resumed /
  complete-or-rejected, in both tick-time and wall-time) from which
  queueing-delay and time-to-first-tick percentiles are derived (see
  docs/serving.md).
* **Preemption is bit-exact**: an active job checkpoints to a host-side
  :class:`~repro.service.slots.SwappedJob` (slot blocks + champion + RNG
  step cursor + temperature cursor) and resumes — possibly on different
  physical slots — with a trajectory identical to an uninterrupted run,
  because the RNG is counter-based on logical (chain index, step)
  coordinates.  SLO admission control (scheduler.py) builds on it: the
  'preempt' overload policy evicts the cheapest active jobs for an urgent
  arrival, 'reject' and 'degrade' bound queue growth at overload.
"""
from __future__ import annotations

import dataclasses
import math
import time
from collections import defaultdict
from functools import partial
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import exchange as exch
from repro.kernels import objective_math as om
from repro.kernels import ops
from repro.service.request import RequestResult, SARequest
from repro.service.scheduler import (AdmissionScheduler, QueueEntry,
                                     SchedulerConfig)
from repro.service.slots import ActiveJob, RidTable, SlotPool, SwappedJob

#: Known optima of the servable (registry) objectives, for accuracy targets.
#: Schwefel is the paper's normalized form, so its optimum is dim-free.
F_OPT = {
    om.KID_SCHWEFEL: -418.982887,
    om.KID_RASTRIGIN: 0.0,
    om.KID_ACKLEY: 0.0,
    om.KID_GRIEWANK: 0.0,
}


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    n_slots: int = 8
    chains_per_slot: int = 64   # chains per slot == kernel block size
    variant: str = "delta"      # 'delta' (O(1) updates) | 'full' (paper)
    use_pallas: object = "auto"  # True | False | 'auto' (TPU only)
    interpret: bool = False     # Pallas interpret mode (tests on CPU)
    scheduler: SchedulerConfig = dataclasses.field(
        default_factory=SchedulerConfig)


@partial(jax.jit, static_argnames=("n_steps", "blk", "variant",
                                   "use_pallas", "interpret", "num_segments"))
def _group_tick(x, kid_blk, T_blk, seed_blk, step0_blk, base_blk, seg, adopt,
                *, n_steps: int, blk: int, variant: str,
                use_pallas: bool, interpret: bool, num_segments: int):
    """One temperature level for one dispatch group, on device.

    Sweep every block on its own objective (``kid_blk`` is a runtime
    input — mixed-objective groups share one lowering) at its own
    temperature, then a segmented champion reduce: chains adopt *their
    request's* champion iff their request runs sync exchange (``adopt``);
    the champion is returned for every segment either way so the host can
    fold best-so-far.
    """
    x, fx = ops.metropolis_sweep_slots(
        x, kid_blk, T_blk, seed_blk, step0_blk, base_blk, n_steps=n_steps,
        blk=blk, variant=variant, use_pallas=use_pallas, interpret=interpret)
    return exch.exchange_sync_segmented(x, fx, seg, num_segments,
                                        adopt_mask=adopt)


class SAServeEngine:
    """Multi-tenant annealing server over one device program per group."""

    def __init__(self, cfg: Optional[EngineConfig] = None):
        # Build a fresh default per engine: a mutable-default-argument
        # EngineConfig() would be evaluated once and shared by every engine
        # constructed without a config (tests pin this down).
        cfg = EngineConfig() if cfg is None else cfg
        self.cfg = cfg
        self.pool = SlotPool(cfg.n_slots, cfg.chains_per_slot)
        self.scheduler = AdmissionScheduler(cfg.scheduler)
        self.rids = RidTable(cfg.n_slots)
        self.results: List[RequestResult] = []
        self.tick_count = 0
        self.sweeps_done = 0          # block-sweeps (slot x level): also the
                                      # occupancy numerator (active slot-ticks)
        self.group_launches = 0
        self.preemptions = 0          # swap-outs performed
        self.rejections = 0           # SLO admission-control drops
        self._use_pallas = ops.resolve_use_pallas(cfg.use_pallas)
        if self._use_pallas and cfg.chains_per_slot % 8:
            raise ValueError(
                f"chains_per_slot={cfg.chains_per_slot} must be a multiple "
                "of 8 (TPU sublanes) on the Pallas path")
        self._epoch = time.perf_counter()
        #: req_id -> (arrival_time in ticks, submit wall time): lifecycle
        #: info that must survive the queue (the scheduler only keeps the
        #: submit tick).
        self._submit_info: Dict[int, Tuple[float, float]] = {}

    def _now(self) -> float:
        """Wall seconds since engine construction (the engine epoch)."""
        return time.perf_counter() - self._epoch

    # ------------------------------------------------------------ frontend
    def submit(self, req: SARequest, arrival_time: Optional[float] = None
               ) -> None:
        """Enqueue ``req``.  ``arrival_time`` (in ticks, may be fractional)
        is the offered-load timestamp for open-loop runs; it defaults to
        the submit tick (closed-loop batch submission)."""
        need = req.slots_needed(self.cfg.chains_per_slot)
        if need > self.cfg.n_slots:
            raise ValueError(
                f"request {req.req_id} needs {need} slots > pool "
                f"{self.cfg.n_slots}; lower n_chains or grow the pool")
        if (req.req_id in self._submit_info
                or any(j.req.req_id == req.req_id
                       for j in self.rids.jobs.values())
                or any(r.req_id == req.req_id
                       for r in self.scheduler.pending)):
            raise ValueError(
                f"request id {req.req_id} is already queued, swapped out or "
                "in flight; req_ids must be unique among live requests")
        self._submit_info[req.req_id] = (
            float(self.tick_count if arrival_time is None else arrival_time),
            self._now())
        self.scheduler.submit(req, self.tick_count)

    @property
    def n_active(self) -> int:
        return len(self.rids.jobs)

    @property
    def done(self) -> bool:
        return self.n_active == 0 and len(self.scheduler) == 0

    # ----------------------------------------------------------- admission
    def _admit(self) -> None:
        plan = self.scheduler.admit(
            self.pool.n_free, self.cfg.chains_per_slot, self.tick_count,
            active=list(self.rids.jobs.values()))
        # Execution order matters: rejections first (they free nothing but
        # must be stamped this tick), then evictions (freeing slots the
        # plan's admissions count on), then placements.
        for entry in plan.rejected:
            self._reject(entry)
        for rid in plan.evict:
            self._swap_out(rid)
        for entry, granted_slots in plan.admitted:
            self._place(entry, granted_slots)

    def _place(self, entry: QueueEntry, granted_slots: int) -> None:
        if entry.swapped is not None:       # swap-in: bit-exact resume
            job = entry.swapped.job
            job.resumed_ticks.append(self.tick_count)
            self.rids.alloc(job)
            job.slots = self.pool.restore(job.rid, entry.swapped.blocks)
            return
        req = entry.req
        arrival, submit_wall = self._submit_info.pop(
            req.req_id, (float(entry.submit_tick), float("nan")))
        job = ActiveJob(req=req, rid=-1, slots=[], T=req.T0,
                        submit_tick=entry.submit_tick,
                        start_tick=self.tick_count,
                        arrival_time=arrival,
                        submit_wall=submit_wall,
                        admit_wall=self._now())
        self.rids.alloc(job)
        job.slots = self.pool.assign(job.rid, req, n_slots=granted_slots)
        job.granted_chains = granted_slots * self.cfg.chains_per_slot

    def _swap_out(self, rid: int) -> None:
        """Preempt: checkpoint a job's device-visible state to host, free
        its slots, and re-queue it for a bit-exact resume."""
        job = self.rids.jobs[rid]
        blocks = self.pool.checkpoint(rid)
        self.pool.release(rid)
        self.rids.free(rid)
        job.slots = []
        job.rid = -1
        job.preempted_ticks.append(self.tick_count)
        self.scheduler.requeue(SwappedJob(job=job, blocks=blocks))
        self.preemptions += 1

    def preempt(self, req_id: int) -> bool:
        """Swap out the in-flight request ``req_id`` (False if not active).

        The scheduler's 'preempt' overload policy calls the same swap-out
        path; this is the operator/test entry point for preempting at a
        chosen temperature level.
        """
        for rid, job in list(self.rids.jobs.items()):
            if job.req.req_id == req_id:
                self._swap_out(rid)
                return True
        return False

    def _reject(self, entry: QueueEntry) -> None:
        """SLO fast-fail: terminal 'rejected' result, no solution."""
        req = entry.req
        arrival, submit_wall = self._submit_info.pop(
            req.req_id, (float(entry.submit_tick), float("nan")))
        self.results.append(RequestResult(
            req_id=req.req_id, objective=req.objective, dim=req.dim,
            x_best=None, f_best=float("inf"), levels_run=0, n_evals=0,
            submit_tick=entry.submit_tick, start_tick=-1,
            finish_tick=self.tick_count, finish_reason="rejected",
            arrival_time=arrival, submit_wall=submit_wall,
            finish_wall=self._now(), requested_chains=req.n_chains,
            granted_chains=0))
        self.rejections += 1

    # ---------------------------------------------------------------- tick
    def tick(self) -> None:
        """Admit, then advance every active slot by one temperature level."""
        self._admit()
        if not self.rids.jobs:
            self.tick_count += 1
            return

        # Dispatch groups are keyed by shape alone — (dim, N) — because the
        # objective id is a runtime kernel input; mixed-objective groups
        # share one compiled program.
        groups: Dict[Tuple[int, int], List[ActiveJob]] = defaultdict(list)
        for job in self.rids.jobs.values():
            groups[(job.req.dim, job.req.N)].append(job)

        for (dim, n_steps), jobs in sorted(groups.items()):
            self._dispatch_group(dim, n_steps, jobs)
            self.group_launches += 1
            for job in jobs:
                if job.first_tick < 0:
                    job.first_tick = self.tick_count
                    job.first_tick_wall = self._now()
                self.sweeps_done += len(job.slots)
                job.level += 1
                job.steps_done += n_steps
                job.evals += n_steps * job.granted_chains
                job.T *= job.req.rho
                job.history.append(job.best_f)   # champion trajectory/level
                reason = self._finish_reason(job)
                if reason is not None:
                    self._retire(job, reason)
        self.tick_count += 1

    def _dispatch_group(self, dim: int, n_steps: int,
                        jobs: List[ActiveJob]) -> None:
        """Pack the group's slots, run one device program, scatter back."""
        cps = self.cfg.chains_per_slot
        slot_list: List[Tuple[int, ActiveJob]] = [
            (s, job) for job in jobs for s in job.slots]
        n_blocks = len(slot_list)
        # Pad to a power of two of blocks so the number of compiled
        # signatures per (dim, N) is O(log n_slots), not O(n_slots).
        n_padded = 1
        while n_padded < n_blocks:
            n_padded *= 2

        x = np.empty((n_padded * cps, dim), np.float32)
        kid_blk = np.empty((n_padded,), np.int32)
        T_blk = np.empty((n_padded,), np.float32)
        seed_blk = np.empty((n_padded,), np.uint32)
        step0_blk = np.empty((n_padded,), np.uint32)
        base_blk = np.empty((n_padded,), np.uint32)
        seg = np.empty((n_padded * cps,), np.int32)
        adopt = np.empty((n_padded * cps,), bool)
        for b, (s, job) in enumerate(slot_list):
            x[b * cps:(b + 1) * cps] = self.pool.get_block(s)
            kid_blk[b] = np.int32(job.req.kid)
            T_blk[b] = job.T
            seed_blk[b] = np.uint32(job.req.seed)
            step0_blk[b] = np.uint32(job.steps_done)
            base_blk[b] = self.pool.chain_base[s]
            seg[b * cps:(b + 1) * cps] = job.rid
            adopt[b * cps:(b + 1) * cps] = job.req.exchange == "sync"
        # Dummy pad blocks: replicate block 0, claim the reserved segment
        # n_slots, never adopt. They cost lanes, not correctness.
        for b in range(n_blocks, n_padded):
            x[b * cps:(b + 1) * cps] = x[:cps]
            kid_blk[b] = kid_blk[0]
            T_blk[b] = T_blk[0]
            seed_blk[b] = seed_blk[0]
            step0_blk[b] = step0_blk[0]
            base_blk[b] = base_blk[0]
            seg[b * cps:(b + 1) * cps] = self.cfg.n_slots
            adopt[b * cps:(b + 1) * cps] = False

        x2, fx2, xb, fb = _group_tick(
            jnp.asarray(x), jnp.asarray(kid_blk), jnp.asarray(T_blk),
            jnp.asarray(seed_blk), jnp.asarray(step0_blk),
            jnp.asarray(base_blk), jnp.asarray(seg),
            jnp.asarray(adopt), n_steps=n_steps, blk=cps,
            variant=self.cfg.variant, use_pallas=self._use_pallas,
            interpret=self.cfg.interpret,
            num_segments=self.cfg.n_slots + 1)
        x2 = np.asarray(x2)
        xb = np.asarray(xb)
        fb = np.asarray(fb)

        for b, (s, job) in enumerate(slot_list):
            # Copy: a bare slice would alias (and pin) the whole padded buffer.
            self.pool.set_block(s, x2[b * cps:(b + 1) * cps].copy())
        for job in jobs:
            f = float(fb[job.rid])
            if f < job.best_f:
                job.best_f = f
                job.best_x = xb[job.rid].copy()

    def _finish_reason(self, job: ActiveJob) -> Optional[str]:
        req = job.req
        if (req.target_error is not None
                and job.best_f <= F_OPT[req.kid] + req.target_error):
            return "target"
        if req.max_evals is not None and job.evals >= req.max_evals:
            return "budget"
        if job.level >= req.n_levels:
            return "ladder"
        return None

    def _retire(self, job: ActiveJob, reason: str) -> None:
        self.results.append(RequestResult(
            req_id=job.req.req_id, objective=job.req.objective,
            dim=job.req.dim, x_best=job.best_x, f_best=job.best_f,
            levels_run=job.level, n_evals=job.evals,
            submit_tick=job.submit_tick, start_tick=job.start_tick,
            finish_tick=self.tick_count, finish_reason=reason,
            arrival_time=job.arrival_time, first_tick=job.first_tick,
            submit_wall=job.submit_wall, admit_wall=job.admit_wall,
            first_tick_wall=job.first_tick_wall, finish_wall=self._now(),
            requested_chains=job.req.n_chains,
            granted_chains=job.granted_chains,
            preempted_ticks=list(job.preempted_ticks),
            resumed_ticks=list(job.resumed_ticks),
            champion_history=list(job.history)))
        self.pool.release(job.rid)
        self.rids.free(job.rid)

    # ----------------------------------------------------------------- run
    def run(self, max_ticks: Optional[int] = None) -> List[RequestResult]:
        """Drive ticks until queue and pool drain (or ``max_ticks``).

        Closed-loop: serves whatever was already :meth:`submit`-ted — the
        degenerate open-loop run with an empty (exhausted) arrival stream.
        """
        from repro.service.arrivals import ArrivalProcess
        return self.run_stream(ArrivalProcess.batch([]), max_ticks=max_ticks)

    def run_stream(self, arrivals, max_ticks: Optional[int] = None
                   ) -> List[RequestResult]:
        """Open-loop serving: admit from an arrival process while ticking.

        ``arrivals`` is an :class:`~repro.service.arrivals.ArrivalProcess`
        (or anything with ``due(now)`` / ``exhausted``).  Each tick first
        submits every request whose arrival time has come due, then
        advances all in-flight work one temperature level; idle ticks (no
        active jobs, next arrival in the future) still advance the clock,
        so arrival timestamps stay on the tick axis.  Per-request
        lifecycle events (submit/admit/first-tick/complete) are stamped in
        both tick-time (deterministic under a fixed arrival seed) and
        wall-time.
        """
        t0 = time.time()
        while True:
            if max_ticks is not None and self.tick_count >= max_ticks:
                break
            for t_arr, req in arrivals.due(self.tick_count):
                self.submit(req, arrival_time=t_arr)
            if self.done:
                if arrivals.exhausted:
                    break
                # Idle: fast-forward the clock to the next arrival instead
                # of spinning empty ticks (low offered load would otherwise
                # execute one no-op tick per time unit).  ceil() lands on
                # the first tick >= next_time — identical tick-axis
                # semantics to ticking through, since due(t) is <=-t.
                # Sources without next_time just tick through idle time.
                nxt = getattr(arrivals, "next_time", None)
                if nxt is not None and math.isfinite(nxt):
                    jump = int(math.ceil(nxt))
                    if max_ticks is not None:
                        jump = min(jump, max_ticks)
                    if jump > self.tick_count:
                        self.tick_count = jump
                        continue
            self.tick()
        self.wall_s = time.time() - t0
        return self.results

    def stats(self) -> dict:
        wall = getattr(self, "wall_s", float("nan"))
        ticks = max(self.tick_count, 1)
        evals = sum(r.n_evals for r in self.results)
        per_s = lambda v: v / wall if wall and wall > 0 else 0.0
        return {
            "ticks": self.tick_count,
            "group_launches": self.group_launches,
            "completed": sum(r.completed for r in self.results),
            "rejected": self.rejections,
            "preemptions": self.preemptions,
            "sweeps": self.sweeps_done,
            "occupancy": self.sweeps_done / (ticks * self.cfg.n_slots),
            "wall_s": wall,
            "requests_per_s": per_s(len(self.results)),
            "sweeps_per_s": per_s(self.sweeps_done),
            "chain_steps_per_s": per_s(evals),
        }


def run_standalone(req: SARequest, cfg: EngineConfig) -> RequestResult:
    """Serve ``req`` alone on a dedicated pool — the per-tenant baseline.

    Placement-invariant RNG + segmented exchange make the packed engine
    produce the *same* trajectory as this single-tenant run (bit-exact
    champions for identical seeds); tests assert it, serve_sa --check
    reports it.
    """
    alone = SAServeEngine(dataclasses.replace(
        cfg, n_slots=req.slots_needed(cfg.chains_per_slot)))
    alone.submit(req)
    return alone.run()[0]
