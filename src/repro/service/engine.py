"""Continuous-batching SA serving engine.

The annealing analogue of a vLLM/LightLLM decode loop (launch/serve.py):

* a sharded pool of chain-block *slots* (slots.py, sharding.py) — the
  "decode batch", one shard per device on a 1-D ``(pool,)`` mesh;
* an admission scheduler (scheduler.py) packs queued requests into free
  slots — "prefill" — and places each request on a home shard;
* one engine **tick** advances every active slot by one temperature level
  (one N-step Metropolis sweep at that slot's own temperature, then a
  champion exchange masked per request);
* a request whose ladder / budget / accuracy target completes frees its
  slots *immediately* and the next queued request takes them — no tail
  latency from stragglers sharing the batch.

Invariants
----------
* **One tick = ``macro_k`` temperature levels** for every active slot
  (one when K=1, the classic tick).  ``tick_count`` always advances on
  the *ladder-level* clock — by K per active macro-tick — so a request's
  temperature ladder position is exactly its count of level-ticks in
  residence and every lifecycle timestamp keeps level units at any K.
  Admission, preemption, migration and fleet ops land only on macro-tick
  boundaries (the top of ``tick()``); within a macro-tick the K levels —
  including the per-level champion exchange — run fused in one device
  program with donated ping-pong state buffers (``_group_tick_fused``).
* **kid is runtime**: per-slot *objective id, temperature, RNG seed, step
  cursor and chain base* are runtime arrays threaded down to the kernel
  (one SMEM entry per block, indexed by ``program_id``) — none of them can
  cause recompilation.  Only *dimensionality and sweep length* remain
  compile-time constants, so active slots are grouped by ``(dim, N)``
  within each shard every tick and dispatched as one device program per
  ``(shard, dim, N)`` group: one compiled sweep program per device serves
  every registry objective, and growing ``SERVABLE`` never costs a
  recompile.  (Groups are additionally padded to power-of-two block
  counts to bound the number of compiled shapes.)
* **Tenant isolation**: champion reduces inside a packed group are
  segmented by request id — tenants never exchange states
  (core/exchange.py) — and placement-invariant RNG makes a request's
  trajectory bit-identical to its standalone single-tenant run.
* **Sharded pool** (sharding.py): ``EngineConfig.n_devices`` engine
  shards each own ``n_slots`` slots on their own mesh device.  The
  scheduler's placement layer homes each admitted request on the
  least-loaded compatible shard and rebalances via Russkov-style
  migration — checkpoint a :class:`~repro.service.slots.SwappedJob` on
  the overloaded shard, restore it on an underloaded one — and because
  restore is placement-invariant, a migrated trajectory is **bit-exact**
  versus an uninterrupted single-device run.  Requests never span shards.
* **Open-loop serving**: :meth:`SAServeEngine.run_stream` interleaves
  admission of an :class:`~repro.service.arrivals.ArrivalProcess` (e.g.
  seeded Poisson) with in-flight progress, stamping per-request lifecycle
  events (submit / admit / first-tick / preempted / resumed /
  complete-or-rejected, in both tick-time and wall-time) from which
  queueing-delay and time-to-first-tick percentiles are derived (see
  docs/serving.md).  All wall times — lifecycle stamps and the run's
  ``wall_s`` alike — come from one monotonic epoch
  (``time.perf_counter`` since engine construction), so a wall-clock
  adjustment mid-run can never skew a latency or throughput figure.
* **Preemption is bit-exact**: an active job checkpoints to a host-side
  :class:`~repro.service.slots.SwappedJob` (slot blocks + champion + RNG
  step cursor + temperature cursor) and resumes — possibly on different
  physical slots of a different shard — with a trajectory identical to an
  uninterrupted run, because the RNG is counter-based on logical (chain
  index, step) coordinates.  SLO admission control (scheduler.py) builds
  on it: the 'preempt' overload policy evicts the cheapest active jobs
  for an urgent arrival, 'reject' and 'degrade' bound queue growth at
  overload.
* **The fleet is elastic** (this PR): :meth:`SAServeEngine.drain` marks a
  shard draining — no new placements; its jobs are checkpoint-evacuated
  onto the survivors each tick (bounded by ``migration_budget``, highest
  effective priority first, shrinking or swapping to the queue when no
  survivor has full-width room) and the shard is retired once empty.
  :meth:`SAServeEngine.resize` composes drain/add for mid-stream fleet
  grow/shrink.  The scheduler's placement layer adds **watermark
  rebalancing** (background moves off shards above ``high_watermark``
  onto shards below ``low_watermark``, hysteresis by construction) and
  **proactive degrade** (shrink *running* degrade-class jobs —
  checkpoint, restore at fewer slots, never below ``min_chains`` — when
  the queue head fits nowhere).  Every moved or shrunk trajectory stays
  bit-exact versus an uninterrupted run with the same width schedule,
  because all three reuse the placement-invariant checkpoint/restore.
"""
from __future__ import annotations

import dataclasses
import math
import time
import warnings
from collections import defaultdict
from functools import partial
from typing import Dict, Iterator, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.core import exchange as exch
from repro.kernels import objective_math as om
from repro.kernels import ops
from repro.objectives import families as fam_mod
from repro.service.request import RequestResult, SARequest
from repro.service.scheduler import (AdmissionScheduler, QueueEntry,
                                     SchedulerConfig, ShardView)
from repro.service.sharding import EngineShard, make_shard, make_shards
from repro.service.slots import ActiveJob, SwappedJob
from repro.service.telemetry import NULL as NULL_TELEMETRY

# The fused macro-tick program donates its input state buffer (the double
# buffer ping-pongs between launches).  Backends without donation support
# (CPU) warn instead of reusing the buffer — functionally identical, so
# silence exactly that warning.
warnings.filterwarnings(
    "ignore", message="Some donated buffers were not usable",
    category=UserWarning)

#: Known optima of the servable *continuous* (registry) objectives, keyed
#: by kernel id — derived from the family layer's name-keyed table so the
#: values live in exactly one place (objectives/families.py).  Schwefel is
#: the paper's normalized form, so its optimum is dim-free.  A continuous
#: request may only set ``target_error`` on an objective listed here —
#: :meth:`SAServeEngine.submit` validates it eagerly (a typed ValueError at
#: the frontend) instead of letting a KeyError wedge a slot mid-tick.
#: Permutation (QAP) requests never consult this dict: every registered
#: instance carries a verifiable ``best_known`` (``req.f_opt``).
F_OPT = {om.KID_BY_NAME[name]: v
         for name, v in fam_mod.F_OPT_BY_NAME.items()}


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    n_slots: int = 8            # slots *per shard*
    chains_per_slot: int = 64   # chains per slot == kernel block size
    n_devices: int = 1          # engine shards on the 1-D (pool,) mesh;
                                # logical shards round-robin when fewer
                                # physical devices exist (sharding.py)
    variant: str = "delta"      # 'delta' (O(1) updates) | 'full' (paper)
    use_pallas: object = "auto"  # True | False | 'auto' (TPU only)
    interpret: bool = False     # Pallas interpret mode (tests on CPU)
    migration_budget: int = 1   # max cross-shard moves per tick (0 = no
                                # automatic rebalancing)
    macro_k: int = 1            # ladder levels fused into one device
                                # dispatch (a "macro-tick").  1 = the
                                # classic one-level tick; K>1 amortizes
                                # host packing/launch over K levels, and
                                # admission/preemption/migration land only
                                # on macro-tick boundaries.  Trajectories
                                # are bit-exact at any K (tests).
    scheduler: SchedulerConfig = dataclasses.field(
        default_factory=SchedulerConfig)

    def __post_init__(self):
        if self.n_devices < 1:
            raise ValueError(f"n_devices must be >= 1, got {self.n_devices}")
        if self.migration_budget < 0:
            raise ValueError("migration_budget must be >= 0")
        if self.macro_k < 1:
            raise ValueError(f"macro_k must be >= 1, got {self.macro_k}")


def _chain_controls(T_blk, seed_blk, base_blk, lvl0, mcode, t_rung, blk: int):
    """Expand per-block controls to the per-chain arrays the composite
    exchange consumes: the schedule temperature, the effective sweep
    temperature (PT chains anneal at their own rung, everyone else at the
    block's ladder value), the request seed, the logical chain index and
    the absolute ladder level."""
    n_blocks = jnp.asarray(T_blk).shape[0]
    sched = jnp.repeat(T_blk, blk)
    T_chain = jnp.where(mcode == exch.MCODE_PT, t_rung, sched)
    seed_c = jnp.repeat(seed_blk, blk)
    cidx = (jnp.repeat(base_blk, blk).astype(jnp.uint32)
            + jnp.tile(jnp.arange(blk, dtype=jnp.uint32), n_blocks))
    lvl_abs = jnp.repeat(lvl0.astype(jnp.uint32), blk)
    return sched, T_chain, seed_c, cidx, lvl_abs


@partial(jax.jit, static_argnames=("n_steps", "blk", "variant",
                                   "use_pallas", "interpret", "num_segments"))
def _group_tick(x, kid_blk, T_blk, seed_blk, step0_blk, base_blk, lvl0_blk,
                dbeta_blk, seg, adopt, mcode, t_rung, partner, pairlo,
                seg_lo, seg_hi, *, n_steps: int, blk: int, variant: str,
                use_pallas: bool, interpret: bool, num_segments: int):
    """One temperature level for one dispatch group, on device.

    Sweep every block on its own objective (``kid_blk`` is a runtime
    input — mixed-objective groups share one lowering) at its own
    temperature — per *chain* when the block belongs to a parallel-
    tempering tenant (``t_rung``) — then the composite segmented exchange
    (core/exchange.serving_exchange): champion reduce, sync/sos adoption,
    PT even/odd swap, PA resample, each masked per workload class so a
    plain-SA-only batch is bitwise the classic path.  The champion is
    returned for every segment either way so the host can fold
    best-so-far.
    """
    sched, T_chain, seed_c, cidx, lvl_abs = _chain_controls(
        T_blk, seed_blk, base_blk, lvl0_blk, mcode, t_rung, blk)
    x, fx = ops.metropolis_sweep_slots(
        x, kid_blk, T_blk, seed_blk, step0_blk, base_blk, n_steps=n_steps,
        blk=blk, variant=variant, use_pallas=use_pallas, interpret=interpret,
        T_chain=T_chain)
    live = jnp.ones(fx.shape, bool)
    return exch.serving_exchange(
        x, fx, seg, num_segments, adopt, mcode, t_rung, sched, partner,
        pairlo, seg_lo, seg_hi, jnp.repeat(dbeta_blk, blk), seed_c, cidx,
        lvl_abs, live)


@partial(jax.jit, static_argnames=("k", "n_steps", "blk", "variant",
                                   "use_pallas", "interpret",
                                   "num_segments"),
         donate_argnums=(0,))
def _group_tick_fused(x, kid_blk, T_lvls, seed_blk, step0_blk, base_blk,
                      levels_blk, lvl0_blk, dbeta_lvls, seg, adopt, mcode,
                      t_rung, partner2, pairlo2, seg_lo, seg_hi, *, k: int,
                      n_steps: int, blk: int, variant: str, use_pallas: bool,
                      interpret: bool, num_segments: int):
    """K temperature levels for one dispatch group, in one device program.

    The macro-tick: an on-device ``fori_loop`` over ``k`` iterations of
    [one-level sweep + composite segmented exchange] — exactly the K=1
    ``_group_tick`` body K times, so each level's floating-point stream is
    identical to K separate dispatches.  Per-level controls:

    * ``T_lvls`` is ``(k, n_blocks)`` — each block's host-precomputed
      temperature ladder slice, one SMEM row per level — and
      ``dbeta_lvls`` its PA inverse-temperature increments (0 elsewhere);
    * level ``i`` sweeps with RNG step cursor ``step0 + i*n_steps`` at
      absolute ladder level ``lvl0_blk + i`` (the exchange RNG counter);
    * ``levels_blk`` is the per-slot level cursor: blocks whose request
      has fewer than ``k`` planned levels go *dead* (``live = i <
      levels_blk``) — the kernel masks their accepts so state passes
      through bit-exactly, and the per-class masks keep their chains out
      of every exchange stage;
    * ``partner2`` / ``pairlo2`` are ``(2, chains)``: row ``i % 2`` holds
      each PT chain's swap partner for that level's even/odd parity
      (host-precomputed from its own job's absolute level).

    Per-level champions come back stacked — ``(k, num_segments)`` values
    and ``(k, num_segments, dim)`` states — for the host to fold level by
    level (truncating at early finishes), plus ``fx_keep``: each chain's
    post-exchange objective value at its *last live* level (dead
    iterations re-derive f(x) bitwise differently, so the live value is
    carried, not recomputed) — the population-annealing ESS controller
    reads it at the boundary.  ``x`` is **donated**: the engine's double
    buffer ping-pongs between launches, so chain state never round-trips
    to host while a group's membership is stable.
    """
    dim = x.shape[1]

    def body(i, carry):
        x, fx_keep, fb_all, xb_all = carry
        live = i < levels_blk                       # (n_blocks,) cursor
        T_i = lax.dynamic_index_in_dim(T_lvls, i, 0, keepdims=False)
        db_i = lax.dynamic_index_in_dim(dbeta_lvls, i, 0, keepdims=False)
        step0_i = step0_blk + jnp.uint32(n_steps) * i.astype(jnp.uint32)
        sched, T_chain, seed_c, cidx, lvl_abs = _chain_controls(
            T_i, seed_blk, base_blk, lvl0_blk + i.astype(jnp.uint32),
            mcode, t_rung, blk)
        x, fx = ops.metropolis_sweep_slots(
            x, kid_blk, T_i, seed_blk, step0_i, base_blk, n_steps=n_steps,
            blk=blk, variant=variant, use_pallas=use_pallas,
            interpret=interpret, live=live, T_chain=T_chain)
        live_c = jnp.repeat(live, blk)
        prt = lax.dynamic_index_in_dim(partner2, i % 2, 0, keepdims=False)
        plo = lax.dynamic_index_in_dim(pairlo2, i % 2, 0, keepdims=False)
        x, fx, xb, fb = exch.serving_exchange(
            x, fx, seg, num_segments, adopt, mcode, t_rung, sched, prt,
            plo, seg_lo, seg_hi, jnp.repeat(db_i, blk), seed_c, cidx,
            lvl_abs, live_c)
        fx_keep = jnp.where(live_c, fx, fx_keep)
        return x, fx_keep, fb_all.at[i].set(fb), xb_all.at[i].set(xb)

    fb0 = jnp.full((k, num_segments), jnp.inf, x.dtype)
    xb0 = jnp.zeros((k, num_segments, dim), x.dtype)
    fx0 = jnp.zeros((x.shape[0],), x.dtype)
    return lax.fori_loop(0, k, body, (x, fx0, fb0, xb0))


@partial(jax.jit, static_argnames=("n_steps", "blk", "use_pallas",
                                   "interpret", "num_segments"))
def _group_tick_qap(x, F_blk, D_blk, T_blk, seed_blk, step0_blk, base_blk,
                    lvl0_blk, seg, adopt, mcode, t_rung, partner, pairlo,
                    seg_lo, seg_hi, *, n_steps: int, blk: int,
                    use_pallas: bool, interpret: bool, num_segments: int):
    """One temperature level for one *permutation-family* dispatch group.

    The QAP counterpart of :func:`_group_tick`: the same control layout
    and the same composite segmented exchange (dtype-agnostic over the
    chain states, so int32 permutations ride it unchanged), but the sweep
    is the pairwise-exchange QAP kernel and the per-block runtime operands
    are the flow/distance matrices (packed ``(n_blocks*n, n)``) instead of
    an objective id.  Chain states ``x`` are int32; objective values stay
    float32 (exact for the integer-valued instances).  No ``variant``/
    ``dbeta``: the QAP sweep is always delta-evaluated (bitwise equal to a
    full evaluation) and permutation requests are method-'sa' only, so the
    PA reweighting increment is identically zero.  A separate jit (typed
    on int32 x) naturally pins one compiled program per family.
    """
    sched, T_chain, seed_c, cidx, lvl_abs = _chain_controls(
        T_blk, seed_blk, base_blk, lvl0_blk, mcode, t_rung, blk)
    x, fx = ops.qap_sweep_slots(
        x, F_blk, D_blk, T_blk, seed_blk, step0_blk, base_blk,
        n_steps=n_steps, blk=blk, use_pallas=use_pallas, interpret=interpret)
    live = jnp.ones(fx.shape, bool)
    return exch.serving_exchange(
        x, fx, seg, num_segments, adopt, mcode, t_rung, sched, partner,
        pairlo, seg_lo, seg_hi, jnp.zeros_like(fx), seed_c, cidx,
        lvl_abs, live)


@partial(jax.jit, static_argnames=("k", "n_steps", "blk", "use_pallas",
                                   "interpret", "num_segments"),
         donate_argnums=(0,))
def _group_tick_qap_fused(x, F_blk, D_blk, T_lvls, seed_blk, step0_blk,
                          base_blk, levels_blk, lvl0_blk, seg, adopt, mcode,
                          t_rung, partner2, pairlo2, seg_lo, seg_hi, *,
                          k: int, n_steps: int, blk: int, use_pallas: bool,
                          interpret: bool, num_segments: int):
    """K temperature levels for one permutation-family group, fused.

    Mirrors :func:`_group_tick_fused` level by level — same live-cursor
    masking, per-level champion stacks and donated ping-pong state buffer
    — with the QAP sweep in place of the Metropolis one.  The champion
    carry is typed explicitly (float32 values, int32 states): the
    continuous path types both off ``x.dtype``, which is exactly what an
    int32 state buffer must not do.  ``fx_keep`` is carried for interface
    parity (the PA controller never reads it here — permutation requests
    are method-'sa' only).
    """
    dim = x.shape[1]

    def body(i, carry):
        x, fx_keep, fb_all, xb_all = carry
        live = i < levels_blk                       # (n_blocks,) cursor
        T_i = lax.dynamic_index_in_dim(T_lvls, i, 0, keepdims=False)
        step0_i = step0_blk + jnp.uint32(n_steps) * i.astype(jnp.uint32)
        sched, T_chain, seed_c, cidx, lvl_abs = _chain_controls(
            T_i, seed_blk, base_blk, lvl0_blk + i.astype(jnp.uint32),
            mcode, t_rung, blk)
        x, fx = ops.qap_sweep_slots(
            x, F_blk, D_blk, T_i, seed_blk, step0_i, base_blk,
            n_steps=n_steps, blk=blk, use_pallas=use_pallas,
            interpret=interpret, live=live)
        live_c = jnp.repeat(live, blk)
        prt = lax.dynamic_index_in_dim(partner2, i % 2, 0, keepdims=False)
        plo = lax.dynamic_index_in_dim(pairlo2, i % 2, 0, keepdims=False)
        x, fx, xb, fb = exch.serving_exchange(
            x, fx, seg, num_segments, adopt, mcode, t_rung, sched, prt,
            plo, seg_lo, seg_hi, jnp.zeros_like(fx), seed_c, cidx,
            lvl_abs, live_c)
        fx_keep = jnp.where(live_c, fx, fx_keep)
        return x, fx_keep, fb_all.at[i].set(fb), xb_all.at[i].set(xb)

    fb0 = jnp.full((k, num_segments), jnp.inf, jnp.float32)
    xb0 = jnp.zeros((k, num_segments, dim), x.dtype)
    fx0 = jnp.zeros((x.shape[0],), jnp.float32)
    return lax.fori_loop(0, k, body, (x, fx0, fb0, xb0))


def _pt_partners(n: int, parity: int):
    """Logical even/odd swap partners for an ``n``-rung PT ladder.

    Parity 0 pairs rungs (0,1)(2,3)…, parity 1 pairs (1,2)(3,4)…; a rung
    without a partner at this parity (rung 0 on odd passes, the last rung
    when the count doesn't divide) is its own partner — the device pass
    treats self-partners as "no swap proposed".  Returns
    ``(partner int32, pairlo uint32)`` with ``pairlo`` the lower logical
    rung of each pair — the shared RNG key that makes both partners draw
    the same accept uniform.
    """
    lg = np.arange(n, dtype=np.int64)
    if parity == 0:
        p = lg ^ 1
    else:
        p = np.where(lg == 0, lg, ((lg - 1) ^ 1) + 1)
    p = np.where(p < n, p, lg)
    return p.astype(np.int32), np.minimum(lg, p).astype(np.uint32)


def _job_mcode(req: SARequest) -> int:
    """Per-chain workload-class code (core/exchange) for a request."""
    if req.method == "pt":
        return exch.MCODE_PT
    if req.method == "pa":
        return exch.MCODE_PA
    return exch.MCODE_SOS if req.exchange == "sos" else exch.MCODE_PLAIN


def _pa_dbeta(t: float, rho: float) -> float:
    """PA inverse-temperature increment across one cooling step, in
    float64 host math (cast to f32 at the SMEM boundary): the Boltzmann
    reweighting exponent between level temperature ``t`` and the next."""
    return 1.0 / (t * rho) - 1.0 / t


class SAServeEngine:
    """Multi-tenant annealing server: one device program per (shard, group)."""

    def __init__(self, cfg: Optional[EngineConfig] = None, telemetry=None):
        # Build a fresh default per engine: a mutable-default-argument
        # EngineConfig() would be evaluated once and shared by every engine
        # constructed without a config (tests pin this down).
        cfg = EngineConfig() if cfg is None else cfg
        self.cfg = cfg
        self.shards: List[EngineShard] = make_shards(
            cfg.n_devices, cfg.n_slots, cfg.chains_per_slot)
        self.scheduler = AdmissionScheduler(cfg.scheduler)
        # Observability is opt-in and purely host-side: the default NULL
        # telemetry no-ops every hook (no span objects, no metrics, no
        # behavior change), and an enabled Telemetry never touches a
        # device buffer or an admission decision — trajectories stay
        # bit-exact with tracing on (tests + serve_sa --check --trace).
        self.telemetry = NULL_TELEMETRY if telemetry is None else telemetry
        self.scheduler.telemetry = self.telemetry
        self.results: List[RequestResult] = []
        self.tick_count = 0
        self.n_submitted = 0          # requests offered via submit(): the
                                      # denominator for terminal accounting
        self.sweeps_done = 0          # block-sweeps (slot x level): also the
                                      # occupancy numerator (active slot-ticks)
        self.group_launches = 0
        self.preemptions = 0          # swap-outs performed
        self.rejections = 0           # SLO admission-control drops
        self.migrations = 0           # cross-shard rebalancing moves
        self.shrinks = 0              # proactive-degrade width reductions
        self.truncations = 0          # finish-deadline ladder truncations
        self.slot_ticks = 0           # Σ over ticks of fleet slot count —
                                      # the occupancy denominator (the
                                      # fleet is elastic, so ticks x slots
                                      # is no longer a constant product)
        self.retired_shards: List[Tuple[int, int]] = []  # (index, tick)
        self._next_shard_index = cfg.n_devices   # shard ids are stable and
                                                 # never reused (resize/add)
        self._ops: List[Tuple[int, int, object]] = []  # (tick, seq, fn)
        self._op_seq = 0
        # Closed-loop controller (service/autoscaler.py): when attached,
        # it samples fleet signals at the top of each tick and may call
        # resize()/schedule_op() itself.  None = no control plane.
        self.controller = None
        self._use_pallas = ops.resolve_use_pallas(cfg.use_pallas)
        if self._use_pallas and cfg.chains_per_slot % 8:
            raise ValueError(
                f"chains_per_slot={cfg.chains_per_slot} must be a multiple "
                "of 8 (TPU sublanes) on the Pallas path")
        self._epoch = time.perf_counter()
        # Phase spans share the engine's monotonic epoch; the NULL
        # telemetry hands back a shared no-op timer (zero allocation).
        self._pt = self.telemetry.make_phase_timer(self._now)
        if self.telemetry.trace is not None:
            self.telemetry.trace.bind_clock(self._now)
        #: req_id -> (arrival_time in ticks, submit wall time): lifecycle
        #: info that must survive the queue (the scheduler only keeps the
        #: submit tick).
        self._submit_info: Dict[int, Tuple[float, float]] = {}

    def _now(self) -> float:
        """Wall seconds since engine construction (the engine epoch).

        Monotonic (``time.perf_counter``): every wall-clock stamp the
        engine emits — lifecycle events *and* ``run_stream``'s ``wall_s``
        — shares this epoch, so intervals between them are meaningful and
        immune to wall-clock adjustments.
        """
        return time.perf_counter() - self._epoch

    # ------------------------------------------------------------ frontend
    def submit(self, req: SARequest, arrival_time: Optional[float] = None
               ) -> None:
        """Enqueue ``req``.  ``arrival_time`` (in ticks, may be fractional)
        is the offered-load timestamp for open-loop runs; it defaults to
        the submit tick (closed-loop batch submission)."""
        need = req.slots_needed(self.cfg.chains_per_slot)
        if need > self.cfg.n_slots:
            raise ValueError(
                f"request {req.req_id} needs {need} slots > the per-shard "
                f"pool of {self.cfg.n_slots}; requests never span shards — "
                "lower n_chains or grow n_slots")
        if (req.target_error is not None
                and req.family == fam_mod.FAMILY_CONTINUOUS
                and req.kid not in F_OPT):
            # Validate here, not mid-tick: an unguarded F_OPT lookup in the
            # finish check would raise KeyError after admission and wedge
            # the request's slots for good.  Permutation requests skip the
            # check: every registered QAP instance carries a best_known.
            raise ValueError(
                f"request {req.req_id} sets target_error but objective "
                f"{req.objective!r} has no registered optimum in "
                "engine.F_OPT; register one or drop target_error")
        if (req.req_id in self._submit_info
                or any(job.req.req_id == req.req_id
                       for _, job in self._iter_jobs())
                or any(r.req_id == req.req_id
                       for r in self.scheduler.pending)):
            raise ValueError(
                f"request id {req.req_id} is already queued, swapped out or "
                "in flight; req_ids must be unique among live requests")
        self._submit_info[req.req_id] = (
            float(self.tick_count if arrival_time is None else arrival_time),
            self._now())
        self.scheduler.submit(req, self.tick_count)
        self.n_submitted += 1
        if self.telemetry.trace is not None:
            self.telemetry.trace.request_begin(
                req.req_id, objective=req.objective, dim=req.dim,
                n_chains=req.n_chains, tick=self.tick_count)

    # ----------------------------------------------------------- shard views
    def _iter_jobs(self) -> Iterator[Tuple[EngineShard, ActiveJob]]:
        for shard in self.shards:
            for job in shard.rids.jobs.values():
                yield shard, job

    def _view(self, shard: EngineShard) -> ShardView:
        jobs = tuple(shard.rids.jobs.values())
        return ShardView(
            index=shard.index, free_slots=shard.pool.n_free, active=jobs,
            shapes=frozenset((j.req.family, j.req.dim, j.req.N)
                             for j in jobs))

    def _shard(self, index: int) -> EngineShard:
        """Shard by stable index.  Indices are identities, not positions:
        a retired shard leaves a gap and added shards get fresh ids."""
        for shard in self.shards:
            if shard.index == index:
                return shard
        raise ValueError(f"no live shard with index {index}")

    @property
    def live_shards(self) -> List[EngineShard]:
        """Shards accepting new placements (not draining)."""
        return [s for s in self.shards if not s.draining]

    @property
    def pool(self):
        """Single-shard convenience alias (tests, notebooks).  Multi-shard
        engines have no 'the pool' — address ``engine.shards[i].pool``."""
        if len(self.shards) == 1:
            return self.shards[0].pool
        raise AttributeError(
            f"engine has {len(self.shards)} shards: use shards[i].pool")

    @property
    def rids(self):
        """Single-shard convenience alias, like :attr:`pool`."""
        if len(self.shards) == 1:
            return self.shards[0].rids
        raise AttributeError(
            f"engine has {len(self.shards)} shards: use shards[i].rids")

    @property
    def n_active(self) -> int:
        return sum(len(s.rids.jobs) for s in self.shards)

    @property
    def done(self) -> bool:
        return self.n_active == 0 and len(self.scheduler) == 0

    # ----------------------------------------------------------- admission
    def _admit(self) -> None:
        cps = self.cfg.chains_per_slot
        budget = self.cfg.migration_budget
        pt = self._pt          # phase spans: planning = 'schedule',
        #                        executing the plans = 'admit'
        # Drain evacuation has first claim on the per-tick move budget:
        # jobs leave draining shards (migrate whole / shrink-migrate /
        # swap to queue, in that order of preference) so the shards can
        # retire.  Draining shards take no new placements — every view
        # handed to the planners below is a survivor.
        if any(s.draining for s in self.shards):
            budget -= self._evacuate_draining(budget)
            self._retire_drained()
        with pt("schedule"):
            views = {s.index: self._view(s) for s in self.live_shards}
            # Head defrag: if the queue head fits on no single shard but
            # the pool as a whole has room, migrate jobs off a donor shard
            # (checkpoint/restore, bit-exact) so the head becomes
            # admissible this very tick.  Snapshots are rebuilt only for
            # the (budget-bounded, usually zero) shards a move touched.
            moves = self.scheduler.plan_migrations(
                list(views.values()), cps, self.tick_count, budget)
        with pt("admit"):
            for rid, src, dst in moves:
                self._migrate_job(self._shard(src), rid, self._shard(dst))
        budget -= len(moves)
        for si in {si for move in moves for si in move[1:]}:
            views[si] = self._view(self._shard(si))
        # Proactive degrade: when migration cannot seat the head (the
        # pool is genuinely full), shrink running degrade-class jobs of
        # strictly lower effective priority — checkpoint/restore at
        # fewer slots, never below their floor — until it fits.
        shrinks = []
        if not moves and self.cfg.scheduler.proactive_degrade:
            with pt("schedule"):
                shrinks = self.scheduler.plan_shrinks(
                    list(views.values()), cps, self.tick_count,
                    self.cfg.scheduler.shrink_budget)
            with pt("admit"):
                for rid, si, keep_slots in shrinks:
                    self._shrink_job(self._shard(si), rid, keep_slots)
                    views[si] = self._view(self._shard(si))
        # Watermark rebalancing: background load-driven moves with
        # whatever move budget the head didn't need.  Skipped on ticks
        # head-defrag or a proactive shrink fired — the slots they freed
        # are earmarked for the head and must survive untouched until
        # admission below seats it (a rebalance move could otherwise
        # land new work on the shrink's shard, wasting the irreversible
        # width cut).
        if not moves and not shrinks:
            with pt("schedule"):
                rmoves = self.scheduler.plan_rebalance(
                    list(views.values()), self.tick_count, budget)
            with pt("admit"):
                for rid, src, dst in rmoves:
                    self._migrate_job(self._shard(src), rid,
                                      self._shard(dst))
            for si in {si for move in rmoves for si in move[1:]}:
                views[si] = self._view(self._shard(si))
        # Then one queue walk across all shards (scheduler.admit_sharded):
        # every entry, in effective-priority order, is tried at full
        # width on every shard — least-loaded first, (dim, N)-locality
        # tie-break — before its degrade/preempt fallback may fire, and
        # the preemption budget bounds evictions per tick across shards.
        with pt("schedule"):
            plan = self.scheduler.admit_sharded(
                list(views.values()), cps, self.tick_count)
        # Execution order matters: rejections first (they free nothing
        # but must be stamped this tick), then evictions (freeing slots
        # the plan's admissions count on), then placements.
        with pt("admit"):
            for entry in plan.rejected:
                self._reject(entry)
            for rid, si in plan.evict:
                self._swap_out(self._shard(si), rid)
            for entry, granted_slots, si in plan.admitted:
                self._place(self._shard(si), entry, granted_slots)

    def _place(self, shard: EngineShard, entry: QueueEntry,
               granted_slots: int) -> None:
        tel = self.telemetry
        if entry.swapped is not None:       # swap-in: bit-exact resume
            job = entry.swapped.job
            job.resumed_ticks.append(self.tick_count)
            shard.rids.alloc(job)
            job.slots = shard.pool.restore(job.rid, entry.swapped.blocks)
            job.home_shard = shard.index
            if tel.enabled:
                tel.decision(self.tick_count, "resume",
                             req_id=job.req.req_id, shard=shard.index,
                             slots=len(job.slots))
                if tel.trace is not None:
                    tel.trace.request_instant(
                        job.req.req_id, "resume", shard=shard.index,
                        tick=self.tick_count)
            return
        req = entry.req
        arrival, submit_wall = self._submit_info.pop(
            req.req_id, (float(entry.submit_tick), float("nan")))
        job = ActiveJob(req=req, rid=-1, slots=[], T=req.T0,
                        submit_tick=entry.submit_tick,
                        start_tick=self.tick_count,
                        arrival_time=arrival,
                        submit_wall=submit_wall,
                        admit_wall=self._now(),
                        home_shard=shard.index,
                        levels_limit=req.n_levels)
        shard.rids.alloc(job)
        job.slots = shard.pool.assign(job.rid, req, n_slots=granted_slots)
        job.granted_chains = granted_slots * self.cfg.chains_per_slot
        if tel.enabled:
            tel.decision(self.tick_count, "admit", req_id=req.req_id,
                         shard=shard.index, granted_slots=granted_slots,
                         requested_chains=req.n_chains,
                         granted_chains=job.granted_chains)
            if tel.trace is not None:
                tel.trace.request_instant(
                    req.req_id, "admit", shard=shard.index,
                    granted_chains=job.granted_chains,
                    tick=self.tick_count)

    def _swap_out(self, shard: EngineShard, rid: int) -> None:
        """Preempt: checkpoint a job's device-visible state to host, free
        its slots, and re-queue it for a bit-exact resume (on whichever
        shard next has room — swap-in doubles as migration)."""
        job = shard.rids.jobs[rid]
        blocks = shard.pool.checkpoint(rid)
        shard.pool.release(rid)
        shard.rids.free(rid)
        job.slots = []
        job.rid = -1
        job.preempted_ticks.append(self.tick_count)
        self.scheduler.requeue(SwappedJob(job=job, blocks=blocks))
        self.preemptions += 1
        tel = self.telemetry
        if tel.enabled:
            tel.decision(self.tick_count, "preempt",
                         req_id=job.req.req_id, shard=shard.index,
                         level=job.level)
            if tel.trace is not None:
                tel.trace.request_instant(
                    job.req.req_id, "preempt", shard=shard.index,
                    level=job.level, tick=self.tick_count)

    def _migrate_job(self, src: EngineShard, rid: int,
                     dst: EngineShard) -> None:
        """Move a resident job between shards without a queue round-trip:
        checkpoint on ``src``, restore on ``dst`` in the same tick.  The
        job keeps annealing this tick (on its new device); the trajectory
        is bit-exact because restore is placement-invariant."""
        job = src.rids.jobs[rid]
        blocks = src.pool.checkpoint(rid)
        src.pool.release(rid)
        src.rids.free(rid)
        dst.rids.alloc(job)
        job.slots = dst.pool.restore(job.rid, blocks)
        job.home_shard = dst.index
        job.migrated_ticks.append(self.tick_count)
        self.migrations += 1
        tel = self.telemetry
        if tel.enabled:
            tel.decision(self.tick_count, "migrate",
                         req_id=job.req.req_id, src=src.index,
                         dst=dst.index, level=job.level)
            if tel.trace is not None:
                tel.trace.request_instant(
                    job.req.req_id, "migrate", src=src.index,
                    dst=dst.index, tick=self.tick_count)

    def migrate(self, req_id: int, to_shard: int) -> bool:
        """Move the in-flight request ``req_id`` to shard ``to_shard``.

        The operator/test entry point for forcing a cross-shard move at a
        chosen temperature level (the scheduler's rebalancer calls the
        same checkpoint/restore path).  Returns False if the request is
        not active, already home, the target shard lacks room, or the
        target is draining (it takes no new placements).
        """
        dst = self._shard(to_shard)     # ValueError on unknown/retired ids
        if dst.draining:
            return False
        for shard, job in self._iter_jobs():
            if job.req.req_id == req_id:
                if shard.index == to_shard \
                        or dst.pool.n_free < len(job.slots):
                    return False
                self._migrate_job(shard, job.rid, dst)
                return True
        return False

    def preempt(self, req_id: int) -> bool:
        """Swap out the in-flight request ``req_id`` (False if not active).

        The scheduler's 'preempt' overload policy calls the same swap-out
        path; this is the operator/test entry point for preempting at a
        chosen temperature level.
        """
        for shard, job in list(self._iter_jobs()):
            if job.req.req_id == req_id:
                self._swap_out(shard, job.rid)
                return True
        return False

    # -------------------------------------------------------- elastic fleet
    def _record_shrink(self, job: ActiveJob, from_chains: int,
                       self_driven: bool = False) -> None:
        job.granted_chains = len(job.slots) * self.cfg.chains_per_slot
        job.shrunk_ticks.append(self.tick_count)
        event = (job.level, from_chains, job.granted_chains)
        # Self-driven (PA ESS) shrinks are re-derived by a standalone
        # replay from the identical fx stream; recording them apart keeps
        # the --check oracle from re-applying them as an external schedule.
        if self_driven:
            job.pa_shrink_events.append(event)
        else:
            job.shrink_events.append(event)
        self.shrinks += 1
        tel = self.telemetry
        if tel.enabled:
            kind = "pa_shrink" if self_driven else "shrink"
            tel.decision(self.tick_count, kind,
                         req_id=job.req.req_id, shard=job.home_shard,
                         level=job.level, from_chains=from_chains,
                         to_chains=job.granted_chains)
            if tel.trace is not None:
                tel.trace.request_instant(
                    job.req.req_id, kind, from_chains=from_chains,
                    to_chains=job.granted_chains, tick=self.tick_count)

    def _maybe_pa_shrink(self, shard: EngineShard, job: ActiveJob,
                         fx_job: np.ndarray) -> None:
        """Population-annealing self-driven width controller.

        At a macro-tick boundary, estimate the effective sample size of
        the job's population under the *next* level transition's
        Boltzmann reweighting — ``job.T`` has already advanced, so the
        increment is ``1/(T·rho) − 1/T`` — and halve the slot footprint
        when ``ESS/width`` falls below the request's ``pa_ess_ratio``: a
        concentrated population doesn't need its lanes, and the freed
        slots go back to admission.  Purely a function of the job's own
        (bit-exact) fx stream and float64 host math, so a standalone
        replay re-derives every one of these shrinks at the same levels.
        """
        req = job.req
        if req.method != "pa" or len(job.slots) <= 1:
            return
        db = _pa_dbeta(job.T, req.rho)
        w = np.exp(-db * (fx_job.astype(np.float64) - float(fx_job.min())))
        ess = float(w.sum()) ** 2 / float((w * w).sum())
        if ess / fx_job.shape[0] < req.pa_ess_ratio:
            self._shrink_job(shard, job.rid, max(1, len(job.slots) // 2),
                             self_driven=True)

    def _shrink_job(self, shard: EngineShard, rid: int,
                    keep_slots: int, self_driven: bool = False) -> None:
        """Proactive degrade in place: checkpoint, drop the tail blocks,
        restore ``keep_slots`` blocks on the same shard.  Surviving
        chains keep logical indices [0, keep_slots * cps) — their
        trajectories (and the job's best-so-far champion) are untouched;
        only the width schedule changes, which a standalone replay of the
        same schedule reproduces bit-exactly (``run_standalone``)."""
        job = shard.rids.jobs[rid]
        if not 0 < keep_slots < len(job.slots):
            raise ValueError(
                f"keep_slots must be in [1, {len(job.slots) - 1}], "
                f"got {keep_slots}")
        from_chains = job.granted_chains
        blocks = shard.pool.checkpoint(rid)[:keep_slots]
        shard.pool.release(rid)
        job.slots = shard.pool.restore(rid, blocks)
        self._record_shrink(job, from_chains, self_driven=self_driven)

    def _shrink_migrate(self, src: EngineShard, rid: int, dst: EngineShard,
                        keep_slots: int) -> None:
        """Drain pressure valve: shrink and migrate in one checkpoint —
        restore only the first ``keep_slots`` blocks on ``dst``."""
        job = src.rids.jobs[rid]
        from_chains = job.granted_chains
        blocks = src.pool.checkpoint(rid)[:keep_slots]
        src.pool.release(rid)
        src.rids.free(rid)
        dst.rids.alloc(job)
        job.slots = dst.pool.restore(job.rid, blocks)
        job.home_shard = dst.index
        job.migrated_ticks.append(self.tick_count)
        self.migrations += 1
        self._record_shrink(job, from_chains)

    # -------------------------------------------- completion-deadline SLO
    def _truncate_job(self, job: ActiveJob, to_levels: int) -> None:
        """Ladder truncation in place: cut the job's remaining temperature
        levels so it finishes by its ``finish_deadline``.  Nothing about
        the chain state, RNG streams or any level's arithmetic changes —
        only where the ladder *ends* — so the trajectory up to the new end
        is prefix-exact with the untruncated run, and a standalone replay
        of the recorded ``truncate_events`` reproduces the terminal
        champion bit-exactly (``run_standalone(truncate_schedule=...)``).
        """
        limit = self._levels_limit(job)
        to_levels = int(to_levels)
        floor = max(int(job.req.min_levels), min(job.level, limit))
        to_levels = max(to_levels, floor)     # never below the SLO floor
        if to_levels >= limit:
            return                            # nothing to cut
        job.truncated_ticks.append(self.tick_count)
        job.truncate_events.append((job.level, limit, to_levels))
        job.levels_limit = to_levels
        self.truncations += 1
        tel = self.telemetry
        if tel.enabled:
            tel.decision(self.tick_count, "truncate",
                         req_id=job.req.req_id, shard=job.home_shard,
                         level=job.level, from_levels=limit,
                         to_levels=to_levels)
            if tel.trace is not None:
                tel.trace.request_instant(
                    job.req.req_id, "truncate", from_levels=limit,
                    to_levels=to_levels, tick=self.tick_count)

    def truncate_active(self, req_id: int, n_levels: int) -> bool:
        """Shorten the running request ``req_id``'s ladder to ``n_levels``
        total temperature levels — the operator/replay entry point for
        finish-deadline degrade; the scheduler's ``plan_truncations``
        drives the same path.  Clamped to the request's ``min_levels``
        floor.  Returns False if the request is not active or the cut
        would not shorten anything (already at/below that length)."""
        for _shard, job in self._iter_jobs():
            if job.req.req_id == req_id:
                before = self._levels_limit(job)
                self._truncate_job(job, n_levels)
                return self._levels_limit(job) < before
        return False

    def _plan_truncations(self) -> None:
        """Apply this boundary's finish-deadline truncations (scheduler
        plans, engine executes — like every other planner)."""
        views = [self._view(s) for s in self.shards]
        with self._pt("schedule"):
            plan = self.scheduler.plan_truncations(views, self.tick_count)
        with self._pt("admit"):
            for rid, si, to_levels in plan:
                self._truncate_job(self._shard(si).rids.jobs[rid],
                                   to_levels)

    def _evacuate_draining(self, budget: int) -> int:
        """Execute this tick's drain plan; returns actions performed."""
        with self._pt("schedule"):
            draining = [self._view(s) for s in self.shards if s.draining]
            survivors = [self._view(s) for s in self.live_shards]
            actions = self.scheduler.plan_evacuation(
                draining, survivors, self.cfg.chains_per_slot,
                self.tick_count, budget)
        with self._pt("admit"):
            for kind, rid, src, dst, width in actions:
                if kind == "migrate":
                    self._migrate_job(self._shard(src), rid,
                                      self._shard(dst))
                elif kind == "shrink":
                    self._shrink_migrate(self._shard(src), rid,
                                         self._shard(dst), width)
                else:
                    self._swap_out(self._shard(src), rid)
        return len(actions)

    def _retire_drained(self) -> None:
        """Remove empty draining shards from the fleet (their index is
        never reused; ``retired_shards`` records index and tick).  A
        retired shard's telemetry series survive it: per-shard metrics
        are labelled by the stable index in the registry, which is never
        pruned."""
        for shard in [s for s in self.shards
                      if s.draining and not s.rids.jobs]:
            self.shards.remove(shard)
            self.retired_shards.append((shard.index, self.tick_count))
            self.telemetry.decision(self.tick_count, "shard_retired",
                                    shard=shard.index)

    def drain(self, shard_index: int) -> None:
        """Begin draining shard ``shard_index`` for retirement.

        The shard takes no new placements; each tick its jobs are
        checkpoint-evacuated onto the surviving shards (bounded by
        ``migration_budget`` actions per tick, highest effective
        priority first — migrated whole when a survivor has room,
        shrunk into the roomiest survivor when degrade-eligible, swapped
        to the queue as the last resort) and it is retired — removed
        from the fleet — once empty.  Idempotent; raises if it would
        leave no live shard.  Every evacuated trajectory stays bit-exact
        (see docs/serving.md).
        """
        shard = self._shard(shard_index)
        if shard.draining:
            return
        if len(self.live_shards) <= 1:
            raise ValueError(
                "cannot drain the last live shard; resize up first")
        shard.draining = True
        self.telemetry.decision(self.tick_count, "drain", shard=shard_index,
                                resident_jobs=len(shard.rids.jobs))
        if not shard.rids.jobs:
            self._retire_drained()

    def add_shards(self, n: int) -> List[int]:
        """Grow the fleet by ``n`` fresh shards (``n_slots`` slots each,
        devices round-robin); returns their (new, never-reused) indices."""
        if n < 0:
            raise ValueError(f"n must be >= 0, got {n}")
        new = []
        for _ in range(n):
            idx = self._next_shard_index
            self._next_shard_index += 1
            self.shards.append(make_shard(
                idx, self.cfg.n_slots, self.cfg.chains_per_slot))
            new.append(idx)
            self.telemetry.decision(self.tick_count, "shard_added",
                                    shard=idx)
        return new

    def resize(self, n_devices: int) -> None:
        """Elastically resize the fleet to ``n_devices`` live shards.

        Growing first cancels in-progress drains (cheapest capacity:
        the shard is already populated), then adds fresh shards.
        Shrinking drains the emptiest live shards (fewest held slots,
        ties to the highest index) — they retire as evacuation
        completes, so the fleet passes through a transient
        ``n_live + n_draining`` state rather than dropping capacity
        instantaneously.
        """
        if n_devices < 1:
            raise ValueError(f"n_devices must be >= 1, got {n_devices}")
        live = self.live_shards
        if n_devices > len(live):
            grow = n_devices - len(live)
            for shard in sorted((s for s in self.shards if s.draining),
                                key=lambda s: s.index):
                if grow == 0:
                    break
                shard.draining = False      # cancel the drain: un-retire
                grow -= 1
            self.add_shards(grow)
        elif n_devices < len(live):
            doomed = sorted(live, key=lambda s: (s.pool.n_active, -s.index))
            for shard in doomed[:len(live) - n_devices]:
                self.drain(shard.index)

    def degrade_active(self, req_id: int, n_chains: int) -> bool:
        """Shrink the running request ``req_id`` to ``n_chains`` chains
        (rounded up to whole slots) — the operator/test entry point for
        proactive degrade at a chosen temperature level; the scheduler's
        ``plan_shrinks`` drives the same path.  Returns False if the
        request is not active, already at/below that width, or a
        parallel-tempering job (a PT job's width is its temperature-ladder
        resolution — truncating it mid-flight would change the method,
        not just the budget; the scheduler's planners skip PT too)."""
        slots_new = max(1, -(-n_chains // self.cfg.chains_per_slot))
        for shard, job in self._iter_jobs():
            if job.req.req_id == req_id:
                if slots_new >= len(job.slots) or job.req.method == "pt":
                    return False
                self._shrink_job(shard, job.rid, slots_new)
                return True
        return False

    def attach_controller(self, controller) -> None:
        """Attach a closed-loop controller (service/autoscaler.py): an
        object with ``maybe_sample(engine)`` — called at the top of every
        tick, before admission — and a ``next_sample_tick`` attribute so
        ``run_stream``'s idle fast-forward never leaps over a scheduled
        sampling tick (controller decisions are tick-aligned like
        scripted ops)."""
        self.controller = controller

    def schedule_op(self, tick: int, fn) -> None:
        """Run ``fn()`` at the start of the first tick >= ``tick`` —
        the hook ``serve_sa --drain-at/--resize`` uses to script fleet
        changes onto the deterministic tick axis."""
        self._ops.append((int(tick), self._op_seq, fn))
        self._op_seq += 1
        self._ops.sort(key=lambda op: op[:2])

    @property
    def _next_op_tick(self) -> float:
        return self._ops[0][0] if self._ops else float("inf")

    def _run_due_ops(self) -> None:
        while self._ops and self._ops[0][0] <= self.tick_count:
            _, _, fn = self._ops.pop(0)
            fn()

    def _reject(self, entry: QueueEntry) -> None:
        """SLO fast-fail: terminal 'rejected' result, no solution."""
        req = entry.req
        arrival, submit_wall = self._submit_info.pop(
            req.req_id, (float(entry.submit_tick), float("nan")))
        self.results.append(RequestResult(
            req_id=req.req_id, objective=req.objective, dim=req.dim,
            x_best=None, f_best=float("inf"), levels_run=0, n_evals=0,
            submit_tick=entry.submit_tick, start_tick=-1,
            finish_tick=self.tick_count, finish_reason="rejected",
            arrival_time=arrival, submit_wall=submit_wall,
            finish_wall=self._now(), requested_chains=req.n_chains,
            granted_chains=0, home_shard=-1))
        self.rejections += 1
        tel = self.telemetry
        if tel.enabled:
            tel.decision(self.tick_count, "reject", req_id=req.req_id,
                         waited=self.tick_count - entry.submit_tick)
            if tel.trace is not None:
                tel.trace.request_end(req.req_id, reason="rejected",
                                      tick=self.tick_count)

    # ---------------------------------------------------------------- tick
    def tick(self) -> None:
        """Admit, then advance every active slot by ``macro_k`` temperature
        levels in one fused dispatch per group (one level when K=1).

        Two passes over the shards: *launch* every ``(shard, dim, N)``
        group's device program first (JAX dispatch is asynchronous, so
        programs on different devices execute concurrently), then
        *collect* — materialize results on host, scatter blocks back and
        retire finished requests.  Collecting inline per group would
        serialize the shards: ``np.asarray`` blocks on the transfer, and
        device k+1 would not launch until device k had fully finished.

        Macro-ticks (K>1): the top of a tick is a **macro-tick boundary**
        — scripted ops, admission, preemption, migration and rebalancing
        all land here, then every group runs K ladder levels on device
        with per-level champion exchange (``_group_tick_fused``) before
        the next boundary.  ``tick_count`` stays on the *ladder-level*
        clock: an active macro-tick advances it by the most levels any
        job consumed (K mid-flight, less only when every job terminated
        inside the macro-tick; 1 per idle tick), so arrival timestamps,
        queue-delay and lifecycle latencies keep level units at any K.

        With telemetry enabled, each phase of the tick runs under a
        monotonic span (``schedule / admit / dispatch / device_wait /
        materialize / retire``), and an explicit ``block_until_ready``
        fence per shard separates host-side launch cost (``dispatch``)
        from device compute (``device_wait``) — at K>1 the fence simply
        covers the whole fused K-level program.  The fence changes *when*
        the host observes completion, never what was computed: the
        launch-all-then-collect order is preserved, so telemetry is
        bit-exact (tests assert it).
        """
        pt = self._pt
        self._run_due_ops()       # scripted drain/resize land tick-aligned
        if self.controller is not None:
            # Closed-loop control: the controller samples fleet signals
            # and may resize()/schedule_op() before this tick's admission
            # sees the fleet, so capacity changes land boundary-aligned
            # exactly like scripted ops.
            with self._pt("schedule"):
                self.controller.maybe_sample(self)
        for shard in self.shards:
            shard.resident_ticks += 1
            self.slot_ticks += shard.pool.n_slots
        self._admit()
        self._plan_truncations()  # finish-deadline cuts, boundary-aligned
        if self.n_active == 0:
            self._retire_drained()
            self._end_tick_telemetry()
            self.tick_count += 1
            return
        K = self.cfg.macro_k
        launches = []
        for shard in self.shards:
            # Dispatch groups are keyed by shape alone — (family, dim, N)
            # — because the objective id (or QAP instance operand) is a
            # runtime kernel input; mixed-objective groups share one
            # compiled program, and one program per *family* serves every
            # instance of that family.  Groups never span shards: each
            # runs on the shard's own device.
            groups: Dict[Tuple[str, int, int], List[ActiveJob]] = \
                defaultdict(list)
            for job in shard.rids.jobs.values():
                groups[(job.req.family, job.req.dim, job.req.N)].append(job)
            with pt("dispatch", shard.index):
                for (family, dim, n_steps), jobs in sorted(groups.items()):
                    launches.append(
                        self._launch_group(shard, family, dim, n_steps, jobs)
                        if K == 1 else
                        self._launch_group_fused(shard, family, dim,
                                                 n_steps, jobs))
                    self.group_launches += 1
        if self.telemetry.enabled:
            self.telemetry.m_launches.inc(len(launches))
            # Fence: wait for each shard's device arrays so device compute
            # lands in its own span instead of smearing into the first
            # np.asarray of the collect pass.  All programs are already
            # in flight, so waiting shard-by-shard keeps the overlap.
            for launch in launches:
                with pt("device_wait", launch[0].index):
                    jax.block_until_ready(launch[4])
        finished = []
        advance = 1
        for launch in launches:
            with pt("materialize", launch[0].index):
                if K == 1:
                    finished.extend(self._collect_group(*launch))
                else:
                    got, levels = self._collect_group_fused(*launch)
                    finished.extend(got)
                    advance = max(advance, levels)
        if advance > 1:
            # The macro-tick held the fleet's slots for `advance` ladder
            # levels (admission waits for the next boundary), so occupancy
            # bills that many slot-ticks per slot — `advance` is the max
            # levels any job actually consumed, < K only when every job
            # terminated inside this macro-tick (the clock must not run
            # past the last level anyone swept, or goodput/occupancy
            # denominators would drift off the K=1 axis).
            for shard in self.shards:
                shard.resident_ticks += advance - 1
                self.slot_ticks += shard.pool.n_slots * (advance - 1)
        with pt("retire"):
            for shard, job, reason, finish_tick in finished:
                self._retire(shard, job, reason, finish_tick=finish_tick)
        # A draining shard whose last job just retired (or evacuated) is
        # removed now, so a run that ends this tick leaves no zombie
        # shards behind.
        self._retire_drained()
        self._end_tick_telemetry(levels=advance)
        self.tick_count += advance

    def _end_tick_telemetry(self, levels: int = 1) -> None:
        """Drain this tick's spans into the registry / trace (no-op when
        telemetry is off — the null timer drains empty).  ``levels`` is
        the ladder-level advance of this tick (K for an active macro-tick)
        so the tick counter metric stays on the level clock."""
        tel = self.telemetry
        if not tel.enabled:
            return
        acc, shard_acc, raw, cpu = self._pt.drain()
        for (shard_idx, phase), secs in shard_acc.items():
            shard = next((s for s in self.shards if s.index == shard_idx),
                         None)
            if shard is not None:
                shard.phase_seconds[phase] = \
                    shard.phase_seconds.get(phase, 0.0) + secs
        tel.end_tick(self.tick_count, acc, shard_acc, raw, self.shards,
                     len(self.scheduler), self.n_active, levels=levels,
                     cpu=cpu)

    def _collect_group(self, shard: EngineShard, n_steps: int,
                       jobs: List[ActiveJob], slot_list, outs):
        """Materialize one group's results and advance its jobs one level;
        returns the finished ``(shard, job, reason, finish_tick)`` tuples
        for the caller's retire pass (slot frees can wait: admission
        happens at the top of the next tick, so deferring the release is
        equivalent)."""
        cps = self.cfg.chains_per_slot
        tel = self.telemetry
        x2, xb, fb = (np.asarray(outs[0]), np.asarray(outs[2]),
                      np.asarray(outs[3]))
        fxh = (np.asarray(outs[1])
               if any(j.req.pa_ess_ratio > 0 for j in jobs) else None)
        for b, (s, job) in enumerate(slot_list):
            # Copy: a bare slice would alias (and pin) the whole padded buffer.
            shard.pool.set_block(s, x2[b * cps:(b + 1) * cps].copy())
        finished = []
        row0 = 0
        for job in jobs:
            rows = slice(row0, row0 + job.granted_chains)
            row0 += job.granted_chains
            f = float(fb[job.rid])
            if f < job.best_f:
                job.best_f = f
                job.best_x = xb[job.rid].copy()
            if job.first_tick < 0:
                job.first_tick = self.tick_count
                job.first_tick_wall = self._now()
            self.sweeps_done += len(job.slots)
            shard.sweeps_done += len(job.slots)
            job.level += 1
            job.steps_done += n_steps
            job.evals += n_steps * job.granted_chains
            job.T *= job.req.rho
            job.history.append(job.best_f)       # champion trajectory/level
            if tel.enabled:
                tel.tenant_slot_ticks(job.req.req_id, len(job.slots))
            reason = self._finish_reason(job)
            if reason is not None:
                finished.append((shard, job, reason, self.tick_count))
            elif fxh is not None:
                self._maybe_pa_shrink(shard, job, fxh[rows])
        return finished

    def _collect_group_fused(self, shard: EngineShard, n_steps: int,
                             jobs: List[ActiveJob], slot_list, outs,
                             planned: Dict[int, int]):
        """Fold one fused macro-tick's results on host.

        Only the per-level champion stacks transfer to host (small); chain
        state stays device-resident — the pool already holds refs into
        ``outs[0]`` (set at launch).  Each job's levels are counted
        exactly as K=1 collects would: fold champion, advance the cursors,
        append history, check the finish reason — stopping at the first
        terminal level.  A target stop mid-macro-tick therefore truncates
        the job identically to the K=1 engine; the extra device levels it
        already swept are discarded with its slots at retire.  Budget and
        ladder stops cannot fire early: the launch planned at most that
        many levels.  ``finish_tick`` is the ladder-level clock value of
        the finishing level — boundary + counted − 1 — so lifecycle
        latencies keep level units at any K.

        Returns ``(finished, max_counted)``: the terminal tuples plus the
        most levels any job in this group consumed — the caller advances
        the tick clock by the fleet-wide max, keeping ``tick_count`` equal
        to the K=1 engine's at every boundary.
        """
        tel = self.telemetry
        boundary = self.tick_count
        fb_all = np.asarray(outs[2])    # (K, num_segments) champion values
        xb_all = np.asarray(outs[3])    # (K, num_segments, dim) champions
        fxh = (np.asarray(outs[1])      # last-live-level post-exchange fx
               if any(j.req.pa_ess_ratio > 0 for j in jobs) else None)
        finished = []
        max_counted = 1
        row0 = 0
        for job in jobs:
            rows = slice(row0, row0 + job.granted_chains)
            row0 += job.granted_chains
            if job.first_tick < 0:
                job.first_tick = boundary
                job.first_tick_wall = self._now()
            counted = 0
            reason = None
            for i in range(planned[job.rid]):
                f = float(fb_all[i, job.rid])
                if f < job.best_f:
                    job.best_f = f
                    job.best_x = xb_all[i, job.rid].copy()
                counted += 1
                self.sweeps_done += len(job.slots)
                shard.sweeps_done += len(job.slots)
                job.level += 1
                job.steps_done += n_steps
                job.evals += n_steps * job.granted_chains
                job.T *= job.req.rho
                job.history.append(job.best_f)   # champion trajectory/level
                if tel.enabled:
                    tel.tenant_slot_ticks(job.req.req_id, len(job.slots))
                reason = self._finish_reason(job)
                if reason is not None:
                    break
            max_counted = max(max_counted, counted)
            if reason is not None:
                finished.append((shard, job, reason, boundary + counted - 1))
            elif fxh is not None:
                self._maybe_pa_shrink(shard, job, fxh[rows])
        return finished, max_counted

    def _pack_class_controls(self, jobs: List[ActiveJob], n_padded: int,
                             n_parities: int):
        """Per-chain workload-class arrays for one packed group.

        A request's chains are contiguous in the packed buffer in logical
        chain order (``slot_list`` enumerates each job's slots in grant
        order), so PT partner rows and PA segment ranges are just offsets
        from the job's first packed row.  Defaults are the identity for
        every stage of the composite exchange: plain code, self-partner,
        self-range — pad blocks and plain-SA tenants pass through bitwise
        untouched.  ``n_parities`` rows of partners are built (1 for the
        K=1 path, 2 for the fused path's even/odd alternation); row ``j``
        holds each chain's partner at the parity of its own job's
        ``level + j``.
        """
        cps = self.cfg.chains_per_slot
        nc = n_padded * cps
        rows = np.arange(nc, dtype=np.int32)
        mcode = np.zeros((nc,), np.int8)
        t_rung = np.ones((nc,), np.float32)
        partner = np.tile(rows, (n_parities, 1))
        pairlo = np.zeros((n_parities, nc), np.uint32)
        seg_lo = rows.copy()
        seg_hi = rows + 1
        row0 = 0
        for job in jobs:
            n = job.granted_chains
            mcode[row0:row0 + n] = _job_mcode(job.req)
            if job.req.method == "pt":
                t_rung[row0:row0 + n] = job.req.pt_rungs(n)
                for j in range(n_parities):
                    prt, plo = _pt_partners(n, (job.level + j) % 2)
                    partner[j, row0:row0 + n] = row0 + prt
                    pairlo[j, row0:row0 + n] = plo
            elif job.req.method == "pa":
                seg_lo[row0:row0 + n] = row0
                seg_hi[row0:row0 + n] = row0 + n
            row0 += n
        return mcode, t_rung, partner, pairlo, seg_lo, seg_hi

    def _launch_group_fused(self, shard: EngineShard, family: str, dim: int,
                            n_steps: int, jobs: List[ActiveJob]):
        """Pack the group's controls, reuse (or rebuild) its device state
        buffer, and launch one fused K-level program (async).

        ``family`` picks the device program and the packing details: the
        continuous Metropolis program takes per-block objective ids and PA
        increments; the permutation (QAP) program takes per-block
        flow/distance operands and int32 chain state.  Everything else —
        level planning, control layout, the double buffer, the collect
        contract — is family-agnostic.

        Per-job level planning: ``min(K, remaining ladder, remaining eval
        budget)`` — computed on host so budget/ladder finishes land on
        exactly the K=1 level, never overshooting.  Temperatures for the
        K levels are iterated in float64 on host (``t *= rho``, matching
        the K=1 cursor update) and threaded as a ``(K, n_blocks)`` SMEM
        array.

        The double buffer: if every slot of the group still references
        this group's cached output buffer at its packed rows — membership,
        order and content unchanged since the last boundary — the host
        repack and transfer of chain state are skipped entirely and the
        cached buffer is donated straight back to the device.  Any
        checkpoint/migrate/shrink/retire in between breaks the signature
        and falls back to a host repack (get_block materializes refs on
        demand).
        """
        cps = self.cfg.chains_per_slot
        K = self.cfg.macro_k
        is_qap = family == fam_mod.FAMILY_PERMUTATION
        slot_list: List[Tuple[int, ActiveJob]] = [
            (s, job) for job in jobs for s in job.slots]
        n_blocks = len(slot_list)
        n_padded = 1
        while n_padded < n_blocks:
            n_padded *= 2

        planned: Dict[int, int] = {}
        for job in jobs:
            p = min(K, max(1, self._levels_limit(job) - job.level))
            if job.req.max_evals is not None:
                per_level = max(1, n_steps * job.granted_chains)
                remaining = job.req.max_evals - job.evals
                p = min(p, max(1, -(-remaining // per_level)))
            planned[job.rid] = p

        kid_blk = np.empty((n_padded,), np.int32)
        if is_qap:
            # Per-block instance operands, packed (n_padded * dim, dim):
            # block b reads rows [b*dim, (b+1)*dim).  Runtime inputs, so
            # mixed instances co-batch without recompiling.
            F_blk = np.empty((n_padded * dim, dim), np.float32)
            D_blk = np.empty((n_padded * dim, dim), np.float32)
        T_lvls = np.empty((K, n_padded), np.float32)
        dbeta_lvls = np.zeros((K, n_padded), np.float32)
        seed_blk = np.empty((n_padded,), np.uint32)
        step0_blk = np.empty((n_padded,), np.uint32)
        base_blk = np.empty((n_padded,), np.uint32)
        levels_blk = np.empty((n_padded,), np.int32)
        lvl0_blk = np.zeros((n_padded,), np.uint32)
        seg = np.empty((n_padded * cps,), np.int32)
        adopt = np.empty((n_padded * cps,), bool)
        for b, (s, job) in enumerate(slot_list):
            kid_blk[b] = np.int32(job.req.kid)
            if is_qap:
                inst = job.req.instance
                F_blk[b * dim:(b + 1) * dim] = inst.F
                D_blk[b * dim:(b + 1) * dim] = inst.D
            is_pa = job.req.method == "pa"
            t = job.T
            for i in range(K):
                # float64 iteration, f32 per level — identical to K=1's
                # pack-then-advance of the float ``job.T`` cursor.
                T_lvls[i, b] = t
                if is_pa:
                    dbeta_lvls[i, b] = _pa_dbeta(t, job.req.rho)
                t *= job.req.rho
            seed_blk[b] = np.uint32(job.req.seed)
            step0_blk[b] = np.uint32(job.steps_done)
            base_blk[b] = shard.pool.chain_base[s]
            levels_blk[b] = planned[job.rid]
            lvl0_blk[b] = np.uint32(job.level)
            seg[b * cps:(b + 1) * cps] = job.rid
            adopt[b * cps:(b + 1) * cps] = (job.req.method == "sa"
                                            and job.req.exchange == "sync")
        for b in range(n_blocks, n_padded):
            # Pad blocks are *dead* (zero planned levels): pure
            # pass-through, so whatever a reused buffer holds in its pad
            # rows is legal — they cost lanes, not correctness.
            kid_blk[b] = kid_blk[0]
            if is_qap:
                F_blk[b * dim:(b + 1) * dim] = F_blk[:dim]
                D_blk[b * dim:(b + 1) * dim] = D_blk[:dim]
            T_lvls[:, b] = T_lvls[:, 0]
            seed_blk[b] = seed_blk[0]
            step0_blk[b] = step0_blk[0]
            base_blk[b] = base_blk[0]
            levels_blk[b] = 0
            seg[b * cps:(b + 1) * cps] = self.cfg.n_slots
            adopt[b * cps:(b + 1) * cps] = False
        mcode, t_rung, partner2, pairlo2, seg_lo, seg_hi = \
            self._pack_class_controls(jobs, n_padded, 2)

        dev = shard.device

        cache = shard.group_cache.get((family, dim, n_steps))
        x_dev = None
        if cache is not None and cache["n_padded"] == n_padded:
            buf = cache["buf"]
            for b, (s, _job) in enumerate(slot_list):
                ref = shard.pool.device_ref(s)
                if ref is None or ref.buf is not buf or ref.start != b * cps:
                    break
            else:
                x_dev = buf              # cache hit: skip repack + transfer
        if x_dev is None:
            x = np.empty((n_padded * cps, dim),
                         np.int32 if is_qap else np.float32)
            for b, (s, _job) in enumerate(slot_list):
                x[b * cps:(b + 1) * cps] = shard.pool.get_block(s)
            for b in range(n_blocks, n_padded):
                x[b * cps:(b + 1) * cps] = x[:cps]
            x_dev = jax.device_put(x, dev)

        # One batched transfer for all control arrays: separate
        # device_put dispatches were the dominant per-launch host cost
        # once the state buffer started cache-hitting.
        if is_qap:
            ctrl = jax.device_put(
                (F_blk, D_blk, T_lvls, seed_blk, step0_blk, base_blk,
                 levels_blk, lvl0_blk, seg, adopt, mcode, t_rung, partner2,
                 pairlo2, seg_lo, seg_hi), dev)
            outs = _group_tick_qap_fused(
                x_dev, *ctrl,
                k=K, n_steps=n_steps, blk=cps,
                use_pallas=self._use_pallas, interpret=self.cfg.interpret,
                num_segments=self.cfg.n_slots + 1)
        else:
            ctrl = jax.device_put(
                (kid_blk, T_lvls, seed_blk, step0_blk, base_blk, levels_blk,
                 lvl0_blk, dbeta_lvls, seg, adopt, mcode, t_rung, partner2,
                 pairlo2, seg_lo, seg_hi), dev)
            outs = _group_tick_fused(
                x_dev, *ctrl,
                k=K, n_steps=n_steps, blk=cps, variant=self.cfg.variant,
                use_pallas=self._use_pallas, interpret=self.cfg.interpret,
                num_segments=self.cfg.n_slots + 1)
        out_x = outs[0]
        # The group's state now lives in the output buffer.  Point every
        # slot there (lazily — materialized only by checkpoint/migrate/
        # shrink or a cache-miss repack) and arm the double buffer for the
        # next boundary.  The donated input has no readers left: every
        # ref into it was just replaced.
        for b, (s, _job) in enumerate(slot_list):
            shard.pool.set_device_block(s, out_x, b * cps, (b + 1) * cps)
        shard.group_cache[(family, dim, n_steps)] = {"buf": out_x,
                                                     "n_padded": n_padded}
        return shard, n_steps, jobs, slot_list, outs, planned

    def _launch_group(self, shard: EngineShard, family: str, dim: int,
                      n_steps: int, jobs: List[ActiveJob]):
        """Pack the group's slots and launch its device program (async);
        returns the collect-pass arguments.  ``family`` picks the program
        (Metropolis vs QAP pairwise-exchange) and the state dtype; see
        :meth:`_launch_group_fused`."""
        cps = self.cfg.chains_per_slot
        is_qap = family == fam_mod.FAMILY_PERMUTATION
        slot_list: List[Tuple[int, ActiveJob]] = [
            (s, job) for job in jobs for s in job.slots]
        n_blocks = len(slot_list)
        # Pad to a power of two of blocks so the number of compiled
        # signatures per (family, dim, N) is O(log n_slots), not
        # O(n_slots).
        n_padded = 1
        while n_padded < n_blocks:
            n_padded *= 2

        x = np.empty((n_padded * cps, dim),
                     np.int32 if is_qap else np.float32)
        kid_blk = np.empty((n_padded,), np.int32)
        if is_qap:
            F_blk = np.empty((n_padded * dim, dim), np.float32)
            D_blk = np.empty((n_padded * dim, dim), np.float32)
        T_blk = np.empty((n_padded,), np.float32)
        dbeta_blk = np.zeros((n_padded,), np.float32)
        seed_blk = np.empty((n_padded,), np.uint32)
        step0_blk = np.empty((n_padded,), np.uint32)
        base_blk = np.empty((n_padded,), np.uint32)
        lvl0_blk = np.zeros((n_padded,), np.uint32)
        seg = np.empty((n_padded * cps,), np.int32)
        adopt = np.empty((n_padded * cps,), bool)
        for b, (s, job) in enumerate(slot_list):
            x[b * cps:(b + 1) * cps] = shard.pool.get_block(s)
            kid_blk[b] = np.int32(job.req.kid)
            if is_qap:
                inst = job.req.instance
                F_blk[b * dim:(b + 1) * dim] = inst.F
                D_blk[b * dim:(b + 1) * dim] = inst.D
            T_blk[b] = job.T
            if job.req.method == "pa":
                dbeta_blk[b] = _pa_dbeta(job.T, job.req.rho)
            seed_blk[b] = np.uint32(job.req.seed)
            step0_blk[b] = np.uint32(job.steps_done)
            base_blk[b] = shard.pool.chain_base[s]
            lvl0_blk[b] = np.uint32(job.level)
            seg[b * cps:(b + 1) * cps] = job.rid
            adopt[b * cps:(b + 1) * cps] = (job.req.method == "sa"
                                            and job.req.exchange == "sync")
        # Dummy pad blocks: replicate block 0, claim the reserved segment
        # n_slots, never adopt. They cost lanes, not correctness.
        for b in range(n_blocks, n_padded):
            x[b * cps:(b + 1) * cps] = x[:cps]
            kid_blk[b] = kid_blk[0]
            if is_qap:
                F_blk[b * dim:(b + 1) * dim] = F_blk[:dim]
                D_blk[b * dim:(b + 1) * dim] = D_blk[:dim]
            T_blk[b] = T_blk[0]
            seed_blk[b] = seed_blk[0]
            step0_blk[b] = step0_blk[0]
            base_blk[b] = base_blk[0]
            seg[b * cps:(b + 1) * cps] = self.cfg.n_slots
            adopt[b * cps:(b + 1) * cps] = False
        mcode, t_rung, partner, pairlo, seg_lo, seg_hi = \
            self._pack_class_controls(jobs, n_padded, 1)

        # Committed transfers pin the group's program to the shard's mesh
        # device.  The call returns device arrays without blocking; the
        # collect pass materializes them after every shard has launched.
        dev = shard.device

        def put(a):
            return jax.device_put(a, dev)

        if is_qap:
            outs = _group_tick_qap(
                put(x), put(F_blk), put(D_blk), put(T_blk), put(seed_blk),
                put(step0_blk), put(base_blk), put(lvl0_blk), put(seg),
                put(adopt), put(mcode), put(t_rung), put(partner[0]),
                put(pairlo[0]), put(seg_lo), put(seg_hi), n_steps=n_steps,
                blk=cps, use_pallas=self._use_pallas,
                interpret=self.cfg.interpret,
                num_segments=self.cfg.n_slots + 1)
        else:
            outs = _group_tick(
                put(x), put(kid_blk), put(T_blk), put(seed_blk),
                put(step0_blk), put(base_blk), put(lvl0_blk),
                put(dbeta_blk), put(seg), put(adopt), put(mcode),
                put(t_rung), put(partner[0]), put(pairlo[0]), put(seg_lo),
                put(seg_hi), n_steps=n_steps, blk=cps,
                variant=self.cfg.variant, use_pallas=self._use_pallas,
                interpret=self.cfg.interpret,
                num_segments=self.cfg.n_slots + 1)
        return shard, n_steps, jobs, slot_list, outs

    def _finish_reason(self, job: ActiveJob) -> Optional[str]:
        req = job.req
        if req.target_error is not None:
            # submit() guarantees the optimum exists; .get keeps the tick
            # loop un-wedgeable even if F_OPT is mutated under a live job.
            # Permutation requests read the instance's best_known instead.
            f_opt = (F_OPT.get(req.kid)
                     if req.family == fam_mod.FAMILY_CONTINUOUS
                     else req.f_opt)
            if f_opt is not None and job.best_f <= f_opt + req.target_error:
                return "target"
        if req.max_evals is not None and job.evals >= req.max_evals:
            return "budget"
        if job.level >= self._levels_limit(job):
            # 'truncated' only when the finish-deadline degrade actually
            # cut the ladder — a full-length finish stays 'ladder' even
            # for requests that carried a finish_deadline.
            return "truncated" if job.truncate_events else "ladder"
        return None

    @staticmethod
    def _levels_limit(job: ActiveJob) -> int:
        """The job's effective ladder length: ``levels_limit`` once placed
        (only ever cut, never below ``req.min_levels``), falling back to
        the request's full ladder for jobs that predate placement."""
        return job.levels_limit or job.req.n_levels

    def _retire(self, shard: EngineShard, job: ActiveJob, reason: str,
                finish_tick: Optional[int] = None) -> None:
        # finish_tick is on the ladder-level clock: the K=1 path passes
        # the current tick; the fused path passes boundary + counted - 1
        # (the level at which the finish reason actually fired).
        if finish_tick is None:
            finish_tick = self.tick_count
        self.results.append(RequestResult(
            req_id=job.req.req_id, objective=job.req.objective,
            dim=job.req.dim, x_best=job.best_x, f_best=job.best_f,
            levels_run=job.level, n_evals=job.evals,
            submit_tick=job.submit_tick, start_tick=job.start_tick,
            finish_tick=finish_tick, finish_reason=reason,
            arrival_time=job.arrival_time, first_tick=job.first_tick,
            submit_wall=job.submit_wall, admit_wall=job.admit_wall,
            first_tick_wall=job.first_tick_wall, finish_wall=self._now(),
            requested_chains=job.req.n_chains,
            granted_chains=job.granted_chains,
            preempted_ticks=list(job.preempted_ticks),
            resumed_ticks=list(job.resumed_ticks),
            champion_history=list(job.history),
            home_shard=job.home_shard,
            migrated_ticks=list(job.migrated_ticks),
            shrunk_ticks=list(job.shrunk_ticks),
            shrink_events=list(job.shrink_events),
            pa_shrink_events=list(job.pa_shrink_events),
            truncated_ticks=list(job.truncated_ticks),
            truncate_events=list(job.truncate_events)))
        shard.pool.release(job.rid)
        shard.rids.free(job.rid)
        tel = self.telemetry
        if tel.enabled:
            tel.decision(self.tick_count, "retire", req_id=job.req.req_id,
                         shard=shard.index, reason=reason, level=job.level,
                         best_f=job.best_f)
            if tel.trace is not None:
                tel.trace.request_end(job.req.req_id, reason=reason,
                                      tick=self.tick_count,
                                      levels=job.level, best_f=job.best_f)

    # ----------------------------------------------------------------- run
    def run(self, max_ticks: Optional[int] = None) -> List[RequestResult]:
        """Drive ticks until queue and pool drain (or ``max_ticks``).

        Closed-loop: serves whatever was already :meth:`submit`-ted — the
        degenerate open-loop run with an empty (exhausted) arrival stream.
        """
        from repro.service.arrivals import ArrivalProcess
        return self.run_stream(ArrivalProcess.batch([]), max_ticks=max_ticks)

    def run_stream(self, arrivals, max_ticks: Optional[int] = None
                   ) -> List[RequestResult]:
        """Open-loop serving: admit from an arrival process while ticking.

        ``arrivals`` is an :class:`~repro.service.arrivals.ArrivalProcess`
        (or anything with ``due(now)`` / ``exhausted``).  Each tick first
        submits every request whose arrival time has come due, then
        advances all in-flight work one temperature level; idle ticks (no
        active jobs, next arrival in the future) still advance the clock,
        so arrival timestamps stay on the tick axis.  Per-request
        lifecycle events (submit/admit/first-tick/complete) are stamped in
        both tick-time (deterministic under a fixed arrival seed) and
        wall-time — the latter on the engine's monotonic epoch, the same
        clock ``wall_s`` is measured on.
        """
        t0 = self._now()
        while True:
            if max_ticks is not None and self.tick_count >= max_ticks:
                break
            for t_arr, req in arrivals.due(self.tick_count):
                self.submit(req, arrival_time=t_arr)
            if self.done:
                if arrivals.exhausted:
                    break
                # Idle: fast-forward the clock to the next arrival instead
                # of spinning empty ticks (low offered load would otherwise
                # execute one no-op tick per time unit).  ceil() lands on
                # the first tick >= next_time — identical tick-axis
                # semantics to ticking through, since due(t) is <=-t.
                # Sources without next_time just tick through idle time.
                nxt = getattr(arrivals, "next_time", None)
                if nxt is not None and math.isfinite(nxt):
                    jump = int(math.ceil(nxt))
                    if max_ticks is not None:
                        jump = min(jump, max_ticks)
                    if self._ops:
                        # A scripted drain/resize must land on its exact
                        # tick, not be leapt over.
                        jump = min(jump, int(self._next_op_tick))
                    if self.controller is not None:
                        # Same for the controller's next sampling tick:
                        # idle gaps are exactly when scale-down decisions
                        # fire, so fast-forwarding past a sample would
                        # skip it (hysteresis windows would never elapse
                        # on a sparse trace).  A sample due now or earlier
                        # caps the jump at/below tick_count, falling
                        # through to tick() where the controller fires.
                        jump = min(jump,
                                   int(self.controller.next_sample_tick))
                    if jump > self.tick_count:
                        # Idle time still counts against occupancy: the
                        # fleet held its slots across the jumped ticks.
                        delta = jump - self.tick_count
                        for shard in self.shards:
                            shard.resident_ticks += delta
                            self.slot_ticks += delta * shard.pool.n_slots
                        self.tick_count = jump
                        continue
            self.tick()
        self.wall_s = self._now() - t0
        return self.results

    def stats(self) -> dict:
        wall = getattr(self, "wall_s", float("nan"))
        evals = sum(r.n_evals for r in self.results)

        def per_s(v):
            return v / wall if wall and wall > 0 else 0.0

        return {
            "ticks": self.tick_count,
            "devices": len(self.shards),
            "draining": sum(s.draining for s in self.shards),
            "shards_retired": len(self.retired_shards),
            "group_launches": self.group_launches,
            "submitted": self.n_submitted,
            "completed": sum(r.completed for r in self.results),
            "rejected": self.rejections,
            "preemptions": self.preemptions,
            "migrations": self.migrations,
            "shrinks": self.shrinks,
            "truncations": self.truncations,
            "sweeps": self.sweeps_done,
            # The fleet is elastic, so the occupancy denominator is the
            # accumulated slot-tick product, not ticks x a fixed slot
            # count (they agree exactly for a static fleet).
            "occupancy": self.sweeps_done / max(self.slot_ticks, 1),
            "shard_occupancy": [s.occupancy() for s in self.shards],
            "wall_s": wall,
            "requests_per_s": per_s(len(self.results)),
            "sweeps_per_s": per_s(self.sweeps_done),
            "chain_steps_per_s": per_s(evals),
            # Cumulative per-phase wall seconds (empty unless telemetry
            # was enabled): aggregate and per shard.
            "phases": self._phase_stats(),
        }

    def _phase_stats(self) -> dict:
        if not self.telemetry.enabled:
            return {}
        hist = self.telemetry.m_tick_phase
        agg = {phase: hist.summary(phase)
               for (phase,) in sorted(hist.series)}
        per_shard = {
            str(s.index): dict(sorted(s.phase_seconds.items()))
            for s in self.shards if s.phase_seconds}
        cpu = {phase: secs for (phase,), secs
               in sorted(self.telemetry.m_phase_cpu.series.items())}
        return {"aggregate": agg, "per_shard": per_shard,
                "cpu_seconds": cpu}


def run_standalone(req: SARequest, cfg: EngineConfig,
                   shrink_schedule=None,
                   truncate_schedule=None) -> RequestResult:
    """Serve ``req`` alone on a dedicated single-device pool — the
    per-tenant baseline.

    Placement-invariant RNG + segmented exchange make the packed engine
    produce the *same* trajectory as this single-tenant run (bit-exact
    champions for identical seeds) — on any home shard, across preemption
    and across cross-shard migration; tests assert it, serve_sa --check
    reports it.

    ``shrink_schedule`` replays proactive degrade: ``(level, n_chains)``
    pairs, applied in order once the job has completed ``level``
    temperature levels (``RequestResult.shrink_events`` records exactly
    this, as ``(level, from, to)``).  A job shrunk mid-flight by drain or
    overload pressure is bit-exact versus this standalone run of the
    same width schedule — the shrink itself (checkpoint, restore,
    placement, co-tenants) perturbs nothing; only the logical width
    trajectory matters.

    ``truncate_schedule`` replays finish-deadline ladder truncation the
    same way on the *level* axis: ``(level, n_levels)`` pairs, applied in
    order once the job has completed ``level`` temperature levels
    (``RequestResult.truncate_events`` records exactly this, as
    ``(level, from, to)``).  Truncation moves only where the ladder ends
    — no level's arithmetic changes — so the truncated run's champion is
    bit-exact with this replay (and prefix-exact with the untruncated
    run at every surviving level).

    The replay applies pending shrinks and truncations at macro-tick
    boundaries, so at ``cfg.macro_k > 1`` the schedules' levels must be
    K-aligned — which engine-recorded ``shrink_events`` and
    ``truncate_events`` always are, because the engine only cuts at
    boundaries and mid-flight jobs run exactly K levels per macro-tick.
    """
    alone = SAServeEngine(dataclasses.replace(
        cfg, n_slots=req.slots_needed(cfg.chains_per_slot), n_devices=1))
    alone.submit(req)
    if not shrink_schedule and not truncate_schedule:
        return alone.run()[0]
    pending = sorted((int(lvl), int(chains))
                     for lvl, chains in (shrink_schedule or ()))
    cuts = sorted((int(lvl), int(levels))
                  for lvl, levels in (truncate_schedule or ()))
    guard = 0
    while not alone.done:
        guard += 1
        assert guard < 100000, "standalone replay failed to drain"
        job = next((j for _, j in alone._iter_jobs()), None)
        while pending and job is not None and job.level >= pending[0][0]:
            alone.degrade_active(req.req_id, pending[0][1])
            pending.pop(0)
        while cuts and job is not None and job.level >= cuts[0][0]:
            alone.truncate_active(req.req_id, cuts[0][1])
            cuts.pop(0)
        alone.tick()
    return alone.results[0]
