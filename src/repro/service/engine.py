"""Continuous-batching SA serving engine.

The annealing analogue of a vLLM/LightLLM decode loop (launch/serve.py):

* a sharded pool of chain-block *slots* (slots.py, sharding.py) — the
  "decode batch", one shard per device on a 1-D ``(pool,)`` mesh;
* an admission scheduler (scheduler.py) packs queued requests into free
  slots — "prefill" — and places each request on a home shard;
* one engine **tick** advances every active slot by one temperature level
  (one N-step Metropolis sweep at that slot's own temperature, then a
  champion exchange masked per request);
* a request whose ladder / budget / accuracy target completes frees its
  slots *immediately* and the next queued request takes them — no tail
  latency from stragglers sharing the batch.

Invariants
----------
* **One tick = one temperature level** for every active slot; a request's
  temperature ladder position is exactly its count of ticks in residence.
* **kid is runtime**: per-slot *objective id, temperature, RNG seed, step
  cursor and chain base* are runtime arrays threaded down to the kernel
  (one SMEM entry per block, indexed by ``program_id``) — none of them can
  cause recompilation.  Only *dimensionality and sweep length* remain
  compile-time constants, so active slots are grouped by ``(dim, N)``
  within each shard every tick and dispatched as one device program per
  ``(shard, dim, N)`` group: one compiled sweep program per device serves
  every registry objective, and growing ``SERVABLE`` never costs a
  recompile.  (Groups are additionally padded to power-of-two block
  counts to bound the number of compiled shapes.)
* **Tenant isolation**: champion reduces inside a packed group are
  segmented by request id — tenants never exchange states
  (core/exchange.py) — and placement-invariant RNG makes a request's
  trajectory bit-identical to its standalone single-tenant run.
* **Sharded pool** (sharding.py): ``EngineConfig.n_devices`` engine
  shards each own ``n_slots`` slots on their own mesh device.  The
  scheduler's placement layer homes each admitted request on the
  least-loaded compatible shard and rebalances via Russkov-style
  migration — checkpoint a :class:`~repro.service.slots.SwappedJob` on
  the overloaded shard, restore it on an underloaded one — and because
  restore is placement-invariant, a migrated trajectory is **bit-exact**
  versus an uninterrupted single-device run.  Requests never span shards.
* **Open-loop serving**: :meth:`SAServeEngine.run_stream` interleaves
  admission of an :class:`~repro.service.arrivals.ArrivalProcess` (e.g.
  seeded Poisson) with in-flight progress, stamping per-request lifecycle
  events (submit / admit / first-tick / preempted / resumed /
  complete-or-rejected, in both tick-time and wall-time) from which
  queueing-delay and time-to-first-tick percentiles are derived (see
  docs/serving.md).  All wall times — lifecycle stamps and the run's
  ``wall_s`` alike — come from one monotonic epoch
  (``time.perf_counter`` since engine construction), so a wall-clock
  adjustment mid-run can never skew a latency or throughput figure.
* **Preemption is bit-exact**: an active job checkpoints to a host-side
  :class:`~repro.service.slots.SwappedJob` (slot blocks + champion + RNG
  step cursor + temperature cursor) and resumes — possibly on different
  physical slots of a different shard — with a trajectory identical to an
  uninterrupted run, because the RNG is counter-based on logical (chain
  index, step) coordinates.  SLO admission control (scheduler.py) builds
  on it: the 'preempt' overload policy evicts the cheapest active jobs
  for an urgent arrival, 'reject' and 'degrade' bound queue growth at
  overload.
"""
from __future__ import annotations

import dataclasses
import math
import time
from collections import defaultdict
from functools import partial
from typing import Dict, Iterator, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import exchange as exch
from repro.kernels import objective_math as om
from repro.kernels import ops
from repro.service.request import RequestResult, SARequest
from repro.service.scheduler import (AdmissionScheduler, QueueEntry,
                                     SchedulerConfig, ShardView)
from repro.service.sharding import EngineShard, make_shards
from repro.service.slots import ActiveJob, SwappedJob

#: Known optima of the servable (registry) objectives, for accuracy targets.
#: Schwefel is the paper's normalized form, so its optimum is dim-free.
#: A request may only set ``target_error`` on an objective listed here —
#: :meth:`SAServeEngine.submit` validates it eagerly (a typed ValueError at
#: the frontend) instead of letting a KeyError wedge a slot mid-tick.
F_OPT = {
    om.KID_SCHWEFEL: -418.982887,
    om.KID_RASTRIGIN: 0.0,
    om.KID_ACKLEY: 0.0,
    om.KID_GRIEWANK: 0.0,
}


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    n_slots: int = 8            # slots *per shard*
    chains_per_slot: int = 64   # chains per slot == kernel block size
    n_devices: int = 1          # engine shards on the 1-D (pool,) mesh;
                                # logical shards round-robin when fewer
                                # physical devices exist (sharding.py)
    variant: str = "delta"      # 'delta' (O(1) updates) | 'full' (paper)
    use_pallas: object = "auto"  # True | False | 'auto' (TPU only)
    interpret: bool = False     # Pallas interpret mode (tests on CPU)
    migration_budget: int = 1   # max cross-shard moves per tick (0 = no
                                # automatic rebalancing)
    scheduler: SchedulerConfig = dataclasses.field(
        default_factory=SchedulerConfig)

    def __post_init__(self):
        if self.n_devices < 1:
            raise ValueError(f"n_devices must be >= 1, got {self.n_devices}")
        if self.migration_budget < 0:
            raise ValueError("migration_budget must be >= 0")


@partial(jax.jit, static_argnames=("n_steps", "blk", "variant",
                                   "use_pallas", "interpret", "num_segments"))
def _group_tick(x, kid_blk, T_blk, seed_blk, step0_blk, base_blk, seg, adopt,
                *, n_steps: int, blk: int, variant: str,
                use_pallas: bool, interpret: bool, num_segments: int):
    """One temperature level for one dispatch group, on device.

    Sweep every block on its own objective (``kid_blk`` is a runtime
    input — mixed-objective groups share one lowering) at its own
    temperature, then a segmented champion reduce: chains adopt *their
    request's* champion iff their request runs sync exchange (``adopt``);
    the champion is returned for every segment either way so the host can
    fold best-so-far.
    """
    x, fx = ops.metropolis_sweep_slots(
        x, kid_blk, T_blk, seed_blk, step0_blk, base_blk, n_steps=n_steps,
        blk=blk, variant=variant, use_pallas=use_pallas, interpret=interpret)
    return exch.exchange_sync_segmented(x, fx, seg, num_segments,
                                        adopt_mask=adopt)


class SAServeEngine:
    """Multi-tenant annealing server: one device program per (shard, group)."""

    def __init__(self, cfg: Optional[EngineConfig] = None):
        # Build a fresh default per engine: a mutable-default-argument
        # EngineConfig() would be evaluated once and shared by every engine
        # constructed without a config (tests pin this down).
        cfg = EngineConfig() if cfg is None else cfg
        self.cfg = cfg
        self.shards: List[EngineShard] = make_shards(
            cfg.n_devices, cfg.n_slots, cfg.chains_per_slot)
        self.scheduler = AdmissionScheduler(cfg.scheduler)
        self.results: List[RequestResult] = []
        self.tick_count = 0
        self.n_submitted = 0          # requests offered via submit(): the
                                      # denominator for terminal accounting
        self.sweeps_done = 0          # block-sweeps (slot x level): also the
                                      # occupancy numerator (active slot-ticks)
        self.group_launches = 0
        self.preemptions = 0          # swap-outs performed
        self.rejections = 0           # SLO admission-control drops
        self.migrations = 0           # cross-shard rebalancing moves
        self._use_pallas = ops.resolve_use_pallas(cfg.use_pallas)
        if self._use_pallas and cfg.chains_per_slot % 8:
            raise ValueError(
                f"chains_per_slot={cfg.chains_per_slot} must be a multiple "
                "of 8 (TPU sublanes) on the Pallas path")
        self._epoch = time.perf_counter()
        #: req_id -> (arrival_time in ticks, submit wall time): lifecycle
        #: info that must survive the queue (the scheduler only keeps the
        #: submit tick).
        self._submit_info: Dict[int, Tuple[float, float]] = {}

    def _now(self) -> float:
        """Wall seconds since engine construction (the engine epoch).

        Monotonic (``time.perf_counter``): every wall-clock stamp the
        engine emits — lifecycle events *and* ``run_stream``'s ``wall_s``
        — shares this epoch, so intervals between them are meaningful and
        immune to wall-clock adjustments.
        """
        return time.perf_counter() - self._epoch

    # ------------------------------------------------------------ frontend
    def submit(self, req: SARequest, arrival_time: Optional[float] = None
               ) -> None:
        """Enqueue ``req``.  ``arrival_time`` (in ticks, may be fractional)
        is the offered-load timestamp for open-loop runs; it defaults to
        the submit tick (closed-loop batch submission)."""
        need = req.slots_needed(self.cfg.chains_per_slot)
        if need > self.cfg.n_slots:
            raise ValueError(
                f"request {req.req_id} needs {need} slots > the per-shard "
                f"pool of {self.cfg.n_slots}; requests never span shards — "
                "lower n_chains or grow n_slots")
        if req.target_error is not None and req.kid not in F_OPT:
            # Validate here, not mid-tick: an unguarded F_OPT lookup in the
            # finish check would raise KeyError after admission and wedge
            # the request's slots for good.
            raise ValueError(
                f"request {req.req_id} sets target_error but objective "
                f"{req.objective!r} has no registered optimum in "
                "engine.F_OPT; register one or drop target_error")
        if (req.req_id in self._submit_info
                or any(job.req.req_id == req.req_id
                       for _, job in self._iter_jobs())
                or any(r.req_id == req.req_id
                       for r in self.scheduler.pending)):
            raise ValueError(
                f"request id {req.req_id} is already queued, swapped out or "
                "in flight; req_ids must be unique among live requests")
        self._submit_info[req.req_id] = (
            float(self.tick_count if arrival_time is None else arrival_time),
            self._now())
        self.scheduler.submit(req, self.tick_count)
        self.n_submitted += 1

    # ----------------------------------------------------------- shard views
    def _iter_jobs(self) -> Iterator[Tuple[EngineShard, ActiveJob]]:
        for shard in self.shards:
            for job in shard.rids.jobs.values():
                yield shard, job

    def _view(self, shard: EngineShard) -> ShardView:
        jobs = tuple(shard.rids.jobs.values())
        return ShardView(
            index=shard.index, free_slots=shard.pool.n_free, active=jobs,
            shapes=frozenset((j.req.dim, j.req.N) for j in jobs))

    @property
    def pool(self):
        """Single-shard convenience alias (tests, notebooks).  Multi-shard
        engines have no 'the pool' — address ``engine.shards[i].pool``."""
        if len(self.shards) == 1:
            return self.shards[0].pool
        raise AttributeError(
            f"engine has {len(self.shards)} shards: use shards[i].pool")

    @property
    def rids(self):
        """Single-shard convenience alias, like :attr:`pool`."""
        if len(self.shards) == 1:
            return self.shards[0].rids
        raise AttributeError(
            f"engine has {len(self.shards)} shards: use shards[i].rids")

    @property
    def n_active(self) -> int:
        return sum(len(s.rids.jobs) for s in self.shards)

    @property
    def done(self) -> bool:
        return self.n_active == 0 and len(self.scheduler) == 0

    # ----------------------------------------------------------- admission
    def _admit(self) -> None:
        # Rebalance first: if the queue head fits on no single shard but
        # the pool as a whole has room, migrate jobs off a donor shard
        # (checkpoint/restore, bit-exact) so the head becomes admissible
        # this very tick.  Snapshots are built once and rebuilt only for
        # the (budget-bounded, usually zero) shards a move touched.
        views = [self._view(s) for s in self.shards]
        moves = self.scheduler.plan_migrations(
            views, self.cfg.chains_per_slot,
            self.tick_count, self.cfg.migration_budget)
        for rid, src, dst in moves:
            self._migrate_job(self.shards[src], rid, self.shards[dst])
        for si in {si for move in moves for si in move[1:]}:
            views[si] = self._view(self.shards[si])
        # Then one queue walk across all shards (scheduler.admit_sharded):
        # every entry, in effective-priority order, is tried at full
        # width on every shard — least-loaded first, (dim, N)-locality
        # tie-break — before its degrade/preempt fallback may fire, and
        # the preemption budget bounds evictions per tick across shards.
        plan = self.scheduler.admit_sharded(
            views, self.cfg.chains_per_slot, self.tick_count)
        # Execution order matters: rejections first (they free nothing
        # but must be stamped this tick), then evictions (freeing slots
        # the plan's admissions count on), then placements.
        for entry in plan.rejected:
            self._reject(entry)
        for rid, si in plan.evict:
            self._swap_out(self.shards[si], rid)
        for entry, granted_slots, si in plan.admitted:
            self._place(self.shards[si], entry, granted_slots)

    def _place(self, shard: EngineShard, entry: QueueEntry,
               granted_slots: int) -> None:
        if entry.swapped is not None:       # swap-in: bit-exact resume
            job = entry.swapped.job
            job.resumed_ticks.append(self.tick_count)
            shard.rids.alloc(job)
            job.slots = shard.pool.restore(job.rid, entry.swapped.blocks)
            job.home_shard = shard.index
            return
        req = entry.req
        arrival, submit_wall = self._submit_info.pop(
            req.req_id, (float(entry.submit_tick), float("nan")))
        job = ActiveJob(req=req, rid=-1, slots=[], T=req.T0,
                        submit_tick=entry.submit_tick,
                        start_tick=self.tick_count,
                        arrival_time=arrival,
                        submit_wall=submit_wall,
                        admit_wall=self._now(),
                        home_shard=shard.index)
        shard.rids.alloc(job)
        job.slots = shard.pool.assign(job.rid, req, n_slots=granted_slots)
        job.granted_chains = granted_slots * self.cfg.chains_per_slot

    def _swap_out(self, shard: EngineShard, rid: int) -> None:
        """Preempt: checkpoint a job's device-visible state to host, free
        its slots, and re-queue it for a bit-exact resume (on whichever
        shard next has room — swap-in doubles as migration)."""
        job = shard.rids.jobs[rid]
        blocks = shard.pool.checkpoint(rid)
        shard.pool.release(rid)
        shard.rids.free(rid)
        job.slots = []
        job.rid = -1
        job.preempted_ticks.append(self.tick_count)
        self.scheduler.requeue(SwappedJob(job=job, blocks=blocks))
        self.preemptions += 1

    def _migrate_job(self, src: EngineShard, rid: int,
                     dst: EngineShard) -> None:
        """Move a resident job between shards without a queue round-trip:
        checkpoint on ``src``, restore on ``dst`` in the same tick.  The
        job keeps annealing this tick (on its new device); the trajectory
        is bit-exact because restore is placement-invariant."""
        job = src.rids.jobs[rid]
        blocks = src.pool.checkpoint(rid)
        src.pool.release(rid)
        src.rids.free(rid)
        dst.rids.alloc(job)
        job.slots = dst.pool.restore(job.rid, blocks)
        job.home_shard = dst.index
        job.migrated_ticks.append(self.tick_count)
        self.migrations += 1

    def migrate(self, req_id: int, to_shard: int) -> bool:
        """Move the in-flight request ``req_id`` to shard ``to_shard``.

        The operator/test entry point for forcing a cross-shard move at a
        chosen temperature level (the scheduler's rebalancer calls the
        same checkpoint/restore path).  Returns False if the request is
        not active, already home, or the target shard lacks room.
        """
        if not 0 <= to_shard < len(self.shards):
            raise ValueError(
                f"to_shard {to_shard} out of range for "
                f"{len(self.shards)} shards")
        dst = self.shards[to_shard]
        for shard, job in self._iter_jobs():
            if job.req.req_id == req_id:
                if shard.index == to_shard \
                        or dst.pool.n_free < len(job.slots):
                    return False
                self._migrate_job(shard, job.rid, dst)
                return True
        return False

    def preempt(self, req_id: int) -> bool:
        """Swap out the in-flight request ``req_id`` (False if not active).

        The scheduler's 'preempt' overload policy calls the same swap-out
        path; this is the operator/test entry point for preempting at a
        chosen temperature level.
        """
        for shard, job in list(self._iter_jobs()):
            if job.req.req_id == req_id:
                self._swap_out(shard, job.rid)
                return True
        return False

    def _reject(self, entry: QueueEntry) -> None:
        """SLO fast-fail: terminal 'rejected' result, no solution."""
        req = entry.req
        arrival, submit_wall = self._submit_info.pop(
            req.req_id, (float(entry.submit_tick), float("nan")))
        self.results.append(RequestResult(
            req_id=req.req_id, objective=req.objective, dim=req.dim,
            x_best=None, f_best=float("inf"), levels_run=0, n_evals=0,
            submit_tick=entry.submit_tick, start_tick=-1,
            finish_tick=self.tick_count, finish_reason="rejected",
            arrival_time=arrival, submit_wall=submit_wall,
            finish_wall=self._now(), requested_chains=req.n_chains,
            granted_chains=0, home_shard=-1))
        self.rejections += 1

    # ---------------------------------------------------------------- tick
    def tick(self) -> None:
        """Admit, then advance every active slot by one temperature level.

        Two passes over the shards: *launch* every ``(shard, dim, N)``
        group's device program first (JAX dispatch is asynchronous, so
        programs on different devices execute concurrently), then
        *collect* — materialize results on host, scatter blocks back and
        retire finished requests.  Collecting inline per group would
        serialize the shards: ``np.asarray`` blocks on the transfer, and
        device k+1 would not launch until device k had fully finished.
        """
        self._admit()
        if self.n_active == 0:
            self.tick_count += 1
            return

        launches = []
        for shard in self.shards:
            # Dispatch groups are keyed by shape alone — (dim, N) —
            # because the objective id is a runtime kernel input;
            # mixed-objective groups share one compiled program.  Groups
            # never span shards: each runs on the shard's own device.
            groups: Dict[Tuple[int, int], List[ActiveJob]] = defaultdict(list)
            for job in shard.rids.jobs.values():
                groups[(job.req.dim, job.req.N)].append(job)
            for (dim, n_steps), jobs in sorted(groups.items()):
                launches.append(self._launch_group(shard, dim, n_steps, jobs))
                self.group_launches += 1
        for launch in launches:
            self._collect_group(*launch)
        self.tick_count += 1

    def _collect_group(self, shard: EngineShard, n_steps: int,
                       jobs: List[ActiveJob], slot_list, outs) -> None:
        """Materialize one group's results and advance its jobs one level."""
        cps = self.cfg.chains_per_slot
        x2, xb, fb = (np.asarray(outs[0]), np.asarray(outs[2]),
                      np.asarray(outs[3]))
        for b, (s, job) in enumerate(slot_list):
            # Copy: a bare slice would alias (and pin) the whole padded buffer.
            shard.pool.set_block(s, x2[b * cps:(b + 1) * cps].copy())
        for job in jobs:
            f = float(fb[job.rid])
            if f < job.best_f:
                job.best_f = f
                job.best_x = xb[job.rid].copy()
            if job.first_tick < 0:
                job.first_tick = self.tick_count
                job.first_tick_wall = self._now()
            self.sweeps_done += len(job.slots)
            shard.sweeps_done += len(job.slots)
            job.level += 1
            job.steps_done += n_steps
            job.evals += n_steps * job.granted_chains
            job.T *= job.req.rho
            job.history.append(job.best_f)       # champion trajectory/level
            reason = self._finish_reason(job)
            if reason is not None:
                self._retire(shard, job, reason)

    def _launch_group(self, shard: EngineShard, dim: int, n_steps: int,
                      jobs: List[ActiveJob]):
        """Pack the group's slots and launch its device program (async);
        returns the collect-pass arguments."""
        cps = self.cfg.chains_per_slot
        slot_list: List[Tuple[int, ActiveJob]] = [
            (s, job) for job in jobs for s in job.slots]
        n_blocks = len(slot_list)
        # Pad to a power of two of blocks so the number of compiled
        # signatures per (dim, N) is O(log n_slots), not O(n_slots).
        n_padded = 1
        while n_padded < n_blocks:
            n_padded *= 2

        x = np.empty((n_padded * cps, dim), np.float32)
        kid_blk = np.empty((n_padded,), np.int32)
        T_blk = np.empty((n_padded,), np.float32)
        seed_blk = np.empty((n_padded,), np.uint32)
        step0_blk = np.empty((n_padded,), np.uint32)
        base_blk = np.empty((n_padded,), np.uint32)
        seg = np.empty((n_padded * cps,), np.int32)
        adopt = np.empty((n_padded * cps,), bool)
        for b, (s, job) in enumerate(slot_list):
            x[b * cps:(b + 1) * cps] = shard.pool.get_block(s)
            kid_blk[b] = np.int32(job.req.kid)
            T_blk[b] = job.T
            seed_blk[b] = np.uint32(job.req.seed)
            step0_blk[b] = np.uint32(job.steps_done)
            base_blk[b] = shard.pool.chain_base[s]
            seg[b * cps:(b + 1) * cps] = job.rid
            adopt[b * cps:(b + 1) * cps] = job.req.exchange == "sync"
        # Dummy pad blocks: replicate block 0, claim the reserved segment
        # n_slots, never adopt. They cost lanes, not correctness.
        for b in range(n_blocks, n_padded):
            x[b * cps:(b + 1) * cps] = x[:cps]
            kid_blk[b] = kid_blk[0]
            T_blk[b] = T_blk[0]
            seed_blk[b] = seed_blk[0]
            step0_blk[b] = step0_blk[0]
            base_blk[b] = base_blk[0]
            seg[b * cps:(b + 1) * cps] = self.cfg.n_slots
            adopt[b * cps:(b + 1) * cps] = False

        # Committed transfers pin the group's program to the shard's mesh
        # device.  The call returns device arrays without blocking; the
        # collect pass materializes them after every shard has launched.
        dev = shard.device
        put = lambda a: jax.device_put(a, dev)
        outs = _group_tick(
            put(x), put(kid_blk), put(T_blk), put(seed_blk), put(step0_blk),
            put(base_blk), put(seg), put(adopt), n_steps=n_steps, blk=cps,
            variant=self.cfg.variant, use_pallas=self._use_pallas,
            interpret=self.cfg.interpret,
            num_segments=self.cfg.n_slots + 1)
        return shard, n_steps, jobs, slot_list, outs

    def _finish_reason(self, job: ActiveJob) -> Optional[str]:
        req = job.req
        if req.target_error is not None:
            # submit() guarantees the optimum exists; .get keeps the tick
            # loop un-wedgeable even if F_OPT is mutated under a live job.
            f_opt = F_OPT.get(req.kid)
            if f_opt is not None and job.best_f <= f_opt + req.target_error:
                return "target"
        if req.max_evals is not None and job.evals >= req.max_evals:
            return "budget"
        if job.level >= req.n_levels:
            return "ladder"
        return None

    def _retire(self, shard: EngineShard, job: ActiveJob, reason: str) -> None:
        self.results.append(RequestResult(
            req_id=job.req.req_id, objective=job.req.objective,
            dim=job.req.dim, x_best=job.best_x, f_best=job.best_f,
            levels_run=job.level, n_evals=job.evals,
            submit_tick=job.submit_tick, start_tick=job.start_tick,
            finish_tick=self.tick_count, finish_reason=reason,
            arrival_time=job.arrival_time, first_tick=job.first_tick,
            submit_wall=job.submit_wall, admit_wall=job.admit_wall,
            first_tick_wall=job.first_tick_wall, finish_wall=self._now(),
            requested_chains=job.req.n_chains,
            granted_chains=job.granted_chains,
            preempted_ticks=list(job.preempted_ticks),
            resumed_ticks=list(job.resumed_ticks),
            champion_history=list(job.history),
            home_shard=job.home_shard,
            migrated_ticks=list(job.migrated_ticks)))
        shard.pool.release(job.rid)
        shard.rids.free(job.rid)

    # ----------------------------------------------------------------- run
    def run(self, max_ticks: Optional[int] = None) -> List[RequestResult]:
        """Drive ticks until queue and pool drain (or ``max_ticks``).

        Closed-loop: serves whatever was already :meth:`submit`-ted — the
        degenerate open-loop run with an empty (exhausted) arrival stream.
        """
        from repro.service.arrivals import ArrivalProcess
        return self.run_stream(ArrivalProcess.batch([]), max_ticks=max_ticks)

    def run_stream(self, arrivals, max_ticks: Optional[int] = None
                   ) -> List[RequestResult]:
        """Open-loop serving: admit from an arrival process while ticking.

        ``arrivals`` is an :class:`~repro.service.arrivals.ArrivalProcess`
        (or anything with ``due(now)`` / ``exhausted``).  Each tick first
        submits every request whose arrival time has come due, then
        advances all in-flight work one temperature level; idle ticks (no
        active jobs, next arrival in the future) still advance the clock,
        so arrival timestamps stay on the tick axis.  Per-request
        lifecycle events (submit/admit/first-tick/complete) are stamped in
        both tick-time (deterministic under a fixed arrival seed) and
        wall-time — the latter on the engine's monotonic epoch, the same
        clock ``wall_s`` is measured on.
        """
        t0 = self._now()
        while True:
            if max_ticks is not None and self.tick_count >= max_ticks:
                break
            for t_arr, req in arrivals.due(self.tick_count):
                self.submit(req, arrival_time=t_arr)
            if self.done:
                if arrivals.exhausted:
                    break
                # Idle: fast-forward the clock to the next arrival instead
                # of spinning empty ticks (low offered load would otherwise
                # execute one no-op tick per time unit).  ceil() lands on
                # the first tick >= next_time — identical tick-axis
                # semantics to ticking through, since due(t) is <=-t.
                # Sources without next_time just tick through idle time.
                nxt = getattr(arrivals, "next_time", None)
                if nxt is not None and math.isfinite(nxt):
                    jump = int(math.ceil(nxt))
                    if max_ticks is not None:
                        jump = min(jump, max_ticks)
                    if jump > self.tick_count:
                        self.tick_count = jump
                        continue
            self.tick()
        self.wall_s = self._now() - t0
        return self.results

    def stats(self) -> dict:
        wall = getattr(self, "wall_s", float("nan"))
        ticks = max(self.tick_count, 1)
        evals = sum(r.n_evals for r in self.results)
        n_slots_total = self.cfg.n_slots * len(self.shards)
        per_s = lambda v: v / wall if wall and wall > 0 else 0.0
        return {
            "ticks": self.tick_count,
            "devices": len(self.shards),
            "group_launches": self.group_launches,
            "submitted": self.n_submitted,
            "completed": sum(r.completed for r in self.results),
            "rejected": self.rejections,
            "preemptions": self.preemptions,
            "migrations": self.migrations,
            "sweeps": self.sweeps_done,
            "occupancy": self.sweeps_done / (ticks * n_slots_total),
            "shard_occupancy": [s.occupancy(ticks) for s in self.shards],
            "wall_s": wall,
            "requests_per_s": per_s(len(self.results)),
            "sweeps_per_s": per_s(self.sweeps_done),
            "chain_steps_per_s": per_s(evals),
        }


def run_standalone(req: SARequest, cfg: EngineConfig) -> RequestResult:
    """Serve ``req`` alone on a dedicated single-device pool — the
    per-tenant baseline.

    Placement-invariant RNG + segmented exchange make the packed engine
    produce the *same* trajectory as this single-tenant run (bit-exact
    champions for identical seeds) — on any home shard, across preemption
    and across cross-shard migration; tests assert it, serve_sa --check
    reports it.
    """
    alone = SAServeEngine(dataclasses.replace(
        cfg, n_slots=req.slots_needed(cfg.chains_per_slot), n_devices=1))
    alone.submit(req)
    return alone.run()[0]
