"""Request/result schema for the multi-tenant SA serving engine.

An :class:`SARequest` is one tenant's optimization job: which problem
family (``continuous`` registry objectives or ``permutation`` QAP
instances), which objective within it, at what dimensionality, with how
many parallel chains, under which cooling schedule, and until which
stopping condition.  Heterogeneous requests — across families — are
co-scheduled on one fleet by the continuous-batching engine (engine.py);
nothing here touches the device.  Everything the representation
determines (state dtype, initial-state sampler, known optimum,
family-specific field validation) is delegated to the request's
:class:`~repro.objectives.families.ProblemFamily`.
"""
from __future__ import annotations

import dataclasses
import math
from typing import List, Optional

import numpy as np

from repro.core.exchange import EXCHANGES
from repro.kernels import objective_math as om
from repro.objectives import families as fam_mod
from repro.objectives import qap

#: Objectives servable by the engine under the default (continuous)
#: family: the Pallas kernel registry.
SERVABLE = tuple(sorted(om.KID_BY_NAME))

#: Annealing method (workload class) per request:
#: ``sa`` — plain parallel SA (the paper's V1/V2, per ``exchange``);
#: ``pt`` — parallel tempering: each chain holds one rung of the request's
#:   temperature ladder, with an even/odd replica-swap pass every level;
#: ``pa`` — population annealing: Boltzmann resampling of the chain
#:   population at every temperature-level transition.
METHODS = ("sa", "pt", "pa")

#: Per-request overload policies (see scheduler.py): what the scheduler may
#: do with/for this request when the pool is saturated.  ``None`` on a
#: request defers to the scheduler-wide default.
OVERLOAD_POLICIES = ("none", "reject", "degrade", "preempt")

#: Terminal finish_reason values.  'rejected' is the only non-completed
#: terminal status: the request was dropped by SLO admission control and
#: carries no solution.  'truncated' is a completed terminal: the ladder
#: was shortened mid-flight (finish-deadline SLO degrade) and ended at
#: the truncated length — the champion up to that level is still
#: bit-exact vs a standalone run of the same truncate schedule.
TERMINAL_REASONS = ("ladder", "target", "budget", "rejected", "truncated")


@dataclasses.dataclass(frozen=True)
class SARequest:
    """One annealing job submitted to the serving engine.

    The chain budget is rounded *up* to whole slots (blocks of
    ``chains_per_slot`` chains) at admission; a request may span several
    slots, which then exchange among themselves — never across tenants.
    """

    req_id: int
    objective: str              # registry name: schwefel|rastrigin|ackley|griewank
    dim: int                    # problem dimensionality
    n_chains: int = 64          # chain budget (rounded up to slot granularity)
    T0: float = 100.0           # initial temperature
    T_min: float = 0.1          # stop temperature (ladder end)
    rho: float = 0.95           # geometric cooling factor
    N: int = 50                 # Metropolis steps per temperature level
    seed: int = 0               # RNG stream seed (placement-invariant)
    priority: int = 0           # higher = served sooner (aged for fairness)
    method: str = "sa"          # workload class: 'sa' | 'pt' | 'pa'
    exchange: str = "sync"      # 'sync' (paper V2) | 'async' (paper V1) |
                                # 'sos' (Onbasoglu–Özdamar stochastic);
                                # ignored for method 'pt'/'pa' (replica
                                # swap / resampling replaces adoption)
    pa_ess_ratio: float = 0.0   # method 'pa' only: if > 0, halve the
                                # population width whenever the effective
                                # sample size falls below ratio*width
                                # (self-driven shrink schedule)
    target_error: Optional[float] = None  # stop early once best_f - f_opt <= this
    max_evals: Optional[int] = None       # objective-evaluation budget cap
    # ---- SLO / admission-control fields (see scheduler.py) ----
    deadline: Optional[float] = None  # max queueing delay in ticks before the
                                      # reject/degrade policies drop the
                                      # request (0 = admit now or never);
                                      # None defers to the scheduler default
    min_chains: Optional[int] = None  # degrade floor: never grant fewer
                                      # chains than this (None = one slot)
    on_overload: Optional[str] = None  # per-request-class overload policy:
                                       # 'none'|'reject'|'degrade'|'preempt';
                                       # None = scheduler-wide default
    # ---- completion-deadline SLO (control plane; see autoscaler.py) ----
    finish_deadline: Optional[float] = None  # finish-tick SLO: max end-to-end
                                             # latency (arrival -> end of the
                                             # completing level) in ticks.
                                             # Distinct from `deadline` (a
                                             # queueing-delay bound): this one
                                             # is met by *ladder truncation* —
                                             # the scheduler may shorten the
                                             # remaining temperature levels of
                                             # a running job, never below
                                             # min_levels.  None = no
                                             # completion SLO (never truncated)
    min_levels: int = 1         # truncation floor: the ladder is never cut
                                # below this many temperature levels, so a
                                # late job still does a minimum of annealing
                                # work instead of returning its init state
    family: str = "continuous"  # problem family: 'continuous' (registry
                                # objectives, float32 box states) |
                                # 'permutation' (QAP instances, int32
                                # permutation states)

    def __post_init__(self):
        fam = fam_mod.get_family(self.family)   # typed error on unknown name
        if self.dim < 1 or self.n_chains < 1 or self.N < 1:
            raise ValueError("dim, n_chains and N must be positive")
        if not (0.0 < self.rho < 1.0) or self.T_min <= 0 or self.T0 <= self.T_min:
            raise ValueError("need T0 > T_min > 0 and 0 < rho < 1")
        if self.exchange not in EXCHANGES:
            raise ValueError(
                f"exchange must be one of {tuple(sorted(EXCHANGES))}")
        if self.method not in METHODS:
            raise ValueError(f"method must be one of {METHODS}")
        if not (0.0 <= self.pa_ess_ratio < 1.0):
            raise ValueError("need 0 <= pa_ess_ratio < 1")
        if self.pa_ess_ratio > 0.0 and self.method != "pa":
            raise ValueError("pa_ess_ratio requires method 'pa'")
        if self.deadline is not None and self.deadline < 0:
            raise ValueError("deadline must be >= 0 ticks")
        if self.min_chains is not None and not (
                1 <= self.min_chains <= self.n_chains):
            raise ValueError("need 1 <= min_chains <= n_chains")
        if self.on_overload is not None \
                and self.on_overload not in OVERLOAD_POLICIES:
            raise ValueError(
                f"on_overload must be one of {OVERLOAD_POLICIES} or None")
        if self.finish_deadline is not None and self.finish_deadline <= 0:
            raise ValueError("finish_deadline must be > 0 ticks")
        if not (1 <= self.min_levels <= self.n_levels):
            raise ValueError(
                f"need 1 <= min_levels <= n_levels ({self.n_levels}); "
                f"got min_levels={self.min_levels}")
        # Family-specific validation last, so its typed errors see
        # structurally-sound generic fields: servable objective, matching
        # dim, and family-incompatible controls (e.g. pa_ess_ratio or a
        # replica method on a permutation request) all fail eagerly here —
        # at construction, never mid-tick.
        fam.validate(self)

    @property
    def prob_family(self) -> "fam_mod.ProblemFamily":
        """The request's problem-family singleton."""
        return fam_mod.get_family(self.family)

    @property
    def state_dtype(self) -> np.dtype:
        """Chain-state dtype of this request's slot blocks."""
        return self.prob_family.state_dtype

    @property
    def kid(self) -> int:
        """Runtime objective id within the family: the kernel registry id
        for continuous requests, the QAP instance id for permutation
        ones (both small stable ints; dispatch never mixes families in
        one program, so the id spaces may overlap)."""
        if self.family == fam_mod.FAMILY_PERMUTATION:
            return qap.INSTANCE_ID[self.objective]
        return om.KID_BY_NAME[self.objective]

    @property
    def f_opt(self) -> Optional[float]:
        """Known optimum of the objective (None if unregistered)."""
        return self.prob_family.f_opt(self)

    @property
    def instance(self) -> qap.QAPInstance:
        """The QAP instance (permutation-family requests only)."""
        return qap.get(self.objective)

    @property
    def n_levels(self) -> int:
        """Ladder length (the paper's do/while loop)."""
        return max(1, int(math.ceil(math.log(self.T_min / self.T0)
                                    / math.log(self.rho))))

    def slots_needed(self, chains_per_slot: int) -> int:
        return max(1, -(-self.n_chains // chains_per_slot))

    def slots_floor(self, chains_per_slot: int) -> int:
        """Smallest admissible footprint in slots (the degrade floor)."""
        if self.min_chains is None:
            return 1
        return max(1, -(-self.min_chains // chains_per_slot))

    def sample_x0(self, n_chains: int) -> np.ndarray:
        """Deterministic initial states, independent of slot placement
        (family-owned: box-uniform float32 for continuous, uniform random
        permutations int32 for QAP)."""
        return self.prob_family.sample_x0(self, n_chains)

    def pt_rungs(self, n_chains: int) -> np.ndarray:
        """Parallel-tempering rung temperatures for a granted width.

        A geometric ladder T_l = T0 * (T_min/T0)^(l/(n-1)) from the
        hottest rung (chain 0, T0) to the coldest (T_min), computed in
        float64 host math and cast once to f32 — serving and standalone
        replay the identical array, whatever width was granted.
        """
        n = max(1, int(n_chains))
        if n == 1:
            return np.asarray([self.T_min], np.float32)
        frac = np.arange(n, dtype=np.float64) / (n - 1)
        return np.asarray(self.T0 * (self.T_min / self.T0) ** frac,
                          np.float64).astype(np.float32)


@dataclasses.dataclass
class RequestResult:
    """Terminal record for a served request.

    Lifecycle timestamps come in two clocks:

    * **tick-time** (``arrival_time`` .. ``finish_tick``): deterministic
      under a fixed arrival seed — what latency *tests* assert on;
    * **wall-time** (``*_wall``, seconds since the engine epoch): what a
      deployment actually observes — surfaced by ``serve_sa --json``.

    Derived latencies (``queue_delay_ticks`` etc.) are properties so the
    definitions live in exactly one place; see docs/serving.md for the
    event diagram.

    A request dropped by SLO admission control terminates with
    ``finish_reason == 'rejected'``: it carries no solution
    (``x_best is None``) and its admission-anchored latencies are nan.
    A preempted-then-resumed request records every swap-out/swap-in tick;
    its champions are bit-exact with an uninterrupted run.
    """

    req_id: int
    objective: str
    dim: int
    x_best: Optional[np.ndarray]  # (dim,); None iff rejected
    f_best: float
    levels_run: int             # temperature levels actually executed
    n_evals: int                # objective evaluations spent
    submit_tick: int            # engine tick at submission
    start_tick: int             # engine tick at admission (-1 if rejected)
    finish_tick: int            # engine tick at completion/rejection
    finish_reason: str          # 'ladder' | 'target' | 'budget' | 'rejected'
    # ---- lifecycle events (streaming/open-loop serving) ----
    arrival_time: float = 0.0   # offered-load timestamp, in (fractional) ticks
    first_tick: int = -1        # tick of the first sweep (== start_tick today)
    submit_wall: float = float("nan")      # wall s since engine epoch
    admit_wall: float = float("nan")
    first_tick_wall: float = float("nan")
    finish_wall: float = float("nan")
    # ---- SLO / preemption metadata ----
    requested_chains: int = 0   # req.n_chains as submitted
    granted_chains: int = 0     # chains actually granted (0 if rejected;
                                # < requested under the degrade policy)
    preempted_ticks: List[int] = dataclasses.field(default_factory=list)
    resumed_ticks: List[int] = dataclasses.field(default_factory=list)
    champion_history: List[float] = dataclasses.field(default_factory=list)
    # ---- sharded-pool metadata ----
    home_shard: int = 0         # engine shard that retired the request
                                # (-1 if rejected: never placed)
    migrated_ticks: List[int] = dataclasses.field(default_factory=list)
    # ---- elastic-fleet metadata (proactive degrade) ----
    # One entry per mid-flight shrink: (ladder level at the shrink,
    # chains before, chains after).  ``granted_chains`` above is the
    # *final* width; the width at admission is the first event's
    # 'before' entry (or granted_chains when the job never shrank).
    shrunk_ticks: List[int] = dataclasses.field(default_factory=list)
    shrink_events: List[tuple] = dataclasses.field(default_factory=list)
    # ---- population-annealing metadata ----
    # Self-driven ESS shrinks (same (level, before, after) shape as
    # shrink_events) are recorded separately: they are *reproduced* by a
    # standalone replay from the identical fx stream, so the bit-exactness
    # oracle must not re-apply them as an external shrink schedule.
    pa_shrink_events: List[tuple] = dataclasses.field(default_factory=list)
    # ---- completion-deadline SLO metadata (ladder truncation) ----
    # One entry per mid-flight ladder truncation: (level at the decision,
    # total levels before, total levels after) — the *level-axis* analogue
    # of shrink_events.  ``run_standalone(truncate_schedule=[(level, to),
    # ...])`` replays it bit-exactly: truncation only moves the ladder's
    # end, never any level's arithmetic, so the packed champion history is
    # a prefix-exact match of the untruncated run.
    truncated_ticks: List[int] = dataclasses.field(default_factory=list)
    truncate_events: List[tuple] = dataclasses.field(default_factory=list)

    # ---- derived status ----
    @property
    def status(self) -> str:
        """Typed terminal status: 'completed' | 'rejected'."""
        return "rejected" if self.finish_reason == "rejected" else "completed"

    @property
    def completed(self) -> bool:
        return self.finish_reason != "rejected"

    @property
    def degraded(self) -> bool:
        """Admitted with fewer chains than requested (degrade policy)."""
        return self.completed and self.granted_chains < self.requested_chains

    @property
    def n_preemptions(self) -> int:
        return len(self.preempted_ticks)

    @property
    def n_migrations(self) -> int:
        """Cross-shard moves (checkpoint/restore between shard pools)."""
        return len(self.migrated_ticks)

    @property
    def n_shrinks(self) -> int:
        """Mid-flight width reductions (proactive degrade)."""
        return len(self.shrunk_ticks)

    @property
    def n_truncations(self) -> int:
        """Mid-flight ladder truncations (finish-deadline degrade)."""
        return len(self.truncated_ticks)

    @property
    def truncated(self) -> bool:
        """The ladder was shortened to meet a finish-deadline SLO."""
        return bool(self.truncate_events)

    @property
    def admitted_chains(self) -> int:
        """Chains granted at admission (before any mid-flight shrink).

        The widest 'before' across scheduler *and* PA self-shrinks: either
        list alone understates the admission width when the first shrink
        came from the other mechanism.
        """
        befores = [int(e[1]) for e in self.shrink_events]
        befores += [int(e[1]) for e in self.pa_shrink_events]
        if befores:
            return max([self.granted_chains] + befores)
        return self.granted_chains

    # ---- derived latencies: tick clock (deterministic) ----
    @property
    def queue_delay_ticks(self) -> float:
        """Arrival -> admission, in ticks (nan if never admitted)."""
        if self.start_tick < 0:
            return float("nan")
        return self.start_tick - self.arrival_time

    @property
    def ttft_ticks(self) -> float:
        """Arrival -> end of the first temperature level, in ticks
        (time-to-first-tick: first visible annealing progress)."""
        if self.first_tick < 0:
            return float("nan")
        return self.first_tick + 1 - self.arrival_time

    @property
    def latency_ticks(self) -> float:
        """Arrival -> end of the completing temperature level, in ticks.

        Same end-of-tick convention as ``ttft_ticks`` (progress at tick t
        is visible at t+1), so latency >= ttft always holds — a request
        finishing on its first tick has latency == ttft.
        """
        return self.finish_tick + 1 - self.arrival_time

    # ---- derived latencies: wall clock ----
    @property
    def queue_delay_wall_s(self) -> float:
        return self.admit_wall - self.submit_wall

    @property
    def ttft_wall_s(self) -> float:
        return self.first_tick_wall - self.submit_wall

    @property
    def latency_wall_s(self) -> float:
        return self.finish_wall - self.submit_wall

    def to_dict(self, include_x: bool = False) -> dict:
        """JSON-ready record (``serve_sa --json``)."""
        d = {
            "req_id": self.req_id, "objective": self.objective,
            "dim": self.dim, "f_best": float(self.f_best),
            "levels_run": self.levels_run, "n_evals": self.n_evals,
            "finish_reason": self.finish_reason, "status": self.status,
            "requested_chains": self.requested_chains,
            "granted_chains": self.granted_chains,
            "preempted_ticks": list(self.preempted_ticks),
            "resumed_ticks": list(self.resumed_ticks),
            "n_preemptions": self.n_preemptions,
            "home_shard": self.home_shard,
            "migrated_ticks": list(self.migrated_ticks),
            "n_migrations": self.n_migrations,
            "shrunk_ticks": list(self.shrunk_ticks),
            "shrink_events": [list(e) for e in self.shrink_events],
            "pa_shrink_events": [list(e) for e in self.pa_shrink_events],
            "n_shrinks": self.n_shrinks,
            "truncated_ticks": list(self.truncated_ticks),
            "truncate_events": [list(e) for e in self.truncate_events],
            "n_truncations": self.n_truncations,
            "admitted_chains": self.admitted_chains,
            "arrival_time": self.arrival_time,
            "submit_tick": self.submit_tick, "start_tick": self.start_tick,
            "first_tick": self.first_tick, "finish_tick": self.finish_tick,
            "queue_delay_ticks": self.queue_delay_ticks,
            "ttft_ticks": self.ttft_ticks,
            "latency_ticks": self.latency_ticks,
            "queue_delay_wall_s": self.queue_delay_wall_s,
            "ttft_wall_s": self.ttft_wall_s,
            "latency_wall_s": self.latency_wall_s,
        }
        if include_x:
            d["x_best"] = (None if self.x_best is None
                           else np.asarray(self.x_best).tolist())
            d["champion_history"] = [float(f) for f in self.champion_history]
        return d
