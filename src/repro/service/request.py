"""Request/result schema for the multi-tenant SA serving engine.

An :class:`SARequest` is one tenant's optimization job: which registry
objective to minimize, at what dimensionality, with how many parallel
chains, under which cooling schedule, and until which stopping condition.
Heterogeneous requests are co-scheduled on one device program by the
continuous-batching engine (engine.py); nothing here touches the device.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional

import numpy as np

from repro.kernels import objective_math as om

#: Objectives servable by the engine: the Pallas kernel registry.
SERVABLE = tuple(sorted(om.KID_BY_NAME))


@dataclasses.dataclass(frozen=True)
class SARequest:
    """One annealing job submitted to the serving engine.

    The chain budget is rounded *up* to whole slots (blocks of
    ``chains_per_slot`` chains) at admission; a request may span several
    slots, which then exchange among themselves — never across tenants.
    """

    req_id: int
    objective: str              # registry name: schwefel|rastrigin|ackley|griewank
    dim: int                    # problem dimensionality
    n_chains: int = 64          # chain budget (rounded up to slot granularity)
    T0: float = 100.0           # initial temperature
    T_min: float = 0.1          # stop temperature (ladder end)
    rho: float = 0.95           # geometric cooling factor
    N: int = 50                 # Metropolis steps per temperature level
    seed: int = 0               # RNG stream seed (placement-invariant)
    priority: int = 0           # higher = served sooner (aged for fairness)
    exchange: str = "sync"      # 'sync' (paper V2) | 'async' (paper V1)
    target_error: Optional[float] = None  # stop early once best_f - f_opt <= this
    max_evals: Optional[int] = None       # objective-evaluation budget cap

    def __post_init__(self):
        if self.objective not in om.KID_BY_NAME:
            raise ValueError(
                f"objective {self.objective!r} not servable; one of {SERVABLE}")
        if self.dim < 1 or self.n_chains < 1 or self.N < 1:
            raise ValueError("dim, n_chains and N must be positive")
        if not (0.0 < self.rho < 1.0) or self.T_min <= 0 or self.T0 <= self.T_min:
            raise ValueError("need T0 > T_min > 0 and 0 < rho < 1")
        if self.exchange not in ("sync", "async"):
            raise ValueError("exchange must be 'sync' or 'async'")

    @property
    def kid(self) -> int:
        return om.KID_BY_NAME[self.objective]

    @property
    def n_levels(self) -> int:
        """Ladder length (the paper's do/while loop)."""
        return max(1, int(math.ceil(math.log(self.T_min / self.T0)
                                    / math.log(self.rho))))

    def slots_needed(self, chains_per_slot: int) -> int:
        return max(1, -(-self.n_chains // chains_per_slot))

    def sample_x0(self, n_chains: int) -> np.ndarray:
        """Deterministic initial states, independent of slot placement."""
        lo, hi = om.BOX[self.kid]
        r = np.random.default_rng(self.seed)
        return (lo + r.random((n_chains, self.dim), dtype=np.float32)
                * (hi - lo)).astype(np.float32)


@dataclasses.dataclass
class RequestResult:
    """Terminal record for a served request."""

    req_id: int
    objective: str
    dim: int
    x_best: np.ndarray          # (dim,)
    f_best: float
    levels_run: int             # temperature levels actually executed
    n_evals: int                # objective evaluations spent
    submit_tick: int            # engine tick at submission
    start_tick: int             # engine tick at admission (queueing delay)
    finish_tick: int            # engine tick at completion
    finish_reason: str          # 'ladder' | 'target' | 'budget'
