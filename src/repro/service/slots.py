"""Slot pool: the annealing analogue of a decode batch's KV-cache slots.

The engine owns a fixed pool of ``n_slots`` chain-block *slots*.  One slot
holds one block of ``chains_per_slot`` chains — exactly one Pallas kernel
block — belonging to at most one request at a time.  A request spanning
multiple slots keeps one slot per contiguous chunk of its chain budget;
``chain_base`` records the chunk's global chain offset *within the request*
so RNG streams are invariant to which physical slots the scheduler picked
(launch/serve.py's SlotCache, with (x, T-ladder position, best) instead of
KV rows).

All state here is host-side numpy; device arrays are packed per dispatch
group by the engine each tick.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import numpy as np

from repro.service.request import SARequest


@dataclasses.dataclass
class ActiveJob:
    """Runtime state of an admitted request (one per tenant in residence)."""

    req: SARequest
    rid: int                    # segment id in [0, n_slots): tenant mask key
    slots: List[int]            # pool slots held, in chain-offset order
    level: int = 0              # temperature levels completed
    T: float = 0.0              # current temperature
    steps_done: int = 0         # Metropolis steps completed (RNG step cursor)
    evals: int = 0              # objective evaluations spent
    best_x: Optional[np.ndarray] = None
    best_f: float = float("inf")
    submit_tick: int = 0
    start_tick: int = 0
    granted_chains: int = 0     # chain budget rounded up to whole slots
    # Lifecycle timestamps (see docs/serving.md): arrival on the tick axis
    # (fractional under open-loop Poisson load), the rest wall-clock seconds
    # since the engine epoch.  first_tick is the tick of the job's first
    # sweep (-1 until it runs).
    arrival_time: float = 0.0
    first_tick: int = -1
    submit_wall: float = float("nan")
    admit_wall: float = float("nan")
    first_tick_wall: float = float("nan")


class SlotPool:
    """Fixed pool of chain-block slots with per-slot ownership."""

    def __init__(self, n_slots: int, chains_per_slot: int):
        if n_slots < 1 or chains_per_slot < 1:
            raise ValueError("n_slots and chains_per_slot must be positive")
        self.n_slots = n_slots
        self.chains_per_slot = chains_per_slot
        self.owner = np.full((n_slots,), -1, np.int32)       # rid or -1
        self.chain_base = np.zeros((n_slots,), np.uint32)    # request chain offset
        self._x: List[Optional[np.ndarray]] = [None] * n_slots

    # ------------------------------------------------------------- queries
    @property
    def n_free(self) -> int:
        return int(np.sum(self.owner < 0))

    @property
    def n_active(self) -> int:
        return self.n_slots - self.n_free

    def free_slots(self) -> List[int]:
        return [int(s) for s in np.flatnonzero(self.owner < 0)]

    def slots_of(self, rid: int) -> List[int]:
        return [int(s) for s in np.flatnonzero(self.owner == rid)]

    def get_block(self, slot: int) -> np.ndarray:
        x = self._x[slot]
        assert x is not None, f"slot {slot} is empty"
        return x

    def set_block(self, slot: int, x: np.ndarray) -> None:
        self._x[slot] = x

    # ---------------------------------------------------------- lifecycle
    def assign(self, rid: int, req: SARequest) -> List[int]:
        """Pack ``req`` into free slots; returns the slot list (chain order).

        Splits the request's initial states into ``chains_per_slot`` blocks:
        slot j of the request holds chains [j*cps, (j+1)*cps) and carries
        ``chain_base = j*cps`` — the placement-invariant RNG index base.
        """
        cps = self.chains_per_slot
        need = req.slots_needed(cps)
        free = self.free_slots()
        if need > len(free):
            raise RuntimeError(
                f"request {req.req_id} needs {need} slots, {len(free)} free")
        chosen = free[:need]
        x0 = req.sample_x0(need * cps)  # budget rounded up to whole slots
        for j, s in enumerate(chosen):
            self.owner[s] = rid
            self.chain_base[s] = np.uint32(j * cps)
            self._x[s] = x0[j * cps:(j + 1) * cps]
        return chosen

    def release(self, rid: int) -> None:
        for s in self.slots_of(rid):
            self.owner[s] = -1
            self.chain_base[s] = 0
            self._x[s] = None


class RidTable:
    """Recyclable request-id (segment-id) allocator, bounded by pool size."""

    def __init__(self, capacity: int):
        self._free = list(range(capacity - 1, -1, -1))
        self.jobs: Dict[int, ActiveJob] = {}

    def alloc(self, job: ActiveJob) -> int:
        rid = self._free.pop()
        job.rid = rid
        self.jobs[rid] = job
        return rid

    def free(self, rid: int) -> None:
        del self.jobs[rid]
        self._free.append(rid)
