"""Slot pool: the annealing analogue of a decode batch's KV-cache slots.

The engine owns a fixed pool of ``n_slots`` chain-block *slots*.  One slot
holds one block of ``chains_per_slot`` chains — exactly one Pallas kernel
block — belonging to at most one request at a time.  A request spanning
multiple slots keeps one slot per contiguous chunk of its chain budget;
``chain_base`` records the chunk's global chain offset *within the request*
so RNG streams are invariant to which physical slots the scheduler picked
(launch/serve.py's SlotCache, with (x, T-ladder position, best) instead of
KV rows).

Slot state is *logically* host-side numpy; device arrays are packed per
dispatch group by the engine each tick.  Under macro-tick fusion the
engine leaves chain state device-resident between launches: a slot may
hold a :class:`DeviceBlockRef` — a lazy view into the group's packed
device output — instead of a numpy block.  ``get_block`` materializes the
ref to host on demand (checkpoint, migration, shrink, repack), so every
consumer of the pool keeps its host-numpy contract while the steady-state
dispatch path skips the host round-trip entirely.

The pool is **dtype-polymorphic**: a slot's block carries whatever dtype
the owning request's family sampled (float32 coordinates for continuous
requests, int32 permutations for QAP), and every lifecycle operation —
assign, checkpoint, restore, shrink repack, device-ref materialization —
is a copy or a view that preserves dtype and bits exactly.  Mixed-family
residency in one pool is therefore free; the engine's per-group packing
(which allocates the packed device array) is the only place a dtype is
ever chosen.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import numpy as np

from repro.service.request import SARequest


class DeviceBlockRef:
    """Lazy slot content: rows ``[start, stop)`` of a packed device array.

    Created by the engine's fused launch path (the group's donated output
    buffer), materialized to host numpy on first ``get_block``.  Identity
    of ``buf`` is what the engine's dispatch cache keys on: if every slot
    of a group still references the same buffer at the same rows, the
    packed state on device is current and the host repack + transfer can
    be skipped (and the buffer donated back to the next launch).
    """

    __slots__ = ("buf", "start", "stop")

    def __init__(self, buf, start: int, stop: int):
        self.buf = buf
        self.start = start
        self.stop = stop

    def materialize(self) -> np.ndarray:
        return np.asarray(self.buf[self.start:self.stop])


@dataclasses.dataclass
class ActiveJob:
    """Runtime state of an admitted request (one per tenant in residence).

    Every field is host-side and serializable, so a job can be checkpointed
    into a :class:`SwappedJob` (preemption) and resumed later bit-exactly:
    the RNG is counter-based on ``(seed, chain_base + c, steps_done)``, so
    slot state + the two cursors (``steps_done``, ``level``/``T``) are the
    *complete* trajectory state.  Mutable per-job fields must use
    ``default_factory`` — instances are long-lived and must never alias.
    """

    req: SARequest
    rid: int                    # segment id in [0, n_slots): tenant mask key
    slots: List[int]            # pool slots held, in chain-offset order
    level: int = 0              # temperature levels completed
    T: float = 0.0              # current temperature
    steps_done: int = 0         # Metropolis steps completed (RNG step cursor)
    evals: int = 0              # objective evaluations spent
    best_x: Optional[np.ndarray] = None
    best_f: float = float("inf")
    submit_tick: int = 0
    start_tick: int = 0
    granted_chains: int = 0     # chains actually granted (may be < requested
                                # under the 'degrade' overload policy)
    # Lifecycle timestamps (see docs/serving.md): arrival on the tick axis
    # (fractional under open-loop Poisson load), the rest wall-clock seconds
    # since the engine epoch.  first_tick is the tick of the job's first
    # sweep (-1 until it runs).
    arrival_time: float = 0.0
    first_tick: int = -1
    submit_wall: float = float("nan")
    admit_wall: float = float("nan")
    first_tick_wall: float = float("nan")
    # Preemption lifecycle: ticks at which the job was swapped out / back
    # in, and the per-level champion trajectory (best_f after each completed
    # temperature level — the bit-exactness witness for resume).
    preempted_ticks: List[int] = dataclasses.field(default_factory=list)
    resumed_ticks: List[int] = dataclasses.field(default_factory=list)
    history: List[float] = dataclasses.field(default_factory=list)
    # Sharded-pool lifecycle: the engine shard currently hosting the job
    # and the ticks at which it migrated between shards (Russkov-style
    # rebalancing: checkpoint on the old shard, restore on the new one —
    # bit-exact, because restore is placement-invariant).
    home_shard: int = 0
    migrated_ticks: List[int] = dataclasses.field(default_factory=list)
    # Proactive-degrade lifecycle: ticks at which the running job was
    # shrunk (checkpoint -> restore at fewer slots), and the shrink
    # schedule on the *level* axis — ``(level, from_chains, to_chains)``
    # per shrink — which is what a standalone replay needs to reproduce
    # the trajectory bit-exactly (the surviving chains keep their logical
    # indices [0, to_chains), so only the width schedule matters).
    shrunk_ticks: List[int] = dataclasses.field(default_factory=list)
    shrink_events: List[tuple] = dataclasses.field(default_factory=list)
    # Population-annealing ESS shrinks, same (level, from, to) shape but
    # kept apart from ``shrink_events``: a standalone replay re-derives
    # them from the identical fx stream, so the bit-exactness oracle must
    # not feed them back in as an external shrink schedule.
    pa_shrink_events: List[tuple] = dataclasses.field(default_factory=list)
    # Completion-deadline lifecycle (ladder truncation): the job's
    # *effective* ladder length — starts at ``req.n_levels`` and only ever
    # decreases (never below ``req.min_levels``) when the scheduler
    # shortens the remaining levels to meet ``req.finish_deadline``.  The
    # level-axis twin of the shrink machinery: ``truncate_events`` records
    # ``(level, from_levels, to_levels)`` per cut, which is exactly what a
    # standalone replay needs (truncation moves the ladder's end, never
    # any level's arithmetic, so champions are prefix-exact).  0 means
    # "not yet placed"; the engine sets it to req.n_levels at admission.
    levels_limit: int = 0
    truncated_ticks: List[int] = dataclasses.field(default_factory=list)
    truncate_events: List[tuple] = dataclasses.field(default_factory=list)


@dataclasses.dataclass
class SwappedJob:
    """Host-side checkpoint of a preempted :class:`ActiveJob`.

    Wraps the job itself (all cursors, champion state and lifecycle stamps
    travel with it — nothing is copied out, so new ActiveJob fields can
    never be forgotten here) plus its chain blocks in chain-offset order.
    ``chain_base`` is *not* stored: it is recomputed as ``j * chains_per
    slot`` on restore, which is exactly the placement-invariant RNG base —
    the resumed job may land on different physical slots and still produce
    a bit-identical trajectory.
    """

    job: ActiveJob
    blocks: List[np.ndarray]    # one (chains_per_slot, dim) block per slot

    @property
    def n_slots(self) -> int:
        return len(self.blocks)


class SlotPool:
    """Fixed pool of chain-block slots with per-slot ownership."""

    def __init__(self, n_slots: int, chains_per_slot: int):
        if n_slots < 1 or chains_per_slot < 1:
            raise ValueError("n_slots and chains_per_slot must be positive")
        self.n_slots = n_slots
        self.chains_per_slot = chains_per_slot
        self.owner = np.full((n_slots,), -1, np.int32)       # rid or -1
        self.chain_base = np.zeros((n_slots,), np.uint32)    # request chain offset
        self._x: List[Optional[np.ndarray]] = [None] * n_slots

    # ------------------------------------------------------------- queries
    @property
    def n_free(self) -> int:
        return int(np.sum(self.owner < 0))

    @property
    def n_active(self) -> int:
        return self.n_slots - self.n_free

    def free_slots(self) -> List[int]:
        return [int(s) for s in np.flatnonzero(self.owner < 0)]

    def slots_of(self, rid: int) -> List[int]:
        return [int(s) for s in np.flatnonzero(self.owner == rid)]

    def get_block(self, slot: int) -> np.ndarray:
        x = self._x[slot]
        assert x is not None, f"slot {slot} is empty"
        if isinstance(x, DeviceBlockRef):
            # Materialize the device-resident block to host and cache it:
            # checkpoint/migrate/shrink and cache-miss repacks all come
            # through here, and repeated reads must not re-transfer.
            x = x.materialize()
            self._x[slot] = x
        return x

    def set_block(self, slot: int, x: np.ndarray) -> None:
        self._x[slot] = x

    def set_device_block(self, slot: int, buf, start: int, stop: int) -> None:
        """Point ``slot`` at rows [start, stop) of a packed device array
        (the fused launch's output) instead of a host copy."""
        self._x[slot] = DeviceBlockRef(buf, start, stop)

    def device_ref(self, slot: int) -> Optional[DeviceBlockRef]:
        """The slot's un-materialized device ref, or None if host-resident."""
        x = self._x[slot]
        return x if isinstance(x, DeviceBlockRef) else None

    # ---------------------------------------------------------- lifecycle
    def assign(self, rid: int, req: SARequest,
               n_slots: Optional[int] = None) -> List[int]:
        """Pack ``req`` into free slots; returns the slot list (chain order).

        Splits the request's initial states into ``chains_per_slot`` blocks:
        slot j of the request holds chains [j*cps, (j+1)*cps) and carries
        ``chain_base = j*cps`` — the placement-invariant RNG index base.
        ``n_slots`` overrides the full-width footprint (the 'degrade'
        overload policy admits with fewer slots, down to the request's
        ``min_chains`` floor); the trajectory is then bit-exact with a
        standalone run of the same request at the granted chain count.
        """
        need = req.slots_needed(self.chains_per_slot) \
            if n_slots is None else n_slots
        cps = self.chains_per_slot
        x0 = req.sample_x0(need * cps)  # budget rounded up to whole slots
        return self._place(rid, req,
                           [x0[j * cps:(j + 1) * cps] for j in range(need)])

    def restore(self, rid: int, blocks: List[np.ndarray]) -> List[int]:
        """Swap a checkpointed job's blocks back in (see :class:`SwappedJob`).

        The physical slots may differ from the ones held before preemption;
        ``chain_base`` is re-derived from block order, which is all the RNG
        keys off — resume is placement-invariant like first admission.
        """
        return self._place(rid, None, [b.copy() for b in blocks])

    def _place(self, rid: int, req: Optional[SARequest],
               blocks: List[np.ndarray]) -> List[int]:
        need = len(blocks)
        free = self.free_slots()
        if need > len(free):
            who = f"request {req.req_id}" if req is not None else f"rid {rid}"
            raise RuntimeError(f"{who} needs {need} slots, {len(free)} free")
        chosen = free[:need]
        for j, s in enumerate(chosen):
            self.owner[s] = rid
            self.chain_base[s] = np.uint32(j * self.chains_per_slot)
            self._x[s] = blocks[j]
        return chosen

    def checkpoint(self, rid: int) -> List[np.ndarray]:
        """Copy ``rid``'s chain blocks out, in chain-offset order.

        Host-side snapshot for preemption: block j holds chains
        [j*cps, (j+1)*cps) of the request regardless of which physical
        slots it occupied.
        """
        slots = sorted(self.slots_of(rid), key=lambda s: self.chain_base[s])
        return [self.get_block(s).copy() for s in slots]

    def release(self, rid: int) -> None:
        for s in self.slots_of(rid):
            self.owner[s] = -1
            self.chain_base[s] = 0
            self._x[s] = None


class RidTable:
    """Recyclable request-id (segment-id) allocator, bounded by pool size."""

    def __init__(self, capacity: int):
        self._free = list(range(capacity - 1, -1, -1))
        self.jobs: Dict[int, ActiveJob] = {}

    def alloc(self, job: ActiveJob) -> int:
        rid = self._free.pop()
        job.rid = rid
        self.jobs[rid] = job
        return rid

    def free(self, rid: int) -> None:
        del self.jobs[rid]
        self._free.append(rid)
