"""Engine telemetry: metrics registry, phase timers, structured events.

The serving stack's counters (``engine.stats()``) answer *what happened*
— requests, sweeps, migrations — but not *where a tick's wall time goes*.
``BENCH_serve_scale.json`` shows why that matters: 4 shards deliver ~5x
goodput **per tick** yet worse wall-clock than 2 shards, because the
Python tick loop and per-tick launch/sync overhead are invisible to every
per-tick counter.  This module is the host/device accounting layer that
localizes the cost (the discipline of Barash et al.'s population-annealing
GPU accounting, applied to a serving loop):

* :class:`MetricsRegistry` — typed counters / gauges / histograms with
  label support, streaming p50/p90/p99 (exponential-bucket histograms:
  O(1) memory, deterministic), a Prometheus-style text exposition and a
  JSON snapshot.  Per-shard series are labelled by stable shard index, so
  a retired shard's counters survive drain/resize.
* :class:`PhaseTimer` / :class:`NullPhaseTimer` — monotonic span
  accumulation for the engine tick's phases (``schedule / admit /
  dispatch / device_wait / materialize / retire``), per shard and
  aggregate.  The null variant is a reusable no-op context manager:
  telemetry off means **zero span objects allocated** per tick (tests
  assert this via :attr:`PhaseTimer.spans_entered`).
* :class:`EventLog` — seeded-deterministic one-line-JSON records of every
  scheduler/engine *decision* (admit, resume, preempt, migrate, shrink,
  reject, retire, drain, shard lifecycle).  Records carry tick-clock
  fields only, so the same seeded stream replays to byte-identical logs —
  a scheduler-decision regression oracle (``serve_sa --events``).
* :func:`compile_events` — a process-wide ``jax`` compile-hook counter
  (``jax.monitoring`` backend-compile events), the witness that telemetry
  adds **zero compiled programs**.

Everything here is host-side observation: enabling telemetry never
touches a device buffer, an RNG stream, or an admission decision, so
trajectories stay bit-exact (``serve_sa --check --trace ...`` proves it).
The one *timing* perturbation is deliberate: with phase timing enabled
the engine fences each shard's launches with ``jax.block_until_ready``
so host-side launch cost separates from device compute — a measurement
choice, not a semantic one (docs/observability.md).
"""
from __future__ import annotations

import json
import math
import time
from typing import Dict, List, Optional, Sequence, Tuple

#: The engine tick's phase taxonomy, in execution order (docs/observability.md):
#:   schedule     — scheduler planning (placement, migration, shrink, admit plans)
#:   admit        — executing the plans (checkpoint/restore, slot assignment)
#:   dispatch     — host-side packing + async device-program launches
#:   device_wait  — block_until_ready fence: device compute the host waits on
#:   materialize  — device->host transfers + scattering blocks back to slots
#:   retire       — finish checks, result records, slot release
TICK_PHASES = ("schedule", "admit", "dispatch", "device_wait",
               "materialize", "retire")


# --------------------------------------------------------------------- metrics
class Counter:
    """Monotonic counter, optionally labelled."""

    kind = "counter"

    def __init__(self, name: str, help: str, labels: Sequence[str] = ()):
        self.name = name
        self.help = help
        self.labels = tuple(labels)
        self.series: Dict[Tuple, float] = {}

    def _key(self, labelvalues: Tuple) -> Tuple:
        if len(labelvalues) != len(self.labels):
            raise ValueError(
                f"{self.name} expects labels {self.labels}, "
                f"got {labelvalues}")
        return labelvalues

    def inc(self, value: float = 1.0, *labelvalues) -> None:
        if value < 0:
            raise ValueError(f"counter {self.name} cannot decrease")
        key = self._key(labelvalues)
        self.series[key] = self.series.get(key, 0.0) + value

    def value(self, *labelvalues) -> float:
        return self.series.get(self._key(labelvalues), 0.0)

    def snapshot(self) -> dict:
        return {self._fmt(k): v for k, v in sorted(self.series.items())}

    def _fmt(self, key: Tuple) -> str:
        if not self.labels:
            return ""
        return ",".join(f"{n}={v}" for n, v in zip(self.labels, key))

    def expose(self) -> List[str]:
        lines = [f"# HELP {self.name} {self.help}",
                 f"# TYPE {self.name} {self.kind}"]
        for key, v in sorted(self.series.items()):
            lines.append(f"{self.name}{_promlabels(self.labels, key)} {_num(v)}")
        return lines


class Gauge(Counter):
    """Point-in-time value, optionally labelled."""

    kind = "gauge"

    def set(self, value: float, *labelvalues) -> None:
        self.series[self._key(labelvalues)] = float(value)

    def inc(self, value: float = 1.0, *labelvalues) -> None:
        key = self._key(labelvalues)
        self.series[key] = self.series.get(key, 0.0) + value


class Histogram:
    """Streaming distribution: exponential buckets + count/sum/min/max.

    Quantiles are estimated by log-linear interpolation inside the bucket
    the cumulative count lands in — O(n_buckets) memory regardless of how
    many observations stream through, and fully deterministic (no
    reservoir sampling).  Bucket error is bounded by ``growth`` (default
    1.25: <= 12% relative error on any quantile), which is ample for
    localizing where milliseconds go.
    """

    kind = "histogram"

    def __init__(self, name: str, help: str, labels: Sequence[str] = (),
                 lo: float = 1e-6, hi: float = 1e3, growth: float = 1.25):
        if not (0 < lo < hi and growth > 1):
            raise ValueError("need 0 < lo < hi and growth > 1")
        self.name = name
        self.help = help
        self.labels = tuple(labels)
        self.lo, self.growth = lo, growth
        n = int(math.ceil(math.log(hi / lo) / math.log(growth)))
        #: bucket b spans [lo*growth^(b-1), lo*growth^b); bucket 0 is
        #: (-inf, lo); bucket n+1 is the +inf overflow.
        self.n_buckets = n + 2
        self.series: Dict[Tuple, dict] = {}

    def _state(self, labelvalues: Tuple) -> dict:
        if len(labelvalues) != len(self.labels):
            raise ValueError(
                f"{self.name} expects labels {self.labels}, "
                f"got {labelvalues}")
        st = self.series.get(labelvalues)
        if st is None:
            st = self.series[labelvalues] = {
                "buckets": [0] * self.n_buckets, "count": 0, "sum": 0.0,
                "min": float("inf"), "max": float("-inf")}
        return st

    def _bucket(self, v: float) -> int:
        if v < self.lo:
            return 0
        b = 1 + int(math.log(v / self.lo) / math.log(self.growth))
        return min(b, self.n_buckets - 1)

    def _edge(self, b: int) -> float:
        """Upper edge of bucket ``b``."""
        if b == 0:
            return self.lo
        return self.lo * self.growth ** b

    def observe(self, value: float, *labelvalues) -> None:
        st = self._state(labelvalues)
        st["buckets"][self._bucket(value)] += 1
        st["count"] += 1
        st["sum"] += value
        st["min"] = min(st["min"], value)
        st["max"] = max(st["max"], value)

    def quantile(self, q: float, *labelvalues) -> float:
        """Estimated q-quantile (q in [0, 1]); nan with no observations."""
        st = self.series.get(tuple(labelvalues))
        if st is None or not st["count"]:
            return float("nan")
        rank = q * st["count"]
        seen = 0
        for b, n in enumerate(st["buckets"]):
            if n and seen + n >= rank:
                lo_edge = self._edge(b - 1) if b else st["min"]
                hi_edge = self._edge(b)
                frac = (rank - seen) / n
                est = lo_edge + (hi_edge - lo_edge) * frac
                return float(min(max(est, st["min"]), st["max"]))
            seen += n
        return float(st["max"])

    def summary(self, *labelvalues) -> dict:
        st = self.series.get(tuple(labelvalues))
        if st is None or not st["count"]:
            return {"count": 0, "sum": 0.0}
        return {
            "count": st["count"], "sum": st["sum"],
            "min": st["min"], "max": st["max"],
            "mean": st["sum"] / st["count"],
            "p50": self.quantile(0.50, *labelvalues),
            "p90": self.quantile(0.90, *labelvalues),
            "p99": self.quantile(0.99, *labelvalues),
        }

    def snapshot(self) -> dict:
        out = {}
        for key in sorted(self.series):
            label = ",".join(f"{n}={v}" for n, v in zip(self.labels, key))
            out[label] = self.summary(*key)
        return out

    def expose(self) -> List[str]:
        lines = [f"# HELP {self.name} {self.help}",
                 f"# TYPE {self.name} summary"]
        for key, st in sorted(self.series.items()):
            for q in (0.5, 0.9, 0.99):
                qlabels = _promlabels(
                    self.labels + ("quantile",), key + (f"{q:g}",))
                lines.append(
                    f"{self.name}{qlabels} {_num(self.quantile(q, *key))}")
            base = _promlabels(self.labels, key)
            lines.append(f"{self.name}_sum{base} {_num(st['sum'])}")
            lines.append(f"{self.name}_count{base} {st['count']}")
        return lines


def _promlabels(names: Sequence[str], values: Tuple) -> str:
    if not names:
        return ""
    body = ",".join(f'{n}="{v}"' for n, v in zip(names, values))
    return "{" + body + "}"


def _num(v: float) -> str:
    if isinstance(v, float) and math.isnan(v):
        return "NaN"
    return f"{v:.9g}" if isinstance(v, float) else str(v)


class MetricsRegistry:
    """Named metric store with Prometheus text + JSON export.

    Metric creation is idempotent (``counter(name)`` returns the existing
    series on a repeat call) so engine layers can declare what they need
    without coordinating.  Per-shard series carry the stable shard index
    as a label — shard retirement never deletes a series, which is how
    metrics survive drain/resize (tests assert it).
    """

    def __init__(self):
        self._metrics: Dict[str, object] = {}

    def _get(self, cls, name: str, help: str, labels: Sequence[str], **kw):
        m = self._metrics.get(name)
        if m is None:
            m = self._metrics[name] = cls(name, help, labels, **kw)
        elif not isinstance(m, cls) or m.labels != tuple(labels):
            raise ValueError(f"metric {name} re-registered with a different "
                             "type or label set")
        return m

    def counter(self, name: str, help: str = "",
                labels: Sequence[str] = ()) -> Counter:
        return self._get(Counter, name, help, labels)

    def gauge(self, name: str, help: str = "",
              labels: Sequence[str] = ()) -> Gauge:
        return self._get(Gauge, name, help, labels)

    def histogram(self, name: str, help: str = "",
                  labels: Sequence[str] = (), **kw) -> Histogram:
        return self._get(Histogram, name, help, labels, **kw)

    def __contains__(self, name: str) -> bool:
        return name in self._metrics

    def __getitem__(self, name: str):
        return self._metrics[name]

    def names(self) -> List[str]:
        return sorted(self._metrics)

    def snapshot(self) -> dict:
        """JSON-ready dump: name -> {kind, series} (``serve_sa --json``)."""
        return {name: {"kind": m.kind, "help": m.help,
                       "series": m.snapshot()}
                for name, m in sorted(self._metrics.items())}

    def exposition(self) -> str:
        """Prometheus text format (one scrape page)."""
        lines: List[str] = []
        for name in sorted(self._metrics):
            lines.extend(self._metrics[name].expose())
        return "\n".join(lines) + "\n"


# ---------------------------------------------------------------- phase timers
class PhaseTimer:
    """Accumulates monotonic spans per (phase, shard) within one tick.

    Used as a reusable context manager::

        with timer("dispatch", shard=3):
            ...

    Spans never nest (the tick's phases are sequential), so one instance
    re-enters itself — no object allocation per span.  ``drain()`` returns
    and resets the accumulated (aggregate, per-shard, raw span, host-CPU)
    state; the engine folds it into histograms / trace events at tick end.

    Each span records **two** clocks: monotonic wall time and the host
    thread's CPU time (``time.thread_time``).  On a host core dedicated to
    the engine loop the two agree; when the host shares cores with device
    compute threads (CPU backend, oversubscribed CI runners) wall spans
    absorb whatever work the OS timesliced in, while thread-CPU counts
    only cycles the engine loop itself burned — the durable measure of
    host-side cost per phase.
    """

    #: Class-wide count of spans ever entered — the zero-overhead witness:
    #: with telemetry disabled this must not move (tests assert it).
    spans_entered = 0

    __slots__ = ("_clock", "acc", "shard_acc", "raw", "cpu_acc", "keep_raw",
                 "_phase", "_shard", "_t0", "_c0")

    def __init__(self, clock, keep_raw: bool = False):
        self._clock = clock         # monotonic epoch-relative seconds
        self.keep_raw = keep_raw    # record (phase, shard, t0, t1) spans
        self.acc: Dict[str, float] = {}
        self.shard_acc: Dict[Tuple[int, str], float] = {}
        self.raw: List[Tuple[str, Optional[int], float, float]] = []
        self.cpu_acc: Dict[str, float] = {}

    def __call__(self, phase: str, shard: Optional[int] = None):
        self._phase, self._shard = phase, shard
        return self

    def __enter__(self):
        PhaseTimer.spans_entered += 1
        self._t0 = self._clock()
        self._c0 = time.thread_time()
        return self

    def __exit__(self, *exc):
        dc = time.thread_time() - self._c0
        t1 = self._clock()
        dt = t1 - self._t0
        self.acc[self._phase] = self.acc.get(self._phase, 0.0) + dt
        self.cpu_acc[self._phase] = self.cpu_acc.get(self._phase, 0.0) + dc
        if self._shard is not None:
            key = (self._shard, self._phase)
            self.shard_acc[key] = self.shard_acc.get(key, 0.0) + dt
        if self.keep_raw:
            self.raw.append((self._phase, self._shard, self._t0, t1))
        return False

    def drain(self):
        acc, shard_acc, raw, cpu = (self.acc, self.shard_acc, self.raw,
                                    self.cpu_acc)
        self.acc, self.shard_acc, self.raw, self.cpu_acc = {}, {}, [], {}
        return acc, shard_acc, raw, cpu


class NullPhaseTimer:
    """No-op spans: one shared instance, no state, no allocation."""

    __slots__ = ()

    def __call__(self, phase, shard=None):
        return self

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False

    def drain(self):
        return {}, {}, [], {}


NULL_PHASE_TIMER = NullPhaseTimer()


# ------------------------------------------------------------------ event log
class EventLog:
    """Deterministic one-line-JSON decision log.

    Every record is ``{"tick": int, "event": str, ...}`` with tick-clock
    fields only — no wall time, no object ids — so the same seeded stream
    produces byte-identical logs run-to-run (the scheduler-decision
    regression oracle).  Keys are emitted sorted; one record per line
    (JSONL, ``serve_sa --events out.jsonl``).
    """

    def __init__(self):
        self.records: List[dict] = []

    def emit(self, tick: int, event: str, **fields) -> None:
        rec = {"tick": int(tick), "event": event}
        rec.update(fields)
        self.records.append(rec)

    def lines(self) -> List[str]:
        return [json.dumps(r, sort_keys=True, separators=(",", ":"))
                for r in self.records]

    def dumps(self) -> str:
        return "\n".join(self.lines()) + ("\n" if self.records else "")

    @staticmethod
    def loads(text: str) -> List[dict]:
        """Parse a JSONL log back into records (the replay side)."""
        return [json.loads(line) for line in text.splitlines() if line]


# ---------------------------------------------------------- jax compile hook
_COMPILE_EVENTS = {"count": 0}
_HOOK_INSTALLED = False

#: jax.monitoring duration-event key emitted once per backend (XLA)
#: compilation — the ground truth for "telemetry adds zero compiled
#: programs".  Internal jits count too, which is fine for a delta test.
_BACKEND_COMPILE_EVENT = "/jax/core/compile/backend_compile_duration"


def _install_compile_hook() -> None:
    global _HOOK_INSTALLED
    if _HOOK_INSTALLED:
        return
    try:
        import jax.monitoring as _mon

        def _listener(name, secs, **kw):
            if name == _BACKEND_COMPILE_EVENT:
                _COMPILE_EVENTS["count"] += 1

        _mon.register_event_duration_secs_listener(_listener)
        _HOOK_INSTALLED = True
    except Exception:        # pragma: no cover - very old jax: counter stays 0
        pass


def compile_events() -> int:
    """Process-wide count of XLA backend compilations observed so far.

    Installs the (idempotent, listener-only) ``jax.monitoring`` hook on
    first call.  Compare before/after a run to prove a feature added no
    compiled programs — telemetry's own acceptance test does exactly that.
    """
    _install_compile_hook()
    return _COMPILE_EVENTS["count"]


# ------------------------------------------------------------------- facade
class Telemetry:
    """The engine's observability bundle: metrics + spans + trace + events.

    Construct one and hand it to :class:`~repro.service.engine.SAServeEngine`;
    the default is the module-level :data:`NULL` singleton, whose every
    hook is a no-op — the disabled path allocates no span objects and
    registers no metrics (zero overhead, bit-for-bit identical behavior).

    ``trace`` is an optional
    :class:`~repro.service.trace.TraceBuilder`; when set, per-phase tick
    spans and request lifecycle events are recorded for Perfetto.
    ``events`` is an optional :class:`EventLog` for the deterministic
    decision log.  Phase *fencing* (the ``device_wait`` separation via
    ``block_until_ready``) is implied by ``enabled``.
    """

    enabled = True

    def __init__(self, trace=None, events: Optional[EventLog] = None):
        self.registry = MetricsRegistry()
        self.trace = trace
        self.events = events
        self.compile_events_start = compile_events()
        # Declared up front so an exposition before the first tick is
        # well-formed, and so layer code can .inc() without re-declaring.
        r = self.registry
        self.m_tick_phase = r.histogram(
            "sa_tick_phase_seconds",
            "Wall seconds per engine tick phase", ("phase",))
        self.m_shard_phase = r.counter(
            "sa_shard_phase_seconds_total",
            "Cumulative wall seconds per shard per tick phase",
            ("shard", "phase"))
        self.m_phase_cpu = r.counter(
            "sa_tick_phase_cpu_seconds_total",
            "Cumulative host-thread CPU seconds per tick phase "
            "(thread_time: excludes time the OS gave to other threads)",
            ("phase",))
        self.m_tick = r.histogram(
            "sa_tick_seconds", "Wall seconds per engine tick")
        self.m_ticks = r.counter("sa_ticks_total", "Engine ticks executed")
        self.m_queue_depth = r.gauge(
            "sa_queue_depth", "Requests waiting in the admission queue")
        self.m_active = r.gauge(
            "sa_active_requests", "Requests resident in slots")
        self.m_slot_occupancy = r.gauge(
            "sa_slot_occupancy", "Fraction of fleet slots held by tenants")
        self.m_shard_slots_used = r.gauge(
            "sa_shard_slots_used", "Slots held per shard", ("shard",))
        self.m_decisions = r.counter(
            "sa_scheduler_decisions_total",
            "Scheduler/engine lifecycle decisions", ("decision",))
        self.m_tenant_slot_ticks = r.counter(
            "sa_tenant_slot_ticks_total",
            "Slot-ticks consumed per tenant (the fairness currency)",
            ("req_id",))
        self.m_compile_events = r.counter(
            "sa_jax_compile_events_total",
            "XLA backend compilations observed since engine construction")
        self.m_launches = r.counter(
            "sa_group_launches_total", "Device-program launches")
        self.m_plans = r.counter(
            "sa_scheduler_plans_total",
            "Actions planned per scheduler planner", ("plan",))

    # -- hooks the engine calls (every one a no-op on NullTelemetry) --
    def make_phase_timer(self, clock) -> PhaseTimer:
        return PhaseTimer(clock, keep_raw=self.trace is not None)

    def decision(self, tick: int, kind: str, **fields) -> None:
        """Record one scheduler/engine decision: counter + event record.
        (Trace instants are emitted separately by the engine, on the
        request's own async track.)"""
        self.m_decisions.inc(1, kind)
        if self.events is not None:
            self.events.emit(tick, kind, **fields)

    def plan(self, kind: str, n_actions: int) -> None:
        """Scheduler hook: ``n_actions`` planned by planner ``kind``."""
        self.m_plans.inc(n_actions, kind)

    def end_tick(self, tick: int, acc, shard_acc, raw, shards,
                 queue_depth: int, n_active: int, levels: int = 1,
                 cpu=None) -> None:
        """Fold one tick's (drained) spans + fleet state into the
        registry and trace.

        ``levels`` is how many ladder levels the engine tick advanced (the
        macro-tick factor K when work ran fused, 1 otherwise):
        ``sa_ticks_total`` counts ladder levels, keeping it equal to the
        engine's ``tick_count`` clock at any K.  ``cpu`` is the tick's
        per-phase host-thread CPU seconds (the PhaseTimer's second clock).
        """
        total = 0.0
        for phase, secs in acc.items():
            self.m_tick_phase.observe(secs, phase)
            total += secs
        for (shard, phase), secs in shard_acc.items():
            self.m_shard_phase.inc(secs, str(shard), phase)
        for phase, secs in (cpu or {}).items():
            self.m_phase_cpu.inc(secs, phase)
        if total:
            self.m_tick.observe(total)
        self.m_ticks.inc(levels)
        self.m_queue_depth.set(queue_depth)
        self.m_active.set(n_active)
        used = held = 0
        for s in shards:
            used += s.pool.n_active
            held += s.pool.n_slots
            self.m_shard_slots_used.set(s.pool.n_active, str(s.index))
        self.m_slot_occupancy.set(used / held if held else 0.0)
        self.m_compile_events.series[()] = float(
            compile_events() - self.compile_events_start)
        if self.trace is not None:
            for phase, shard, t0, t1 in raw:
                self.trace.span(phase, t0, t1, shard=shard, tick=tick)

    def tenant_slot_ticks(self, req_id: int, n_slots: int) -> None:
        self.m_tenant_slot_ticks.inc(n_slots, str(req_id))


class NullTelemetry:
    """Telemetry off: every hook is a no-op, nothing is allocated."""

    enabled = False
    trace = None
    events = None
    registry = None

    __slots__ = ()

    def make_phase_timer(self, clock):
        return NULL_PHASE_TIMER

    def decision(self, tick, kind, **fields):
        pass

    def plan(self, kind, n_actions):
        pass

    def end_tick(self, tick, acc, shard_acc, raw, shards, queue_depth,
                 n_active, levels=1, cpu=None):
        pass

    def tenant_slot_ticks(self, req_id, n_slots):
        pass


#: The default for every engine: observability off, zero overhead.
NULL = NullTelemetry()
