"""Sharded slot pool: one engine shard per mesh device.

The paper's synchronous SA wins because it scales with the device's
parallelism — but a single slot pool caps the serving engine at one
device's worth of chain blocks.  This module shards the pool over a 1-D
``(pool,)`` JAX device mesh (launch/mesh.py): :class:`EngineShard` pairs
one device with a private :class:`~repro.service.slots.SlotPool` and
:class:`~repro.service.slots.RidTable`, and the engine runs each shard's
dispatch groups as *independent device programs* — one per
``(shard, family, dim, N)`` — so shards anneal concurrently (JAX async dispatch
overlaps the launches) and compile counts stay bounded per device exactly
as they were for the single pool.

Why shards are private, not a ``shard_map`` over one global pool:

* **Tenant state is ragged.**  Slots hold heterogeneous ``(dim,)`` blocks
  and join different ``(dim, N)`` dispatch groups each tick; a collective
  program over the union would re-introduce the straggler coupling the
  continuous-batching design exists to avoid.
* **Migration wants checkpoints, not collectives.**  Russkov et al.
  (arXiv:2006.00561) redistribute replicas between accelerators by moving
  their state; our :class:`~repro.service.slots.SwappedJob` checkpoint is
  already bit-exact and placement-invariant (counter-based RNG on logical
  chain coordinates), so moving a job between shards is checkpoint-on-A /
  restore-on-B with zero trajectory perturbation — the scheduler treats
  cross-shard rebalancing exactly like preemption's swap-to-host, minus
  the queue round-trip.

Placement itself (which shard a request calls home) lives in the
scheduler (scheduler.py: ``place`` / ``plan_migrations``); this module
only knows about devices and per-shard state.
"""
from __future__ import annotations

import dataclasses
from typing import List

import jax

from repro.launch.mesh import slot_pool_mesh
from repro.service.slots import RidTable, SlotPool


def slot_pool_devices(n_shards: int) -> List[object]:
    """The devices backing ``n_shards`` engine shards.

    Uses the 1-D ``(pool,)`` mesh when enough physical devices exist
    (``XLA_FLAGS=--xla_force_host_platform_device_count=N`` provides them
    on CPU).  When oversubscribed, logical shards round-robin over the
    devices that do exist: placement, migration and accounting behave
    identically — only true parallel dispatch is lost — so the sharding
    logic stays testable on a single-device host.
    """
    if n_shards < 1:
        raise ValueError(f"n_shards must be >= 1, got {n_shards}")
    devices = jax.devices()
    if n_shards <= len(devices):
        return list(slot_pool_mesh(n_shards).devices.reshape(-1))
    return [devices[i % len(devices)] for i in range(n_shards)]


@dataclasses.dataclass
class EngineShard:
    """One device's slice of the serving state.

    A shard owns a private slot pool and rid table; rids (segment ids in
    the masked champion exchange) are shard-local, which keeps the
    segmented reduce identical to the single-pool engine.  Dispatch
    groups never span shards — each shard's groups compile and launch on
    its own device.

    The fleet is *elastic* (engine.py ``drain``/``resize``): a shard
    marked ``draining`` accepts no new placements while the engine
    checkpoint-evacuates its jobs onto the survivors, and is retired —
    removed from the fleet — once empty.  Shard ``index`` is therefore a
    stable identity, not a list position: retired indices are never
    reused, and shards added later get fresh indices.
    """

    index: int                  # stable shard id (never reused)
    device: object              # jax.Device the shard's programs run on
    pool: SlotPool
    rids: RidTable
    sweeps_done: int = 0        # block-sweeps on this shard (utilization
                                # numerator for per-shard occupancy)
    resident_ticks: int = 0     # engine ticks this shard was in the fleet
                                # (utilization denominator — shards may
                                # join/leave mid-run)
    draining: bool = False      # no new placements; evacuating to retire
    phase_seconds: dict = dataclasses.field(default_factory=dict)
                                # cumulative wall seconds per tick phase
                                # (telemetry.py); empty when telemetry is
                                # off — populated by the engine's
                                # per-shard span folding
    group_cache: dict = dataclasses.field(default_factory=dict)
                                # (family, dim, N) -> {"buf": device array,
                                # "n_padded": int}: the fused macro-tick
                                # path's double buffer.  When a group's
                                # membership is unchanged since its last
                                # launch (every slot still references this
                                # buffer at its packed rows), the host
                                # repack + transfer is skipped and the
                                # buffer is donated straight back to the
                                # next launch (engine._launch_group_fused)

    @property
    def jobs(self):
        """rid -> ActiveJob resident on this shard."""
        return self.rids.jobs

    def occupancy(self, ticks: int = 0) -> float:
        """Fraction of this shard's slot-ticks spent sweeping.  Uses the
        shard's own residency by default (elastic fleets: shards join and
        leave mid-run); pass ``ticks`` to override the denominator."""
        denom = ticks if ticks else self.resident_ticks
        return self.sweeps_done / (max(denom, 1) * self.pool.n_slots)


def make_shard(index: int, n_slots: int, chains_per_slot: int) -> EngineShard:
    """Build one shard on the device backing ``index`` (round-robin over
    the physical devices — the elastic-fleet grow path, where shards are
    added one at a time with fresh indices)."""
    devices = jax.devices()
    return EngineShard(index=index, device=devices[index % len(devices)],
                       pool=SlotPool(n_slots, chains_per_slot),
                       rids=RidTable(n_slots))


def make_shards(n_devices: int, n_slots: int,
                chains_per_slot: int) -> List[EngineShard]:
    """Build the engine's shard list: ``n_slots`` slots *per shard*."""
    return [EngineShard(index=i, device=dev,
                        pool=SlotPool(n_slots, chains_per_slot),
                        rids=RidTable(n_slots))
            for i, dev in enumerate(slot_pool_devices(n_devices))]
