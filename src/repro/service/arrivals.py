"""Arrival processes for open-loop serving (the streaming frontend).

The PR-1 engine was *closed-loop*: the whole request mix was enqueued up
front, so queueing delay only measured pool contention.  Open-loop serving
offers requests on a timeline instead — the load generator does not wait
for the system — which is how serving systems are actually benchmarked
(and how Russkov et al.'s replica-redistribution setting measures admission
latency under live load).

Time is measured in **engine ticks** — temperature levels, the engine's
natural clock (one macro-tick advances it by the levels it consumed, so
the unit is K-invariant).  Arrival timestamps may be
fractional; a request with arrival time ``t`` becomes visible to the
scheduler at the first tick ``>= t``.  Everything here is host-side numpy
and deterministic under a fixed seed, so latency distributions are
reproducible bit-for-bit — tests assert on them.

Three constructors:

* :meth:`ArrivalProcess.poisson` — exponential inter-arrival gaps at
  ``rate`` requests/tick (the M/G/c-style offered load).
* :meth:`ArrivalProcess.trace`  — explicit timestamps (replay a recorded
  trace).
* :meth:`ArrivalProcess.batch`  — everything at t=0 (the closed-loop
  special case; ``engine.run`` is equivalent).
"""
from __future__ import annotations

from typing import Dict, Iterable, List, Optional, Sequence, Tuple

import numpy as np

from repro.service.request import RequestResult, SARequest


class ArrivalProcess:
    """A time-ordered stream of ``(arrival_time, SARequest)`` pairs."""

    def __init__(self, requests: Sequence[SARequest],
                 times: Sequence[float]):
        if len(requests) != len(times):
            raise ValueError(
                f"{len(requests)} requests vs {len(times)} arrival times")
        order = np.argsort(np.asarray(times, np.float64), kind="stable")
        self._items: List[Tuple[float, SARequest]] = [
            (float(times[i]), requests[i]) for i in order]
        self._next = 0

    # ------------------------------------------------------------- factories
    @classmethod
    def poisson(cls, requests: Sequence[SARequest], rate: float,
                seed: int = 0) -> "ArrivalProcess":
        """Seeded Poisson arrivals at ``rate`` requests per engine tick."""
        if rate <= 0:
            raise ValueError(f"rate must be positive, got {rate}")
        rng = np.random.default_rng(seed)
        gaps = rng.exponential(1.0 / rate, size=len(requests))
        return cls(requests, np.cumsum(gaps))

    @classmethod
    def bursty(cls, requests: Sequence[SARequest], rate: float,
               burst: int = 4, seed: int = 0) -> "ArrivalProcess":
        """Seeded bursty arrivals: groups of ``burst`` requests land at one
        instant, with exponential gaps between instants scaled so the
        long-run offered load is still ``rate`` requests/tick.  The
        overload generator for admission-control tests: micro-bursts force
        transient saturation even when the mean load is sustainable.
        """
        if rate <= 0:
            raise ValueError(f"rate must be positive, got {rate}")
        if burst < 1:
            raise ValueError(f"burst must be >= 1, got {burst}")
        rng = np.random.default_rng(seed)
        n_bursts = -(-len(requests) // burst)
        starts = np.cumsum(rng.exponential(burst / rate, size=n_bursts))
        return cls(requests,
                   [float(starts[i // burst]) for i in range(len(requests))])

    @classmethod
    def diurnal(cls, requests: Sequence[SARequest], rate: float,
                period: float = 200.0, amplitude: float = 0.8,
                seed: int = 0) -> "ArrivalProcess":
        """Seeded diurnal load: an inhomogeneous Poisson process whose
        intensity swings sinusoidally around ``rate`` —
        ``lambda(t) = rate * (1 + amplitude * sin(2*pi*t/period))`` —
        the day/night envelope autoscaler benchmarks provision against
        (peak demand is ``(1+amplitude)x`` the mean, the trough
        ``(1-amplitude)x``).

        Sampled by time-warping a unit-rate Poisson process through the
        inverse cumulative intensity: with
        ``Lambda(t) = rate*t + rate*amplitude*period/(2*pi)
        * (1 - cos(2*pi*t/period))`` (non-decreasing for amplitude <= 1),
        unit-exponential cumulative sums ``s_i`` map to arrivals
        ``t_i = Lambda^{-1}(s_i)`` — inverted numerically on a fine grid,
        deterministic under ``seed``.
        """
        if rate <= 0:
            raise ValueError(f"rate must be positive, got {rate}")
        if period <= 0:
            raise ValueError(f"period must be positive, got {period}")
        if not 0.0 <= amplitude <= 1.0:
            raise ValueError(
                f"amplitude must be in [0, 1], got {amplitude}")
        rng = np.random.default_rng(seed)
        s = np.cumsum(rng.exponential(1.0, size=len(requests)))
        # Grid out to where Lambda certainly exceeds the last event (the
        # trough can run as slow as rate*(1-amplitude), but Lambda over a
        # whole period always averages `rate`, so s_max/rate + one period
        # bounds the horizon), 64 points per period for interp accuracy.
        horizon = (float(s[-1]) / rate if len(s) else 1.0) + period
        grid = np.linspace(0.0, horizon,
                           max(2, int(64 * horizon / period)))
        big_l = rate * grid + (rate * amplitude * period / (2 * np.pi)
                               * (1.0 - np.cos(2 * np.pi * grid / period)))
        return cls(requests, np.interp(s, big_l, grid))

    @classmethod
    def trace(cls, requests: Sequence[SARequest],
              times: Iterable[float]) -> "ArrivalProcess":
        """Replay explicit arrival timestamps (ticks)."""
        return cls(requests, list(times))

    @classmethod
    def batch(cls, requests: Sequence[SARequest]) -> "ArrivalProcess":
        """All requests offered at t=0 — the closed-loop special case."""
        return cls(requests, [0.0] * len(requests))

    # --------------------------------------------------------------- queries
    def __len__(self) -> int:
        return len(self._items)

    @property
    def exhausted(self) -> bool:
        return self._next >= len(self._items)

    @property
    def next_time(self) -> float:
        """Arrival time of the next undelivered request (inf if none)."""
        if self.exhausted:
            return float("inf")
        return self._items[self._next][0]

    def due(self, now: float) -> List[Tuple[float, SARequest]]:
        """Pop every request with ``arrival_time <= now`` (time order)."""
        out: List[Tuple[float, SARequest]] = []
        while not self.exhausted and self._items[self._next][0] <= now:
            out.append(self._items[self._next])
            self._next += 1
        return out


def percentile(values: Sequence[float], q: float) -> float:
    """np.percentile with an empty-input guard (returns nan)."""
    arr = np.asarray([v for v in values if np.isfinite(v)], np.float64)
    return float(np.percentile(arr, q)) if arr.size else float("nan")


def latency_summary(results: Sequence[RequestResult],
                    ticks: int = 0,
                    n_submitted: Optional[int] = None) -> Dict[str, float]:
    """Aggregate open-loop latency metrics over completed requests.

    Tick-clock percentiles (p50/p99 queueing delay, time-to-first-tick,
    end-to-end latency) are deterministic under a fixed arrival seed;
    goodput is completed requests per tick.  Wall-clock medians ride along
    for operators (nan when requests were submitted without wall stamps).

    Only *completed* requests enter the latency percentiles and goodput —
    a rejected request has no admission to measure; it is counted (and its
    preemptions summed) separately, so the reject policy cannot launder its
    drops into better-looking latency numbers unnoticed.

    Terminal accounting is **typed**: ``rejected`` counts only results
    whose status is the 'rejected' terminal — never a complement like
    ``len(results) - completed``, which would lump any future non-rejected
    terminal in with SLO drops.  Requests still in flight (or queued, or
    swapped out) when a ``--max-ticks`` horizon cut the run short have no
    terminal result at all; pass ``n_submitted`` (e.g.
    ``engine.n_submitted``) to surface them as ``incomplete`` instead of
    letting overload benchmarks overstate drops.
    """
    done = [r for r in results if r.completed]
    rejected = [r for r in results if r.status == "rejected"]
    qd = [r.queue_delay_ticks for r in done]
    tt = [r.ttft_ticks for r in done]
    lat = [r.latency_ticks for r in done]
    return {
        "completed": len(done),
        "rejected": len(rejected),
        "incomplete": (max(0, n_submitted - len(results))
                       if n_submitted is not None else 0),
        # Preemptions over ALL terminated requests: evicted-then-rejected
        # work is real preemption churn and must stay visible.
        "preemptions": sum(r.n_preemptions for r in results),
        "migrations": sum(r.n_migrations for r in results),
        "truncations": sum(r.n_truncations for r in results),
        "queue_delay_p50": percentile(qd, 50),
        "queue_delay_p99": percentile(qd, 99),
        "ttft_p50": percentile(tt, 50),
        "ttft_p99": percentile(tt, 99),
        "latency_p50": percentile(lat, 50),
        "latency_p99": percentile(lat, 99),
        "goodput_req_per_tick": (len(done) / ticks) if ticks else
        float("nan"),
        "queue_delay_wall_p50_s": percentile(
            [r.queue_delay_wall_s for r in done], 50),
        "latency_wall_p50_s": percentile(
            [r.latency_wall_s for r in done], 50),
    }
