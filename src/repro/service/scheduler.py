"""Admission/packing scheduler for the SA serving engine.

Continuous batching needs two decisions per tick: *which* queued requests to
admit, and *whether* to hold slots back for a large request that cannot fit
yet.  The base policy is priority-with-aging plus bounded backfill:

* effective priority = static priority + ``aging`` x ticks queued, so a
  low-priority request cannot starve forever (the fairness half of
  Russkov-style replica redistribution: the pool keeps being re-packed as
  ladders finish at different times);
* requests are scanned in effective-priority order and admitted greedily
  while they fit (*backfill*: a small request may overtake a large one that
  is short on slots, keeping occupancy high);
* once the head-of-line request has waited more than ``hol_patience`` ticks,
  backfill past it stops, letting freed slots accumulate until it fits —
  bounded head-of-line starvation instead of either extreme.

On top of that sit the **overload policies** (per request class via
``SARequest.on_overload``, defaulting to ``SchedulerConfig.overload``),
which decide what happens when a request cannot be admitted at full width:

* ``reject``  — SLO fast-fail: once the request has queued longer than its
  ``deadline`` (ticks; ``deadline=0`` means *admit now or never*) it is
  dropped with a typed 'rejected' status.  This bounds both queue length
  and the queueing delay of everything that *is* admitted.
* ``degrade`` — admit immediately with fewer chains, down to the request's
  ``min_chains`` floor (rounded up to whole slots; one slot if unset).
  Champion exchange scales with it automatically (the segmented reduce runs
  over whatever blocks the request holds), and the run is bit-exact with a
  standalone run at the granted chain count.  The ``reject`` deadline is
  kept as a backstop — if even the floor cannot be admitted in time the
  request is dropped — so degrade also bounds queue growth.
* ``preempt`` — evict the lowest-effective-priority active job(s) whose
  effective priority is *strictly* below the candidate's, bounded by
  ``preemption_budget`` evictions per tick, checkpoint them to host
  (:class:`~repro.service.slots.SwappedJob`) and re-queue them for a
  bit-exact resume.  Because every job ages at the same rate, preemption
  order is stable — no eviction/resume thrash cycles.  Surplus slots an
  eviction frees beyond the urgent arrival's need are reserved for work
  that outranks the victims for the rest of the tick: eviction never
  directly funds a lower-priority admission (from the next tick on the
  ordinary backfill/aging/hol rules govern them again).

With the slot pool sharded over a device mesh (sharding.py), the
scheduler additionally owns the **placement layer**:

* :meth:`AdmissionScheduler.place` orders the shards for each tick's
  admission scans — least-loaded first, with a locality tie-break toward
  a shard already running the queue head's ``(family, dim, N)`` dispatch
  shape — so every admitted request's *home shard* is the emptiest
  compatible one, deterministically;
* :meth:`AdmissionScheduler.plan_migrations` rebalances à la Russkov
  et al. (arXiv:2006.00561): when the queue head fits on no single shard
  but the pool as a whole has room, it plans bounded cross-shard moves
  (checkpoint on the donor, restore on the recipient — bit-exact, since
  restore is placement-invariant) until the head is admissible.

The **elastic-fleet layer** (this PR) extends placement in three ways,
all riding the same bit-exact ``SwappedJob`` checkpoint/restore:

* :meth:`AdmissionScheduler.plan_evacuation` — shard drain.  Jobs on a
  draining shard are moved onto the survivors in effective-priority
  order (highest first: the most important work is off the doomed
  device soonest), bounded per tick.  A job no survivor can seat whole
  is *shrunk into* the roomiest survivor if its overload class allows
  (down to its ``min_chains`` floor), and swapped out to the queue as
  the last resort — drain always makes progress and never loses work.
* :meth:`AdmissionScheduler.plan_rebalance` — watermark rebalancing.
  Generalizes head-of-queue defrag into a *background* load balancer:
  every tick, narrow jobs are moved from shards whose utilization
  exceeds ``high_watermark`` onto shards below ``low_watermark``.
  Hysteresis is structural: a move is only planned when the donor stays
  at least as loaded as the recipient afterwards, so the load ordering
  never inverts and a later tick can never plan the reverse move.
* :meth:`AdmissionScheduler.plan_shrinks` — proactive degrade.  When
  the queue head fits on no shard and migration cannot help (the pool
  is genuinely full), *running* degrade-class jobs of strictly lower
  effective priority are shrunk in place (checkpoint -> restore at
  fewer slots, never below their floor) until the head seats — the
  admission-time 'degrade' policy applied to work already in flight.

Invariants
----------
* The scheduler never over-commits: the slots granted by one ``admit()``
  plan are <= the ``free_slots`` it was offered plus the slots released by
  the evictions in the same plan.
* Admission order is deterministic: effective-priority sort is stable with
  ties broken by submission order, so a fixed (request mix, arrival seed)
  reproduces the exact same packing — the foundation of the engine's
  reproducible latency distributions.
* Swapped (preempted) jobs are *admitted work*: they resume at exactly
  their granted width and are never rejected or degraded — only delayed.
* Scheduling is objective-blind.  Since the kernels dispatch the objective
  id at runtime, co-batching never constrains *which* requests may share a
  device program — only shape ``(family, dim, N)`` does (the family picks
  the sweep kernel and state dtype), and that grouping happens downstream
  in the engine.
* The scheduler holds only queue entries ``(request, submit_tick, swapped
  checkpoint)``; open-loop arrival timestamps live in the engine's
  lifecycle records (engine.py), so queue policy and load generation stay
  decoupled.
"""
from __future__ import annotations

import dataclasses
import functools
import math
from typing import FrozenSet, List, Optional, Sequence, Tuple

from repro.service.request import OVERLOAD_POLICIES, SARequest
from repro.service.slots import ActiveJob, SwappedJob
from repro.service.telemetry import NULL as NULL_TELEMETRY


def _planned(kind: str):
    """Report a planner's action count to the scheduler's telemetry
    (``sa_scheduler_plans_total{plan=kind}``).  A no-op call when
    telemetry is off (the default ``NULL`` bundle)."""
    def deco(fn):
        @functools.wraps(fn)
        def wrapper(self, *args, **kwargs):
            out = fn(self, *args, **kwargs)
            self.telemetry.plan(kind, len(out))
            return out
        return wrapper
    return deco


@dataclasses.dataclass(frozen=True)
class SchedulerConfig:
    policy: str = "priority"    # 'priority' (aged) | 'fifo'
    aging: float = 0.05         # priority points per queued tick
    hol_patience: int = 16      # ticks the head may starve before backfill stops
    overload: str = "none"      # default overload policy for requests whose
                                # on_overload is None: 'none'|'reject'|
                                # 'degrade'|'preempt'
    default_deadline: Optional[float] = None  # deadline (ticks) for requests
                                              # that set none themselves
    preemption_budget: int = 1  # max swap-outs per tick
    # ---- elastic-fleet knobs (inert at the defaults) ----
    high_watermark: float = 1.0  # shard utilization above which the
                                 # background rebalancer moves work off
                                 # (1.0 = never: disabled)
    low_watermark: float = 0.0   # shard utilization below which a shard
                                 # may receive rebalanced work (0.0 =
                                 # never: disabled)
    proactive_degrade: bool = False  # shrink *running* degrade-class jobs
                                     # when the queue head fits nowhere
    shrink_budget: int = 1      # max in-place shrinks per tick

    def __post_init__(self):
        if self.policy not in ("priority", "fifo"):
            raise ValueError("policy must be 'priority' or 'fifo'")
        if self.overload not in OVERLOAD_POLICIES:
            raise ValueError(
                f"overload must be one of {OVERLOAD_POLICIES}")
        if self.default_deadline is not None and self.default_deadline < 0:
            raise ValueError("default_deadline must be >= 0 ticks")
        if self.preemption_budget < 0:
            raise ValueError("preemption_budget must be >= 0")
        if not (0.0 <= self.low_watermark <= self.high_watermark <= 1.0):
            raise ValueError(
                "need 0 <= low_watermark <= high_watermark <= 1")
        if self.shrink_budget < 0:
            raise ValueError("shrink_budget must be >= 0")


@dataclasses.dataclass
class QueueEntry:
    """One queued unit of work: a fresh request, or a preempted job's
    checkpoint waiting to resume (``swapped`` set)."""

    req: SARequest
    submit_tick: int            # original submission tick — the aging base
                                # survives preemption, so swapped jobs age
                                # ahead of newer arrivals
    swapped: Optional[SwappedJob] = None


@dataclasses.dataclass
class AdmissionPlan:
    """One tick's admission decisions, in execution order for the engine:
    reject, then evict (frees slots), then place."""

    admitted: List[Tuple[QueueEntry, int]] = dataclasses.field(
        default_factory=list)   # (entry, granted_slots)
    evict: List[int] = dataclasses.field(default_factory=list)  # rids
    rejected: List[QueueEntry] = dataclasses.field(default_factory=list)


@dataclasses.dataclass
class ShardedAdmissionPlan:
    """One tick's admission decisions across every shard, in execution
    order for the engine: reject, then evict (frees slots), then place.
    ``admitted`` and ``evict`` entries carry their shard index — rids are
    shard-local."""

    admitted: List[Tuple[QueueEntry, int, int]] = dataclasses.field(
        default_factory=list)   # (entry, granted_slots, shard index)
    evict: List[Tuple[int, int]] = dataclasses.field(
        default_factory=list)   # (rid, shard index)
    rejected: List[QueueEntry] = dataclasses.field(default_factory=list)


@dataclasses.dataclass(frozen=True)
class ShardView:
    """Scheduler-facing snapshot of one engine shard — the placement
    layer's input.  The scheduler never touches pools or devices; the
    engine summarizes each shard into (free capacity, resident jobs,
    resident dispatch shapes) before asking for placement or migration
    decisions."""

    index: int                          # engine shard id
    free_slots: int
    active: Tuple[ActiveJob, ...]       # jobs resident on the shard
    shapes: FrozenSet[Tuple[str, int, int]]  # (family, dim, N) dispatch
                                             # shapes resident

    @property
    def used_slots(self) -> int:
        return sum(len(j.slots) for j in self.active)

    @property
    def capacity(self) -> int:
        """Total slots on the shard (free + held)."""
        return self.free_slots + self.used_slots


#: One planned cross-shard move: (rid on the donor shard, donor shard
#: index, recipient shard index).
Migration = Tuple[int, int, int]

#: One planned in-place shrink (proactive degrade): (rid, shard index,
#: slots to keep — strictly fewer than held, never below the floor).
Shrink = Tuple[int, int, int]

#: One planned finish-deadline ladder truncation: (rid, shard index,
#: total levels to keep — strictly fewer than the job's current limit,
#: never below the request's ``min_levels`` floor).
Truncation = Tuple[int, int, int]

#: One planned drain-evacuation action, in execution order — always a
#: 5-tuple ``(kind, rid, src, dst, width)``:
#: ('migrate', rid, src, dst, width) moves the job whole;
#: ('shrink', rid, src, dst, new_width) migrates keeping only the first
#: ``new_width`` slots; ('swap', rid, src, -1, 0) checkpoints the job to
#: the queue for a later bit-exact resume (no destination, no width).
Evacuation = Tuple[str, int, int, int, int]


class AdmissionScheduler:
    """FIFO/priority queue with aging, bounded backfill and SLO policies."""

    def __init__(self, cfg: Optional[SchedulerConfig] = None):
        # A fresh default per instance: a shared default-argument config
        # instance would make every scheduler alias one object.
        self.cfg = SchedulerConfig() if cfg is None else cfg
        self._queue: List[QueueEntry] = []
        # The engine re-binds this to its own bundle; standalone
        # schedulers observe nothing.
        self.telemetry = NULL_TELEMETRY

    def __len__(self) -> int:
        return len(self._queue)

    @property
    def pending(self) -> List[SARequest]:
        return [e.req for e in self._queue]

    @property
    def entries(self) -> Tuple[QueueEntry, ...]:
        """Read-only snapshot of the queue (controller backlog signal:
        swapped entries expose their remaining-levels checkpoint)."""
        return tuple(self._queue)

    def submit(self, req: SARequest, tick: int) -> None:
        self._queue.append(QueueEntry(req, tick))

    def requeue(self, swapped: SwappedJob) -> None:
        """Put a preempted job back in the queue to await resume."""
        self._queue.append(QueueEntry(swapped.job.req,
                                      swapped.job.submit_tick, swapped))

    # ----------------------------------------------------------- policy bits
    def overload_policy(self, req: SARequest) -> str:
        return req.on_overload if req.on_overload is not None \
            else self.cfg.overload

    def _degradable(self, job) -> bool:
        """Mid-flight width-shrinkable: degrade-class, and not parallel
        tempering — a PT job's width *is* its temperature-ladder
        resolution, so truncating it in place would change the method
        rather than the budget (PA jobs stay shrinkable; their resampling
        composes with any width schedule).  Admission-time degrade is
        unaffected: granting a PT request fewer chains up front just
        builds a coarser ladder from level 0."""
        return (self.overload_policy(job.req) == "degrade"
                and job.req.method != "pt")

    def deadline_of(self, req: SARequest) -> Optional[float]:
        return req.deadline if req.deadline is not None \
            else self.cfg.default_deadline

    def effective_priority(self, req: SARequest, submit_tick: int,
                           tick: int) -> float:
        return req.priority + self.cfg.aging * (tick - submit_tick)

    def _ordered(self, tick: int) -> List[QueueEntry]:
        if self.cfg.policy == "fifo":
            return list(self._queue)
        # Stable sort: ties broken by submission order (list order).
        return sorted(self._queue, key=lambda e: -self.effective_priority(
            e.req, e.submit_tick, tick))

    def _expired(self, entry: QueueEntry, tick: int) -> bool:
        """Deadline fast-fail: reject/degrade-class requests are dropped the
        first admit scan after their queueing delay exceeds the deadline.
        Swapped jobs are admitted work and are never dropped."""
        if entry.swapped is not None:
            return False
        if self.overload_policy(entry.req) not in ("reject", "degrade"):
            return False
        deadline = self.deadline_of(entry.req)
        return deadline is not None and tick - entry.submit_tick > deadline

    # ------------------------------------------------------------- placement
    def _head(self, tick: int) -> Optional[QueueEntry]:
        """Highest-effective-priority queued entry that is not expired —
        the one whose placement the shard ordering optimizes for."""
        for entry in self._ordered(tick):
            if not self._expired(entry, tick):
                return entry
        return None

    @staticmethod
    def _shard_key(free: int, has_shape: bool, index: int):
        """Deterministic shard preference: least-loaded first (most free
        slots), then locality (a shard already running the request's
        ``(family, dim, N)`` dispatch shape dispatches it without opening
        a new per-shard device program), then lowest index."""
        return (-free, 0 if has_shape else 1, index)

    def place(self, shards: Sequence[ShardView], tick: int
              ) -> List[ShardView]:
        """Home-shard preference order for the queue head.

        The ordering primitive behind :meth:`admit_sharded` (which
        re-evaluates it per entry against live free counts): least-loaded
        first, locality tie-break toward the head's ``(family, dim, N)``
        shape, then index — fully deterministic, like the admission order
        itself.
        """
        head = self._head(tick)
        head_shape = (head.req.family, head.req.dim, head.req.N) \
            if head is not None else None
        return sorted(shards, key=lambda s: self._shard_key(
            s.free_slots, head_shape in s.shapes, s.index))

    @_planned("migrate")
    def plan_migrations(self, shards: Sequence[ShardView],
                        chains_per_slot: int, tick: int,
                        budget: int) -> List[Migration]:
        """Russkov-style rebalance: cross-shard moves that seat the head.

        Fires only when the queue head fits on *no* single shard but the
        pool as a whole has room: jobs are then checkpointed off one donor
        shard onto other shards' free slots until the donor can seat the
        head.  Moves are bounded by ``budget`` per tick, prefer the donor
        already closest to fitting, and move the narrowest jobs first
        (smallest checkpoints).  Migration never perturbs a trajectory —
        restore is placement-invariant — so no priority test guards it;
        thrash is impossible because a plan is only returned when it makes
        the head admissible, which removes the head from the queue.

        Returns ``(rid, donor shard, recipient shard)`` moves in execution
        order; empty when the head fits somewhere (or nothing can help).
        """
        if budget <= 0 or not self._queue:
            return []
        head = self._head(tick)
        if head is None:
            return []
        need = head.swapped.n_slots if head.swapped is not None \
            else head.req.slots_needed(chains_per_slot)
        if max((s.free_slots for s in shards), default=0) >= need:
            return []                   # fits already: admission handles it
        # Donor candidates, closest-to-fitting first (fewest slots to
        # clear), ties by index.  Recipients absorb moved jobs into their
        # genuinely-free slots only.
        for donor in sorted(shards, key=lambda s: (-s.free_slots, s.index)):
            freed = donor.free_slots
            moves: List[Migration] = []
            rec_free = {s.index: s.free_slots for s in shards
                        if s.index != donor.index}
            # Narrowest jobs first: cheapest checkpoints, finest packing.
            for job in sorted(donor.active,
                              key=lambda j: (len(j.slots), j.rid)):
                if freed >= need or len(moves) >= budget:
                    break
                width = len(job.slots)
                target = min((i for i, f in rec_free.items() if f >= width),
                             key=lambda i: (-rec_free[i], i), default=None)
                if target is None:
                    continue
                moves.append((job.rid, donor.index, target))
                rec_free[target] -= width
                freed += width
            if freed >= need and moves:
                return moves
        return []

    # ---------------------------------------------------------- elastic fleet
    @_planned("evacuate")
    def plan_evacuation(self, draining: Sequence[ShardView],
                        survivors: Sequence[ShardView],
                        chains_per_slot: int, tick: int,
                        budget: int) -> List[Evacuation]:
        """Plan this tick's shard-drain moves (bounded by ``budget``).

        Jobs leave draining shards in effective-priority order (highest
        first — the most important work is off the retiring device
        soonest, and keeps annealing without a queue round-trip).  Per
        job, in preference order:

        1. **migrate** whole onto the survivor with the most free room
           (lowest index on ties) — zero trajectory perturbation;
        2. **shrink-migrate**: a degrade-class job that fits nowhere
           whole is restored on the roomiest survivor at the width that
           fits, never below its ``min_chains`` floor (the proactive-
           degrade pressure valve applied to drain);
        3. **swap** out to the queue — the job checkpoints to host and
           resumes bit-exactly on whichever survivor next has room
           (swapped jobs are admitted work: never rejected or degraded).

        Drain therefore always makes progress and never loses work.
        """
        if budget <= 0 or not survivors:
            return []
        free = {s.index: s.free_slots for s in survivors}
        actions: List[Evacuation] = []
        jobs = [(j, d.index) for d in sorted(draining, key=lambda s: s.index)
                for j in d.active]
        jobs.sort(key=lambda ji: (-self.effective_priority(
            ji[0].req, ji[0].submit_tick, tick), ji[1], ji[0].rid))
        for job, src in jobs:
            if len(actions) >= budget:
                break
            width = len(job.slots)
            dst = min((i for i, f in free.items() if f >= width),
                      key=lambda i: (-free[i], i), default=None)
            if dst is not None:
                actions.append(("migrate", job.rid, src, dst, width))
                free[dst] -= width
                continue
            floor = job.req.slots_floor(chains_per_slot)
            roomiest = min(free, key=lambda i: (-free[i], i))
            if (self._degradable(job)
                    and floor <= free[roomiest] and floor < width):
                keep = min(free[roomiest], width - 1)
                actions.append(("shrink", job.rid, src, roomiest, keep))
                free[roomiest] -= keep
                continue
            actions.append(("swap", job.rid, src, -1, 0))
        return actions

    @_planned("rebalance")
    def plan_rebalance(self, shards: Sequence[ShardView], tick: int,
                       budget: int) -> List[Migration]:
        """Watermark rebalancing: background load-driven moves each tick.

        Generalizes :meth:`plan_migrations` (which fires only for the
        queue head) into a continuous balancer: while some shard's
        utilization exceeds ``high_watermark`` and another sits below
        ``low_watermark``, the narrowest job on the most-loaded shard
        moves to the least-loaded one — checkpoint/restore, bit-exact —
        bounded by ``budget`` per tick.

        Hysteresis is structural, not temporal: a move is planned only
        if the donor remains at least as loaded as the recipient after
        it (``used_src - w >= used_dst + w``).  The load ordering never
        inverts, so no later tick can profitably plan the reverse move —
        thrash is impossible by construction, without cooldown state.
        """
        hi, lo = self.cfg.high_watermark, self.cfg.low_watermark
        if budget <= 0 or len(shards) < 2 or (hi >= 1.0 and lo <= 0.0):
            return []
        cap = {s.index: s.capacity for s in shards}
        used = {s.index: s.used_slots for s in shards}
        jobs = {s.index: sorted(s.active, key=lambda j: (len(j.slots), j.rid))
                for s in shards}
        moves: List[Migration] = []
        while len(moves) < budget:
            util = {i: used[i] / max(cap[i], 1) for i in cap}
            srcs = sorted((i for i in cap if util[i] > hi),
                          key=lambda i: (-util[i], i))
            dsts = sorted((i for i in cap if util[i] < lo),
                          key=lambda i: (util[i], i))
            planned = None
            for si in srcs:
                for job in jobs[si]:          # narrowest first
                    w = len(job.slots)
                    for di in dsts:
                        if di == si or cap[di] - used[di] < w:
                            continue
                        if used[si] - w < used[di] + w:
                            continue          # would invert the ordering
                        planned = (job, si, di)
                        break
                    if planned:
                        break
                if planned:
                    break
            if planned is None:
                break
            job, si, di = planned
            moves.append((job.rid, si, di))
            jobs[si].remove(job)
            used[si] -= len(job.slots)
            used[di] += len(job.slots)
        return moves

    @_planned("shrink")
    def plan_shrinks(self, shards: Sequence[ShardView],
                     chains_per_slot: int, tick: int,
                     budget: int) -> List[Shrink]:
        """Proactive degrade: shrink *running* jobs to seat the queue head.

        Fires only when the head fits on no shard at full width (the
        same trigger as the admission-time fallbacks) and the pool has
        no free room migration could consolidate.  Candidates are
        degrade-class jobs holding more than their floor whose effective
        priority is *strictly* below the head's (the preempt policy's
        inversion guard, applied to width instead of residency).  On one
        shard — cheapest victims first, largest reclaimable surplus on
        ties — widths are cut just enough for the head to seat there;
        all-or-nothing, bounded by ``budget`` per tick.

        Returns ``(rid, shard index, slots to keep)`` in execution
        order; empty when the head is seatable anyway or no shard can
        reclaim enough width.
        """
        if budget <= 0 or not self._queue:
            return []
        head = self._head(tick)
        if head is None:
            return []
        need = head.swapped.n_slots if head.swapped is not None \
            else head.req.slots_needed(chains_per_slot)
        if max((s.free_slots for s in shards), default=0) >= need:
            return []                   # admission will seat it
        head_eff = self.effective_priority(head.req, head.submit_tick, tick)
        for view in sorted(shards, key=lambda s: (-s.free_slots, s.index)):
            cands = []
            for job in view.active:
                floor = job.req.slots_floor(chains_per_slot)
                eff = self.effective_priority(job.req, job.submit_tick, tick)
                if (self._degradable(job)
                        and len(job.slots) > floor and eff < head_eff):
                    cands.append((eff, floor - len(job.slots), job.rid,
                                  job, floor))
            cands.sort()                # cheapest first, widest surplus ties
            avail = view.free_slots
            plan: List[Shrink] = []
            for eff, _, rid, job, floor in cands:
                if avail >= need or len(plan) >= budget:
                    break
                take = min(len(job.slots) - floor, need - avail)
                plan.append((rid, view.index, len(job.slots) - take))
                avail += take
            if avail >= need and plan:
                return plan
        return []

    @_planned("truncate")
    def plan_truncations(self, shards: Sequence[ShardView],
                         tick: int) -> List[Truncation]:
        """Finish-deadline degrade on the *level* axis: cut a running
        job's remaining temperature levels when, at one level per tick
        from now, it would finish past its ``finish_deadline``.

        The latest finish tick that still meets the SLO is
        ``D = arrival_time + finish_deadline - 1`` (completion latency is
        ``finish_tick + 1 - arrival_time``).  A job at ``level`` of
        ``limit`` total levels finishes at ``tick + (limit - level) - 1``;
        when that overshoots, the ladder is cut to
        ``level + floor(D - tick) + 1`` total levels, clamped to the
        request's ``min_levels`` floor — an over-late job keeps at least
        its floor and misses the SLO rather than returning garbage.

        Runs at macro-tick boundaries (the engine calls it right after
        admission), so recorded truncation levels are K-aligned for
        ``run_standalone`` replay, exactly like shrink schedules.  Unlike
        width shrinks, truncation is method-agnostic: it moves the
        ladder's end without touching any level's arithmetic, so PT and
        PA jobs are as cuttable as plain SA.

        Returns ``(rid, shard index, total levels to keep)`` in
        execution order.
        """
        plan: List[Truncation] = []
        for view in shards:
            for job in view.active:
                fd = job.req.finish_deadline
                if fd is None:
                    continue
                limit = job.levels_limit or job.req.n_levels
                latest = job.arrival_time + fd - 1     # last OK finish tick
                if tick + (limit - job.level) - 1 <= latest:
                    continue                            # on time as-is
                allowed = math.floor(latest - tick) + 1  # levels from now
                new_total = max(int(job.req.min_levels),
                                job.level + max(0, allowed))
                if new_total < limit:
                    plan.append((job.rid, view.index, new_total))
        return plan

    # ------------------------------------------------------------- admission
    def admit(self, free_slots: int, chains_per_slot: int, tick: int,
              active: Sequence[ActiveJob] = (),
              preemption_budget: Optional[int] = None) -> AdmissionPlan:
        """Plan this tick's admissions into ``free_slots`` slots.

        ``active`` is the engine's in-residence job list — the eviction
        candidates for the preempt policy.  Returns an
        :class:`AdmissionPlan`; planned entries are removed from the queue
        (the engine re-queues evicted jobs via :meth:`requeue`).  The plan
        never over-commits: granted slots <= free + evicted slots.

        The single-pool view of :meth:`admit_sharded` — one shard holding
        the whole pool; exactly the pre-sharding admission semantics.
        """
        view = ShardView(
            index=0, free_slots=free_slots, active=tuple(active),
            shapes=frozenset((j.req.family, j.req.dim, j.req.N)
                             for j in active))
        plan = self.admit_sharded([view], chains_per_slot, tick,
                                  preemption_budget=preemption_budget)
        return AdmissionPlan(
            admitted=[(e, granted) for e, granted, _ in plan.admitted],
            evict=[rid for rid, _ in plan.evict],
            rejected=plan.rejected)

    def admit_sharded(self, shards: Sequence[ShardView],
                      chains_per_slot: int, tick: int,
                      preemption_budget: Optional[int] = None
                      ) -> ShardedAdmissionPlan:
        """Plan one tick's admissions across every shard of the pool.

        One queue walk in effective-priority order; **each entry is tried
        at full width on every shard** (least-loaded first, locality
        tie-break) before its overload fallback may fire — a request is
        degraded, or a tenant evicted for it, only when *no* shard can
        seat it whole.  Lower-priority entries therefore can never
        pre-empt slots a higher-priority entry's fallback would have
        used: the walk order is the priority order, exactly as in the
        single-pool scheduler.  The preemption budget bounds evictions
        per *tick* across all shards.
        """
        plan = ShardedAdmissionPlan()
        budget = self.cfg.preemption_budget if preemption_budget is None \
            else preemption_budget
        # Per-shard live state.  Slots freed by evictions are tracked
        # separately from genuinely-free slots: surplus eviction capacity
        # may only seat entries whose effective priority is >= that of
        # every job evicted from that shard this tick (``evict_floor``) —
        # otherwise evicting a mid-priority job for an urgent one could
        # hand its leftover slots to a *lower*-priority queued request in
        # the same pass, a priority inversion against the victim.
        free = {s.index: s.free_slots for s in shards}
        evicted_free = {s.index: 0 for s in shards}
        evict_floor = {s.index: float("-inf") for s in shards}
        shapes = {s.index: set(s.shapes) for s in shards}
        # Eviction candidates per shard, cheapest first: lowest effective
        # priority, ties broken by most-recent admission (LIFO — the job
        # that has annealed least loses least progress).
        candidates = {
            s.index: sorted(s.active, key=lambda j: (self.effective_priority(
                j.req, j.submit_tick, tick), -j.start_tick, j.rid))
            for s in shards}
        blocked_head = False
        for entry in self._ordered(tick):
            if self._expired(entry, tick):
                plan.rejected.append(entry)
                continue
            req = entry.req
            need = entry.swapped.n_slots if entry.swapped is not None \
                else req.slots_needed(chains_per_slot)
            if blocked_head:
                continue
            eff = self.effective_priority(req, entry.submit_tick, tick)
            shape = (req.family, req.dim, req.N)

            def usable(si):
                outranks = eff >= evict_floor[si]
                return free[si] + (evicted_free[si] if outranks else 0)

            order = sorted(free, key=lambda si: self._shard_key(
                usable(si), shape in shapes[si], si))
            placed = False
            for si in order:                 # full width, on any shard
                if need <= usable(si):
                    plan.admitted.append((entry, need, si))
                    free[si], evicted_free[si] = self._consume(
                        need, free[si], evicted_free[si])
                    shapes[si].add(shape)
                    placed = True
                    break
            policy = self.overload_policy(req) if not placed else "none"
            if policy == "preempt" and budget > 0:
                for si in order:             # fewest evictions first
                    if not candidates[si]:
                        continue
                    outranks = eff >= evict_floor[si]
                    avail = usable(si)
                    victims, gain, vmax = self._select_victims(
                        eff, need, avail, budget, candidates[si], tick)
                    if victims is None:
                        continue
                    for job in victims:
                        plan.evict.append((job.rid, si))
                        candidates[si].remove(job)
                    budget -= len(victims)
                    plan.admitted.append((entry, need, si))
                    # The entry drained `avail` and the evictions' gain
                    # down to `surplus` slots, which stay in the
                    # eviction-reserved pool (floored at the priciest
                    # victim so far — conservative across rounds).
                    surplus = avail + gain - need
                    if outranks:
                        free[si], evicted_free[si] = 0, surplus
                    else:
                        free[si], evicted_free[si] = \
                            0, evicted_free[si] + surplus
                    evict_floor[si] = max(evict_floor[si], vmax)
                    shapes[si].add(shape)
                    placed = True
                    break
            if not placed and policy == "degrade" and entry.swapped is None:
                floor_slots = req.slots_floor(chains_per_slot)
                si = order[0]                # most usable: widest grant
                grant = usable(si)
                if floor_slots <= grant:     # all that fits, down to floor
                    plan.admitted.append((entry, grant, si))
                    free[si], evicted_free[si] = self._consume(
                        grant, free[si], evicted_free[si])
                    shapes[si].add(shape)
                    placed = True
            if not placed and tick - entry.submit_tick > self.cfg.hol_patience:
                # Head-of-line starved past patience: stop backfilling so
                # freed slots can accumulate for it.
                blocked_head = True
        taken = {id(e) for e, _, _ in plan.admitted}
        taken.update(id(e) for e in plan.rejected)
        self._queue = [e for e in self._queue if id(e) not in taken]
        self.telemetry.plan("admit", len(plan.admitted))
        return plan

    @staticmethod
    def _consume(need: int, free: int, evicted_free: int):
        """Drain the plain free pool first, then eviction-freed slots."""
        from_free = min(free, need)
        return free - from_free, evicted_free - (need - from_free)

    def _select_victims(self, mine: float, need: int, usable: int,
                        budget: int, candidates: List[ActiveJob],
                        tick: int):
        """Pick strictly-lower-effective-priority victims until ``need``
        slots are reachable, if the preemption budget allows;
        all-or-nothing.  Returns (victims | None, slot gain, max victim
        effective priority)."""
        victims: List[ActiveJob] = []
        gain = 0
        floor = float("-inf")
        for job in candidates:
            if usable + gain >= need or len(victims) >= budget:
                break
            eff = self.effective_priority(job.req, job.submit_tick, tick)
            if eff >= mine:
                break               # sorted ascending: no cheaper victims left
            victims.append(job)
            gain += len(job.slots)
            floor = max(floor, eff)
        if usable + gain < need:
            return None, 0, floor   # insufficient: evict nothing
        return victims, gain, floor
