"""Admission/packing scheduler for the SA serving engine.

Continuous batching needs two decisions per tick: *which* queued requests to
admit, and *whether* to hold slots back for a large request that cannot fit
yet.  The policy here is priority-with-aging plus bounded backfill:

* effective priority = static priority + ``aging`` x ticks queued, so a
  low-priority request cannot starve forever (the fairness half of
  Russkov-style replica redistribution: the pool keeps being re-packed as
  ladders finish at different times);
* requests are scanned in effective-priority order and admitted greedily
  while they fit (*backfill*: a small request may overtake a large one that
  is short on slots, keeping occupancy high);
* once the head-of-line request has waited more than ``hol_patience`` ticks,
  backfill past it stops, letting freed slots accumulate until it fits —
  bounded head-of-line starvation instead of either extreme.

Invariants
----------
* The scheduler never over-commits: the sum of ``slots_needed`` over one
  ``admit()`` batch is <= the ``free_slots`` it was offered.
* Admission order is deterministic: effective-priority sort is stable with
  ties broken by submission order, so a fixed (request mix, arrival seed)
  reproduces the exact same packing — the foundation of the engine's
  reproducible latency distributions.
* Scheduling is objective-blind.  Since the kernel dispatches the objective
  id at runtime, co-batching never constrains *which* requests may share a
  device program — only shape ``(dim, N)`` does, and that grouping happens
  downstream in the engine.
* The scheduler holds only ``(request, submit_tick)``; open-loop arrival
  timestamps live in the engine's lifecycle records (engine.py), so queue
  policy and load generation stay decoupled.
"""
from __future__ import annotations

import dataclasses
from typing import List, Tuple

from repro.service.request import SARequest


@dataclasses.dataclass(frozen=True)
class SchedulerConfig:
    policy: str = "priority"    # 'priority' (aged) | 'fifo'
    aging: float = 0.05         # priority points per queued tick
    hol_patience: int = 16      # ticks the head may starve before backfill stops

    def __post_init__(self):
        if self.policy not in ("priority", "fifo"):
            raise ValueError("policy must be 'priority' or 'fifo'")


class AdmissionScheduler:
    """FIFO/priority queue with aging and bounded backfill."""

    def __init__(self, cfg: SchedulerConfig = SchedulerConfig()):
        self.cfg = cfg
        self._queue: List[Tuple[SARequest, int]] = []  # (request, submit_tick)

    def __len__(self) -> int:
        return len(self._queue)

    @property
    def pending(self) -> List[SARequest]:
        return [r for r, _ in self._queue]

    def submit(self, req: SARequest, tick: int) -> None:
        self._queue.append((req, tick))

    def effective_priority(self, req: SARequest, submit_tick: int,
                           tick: int) -> float:
        return req.priority + self.cfg.aging * (tick - submit_tick)

    def _ordered(self, tick: int) -> List[Tuple[SARequest, int]]:
        if self.cfg.policy == "fifo":
            return list(self._queue)
        # Stable sort: ties broken by submission order (list order).
        return sorted(self._queue,
                      key=lambda e: -self.effective_priority(e[0], e[1], tick))

    def admit(self, free_slots: int, chains_per_slot: int,
              tick: int) -> List[Tuple[SARequest, int]]:
        """Pick requests to place into ``free_slots`` slots this tick.

        Returns [(request, submit_tick)] in admission order and removes them
        from the queue.  Never over-commits the pool.
        """
        admitted: List[Tuple[SARequest, int]] = []
        blocked_head = False
        for entry in self._ordered(tick):
            req, sub = entry
            need = req.slots_needed(chains_per_slot)
            if need <= free_slots and not blocked_head:
                admitted.append(entry)
                free_slots -= need
            elif need > free_slots and not blocked_head:
                # Head-of-line can't fit. Backfill behind it only while it
                # has not starved past patience.
                if tick - sub > self.cfg.hol_patience:
                    blocked_head = True
        taken = {id(e) for e in admitted}
        self._queue = [e for e in self._queue if id(e) not in taken]
        return admitted
