"""Admission/packing scheduler for the SA serving engine.

Continuous batching needs two decisions per tick: *which* queued requests to
admit, and *whether* to hold slots back for a large request that cannot fit
yet.  The base policy is priority-with-aging plus bounded backfill:

* effective priority = static priority + ``aging`` x ticks queued, so a
  low-priority request cannot starve forever (the fairness half of
  Russkov-style replica redistribution: the pool keeps being re-packed as
  ladders finish at different times);
* requests are scanned in effective-priority order and admitted greedily
  while they fit (*backfill*: a small request may overtake a large one that
  is short on slots, keeping occupancy high);
* once the head-of-line request has waited more than ``hol_patience`` ticks,
  backfill past it stops, letting freed slots accumulate until it fits —
  bounded head-of-line starvation instead of either extreme.

On top of that sit the **overload policies** (per request class via
``SARequest.on_overload``, defaulting to ``SchedulerConfig.overload``),
which decide what happens when a request cannot be admitted at full width:

* ``reject``  — SLO fast-fail: once the request has queued longer than its
  ``deadline`` (ticks; ``deadline=0`` means *admit now or never*) it is
  dropped with a typed 'rejected' status.  This bounds both queue length
  and the queueing delay of everything that *is* admitted.
* ``degrade`` — admit immediately with fewer chains, down to the request's
  ``min_chains`` floor (rounded up to whole slots; one slot if unset).
  Champion exchange scales with it automatically (the segmented reduce runs
  over whatever blocks the request holds), and the run is bit-exact with a
  standalone run at the granted chain count.  The ``reject`` deadline is
  kept as a backstop — if even the floor cannot be admitted in time the
  request is dropped — so degrade also bounds queue growth.
* ``preempt`` — evict the lowest-effective-priority active job(s) whose
  effective priority is *strictly* below the candidate's, bounded by
  ``preemption_budget`` evictions per tick, checkpoint them to host
  (:class:`~repro.service.slots.SwappedJob`) and re-queue them for a
  bit-exact resume.  Because every job ages at the same rate, preemption
  order is stable — no eviction/resume thrash cycles.  Surplus slots an
  eviction frees beyond the urgent arrival's need are reserved for work
  that outranks the victims for the rest of the tick: eviction never
  directly funds a lower-priority admission (from the next tick on the
  ordinary backfill/aging/hol rules govern them again).

Invariants
----------
* The scheduler never over-commits: the slots granted by one ``admit()``
  plan are <= the ``free_slots`` it was offered plus the slots released by
  the evictions in the same plan.
* Admission order is deterministic: effective-priority sort is stable with
  ties broken by submission order, so a fixed (request mix, arrival seed)
  reproduces the exact same packing — the foundation of the engine's
  reproducible latency distributions.
* Swapped (preempted) jobs are *admitted work*: they resume at exactly
  their granted width and are never rejected or degraded — only delayed.
* Scheduling is objective-blind.  Since the kernel dispatches the objective
  id at runtime, co-batching never constrains *which* requests may share a
  device program — only shape ``(dim, N)`` does, and that grouping happens
  downstream in the engine.
* The scheduler holds only queue entries ``(request, submit_tick, swapped
  checkpoint)``; open-loop arrival timestamps live in the engine's
  lifecycle records (engine.py), so queue policy and load generation stay
  decoupled.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Tuple

from repro.service.request import OVERLOAD_POLICIES, SARequest
from repro.service.slots import ActiveJob, SwappedJob


@dataclasses.dataclass(frozen=True)
class SchedulerConfig:
    policy: str = "priority"    # 'priority' (aged) | 'fifo'
    aging: float = 0.05         # priority points per queued tick
    hol_patience: int = 16      # ticks the head may starve before backfill stops
    overload: str = "none"      # default overload policy for requests whose
                                # on_overload is None: 'none'|'reject'|
                                # 'degrade'|'preempt'
    default_deadline: Optional[float] = None  # deadline (ticks) for requests
                                              # that set none themselves
    preemption_budget: int = 1  # max swap-outs per tick

    def __post_init__(self):
        if self.policy not in ("priority", "fifo"):
            raise ValueError("policy must be 'priority' or 'fifo'")
        if self.overload not in OVERLOAD_POLICIES:
            raise ValueError(
                f"overload must be one of {OVERLOAD_POLICIES}")
        if self.default_deadline is not None and self.default_deadline < 0:
            raise ValueError("default_deadline must be >= 0 ticks")
        if self.preemption_budget < 0:
            raise ValueError("preemption_budget must be >= 0")


@dataclasses.dataclass
class QueueEntry:
    """One queued unit of work: a fresh request, or a preempted job's
    checkpoint waiting to resume (``swapped`` set)."""

    req: SARequest
    submit_tick: int            # original submission tick — the aging base
                                # survives preemption, so swapped jobs age
                                # ahead of newer arrivals
    swapped: Optional[SwappedJob] = None


@dataclasses.dataclass
class AdmissionPlan:
    """One tick's admission decisions, in execution order for the engine:
    reject, then evict (frees slots), then place."""

    admitted: List[Tuple[QueueEntry, int]] = dataclasses.field(
        default_factory=list)   # (entry, granted_slots)
    evict: List[int] = dataclasses.field(default_factory=list)  # rids
    rejected: List[QueueEntry] = dataclasses.field(default_factory=list)


class AdmissionScheduler:
    """FIFO/priority queue with aging, bounded backfill and SLO policies."""

    def __init__(self, cfg: Optional[SchedulerConfig] = None):
        # A fresh default per instance: a shared default-argument config
        # instance would make every scheduler alias one object.
        self.cfg = SchedulerConfig() if cfg is None else cfg
        self._queue: List[QueueEntry] = []

    def __len__(self) -> int:
        return len(self._queue)

    @property
    def pending(self) -> List[SARequest]:
        return [e.req for e in self._queue]

    def submit(self, req: SARequest, tick: int) -> None:
        self._queue.append(QueueEntry(req, tick))

    def requeue(self, swapped: SwappedJob) -> None:
        """Put a preempted job back in the queue to await resume."""
        self._queue.append(QueueEntry(swapped.job.req,
                                      swapped.job.submit_tick, swapped))

    # ----------------------------------------------------------- policy bits
    def overload_policy(self, req: SARequest) -> str:
        return req.on_overload if req.on_overload is not None \
            else self.cfg.overload

    def deadline_of(self, req: SARequest) -> Optional[float]:
        return req.deadline if req.deadline is not None \
            else self.cfg.default_deadline

    def effective_priority(self, req: SARequest, submit_tick: int,
                           tick: int) -> float:
        return req.priority + self.cfg.aging * (tick - submit_tick)

    def _ordered(self, tick: int) -> List[QueueEntry]:
        if self.cfg.policy == "fifo":
            return list(self._queue)
        # Stable sort: ties broken by submission order (list order).
        return sorted(self._queue, key=lambda e: -self.effective_priority(
            e.req, e.submit_tick, tick))

    def _expired(self, entry: QueueEntry, tick: int) -> bool:
        """Deadline fast-fail: reject/degrade-class requests are dropped the
        first admit scan after their queueing delay exceeds the deadline.
        Swapped jobs are admitted work and are never dropped."""
        if entry.swapped is not None:
            return False
        if self.overload_policy(entry.req) not in ("reject", "degrade"):
            return False
        deadline = self.deadline_of(entry.req)
        return deadline is not None and tick - entry.submit_tick > deadline

    # ------------------------------------------------------------- admission
    def admit(self, free_slots: int, chains_per_slot: int, tick: int,
              active: Sequence[ActiveJob] = ()) -> AdmissionPlan:
        """Plan this tick's admissions into ``free_slots`` slots.

        ``active`` is the engine's in-residence job list — the eviction
        candidates for the preempt policy.  Returns an
        :class:`AdmissionPlan`; planned entries are removed from the queue
        (the engine re-queues evicted jobs via :meth:`requeue`).  The plan
        never over-commits: granted slots <= free + evicted slots.
        """
        plan = AdmissionPlan()
        # Eviction candidates, cheapest first: lowest effective priority,
        # ties broken by most-recent admission (LIFO — the job that has
        # annealed least loses least progress).
        candidates = sorted(
            active, key=lambda j: (self.effective_priority(
                j.req, j.submit_tick, tick), -j.start_tick, j.rid))
        budget = self.cfg.preemption_budget
        # Slots freed by this pass's evictions are tracked separately from
        # genuinely-free slots: surplus eviction capacity may only seat
        # entries whose effective priority is >= that of every job evicted
        # this tick (``evict_floor``) — otherwise evicting a mid-priority
        # job for an urgent one could hand its leftover slots to a
        # *lower*-priority queued request in the same pass, a priority
        # inversion against the victim.
        free = free_slots
        evicted_free = 0
        evict_floor = float("-inf")      # max eff among this pass's victims
        blocked_head = False
        for entry in self._ordered(tick):
            if self._expired(entry, tick):
                plan.rejected.append(entry)
                continue
            req = entry.req
            need = entry.swapped.n_slots if entry.swapped is not None \
                else req.slots_needed(chains_per_slot)
            if blocked_head:
                continue
            eff = self.effective_priority(req, entry.submit_tick, tick)
            outranks_victims = eff >= evict_floor
            usable = free + (evicted_free if outranks_victims else 0)
            if need <= usable:
                plan.admitted.append((entry, need))
                free, evicted_free = self._consume(need, free, evicted_free)
                continue
            placed = False
            policy = self.overload_policy(req)
            if policy == "preempt" and budget > 0 and candidates:
                placed, surplus, vmax, budget = self._try_preempt(
                    plan, entry, need, usable, budget, candidates, tick)
                if placed:
                    # The entry drained `usable` and the evictions' gain
                    # down to `surplus` slots, which stay in the
                    # eviction-reserved pool (floored at the priciest
                    # victim so far — conservative across rounds).
                    if outranks_victims:
                        free, evicted_free = 0, surplus
                    else:
                        free, evicted_free = 0, evicted_free + surplus
                    evict_floor = max(evict_floor, vmax)
            if not placed and policy == "degrade" and entry.swapped is None:
                floor_slots = req.slots_floor(chains_per_slot)
                if floor_slots <= usable:  # grant all that fits, down to floor
                    plan.admitted.append((entry, usable))
                    free, evicted_free = self._consume(usable, free,
                                                       evicted_free)
                    placed = True
            if not placed and tick - entry.submit_tick > self.cfg.hol_patience:
                # Head-of-line starved past patience: stop backfilling so
                # freed slots can accumulate for it.
                blocked_head = True
        taken = {id(e) for e, _ in plan.admitted}
        taken.update(id(e) for e in plan.rejected)
        self._queue = [e for e in self._queue if id(e) not in taken]
        return plan

    @staticmethod
    def _consume(need: int, free: int, evicted_free: int):
        """Drain the plain free pool first, then eviction-freed slots."""
        from_free = min(free, need)
        return free - from_free, evicted_free - (need - from_free)

    def _try_preempt(self, plan: AdmissionPlan, entry: QueueEntry, need: int,
                     usable: int, budget: int, candidates: List[ActiveJob],
                     tick: int):
        """Evict strictly-lower-effective-priority jobs until ``entry``
        fits, if the preemption budget allows; all-or-nothing.  Returns
        (placed, surplus slots freed beyond need, max victim effective
        priority, remaining budget)."""
        mine = self.effective_priority(entry.req, entry.submit_tick, tick)
        victims: List[ActiveJob] = []
        gain = 0
        floor = float("-inf")
        for job in candidates:
            if usable + gain >= need or len(victims) >= budget:
                break
            eff = self.effective_priority(job.req, job.submit_tick, tick)
            if eff >= mine:
                break               # sorted ascending: no cheaper victims left
            victims.append(job)
            gain += len(job.slots)
            floor = max(floor, eff)
        if usable + gain < need:
            return False, 0, floor, budget  # insufficient: evict nothing
        for job in victims:
            plan.evict.append(job.rid)
            candidates.remove(job)
        plan.admitted.append((entry, need))
        return True, usable + gain - need, floor, budget - len(victims)
