"""Closed-loop fleet autoscaler: the serving engine's control plane.

ROADMAP item 4 composes the two elastic-fleet primitives that already
exist — ``engine.resize`` (drain-based shrink, instant grow) and
completion-deadline SLOs (ladder truncation) — into a controller that
*decides*, in the replica-redistribution lineage of Russkov et al.
(arXiv:2006.00561) and the continuous-batching control loops of LLM
serving systems: sample fleet signals on a fixed tick cadence, scale up
*before* predicted deadline violations, scale down only after sustained
idleness.

Control law (one sample, pure host arithmetic — no device work):

* **Demand** is outstanding work in *slot-levels*: every queued request
  contributes ``slots_needed x n_levels`` (a swapped checkpoint its held
  slots x remaining levels), every resident job ``slots_held x remaining
  levels``.  One occupied slot retires exactly one slot-level per tick,
  so a shard's goodput is its slot count and the fleet clears demand in
  ``demand / capacity_slots`` ticks if perfectly packed.
* **Window** is the tightest completion budget: the minimum over
  outstanding work of ``arrival + finish_deadline - now`` (clamped to
  >= 1).  Work without a finish deadline falls back to its remaining
  ladder length — "finish within about one ladder" — so the controller
  still tracks load when no SLOs are set.
* **Scale up** when ``demand x headroom > capacity_slots x window``:
  the fleet, at tick-goodput, would miss the tightest deadline.  The
  target is the smallest fleet that wouldn't
  (``ceil(demand x headroom / (window x slots_per_shard))``), clamped
  to ``[min_shards, max_shards]`` — one decision jumps straight to the
  predicted need rather than creeping one shard per sample.
* **Scale down** by one shard (``resize`` drains the emptiest) only
  after ``window`` *consecutive* samples with utilization below
  ``low_util`` and an empty queue — the hysteresis that keeps a diurnal
  trough from flapping the fleet — and never below what current demand
  needs.
* **Cooldown**: at most one fleet-size change per ``cooldown`` ticks,
  bounding resize thrash regardless of how noisy the signals get (the
  hypothesis property suite asserts exactly this).

The controller is sampled at the top of ``engine.tick()`` — before
admission, aligned with scripted ops — and ``run_stream``'s idle
fast-forward never jumps past ``next_sample_tick``, so decisions land on
the deterministic tick axis: a seeded trace replays to the identical
scaling history, and every trajectory stays bit-exact (scale-ups add
empty shards; scale-downs drain via the checkpoint/restore paths that
are already placement-invariant).
"""
from __future__ import annotations

import dataclasses
import math
from typing import List, Optional, Tuple


@dataclasses.dataclass(frozen=True)
class AutoscalerConfig:
    """Control-plane knobs (defaults are deliberately conservative)."""

    min_shards: int = 1         # floor: never drain below this
    max_shards: int = 8         # ceiling: never grow beyond this
    sample_every: int = 8       # ticks between control samples
    headroom: float = 1.25      # demand safety multiplier on scale-up
                                # (covers packing loss + arrivals between
                                # samples)
    low_util: float = 0.35      # utilization low watermark
    window: int = 3             # consecutive low samples before scale-down
    cooldown: int = 32          # min ticks between fleet-size changes

    def __post_init__(self):
        if not 1 <= self.min_shards <= self.max_shards:
            raise ValueError(
                f"need 1 <= min_shards <= max_shards; got "
                f"{self.min_shards}..{self.max_shards}")
        if self.sample_every < 1:
            raise ValueError("sample_every must be >= 1 tick")
        if self.headroom < 1.0:
            raise ValueError("headroom must be >= 1.0")
        if not 0.0 <= self.low_util <= 1.0:
            raise ValueError("low_util must be in [0, 1]")
        if self.window < 1:
            raise ValueError("window must be >= 1 sample")
        if self.cooldown < 0:
            raise ValueError("cooldown must be >= 0 ticks")


class Autoscaler:
    """Attach with ``engine.attach_controller(Autoscaler(cfg))``; the
    engine calls :meth:`maybe_sample` every tick."""

    def __init__(self, cfg: Optional[AutoscalerConfig] = None):
        self.cfg = AutoscalerConfig() if cfg is None else cfg
        #: Next tick at which the controller will sample.  run_stream's
        #: idle fast-forward caps its jumps here so sparse traces cannot
        #: leap over a scale-down decision.
        self.next_sample_tick = 0
        self.samples = 0
        #: Decision log: (tick, kind, from_shards, to_shards) — 'grow'
        #: and 'shrink' entries only; benches and tests replay it.
        self.decisions: List[Tuple[int, str, int, int]] = []
        self._low_streak = 0
        self._last_action_tick = -(10 ** 9)   # first action never blocked

    # ---------------------------------------------------------------- signals
    @staticmethod
    def _levels_left(job) -> int:
        limit = job.levels_limit or job.req.n_levels
        return max(0, limit - job.level)

    def signals(self, engine) -> dict:
        """One sample of the fleet, host-side only.

        ``demand`` in slot-levels, ``window`` in ticks (the tightest
        completion budget), ``util`` in [0, 1], ``headroom_min`` the
        worst per-request slack (window - remaining levels; negative
        means a predicted SLO miss at one level per tick).
        """
        now = engine.tick_count
        live = engine.live_shards
        capacity = sum(s.pool.n_slots for s in live)
        used = sum(s.pool.n_active for s in live)
        cps = engine.cfg.chains_per_slot

        demand = 0          # outstanding slot-levels
        windows = []        # (window ticks, remaining levels) per unit
        for shard in engine.shards:
            for job in shard.rids.jobs.values():
                left = self._levels_left(job)
                demand += len(job.slots) * left
                fd = job.req.finish_deadline
                win = (job.arrival_time + fd - now) if fd is not None \
                    else float(left)
                windows.append((win, left))
        for entry in engine.scheduler.entries:
            req = entry.req
            if entry.swapped is not None:
                left = self._levels_left(entry.swapped.job)
                slots = entry.swapped.n_slots
                job = entry.swapped.job
                fd = req.finish_deadline
                win = (job.arrival_time + fd - now) if fd is not None \
                    else float(left)
            else:
                left = req.n_levels
                slots = req.slots_needed(cps)
                fd = req.finish_deadline
                arrival, _ = engine._submit_info.get(
                    req.req_id, (float(entry.submit_tick), float("nan")))
                win = (arrival + fd - now) if fd is not None \
                    else float(left)
            demand += slots * left
            windows.append((win, left))

        window = max(1.0, min((w for w, _ in windows),
                              default=float("inf")))
        headroom_min = min((w - left for w, left in windows),
                           default=float("inf"))
        return {
            "tick": now,
            "live_shards": len(live),
            "capacity_slots": capacity,
            "used_slots": used,
            "util": used / capacity if capacity else 0.0,
            "queued": len(engine.scheduler),
            "demand_slot_levels": demand,
            "window": window,
            "headroom_min": headroom_min,
        }

    # ------------------------------------------------------------------ loop
    def maybe_sample(self, engine) -> None:
        """Engine hook: sample + act if this tick is a sampling tick."""
        if engine.tick_count < self.next_sample_tick:
            return
        self.next_sample_tick = engine.tick_count + self.cfg.sample_every
        self.samples += 1
        self._control(engine, self.signals(engine))

    def _control(self, engine, sig: dict) -> None:
        cfg = self.cfg
        now = sig["tick"]
        n_live = sig["live_shards"]
        slots_per_shard = engine.cfg.n_slots
        # Smallest fleet that clears outstanding demand inside the
        # tightest completion window at one slot-level per slot-tick.
        if math.isfinite(sig["window"]):
            need = max(cfg.min_shards, math.ceil(
                sig["demand_slot_levels"] * cfg.headroom
                / (sig["window"] * slots_per_shard)))
        else:               # no outstanding work at all
            need = cfg.min_shards
        need = min(need, cfg.max_shards)

        tel = engine.telemetry
        if tel.enabled:
            tel.decision(now, "autoscale_sample", **{
                k: v for k, v in sig.items() if k != "tick"})

        cooled = now - self._last_action_tick >= cfg.cooldown
        if need > n_live:
            self._low_streak = 0
            if cooled:
                self._act(engine, now, "grow", n_live, need)
            return
        low = (sig["util"] < cfg.low_util and sig["queued"] == 0)
        self._low_streak = self._low_streak + 1 if low else 0
        if (low and self._low_streak >= cfg.window and cooled
                and n_live > max(cfg.min_shards, need)):
            self._low_streak = 0
            self._act(engine, now, "shrink", n_live, n_live - 1)

    def _act(self, engine, tick: int, kind: str, n_from: int,
             n_to: int) -> None:
        self._last_action_tick = tick
        self.decisions.append((tick, kind, n_from, n_to))
        engine.resize(n_to)     # grow adds shards; shrink drains emptiest
        if engine.telemetry.enabled:
            engine.telemetry.decision(tick, "autoscale_" + kind,
                                      from_shards=n_from, to_shards=n_to)
