"""Architecture + shape registry (assignment pool)."""
from repro.configs.archs import ARCHS, ARCH_IDS, get_arch
from repro.configs.common import SHAPES, ArchSpec, shrink

__all__ = ["ARCHS", "ARCH_IDS", "get_arch", "SHAPES", "ArchSpec", "shrink"]
