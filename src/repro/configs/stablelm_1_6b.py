"""Assigned architecture config: stablelm-1.6b (defined in archs.py)."""
from repro.configs.archs import get_arch

ARCH = get_arch("stablelm-1.6b")
MODEL = ARCH.model
