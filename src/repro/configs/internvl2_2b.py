"""Assigned architecture config: internvl2-2b (defined in archs.py)."""
from repro.configs.archs import get_arch

ARCH = get_arch("internvl2-2b")
MODEL = ARCH.model
