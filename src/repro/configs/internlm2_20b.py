"""Assigned architecture config: internlm2-20b (defined in archs.py)."""
from repro.configs.archs import get_arch

ARCH = get_arch("internlm2-20b")
MODEL = ARCH.model
