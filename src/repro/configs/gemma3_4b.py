"""Assigned architecture config: gemma3-4b (defined in archs.py)."""
from repro.configs.archs import get_arch

ARCH = get_arch("gemma3-4b")
MODEL = ARCH.model
