"""Shared architecture/shape plumbing for the assigned-architecture pool.

Every architecture module exposes ``ARCH: ArchSpec``.  The four assigned
input shapes are global; per-arch skip rules follow DESIGN.md §5.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

from repro.models.model import LayerSpec, ModelConfig

# assigned shape set: name -> (seq_len, global_batch, kind)
SHAPES = {
    "train_4k": (4096, 256, "train"),
    "prefill_32k": (32768, 32, "prefill"),
    "decode_32k": (32768, 128, "decode"),
    "long_500k": (524288, 1, "decode"),
}


@dataclasses.dataclass(frozen=True)
class ArchSpec:
    arch_id: str
    model: ModelConfig
    long_ok: bool = False       # sub-quadratic enough for long_500k
    decode_ok: bool = True      # encoder-only archs would set False
    source: str = ""            # provenance tag from the assignment table

    def shapes(self):
        for name, (seq, batch, kind) in SHAPES.items():
            if name == "long_500k" and not self.long_ok:
                continue
            if kind == "decode" and not self.decode_ok:
                continue
            yield name, (seq, batch, kind)


def dense_blocks(n_layers: int, window: Optional[int] = None):
    return ((
        (LayerSpec(kind="attn", window=window, mlp="dense"),),
        n_layers,
    ),)


def shrink(cfg: ModelConfig, **over) -> ModelConfig:
    """Reduced same-family config for CPU smoke tests (few layers, tiny
    widths/vocab/experts) — structure preserved, scale removed."""
    blocks = tuple((pattern, 1) for pattern, _ in cfg.blocks[:2])
    small = dict(
        d_model=64,
        n_heads=4,
        n_kv_heads=min(cfg.n_kv_heads, 4) if cfg.n_kv_heads > 1 else 1,
        head_dim=16,
        d_ff=128,
        vocab_size=256,
        blocks=blocks,
        n_enc_layers=min(cfg.n_enc_layers, 2),
        n_experts=min(cfg.n_experts, 4) if cfg.n_experts else 0,
        top_k=min(cfg.top_k, 2) if cfg.top_k else 0,
        d_ff_expert=32 if cfg.d_ff_expert else 0,
        kv_lora=32 if cfg.kv_lora else 0,
        d_nope=16 if cfg.d_nope else 0,
        d_rope=16 if cfg.d_rope else 0,
        d_state=min(cfg.d_state, 4),
        expand=cfg.expand,
        dt_rank=4 if cfg.dt_rank or cfg.blocks_have("mamba") else 0,
        max_seq=512,
        frontend_len=4 if cfg.frontend_len else 0,
        remat="none",
        moe_ep=False,
    )
    # shrink sliding windows in the pattern
    blocks2 = []
    for pattern, reps in blocks:
        blocks2.append((tuple(
            dataclasses.replace(s, window=8 if s.window else None)
            for s in pattern), reps))
    small["blocks"] = tuple(blocks2)
    small.update(over)
    return dataclasses.replace(cfg, **small)


def _blocks_have(self, kind: str) -> bool:
    return any(s.kind == kind for pattern, _ in self.blocks for s in pattern)


ModelConfig.blocks_have = _blocks_have
