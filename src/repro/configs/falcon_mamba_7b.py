"""Assigned architecture config: falcon-mamba-7b (defined in archs.py)."""
from repro.configs.archs import get_arch

ARCH = get_arch("falcon-mamba-7b")
MODEL = ARCH.model
