"""Assigned architecture config: granite-20b (defined in archs.py)."""
from repro.configs.archs import get_arch

ARCH = get_arch("granite-20b")
MODEL = ARCH.model
