"""Assigned architecture config: jamba-v0.1-52b (defined in archs.py)."""
from repro.configs.archs import get_arch

ARCH = get_arch("jamba-v0.1-52b")
MODEL = ARCH.model
