"""Assigned architecture config: whisper-base (defined in archs.py)."""
from repro.configs.archs import get_arch

ARCH = get_arch("whisper-base")
MODEL = ARCH.model
