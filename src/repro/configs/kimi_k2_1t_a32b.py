"""Assigned architecture config: kimi-k2-1t-a32b (defined in archs.py)."""
from repro.configs.archs import get_arch

ARCH = get_arch("kimi-k2-1t-a32b")
MODEL = ARCH.model
