"""The 10 assigned architectures (exact configs from the assignment table).

Sources noted per-arch; where the upstream model differs in minutiae from the
assignment line, the assignment line wins (it defines the graded cells).
Substrate simplifications (GELU->SwiGLU for whisper/granite, LayerNorm->
RMSNorm) are uniform across archs and noted in DESIGN.md.
"""
from __future__ import annotations

from repro.configs.common import ArchSpec, dense_blocks
from repro.models.model import LayerSpec, ModelConfig

_A = {}


def _reg(spec: ArchSpec):
    _A[spec.arch_id] = spec
    return spec


# ------------------------------------------------------------ gemma3-4b
# 34L, 5:1 local:global interleave, window 1024, GQA 8H/kv4, 128k ctx.
_L = LayerSpec(kind="attn", window=1024, mlp="dense")
_G = LayerSpec(kind="attn", window=None, mlp="dense")
_reg(ArchSpec(
    arch_id="gemma3-4b",
    model=ModelConfig(
        name="gemma3-4b", d_model=2560, n_heads=8, n_kv_heads=4, head_dim=256,
        d_ff=10240, vocab_size=262144,
        blocks=(((_L, _L, _L, _L, _L, _G), 5), ((_L, _L, _L, _L), 1)),
        rope_theta=10000.0, max_seq=131072,
    ),
    long_ok=True,  # only 6 global layers hold the full 512k cache
    source="hf:google/gemma-3-4b (assignment table)",
))

# ---------------------------------------------------------- stablelm-1.6b
_reg(ArchSpec(
    arch_id="stablelm-1.6b",
    model=ModelConfig(
        name="stablelm-1.6b", d_model=2048, n_heads=32, n_kv_heads=32,
        head_dim=64, d_ff=5632, vocab_size=100352,
        blocks=dense_blocks(24),
    ),
    long_ok=False,  # pure full attention -> long_500k skipped (DESIGN §5)
    source="hf:stabilityai/stablelm-2-1_6b",
))

# ------------------------------------------------------------ granite-20b
_reg(ArchSpec(
    arch_id="granite-20b",
    model=ModelConfig(
        name="granite-20b", d_model=6144, n_heads=48, n_kv_heads=1,
        head_dim=128, d_ff=24576, vocab_size=49152,
        blocks=dense_blocks(52),
    ),
    long_ok=False,
    source="arXiv:2405.04324 (MQA kv=1)",
))

# ----------------------------------------------------------- internlm2-20b
_reg(ArchSpec(
    arch_id="internlm2-20b",
    model=ModelConfig(
        name="internlm2-20b", d_model=6144, n_heads=48, n_kv_heads=8,
        head_dim=128, d_ff=16384, vocab_size=92544,
        blocks=dense_blocks(48),
    ),
    long_ok=False,
    source="arXiv:2403.17297",
))

# --------------------------------------------------------- falcon-mamba-7b
_M = LayerSpec(kind="mamba", mlp="dense")
_reg(ArchSpec(
    arch_id="falcon-mamba-7b",
    model=ModelConfig(
        name="falcon-mamba-7b", d_model=4096, n_heads=1, n_kv_heads=1,
        head_dim=64, d_ff=0, vocab_size=65024,
        # mamba1 block has no separate MLP: d_ff=0 -> use pure mamba layers
        blocks=(((LayerSpec(kind="mamba", mlp="none"),), 64),),
        d_state=16, d_conv=4, expand=2, dt_rank=256,
    ),
    long_ok=True,  # O(1) recurrent state
    source="arXiv:2410.05355 (mamba1)",
))

# ------------------------------------------------------------ jamba-v0.1
# 1:7 attn:mamba interleave; MoE every other layer (16 experts, top-2).
_Jm_d = LayerSpec(kind="mamba", mlp="dense")
_Jm_e = LayerSpec(kind="mamba", mlp="moe")
_Ja_d = LayerSpec(kind="attn", window=None, mlp="dense")
_reg(ArchSpec(
    arch_id="jamba-v0.1-52b",
    model=ModelConfig(
        name="jamba-v0.1-52b", d_model=4096, n_heads=32, n_kv_heads=8,
        head_dim=128, d_ff=14336, vocab_size=65536,
        blocks=(((_Jm_d, _Jm_e, _Jm_d, _Jm_e, _Ja_d, _Jm_e, _Jm_d, _Jm_e), 4),),
        n_experts=16, top_k=2, d_ff_expert=14336,
        d_state=16, d_conv=4, expand=2, dt_rank=256,
    ),
    long_ok=True,  # only 4 attention layers hold caches (1:7 hybrid)
    source="arXiv:2403.19887",
))

# ----------------------------------------------------------- internvl2-2b
_reg(ArchSpec(
    arch_id="internvl2-2b",
    model=ModelConfig(
        name="internvl2-2b", d_model=2048, n_heads=16, n_kv_heads=8,
        head_dim=128, d_ff=8192, vocab_size=92553,
        blocks=dense_blocks(24),
        frontend="vision_stub", frontend_len=1024,
    ),
    long_ok=False,
    source="arXiv:2404.16821 (InternViT stub + InternLM2-2B backbone)",
))

# ------------------------------------------------------------ whisper-base
_W = LayerSpec(kind="attn", window=None, mlp="dense", cross_attn=True)
_reg(ArchSpec(
    arch_id="whisper-base",
    model=ModelConfig(
        name="whisper-base", d_model=512, n_heads=8, n_kv_heads=8,
        head_dim=64, d_ff=2048, vocab_size=51865,
        blocks=(((_W,), 6),),
        kind="encdec", n_enc_layers=6,
        use_rope=False, max_seq=65536,  # extended decoder position table
        frontend="audio_stub", frontend_len=1500,
    ),
    long_ok=False,  # 448-token natural decoder ctx; 500k senseless
    source="arXiv:2212.04356 (conv frontend stubbed)",
))

# -------------------------------------------------------- deepseek-v2-lite
_Dd = LayerSpec(kind="mla", mlp="dense")
_De = LayerSpec(kind="mla", mlp="moe")
_reg(ArchSpec(
    arch_id="deepseek-v2-lite-16b",
    model=ModelConfig(
        name="deepseek-v2-lite-16b", d_model=2048, n_heads=16, n_kv_heads=16,
        head_dim=128, d_ff=10944, vocab_size=102400,
        blocks=(((_Dd,), 1), ((_De,), 26)),
        n_experts=64, top_k=6, n_shared=2, d_ff_expert=1408,
        kv_lora=512, d_nope=128, d_rope=64,
    ),
    long_ok=False,  # MLA compresses memory but attention is still full
    source="arXiv:2405.04434 (MLA kv_lora=512; 2 shared + 64 routed top-6)",
))

# ------------------------------------------------------------- kimi-k2-1t
_Kd = LayerSpec(kind="attn", window=None, mlp="dense")
_Ke = LayerSpec(kind="attn", window=None, mlp="moe")
_reg(ArchSpec(
    arch_id="kimi-k2-1t-a32b",
    model=ModelConfig(
        name="kimi-k2-1t-a32b", d_model=7168, n_heads=64, n_kv_heads=8,
        head_dim=112, d_ff=18432, vocab_size=163840,
        blocks=(((_Kd,), 1), ((_Ke,), 60)),
        n_experts=384, top_k=8, n_shared=1, d_ff_expert=2048,
    ),
    long_ok=False,
    source="arXiv:2501.kimi2 (paper-table; GQA kv=8 per assignment)",
))

ARCHS = dict(_A)
ARCH_IDS = tuple(ARCHS.keys())


def get_arch(arch_id: str) -> ArchSpec:
    return ARCHS[arch_id]
