"""Assigned architecture config: deepseek-v2-lite-16b (defined in archs.py)."""
from repro.configs.archs import get_arch

ARCH = get_arch("deepseek-v2-lite-16b")
MODEL = ARCH.model
