"""AdamW and Adafactor as pure pytree transforms.

State layout mirrors the param pytree so the same PartitionSpecs shard the
optimizer state (ZeRO-style: state is FSDP-sharded exactly like its param).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

Params = Any


@dataclasses.dataclass(frozen=True)
class OptConfig:
    kind: str = "adamw"        # 'adamw' | 'adafactor'
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.999          # adafactor: decay for factored 2nd moment
    eps: float = 1e-8
    weight_decay: float = 0.01
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10000
    min_lr_ratio: float = 0.1
    moment_dtype: str = "float32"   # 'bfloat16' halves 1st-moment memory


def schedule_lr(cfg: OptConfig, step):
    """Linear warmup + cosine decay."""
    step = step.astype(jnp.float32)
    # (step+1): the first step must not see lr=0 (off-by-one guard)
    warm = jnp.minimum(1.0, (step + 1.0) / max(1, cfg.warmup_steps))
    prog = jnp.clip((step - cfg.warmup_steps)
                    / max(1, cfg.total_steps - cfg.warmup_steps), 0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(np.pi * prog))
    return cfg.lr * warm * (cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * cos)


def global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


# ------------------------------------------------------------------- AdamW
def adamw_init(params, cfg: OptConfig):
    mdt = jnp.dtype(cfg.moment_dtype)
    return {
        "m": jax.tree.map(lambda p: jnp.zeros_like(p, mdt), params),
        "v": jax.tree.map(lambda p: jnp.zeros_like(p, mdt), params),
        "step": jnp.zeros((), jnp.int32),
    }


def _adamw_update(g, p, m, v, lr, cfg: OptConfig, step):
    g = g.astype(jnp.float32)
    m1 = cfg.b1 * m.astype(jnp.float32) + (1 - cfg.b1) * g
    v1 = cfg.b2 * v.astype(jnp.float32) + (1 - cfg.b2) * g * g
    t = step.astype(jnp.float32) + 1.0
    mh = m1 / (1 - cfg.b1 ** t)
    vh = v1 / (1 - cfg.b2 ** t)
    upd = mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
    return -lr * upd, m1, v1


# --------------------------------------------------------------- Adafactor
def _factored_dims(shape):
    """Last two non-trivial dims get factored; else None (vector-like)."""
    if len(shape) < 2 or shape[-1] <= 1 or shape[-2] <= 1:
        return None
    return len(shape) - 2, len(shape) - 1


def adafactor_init(params, cfg: OptConfig):
    mdt = jnp.dtype(cfg.moment_dtype)

    def vstate(p):
        f = _factored_dims(p.shape)
        if f is None:
            return {"v": jnp.zeros(p.shape, jnp.float32)}
        r, c = f
        vr = jnp.zeros(p.shape[:-1], jnp.float32)            # row stats
        vc = jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)  # col stats
        return {"vr": vr, "vc": vc}

    return {
        "m": jax.tree.map(lambda p: jnp.zeros_like(p, mdt), params),
        "v": jax.tree.map(vstate, params),
        "step": jnp.zeros((), jnp.int32),
    }


def _adafactor_update(g, p, m, v, lr, cfg: OptConfig, step):
    g = g.astype(jnp.float32)
    t = step.astype(jnp.float32) + 1.0
    beta2 = 1.0 - t ** -0.8  # Adafactor's schedule-free decay
    g2 = g * g + 1e-30
    f = _factored_dims(g.shape)
    if f is None:
        v1 = {"v": beta2 * v["v"] + (1 - beta2) * g2}
        pre = g / (jnp.sqrt(v1["v"]) + cfg.eps)
        vout = v1
    else:
        r, c = f
        vr = beta2 * v["vr"] + (1 - beta2) * jnp.mean(g2, axis=-1)
        vc = beta2 * v["vc"] + (1 - beta2) * jnp.mean(g2, axis=-2)
        rfac = vr / jnp.clip(jnp.mean(vr, axis=-1, keepdims=True), 1e-30)
        pre = g * jax.lax.rsqrt(rfac[..., None] + cfg.eps) \
            * jax.lax.rsqrt(vc[..., None, :] + cfg.eps)
        vout = {"vr": vr, "vc": vc}
    # update clipping (RMS <= 1) per Adafactor
    rms = jnp.sqrt(jnp.mean(pre * pre) + 1e-30)
    pre = pre / jnp.maximum(1.0, rms)
    m1 = cfg.b1 * m.astype(jnp.float32) + (1 - cfg.b1) * pre
    upd = m1 + cfg.weight_decay * p.astype(jnp.float32)
    return -lr * upd, m1, vout


# ------------------------------------------------------------------ driver
def init_opt_state(params, cfg: OptConfig):
    return (adafactor_init if cfg.kind == "adafactor" else adamw_init)(params, cfg)


def opt_update(grads, params, state, cfg: OptConfig):
    """Returns (updates, new_state). Applies grad clip + lr schedule."""
    step = state["step"]
    lr = schedule_lr(cfg, step)
    if cfg.grad_clip:
        gn = global_norm(grads)
        scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gn, 1e-9))
        grads = jax.tree.map(lambda g: g * scale.astype(g.dtype), grads)

    mdt = jnp.dtype(cfg.moment_dtype)
    upd_fn = _adafactor_update if cfg.kind == "adafactor" else _adamw_update

    flat_g, tdef = jax.tree.flatten(grads)
    flat_p = jax.tree.leaves(params)
    flat_m = jax.tree.leaves(state["m"])
    if cfg.kind == "adafactor":
        # v is a tree of dicts — flatten at the param level
        flat_v = tdef.flatten_up_to(state["v"])
    else:
        flat_v = jax.tree.leaves(state["v"])

    ups, ms, vs = [], [], []
    for g, p, m, v in zip(flat_g, flat_p, flat_m, flat_v):
        u, m1, v1 = upd_fn(g, p, m, v, lr, cfg, step)
        ups.append(u)
        ms.append(m1.astype(mdt))
        vs.append(v1)
    updates = jax.tree.unflatten(tdef, ups)
    new_state = {
        "m": jax.tree.unflatten(tdef, ms),
        "v": jax.tree.unflatten(tdef, vs),
        "step": step + 1,
    }
    return updates, new_state


def apply_updates(params, updates):
    return jax.tree.map(lambda p, u: (p.astype(jnp.float32) + u).astype(p.dtype),
                        params, updates)
