"""Optimizers (pure pytree implementations): AdamW and Adafactor.

Adafactor (factored second moment + bf16 first moment) is the default for
≥100B-parameter configs: AdamW state at kimi-k2 scale would need ~16 TB
(> 512 × 16 GB HBM), Adafactor needs ~4.5 bytes/param (DESIGN.md §6).
"""
from repro.optim.optimizers import (OptConfig, adafactor_init, adamw_init,
                                    apply_updates, global_norm, init_opt_state,
                                    opt_update)

__all__ = ["OptConfig", "adamw_init", "adafactor_init", "init_opt_state",
           "opt_update", "apply_updates", "global_norm"]
