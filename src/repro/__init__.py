"""repro: parallel simulated annealing (Ferreiro et al.) as a multi-pod JAX framework."""
__version__ = "0.1.0"
