"""Pure-jnp oracle for the Metropolis-sweep kernel.

Computes the *identical* floating-point recurrence as the Pallas kernel
(same RNG counters via ``rng.draws3``, same accumulator math via
``objective_math``), vectorized over all chains at once with no blocking.
Because the RNG is counter-based on the global chain index, the kernel's
chain-block decomposition does not change random streams, so kernel and
oracle must agree to float tolerance.

For the multi-tenant serving engine the control inputs generalize from
scalars to per-chain arrays: ``kid``, ``T``, ``seed`` and ``step0`` may each
be a scalar or a ``(chains,)`` array, and ``cidx`` optionally overrides the
global chain indices — the per-chain analogue of the kernel's per-block
SMEM arrays (a serving slot's chains all share one entry).

Like the kernel, the objective id ``kid`` is a *runtime* input when passed
as an array or traced value (dispatched with branchless ``jnp.where``
chains — objective_math ``*_rt``), so one compiled oracle serves every
registry objective at a fixed ``(dim, n_steps, variant)`` and
mixed-objective batches are legal.  A concrete Python-int ``kid`` compiles
the single objective branch instead (1x objective math for batch callers;
both paths are bit-exact against each other by construction).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.kernels import objective_math as om
from repro.kernels import rng


def _col(v, chains: int, dtype):
    """Scalar or (chains,) input -> (chains, 1) column."""
    a = jnp.asarray(v, dtype).reshape(-1)
    if a.shape[0] == 1:
        a = jnp.broadcast_to(a, (chains,))
    return a[:, None]


def metropolis_sweep_ref(x, T, seed, step0, *, kid, n_steps: int,
                         variant: str = "delta", cidx=None, live=None):
    from repro.kernels.metropolis_sweep import _validate_kid
    _validate_kid(kid)
    # Concrete scalar kid -> single-branch specialization (1x objective
    # math, one jit cache entry per objective — the pre-runtime behavior);
    # array/traced kid -> runtime jnp.where dispatch, one entry total.
    if isinstance(kid, (int, np.integer)):
        return _metropolis_sweep_ref_static(
            x, T, seed, step0, kid=int(kid), n_steps=n_steps,
            variant=variant, cidx=cidx, live=live)
    return _metropolis_sweep_ref(x, T, seed, step0, kid=kid, n_steps=n_steps,
                                 variant=variant, cidx=cidx, live=live)


@partial(jax.jit, static_argnames=("kid", "n_steps", "variant"))
def _metropolis_sweep_ref_static(x, T, seed, step0, *, kid: int,
                                 n_steps: int, variant: str = "delta",
                                 cidx=None, live=None):
    lo, hi = om.BOX[kid]
    return _sweep_ref_body(x, T, seed, step0, kid, np.float32(lo),
                           np.float32(hi), om.init_acc, om.combine, om.term,
                           om.full_eval, n_steps, variant, cidx, live)


@partial(jax.jit, static_argnames=("n_steps", "variant"))
def _metropolis_sweep_ref(x, T, seed, step0, *, kid, n_steps: int,
                          variant: str = "delta", cidx=None, live=None):
    kid = _col(kid, x.shape[0], jnp.int32)
    lo, hi = om.box_rt(kid, dtype=x.dtype)  # (chains, 1) box bounds
    return _sweep_ref_body(x, T, seed, step0, kid, lo, hi, om.init_acc_rt,
                           om.combine_rt, om.term_rt, om.full_eval_rt,
                           n_steps, variant, cidx, live)


def _sweep_ref_body(x, T, seed, step0, kid, lo, hi, init_acc, combine, term,
                    full_eval, n_steps, variant, cidx, live=None):
    chains, dim = x.shape
    if cidx is None:
        cidx = jnp.arange(chains, dtype=jnp.uint32)[:, None]  # (chains, 1)
    else:
        cidx = _col(cidx, chains, jnp.uint32)
    coords = jnp.broadcast_to(jnp.arange(dim, dtype=jnp.int32), (chains, dim))
    seed = _col(seed, chains, jnp.uint32)
    step0 = _col(step0, chains, jnp.uint32)
    T = _col(T, chains, x.dtype)
    # Per-chain level cursor (macro-tick serving): a dead chain's accepts
    # are all masked off so its state passes through bit-exactly — the
    # oracle-side mirror of the kernel's per-block ``live`` SMEM input.
    live = None if live is None else _col(live, chains, jnp.bool_)

    if variant == "delta":
        S, logP, sgnP = init_acc(kid, x)
        fx = combine(kid, S, logP, sgnP, dim)

        def body(i, carry):
            x, fx, S, logP, sgnP = carry
            rbits, uval, uacc = rng.draws3(seed, cidx, (step0 + i).astype(jnp.uint32))
            d = (rbits % np.uint32(dim)).astype(jnp.int32)
            onehot = coords == d
            xi_old = jnp.sum(jnp.where(onehot, x, 0.0), axis=1, keepdims=True)
            newval = lo + uval * (hi - lo)
            df = d.astype(x.dtype)
            s_old, p_old = term(kid, xi_old, df)
            s_new, p_new = term(kid, newval, df)
            S1 = S - s_old + s_new
            logP1 = (logP
                     - jnp.log(jnp.maximum(jnp.abs(p_old), 1e-30))
                     + jnp.log(jnp.maximum(jnp.abs(p_new), 1e-30)))
            sg = jnp.where(p_old < 0, -1.0, 1.0) * jnp.where(p_new < 0, -1.0, 1.0)
            sgnP1 = sgnP * sg.astype(sgnP.dtype)
            f1 = combine(kid, S1, logP1, sgnP1, dim)
            acc = uacc <= jnp.exp(jnp.clip(-(f1 - fx) / T, -80.0, 80.0))
            if live is not None:
                acc = acc & live
            x = jnp.where(onehot & acc, newval, x)
            fx = jnp.where(acc, f1, fx)
            S = jnp.where(acc, S1, S)
            logP = jnp.where(acc, logP1, logP)
            sgnP = jnp.where(acc, sgnP1, sgnP)
            return x, fx, S, logP, sgnP

        x, fx, *_ = lax.fori_loop(0, n_steps, body, (x, fx, S, logP, sgnP))
    else:
        fx = full_eval(kid, x, dim)

        def body(i, carry):
            x, fx = carry
            rbits, uval, uacc = rng.draws3(seed, cidx, (step0 + i).astype(jnp.uint32))
            d = (rbits % np.uint32(dim)).astype(jnp.int32)
            onehot = coords == d
            newval = lo + uval * (hi - lo)
            x1 = jnp.where(onehot, newval, x)
            f1 = full_eval(kid, x1, dim)
            acc = uacc <= jnp.exp(jnp.clip(-(f1 - fx) / T, -80.0, 80.0))
            if live is not None:
                acc = acc & live
            x = jnp.where(acc, x1, x)
            fx = jnp.where(acc, f1, fx)
            return x, fx

        x, fx = lax.fori_loop(0, n_steps, body, (x, fx))

    return x, fx[:, 0]


def qap_sweep_ref(p, F, D, T, seed, step0, *, n_steps: int, cidx=None,
                  live=None):
    """Pure-jnp oracle for the QAP pairwise-exchange sweep kernel.

    Runs the *shared* step recurrence (``qap_sweep.qap_swap_sweep``) over
    the whole batch at once, so it is bit-exact against the Pallas
    lowering by construction — the permutation-family analogue of
    ``metropolis_sweep_ref``.  ``F``/``D`` are ``(n, n)`` (one instance for
    every chain) or per-chain ``(chains, n, n)``; ``T``/``seed``/``step0``
    are scalars or ``(chains,)``; ``cidx`` optionally overrides the global
    chain indices and ``live`` is the per-chain macro-tick level cursor.

    Returns (p_out (chains, n) int32, f_out (chains,) float32).
    """
    return _qap_sweep_ref(p, F, D, T, seed, step0, n_steps=n_steps,
                          cidx=cidx, live=live)


@partial(jax.jit, static_argnames=("n_steps",))
def _qap_sweep_ref(p, F, D, T, seed, step0, *, n_steps: int, cidx=None,
                   live=None):
    from repro.kernels.qap_sweep import qap_full_cost, qap_swap_sweep
    chains = p.shape[0]
    if cidx is None:
        cidx = jnp.arange(chains, dtype=jnp.uint32)[:, None]
    else:
        cidx = _col(cidx, chains, jnp.uint32)
    seed = _col(seed, chains, jnp.uint32)
    step0 = _col(step0, chains, jnp.uint32)
    T = _col(T, chains, jnp.float32)
    live = None if live is None else _col(live, chains, jnp.bool_)
    F = jnp.asarray(F, jnp.float32)
    D = jnp.asarray(D, jnp.float32)
    fx = qap_full_cost(p, F, D)
    p, fx = qap_swap_sweep(p, fx, F, D, T, seed, cidx, step0,
                           n_steps=n_steps, live=live)
    return p, fx[:, 0]
