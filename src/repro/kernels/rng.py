"""Counter-based threefry2x32 RNG usable both inside Pallas kernels and in
pure-jnp reference code.

This is the TPU adaptation of the paper's CURAND usage: random bits are
produced on the fly from (key, counter) with pure uint32 VPU arithmetic —
no RNG state ever touches HBM (DESIGN.md §2).  Streams are indexed by
(seed, global_chain_index, step, draw), so results are *identical* under any
chain blocking/sharding — the kernel and the reference oracle agree exactly.
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

_ROT = (13, 15, 26, 6, 17, 29, 16, 24)
_PARITY = np.uint32(0x1BD11BDA)


def _rotl(x, r):
    return (x << np.uint32(r)) | (x >> np.uint32(32 - r))


def threefry2x32(k0, k1, x0, x1):
    """Standard 20-round threefry2x32. All args uint32 arrays (broadcastable).

    Returns two uint32 arrays of the broadcast shape.
    """
    k0 = jnp.asarray(k0, jnp.uint32)
    k1 = jnp.asarray(k1, jnp.uint32)
    x0 = jnp.asarray(x0, jnp.uint32)
    x1 = jnp.asarray(x1, jnp.uint32)
    ks = (k0, k1, k0 ^ k1 ^ _PARITY)
    x0 = x0 + ks[0]
    x1 = x1 + ks[1]
    for block in range(5):
        for i in range(4):
            x0 = x0 + x1
            x1 = _rotl(x1, _ROT[(block * 4 + i) % 8])
            x1 = x1 ^ x0
        x0 = x0 + ks[(block + 1) % 3]
        x1 = x1 + ks[(block + 2) % 3] + np.uint32(block + 1)
    return x0, x1


def uniform_from_bits(bits):
    """uint32 -> float32 uniform in [0, 1) with 24-bit mantissa usage."""
    return (bits >> np.uint32(8)).astype(jnp.float32) * np.float32(1.0 / (1 << 24))


def draws3(seed, chain_idx, step):
    """The paper's three uniforms per Metropolis step + one spare.

    chain_idx: uint32 array (any shape); step: scalar uint32.
    Returns (u_coord_bits, u_value, u_accept) — the coordinate draw is
    returned as raw bits so the caller can mod by ``dim`` without bias games.
    """
    seed = jnp.asarray(seed, jnp.uint32)
    step = jnp.asarray(step, jnp.uint32)
    c = jnp.asarray(chain_idx, jnp.uint32)
    r0, r1 = threefry2x32(seed, step * np.uint32(2), c, jnp.zeros_like(c))
    r2, _ = threefry2x32(seed, step * np.uint32(2) + np.uint32(1), c, jnp.ones_like(c))
    return r0, uniform_from_bits(r1), uniform_from_bits(r2)
