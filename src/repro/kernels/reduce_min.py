"""Pallas TPU kernel: block-tree (min, argmin) reduction.

The paper's V1/V2 champion selection is a Thrust ``reduceMin`` over the
per-chain objective values (shared-memory partial reductions per block,
then a host-side combine).  TPU adaptation: a grid of chain blocks, each
reducing its (1, blk) VMEM tile to a per-block (min, argmin) pair on the
VPU; the tiny (n_blocks,) tail is combined with a plain ``jnp.argmin``
(the analogue of Thrust's final pass, but staying on-device).

Tie-breaking matches ``jnp.argmin``: the first (lowest-index) minimum wins
within a block and across blocks, so the kernel is bit-identical to the
oracle (tests/test_kernels_pallas.py sweeps shapes/dtypes).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl


def _argmin_kernel(f_ref, m_ref, i_ref, *, blk: int):
    pid = pl.program_id(0)
    f = f_ref[...]                                    # (1, blk)
    idx = lax.broadcasted_iota(jnp.int32, (1, blk), 1)
    m = jnp.min(f)
    # first index attaining the block minimum
    i = jnp.min(jnp.where(f == m, idx, blk))
    m_ref[0, 0] = m
    i_ref[0, 0] = pid * blk + i


def block_argmin_pallas(f, *, blk: int = 1024, interpret: bool = False):
    """Per-block (min, argmin) of a 1-D value vector.

    Returns (mins (n_blocks,), idxs (n_blocks,)); combine with
    :func:`argmin_reduce` (or any tail reduce).
    """
    (n,) = f.shape
    if n % blk:
        raise ValueError(f"n={n} must be a multiple of blk={blk}")
    grid = (n // blk,)
    mins, idxs = pl.pallas_call(
        functools.partial(_argmin_kernel, blk=blk),
        grid=grid,
        in_specs=[pl.BlockSpec((1, blk), lambda i: (0, i))],
        out_specs=[pl.BlockSpec((1, 1), lambda i: (0, i)),
                   pl.BlockSpec((1, 1), lambda i: (0, i))],
        out_shape=[jax.ShapeDtypeStruct((1, grid[0]), f.dtype),
                   jax.ShapeDtypeStruct((1, grid[0]), jnp.int32)],
        interpret=interpret,
        name="block_argmin",
    )(f.reshape(1, n))
    return mins[0], idxs[0]


def argmin_reduce(f, *, blk: int = 1024, use_pallas: bool = False,
                  interpret: bool = False):
    """(min_value, argmin_index) of ``f`` — the paper's reduceMin.

    With ``use_pallas`` the per-block stage runs as the TPU kernel;
    otherwise pure jnp (identical result).
    """
    (n,) = f.shape
    if use_pallas and n % blk == 0 and n >= blk:
        mins, idxs = block_argmin_pallas(f, blk=blk, interpret=interpret)
        j = jnp.argmin(mins)            # ties: first block wins, as jnp
        return mins[j], idxs[j]
    i = jnp.argmin(f)
    return f[i], i.astype(jnp.int32)
