"""Pallas TPU kernels (validated in interpret mode on CPU)."""
from repro.kernels import objective_math
from repro.kernels.ops import metropolis_sweep, resolve_use_pallas
from repro.kernels.reduce_min import argmin_reduce, block_argmin_pallas
