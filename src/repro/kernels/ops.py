"""Public jit'd wrappers around the Pallas kernels.

``use_pallas='auto'`` selects the Pallas path on TPU backends and the pure
XLA reference elsewhere (the CPU container cannot lower TPU custom calls;
tests exercise the kernels under ``interpret=True``).
"""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import objective_math as om
from repro.kernels import ref as ref_mod
from repro.kernels.metropolis_sweep import metropolis_sweep_pallas


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def resolve_use_pallas(use_pallas) -> bool:
    if use_pallas == "auto":
        return _on_tpu()
    return bool(use_pallas)


@partial(jax.jit, static_argnames=("kid", "n_steps", "variant", "blk",
                                   "use_pallas", "interpret"))
def metropolis_sweep(x, T, seed, step0, *, kid: int, n_steps: int,
                     variant: str = "delta", blk: int = 256,
                     use_pallas: bool = False, interpret: bool = False):
    """N-step Metropolis sweep over all chains (see metropolis_sweep.py).

    Returns (x_out (chains, dim), f_out (chains,)).
    """
    if use_pallas:
        chains = x.shape[0]
        eff_blk = min(blk, chains)
        return metropolis_sweep_pallas(
            x, T, seed, step0, kid=kid, n_steps=n_steps, blk=eff_blk,
            variant=variant, interpret=interpret)
    return ref_mod.metropolis_sweep_ref(
        x, T, seed, step0, kid=kid, n_steps=n_steps, variant=variant)


@partial(jax.jit, static_argnames=("kid", "n_steps", "blk", "variant",
                                   "use_pallas", "interpret"))
def metropolis_sweep_slots(x, T_blocks, seeds, step0s, chain_base, *,
                           kid: int, n_steps: int, blk: int,
                           variant: str = "delta", use_pallas: bool = False,
                           interpret: bool = False):
    """Heterogeneous-slot Metropolis sweep: one serving slot per chain-block.

    ``x`` is ``(n_blocks * blk, dim)`` — the packed states of every active
    slot in a dispatch group — and each per-block control array has one entry
    per slot: its request's temperature, RNG seed, Metropolis step counter
    and global chain-index base.  On TPU this is a single Pallas launch with
    the SMEM arrays indexed by ``program_id``; elsewhere the per-block arrays
    expand to per-chain columns for the jnp oracle.  Both produce identical
    streams, so slot placement never changes a request's trajectory.

    Returns (x_out (n_blocks*blk, dim), f_out (n_blocks*blk,)).
    """
    chains = x.shape[0]
    if chains % blk:
        raise ValueError(
            f"packed chains={chains} must be a multiple of blk={blk}")
    if use_pallas:
        from repro.kernels.metropolis_sweep import metropolis_sweep_pallas as mk
        return mk(x, T_blocks, seeds, step0s, kid=kid, n_steps=n_steps,
                  blk=blk, variant=variant, interpret=interpret,
                  chain_base=chain_base)
    n_blocks = chains // blk

    def expand(a):
        a = jnp.asarray(a).reshape(-1)
        if a.shape[0] == 1:  # scalar input: same broadcast as the Pallas path
            a = jnp.broadcast_to(a, (n_blocks,))
        return jnp.repeat(a, blk)

    lane = jnp.tile(jnp.arange(blk, dtype=jnp.uint32), n_blocks)
    cidx = expand(chain_base).astype(jnp.uint32) + lane
    return ref_mod.metropolis_sweep_ref(
        x, expand(T_blocks), expand(seeds), expand(step0s),
        kid=kid, n_steps=n_steps, variant=variant, cidx=cidx)


def kid_for(objective) -> Optional[int]:
    """Registry kernel id for an Objective, or None."""
    return getattr(objective, "kernel_id", None)
