"""Public jit'd wrappers around the Pallas kernels.

``use_pallas='auto'`` selects the Pallas path on TPU backends and the pure
XLA reference elsewhere (the CPU container cannot lower TPU custom calls;
tests exercise the kernels under ``interpret=True``).
"""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import objective_math as om
from repro.kernels import ref as ref_mod
from repro.kernels.metropolis_sweep import metropolis_sweep_pallas


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def resolve_use_pallas(use_pallas) -> bool:
    if use_pallas == "auto":
        return _on_tpu()
    return bool(use_pallas)


def metropolis_sweep(x, T, seed, step0, *, kid, n_steps: int,
                     variant: str = "delta", blk: int = 256,
                     use_pallas: bool = False, interpret: bool = False):
    """N-step Metropolis sweep over all chains (see metropolis_sweep.py).

    A concrete Python-int ``kid`` compiles the single objective branch
    (1x objective math, one program per objective — the batch/benchmark
    path); an array or jnp scalar ``kid`` is a runtime input dispatched
    inside one compiled program that serves every registry objective.
    Concrete out-of-registry ids are rejected eagerly — inside jit they
    would otherwise fall through the runtime dispatch to kid 0.

    Returns (x_out (chains, dim), f_out (chains,)).
    """
    from repro.kernels.metropolis_sweep import _validate_kid
    _validate_kid(kid)
    if isinstance(kid, (int, np.integer)):
        return _metropolis_sweep_static(
            x, T, seed, step0, kid=int(kid), n_steps=n_steps,
            variant=variant, blk=blk, use_pallas=use_pallas,
            interpret=interpret)
    return _metropolis_sweep(x, T, seed, step0, kid=kid, n_steps=n_steps,
                             variant=variant, blk=blk, use_pallas=use_pallas,
                             interpret=interpret)


def _metropolis_sweep_impl(x, T, seed, step0, *, kid, n_steps, variant, blk,
                           use_pallas, interpret):
    if use_pallas:
        chains = x.shape[0]
        eff_blk = min(blk, chains)
        return metropolis_sweep_pallas(
            x, T, seed, step0, kid=kid, n_steps=n_steps, blk=eff_blk,
            variant=variant, interpret=interpret)
    return ref_mod.metropolis_sweep_ref(
        x, T, seed, step0, kid=kid, n_steps=n_steps, variant=variant)


_metropolis_sweep = partial(jax.jit, static_argnames=(
    "n_steps", "variant", "blk", "use_pallas",
    "interpret"))(_metropolis_sweep_impl)
_metropolis_sweep_static = partial(jax.jit, static_argnames=(
    "kid", "n_steps", "variant", "blk", "use_pallas",
    "interpret"))(_metropolis_sweep_impl)


def metropolis_sweep_slots(x, kids, T_blocks, seeds, step0s, chain_base, *,
                           n_steps: int, blk: int,
                           variant: str = "delta", use_pallas: bool = False,
                           interpret: bool = False, live=None, T_chain=None):
    """Heterogeneous-slot Metropolis sweep: one serving slot per chain-block.

    ``x`` is ``(n_blocks * blk, dim)`` — the packed states of every active
    slot in a dispatch group — and each per-block control array has one entry
    per slot: its request's objective id (``kids``, runtime int32 — mixed
    objectives co-batch in one launch and never recompile), temperature, RNG
    seed, Metropolis step counter and global chain-index base.  On TPU this
    is a single Pallas launch with the SMEM arrays indexed by
    ``program_id``; elsewhere the per-block arrays expand to per-chain
    columns for the jnp oracle.  Both produce identical streams, so slot
    placement never changes a request's trajectory.

    ``live`` (optional, per-block bool/int32) is the macro-tick level
    cursor: a dead block passes its state through bit-exactly — used by
    the fused K-level engine path when co-batched requests have different
    remaining ladder depths.

    ``T_chain`` (optional, per-chain float32 ``(n_blocks*blk,)``) overrides
    the per-block temperature with one value per chain — the
    parallel-tempering layout where each chain holds a rung of its
    request's ladder.  A chain carrying its block's ladder value is
    bit-identical to the per-block path on both backends (the ref oracle
    is already per-chain; the Pallas kernel broadcasts either source into
    the same (blk, 1) accept test).

    Returns (x_out (n_blocks*blk, dim), f_out (n_blocks*blk,)).
    """
    from repro.kernels.metropolis_sweep import _validate_kid
    _validate_kid(kids)
    return _metropolis_sweep_slots(
        x, kids, T_blocks, seeds, step0s, chain_base, live=live,
        T_chain=T_chain, n_steps=n_steps,
        blk=blk, variant=variant, use_pallas=use_pallas, interpret=interpret)


@partial(jax.jit, static_argnames=("n_steps", "blk", "variant",
                                   "use_pallas", "interpret"))
def _metropolis_sweep_slots(x, kids, T_blocks, seeds, step0s, chain_base, *,
                            n_steps: int, blk: int,
                            variant: str = "delta",
                            use_pallas: bool = False,
                            interpret: bool = False, live=None,
                            T_chain=None):
    chains = x.shape[0]
    if chains % blk:
        raise ValueError(
            f"packed chains={chains} must be a multiple of blk={blk}")
    if use_pallas:
        from repro.kernels.metropolis_sweep import metropolis_sweep_pallas as mk
        return mk(x, T_blocks, seeds, step0s, kid=kids, n_steps=n_steps,
                  blk=blk, variant=variant, interpret=interpret,
                  chain_base=chain_base, live=live, t_chain=T_chain)
    n_blocks = chains // blk

    def expand(a):
        a = jnp.asarray(a).reshape(-1)
        if a.shape[0] == 1:  # scalar input: same broadcast as the Pallas path
            a = jnp.broadcast_to(a, (n_blocks,))
        return jnp.repeat(a, blk)

    lane = jnp.tile(jnp.arange(blk, dtype=jnp.uint32), n_blocks)
    cidx = expand(chain_base).astype(jnp.uint32) + lane
    live_c = None if live is None else expand(live)
    T_eff = expand(T_blocks) if T_chain is None else jnp.asarray(
        T_chain, x.dtype).reshape(-1)
    return ref_mod.metropolis_sweep_ref(
        x, T_eff, expand(seeds), expand(step0s),
        kid=expand(kids), n_steps=n_steps, variant=variant, cidx=cidx,
        live=live_c)


def qap_sweep_slots(x, F_blocks, D_blocks, T_blocks, seeds, step0s,
                    chain_base, *, n_steps: int, blk: int,
                    use_pallas: bool = False, interpret: bool = False,
                    live=None):
    """Heterogeneous-slot QAP pairwise-exchange sweep (permutation family).

    The ``metropolis_sweep_slots`` counterpart for int32 permutation
    states: ``x`` is ``(n_blocks * blk, n)`` packed slot states and
    ``F_blocks``/``D_blocks`` are the per-slot instance operands packed
    ``(n_blocks * n, n)`` — block ``b`` reads rows ``[b*n, (b+1)*n)`` — so
    mixed QAP instances co-batch in one launch and the compiled program
    never depends on which instances occupy the batch.  Per-block controls
    (``T_blocks``, ``seeds``, ``step0s``, ``chain_base``, optional
    ``live``) have the exact semantics of the continuous path; on TPU they
    land in SMEM, elsewhere they expand to per-chain columns for the jnp
    oracle.  Both paths run the shared step math on the same counter-based
    streams and the instance data is integer-valued (exact in float32), so
    they agree *bitwise* and slot placement never changes a trajectory.

    Returns (p_out (n_blocks*blk, n) int32, f_out (n_blocks*blk,) f32).
    """
    return _qap_sweep_slots(
        x, F_blocks, D_blocks, T_blocks, seeds, step0s, chain_base,
        live=live, n_steps=n_steps, blk=blk, use_pallas=use_pallas,
        interpret=interpret)


@partial(jax.jit, static_argnames=("n_steps", "blk", "use_pallas",
                                   "interpret"))
def _qap_sweep_slots(x, F_blocks, D_blocks, T_blocks, seeds, step0s,
                     chain_base, *, n_steps: int, blk: int,
                     use_pallas: bool = False, interpret: bool = False,
                     live=None):
    chains, n = x.shape
    if chains % blk:
        raise ValueError(
            f"packed chains={chains} must be a multiple of blk={blk}")
    if use_pallas:
        from repro.kernels.qap_sweep import qap_sweep_pallas
        return qap_sweep_pallas(
            x, F_blocks, D_blocks, T_blocks, seeds, step0s,
            n_steps=n_steps, blk=blk, interpret=interpret,
            chain_base=chain_base, live=live)
    n_blocks = chains // blk

    def expand(a):
        a = jnp.asarray(a).reshape(-1)
        if a.shape[0] == 1:  # scalar input: same broadcast as the Pallas path
            a = jnp.broadcast_to(a, (n_blocks,))
        return jnp.repeat(a, blk)

    def expand_mat(M):
        M = jnp.asarray(M, jnp.float32)
        if M.shape == (n, n):
            return M  # one instance for every chain: broadcast in the math
        return jnp.repeat(M.reshape(n_blocks, n, n), blk, axis=0)

    lane = jnp.tile(jnp.arange(blk, dtype=jnp.uint32), n_blocks)
    cidx = expand(chain_base).astype(jnp.uint32) + lane
    live_c = None if live is None else expand(live)
    return ref_mod.qap_sweep_ref(
        x, expand_mat(F_blocks), expand_mat(D_blocks), expand(T_blocks),
        expand(seeds), expand(step0s), n_steps=n_steps, cidx=cidx,
        live=live_c)


def kid_for(objective) -> Optional[int]:
    """Registry kernel id for an Objective, or None."""
    return getattr(objective, "kernel_id", None)
