"""Public jit'd wrappers around the Pallas kernels.

``use_pallas='auto'`` selects the Pallas path on TPU backends and the pure
XLA reference elsewhere (the CPU container cannot lower TPU custom calls;
tests exercise the kernels under ``interpret=True``).
"""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import objective_math as om
from repro.kernels import ref as ref_mod
from repro.kernels.metropolis_sweep import metropolis_sweep_pallas


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def resolve_use_pallas(use_pallas) -> bool:
    if use_pallas == "auto":
        return _on_tpu()
    return bool(use_pallas)


@partial(jax.jit, static_argnames=("kid", "n_steps", "variant", "blk",
                                   "use_pallas", "interpret"))
def metropolis_sweep(x, T, seed, step0, *, kid: int, n_steps: int,
                     variant: str = "delta", blk: int = 256,
                     use_pallas: bool = False, interpret: bool = False):
    """N-step Metropolis sweep over all chains (see metropolis_sweep.py).

    Returns (x_out (chains, dim), f_out (chains,)).
    """
    if use_pallas:
        chains = x.shape[0]
        eff_blk = min(blk, chains)
        return metropolis_sweep_pallas(
            x, T, seed, step0, kid=kid, n_steps=n_steps, blk=eff_blk,
            variant=variant, interpret=interpret)
    return ref_mod.metropolis_sweep_ref(
        x, T, seed, step0, kid=kid, n_steps=n_steps, variant=variant)


def kid_for(objective) -> Optional[int]:
    """Registry kernel id for an Objective, or None."""
    return getattr(objective, "kernel_id", None)
