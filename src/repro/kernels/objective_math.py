"""Kernel-side objective math for the registry objectives.

Shared by the Pallas kernel (``metropolis_sweep.py``) and the pure-jnp
oracle (``ref.py``) so both compute identical floating-point expressions.

Accumulator layout (uniform across objectives, unused slots stay zero):
  S    : (..., 2)  sum accumulators
  logP : (..., 1)  log-magnitude of the product accumulator
  sgnP : (..., 1)  sign (+-1) of the product accumulator

Two dispatch surfaces per primitive:

* static (``full_eval``, ``term``, ``init_acc``, ``combine``, ``BOX``) —
  ``kid`` is a Python int, one branch is traced.  Compile-time specialised;
  adding an objective recompiles every caller.
* runtime (``*_rt``, ``box_rt``) — ``kid`` is a traced int32 (a scalar read
  from SMEM in the kernel, a per-chain column in the oracle).  Every
  registry branch is evaluated and the right one is chosen with a
  branchless ``jnp.where`` chain, so one compiled program serves all
  registry objectives and growing the registry never costs a recompile.
  Each branch computes the *identical* floating-point expression as its
  static counterpart (select returns the branch value verbatim; garbage in
  unselected branches is discarded, never propagated).  Two callers using
  runtime dispatch are therefore bit-exact with each other — the serving
  engine's placement/preemption/migration invariants rest on this.  A
  runtime-dispatch program versus the *static* single-branch lowering is
  the same math in two different XLA programs: trajectories (states and
  accept/reject decisions) agree bitwise, but fusion may contract the
  delta-variant's cached accumulator differently at the last ULP, so that
  comparison is held to ULP tolerance in tests, not bitwise.
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp
from jax import lax

KID_SCHWEFEL = 0
KID_RASTRIGIN = 1
KID_ACKLEY = 2
KID_GRIEWANK = 3
KID_EXPONENTIAL = 4
KID_SALOMON = 5

KID_BY_NAME = {
    "schwefel": KID_SCHWEFEL,
    "rastrigin": KID_RASTRIGIN,
    "ackley": KID_ACKLEY,
    "griewank": KID_GRIEWANK,
    "exponential": KID_EXPONENTIAL,
    "salomon": KID_SALOMON,
}
# Uniform box per registry objective.
BOX = {
    KID_SCHWEFEL: (-512.0, 512.0),
    KID_RASTRIGIN: (-5.12, 5.12),
    KID_ACKLEY: (-30.0, 30.0),
    KID_GRIEWANK: (-600.0, 600.0),
    KID_EXPONENTIAL: (-1.0, 1.0),
    KID_SALOMON: (-100.0, 100.0),
}
N_KIDS = len(KID_BY_NAME)

_PI = np.float32(np.pi)
_E = np.float32(np.e)
_TINY = np.float32(1e-30)


def full_eval(kid: int, x, dim: int):
    """Full objective evaluation; x: (..., dim) -> (..., 1)."""
    if kid == KID_SCHWEFEL:
        f = -jnp.sum(x * jnp.sin(jnp.sqrt(jnp.abs(x))), -1, keepdims=True) / dim
    elif kid == KID_RASTRIGIN:
        f = 10.0 * dim + jnp.sum(x * x - 10.0 * jnp.cos(2 * _PI * x), -1, keepdims=True)
    elif kid == KID_ACKLEY:
        s1 = jnp.sum(x * x, -1, keepdims=True)
        s2 = jnp.sum(jnp.cos(2 * _PI * x), -1, keepdims=True)
        f = (-20.0 * jnp.exp(-0.2 * jnp.sqrt(s1 / dim))
             - jnp.exp(s2 / dim) + 20.0 + _E)
    elif kid == KID_GRIEWANK:
        # In-trace iota (not a jnp.arange constant): Pallas kernels reject
        # captured non-scalar constants, so the index vector must be an op.
        i = lax.broadcasted_iota(jnp.int32, x.shape, x.ndim - 1).astype(x.dtype)
        s = jnp.sum(x * x, -1, keepdims=True) / 4000.0
        p = jnp.prod(jnp.cos(x / jnp.sqrt(i + 1.0)), -1, keepdims=True)
        f = 1.0 + s - p
    elif kid == KID_EXPONENTIAL:
        f = -jnp.exp(-0.5 * jnp.sum(x * x, -1, keepdims=True))
    elif kid == KID_SALOMON:
        r = jnp.sqrt(jnp.sum(x * x, -1, keepdims=True))
        f = 1.0 - jnp.cos(2 * _PI * r) + 0.1 * r
    else:
        raise ValueError(f"unknown kernel objective id {kid}")
    return f.astype(x.dtype)


def term(kid: int, xi, d):
    """Per-coordinate contributions. xi, d: (..., 1). Returns (s (...,2), p (...,1))."""
    z = jnp.zeros_like(xi)
    if kid == KID_SCHWEFEL:
        return jnp.concatenate([xi * jnp.sin(jnp.sqrt(jnp.abs(xi))), z], -1), jnp.ones_like(xi)
    if kid == KID_RASTRIGIN:
        return jnp.concatenate([xi * xi - 10.0 * jnp.cos(2 * _PI * xi), z], -1), jnp.ones_like(xi)
    if kid == KID_ACKLEY:
        return jnp.concatenate([xi * xi, jnp.cos(2 * _PI * xi)], -1), jnp.ones_like(xi)
    if kid == KID_GRIEWANK:
        s = jnp.concatenate([xi * xi / 4000.0, z], -1)
        p = jnp.cos(xi / jnp.sqrt(d.astype(xi.dtype) + 1.0))
        return s, p
    if kid in (KID_EXPONENTIAL, KID_SALOMON):
        # Both reduce to the radial sum S0 = Σ x_i²; combine() does the rest.
        return jnp.concatenate([xi * xi, z], -1), jnp.ones_like(xi)
    raise ValueError(f"unknown kernel objective id {kid}")


def init_acc(kid: int, x):
    """Exact O(dim) accumulator init from the state block x: (..., dim)."""
    d = lax.broadcasted_iota(jnp.int32, x.shape, x.ndim - 1).astype(x.dtype)
    # term() over every coordinate: reshape to (..., dim, 1)
    s, p = term(kid, x[..., None], d[..., None])  # (..., dim, 2), (..., dim, 1)
    S = jnp.sum(s, axis=-2)
    logP = jnp.sum(jnp.log(jnp.maximum(jnp.abs(p), _TINY)), axis=-2)
    sgnP = jnp.prod(jnp.where(p < 0, -1.0, 1.0).astype(x.dtype), axis=-2)
    return S, logP, sgnP


def combine(kid: int, S, logP, sgnP, dim: int):
    """Accumulators -> objective value (..., 1)."""
    if kid == KID_SCHWEFEL:
        return -S[..., 0:1] / dim
    if kid == KID_RASTRIGIN:
        return 10.0 * dim + S[..., 0:1]
    if kid == KID_ACKLEY:
        return (-20.0 * jnp.exp(-0.2 * jnp.sqrt(S[..., 0:1] / dim))
                - jnp.exp(S[..., 1:2] / dim) + 20.0 + _E)
    if kid == KID_GRIEWANK:
        P = sgnP * jnp.exp(logP)
        return 1.0 + S[..., 0:1] - P
    if kid == KID_EXPONENTIAL:
        return -jnp.exp(-0.5 * S[..., 0:1])
    if kid == KID_SALOMON:
        r = jnp.sqrt(S[..., 0:1])
        return 1.0 - jnp.cos(2 * _PI * r) + 0.1 * r
    raise ValueError(f"unknown kernel objective id {kid}")


# --------------------------------------------------------------------------
# Runtime dispatch: kid is a traced int32, not a Python int.  Every branch
# below is the *static* implementation above, so a select at runtime yields
# the same bits as compiling the branch in.  Branchless by construction —
# no lax.switch — which keeps the Pallas TPU lowering trivial (the VPU has
# no divergence to worry about, only redundant lanes).
def box_rt(kid, dtype=jnp.float32):
    """Per-kid box bounds. kid: traced int (any shape). Returns (lo, hi)
    broadcast to kid's shape."""
    lo = jnp.full_like(kid, BOX[0][0], dtype=dtype)
    hi = jnp.full_like(kid, BOX[0][1], dtype=dtype)
    for k in range(1, N_KIDS):
        lo = jnp.where(kid == k, np.float32(BOX[k][0]), lo)
        hi = jnp.where(kid == k, np.float32(BOX[k][1]), hi)
    return lo, hi


def full_eval_rt(kid, x, dim: int):
    """Runtime-kid full_eval; kid broadcastable to (..., 1)."""
    f = full_eval(0, x, dim)
    for k in range(1, N_KIDS):
        f = jnp.where(kid == k, full_eval(k, x, dim), f)
    return f


def term_rt(kid, xi, d):
    """Runtime-kid term; kid broadcastable to (..., 1)."""
    s, p = term(0, xi, d)
    for k in range(1, N_KIDS):
        sk, pk = term(k, xi, d)
        s = jnp.where(kid == k, sk, s)
        p = jnp.where(kid == k, pk, p)
    return s, p


def init_acc_rt(kid, x):
    """Runtime-kid init_acc; kid broadcastable to (..., 1)."""
    S, logP, sgnP = init_acc(0, x)
    for k in range(1, N_KIDS):
        Sk, logPk, sgnPk = init_acc(k, x)
        S = jnp.where(kid == k, Sk, S)
        logP = jnp.where(kid == k, logPk, logP)
        sgnP = jnp.where(kid == k, sgnPk, sgnP)
    return S, logP, sgnP


def combine_rt(kid, S, logP, sgnP, dim: int):
    """Runtime-kid combine; kid broadcastable to (..., 1)."""
    f = combine(0, S, logP, sgnP, dim)
    for k in range(1, N_KIDS):
        f = jnp.where(kid == k, combine(k, S, logP, sgnP, dim), f)
    return f
