"""Pallas TPU kernel: fused Metropolis sweep (the paper's Listing 2/4 body).

One kernel invocation advances a block of ``blk`` chains by ``n_steps``
Metropolis iterations at fixed temperature, entirely in VMEM:

  HBM traffic   : one read of the (blk, dim) state block + one write, per
                  *sweep* (N steps) — the CUDA version's design goal
                  ("no global-memory round trips inside the chain") mapped
                  to the TPU memory hierarchy.
  RNG           : counter-based threefry2x32 on the VPU (see rng.py); the
                  TPU analogue of per-thread CURAND state.
  accept/reject : branchless masked selects — no divergence on TPU.

Variants
--------
``full``  : paper-faithful — every proposal evaluates the objective over all
            ``dim`` coordinates (O(dim) transcendentals per step).
``delta`` : beyond-paper — sum/product accumulators updated in O(1) per step
            (DESIGN.md §2); identical proposal/acceptance stream.

Multi-tenant serving (service/engine.py) drives *heterogeneous* chain-blocks
through one kernel launch: every SMEM control input (objective id,
temperature, RNG seed, step counter, global chain-index base) is a per-block
array indexed by ``program_id``, so each block — one serving *slot* —
anneals its own objective at its own temperature and draws from its own
request's random stream regardless of which slot it was packed into.
Scalar inputs broadcast to all blocks, which keeps the original single-job
call signature working unchanged.

Invariants
----------
* ``kid`` is a **runtime** input (per-block SMEM int32) whenever it is
  passed as an array or traced value — the serving engine's path: one
  compiled program serves every registry objective at a fixed
  ``(dim, n_steps, blk, variant)``, dispatching inside the kernel with
  branchless ``jnp.where`` chains (objective_math ``*_rt``).  Growing the
  objective registry therefore never triggers a recompile — the serving
  engine's compile-stability guarantee.  The runtime path evaluates all
  ``N_KIDS`` branches per proposal and selects one; a *concrete Python
  int* ``kid`` instead compiles the single branch (the pre-runtime
  specialization — batch/benchmark callers keep 1x objective math, at the
  old cost of one lowering per objective).
* Runtime dispatch is bit-exact versus the static-``kid`` lowering: each
  ``jnp.where`` branch is the identical floating-point expression, so the
  two paths interleave freely (tests compare them directly).
* One kernel invocation advances every chain by exactly ``n_steps``
  proposals at its block's (fixed) temperature — the serving engine's
  "one tick = one temperature level" contract bottoms out here.

Block shape: ``(blk, dim)``; ``blk`` is a multiple of 8 (sublanes), ``dim``
pads to the 128-lane VREG width. Chains are fully independent so the grid
over chain-blocks is embarrassingly parallel ("arbitrary dimension" in
Mosaic terms). A chain count that is not a multiple of ``blk`` is padded up
(and sliced back) rather than rejected; padded chains burn VPU lanes but
never perturb real chains' streams (counter-based RNG on the global index).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

from repro.kernels import objective_math as om
from repro.kernels import rng


def _accept_prob(f0, f1, T):
    return jnp.exp(jnp.clip(-(f1 - f0) / T, -80.0, 80.0))


def _step_draws(seed, cidx, step0, i):
    """Three uniforms for step i (paper Step 3): coord bits, value, accept."""
    return rng.draws3(seed, cidx, (step0 + i).astype(jnp.uint32))


def _sweep_kernel(*refs, kid_static, n_steps: int, blk: int,
                  variant: str, with_live: bool = False,
                  with_chain_t: bool = False):
    # Ref layout: 5-or-6 SMEM control refs, then the VMEM tensor refs.
    # ``live`` (macro-tick serving path) is the per-slot level cursor —
    # blocks whose request has exhausted its planned ladder levels for
    # this macro-tick pass their state through bit-exactly (acc forced
    # to False; the counter-based RNG is stateless so no draws are
    # consumed on their behalf).  ``with_chain_t`` (replica-exchange
    # serving path) swaps the per-block SMEM temperature for a (blk, 1)
    # VMEM column so every chain — a parallel-tempering rung — anneals at
    # its own temperature inside one block.
    n_smem = 6 if with_live else 5
    kid_ref, seed_ref, step0_ref, t_ref, base_ref = refs[:5]
    live_ref = refs[5] if with_live else None
    vrefs = refs[n_smem:]
    if with_chain_t:
        x_ref, tc_ref, xo_ref, fo_ref = vrefs
    else:
        x_ref, xo_ref, fo_ref = vrefs
        tc_ref = None
    dim = x_ref.shape[-1]

    pid = pl.program_id(0)
    if kid_static is not None:
        # Concrete objective: compile the single branch (pre-runtime-dispatch
        # behavior — batch callers keep 1x objective math per proposal).
        kid = kid_static
        lo, hi = om.BOX[kid]
        lo, hi = np.float32(lo), np.float32(hi)
        init_acc, combine, term, full_eval = (
            om.init_acc, om.combine, om.term, om.full_eval)
    else:
        kid = kid_ref[pid]      # runtime objective id: scalar per block
        lo, hi = om.box_rt(kid)
        init_acc, combine, term, full_eval = (
            om.init_acc_rt, om.combine_rt, om.term_rt, om.full_eval_rt)
    seed = seed_ref[pid]
    step0 = step0_ref[pid]
    # Per-chain (blk, 1) temperature column, or the block's SMEM scalar —
    # broadcasting against the (blk, 1) accept shapes either way.
    T = t_ref[pid] if tc_ref is None else tc_ref[...]
    base = base_ref[pid]
    live = None if live_ref is None else live_ref[pid] != 0
    cidx = base + lax.broadcasted_iota(jnp.int32, (blk, 1), 0).astype(jnp.uint32)
    coords = lax.broadcasted_iota(jnp.int32, (blk, dim), 1)

    x = x_ref[...]

    if variant == "delta":
        S, logP, sgnP = init_acc(kid, x)
        fx = combine(kid, S, logP, sgnP, dim)

        def body(i, carry):
            x, fx, S, logP, sgnP = carry
            rbits, uval, uacc = _step_draws(seed, cidx, step0, i)
            d = (rbits % np.uint32(dim)).astype(jnp.int32)  # (blk, 1)
            onehot = coords == d
            xi_old = jnp.sum(jnp.where(onehot, x, 0.0), axis=1, keepdims=True)
            newval = lo + uval * (hi - lo)
            df = d.astype(x.dtype)
            s_old, p_old = term(kid, xi_old, df)
            s_new, p_new = term(kid, newval, df)
            S1 = S - s_old + s_new
            logP1 = (logP
                     - jnp.log(jnp.maximum(jnp.abs(p_old), 1e-30))
                     + jnp.log(jnp.maximum(jnp.abs(p_new), 1e-30)))
            sg = jnp.where(p_old < 0, -1.0, 1.0) * jnp.where(p_new < 0, -1.0, 1.0)
            sgnP1 = sgnP * sg.astype(sgnP.dtype)
            f1 = combine(kid, S1, logP1, sgnP1, dim)
            acc = uacc <= _accept_prob(fx, f1, T)  # (blk, 1)
            if live is not None:
                acc = acc & live
            x = jnp.where(onehot & acc, newval, x)
            fx = jnp.where(acc, f1, fx)
            S = jnp.where(acc, S1, S)
            logP = jnp.where(acc, logP1, logP)
            sgnP = jnp.where(acc, sgnP1, sgnP)
            return x, fx, S, logP, sgnP

        x, fx, *_ = lax.fori_loop(0, n_steps, body, (x, fx, S, logP, sgnP))
    else:  # full: paper-faithful O(dim) evaluation per step
        fx = full_eval(kid, x, dim)

        def body(i, carry):
            x, fx = carry
            rbits, uval, uacc = _step_draws(seed, cidx, step0, i)
            d = (rbits % np.uint32(dim)).astype(jnp.int32)
            onehot = coords == d
            newval = lo + uval * (hi - lo)
            x1 = jnp.where(onehot, newval, x)
            f1 = full_eval(kid, x1, dim)
            acc = uacc <= _accept_prob(fx, f1, T)
            if live is not None:
                acc = acc & live
            x = jnp.where(acc, x1, x)
            fx = jnp.where(acc, f1, fx)
            return x, fx

        x, fx = lax.fori_loop(0, n_steps, body, (x, fx))

    xo_ref[...] = x
    fo_ref[...] = fx


def _per_block(v, n_blocks: int, dtype, name: str):
    """Broadcast a scalar — or validate a (n_blocks,) array — of SMEM input."""
    a = jnp.asarray(v, dtype).reshape(-1)
    if a.shape[0] == 1:
        return jnp.broadcast_to(a, (n_blocks,))
    if a.shape[0] != n_blocks:
        raise ValueError(
            f"{name} has {a.shape[0]} entries for a {n_blocks}-block grid; "
            f"pass a scalar or one entry per chain-block")
    return a


def _validate_kid(kid) -> None:
    """Reject out-of-range objective ids while they are still concrete.

    Runtime dispatch would otherwise fall through the ``jnp.where`` chains
    to kid 0 and silently anneal Schwefel.  Traced values can't be checked
    here — inside jit the serving engine's ids are already validated by
    SARequest, which is the only path that reaches this under a tracer.
    """
    if isinstance(kid, jax.core.Tracer):
        return
    arr = np.asarray(kid)
    if arr.size and bool(((arr < 0) | (arr >= om.N_KIDS)).any()):
        raise ValueError(
            f"objective id(s) {arr.tolist()} outside the kernel registry "
            f"[0, {om.N_KIDS})")


def metropolis_sweep_pallas(x, T, seed, step0, *, kid, n_steps: int,
                            blk: int = 256, variant: str = "delta",
                            interpret: bool = False, chain_base=None,
                            live=None, t_chain=None):
    """Run an N-step Metropolis sweep for all chains.

    Args:
      x: (chains, dim) float32 chain states.
      T: temperature — scalar, or (chains//blk,) array for per-block
         (per-serving-slot) temperatures.
      seed, step0: RNG stream coordinates; scalar or per-block arrays, so
         co-scheduled requests keep independent, placement-invariant streams.
      kid: registry objective id (objective_math.KID_*) — a **runtime**
         input: scalar, or (chains//blk,) int32 array for per-block
         (per-serving-slot) objectives.  Not baked into the compiled
         program; one lowering serves every registry objective.
      n_steps: Metropolis steps (paper's N).
      blk: chains per kernel block (multiple of 8).
      variant: 'delta' (O(1) updates) or 'full' (paper-faithful).
      chain_base: optional per-block global chain-index base (uint32,
         (chains//blk,)); defaults to ``block * blk`` (the single-job
         layout). The RNG stream of chain c in block b is indexed by
         ``chain_base[b] + c``, which is what makes a request's streams
         identical no matter which slots the scheduler packed it into.
      live: optional per-block level cursor (bool/int32, (chains//blk,)).
         A dead block (``live == 0``) passes its state through bit-exactly
         — every accept is masked off, so ``x`` is unchanged and no random
         stream advances (counter-based RNG draws are stateless).  The
         macro-tick engine uses this so co-batched requests with different
         remaining ladder depths fuse into one K-level dispatch.
      t_chain: optional per-chain temperatures (float32, (chains,) or
         (chains, 1)).  When given, each chain anneals at its own
         temperature (parallel-tempering rungs) and the per-block ``T`` is
         ignored; a block whose rows all carry the block temperature is
         bit-identical to the SMEM-scalar path (same broadcasting into the
         accept test).

    Returns (x_out, f_out): (chains, dim) and (chains,).
    """
    chains, dim = x.shape
    _validate_kid(kid)
    pad = (-chains) % blk
    if pad:
        if chain_base is not None or live is not None \
                or t_chain is not None or any(
                jnp.ndim(v) and jnp.size(v) > 1 for v in (T, seed, step0, kid)):
            raise ValueError(
                f"chains={chains} must be a multiple of blk={blk} when "
                "per-block control arrays are given")
        # Pad with dummy chains at the origin — inside every registry box
        # (a static om.BOX[kid] lookup is no longer possible: kid may be
        # traced).  Their streams use indices >= chains so real chains are
        # untouched. Sliced off below.
        x = jnp.concatenate(
            [x, jnp.zeros((pad, dim), x.dtype)], axis=0)
    n_chains_p = chains + pad
    grid = (n_chains_p // blk,)
    n_blocks = grid[0]

    # Concrete scalar kid -> compile the single objective branch; array or
    # traced kid -> runtime SMEM dispatch (one lowering for all objectives).
    kid_static = int(kid) if isinstance(kid, (int, np.integer)) else None
    with_live = live is not None
    with_chain_t = t_chain is not None
    kernel = functools.partial(
        _sweep_kernel, kid_static=kid_static, n_steps=n_steps, blk=blk,
        variant=variant, with_live=with_live, with_chain_t=with_chain_t)

    kid_arr = _per_block(kid, n_blocks, jnp.int32, "kid")
    seed_arr = _per_block(seed, n_blocks, jnp.uint32, "seed")
    step0_arr = _per_block(step0, n_blocks, jnp.uint32, "step0")
    t_arr = _per_block(T, n_blocks, jnp.float32, "T")
    if chain_base is None:
        base_arr = (jnp.arange(n_blocks, dtype=jnp.uint32)
                    * np.uint32(blk))
    else:
        base_arr = _per_block(chain_base, n_blocks, jnp.uint32, "chain_base")

    inputs = [kid_arr, seed_arr, step0_arr, t_arr, base_arr]
    n_smem = 5
    if with_live:
        inputs.append(_per_block(live, n_blocks, jnp.int32, "live"))
        n_smem = 6
    inputs.append(x)
    in_specs = ([pl.BlockSpec(memory_space=pltpu.SMEM)] * n_smem
                + [pl.BlockSpec((blk, dim), lambda i: (i, 0))])
    if with_chain_t:
        tc = jnp.asarray(t_chain, jnp.float32).reshape(-1, 1)
        if tc.shape[0] != chains:
            raise ValueError(
                f"t_chain has {tc.shape[0]} entries for {chains} chains")
        inputs.append(tc)
        in_specs.append(pl.BlockSpec((blk, 1), lambda i: (i, 0)))

    name = (f"metropolis_sweep_{variant}" if kid_static is None
            else f"metropolis_sweep_{variant}_k{kid_static}")
    x_out, f_out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=[
            pl.BlockSpec((blk, dim), lambda i: (i, 0)),
            pl.BlockSpec((blk, 1), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n_chains_p, dim), x.dtype),
            jax.ShapeDtypeStruct((n_chains_p, 1), x.dtype),
        ],
        interpret=interpret,
        name=name + ("_lv" if with_live else "") +
             ("_ct" if with_chain_t else ""),
    )(*inputs)
    return x_out[:chains], f_out[:chains, 0]
