"""Pallas TPU kernel: fused pairwise-exchange QAP sweep (permutation family).

The combinatorial counterpart of metropolis_sweep.py, after Paul's GPU SA
for the QAP (arXiv 1208.2675): one kernel invocation advances a block of
``blk`` chains — each an ``int32`` permutation ``p`` of ``n`` locations —
by ``n_steps`` pairwise-exchange Metropolis moves at fixed temperature,
entirely in VMEM.  Each proposal swaps the locations of two facilities
``i, j`` and evaluates the cost change in **O(n)** (the delta trick), not
O(n^2); the accept test, RNG and per-block SMEM control layout are shared
with the continuous kernel:

  RNG           : the same counter-based threefry2x32 draws3 stream,
                  indexed by (request seed, global chain index, step) — so
                  QAP trajectories are placement/preemption/migration
                  invariant exactly like continuous ones.
  controls      : per-block SMEM arrays (T, seed, step0, chain_base, live)
                  indexed by ``program_id`` — heterogeneous serving slots
                  in one launch, ``live`` masking dead macro-tick blocks.
  constants     : per-request flow/distance matrices enter as *per-block
                  VMEM operands* — packed ``(n_blocks * n, n)`` so each
                  block reads its own instance — which keeps the compiled
                  program independent of which QAP instances occupy the
                  batch: one lowering per ``(n, n_steps, blk)``.

Exactness contract
------------------
Registered instances carry small-integer matrices, so every product and
partial sum below is an integer far below 2**24: float32 arithmetic on
them is *exact* and order-independent.  The delta-carried ``fx`` therefore
equals a from-scratch ``qap_full_cost`` **bitwise**, and the pure-jnp
oracle (`ref.qap_sweep_ref`, built on the same shared step math) matches
the Pallas lowering bitwise — the property the serving engine's
bit-exactness oracle stands on (tests/test_qap.py).

Gathers are expressed as one-hot matmuls (sums of a single non-zero term
— exact regardless of order), the Mosaic-friendly formulation; ``n`` is
tiny (<= a few dozen), so the (blk, n, n) one-hots live comfortably in
VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

from repro.kernels import rng
from repro.kernels.metropolis_sweep import _per_block


def qap_full_cost(p, F, D):
    """Full QAP cost ``sum_{u,v} F[u,v] * D[p[u],p[v]]`` per chain.

    Args:
      p: (B, n) int32 permutations.
      F, D: (n, n) — or (B, n, n) per-chain — float32 integer-valued
        matrices (broadcasting batched matmuls either way).

    Returns (B, 1) float32 costs — exact for integer data below 2**24.
    """
    n = p.shape[-1]
    locs = jnp.arange(n, dtype=p.dtype)
    P = (p[..., None] == locs).astype(jnp.float32)        # (B, n, n) one-hot
    DP = (P @ D) @ jnp.swapaxes(P, -1, -2)                # D[p[u], p[v]]
    return jnp.sum(F * DP, axis=(-2, -1))[..., None]


def qap_swap_sweep(p, fx, F, D, T, seed, cidx, step0, *, n_steps: int,
                   live=None):
    """``n_steps`` pairwise-exchange Metropolis moves, delta-evaluated.

    The *shared* step recurrence: both the Pallas kernel (per block,
    (n, n) operands, SMEM scalars) and the pure-jnp oracle (whole batch,
    per-chain columns/operands) call exactly this function, so the two
    paths agree bitwise by construction for integer-valued data.

    Per step, from one ``rng.draws3`` triple: facility ``i`` from the raw
    bits (mod n), facility ``j`` from the value uniform (floor(u * n)),
    and the accept uniform.  ``i == j`` proposes the identity (delta is
    exactly 0.0, always accepted, state unchanged).  The delta for
    swapping the locations ``a = p[i]``, ``b = p[j]`` is the general
    (asymmetric-F/D) O(n) form:

      sum_{k != i,j} (F[i,k]-F[j,k]) (D[b,p[k]]-D[a,p[k]])
                   + (F[k,i]-F[k,j]) (D[p[k],b]-D[p[k],a])
      + (F[i,i]-F[j,j]) (D[b,b]-D[a,a]) + (F[i,j]-F[j,i]) (D[b,a]-D[a,b])

    Args:
      p: (B, n) int32 permutations; fx: (B, 1) float32 current costs.
      F, D: (n, n) or (B, n, n) float32 operands.
      T: temperature — scalar or (B, 1) column.
      seed / cidx / step0: RNG stream coordinates (uint32; scalar or
        (B, 1)), identical indexing to the continuous kernel.
      live: optional mask (scalar bool or (B, 1)); dead rows pass through
        bit-exactly (no accepted moves, no stream consumed — draws are
        stateless).

    Returns (p, fx) after ``n_steps`` moves.
    """
    n = p.shape[-1]
    locs = jnp.arange(n, dtype=p.dtype)

    def row(M, v):
        """One-hot row select: ``row(M, onehot(i))[k] = M[i, k]``."""
        return (v[:, None, :] @ M)[:, 0, :]

    def body(s, carry):
        p, fx = carry
        rbits, uval, uacc = rng.draws3(seed, cidx,
                                       (step0 + s).astype(jnp.uint32))
        i_fac = (rbits % jnp.uint32(n)).astype(p.dtype)          # (B, 1)
        j_fac = jnp.minimum((uval * n).astype(p.dtype), n - 1)   # (B, 1)
        ei = locs[None, :] == i_fac                              # (B, n)
        ej = locs[None, :] == j_fac
        eif = ei.astype(jnp.float32)
        ejf = ej.astype(jnp.float32)
        a = jnp.sum(jnp.where(ei, p, 0), axis=-1, keepdims=True)  # p[i]
        b = jnp.sum(jnp.where(ej, p, 0), axis=-1, keepdims=True)  # p[j]
        laf = (locs[None, :] == a).astype(jnp.float32)
        lbf = (locs[None, :] == b).astype(jnp.float32)

        FT = jnp.swapaxes(F, -1, -2)
        DT = jnp.swapaxes(D, -1, -2)
        Fi, Fj = row(F, eif), row(F, ejf)          # F[i,:], F[j,:]
        FiT, FjT = row(FT, eif), row(FT, ejf)      # F[:,i], F[:,j]
        Da, Db = row(D, laf), row(D, lbf)          # D[a,:], D[b,:]
        DaT, DbT = row(DT, laf), row(DT, lbf)      # D[:,a], D[:,b]

        # Gathers at p[k] via the permutation one-hot (exact sums of one
        # non-zero term): g(R)[k] = R[p[k]].
        P = (p[..., None] == locs).astype(jnp.float32)    # (B, n, n)

        def g(R):
            return (P @ R[..., None])[..., 0]

        kmask = (1.0 - eif) * (1.0 - ejf)                 # k not in {i, j}
        t1 = jnp.sum((Fi - Fj) * (g(Db) - g(Da)) * kmask,
                     axis=-1, keepdims=True)
        t2 = jnp.sum((FiT - FjT) * (g(DbT) - g(DaT)) * kmask,
                     axis=-1, keepdims=True)

        def pick(R, v):
            return jnp.sum(R * v, axis=-1, keepdims=True)

        diag = (pick(Fi, eif) - pick(Fj, ejf)) \
            * (pick(Db, lbf) - pick(Da, laf))
        cross = (pick(Fi, ejf) - pick(Fj, eif)) \
            * (pick(Db, laf) - pick(Da, lbf))
        delta = t1 + t2 + diag + cross

        acc = uacc <= jnp.exp(jnp.clip(-delta / T, -80.0, 80.0))
        if live is not None:
            acc = acc & live
        p_new = jnp.where(ei, b, jnp.where(ej, a, p))
        p = jnp.where(acc, p_new, p)
        fx = jnp.where(acc, fx + delta, fx)
        return p, fx

    return lax.fori_loop(0, n_steps, body, (p, fx))


def _qap_kernel(T_ref, seed_ref, step0_ref, base_ref, live_ref,
                p_ref, F_ref, D_ref, po_ref, fo_ref, *, n_steps: int,
                blk: int):
    """One grid step: sweep one (blk, n) block on its own instance."""
    pid = pl.program_id(0)
    n = p_ref.shape[-1]
    T = T_ref[pid]
    seed = seed_ref[pid]
    step0 = step0_ref[pid]
    live = live_ref[pid] != 0
    cidx = (base_ref[pid]
            + lax.broadcasted_iota(jnp.int32, (blk, 1), 0).astype(jnp.uint32))
    p = p_ref[...]
    F = F_ref[...]
    D = D_ref[...]
    # Initial cost from scratch — exact (integer-valued f32), so the carry
    # that leaves this kernel bitwise equals a host full evaluation.
    fx = qap_full_cost(p, F, D)
    del n
    p, fx = qap_swap_sweep(p, fx, F, D, T, seed, cidx, step0,
                           n_steps=n_steps, live=live)
    po_ref[...] = p
    fo_ref[...] = fx


def qap_sweep_pallas(p, F_blocks, D_blocks, T, seed, step0, *,
                     n_steps: int, blk: int = 256, interpret: bool = False,
                     chain_base=None, live=None):
    """Run an N-step QAP swap sweep for all chains.

    Args:
      p: (chains, n) int32 permutation states; ``chains`` must be a
        multiple of ``blk`` (the serving engine always packs whole slots).
      F_blocks, D_blocks: per-block instance operands — ``(n, n)`` (one
        instance for every block) or packed ``(n_blocks * n, n)`` (block
        ``b`` reads rows ``[b*n, (b+1)*n)``); float32, integer-valued.
      T, seed, step0: per-block SMEM controls, scalar or (chains//blk,)
        — same semantics as metropolis_sweep_pallas.
      chain_base: optional per-block global chain-index base (uint32);
        defaults to ``block * blk``.
      live: optional per-block level cursor (bool/int32); dead blocks pass
        through bit-exactly (macro-tick fusion).

    Returns (p_out, f_out): (chains, n) int32 and (chains,) float32.
    """
    chains, n = p.shape
    if chains % blk:
        raise ValueError(
            f"chains={chains} must be a multiple of blk={blk} for the QAP "
            "sweep (the engine packs whole slots)")
    grid = (chains // blk,)
    n_blocks = grid[0]

    def pack(M, name):
        M = jnp.asarray(M, jnp.float32)
        if M.shape == (n, n):
            M = jnp.tile(M, (n_blocks, 1))
        if M.shape != (n_blocks * n, n):
            raise ValueError(
                f"{name} must be (n, n) or (n_blocks*n, n) = "
                f"({n_blocks * n}, {n}); got {M.shape}")
        return M

    Fb = pack(F_blocks, "F_blocks")
    Db = pack(D_blocks, "D_blocks")
    t_arr = _per_block(T, n_blocks, jnp.float32, "T")
    seed_arr = _per_block(seed, n_blocks, jnp.uint32, "seed")
    step0_arr = _per_block(step0, n_blocks, jnp.uint32, "step0")
    if chain_base is None:
        base_arr = (jnp.arange(n_blocks, dtype=jnp.uint32)
                    * jnp.uint32(blk))
    else:
        base_arr = _per_block(chain_base, n_blocks, jnp.uint32, "chain_base")
    live_arr = (_per_block(1, n_blocks, jnp.int32, "live") if live is None
                else _per_block(live, n_blocks, jnp.int32, "live"))

    kernel = functools.partial(_qap_kernel, n_steps=n_steps, blk=blk)
    p_out, f_out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=(
            [pl.BlockSpec(memory_space=pltpu.SMEM)] * 5
            + [pl.BlockSpec((blk, n), lambda i: (i, 0)),
               pl.BlockSpec((n, n), lambda i: (i, 0)),
               pl.BlockSpec((n, n), lambda i: (i, 0))]),
        out_specs=[
            pl.BlockSpec((blk, n), lambda i: (i, 0)),
            pl.BlockSpec((blk, 1), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((chains, n), p.dtype),
            jax.ShapeDtypeStruct((chains, 1), jnp.float32),
        ],
        interpret=interpret,
        name=f"qap_sweep_n{n}",
    )(t_arr, seed_arr, step0_arr, base_arr, live_arr, p, Fb, Db)
    return p_out, f_out[:, 0]
