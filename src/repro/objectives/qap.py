"""Quadratic Assignment Problem instances for the permutation family.

The QAP (Koopmans–Beckmann form) assigns ``n`` facilities to ``n``
locations, minimising

    cost(p) = sum_{i,j} F[i, j] * D[p[i], p[j]]

over permutations ``p`` (facility ``i`` at location ``p[i]``), with flow
matrix ``F`` and distance matrix ``D``.  Paul (arXiv 1208.2675) drives
exactly this objective with GPU simulated annealing using pairwise-exchange
moves and O(n) delta evaluation — the combinatorial counterpart of the
paper's continuous sweep, and the forcing function for this repo's
problem-family refactor.

Instances
---------
The container vendors no QAPLIB data files, so the registry ships two
QAPLIB-*style* instances whose data is generated from seeded NumPy
generators (fully reproducible from this file alone) and whose reference
optima are *verifiable*, not copied:

``syn10``  : n=10, dense asymmetric integer matrices.  ``best_known`` is
             the **proven** optimum, found by exhaustive enumeration of
             all 10! permutations (scripted, single pass, vectorised).
``grid12`` : n=12, Nugent-style — Manhattan distances on a 3x4 grid,
             symmetric random integer flows.  ``best_known`` is the best
             value from 200k-start pairwise-swap (2-opt) descent; ~1.6%
             of random starts terminate at it, so it is the global
             optimum with overwhelming confidence.

Every instance carries a witness permutation ``p_best`` achieving
``best_known``; tests recompute its cost so any silent data corruption
(or generator drift across NumPy versions) fails loudly.

Exactness note: all entries are small integers, so every product and
partial sum in the cost (and in the swap-move delta) is an integer well
below 2**24 — float32 arithmetic on these values is *exact*, which is
what lets the serving engine's delta-evaluated kernel stay bitwise equal
to a full re-evaluation (the bit-exactness oracle extends to QAP).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Tuple

import numpy as np


@dataclasses.dataclass(frozen=True)
class QAPInstance:
    """One registered QAP instance (matrices are read-only float32)."""

    name: str
    F: np.ndarray            #: (n, n) flow matrix, float32, integer-valued
    D: np.ndarray            #: (n, n) distance matrix, float32, integer-valued
    best_known: int          #: reference optimum (see module docstring)
    p_best: Tuple[int, ...]  #: witness permutation achieving best_known
    proven: bool             #: True when best_known is an exhaustive optimum
    source: str              #: one-line provenance of the data

    @property
    def n(self) -> int:
        return int(self.F.shape[0])

    def cost(self, p) -> np.ndarray:
        """Host-side full evaluation; ``p`` is (n,) or (chains, n) int."""
        p = np.asarray(p)
        F = self.F.astype(np.int64)
        D = self.D.astype(np.int64)
        if p.ndim == 1:
            return (F * D[np.ix_(p, p)]).sum()
        return (F[None] * D[p[:, :, None], p[:, None, :]]).sum(axis=(1, 2))


def _freeze(a: np.ndarray) -> np.ndarray:
    a = np.ascontiguousarray(a, np.float32)
    a.setflags(write=False)
    return a


def _grid_distance(rows: int, cols: int) -> np.ndarray:
    """Manhattan distances between cells of a rows x cols grid (the Nugent
    layout family; nug12 uses the same 3x4 construction)."""
    n = rows * cols
    r = np.arange(n) // cols
    c = np.arange(n) % cols
    return (np.abs(r[:, None] - r[None, :])
            + np.abs(c[:, None] - c[None, :]))


def _make_syn10() -> QAPInstance:
    g = np.random.default_rng(2675)      # arXiv 1208.2675
    F = g.integers(0, 10, (10, 10))
    D = g.integers(0, 10, (10, 10))
    np.fill_diagonal(F, 0)
    np.fill_diagonal(D, 0)
    return QAPInstance(
        name="syn10", F=_freeze(F), D=_freeze(D),
        best_known=1024, p_best=(1, 2, 0, 3, 5, 9, 6, 7, 8, 4),
        proven=True,
        source="seeded synthetic (default_rng(2675)); optimum proven by "
               "exhaustive enumeration of all 10! assignments")


def _make_grid12() -> QAPInstance:
    D = _grid_distance(3, 4)
    g = np.random.default_rng(1208)      # arXiv 1208.2675
    F = np.triu(g.integers(0, 11, (12, 12)), 1)
    F = F + F.T
    return QAPInstance(
        name="grid12", F=_freeze(F), D=_freeze(D),
        best_known=1278, p_best=(6, 0, 2, 9, 7, 3, 11, 10, 8, 5, 1, 4),
        proven=False,
        source="Nugent-style synthetic: Manhattan 3x4 grid distances, "
               "seeded symmetric flows (default_rng(1208)); best known "
               "from 200k-start 2-opt descent (~1.6% of starts reach it)")


#: Registered instances, by name — the permutation family's servable set.
INSTANCES: Dict[str, QAPInstance] = {
    inst.name: inst for inst in (_make_syn10(), _make_grid12())
}

#: Stable small integer id per instance (registry order), the permutation
#: family's analogue of a continuous ``kid``.
INSTANCE_ID = {name: i for i, name in enumerate(sorted(INSTANCES))}


def get(name: str) -> QAPInstance:
    if name not in INSTANCES:
        raise ValueError(
            f"unknown QAP instance {name!r}; registered: "
            f"{sorted(INSTANCES)}")
    return INSTANCES[name]
