"""The paper's benchmark suite: 19 function families, 41 problem instances.

Each factory returns an :class:`Objective`.  Where the function admits the
sum/product decomposition of :class:`DecomposableSpec` we attach it so the
Metropolis sweep can delta-evaluate single-coordinate moves in O(1)
(DESIGN.md §2 — beyond-paper optimization; full evaluation remains the
paper-faithful baseline).

Notes
-----
* Cosine mixture: the paper prints ``-0.1 Σcos(5πx) - Σx²`` but the quoted
  minima (-0.2 at n=2, -0.4 at n=4, at the origin) correspond to the standard
  form ``-0.1 Σcos(5πx) + Σx²``; we implement the standard form.
* Modified Langerman / Shekel Foxholes use the 1st-ICEO dataset (Bersini et
  al. 1996); the paper's PDF table is garbled, but the quoted optima match
  this dataset (e.g. Foxholes n=5 optimum at row 3 with c₃ = 0.100).
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from .base import DecomposableSpec, Objective, box

_E = float(np.e)
_PI = float(np.pi)


def _no_prod(x):
    return jnp.zeros(x.shape + (0,), x.dtype)


def _no_sum(x):
    return jnp.zeros(x.shape + (0,), x.dtype)


# ---------------------------------------------------------------- F0 Schwefel
def schwefel(n: int) -> Objective:
    """Normalized Schwefel: f(x) = -(1/n) Σ x_i sin(√|x_i|), x ∈ [-512,512]^n."""

    def fn(x):
        return -jnp.mean(x * jnp.sin(jnp.sqrt(jnp.abs(x))), axis=-1)

    spec = DecomposableSpec(
        n_sum=1,
        n_prod=0,
        terms=lambda x, i: (
            (x * jnp.sin(jnp.sqrt(jnp.abs(x))))[..., None],
            _no_prod(x),
        ),
        combine=lambda S, P, n: -S[..., 0] / n,
    )
    lo, hi = box(-512.0, 512.0, n)
    return Objective(
        name=f"schwefel_{n}", dim=n, lower=lo, upper=hi, fn=fn,
        f_opt=-418.982887 / 1.0, x_opt=np.full((n,), 420.968746),
        decomposable=spec, kernel_id=0,
    )


# ----------------------------------------------------------------- F1 Ackley
def ackley(n: int) -> Objective:
    def fn(x):
        s1 = jnp.mean(x * x, axis=-1)
        s2 = jnp.mean(jnp.cos(2 * _PI * x), axis=-1)
        return -20.0 * jnp.exp(-0.2 * jnp.sqrt(s1)) - jnp.exp(s2) + 20.0 + _E

    spec = DecomposableSpec(
        n_sum=2,
        n_prod=0,
        terms=lambda x, i: (
            jnp.stack([x * x, jnp.cos(2 * _PI * x)], axis=-1),
            _no_prod(x),
        ),
        combine=lambda S, P, n: (
            -20.0 * jnp.exp(-0.2 * jnp.sqrt(S[..., 0] / n))
            - jnp.exp(S[..., 1] / n) + 20.0 + _E
        ),
    )
    lo, hi = box(-30.0, 30.0, n)
    return Objective(
        name=f"ackley_{n}", dim=n, lower=lo, upper=hi, fn=fn,
        f_opt=0.0, x_opt=np.zeros((n,)), decomposable=spec, kernel_id=2,
    )


# ----------------------------------------------------------------- F2 Branin
def branin() -> Objective:
    def fn(x):
        x1, x2 = x[..., 0], x[..., 1]
        a = x2 - 5.1 / (4 * _PI ** 2) * x1 ** 2 + 5.0 / _PI * x1 - 6.0
        return a ** 2 + 10.0 * (1.0 - 1.0 / (8 * _PI)) * jnp.cos(x1) + 10.0

    lo, hi = box(-20.0, 20.0, 2)
    return Objective(
        name="branin", dim=2, lower=lo, upper=hi, fn=fn,
        f_opt=0.397887, x_opt=np.array([_PI, 2.275]),
    )


# --------------------------------------------------------- F3 Cosine mixture
def cosine_mixture(n: int) -> Objective:
    def fn(x):
        return -0.1 * jnp.sum(jnp.cos(5 * _PI * x), axis=-1) + jnp.sum(x * x, axis=-1)

    spec = DecomposableSpec(
        n_sum=2,
        n_prod=0,
        terms=lambda x, i: (
            jnp.stack([jnp.cos(5 * _PI * x), x * x], axis=-1),
            _no_prod(x),
        ),
        combine=lambda S, P, n: -0.1 * S[..., 0] + S[..., 1],
    )
    lo, hi = box(-1.0, 1.0, n)
    return Objective(
        name=f"cosine_{n}", dim=n, lower=lo, upper=hi, fn=fn,
        f_opt=-0.1 * n, x_opt=np.zeros((n,)), decomposable=spec,
    )


# ------------------------------------------------------ F4 Dekkers and Aarts
def dekkers_aarts() -> Objective:
    def fn(x):
        x1, x2 = x[..., 0], x[..., 1]
        r2 = x1 ** 2 + x2 ** 2
        return 1e5 * x1 ** 2 + x2 ** 2 - r2 ** 2 + 1e-5 * r2 ** 4

    lo, hi = box(-20.0, 20.0, 2)
    return Objective(
        name="dekkers_aarts", dim=2, lower=lo, upper=hi, fn=fn,
        f_opt=-24776.518, x_opt=np.array([0.0, 14.945]),
    )


# ------------------------------------------------------------------ F5 Easom
def easom() -> Objective:
    def fn(x):
        x1, x2 = x[..., 0], x[..., 1]
        return -jnp.cos(x1) * jnp.cos(x2) * jnp.exp(-((x1 - _PI) ** 2) - (x2 - _PI) ** 2)

    lo, hi = box(-10.0, 10.0, 2)
    return Objective(
        name="easom", dim=2, lower=lo, upper=hi, fn=fn,
        f_opt=-1.0, x_opt=np.array([_PI, _PI]),
    )


# ------------------------------------------------------------ F6 Exponential
def exponential(n: int = 4) -> Objective:
    def fn(x):
        return -jnp.exp(-0.5 * jnp.sum(x * x, axis=-1))

    spec = DecomposableSpec(
        n_sum=1,
        n_prod=0,
        terms=lambda x, i: ((x * x)[..., None], _no_prod(x)),
        combine=lambda S, P, n: -jnp.exp(-0.5 * S[..., 0]),
    )
    lo, hi = box(-1.0, 1.0, n)
    return Objective(
        name=f"exponential_{n}", dim=n, lower=lo, upper=hi, fn=fn,
        f_opt=-1.0, x_opt=np.zeros((n,)), decomposable=spec, kernel_id=4,
    )


# ---------------------------------------------------- F7 Goldstein and Price
def goldstein_price() -> Objective:
    def fn(x):
        x1, x2 = x[..., 0], x[..., 1]
        a = 1 + (x1 + x2 + 1) ** 2 * (
            19 - 14 * x1 + 3 * x1 ** 2 - 14 * x2 + 6 * x1 * x2 + 3 * x2 ** 2
        )
        b = 30 + (2 * x1 - 3 * x2) ** 2 * (
            18 - 32 * x1 + 12 * x1 ** 2 + 48 * x2 - 36 * x1 * x2 + 27 * x2 ** 2
        )
        return a * b

    lo, hi = box(-2.0, 2.0, 2)
    return Objective(
        name="goldstein_price", dim=2, lower=lo, upper=hi, fn=fn,
        f_opt=3.0, x_opt=np.array([0.0, -1.0]),
    )


# --------------------------------------------------------------- F8 Griewank
def griewank(n: int) -> Objective:
    def fn(x):
        i = jnp.arange(1, n + 1, dtype=x.dtype)
        s = jnp.sum(x * x / 4000.0, axis=-1)
        p = jnp.prod(jnp.cos(x / jnp.sqrt(i)), axis=-1)
        return 1.0 + s - p

    spec = DecomposableSpec(
        n_sum=1,
        n_prod=1,
        terms=lambda x, i: (
            (x * x / 4000.0)[..., None],
            (jnp.cos(x / jnp.sqrt(i.astype(x.dtype) + 1.0)))[..., None],
        ),
        combine=lambda S, P, n: 1.0 + S[..., 0] - P[..., 0],
    )
    lo, hi = box(-600.0, 600.0, n)
    return Objective(
        name=f"griewank_{n}", dim=n, lower=lo, upper=hi, fn=fn,
        f_opt=0.0, x_opt=np.zeros((n,)), decomposable=spec, kernel_id=3,
    )


# ------------------------------------------------------------- F9 Himmelblau
def himmelblau() -> Objective:
    def fn(x):
        x1, x2 = x[..., 0], x[..., 1]
        return (x1 ** 2 + x2 - 11.0) ** 2 + (x1 + x2 ** 2 - 7.0) ** 2

    lo, hi = box(-6.0, 6.0, 2)
    return Objective(
        name="himmelblau", dim=2, lower=lo, upper=hi, fn=fn,
        f_opt=0.0, x_opt=np.array([3.0, 2.0]),
    )


# ----------------------------------------------------- F10 Levy and Montalvo
def levy_montalvo(n: int) -> Objective:
    def fn(x):
        y = 1.0 + 0.25 * (x + 1.0)
        t1 = 10.0 * jnp.sin(_PI * y[..., 0]) ** 2
        mid = jnp.sum(
            (y[..., :-1] - 1.0) ** 2 * (1.0 + 10.0 * jnp.sin(_PI * y[..., 1:]) ** 2),
            axis=-1,
        )
        tn = (y[..., -1] - 1.0) ** 2
        return _PI / n * (t1 + mid + tn)

    lo, hi = box(-10.0, 10.0, n)
    return Objective(
        name=f"levy_montalvo_{n}", dim=n, lower=lo, upper=hi, fn=fn,
        f_opt=0.0, x_opt=np.full((n,), -1.0),
    )


# ----------------------------------------------------------- ICEO data table
_ICEO_A = np.array([
    [9.681, 0.667, 4.783, 9.095, 3.517, 9.325, 6.544, 0.211, 5.122, 2.020],
    [9.400, 2.041, 3.788, 7.931, 2.882, 2.672, 3.568, 1.284, 7.033, 7.374],
    [8.025, 9.152, 5.114, 7.621, 4.564, 4.711, 2.996, 6.126, 0.734, 4.982],
    [2.196, 0.415, 5.649, 6.979, 9.510, 9.166, 6.304, 6.054, 9.377, 1.426],
    [8.074, 8.777, 3.467, 1.863, 6.708, 6.349, 4.534, 0.276, 7.633, 1.567],
    [7.650, 5.658, 0.720, 2.764, 3.278, 5.283, 7.474, 6.274, 1.409, 8.208],
    [1.256, 3.605, 8.623, 6.905, 0.584, 8.133, 6.071, 6.888, 4.187, 5.448],
    [8.314, 2.261, 4.224, 1.781, 4.124, 0.932, 8.129, 8.658, 1.208, 5.762],
    [0.226, 8.858, 1.420, 0.945, 1.622, 4.698, 6.228, 9.096, 0.972, 7.637],
    [7.305, 2.228, 1.242, 5.928, 9.133, 1.826, 4.060, 5.204, 8.713, 8.247],
    [0.652, 7.027, 0.508, 4.876, 8.807, 4.632, 5.808, 6.937, 3.291, 7.016],
    [2.699, 3.516, 5.874, 4.119, 4.461, 7.496, 8.817, 0.690, 6.593, 9.789],
    [8.327, 3.897, 2.017, 9.570, 9.825, 1.150, 1.395, 3.885, 6.354, 0.109],
    [2.132, 7.006, 7.136, 2.641, 1.882, 5.943, 7.273, 7.691, 2.880, 0.564],
    [4.707, 5.579, 4.080, 0.581, 9.698, 8.542, 8.077, 8.515, 9.231, 4.670],
    [8.304, 7.559, 8.567, 0.322, 7.128, 8.392, 1.472, 8.524, 2.277, 7.826],
    [8.632, 4.409, 4.832, 5.768, 7.050, 6.715, 1.711, 4.323, 4.405, 4.591],
    [4.887, 9.112, 0.170, 8.967, 9.693, 9.867, 7.508, 7.770, 8.382, 6.740],
    [2.440, 6.686, 4.299, 1.007, 7.008, 1.427, 9.398, 8.480, 9.950, 1.675],
    [6.306, 8.583, 6.084, 1.138, 4.350, 3.134, 7.853, 6.061, 7.457, 2.258],
    [0.652, 2.343, 1.370, 0.821, 1.310, 1.063, 0.689, 8.819, 8.833, 9.070],
    [5.558, 1.272, 5.756, 9.857, 2.279, 2.764, 1.284, 1.677, 1.244, 1.234],
    [3.352, 7.549, 9.817, 9.437, 8.687, 4.167, 2.570, 6.540, 0.228, 0.027],
    [8.798, 0.880, 2.370, 0.168, 1.701, 3.680, 1.231, 2.390, 2.499, 0.064],
    [1.460, 8.057, 1.336, 7.217, 7.914, 3.615, 9.981, 9.198, 5.292, 1.224],
    [0.432, 8.645, 8.774, 0.249, 8.081, 7.461, 4.416, 0.652, 4.002, 4.644],
    [0.679, 2.800, 5.523, 3.049, 2.968, 7.225, 6.730, 4.199, 9.614, 9.229],
    [4.263, 1.074, 7.286, 5.599, 8.291, 5.200, 9.214, 8.272, 4.398, 4.506],
    [9.496, 4.830, 3.150, 8.270, 5.079, 1.231, 5.731, 9.494, 1.883, 9.732],
    [4.138, 2.562, 2.532, 9.661, 5.611, 5.500, 6.886, 2.341, 9.699, 6.500],
])
_ICEO_C = np.array([
    0.806, 0.517, 0.100, 0.908, 0.965, 0.669, 0.524, 0.902, 0.531, 0.876,
    0.462, 0.491, 0.463, 0.714, 0.352, 0.869, 0.813, 0.811, 0.828, 0.964,
    0.789, 0.360, 0.369, 0.992, 0.332, 0.817, 0.632, 0.883, 0.608, 0.326,
])


# ---------------------------------------------------- F11 Modified Langerman
def langerman(n: int) -> Objective:
    A = jnp.asarray(_ICEO_A[:5, :n])
    c = jnp.asarray(_ICEO_C[:5])

    def fn(x):
        d2 = jnp.sum((x[..., None, :] - A) ** 2, axis=-1)  # (..., 5)
        return -jnp.sum(c * jnp.exp(-d2 / _PI) * jnp.cos(_PI * d2), axis=-1)

    lo, hi = box(0.0, 10.0, n)
    x_opt = {2: np.array([9.6810707, 0.6666515]), 5: _ICEO_A[4, :5]}.get(n)
    f_opt = {2: -1.080938, 5: -0.964999}.get(n)
    return Objective(
        name=f"langerman_{n}", dim=n, lower=lo, upper=hi, fn=fn,
        f_opt=f_opt, x_opt=x_opt,
    )


# -------------------------------------------------------- F12 Michalewicz
def michalewicz(n: int, m: int = 10) -> Objective:
    def fn(x):
        i = jnp.arange(1, n + 1, dtype=x.dtype)
        return -jnp.sum(jnp.sin(x) * jnp.sin(i * x * x / _PI) ** (2 * m), axis=-1)

    spec = DecomposableSpec(
        n_sum=1,
        n_prod=0,
        terms=lambda x, i: (
            (jnp.sin(x) * jnp.sin((i.astype(x.dtype) + 1.0) * x * x / _PI) ** (2 * m))[..., None],
            _no_prod(x),
        ),
        combine=lambda S, P, n: -S[..., 0],
    )
    lo, hi = box(0.0, _PI, n)
    f_opt = {2: -1.8013, 5: -4.6877, 10: -9.6602}.get(n)
    return Objective(
        name=f"michalewicz_{n}", dim=n, lower=lo, upper=hi, fn=fn,
        f_opt=f_opt, x_opt=None, decomposable=spec,
    )


# -------------------------------------------------------------- F13 Rastrigin
def rastrigin(n: int) -> Objective:
    def fn(x):
        return 10.0 * n + jnp.sum(x * x - 10.0 * jnp.cos(2 * _PI * x), axis=-1)

    spec = DecomposableSpec(
        n_sum=1,
        n_prod=0,
        terms=lambda x, i: ((x * x - 10.0 * jnp.cos(2 * _PI * x))[..., None], _no_prod(x)),
        combine=lambda S, P, n: 10.0 * n + S[..., 0],
    )
    lo, hi = box(-5.12, 5.12, n)
    return Objective(
        name=f"rastrigin_{n}", dim=n, lower=lo, upper=hi, fn=fn,
        f_opt=0.0, x_opt=np.zeros((n,)), decomposable=spec, kernel_id=1,
    )


# ------------------------------------------------------------- F14 Rosenbrock
def rosenbrock(n: int = 4) -> Objective:
    def fn(x):
        return jnp.sum(
            100.0 * (x[..., 1:] - x[..., :-1] ** 2) ** 2 + (1.0 - x[..., :-1]) ** 2,
            axis=-1,
        )

    lo, hi = box(-2.048, 2.048, n)
    return Objective(
        name=f"rosenbrock_{n}", dim=n, lower=lo, upper=hi, fn=fn,
        f_opt=0.0, x_opt=np.ones((n,)),
    )


# ---------------------------------------------------------------- F15 Salomon
def salomon(n: int = 10) -> Objective:
    def fn(x):
        r = jnp.sqrt(jnp.sum(x * x, axis=-1))
        return 1.0 - jnp.cos(2 * _PI * r) + 0.1 * r

    spec = DecomposableSpec(
        n_sum=1,
        n_prod=0,
        terms=lambda x, i: ((x * x)[..., None], _no_prod(x)),
        combine=lambda S, P, n: (
            1.0 - jnp.cos(2 * _PI * jnp.sqrt(S[..., 0])) + 0.1 * jnp.sqrt(S[..., 0])
        ),
    )
    lo, hi = box(-100.0, 100.0, n)
    return Objective(
        name=f"salomon_{n}", dim=n, lower=lo, upper=hi, fn=fn,
        f_opt=0.0, x_opt=np.zeros((n,)), decomposable=spec, kernel_id=5,
    )


# ------------------------------------------------- F16 Six-Hump Camel Back
def six_hump_camel() -> Objective:
    def fn(x):
        x1, x2 = x[..., 0], x[..., 1]
        return (
            (4.0 - 2.1 * x1 ** 2 + x1 ** 4 / 3.0) * x1 ** 2
            + x1 * x2
            + (-4.0 + 4.0 * x2 ** 2) * x2 ** 2
        )

    lo = np.array([-3.0, -2.0])
    hi = np.array([3.0, 2.0])
    return Objective(
        name="six_hump_camel", dim=2, lower=lo, upper=hi, fn=fn,
        f_opt=-1.0316, x_opt=np.array([-0.0898, 0.7126]),
    )


# ---------------------------------------------------------------- F17 Shubert
def shubert(n: int = 2) -> Objective:
    def inner(xi):
        j = jnp.arange(1.0, 6.0, dtype=xi.dtype)
        return jnp.sum(j * jnp.cos((j + 1.0) * xi[..., None] + j), axis=-1)

    def fn(x):
        vals = inner(x)  # (..., n)
        return jnp.prod(vals, axis=-1)

    spec = DecomposableSpec(
        n_sum=0,
        n_prod=1,
        terms=lambda x, i: (_no_sum(x), inner(x)[..., None]),
        combine=lambda S, P, n: P[..., 0],
    )
    lo, hi = box(-10.0, 10.0, n)
    return Objective(
        name=f"shubert_{n}", dim=n, lower=lo, upper=hi, fn=fn,
        f_opt=-186.7309 if n == 2 else None,
        x_opt=np.array([-7.0835, 4.8580]) if n == 2 else None,
        decomposable=spec,
    )


# ----------------------------------------------------------------- F18 Shekel
_SHEKEL_A = np.array([
    [4.0, 4.0, 4.0, 4.0], [1.0, 1.0, 1.0, 1.0], [8.0, 8.0, 8.0, 8.0],
    [6.0, 6.0, 6.0, 6.0], [3.0, 7.0, 3.0, 7.0], [2.0, 9.0, 2.0, 9.0],
    [5.0, 5.0, 3.0, 3.0], [8.0, 1.0, 8.0, 1.0], [6.0, 2.0, 6.0, 2.0],
    [7.0, 3.6, 7.0, 3.6],
])
# NOTE: the paper's printed c-vector drops one 0.4 entry (9 values for
# m=10) — a typesetting error; the paper's own quoted optima
# (-10.1532/-10.4029/-10.5364) match the standard Shekel c below.
_SHEKEL_C = np.array([0.1, 0.2, 0.2, 0.4, 0.4, 0.6, 0.3, 0.7, 0.5, 0.5])


def shekel(m: int) -> Objective:
    A = jnp.asarray(_SHEKEL_A[:m])
    c = jnp.asarray(_SHEKEL_C[:m])

    def fn(x):
        d2 = jnp.sum((x[..., None, :] - A) ** 2, axis=-1)  # (..., m)
        return -jnp.sum(1.0 / (d2 + c), axis=-1)

    lo, hi = box(0.0, 10.0, 4)
    f_opt = {5: -10.1532, 7: -10.4029, 10: -10.5364}[m]
    return Objective(
        name=f"shekel_{m}", dim=4, lower=lo, upper=hi, fn=fn,
        f_opt=f_opt, x_opt=np.array([4.0, 4.0, 4.0, 4.0]),
    )


# ------------------------------------------- F19 Modified Shekel Foxholes
def shekel_foxholes(n: int) -> Objective:
    A = jnp.asarray(_ICEO_A[:, :n])
    c = jnp.asarray(_ICEO_C)

    def fn(x):
        d2 = jnp.sum((x[..., None, :] - A) ** 2, axis=-1)  # (..., 30)
        return -jnp.sum(1.0 / (d2 + c), axis=-1)

    lo, hi = box(-5.0, 15.0, n)
    x_opt = {2: np.array([8.024, 9.146]), 5: _ICEO_A[2, :5]}.get(n)
    f_opt = {2: -12.1190, 5: -10.4056}.get(n)
    return Objective(
        name=f"foxholes_{n}", dim=n, lower=lo, upper=hi, fn=fn,
        f_opt=f_opt, x_opt=x_opt,
    )
