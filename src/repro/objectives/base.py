"""Objective-function abstraction for the SA solver.

Every objective is a box-constrained function ``f: R^n -> R`` evaluated in a
batch-vectorized way: ``f(x)`` accepts ``x`` of shape ``(..., n)`` and returns
``(...)``.  Objectives optionally expose a *decomposable structure* that lets
the Metropolis sweep apply an O(1) delta-evaluation when a single coordinate
changes (the beyond-paper optimization described in DESIGN.md §2):

    f(x) = combine(S, P, n),   S_k = sum_i s_terms_k(x_i, i),
                               P_k = prod_i p_terms_k(x_i, i)

``terms(x_i, i) -> (s_vec, p_vec)`` returns the per-coordinate contributions.
The ``combine`` function maps the accumulator vectors back to the scalar f.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional, Sequence

import jax.numpy as jnp
import numpy as np

Array = jnp.ndarray


@dataclasses.dataclass(frozen=True, eq=False)
class DecomposableSpec:
    """Delta-evaluation structure: vector sum/product accumulators."""

    n_sum: int
    n_prod: int
    # terms(x_i, i) -> (s_vec[(..., n_sum)], p_vec[(..., n_prod)])
    terms: Callable[[Array, Array], tuple[Array, Array]]
    # combine(S[(..., n_sum)], P[(..., n_prod)], n) -> (...)
    combine: Callable[[Array, Array, int], Array]

    def init_acc(self, x: Array) -> tuple[Array, Array]:
        """Full O(n) accumulator computation (used at level refresh)."""
        n = x.shape[-1]
        idx = jnp.arange(n)
        s, p = self.terms(x, idx)  # broadcast over trailing coord axis
        # ``terms`` maps (..., n) coords -> (..., n, n_sum)/(..., n, n_prod)
        S = s.sum(axis=-2) if self.n_sum else jnp.zeros(x.shape[:-1] + (0,), x.dtype)
        if self.n_prod:
            # log-magnitude + sign representation for numerically stable O(1)
            # updates (|p| can underflow fp32 for n=512 products of cosines).
            logP = jnp.log(jnp.maximum(jnp.abs(p), 1e-30)).sum(axis=-2)
            sgnP = jnp.prod(jnp.sign(p), axis=-2)
        else:
            logP = jnp.zeros(x.shape[:-1] + (0,), x.dtype)
            sgnP = jnp.ones(x.shape[:-1] + (0,), x.dtype)
        return S, (logP, sgnP)

    def value(self, S: Array, logsgnP: tuple[Array, Array], n: int) -> Array:
        logP, sgnP = logsgnP
        P = sgnP * jnp.exp(logP)
        return self.combine(S, P, n)


@dataclasses.dataclass(frozen=True, eq=False)
class Objective:
    """A box-constrained minimization problem instance."""

    name: str
    dim: int
    lower: np.ndarray  # (dim,)
    upper: np.ndarray  # (dim,)
    fn: Callable[[Array], Array]  # (..., dim) -> (...)
    f_opt: Optional[float] = None  # known global minimum value
    x_opt: Optional[np.ndarray] = None  # one known minimizer (dim,)
    decomposable: Optional[DecomposableSpec] = None
    kernel_id: Optional[int] = None  # id in the Pallas kernel registry

    def __call__(self, x: Array) -> Array:
        return self.fn(x)

    @property
    def bounds(self) -> tuple[Array, Array]:
        return jnp.asarray(self.lower), jnp.asarray(self.upper)

    def sample_uniform(self, key, shape: Sequence[int]) -> Array:
        import jax

        lo, hi = self.bounds
        u = jax.random.uniform(key, tuple(shape) + (self.dim,))
        return lo + u * (hi - lo)

    def error_to_opt(self, x: Array, fx: Array) -> tuple[Array, Array]:
        """|f_a - f_r| and relative L2 location error (the paper's two metrics)."""
        df = jnp.abs(fx - self.f_opt) if self.f_opt is not None else jnp.nan
        if self.x_opt is not None:
            xo = jnp.asarray(self.x_opt)
            denom = jnp.maximum(jnp.linalg.norm(xo), 1e-12)
            dx = jnp.linalg.norm(x - xo, axis=-1) / denom
        else:
            dx = jnp.nan
        return df, dx


def box(lo: float, hi: float, n: int) -> tuple[np.ndarray, np.ndarray]:
    return np.full((n,), lo, np.float64), np.full((n,), hi, np.float64)
