"""Problem families: the representation layer of the serving stack.

A *family* owns everything about a problem class that the representation
determines — the chain-state dtype and per-chain shape, the deterministic
initial-state sampler, the known optimum lookup, and which sweep kernel the
engine dispatches — while the serving machinery above it (slots, scheduler,
engine tick loop, exchange operators, checkpoint/restore) stays family-
agnostic.  A request names its family (``SARequest.family``) and an
objective *within* that family; dispatch groups are keyed by
``(family, dim, N)``, so heterogeneous families co-batch in one fleet with
one compiled device program per family per shape.

Registered families
-------------------
``continuous``  : the six registry objectives (objective_math) — float32
                  states in a box, per-coordinate Metropolis moves, one
                  sweep program for the whole registry (runtime ``kid``).
``permutation`` : QAP instances (objectives/qap.py) — int32 permutation
                  states, pairwise-exchange Metropolis moves with O(n)
                  delta evaluation (kernels/qap_sweep.py), flow/distance
                  matrices threaded as per-request constant operands.

Both families ride the same placement-invariant counter-based RNG and the
same segmented exchange, so the engine's bit-exactness oracle
(``run_standalone`` / ``serve_sa --check``) holds for either —
across preemption, migration, drain, resize and macro-K fusion.
"""
from __future__ import annotations

from typing import Optional, Tuple

import numpy as np

from repro.kernels import objective_math as om
from repro.objectives import qap

FAMILY_CONTINUOUS = "continuous"
FAMILY_PERMUTATION = "permutation"

#: Known optima of the continuous registry objectives, by name (Schwefel is
#: the paper's normalized form, so its optimum is dim-free).  The engine's
#: kid-keyed ``F_OPT`` is derived from this — one source of truth.
F_OPT_BY_NAME = {
    "schwefel": -418.982887,
    "rastrigin": 0.0,
    "ackley": 0.0,
    "griewank": 0.0,
    "exponential": -1.0,
    "salomon": 0.0,
}


class ProblemFamily:
    """One problem representation: state layout + samplers + optima.

    Subclasses are stateless singletons; every method takes the request so
    a family never caches per-tenant data.  ``validate`` runs inside
    ``SARequest.__post_init__`` — family-incompatible fields fail eagerly
    with a typed ValueError at construction, never mid-tick.
    """

    #: family name — the ``SARequest.family`` value and dispatch-group key
    name: str = ""
    #: chain-state dtype of this family's slot blocks
    state_dtype: np.dtype = np.dtype(np.float32)

    def servable(self) -> Tuple[str, ...]:
        """Objective names servable under this family."""
        raise NotImplementedError

    def validate(self, req) -> None:
        """Family-specific request validation (typed ValueErrors)."""
        raise NotImplementedError

    def sample_x0(self, req, n_chains: int) -> np.ndarray:
        """Deterministic (n_chains, dim) initial states from ``req.seed``,
        independent of slot placement."""
        raise NotImplementedError

    def f_opt(self, req) -> Optional[float]:
        """Known optimum for ``req.objective`` (None if unregistered)."""
        raise NotImplementedError


class ContinuousFamily(ProblemFamily):
    """The paper's family: registry objectives over a float32 box."""

    name = FAMILY_CONTINUOUS
    state_dtype = np.dtype(np.float32)

    def servable(self) -> Tuple[str, ...]:
        return tuple(sorted(om.KID_BY_NAME))

    def validate(self, req) -> None:
        if req.objective not in om.KID_BY_NAME:
            raise ValueError(
                f"objective {req.objective!r} not servable; "
                f"one of {self.servable()}")

    def sample_x0(self, req, n_chains: int) -> np.ndarray:
        lo, hi = om.BOX[om.KID_BY_NAME[req.objective]]
        r = np.random.default_rng(req.seed)
        return (lo + r.random((n_chains, req.dim), dtype=np.float32)
                * (hi - lo)).astype(np.float32)

    def f_opt(self, req) -> Optional[float]:
        return F_OPT_BY_NAME.get(req.objective)


class PermutationFamily(ProblemFamily):
    """QAP: int32 permutation states, pairwise-exchange moves.

    Method restrictions are representational, not incidental: parallel
    tempering's rung layout and population annealing's Boltzmann-resample
    weights are defined on this stack only for the continuous sweep today,
    so ``method`` must be ``'sa'`` (all three ``exchange`` policies work —
    champion adoption copies permutations verbatim).
    """

    name = FAMILY_PERMUTATION
    state_dtype = np.dtype(np.int32)

    def servable(self) -> Tuple[str, ...]:
        return tuple(sorted(qap.INSTANCES))

    def validate(self, req) -> None:
        if req.objective not in qap.INSTANCES:
            raise ValueError(
                f"objective {req.objective!r} not servable by the "
                f"permutation family; one of {self.servable()}")
        inst = qap.INSTANCES[req.objective]
        if req.dim != inst.n:
            raise ValueError(
                f"request dim {req.dim} does not match QAP instance "
                f"{req.objective!r} size n={inst.n}")
        if req.pa_ess_ratio != 0.0:
            raise ValueError(
                "pa_ess_ratio is a population-annealing control and is "
                "invalid on a permutation-family request")
        if req.method != "sa":
            raise ValueError(
                f"method {req.method!r} is not supported by the "
                "permutation family (no temperature-rung replica layout "
                "or resampling weights for permutation states); use "
                "method='sa'")

    def sample_x0(self, req, n_chains: int) -> np.ndarray:
        # One generator, chains drawn in logical chain order — the
        # permutation analogue of the continuous box sampler, equally
        # placement-invariant.
        r = np.random.default_rng(req.seed)
        return np.stack(
            [r.permutation(req.dim) for _ in range(n_chains)]
        ).astype(np.int32)

    def f_opt(self, req) -> Optional[float]:
        return float(qap.INSTANCES[req.objective].best_known)


CONTINUOUS = ContinuousFamily()
PERMUTATION = PermutationFamily()

#: The family registry: ``SARequest.family`` values -> singleton.
FAMILIES = {f.name: f for f in (CONTINUOUS, PERMUTATION)}


def get_family(name: str) -> ProblemFamily:
    if name not in FAMILIES:
        raise ValueError(
            f"unknown problem family {name!r}; one of "
            f"{tuple(sorted(FAMILIES))}")
    return FAMILIES[name]
