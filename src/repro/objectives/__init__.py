"""Benchmark objective suite (paper Table 8: 41 problems, 19 families)."""
from __future__ import annotations

from .base import DecomposableSpec, Objective
from . import functions as F

__all__ = ["Objective", "DecomposableSpec", "get", "SUITE", "suite_objectives"]

# Paper Table 8 — reference id -> factory call.
SUITE = {
    "F0_a": lambda: F.schwefel(8),
    "F0_b": lambda: F.schwefel(16),
    "F0_c": lambda: F.schwefel(32),
    "F0_d": lambda: F.schwefel(64),
    "F0_e": lambda: F.schwefel(128),
    "F0_f": lambda: F.schwefel(256),
    "F0_g": lambda: F.schwefel(512),
    "F1_a": lambda: F.ackley(30),
    "F1_b": lambda: F.ackley(100),
    "F1_c": lambda: F.ackley(200),
    "F1_d": lambda: F.ackley(400),
    "F2": lambda: F.branin(),
    "F3_a": lambda: F.cosine_mixture(2),
    "F3_b": lambda: F.cosine_mixture(4),
    "F4": lambda: F.dekkers_aarts(),
    "F5": lambda: F.easom(),
    "F6": lambda: F.exponential(4),
    "F7": lambda: F.goldstein_price(),
    "F8_a": lambda: F.griewank(100),
    "F8_b": lambda: F.griewank(200),
    "F8_c": lambda: F.griewank(400),
    "F9": lambda: F.himmelblau(),
    "F10_a": lambda: F.levy_montalvo(2),
    "F10_b": lambda: F.levy_montalvo(5),
    "F10_c": lambda: F.levy_montalvo(10),
    "F11_a": lambda: F.langerman(2),
    "F11_b": lambda: F.langerman(5),
    "F12_a": lambda: F.michalewicz(2),
    "F12_b": lambda: F.michalewicz(5),
    "F12_c": lambda: F.michalewicz(10),
    "F13_a": lambda: F.rastrigin(100),
    "F13_b": lambda: F.rastrigin(400),
    "F14": lambda: F.rosenbrock(4),
    "F15": lambda: F.salomon(10),
    "F16": lambda: F.six_hump_camel(),
    "F17": lambda: F.shubert(2),
    "F18_a": lambda: F.shekel(5),
    "F18_b": lambda: F.shekel(7),
    "F18_c": lambda: F.shekel(10),
    "F19_a": lambda: F.shekel_foxholes(2),
    "F19_b": lambda: F.shekel_foxholes(5),
}


def get(ref: str) -> Objective:
    """Instantiate a suite problem by its paper reference (e.g. ``"F0_b"``)."""
    return SUITE[ref]()


def suite_objectives():
    for ref, factory in SUITE.items():
        yield ref, factory()
