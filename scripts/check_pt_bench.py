#!/usr/bin/env python
"""Gate the workload-class quality bench (BENCH_serve_pt.json).

The PT/PA tentpole's acceptance lives here: on the same seeded
Rastrigin-class stream, parallel tempering — and, for the committed
artifact, population annealing — must reach the target error in fewer
mean temperature levels than plain SA (the ``sa`` row: exchange='async',
no inter-chain communication).  CI runs this twice: against the
committed artifact (validates the committed claim, including PA) and
against a freshly generated reduced smoke (PT only — its margin is
~2.5x and robust to backend drift; PA's is real but thin enough that a
tiny-seed smoke would be noise-gated).

Checks:

1. rows exist for 'sa' and 'pt' (and 'pa' with --require-pa);
2. every gated cohort's hit_rate >= the sa baseline's (reaching the
   target less often can't be laundered into a levels win — misses only
   count at full-ladder length);
3. pt.mean_levels < sa.mean_levels * --max-ratio (default 1.0: strictly
   fewer levels);
4. with --require-pa: pa.mean_levels < sa.mean_levels * --max-ratio.

Exit 0 when every check passes, 1 otherwise (each failure is printed).

  python scripts/check_pt_bench.py artifacts/bench/BENCH_serve_pt.json \
      --require-pa
"""
from __future__ import annotations

import argparse
import json
import sys


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("artifact", help="BENCH_serve_pt.json to gate")
    ap.add_argument("--require-pa", action="store_true",
                    help="also require the pa cohort to beat plain sa")
    ap.add_argument("--max-ratio", type=float, default=1.0,
                    help="gated mean_levels must be < sa mean_levels x "
                         "this (1.0 = strictly fewer levels)")
    args = ap.parse_args(argv)

    with open(args.artifact) as fh:
        doc = json.load(fh)
    rows = {r["label"]: r for r in doc.get("rows", [])}

    failures = []
    needed = ["sa", "pt"] + (["pa"] if args.require_pa else [])
    for label in needed:
        if label not in rows:
            failures.append(f"missing cohort row {label!r}")
    if failures:
        for f in failures:
            print(f"FAIL: {f}")
        sys.exit(1)

    sa = rows["sa"]
    if sa["hit_rate"] <= 0.0:
        failures.append("sa baseline never reached the target — the "
                        "levels metric is vacuous; loosen --target")
    gated = ["pt"] + (["pa"] if args.require_pa else [])
    for label in gated:
        row = rows[label]
        if row["hit_rate"] < sa["hit_rate"]:
            failures.append(
                f"{label} hit_rate {row['hit_rate']:.2f} < sa baseline "
                f"{sa['hit_rate']:.2f}")
        bound = sa["mean_levels"] * args.max_ratio
        if not row["mean_levels"] < bound:
            failures.append(
                f"{label} mean_levels {row['mean_levels']:.1f} not < "
                f"{bound:.1f} (sa {sa['mean_levels']:.1f} x "
                f"{args.max_ratio})")
        else:
            print(f"OK: {label} mean_levels {row['mean_levels']:.1f} < "
                  f"sa {sa['mean_levels']:.1f} "
                  f"(hit {row['hit_rate']:.0%} vs {sa['hit_rate']:.0%})")

    if failures:
        for f in failures:
            print(f"FAIL: {f}")
        sys.exit(1)
    print(f"check_pt_bench: all gates passed for {args.artifact}")


if __name__ == "__main__":
    main()
