#!/usr/bin/env python
"""Gate the QAP quality bench (BENCH_serve_qap.json).

The combinatorial path's quality acceptance: on the built-in QAP
instances (objectives/qap.py — seeded, witness-verified analogues of the
small QAPLIB instances like nug12/tai12a, which cannot be vendored
verbatim), the serving engine's seeded cohorts must land within
``--max-gap`` percent of each instance's best_known cost.  CI runs this
twice: against the committed artifact (validates the committed claim)
and against a freshly generated reduced smoke.

Checks, per instance row:

1. the row exists (one per objectives/qap.py instance named in
   ``--instances``, default: every row in the artifact);
2. **integrity**: best_found >= best_known.  The instances ship witness
   permutations reproducing best_known (syn10's is exhaustively proven),
   so a cohort that "beats" it means broken kernel arithmetic or a stale
   best_known — either way the artifact is wrong, not impressive;
3. **quality**: gap_pct <= --max-gap (default 2.0: within 2% of
   best_known);
4. hit_rate is sane (in [0, 1]); with --require-hit, at least one seed
   must have reached best_known exactly.

Exit 0 when every check passes, 1 otherwise (each failure is printed).

  python scripts/check_qap_bench.py artifacts/bench/BENCH_serve_qap.json
"""
from __future__ import annotations

import argparse
import json
import sys


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("artifact", help="BENCH_serve_qap.json to gate")
    ap.add_argument("--max-gap", type=float, default=2.0,
                    help="max allowed gap_pct to best_known, percent")
    ap.add_argument("--instances", default=None,
                    help="comma-separated instance labels that must be "
                         "present (default: gate whatever rows exist)")
    ap.add_argument("--require-hit", action="store_true",
                    help="additionally require hit_rate > 0 (some seed "
                         "reached best_known exactly)")
    args = ap.parse_args(argv)

    with open(args.artifact) as fh:
        doc = json.load(fh)
    rows = {r["label"]: r for r in doc.get("rows", [])}

    failures = []
    needed = (args.instances.split(",") if args.instances
              else sorted(rows))
    if not needed:
        failures.append("artifact has no instance rows")
    for label in needed:
        if label not in rows:
            failures.append(f"missing instance row {label!r}")
            continue
        row = rows[label]
        if row["best_found"] < row["best_known"]:
            failures.append(
                f"{label}: best_found {row['best_found']:g} beats "
                f"best_known {row['best_known']:g} — kernel arithmetic "
                "or instance data is wrong")
            continue
        if not (0.0 <= row["hit_rate"] <= 1.0):
            failures.append(f"{label}: hit_rate {row['hit_rate']} "
                            "outside [0, 1]")
        if args.require_hit and row["hit_rate"] <= 0.0:
            failures.append(
                f"{label}: no seed reached best_known "
                f"(--require-hit; best_found {row['best_found']:g})")
        if row["gap_pct"] > args.max_gap:
            failures.append(
                f"{label}: gap {row['gap_pct']:.2f}% > --max-gap "
                f"{args.max_gap:g}% (best_found {row['best_found']:g} "
                f"vs best_known {row['best_known']:g})")
        else:
            print(f"OK: {label} best_found {row['best_found']:g} within "
                  f"{row['gap_pct']:.2f}% of best_known "
                  f"{row['best_known']:g} "
                  f"(hit {row['hit_rate']:.0%} of {row['seeds']} seeds)")

    if failures:
        for f in failures:
            print(f"FAIL: {f}")
        sys.exit(1)
    print(f"check_qap_bench: all gates passed for {args.artifact}")


if __name__ == "__main__":
    main()
