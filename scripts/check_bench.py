#!/usr/bin/env python
"""Consolidated declarative bench gate: one entry point for every
``BENCH_*.json`` quality/regression check.

Replaces the per-bench ``check_pt_bench.py`` / ``check_qap_bench.py`` /
``check_wall_regression.py`` scripts: each gate is a stanza in
``scripts/bench_gates.toml`` (artifact path, required rows, parameter
table, and a list of named assert expressions), so a new bench registers
as config instead of another bespoke script, and CI calls one gate step.

Gate stanza schema (see bench_gates.toml for the live set)::

  [gates.NAME]
  artifact = "artifacts/bench/BENCH_x.json"   # repo-relative
  label_key = "label"        # optional: build rows[label] from doc rows
  sort_key = "devices"       # optional: rowlist sorted by this (numeric)
  require_rows = ["sa"]      # optional: labels that must exist
  baseline = "path.json"     # optional: committed artifact to compare
                             # against (exposes bdoc/brows/blist)
  [gates.NAME.params]        # free-form numbers the asserts reference
  max_gap = 2.0
  [[gates.NAME.asserts]]     # evaluated in order; all must be truthy
  name = "gap within bound"
  expr = "all(r['gap_pct'] <= params['max_gap'] for r in rowlist)"

Assert expressions are Python, evaluated with no builtins except a safe
arithmetic/iteration subset, against: ``doc`` (the artifact), ``rowlist``
(its rows, sorted when ``sort_key`` is set), ``rows`` / ``row(label)``
(label-keyed, when ``label_key`` is set), ``params``, and — when
``baseline`` is set — ``bdoc`` / ``blist`` / ``brows``.

Provenance mode (``--provenance DIR``) validates that every committed
``BENCH_*.json`` carries the full reproducibility stamp: a non-dirty git
sha, jax version, device census (backend + device_count), and at least
one recorded seed — so stale or hand-edited benches can't merge.

Usage::

  python scripts/check_bench.py                      # run every gate
  python scripts/check_bench.py qap_committed wall   # run named gates
  python scripts/check_bench.py --provenance artifacts/bench
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

try:
    import tomllib                      # Python >= 3.11
except ImportError:                     # pragma: no cover
    import tomli as tomllib             # Python 3.10 fallback

REPO = Path(__file__).resolve().parent.parent
DEFAULT_CONFIG = Path(__file__).resolve().parent / "bench_gates.toml"

#: The only names assert expressions may call — enough for arithmetic,
#: comparison and iteration over rows; no imports, no attribute escape
#: hatches like getattr/eval.
SAFE_BUILTINS = {
    "abs": abs, "all": all, "any": any, "bool": bool, "enumerate":
    enumerate, "float": float, "int": int, "len": len, "max": max,
    "min": min, "round": round, "sorted": sorted, "str": str, "sum":
    sum, "zip": zip,
}


def _load(path: Path) -> dict:
    with open(path, encoding="utf-8") as fh:
        return json.load(fh)


def _row_env(doc: dict, gate: dict) -> dict:
    rowlist = list(doc.get("rows", []))
    if "sort_key" in gate:
        rowlist.sort(key=lambda r: r[gate["sort_key"]])
    env = {"rowlist": rowlist}
    if "label_key" in gate:
        env["rows"] = {r[gate["label_key"]]: r for r in rowlist}
    return env


def run_gate(name: str, gate: dict, repo: Path = REPO) -> list:
    """Run one gate stanza; returns a list of failure strings."""
    art = repo / gate["artifact"]
    if not art.exists():
        return [f"{name}: artifact {gate['artifact']} not found"]
    doc = _load(art)
    env = {"doc": doc, "params": dict(gate.get("params", {}))}
    env.update(_row_env(doc, gate))
    rows = env.get("rows", {})
    env["row"] = rows.get       # row('sa') -> the row dict, or None

    failures = []
    for label in gate.get("require_rows", []):
        if label not in rows:
            failures.append(f"{name}: missing required row {label!r} in "
                            f"{gate['artifact']}")
    if failures:
        return failures         # row asserts would only KeyError-cascade

    if "baseline" in gate:
        bpath = repo / gate["baseline"]
        if not bpath.exists():
            return [f"{name}: baseline {gate['baseline']} not found"]
        bdoc = _load(bpath)
        benv = _row_env(bdoc, gate)
        env["bdoc"] = bdoc
        env["blist"] = benv["rowlist"]
        env["brows"] = benv.get("rows", {})

    for check in gate.get("asserts", []):
        cname, expr = check["name"], check["expr"]
        try:
            # env goes in globals, not locals: generator expressions in
            # the asserts resolve free names against globals only.
            ok = eval(expr, {"__builtins__": SAFE_BUILTINS, **env})
        except Exception as exc:        # a broken expr is a failed gate
            failures.append(f"{name}/{cname}: raised {exc!r} "
                            f"(expr: {expr})")
            continue
        if ok:
            print(f"OK   {name}: {cname}")
        else:
            failures.append(f"{name}/{cname}: {expr}")
    return failures


#: Provenance keys every committed artifact must carry with non-null
#: values (git_sha additionally must not be -dirty; at least one key
#: containing 'seed' must be recorded on top of these).
_REQUIRED_PROVENANCE = ("git_sha", "jax_version", "backend",
                        "device_count")


def check_provenance(bench_dir: Path) -> list:
    """Validate the reproducibility stamp on every BENCH_*.json."""
    files = sorted(bench_dir.glob("BENCH_*.json"))
    if not files:
        return [f"no BENCH_*.json artifacts under {bench_dir}"]
    failures = []
    for path in files:
        rel = path.name
        try:
            prov = _load(path).get("provenance")
        except (OSError, json.JSONDecodeError) as exc:
            failures.append(f"{rel}: unreadable ({exc})")
            continue
        if not isinstance(prov, dict):
            failures.append(f"{rel}: no provenance stamp")
            continue
        for key in _REQUIRED_PROVENANCE:
            if prov.get(key) in (None, ""):
                failures.append(f"{rel}: provenance.{key} missing/null")
        sha = prov.get("git_sha")
        if isinstance(sha, str) and sha.endswith("-dirty"):
            failures.append(
                f"{rel}: dirty git sha {sha!r} — regenerate from a "
                "clean tree so the artifact is reproducible")
        if not any(v is not None and "seed" in k for k, v in prov.items()):
            failures.append(f"{rel}: no seed recorded in provenance")
        if not any(f.startswith(rel) for f in failures):
            print(f"OK   {rel}: sha {str(sha)[:12]} "
                  f"jax {prov['jax_version']} "
                  f"{prov['backend']} x{prov['device_count']}")
    return failures


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("gates", nargs="*",
                    help="gate names from the config (default: all)")
    ap.add_argument("--config", default=str(DEFAULT_CONFIG),
                    help="bench_gates.toml path")
    ap.add_argument("--provenance", default=None, metavar="DIR",
                    help="instead of gating metrics, validate the "
                         "provenance stamp on every BENCH_*.json in DIR")
    ap.add_argument("--list", action="store_true",
                    help="list configured gates and exit")
    args = ap.parse_args(argv)

    if args.provenance:
        failures = check_provenance(Path(args.provenance))
        for f in failures:
            print(f"FAIL {f}")
        print(f"check_bench --provenance: "
              f"{'FAILED' if failures else 'all stamps valid'}")
        return 1 if failures else 0

    with open(args.config, "rb") as fh:
        config = tomllib.load(fh)
    gates = config.get("gates", {})
    if args.list:
        for name, gate in gates.items():
            print(f"{name}: {gate['artifact']}"
                  + (f" vs {gate['baseline']}" if "baseline" in gate
                     else ""))
        return 0
    unknown = [g for g in args.gates if g not in gates]
    if unknown:
        print(f"unknown gate(s) {unknown}; configured: {sorted(gates)}")
        return 2
    selected = args.gates or list(gates)

    failures = []
    for name in selected:
        failures.extend(run_gate(name, gates[name]))
    for f in failures:
        print(f"FAIL {f}")
    print(f"check_bench: {len(selected)} gate(s), "
          f"{'FAILED' if failures else 'all passed'}")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
