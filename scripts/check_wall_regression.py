#!/usr/bin/env python
"""Gate the wall-clock goodput bench (BENCH_serve_wall.json).

ROADMAP item 1's acceptance lives here: macro-tick fusion must keep the
host dispatch path off the critical path, and adding shards must not
*cost* wall-clock throughput.  CI runs this against the artifact it just
generated, compared to the committed one, so a regression hard-fails the
job instead of silently landing in an uploaded artifact nobody reads.

Checks on the fresh artifact (machine-consistent, within one run):

1. dispatch share at the highest shard count < --max-dispatch-share
   (default 0.5; the pre-macro-tick baseline was 0.938).  The share is
   ``phase_cpu_share.dispatch`` when present — host thread-CPU seconds
   spent dispatching / instrumented wall, which stays truthful when
   device compute timeshares cores with the engine loop (CPU backend,
   small runners) — falling back to the wall-span share for old
   artifacts;
2. wall-clock req/s at the highest shard count >= (1 - --invert-slack)
   x req/s at the next lower shard count (scaling must not invert;
   the slack absorbs run-to-run noise on shared runners).

Checks against the committed baseline (--baseline) use only
machine-durable signals — phase *shares* and scaling *ratios*, never
absolute wall seconds (the artifact's own note explains why):

3. fresh dispatch share at max shards <= baseline share + --share-slack;
4. fresh scaling ratio (req/s at max shards / req/s at min shards)
   >= baseline ratio * (1 - --ratio-slack).

Exit 0 when every check passes, 1 otherwise (each failure is printed).
"""

from __future__ import annotations

import argparse
import json
import sys


def _rows(path):
    with open(path) as fh:
        doc = json.load(fh)
    rows = sorted(doc.get("rows", []), key=lambda r: r["devices"])
    if len(rows) < 2:
        sys.exit(f"{path}: need rows for >= 2 shard counts, got {len(rows)}")
    return rows


def _dispatch_share(row):
    cpu = row.get("phase_cpu_share")
    if cpu is not None:
        return float(cpu.get("dispatch", 0.0))
    return float(row["phase_share"]["dispatch"])


def _scaling_ratio(rows):
    lo, hi = rows[0], rows[-1]
    if lo["requests_per_s"] <= 0:
        sys.exit("min-shard req/s is zero; bench horizon too short to gate on")
    return hi["requests_per_s"] / lo["requests_per_s"]


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("artifact", help="fresh BENCH_serve_wall.json to gate")
    ap.add_argument(
        "--baseline",
        default=None,
        help="committed BENCH_serve_wall.json to compare durable signals against",
    )
    ap.add_argument("--max-dispatch-share", type=float, default=0.5)
    ap.add_argument(
        "--invert-slack",
        type=float,
        default=0.10,
        help="allowed relative req/s shortfall of the top shard count vs "
        "the next lower one (noise tolerance for the inversion check)",
    )
    ap.add_argument(
        "--share-slack",
        type=float,
        default=0.10,
        help="allowed dispatch-share increase vs baseline (absolute)",
    )
    ap.add_argument(
        "--ratio-slack",
        type=float,
        default=0.25,
        help="allowed relative drop in the max/min req/s scaling ratio",
    )
    args = ap.parse_args(argv)

    rows = _rows(args.artifact)
    top, prev = rows[-1], rows[-2]
    share = _dispatch_share(top)
    ratio = _scaling_ratio(rows)
    failures = []

    print(
        f"[wall-gate] {args.artifact}: devices={[r['devices'] for r in rows]} "
        f"req/s={[round(r['requests_per_s'], 3) for r in rows]} "
        f"dispatch_share@{top['devices']}={share:.3f} scaling_ratio={ratio:.3f}"
    )

    if share >= args.max_dispatch_share:
        failures.append(
            f"dispatch share at {top['devices']} shards is {share:.3f} "
            f">= {args.max_dispatch_share} — host launch path is back on "
            f"the critical path"
        )
    if top["requests_per_s"] < prev["requests_per_s"] * (1 - args.invert_slack):
        failures.append(
            f"wall-clock req/s inverted: {top['devices']} shards "
            f"({top['requests_per_s']:.3f}) < {prev['devices']} shards "
            f"({prev['requests_per_s']:.3f}) * (1 - {args.invert_slack})"
        )

    if args.baseline:
        base = _rows(args.baseline)
        base_share = _dispatch_share(base[-1])
        base_ratio = _scaling_ratio(base)
        print(
            f"[wall-gate] baseline {args.baseline}: "
            f"dispatch_share@{base[-1]['devices']}={base_share:.3f} "
            f"scaling_ratio={base_ratio:.3f}"
        )
        if share > base_share + args.share_slack:
            failures.append(
                f"dispatch share regressed vs committed artifact: "
                f"{share:.3f} > {base_share:.3f} + {args.share_slack}"
            )
        if ratio < base_ratio * (1 - args.ratio_slack):
            failures.append(
                f"req/s scaling ratio regressed vs committed artifact: "
                f"{ratio:.3f} < {base_ratio:.3f} * (1 - {args.ratio_slack})"
            )

    for msg in failures:
        print(f"[wall-gate] FAIL: {msg}")
    if failures:
        return 1
    print("[wall-gate] OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
