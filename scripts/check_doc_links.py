#!/usr/bin/env python
"""Docs link checker: every relative markdown link must resolve.

Scans the repo's markdown files for ``[text](target)`` links and verifies
that each relative target exists on disk (anchors are stripped; absolute
URLs and mailto are skipped).  Exits non-zero listing every broken link —
CI runs this so README/docs references cannot rot silently.

  python scripts/check_doc_links.py [root]
"""
from __future__ import annotations

import re
import sys
from pathlib import Path

#: inline markdown links; deliberately simple — no reference-style links in
#: this repo, and nested parens in URLs don't occur.
LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
SKIP_PREFIXES = ("http://", "https://", "mailto:", "#")
#: directories never scanned (vendored/derived content)
SKIP_DIRS = {".git", "__pycache__", ".pytest_cache", "node_modules"}


def iter_markdown(root: Path):
    for path in sorted(root.rglob("*.md")):
        if not SKIP_DIRS.intersection(p.name for p in path.parents):
            yield path


def check(root: Path) -> list:
    broken = []
    for md in iter_markdown(root):
        for m in LINK_RE.finditer(md.read_text(encoding="utf-8")):
            target = m.group(1)
            if target.startswith(SKIP_PREFIXES):
                continue
            rel = target.split("#", 1)[0]
            if not rel:
                continue
            resolved = (md.parent / rel).resolve()
            if not resolved.exists():
                broken.append((md.relative_to(root), target))
    return broken


def main(argv=None):
    argv = sys.argv[1:] if argv is None else argv
    root = Path(argv[0]) if argv else Path(__file__).resolve().parents[1]
    broken = check(root)
    if broken:
        print(f"{len(broken)} broken doc link(s):")
        for md, target in broken:
            print(f"  {md}: ({target})")
        return 1
    n = sum(1 for _ in iter_markdown(root))
    print(f"doc links OK across {n} markdown files")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
