"""Paper Table 3: error vs number of launched chains (threads).

Paper: n=16, T0=5, T_min=0.5, rho=0.7, N=5; chains 768 -> 76 800 -> 7.68e6,
error falls as the chain population grows at fixed (tiny) ladder budget.
Quick mode uses 64 -> 512 -> 4096 chains (same claim, CPU-sized).
"""
from __future__ import annotations

import jax
import numpy as np

from repro.core import SAConfig, sa_minimize
from repro.objectives import functions as F

from .common import Budget, Table


def run(budget: Budget) -> Table:
    chain_counts = [64, 512, 4096] if budget.quick else [768, 76800, 768000]
    reps = 3 if budget.quick else 10
    obj = F.schwefel(16)

    t = Table(f"Table 3 — error vs chain count ({budget.label})",
              ["chains", "evals", "|f-f*|", "rel-x err"],
              fmt={"evals": ".3e", "|f-f*|": ".3e", "rel-x err": ".3e"})
    errs = []
    for w in chain_counts:
        cfg = SAConfig(T0=5.0, T_min=0.5, rho=0.7, N=5, n_chains=w,
                       exchange="sync", record_history=False)
        ef, ex = [], []
        for rep in range(reps):
            res = sa_minimize(obj, cfg, key=jax.random.PRNGKey(rep))
            df, dx = obj.error_to_opt(res.x_best, res.f_best)
            ef.append(float(df))
            ex.append(float(dx))
        errs.append(np.mean(ef))
        t.add(chains=w, evals=cfg.n_evals, **{"|f-f*|": np.mean(ef),
                                              "rel-x err": np.mean(ex)})
    t.show()
    mono = all(errs[i + 1] <= errs[i] * 1.5 for i in range(len(errs) - 1))
    print(f"[claim] error falls as chains grow: "
          f"{'OK' if errs[-1] < errs[0] else 'NOT SEEN'}"
          f" (monotone-ish: {mono})")
    t.save("table3_chains_error")
    return t


if __name__ == "__main__":
    run(Budget(quick=True))
