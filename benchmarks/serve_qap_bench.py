"""QAP solution-quality bench: gap-to-best-known through the serving engine.

For each built-in QAP instance (objectives/qap.py), a seeded cohort of
permutation-family requests is served through the continuous-batching
engine — all cohorts co-batched in one fleet, macro-K fused — and the
per-seed champions are reduced to the quality row the gate
(scripts/check_bench.py, `qap_*` gates in bench_gates.toml) consumes:

  best_found   min cost over the cohort (must never beat best_known:
               the instances ship witness permutations, so a "better"
               value means broken kernel arithmetic or stale data),
  gap_pct      (best_found - best_known) / best_known,
  mean_gap_pct mean per-seed gap (cohort robustness, not just the max),
  hit_rate     fraction of seeds whose champion reached best_known.

Costs are small-integer sums evaluated exactly in float32 (see
kernels/qap_sweep.py), so every number here is deterministic for fixed
seeds — a committable perf-trajectory artifact, not a wall-clock bench.

  PYTHONPATH=src python benchmarks/serve_qap_bench.py \
      --seeds 8 --chains 32 --chains-per-slot 16
"""
from __future__ import annotations

import argparse
from pathlib import Path

try:
    from .common import Table, write_bench
except ImportError:  # run as a plain script
    import sys
    sys.path.insert(0, str(Path(__file__).resolve().parent))
    from common import Table, write_bench

from repro.objectives import qap
from repro.service.engine import EngineConfig, SAServeEngine
from repro.service.request import SARequest
from repro.service.serve_sa import _jsonable

DEFAULT_OUT = (Path(__file__).resolve().parents[1] / "artifacts" / "bench"
               / "BENCH_serve_qap.json")

#: Cooling schedule sized to QAP swap-move deltas (tens per exchange):
#: ~45 levels of 40 sweeps — small enough for CPU CI, deep enough that
#: the cohort reliably lands within a few percent of best_known.
SCHEDULE = dict(T0=50.0, T_min=0.5, rho=0.90, N=40)


def bench(args) -> dict:
    cfg = EngineConfig(n_slots=args.slots,
                       chains_per_slot=args.chains_per_slot,
                       macro_k=args.macro_k, use_pallas=False)
    engine = SAServeEngine(cfg)
    names = sorted(qap.INSTANCES)
    reqs = []
    for i, name in enumerate(names):
        inst = qap.get(name)
        for s in range(args.seeds):
            reqs.append(SARequest(
                req_id=len(reqs), objective=name, dim=inst.n,
                n_chains=args.chains, seed=args.seed0 + 100 * i + s,
                family="permutation", **SCHEDULE))
    for r in reqs:
        engine.submit(r)
    results = {r.req_id: r for r in engine.run(max_ticks=args.max_ticks)}
    assert len(results) == len(reqs), "bench stream did not drain"

    rows = []
    for name in names:
        inst = qap.get(name)
        found = [results[r.req_id].f_best for r in reqs
                 if r.objective == name]
        best = min(found)
        gaps = [(f - inst.best_known) / inst.best_known for f in found]
        rows.append({
            "label": name, "n": inst.n, "proven": inst.proven,
            "source": inst.source,
            "best_known": inst.best_known,
            "best_found": best,
            "gap_pct": 100.0 * (best - inst.best_known) / inst.best_known,
            "mean_gap_pct": 100.0 * sum(gaps) / len(gaps),
            "hit_rate": sum(f == inst.best_known for f in found)
            / len(found),
            "seeds": args.seeds, "chains": args.chains,
        })
    return {
        "config": {
            "seeds": args.seeds, "seed0": args.seed0,
            "chains": args.chains, "slots": args.slots,
            "chains_per_slot": args.chains_per_slot,
            "macro_k": args.macro_k, "max_ticks": args.max_ticks,
            "schedule": SCHEDULE,
        },
        "rows": rows,
    }


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--seeds", type=int, default=8,
                    help="cohort size per instance (independent RNG seeds)")
    ap.add_argument("--seed0", type=int, default=0,
                    help="base seed; cohort i uses seed0 + 100*i + s")
    ap.add_argument("--chains", type=int, default=32,
                    help="chains per request")
    ap.add_argument("--slots", type=int, default=8,
                    help="engine slot-pool size")
    ap.add_argument("--chains-per-slot", type=int, default=16)
    ap.add_argument("--macro-k", type=int, default=4,
                    help="temperature levels fused per dispatch")
    ap.add_argument("--max-ticks", type=int, default=5000)
    ap.add_argument("--out", default=None,
                    help="artifact path (default "
                         "artifacts/bench/BENCH_serve_qap.json)")
    args = ap.parse_args(argv)

    doc = bench(args)
    cols = ["label", "n", "best_known", "best_found", "gap_pct",
            "mean_gap_pct", "hit_rate", "seeds", "chains", "proven"]
    table = Table(
        f"QAP quality through the serving engine ({args.seeds} seeds x "
        f"{args.chains} chains per instance, T0={SCHEDULE['T0']:g} "
        f"rho={SCHEDULE['rho']:g} N={SCHEDULE['N']}, macro-K "
        f"{args.macro_k})",
        cols,
        fmt={"gap_pct": ".2f", "mean_gap_pct": ".2f", "hit_rate": ".0%"})
    for row in doc["rows"]:
        table.add(**{k: row[k] for k in cols})
    table.show()
    out = write_bench(Path(args.out) if args.out else DEFAULT_OUT,
                      _jsonable(doc), seed0=args.seed0)
    print(f"\nwrote {out}")
    return doc


if __name__ == "__main__":
    main()
