"""Shared benchmark plumbing: timing, tables, error metrics."""
from __future__ import annotations

import dataclasses
import json
import time
from pathlib import Path
from typing import Callable, Optional

import jax
import numpy as np

ARTIFACTS = Path(__file__).resolve().parent.parent / "artifacts"


def block(x):
    return jax.block_until_ready(x)


def time_fn(fn: Callable, *args, repeats: int = 3, warmup: int = 1,
            **kw) -> tuple[float, object]:
    """Median wall time of ``fn(*args)`` after ``warmup`` calls."""
    out = None
    for _ in range(warmup):
        out = block(fn(*args, **kw))
    ts = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        out = block(fn(*args, **kw))
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts)), out


class Table:
    """Fixed-width console table that also accumulates JSON rows."""

    def __init__(self, title: str, columns: list[str], fmt: Optional[dict] = None):
        self.title = title
        self.columns = columns
        self.fmt = fmt or {}
        self.rows: list[dict] = []

    def add(self, **row):
        self.rows.append(row)

    def _cell(self, col, v):
        if v is None:
            return "-"
        f = self.fmt.get(col)
        if f:
            return format(v, f)
        if isinstance(v, float):
            return f"{v:.4g}"
        return str(v)

    def show(self):
        print(f"\n=== {self.title} ===")
        cells = [[self._cell(c, r.get(c)) for c in self.columns]
                 for r in self.rows]
        widths = [max(len(c), *(len(row[i]) for row in cells)) if cells
                  else len(c) for i, c in enumerate(self.columns)]
        print("  ".join(c.rjust(w) for c, w in zip(self.columns, widths)))
        for row in cells:
            print("  ".join(v.rjust(w) for v, w in zip(row, widths)))

    def save(self, name: str):
        out = ARTIFACTS / "bench"
        out.mkdir(parents=True, exist_ok=True)
        (out / f"{name}.json").write_text(
            json.dumps({"title": self.title, "rows": self.rows}, indent=1,
                       default=float))


@dataclasses.dataclass
class Budget:
    """Benchmark scale: quick (CPU CI) vs full (paper-scale)."""
    quick: bool = True

    @property
    def label(self):
        return "quick" if self.quick else "full"
