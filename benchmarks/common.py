"""Shared benchmark plumbing: timing, tables, provenance, error metrics."""
from __future__ import annotations

import dataclasses
import json
import platform
import subprocess
import time
from pathlib import Path
from typing import Callable, Optional

import jax
import numpy as np

ARTIFACTS = Path(__file__).resolve().parent.parent / "artifacts"
_REPO = Path(__file__).resolve().parent.parent


def block(x):
    return jax.block_until_ready(x)


def _git_sha() -> Optional[str]:
    """HEAD sha, ``-dirty``-suffixed when *tracked source* is modified.

    ``artifacts/`` and untracked files are excluded from the dirty
    check: artifacts are benchmark *outputs*, so regenerating them must
    not mark their own stamps dirty (the provenance CI gate rejects
    dirty-sha artifacts — only code changes should trip it).
    """
    try:
        out = subprocess.run(
            ["git", "rev-parse", "HEAD"], cwd=_REPO, capture_output=True,
            text=True, timeout=10)
        sha = out.stdout.strip()
        if out.returncode == 0 and sha:
            dirty = subprocess.run(
                ["git", "status", "--porcelain", "-uno", "--",
                 ".", ":(exclude)artifacts"],
                cwd=_REPO, capture_output=True, text=True, timeout=10)
            return sha + ("-dirty" if dirty.stdout.strip() else "")
    except Exception:
        pass
    return None


def provenance(**extra) -> dict:
    """Reproducibility stamp for BENCH_*.json artifacts.

    Records where a number came from — git sha (with a ``-dirty``
    marker), jax/numpy versions, backend and device census, host
    platform — so a committed benchmark JSON is auditable long after the
    machine that produced it is gone.  Per-bench facts (seed, config)
    are passed through ``extra``; everything else every artifact shares.
    """
    devices = jax.devices()
    info = {
        "git_sha": _git_sha(),
        "jax_version": jax.__version__,
        "numpy_version": np.__version__,
        "backend": jax.default_backend(),
        "device_count": len(devices),
        "device_kind": devices[0].device_kind if devices else None,
        "python_version": platform.python_version(),
        "platform": platform.platform(),
    }
    info.update(extra)
    return info


def _sanitize(obj):
    """Map non-finite floats to None: committed artifacts must be strict
    RFC 8259 JSON (bare NaN breaks jq / JSON.parse / Go decoders)."""
    if isinstance(obj, dict):
        return {k: _sanitize(v) for k, v in obj.items()}
    if isinstance(obj, (list, tuple)):
        return [_sanitize(v) for v in obj]
    if isinstance(obj, (np.floating, np.integer)):
        obj = obj.item()
    if isinstance(obj, float) and not np.isfinite(obj):
        return None
    return obj


def write_bench(out: Path, doc: dict, **prov_extra) -> Path:
    """Write one benchmark artifact with the provenance stamp attached.

    The single chokepoint every ``BENCH_*.json`` writer goes through:
    stamps :func:`provenance` (plus per-bench ``prov_extra`` such as the
    seed), sanitizes non-finite floats and writes deterministic
    (sorted-key) JSON.
    """
    out = Path(out)
    out.parent.mkdir(parents=True, exist_ok=True)
    doc = dict(doc)
    doc["provenance"] = provenance(**prov_extra)
    out.write_text(json.dumps(_sanitize(doc), indent=2, sort_keys=True,
                              allow_nan=False, default=float) + "\n")
    return out


def time_fn(fn: Callable, *args, repeats: int = 3, warmup: int = 1,
            **kw) -> tuple[float, object]:
    """Median wall time of ``fn(*args)`` after ``warmup`` calls."""
    out = None
    for _ in range(warmup):
        out = block(fn(*args, **kw))
    ts = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        out = block(fn(*args, **kw))
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts)), out


class Table:
    """Fixed-width console table that also accumulates JSON rows."""

    def __init__(self, title: str, columns: list[str], fmt: Optional[dict] = None):
        self.title = title
        self.columns = columns
        self.fmt = fmt or {}
        self.rows: list[dict] = []

    def add(self, **row):
        self.rows.append(row)

    def _cell(self, col, v):
        if v is None:
            return "-"
        f = self.fmt.get(col)
        if f:
            return format(v, f)
        if isinstance(v, float):
            return f"{v:.4g}"
        return str(v)

    def show(self):
        print(f"\n=== {self.title} ===")
        cells = [[self._cell(c, r.get(c)) for c in self.columns]
                 for r in self.rows]
        widths = [max(len(c), *(len(row[i]) for row in cells)) if cells
                  else len(c) for i, c in enumerate(self.columns)]
        print("  ".join(c.rjust(w) for c, w in zip(self.columns, widths)))
        for row in cells:
            print("  ".join(v.rjust(w) for v, w in zip(row, widths)))

    def save(self, name: str):
        write_bench(ARTIFACTS / "bench" / f"{name}.json",
                    {"title": self.title, "rows": self.rows})


@dataclasses.dataclass
class Budget:
    """Benchmark scale: quick (CPU CI) vs full (paper-scale)."""
    quick: bool = True

    @property
    def label(self):
        return "quick" if self.quick else "full"
