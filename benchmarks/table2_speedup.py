"""Paper Table 2: parallel-vs-sequential performance across dimension n.

The paper measures GPU wall-time speedup of 16384 CUDA chains vs one CPU
core.  The TPU-adapted equivalent on this container: throughput (Metropolis
steps/s summed over chains) of the vectorized parallel engine vs the same
engine at n_chains=1 — the vectorization speedup.  The paper's qualitative
claims asserted here:
  * speedup grows with the chain count and saturates;
  * speedup *drops* as n grows (the sweep becomes memory-bound: state
    streaming dominates the O(n) objective arithmetic).
"""
from __future__ import annotations

import jax

from repro.core import SAConfig, sa_minimize
from repro.objectives import functions as F

from .common import Budget, Table, time_fn


def _throughput(obj, n_chains: int, budget: Budget) -> float:
    """Metropolis proposals/s for one ladder run."""
    cfg = SAConfig(T0=10.0, T_min=1.0, rho=0.7,
                   N=20 if budget.quick else 100,
                   n_chains=n_chains, exchange="sync",
                   record_history=False)

    def run(seed):
        return sa_minimize(obj, cfg, key=jax.random.PRNGKey(seed)).f_best

    dt, _ = time_fn(run, 0, repeats=2, warmup=1)
    return cfg.n_evals / dt


def run(budget: Budget) -> Table:
    dims = [8, 16, 32] if budget.quick else [8, 16, 32, 64, 128, 256, 512]
    chains = 4096 if budget.quick else 16384

    t = Table(f"Table 2 — parallel throughput vs sequential ({budget.label})",
              ["n", "V0 evals/s", f"V1x{chains} evals/s", "speedup"],
              fmt={"V0 evals/s": ".3e", f"V1x{chains} evals/s": ".3e",
                   "speedup": ".1f"})
    speedups = []
    for n in dims:
        obj = F.schwefel(n)
        seq = _throughput(obj, 1, budget)
        par = _throughput(obj, chains, budget)
        speedups.append(par / seq)
        t.add(n=n, **{"V0 evals/s": seq, f"V1x{chains} evals/s": par,
                      "speedup": par / seq})
    t.show()
    print(f"[claim] speedup decreases with n (memory-bound at large n): "
          f"{'OK' if speedups[-1] < speedups[0] else 'NOT SEEN'} "
          f"({speedups[0]:.0f}x -> {speedups[-1]:.0f}x)")
    t.save("table2_speedup")
    return t


if __name__ == "__main__":
    run(Budget(quick=True))
