"""Synthetic-load throughput benchmark for the SA serving engine.

Saturating load: the queue starts with ``load_factor`` x more requests than
the slot pool can hold, so free slots are always refillable — the
continuous-batching claim is that occupancy stays high (>= 80%) and no
tail latency accrues from stragglers.  Reports requests/s, sweeps/s (one
sweep = one slot advanced one temperature level), chain-steps/s, and mean
slot occupancy, swept over pool sizes.

  PYTHONPATH=src python benchmarks/serve_sa_bench.py \
      --slots 4,8 --requests-per-slot 4 --chains-per-slot 32
"""
from __future__ import annotations

import argparse

try:
    from .common import Table
except ImportError:  # run as a plain script: python benchmarks/serve_sa_bench.py
    import sys
    from pathlib import Path
    sys.path.insert(0, str(Path(__file__).resolve().parent))
    from common import Table

from repro.service.engine import EngineConfig, SAServeEngine
from repro.service.scheduler import SchedulerConfig
from repro.service.serve_sa import make_mix


def bench_pool(n_slots: int, requests_per_slot: int, chains_per_slot: int,
               variant: str, seed: int) -> dict:
    cfg = EngineConfig(n_slots=n_slots, chains_per_slot=chains_per_slot,
                       variant=variant,
                       scheduler=SchedulerConfig(policy="priority"))
    engine = SAServeEngine(cfg)
    n_requests = requests_per_slot * n_slots
    for req in make_mix(n_requests, chains_per_slot, seed=seed,
                        max_slots_per_req=min(2, n_slots)):
        engine.submit(req)
    engine.run()
    s = engine.stats()
    s["n_slots"] = n_slots
    s["requests"] = n_requests
    return s


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--slots", default="4,8",
                    help="comma-separated pool sizes to sweep")
    ap.add_argument("--requests-per-slot", type=int, default=4,
                    help="queue depth multiple (saturating load)")
    ap.add_argument("--chains-per-slot", type=int, default=32)
    ap.add_argument("--variant", default="delta", choices=["delta", "full"])
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    table = Table(
        "SA serving engine: continuous-batching throughput (synthetic load)",
        ["n_slots", "requests", "ticks", "wall_s", "requests_per_s",
         "sweeps_per_s", "chain_steps_per_s", "occupancy"],
        fmt={"wall_s": ".2f", "requests_per_s": ".2f", "sweeps_per_s": ".1f",
             "chain_steps_per_s": ".3g", "occupancy": ".1%"})
    worst = 1.0
    for n_slots in [int(s) for s in args.slots.split(",")]:
        row = bench_pool(n_slots, args.requests_per_slot,
                         args.chains_per_slot, args.variant, args.seed)
        worst = min(worst, row["occupancy"])
        table.add(**{k: row[k] for k in table.columns})
    table.show()
    print(f"\nmean slot occupancy (worst pool): {worst:.1%} "
          f"({'PASS' if worst >= 0.80 else 'BELOW'} 80% target under "
          "saturating load)")
    return table.rows


if __name__ == "__main__":
    main()
