"""Paper Tables 4/5/6: throughput scaling when growing (4) the chain count,
(5) the Markov-chain length N, and (6) the total function-eval budget.

The paper's claim set:
  * Table 4: speedup rises with chains and saturates (more parallel work
    amortizes fixed overhead) — here: evals/s rises with chains, saturates;
  * Table 5: speedup is maintained as N doubles (longer sweeps amortize
    the per-level exchange) — here: evals/s roughly flat-to-rising in N;
  * Table 6: same when the budget doubles via any knob.
"""
from __future__ import annotations

import jax

from repro.core import SAConfig, sa_minimize
from repro.objectives import functions as F

from .common import Budget, Table, time_fn


def _tput(obj, n_chains, N, budget) -> float:
    cfg = SAConfig(T0=10.0, T_min=1.0, rho=0.7, N=N, n_chains=n_chains,
                   exchange="sync", record_history=False)

    def run(seed):
        return sa_minimize(obj, cfg, key=jax.random.PRNGKey(seed)).f_best

    dt, _ = time_fn(run, 0, repeats=2, warmup=1)
    return cfg.n_evals / dt


def run(budget: Budget) -> Table:
    obj16, obj32 = F.schwefel(16), F.schwefel(32)

    # Table 4: chains doubling.
    chain_list = ([512, 1024, 2048, 4096] if budget.quick
                  else [8192, 16384, 32768, 65536, 131072])
    t4 = Table(f"Table 4 — evals/s vs chains ({budget.label})",
               ["chains", "n=16 evals/s", "n=32 evals/s"],
               fmt={"n=16 evals/s": ".3e", "n=32 evals/s": ".3e"})
    r16 = []
    for w in chain_list:
        a, b = _tput(obj16, w, 20, budget), _tput(obj32, w, 20, budget)
        r16.append(a)
        t4.add(chains=w, **{"n=16 evals/s": a, "n=32 evals/s": b})
    t4.show()
    print(f"[claim] throughput rises with chains then saturates: "
          f"{'OK' if r16[-1] > r16[0] else 'NOT SEEN'}")
    t4.save("table4_chains_scaling")

    # Table 5: N doubling at fixed chains.
    Ns = [25, 50, 100] if budget.quick else [50, 100, 200, 400, 800]
    w = 1024 if budget.quick else 16384
    t5 = Table(f"Table 5 — evals/s vs N ({budget.label})",
               ["N", "n=16 evals/s", "n=32 evals/s"],
               fmt={"n=16 evals/s": ".3e", "n=32 evals/s": ".3e"})
    rN = []
    for N in Ns:
        a, b = _tput(obj16, w, N, budget), _tput(obj32, w, N, budget)
        rN.append(a)
        t5.add(N=N, **{"n=16 evals/s": a, "n=32 evals/s": b})
    t5.show()
    print(f"[claim] throughput maintained as N grows: "
          f"{'OK' if rN[-1] > 0.7 * rN[0] else 'NOT SEEN'}")
    t5.save("table5_N_scaling")

    # Table 6: budget doubling via chains (evals/s should hold).
    t6 = Table(f"Table 6 — evals/s vs total budget ({budget.label})",
               ["evals", "n=16 evals/s", "n=32 evals/s"],
               fmt={"evals": ".3e", "n=16 evals/s": ".3e",
                    "n=32 evals/s": ".3e"})
    for w in chain_list[:3]:
        cfg = SAConfig(T0=10.0, T_min=1.0, rho=0.7, N=20, n_chains=w)
        a, b = _tput(obj16, w, 20, budget), _tput(obj32, w, 20, budget)
        t6.add(evals=cfg.n_evals, **{"n=16 evals/s": a, "n=32 evals/s": b})
    t6.show()
    t6.save("table6_budget_scaling")
    return t4


if __name__ == "__main__":
    run(Budget(quick=True))
