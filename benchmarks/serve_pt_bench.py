"""Workload-class quality benchmark: levels-to-target for SA / PT / PA.

Four cohorts of IDENTICAL seeded requests (same objective, dim, chain
count, cooling schedule, seeds — only the workload class differs) are
served through the engine, each request stopping the moment its champion
crosses ``target_error``.  The metric is **temperature levels run until
the target stop** — the ladder-axis cost of reaching a fixed solution
quality; a request that never crosses runs the full ladder and counts at
ladder length (a conservative penalty), and is excluded from the hit
rate.

Cohorts:

* ``sa``      — plain parallel SA, ``exchange='async'`` (paper V1: no
                inter-chain communication — the baseline the PT/PA gate
                compares against);
* ``sa+sync`` — SA with the champion broadcast (paper V2), for context;
* ``pt``      — parallel tempering: chains hold rungs of the request's
                geometric [T0, T_min] ladder, even/odd Metropolis swaps
                every level.  The cold rungs refine from level 1 instead
                of waiting for the schedule to cool, which is exactly
                what the levels-to-target metric measures;
* ``pa``      — population annealing: per-level Boltzmann resampling
                concentrates the population in the best basins as the
                inverse-temperature increments grow.

The run is deterministic (counter-based RNG, fixed seeds, closed-loop
admission), so the committed artifact is reproducible bit-for-bit on the
same backend.  ``scripts/check_bench.py`` (the ``pt_*`` gates in
``bench_gates.toml``) gates the result: PT (and,
for the committed artifact, PA) must reach the target in fewer mean
levels than plain SA.

  PYTHONPATH=src python benchmarks/serve_pt_bench.py \
      --out artifacts/bench/BENCH_serve_pt.json
"""
from __future__ import annotations

import argparse
from pathlib import Path

import numpy as np

try:
    from .common import ARTIFACTS, write_bench
except ImportError:  # run as a plain script
    import sys
    sys.path.insert(0, str(Path(__file__).resolve().parent))
    from common import ARTIFACTS, write_bench

from repro.service.engine import EngineConfig, SAServeEngine
from repro.service.request import SARequest


def run_cohort(label: str, method: str, exchange: str, args) -> dict:
    """Serve one cohort of identically-seeded requests; return its row."""
    cfg = EngineConfig(n_slots=args.slots,
                       chains_per_slot=args.chains_per_slot,
                       n_devices=1, macro_k=args.macro_k, use_pallas=False)
    engine = SAServeEngine(cfg)
    reqs = [SARequest(req_id=i, objective=args.objective, dim=args.dim,
                      n_chains=args.chains, seed=args.seed0 + i,
                      method=method, exchange=exchange,
                      T0=args.T0, T_min=args.T_min, rho=args.rho, N=args.N,
                      target_error=args.target)
            for i in range(args.seeds)]
    for r in reqs:
        engine.submit(r)
    results = {r.req_id: r for r in engine.run(max_ticks=args.max_ticks)}
    levels = [results[i].levels_run for i in range(args.seeds)]
    hits = [results[i].finish_reason == "target" for i in range(args.seeds)]
    errs = [abs(results[i].f_best) for i in range(args.seeds)]  # f_opt = 0
    return {
        "label": label, "method": method, "exchange": exchange,
        "levels": levels, "mean_levels": float(np.mean(levels)),
        "median_levels": float(np.median(levels)),
        "hit_rate": float(np.mean(hits)), "n": args.seeds,
        "mean_error": float(np.mean(errs)),
    }


COHORTS = [
    ("sa", "sa", "async"),          # plain SA: the gate baseline
    ("sa+sync", "sa", "sync"),
    ("pt", "pt", "sync"),
    ("pa", "pa", "sync"),
]


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--objective", default="rastrigin")
    ap.add_argument("--dim", type=int, default=6)
    ap.add_argument("--chains", type=int, default=64,
                    help="chains per request (PT ladder width = rung count)")
    ap.add_argument("--seeds", type=int, default=8,
                    help="requests per cohort (seed0..seed0+n-1)")
    ap.add_argument("--seed0", type=int, default=1000)
    ap.add_argument("--target", type=float, default=3.0,
                    help="target error (|f_best - f_opt|) that stops a run")
    ap.add_argument("--T0", type=float, default=100.0)
    ap.add_argument("--T-min", dest="T_min", type=float, default=0.5)
    ap.add_argument("--rho", type=float, default=0.88)   # ~39-level ladder
    ap.add_argument("--N", type=int, default=20)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--chains-per-slot", type=int, default=16)
    ap.add_argument("--macro-k", type=int, default=1)
    ap.add_argument("--max-ticks", type=int, default=20000)
    ap.add_argument("--out", default=None,
                    help="write BENCH JSON here (default: "
                         "artifacts/bench/BENCH_serve_pt.json)")
    args = ap.parse_args(argv)

    rows = []
    for label, method, exchange in COHORTS:
        row = run_cohort(label, method, exchange, args)
        rows.append(row)
        print(f"[serve_pt] {label:<8} mean_levels={row['mean_levels']:6.1f} "
              f"hit={row['hit_rate']:.0%} mean_err={row['mean_error']:.2f} "
              f"levels={row['levels']}")

    doc = {
        "bench": "serve_pt",
        "config": {
            "objective": args.objective, "dim": args.dim,
            "chains": args.chains, "seeds": args.seeds,
            "seed0": args.seed0, "target_error": args.target,
            "T0": args.T0, "T_min": args.T_min, "rho": args.rho,
            "N": args.N, "slots": args.slots,
            "chains_per_slot": args.chains_per_slot,
            "macro_k": args.macro_k,
        },
        "metric": "mean temperature levels run until the champion crossed "
                  "target_error (misses run the full ladder and count at "
                  "ladder length)",
        "note": "levels are integers determined by bit-exact trajectories: "
                "reproducible on the same backend/jax version; "
                "scripts/check_bench.py (pt_* gates) gates pt (and pa) vs the plain "
                "'sa' baseline",
        "rows": rows,
    }
    out = ARTIFACTS / "bench" / "BENCH_serve_pt.json" if args.out is None \
        else Path(args.out)
    write_bench(out, doc, seed0=args.seed0, seeds=args.seeds)
    print(f"[serve_pt] wrote {out}")
    return doc


if __name__ == "__main__":
    main()
