"""Kernel-level benchmark: the fused Metropolis-sweep engine.

Two comparisons on the XLA path (the Pallas kernel targets TPU and is
validated under interpret=True in tests; interpret-mode timing is not
meaningful):

  1. paper-faithful full evaluation vs beyond-paper delta evaluation —
     the O(n) -> O(1) per-step win (DESIGN.md §2), growing with n;
  2. proposals/s as chains scale (vectorization headroom).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import ops
from repro.objectives import functions as F

from .common import Budget, Table, time_fn


def run(budget: Budget) -> Table:
    dims = [16, 64, 256] if budget.quick else [16, 64, 256, 512]
    chains = 2048 if budget.quick else 16384
    n_steps = 50 if budget.quick else 200

    t = Table(f"Kernel — full vs delta eval, {chains} chains ({budget.label})",
              ["n", "full evals/s", "delta evals/s", "delta/full"],
              fmt={"full evals/s": ".3e", "delta evals/s": ".3e",
                   "delta/full": ".2f"})
    for n in dims:
        obj = F.schwefel(n)
        kid = obj.kernel_id
        key = jax.random.PRNGKey(0)
        x = obj.sample_uniform(key, (chains,)).astype(jnp.float32)
        res = {}
        for variant in ("full", "delta"):
            def sweep(x):
                xo, fo = ops.metropolis_sweep(
                    x, 1.0, 7, 0, kid=kid, n_steps=n_steps, variant=variant)
                return fo

            dt, _ = time_fn(sweep, x, repeats=3, warmup=1)
            res[variant] = chains * n_steps / dt
        t.add(n=n, **{"full evals/s": res["full"],
                      "delta evals/s": res["delta"],
                      "delta/full": res["delta"] / res["full"]})
    t.show()
    print("[claim] delta-eval advantage grows with n "
          "(O(n) -> O(1) per proposal)")
    t.save("kernels_bench")
    return t


if __name__ == "__main__":
    run(Budget(quick=True))
