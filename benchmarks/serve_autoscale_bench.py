"""Autoscaler benchmark: closed-loop control vs static fleets under
diurnal load.

One seeded diurnal trace (sinusoidal offered load — the day/night
envelope) with completion-deadline SLOs on every request is served five
ways: by every static fleet size from 1 to ``--max-shards`` shards, and
by the closed-loop autoscaler (``service/autoscaler.py``) starting from
one shard.  For each run the bench records

* **shard_ticks** — ``engine.slot_ticks / n_slots``: shard-tick capacity
  held over the run, the cost metric (a static fleet bills every shard
  every tick, troughs included; the autoscaler only bills what it keeps
  live);
* **p99 completion violation** — the 99th percentile of
  ``latency - finish_deadline`` over completed requests (<= 0 means the
  p99 completion SLO is met);
* **lost** — submitted requests with no terminal result (must be 0
  everywhere: elasticity may never drop work).

The headline claim (gated in CI via ``scripts/bench_gates.toml``): the
autoscaler meets the p99 completion SLO at >= 20% fewer shard-ticks
than the *cheapest static fleet that also meets it*.  Small static
fleets miss the SLO — peak-load queueing delay exceeds the deadline
slack, and ladder truncation cannot compress below ``min_levels`` —
while large static fleets burn idle shard-ticks through the trough the
autoscaler drains away.

Everything is deterministic: the trace is seeded, controller decisions
are tick-aligned, and the report includes the autoscaler's full scaling
history.

Usage::

  PYTHONPATH=src python benchmarks/serve_autoscale_bench.py          # full
  PYTHONPATH=src python benchmarks/serve_autoscale_bench.py --quick  # smoke
"""
from __future__ import annotations

import argparse
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent))

from common import ARTIFACTS, Table, write_bench  # noqa: E402

from repro.service.arrivals import ArrivalProcess, latency_summary, \
    percentile  # noqa: E402
from repro.service.autoscaler import Autoscaler, AutoscalerConfig  # noqa: E402
from repro.service.engine import EngineConfig, SAServeEngine  # noqa: E402
from repro.service.scheduler import SchedulerConfig  # noqa: E402
from repro.service.serve_sa import make_mix  # noqa: E402


def _serve(reqs, cfg, arrivals, controller=None, max_ticks=20000):
    eng = SAServeEngine(cfg)
    if controller is not None:
        eng.attach_controller(controller)
    results = eng.run_stream(arrivals, max_ticks=max_ticks)
    return eng, results


def _row(label, eng, results, reqs):
    by_id = {r.req_id: r for r in results}
    viol = [by_id[q.req_id].latency_ticks - q.finish_deadline
            for q in reqs if q.req_id in by_id and by_id[q.req_id].completed]
    stats = eng.stats()
    lat = latency_summary(results, ticks=eng.tick_count,
                          n_submitted=eng.n_submitted)
    return {
        "fleet": label,
        "shard_ticks": eng.slot_ticks / eng.cfg.n_slots,
        "ticks": eng.tick_count,
        "completed": lat["completed"],
        "lost": eng.n_submitted - len(results),
        "p99_latency": lat["latency_p99"],
        "p99_violation": percentile(viol, 99),
        "slo_met": bool(percentile(viol, 99) <= 0.0),
        "truncations": stats["truncations"],
        "shards_end": stats["devices"],
    }


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--quick", action="store_true",
                    help="smaller trace for CI smoke (not the committed "
                         "artifact)")
    ap.add_argument("--requests", type=int, default=None)
    ap.add_argument("--rate", type=float, default=0.13,
                    help="mean offered load, requests/tick (peak demand "
                         "at amplitude 1 needs ~3.5 of the 4 shards; the "
                         "trough goes quiet — the envelope the "
                         "autoscaler tracks and static fleets cannot)")
    ap.add_argument("--period", type=float, default=160.0,
                    help="diurnal cycle, ticks (the trace spans ~3 "
                         "cycles at the defaults)")
    ap.add_argument("--amplitude", type=float, default=1.0,
                    help="intensity swing (1.0: trough goes fully quiet)")
    ap.add_argument("--slots", type=int, default=4,
                    help="slots per shard")
    ap.add_argument("--chains-per-slot", type=int, default=8)
    ap.add_argument("--max-shards", type=int, default=4)
    ap.add_argument("--deadline-factor", type=float, default=1.9,
                    help="finish_deadline = factor x ladder length "
                         "(tight enough that 1-3 static shards miss the "
                         "p99 SLO at peak queueing delay; 4 meet it)")
    ap.add_argument("--min-levels-frac", type=float, default=0.5)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--arrival-seed", type=int, default=7)
    ap.add_argument("--out", default=str(ARTIFACTS / "bench" /
                                         "BENCH_serve_autoscale.json"))
    args = ap.parse_args(argv)
    n_requests = args.requests if args.requests is not None else \
        (16 if args.quick else 64)

    reqs = make_mix(n_requests, args.chains_per_slot, seed=args.seed,
                    max_slots_per_req=2,
                    finish_deadline_factor=args.deadline_factor,
                    min_levels_frac=args.min_levels_frac)

    def cfg(n_devices):
        return EngineConfig(
            n_slots=args.slots, chains_per_slot=args.chains_per_slot,
            n_devices=n_devices, scheduler=SchedulerConfig())

    def arrivals():
        # Rebuilt per run: ArrivalProcess is consumed as it is served.
        return ArrivalProcess.diurnal(
            reqs, rate=args.rate, period=args.period,
            amplitude=args.amplitude, seed=args.arrival_seed)

    table = Table(
        "autoscaler vs static fleets, diurnal load "
        f"(rate {args.rate}/tick x {args.amplitude} swing, "
        f"period {args.period})",
        ["fleet", "shard_ticks", "ticks", "completed", "lost",
         "p99_latency", "p99_violation", "slo_met", "truncations",
         "shards_end"],
        fmt={"shard_ticks": ".0f", "p99_latency": ".1f",
             "p99_violation": ".1f"})

    for n in range(1, args.max_shards + 1):
        eng, results = _serve(reqs, cfg(n), arrivals())
        table.add(**_row(f"static{n}", eng, results, reqs))

    ctl = Autoscaler(AutoscalerConfig(
        min_shards=1, max_shards=args.max_shards, sample_every=4,
        headroom=1.25, low_util=0.5, window=2, cooldown=8))
    eng, results = _serve(reqs, cfg(1), arrivals(), controller=ctl)
    auto = _row("auto", eng, results, reqs)
    table.add(**auto)
    table.show()

    static_ok = [r for r in table.rows
                 if r["fleet"] != "auto" and r["slo_met"]]
    best_static = min(static_ok, key=lambda r: r["shard_ticks"]) \
        if static_ok else None
    saving_pct = (100.0 * (1.0 - auto["shard_ticks"]
                           / best_static["shard_ticks"])
                  if best_static else float("nan"))
    summary = {
        "auto_shard_ticks": auto["shard_ticks"],
        "auto_slo_met": auto["slo_met"],
        "best_static_ok": best_static["fleet"] if best_static else None,
        "best_static_ok_shard_ticks":
            best_static["shard_ticks"] if best_static else None,
        "saving_pct": saving_pct,
        "total_lost": sum(r["lost"] for r in table.rows),
        "decisions": [list(d) for d in ctl.decisions],
        "samples": ctl.samples,
    }
    print(f"\nautoscaler: slo_met={auto['slo_met']} "
          f"shard_ticks={auto['shard_ticks']:.0f} vs best static meeting "
          f"SLO ({summary['best_static_ok']}): saving {saving_pct:.1f}%")

    write_bench(Path(args.out),
                {"title": table.title, "rows": table.rows,
                 "summary": summary},
                seed=args.seed, arrival_seed=args.arrival_seed,
                rate=args.rate, period=args.period,
                amplitude=args.amplitude, requests=n_requests,
                deadline_factor=args.deadline_factor,
                quick=args.quick)
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
