"""Paper Table 1: accuracy of V0 (sequential) / V1 (async) / V2 (sync) on
the normalized Schwefel function across dimensions, equal eval budget.

Paper config: T0=1000, T_min=0.01, rho=0.99, N=100, 16384 chains,
dims 8..512, 30 repetitions.  Quick mode shrinks the ladder/chains/dims and
repetitions so the whole table runs in ~1 min on CPU; the *ordering claim*
(V2 error << V1 <= V0 at equal evals) is scale-independent and is asserted.
"""
from __future__ import annotations

import jax
import numpy as np

from repro.core import SAConfig, sa_minimize
from repro.objectives import functions as F

from .common import Budget, Table


def run(budget: Budget) -> Table:
    if budget.quick:
        dims, reps = [8, 16, 32], 3
        base = dict(T0=100.0, T_min=0.05, rho=0.9, N=30, n_chains=1024)
    else:  # paper scale
        dims, reps = [8, 16, 32, 64, 128, 256, 512], 30
        base = dict(T0=1000.0, T_min=0.01, rho=0.99, N=100, n_chains=16384)

    t = Table(f"Table 1 — Schwefel accuracy, V0/V1/V2 ({budget.label})",
              ["n", "V0 |f-f*|", "V1 |f-f*|", "V2 |f-f*|",
               "V0 rel-x", "V1 rel-x", "V2 rel-x"],
              fmt={c: ".3e" for c in
                   ["V0 |f-f*|", "V1 |f-f*|", "V2 |f-f*|",
                    "V0 rel-x", "V1 rel-x", "V2 rel-x"]})

    orderings_ok = 0
    for n in dims:
        obj = F.schwefel(n)
        errs = {}
        for tag, over in [("V0", dict(exchange="async", n_chains=1)),
                          ("V1", dict(exchange="async")),
                          ("V2", dict(exchange="sync"))]:
            ef, ex = [], []
            for rep in range(reps):
                cfg = SAConfig(**{**base, **over}, seed=rep,
                               record_history=False)
                res = sa_minimize(obj, cfg, key=jax.random.PRNGKey(rep))
                df, dx = obj.error_to_opt(res.x_best, res.f_best)
                ef.append(float(df))
                ex.append(float(dx))
            errs[tag] = (float(np.mean(ef)), float(np.mean(ex)))
        t.add(n=n, **{"V0 |f-f*|": errs["V0"][0], "V1 |f-f*|": errs["V1"][0],
                      "V2 |f-f*|": errs["V2"][0], "V0 rel-x": errs["V0"][1],
                      "V1 rel-x": errs["V1"][1], "V2 rel-x": errs["V2"][1]})
        if errs["V2"][0] <= errs["V1"][0] + 1e-12:
            orderings_ok += 1

    t.show()
    print(f"[claim] V2 <= V1 error on {orderings_ok}/{len(dims)} dims "
          f"(paper: V2 << V1 on all)")
    t.save("table1_accuracy")
    return t


if __name__ == "__main__":
    run(Budget(quick=True))
