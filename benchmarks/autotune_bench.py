"""Autotuner benchmark: SA vs exhaustive search over the sharding space for
three assigned architectures (dense / MoE / hybrid) — quality (gap to
optimum) and time.  Demonstrates the paper's algorithm as a production
framework service (DESIGN.md §4.3).
"""
from __future__ import annotations

import time

from repro.configs import get_arch
from repro.distributed.autotune import TuneProblem, autotune, exhaustive_best

from .common import Budget, Table

_ARCHS = ["stablelm-1.6b", "deepseek-v2-lite-16b", "jamba-v0.1-52b"]


def run(budget: Budget) -> Table:
    archs = _ARCHS if budget.quick else _ARCHS + ["kimi-k2-1t-a32b",
                                                  "granite-20b"]
    t = Table(f"Autotuner — SA vs exhaustive ({budget.label})",
              ["arch", "SA ms/step", "opt ms/step", "gap %", "SA s",
               "exh s", "choice"],
              fmt={"SA ms/step": ".3f", "opt ms/step": ".3f",
                   "gap %": ".2f", "SA s": ".1f", "exh s": ".1f"})
    for aid in archs:
        prob = TuneProblem(cfg=get_arch(aid).model, seq=4096, batch=256,
                           chips=256)
        t0 = time.time()
        sa_choice, sa_cost = autotune(prob, n_chains=256)
        t_sa = time.time() - t0
        t0 = time.time()
        _, ex_cost = exhaustive_best(prob)
        t_ex = time.time() - t0
        gap = (sa_cost - ex_cost) / ex_cost * 100
        t.add(arch=aid, **{"SA ms/step": sa_cost * 1e3,
                           "opt ms/step": ex_cost * 1e3, "gap %": gap,
                           "SA s": t_sa, "exh s": t_ex,
                           "choice": f"dp{sa_choice['dp']}/tp{sa_choice['tp']}"
                                     f"/{sa_choice['remat']}"
                                     f"/{'ep' if sa_choice['ep'] else 'rep'}"
                                     f"/mb{sa_choice['microbatch']}"
                                     f"/{sa_choice['compress']}"})
    t.show()
    print("[claim] SA matches the exhaustive optimum on every arch")
    t.save("autotune_bench")
    return t


if __name__ == "__main__":
    run(Budget(quick=True))
