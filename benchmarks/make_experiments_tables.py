"""Regenerate the auto-filled sections of EXPERIMENTS.md from artifacts.

Usage: PYTHONPATH=src python -m benchmarks.make_experiments_tables
Replaces text between  <!-- AUTO:name -->  and  <!-- /AUTO:name -->.
"""
from __future__ import annotations

import json
import re
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent
FINAL = ROOT / "artifacts" / "dryrun_final"
MULTI = ROOT / "artifacts" / "dryrun"
PERF = ROOT / "artifacts" / "perf"


def _load(d: Path) -> list[dict]:
    out = []
    for p in sorted(d.glob("*.json")):
        try:
            out.append(json.loads(p.read_text()))
        except json.JSONDecodeError:
            continue
    return out


def roofline_table() -> str:
    final = {(r["arch"], r["shape"]): r for r in _load(FINAL)}
    v1 = {(r["arch"], r["shape"]): r for r in _load(MULTI)
          if not r.get("multi_pod")}
    keys = sorted(set(final) | set(v1))
    rows = ["| arch | shape | compute s | memory s | collective s | "
            "bottleneck | MODEL/HLO flops | peak GiB/dev | parser |",
            "|---|---|---|---|---|---|---|---|---|"]
    for k in keys:
        r = final.get(k)
        ver = "v2"
        if r is None:
            r = v1[k]
            ver = "v1"
        t = r["roofline"]
        u = r.get("useful_flops_frac")
        rows.append(
            f"| {r['arch']} | {r['shape']} | {t['compute_s']:.3e} | "
            f"{t['memory_s']:.3e} | {t['collective_s']:.3e} | "
            f"**{r['bottleneck']}** | "
            f"{'-' if u is None else f'{u:.2f}'} | "
            f"{r['bytes_per_device']['peak'] / 2**30:.2f} | {ver} |")
    return "\n".join(rows)


def dryrun_table() -> str:
    ok = {}
    for r in _load(MULTI):
        key = (r["arch"], r["shape"])
        ok.setdefault(key, set()).add("multi" if r["multi_pod"] else "single")
    for r in _load(FINAL):
        ok.setdefault((r["arch"], r["shape"]), set()).add("single")
    rows = ["| arch | shape | 16x16 (256) | 2x16x16 (512) |",
            "|---|---|---|---|"]
    for (a, s), meshes in sorted(ok.items()):
        rows.append(f"| {a} | {s} | "
                    f"{'ok' if 'single' in meshes else 'MISSING'} | "
                    f"{'ok' if 'multi' in meshes else 'MISSING'} |")
    n = len(ok)
    both = sum(1 for m in ok.values() if len(m) == 2)
    rows.append(f"\n**{n} cells; {both} compiled on both meshes.**")
    return "\n".join(rows)


def memory_summary() -> str:
    final = {(r["arch"], r["shape"]): r for r in _load(FINAL)}
    v1 = {(r["arch"], r["shape"]): r for r in _load(MULTI)
          if not r.get("multi_pod")}
    merged = {**v1, **final}
    rows = ["| arch | shape | argument GiB | temp GiB | peak GiB | "
            "fits 16 GiB HBM |", "|---|---|---|---|---|---|"]
    for _, r in sorted(merged.items()):
        b = r["bytes_per_device"]
        if "argument" not in b:
            continue
        peak = b["peak"] / 2**30
        rows.append(f"| {r['arch']} | {r['shape']} | "
                    f"{b['argument']/2**30:.2f} | {b['temp']/2**30:.2f} | "
                    f"{peak:.2f} | {'yes' if peak <= 16 else 'NO (see §Perf)'} |")
    return "\n".join(rows)


def perf_artifacts() -> str:
    rows = ["| tag | arch/cell | compute s | memory s | collective s | "
            "bottleneck | arg GiB | peak GiB |", "|---|---|---|---|---|---|---|---|"]
    for r in _load(PERF):
        t = r.get("roofline")
        b = r.get("bytes_per_device", {})
        if t is None:  # capacity-only records (production compile only)
            rows.append(f"| {r.get('tag','')} | {r['arch']}/{r['shape']} | "
                        f"- | - | - | capacity-only | "
                        f"{b.get('argument',0)/2**30:.2f} | "
                        f"{b.get('peak',0)/2**30:.2f} |")
            continue
        rows.append(f"| {r.get('tag','')} | {r['arch']}/{r['shape']} | "
                    f"{t['compute_s']:.3e} | {t['memory_s']:.3e} | "
                    f"{t['collective_s']:.3e} | {r['bottleneck']} | "
                    f"{b.get('argument',0)/2**30:.2f} | "
                    f"{b.get('peak',0)/2**30:.2f} |")
    return "\n".join(rows)


def main():
    md = (ROOT / "EXPERIMENTS.md").read_text()
    for name, gen in [("roofline", roofline_table),
                      ("dryrun", dryrun_table),
                      ("memory", memory_summary),
                      ("perf_artifacts", perf_artifacts)]:
        pat = re.compile(rf"(<!-- AUTO:{name} -->).*?(<!-- /AUTO:{name} -->)",
                         re.DOTALL)
        md = pat.sub(lambda m: m.group(1) + "\n" + gen() + "\n" + m.group(2),
                     md)
    (ROOT / "EXPERIMENTS.md").write_text(md)
    print("EXPERIMENTS.md tables regenerated")


if __name__ == "__main__":
    main()
