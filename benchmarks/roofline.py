"""Roofline report: reads artifacts/dryrun/*.json (produced by
``python -m repro.launch.dryrun --all --sa --both-meshes``) and prints the
per-(arch x shape x mesh) three-term roofline table — the §Roofline source
of EXPERIMENTS.md.

This module does NOT compile anything (the dry-run owns that); it only
aggregates, so ``benchmarks.run`` stays fast.
"""
from __future__ import annotations

import json
from pathlib import Path

from .common import ARTIFACTS, Budget, Table


def load_records(d: Path | None = None) -> list[dict]:
    """Prefer the final-parser single-pod sweep; fall back per-cell to the
    both-mesh sweep (see EXPERIMENTS.md §Methodology on parser versions)."""
    if d is not None:
        dirs = [d]
    else:
        dirs = [ARTIFACTS / "dryrun_final", ARTIFACTS / "dryrun"]
    seen = {}
    for dd in dirs:
        if not dd.exists():
            continue
        for p in sorted(dd.glob("*.json")):
            try:
                r = json.loads(p.read_text())
            except json.JSONDecodeError:
                continue
            key = (r.get("arch"), r.get("shape"), r.get("multi_pod"),
                   r.get("tag"))
            if key not in seen:
                seen[key] = r
    return list(seen.values())


def run(budget: Budget) -> Table:
    recs = load_records()
    t = Table("Roofline — per (arch x shape x mesh), from compiled dry-run",
              ["arch", "shape", "mesh", "compute_s", "memory_s",
               "collective_s", "bottleneck", "useful_flops", "peak GiB"],
              fmt={"compute_s": ".3e", "memory_s": ".3e",
                   "collective_s": ".3e", "useful_flops": ".2f",
                   "peak GiB": ".1f"})
    if not recs:
        print("\n[roofline] no dry-run artifacts found — run "
              "PYTHONPATH=src python -m repro.launch.dryrun --all --sa "
              "--both-meshes first")
        return t
    for r in recs:
        if r.get("tag"):  # perf-iteration variants reported in EXPERIMENTS.md
            continue
        terms = r["roofline"]
        t.add(arch=r["arch"], shape=r["shape"],
              mesh="x".join(str(s) for s in r["mesh"]),
              compute_s=terms["compute_s"], memory_s=terms["memory_s"],
              collective_s=terms["collective_s"],
              bottleneck=r["bottleneck"],
              useful_flops=r.get("useful_flops_frac"),
              **{"peak GiB": r["bytes_per_device"]["peak"] / 2 ** 30})
    t.show()
    doms = {}
    for r in recs:
        if not r.get("tag"):
            doms[r["bottleneck"]] = doms.get(r["bottleneck"], 0) + 1
    print(f"[roofline] bottleneck census: {doms} over {len(t.rows)} cells")
    t.save("roofline")
    return t


if __name__ == "__main__":
    run(Budget(quick=True))
