"""Open-loop latency benchmark for the SA serving engine.

Streams the synthetic heterogeneous mix through the engine on a seeded
Poisson timeline and sweeps the offered load, reporting per rate:

  p50/p99 queueing delay (arrival -> admission, ticks),
  p50/p99 time-to-first-tick (arrival -> first temperature level done),
  p50/p99 end-to-end latency, goodput (completed requests/tick) and slot
  occupancy.

The tick clock makes the whole table deterministic for fixed seeds — the
classic open-loop serving curve (latency vs offered load) without wall-
clock noise.  Wall-clock medians are printed alongside for scale.

  PYTHONPATH=src python benchmarks/serve_sa_latency.py \
      --rates 0.2,0.5,1.0 --requests 24 --slots 4 --chains-per-slot 16

``--overload`` switches to the admission-control comparison: every
overload policy (none/reject/degrade/preempt) serves the *same* seeded
Poisson stream at ``--overload-factor`` x the pool's saturating load, and
goodput / p99 queueing delay / rejections / preemptions / final backlog
per policy are printed and written to ``--out``
(artifacts/bench/BENCH_serve_overload.json) — a deterministic perf
trajectory for future PRs.

  PYTHONPATH=src python benchmarks/serve_sa_latency.py --overload \
      --requests 120 --slots 5 --chains-per-slot 8 --max-ticks 400

``--scale-devices 1,2,4`` serves the *same* seeded stream once per shard
count (``--slots`` slots per shard on the 1-D ``(pool,)`` mesh) at a fixed
``--rate`` and reports the goodput / p99 gain sharding buys — the
multi-device acceptance check; the table also lands in
``artifacts/bench/BENCH_serve_scale.json`` (CI uploads it).  Run under
``XLA_FLAGS=--xla_force_host_platform_device_count=4`` for real host
devices; logical shards otherwise.

  PYTHONPATH=src python benchmarks/serve_sa_latency.py \
      --scale-devices 1,2,4 --rate 1.0 --requests 48 --slots 2 \
      --chains-per-slot 8 --max-ticks 120

``--drain`` is the elastic-fleet acceptance mode: the same seeded Poisson
stream (at ``--drain-load-factor`` x the N-shard saturating load) is
served twice — once on a static N-shard fleet, once draining one shard at
``--drain-tick`` (N -> N-1 mid-stream: no new placements, jobs
checkpoint-evacuate onto the survivors, the shard retires once empty).
The drain run must complete with **zero lost requests** (exit 1
otherwise) and the comparison reports how far the drain pushed p99
queueing delay; everything lands in
``artifacts/bench/BENCH_serve_drain.json``.

  PYTHONPATH=src python benchmarks/serve_sa_latency.py --drain \
      --devices 4 --slots 2 --chains-per-slot 8 --requests 48 \
      --drain-tick 12

``--wall`` is the host-tick-bottleneck bench (ROADMAP item 1): the same
seeded stream is served once per ``--wall-devices`` shard count and
**wall-clock** req/s (not req/tick) is reported, with the per-phase tick
breakdown (``schedule / admit / dispatch / device_wait / materialize /
retire``, telemetry.py) from a bit-exact instrumented re-run attached —
so "more shards, more per-tick goodput, worse wall-clock" decomposes
into *which phase* eats the time.  Lands in
``artifacts/bench/BENCH_serve_wall.json``.

  XLA_FLAGS=--xla_force_host_platform_device_count=4 PYTHONPATH=src \
      python benchmarks/serve_sa_latency.py --wall --wall-devices 1,2,4 \
      --requests 24 --slots 2 --chains-per-slot 8 --max-ticks 120
"""
from __future__ import annotations

import argparse
import dataclasses
from pathlib import Path

try:
    from .common import Table, write_bench
except ImportError:  # run as a plain script: python benchmarks/serve_sa_latency.py
    import sys
    sys.path.insert(0, str(Path(__file__).resolve().parent))
    from common import Table, write_bench

from repro.service.arrivals import ArrivalProcess, latency_summary
from repro.service.engine import EngineConfig, SAServeEngine
from repro.service.scheduler import SchedulerConfig
from repro.service.serve_sa import _jsonable, make_mix
from repro.service.telemetry import TICK_PHASES, Telemetry

#: Default artifact paths (repo-relative), one per benchmark mode.
_BENCH_DIR = Path(__file__).resolve().parents[1] / "artifacts" / "bench"
DEFAULT_OVERLOAD_OUT = _BENCH_DIR / "BENCH_serve_overload.json"
DEFAULT_DRAIN_OUT = _BENCH_DIR / "BENCH_serve_drain.json"
DEFAULT_SCALE_OUT = _BENCH_DIR / "BENCH_serve_scale.json"
DEFAULT_WALL_OUT = _BENCH_DIR / "BENCH_serve_wall.json"


def bench_rate(rate: float, n_requests: int, n_slots: int,
               chains_per_slot: int, variant: str, seed: int,
               arrival_seed: int, max_ticks: int,
               n_devices: int = 1, macro_k: int = 1,
               method: str = "sa", family: str = "continuous") -> dict:
    cfg = EngineConfig(n_slots=n_slots, chains_per_slot=chains_per_slot,
                       n_devices=n_devices, variant=variant,
                       macro_k=macro_k,
                       scheduler=SchedulerConfig(policy="priority"))
    engine = SAServeEngine(cfg)
    reqs = make_mix(n_requests, chains_per_slot, seed=seed,
                    max_slots_per_req=min(2, n_slots),
                    method=method, family=family)
    arrivals = ArrivalProcess.poisson(reqs, rate=rate, seed=arrival_seed)
    engine.run_stream(arrivals, max_ticks=max_ticks)
    stats = engine.stats()
    row = latency_summary(engine.results, ticks=engine.tick_count,
                          n_submitted=engine.n_submitted)
    row.update(rate=rate, devices=n_devices, ticks=engine.tick_count,
               migrations=stats["migrations"],
               occupancy=stats["occupancy"], wall_s=stats["wall_s"])
    return row


def saturating_rate(reqs, n_slots: int, chains_per_slot: int) -> float:
    """Offered load (req/tick) that exactly fills the pool on average.

    A request holding ``w`` slots for its full ladder of ``L`` levels costs
    ``w * L`` slot-ticks, so capacity = n_slots / E[w * L].  Early stops
    (target/budget) only lower the true cost, making this a conservative
    saturation estimate.
    """
    cost = [r.slots_needed(chains_per_slot) * r.n_levels for r in reqs]
    return n_slots / (sum(cost) / len(cost))


def bench_overload(args) -> dict:
    """Same seeded overload stream through every overload policy."""
    reqs = make_mix(args.requests, args.chains_per_slot, seed=args.seed,
                    max_slots_per_req=min(2, args.slots),
                    method=args.method, family=args.family)
    # Capacity scales with the sharded pool: n_slots per shard x devices.
    rate = args.overload_factor * saturating_rate(
        reqs, args.slots * args.devices, args.chains_per_slot)
    policies = {}
    for policy in ("none", "reject", "degrade", "preempt"):
        cfg = EngineConfig(
            n_slots=args.slots, chains_per_slot=args.chains_per_slot,
            n_devices=args.devices, variant=args.variant,
            scheduler=SchedulerConfig(
                policy="priority", overload=policy,
                default_deadline=args.deadline,
                preemption_budget=args.preemption_budget))
        engine = SAServeEngine(cfg)
        engine.run_stream(
            ArrivalProcess.poisson(reqs, rate=rate, seed=args.arrival_seed),
            max_ticks=args.max_ticks)
        stats = engine.stats()
        lat = latency_summary(engine.results, ticks=engine.tick_count,
                              n_submitted=engine.n_submitted)
        policies[policy] = {
            "completed": lat["completed"],
            "rejected": lat["rejected"],
            "incomplete": lat["incomplete"],
            "preemptions": stats["preemptions"],
            "migrations": stats["migrations"],
            "degraded": sum(r.degraded for r in engine.results),
            "backlog": len(engine.scheduler),      # unbounded growth witness
            "goodput_req_per_tick": lat["goodput_req_per_tick"],
            "queue_delay_p50": lat["queue_delay_p50"],
            "queue_delay_p99": lat["queue_delay_p99"],
            "latency_p99": lat["latency_p99"],
            "occupancy": stats["occupancy"],
            "wall_s": stats["wall_s"],             # non-deterministic; scale only
        }
    return {
        "config": {
            "requests": args.requests, "slots": args.slots,
            "chains_per_slot": args.chains_per_slot,
            "devices": args.devices,
            "variant": args.variant, "seed": args.seed,
            "method": args.method, "family": args.family,
            "arrival_seed": args.arrival_seed,
            "overload_factor": args.overload_factor,
            "rate_req_per_tick": rate, "deadline": args.deadline,
            "preemption_budget": args.preemption_budget,
            "max_ticks": args.max_ticks,
        },
        "policies": policies,
    }


def run_overload(args):
    doc = bench_overload(args)
    cols = ["policy", "completed", "rejected", "incomplete", "degraded",
            "preemptions", "backlog", "goodput_req_per_tick",
            "queue_delay_p50", "queue_delay_p99", "occupancy"]
    table = Table(
        f"SA serving engine: overload policies at "
        f"{args.overload_factor:g}x saturating load "
        f"({doc['config']['rate_req_per_tick']:.3f} req/tick, deadline "
        f"{args.deadline:g} ticks, seeded Poisson)",
        cols,
        fmt={"goodput_req_per_tick": ".3f", "queue_delay_p50": ".1f",
             "queue_delay_p99": ".1f", "occupancy": ".1%"})
    for policy, row in doc["policies"].items():
        table.add(policy=policy, **{k: row[k] for k in cols[1:]})
    table.show()
    out = write_bench(Path(args.out) if args.out else DEFAULT_OVERLOAD_OUT,
                      _jsonable(doc), seed=args.seed,
                      arrival_seed=args.arrival_seed)
    print(f"\nwrote {out}")
    base = doc["policies"]["none"]
    for policy in ("reject", "degrade"):
        bounded = (doc["policies"][policy]["queue_delay_p99"]
                   <= args.deadline + 1)
        print(f"{policy:>8}: p99 queue delay "
              f"{doc['policies'][policy]['queue_delay_p99']:.1f}t "
              f"({'bounded by deadline' if bounded else 'NOT bounded'}) vs "
              f"baseline {base['queue_delay_p99']:.1f}t, backlog "
              f"{doc['policies'][policy]['backlog']} vs {base['backlog']}")
    return doc


def bench_drain(args) -> dict:
    """Same seeded stream, static fleet vs mid-stream N -> N-1 drain."""
    if args.devices < 2:
        raise SystemExit("--drain needs --devices >= 2")
    reqs = make_mix(args.requests, args.chains_per_slot, seed=args.seed,
                    max_slots_per_req=min(2, args.slots),
                    method=args.method, family=args.family)
    rate = args.drain_load_factor * saturating_rate(
        reqs, args.slots * args.devices, args.chains_per_slot)

    def serve(drain_tick):
        cfg = EngineConfig(
            n_slots=args.slots, chains_per_slot=args.chains_per_slot,
            n_devices=args.devices, variant=args.variant,
            migration_budget=args.migration_budget,
            scheduler=SchedulerConfig(policy="priority"))
        engine = SAServeEngine(cfg)
        if drain_tick is not None:
            engine.schedule_op(
                drain_tick,
                lambda: engine.drain(
                    max(s.index for s in engine.live_shards)))
        engine.run_stream(
            ArrivalProcess.poisson(
                [dataclasses.replace(r) for r in reqs],
                rate=rate, seed=args.arrival_seed),
            max_ticks=args.max_ticks)
        stats = engine.stats()
        lat = latency_summary(engine.results, ticks=engine.tick_count,
                              n_submitted=engine.n_submitted)
        lost = engine.n_submitted - len(engine.results)
        return {
            "submitted": engine.n_submitted,
            "completed": lat["completed"],
            "rejected": lat["rejected"],
            "incomplete": lat["incomplete"],
            "lost": lost,                          # must be 0: no request may
                                                   # vanish across retirement
            "migrations": stats["migrations"],
            "preemptions": stats["preemptions"],
            "shrinks": stats["shrinks"],
            "devices_final": stats["devices"],
            "shards_retired": stats["shards_retired"],
            "drain_completed_tick": (engine.retired_shards[0][1]
                                     if engine.retired_shards else None),
            "ticks": engine.tick_count,
            "queue_delay_p50": lat["queue_delay_p50"],
            "queue_delay_p99": lat["queue_delay_p99"],
            "latency_p99": lat["latency_p99"],
            "goodput_req_per_tick": lat["goodput_req_per_tick"],
            "occupancy": stats["occupancy"],
            "wall_s": stats["wall_s"],             # non-deterministic; scale
        }

    baseline = serve(None)
    drained = serve(args.drain_tick)
    # "Bounded": the drain run's p99 queueing delay stays within the lost
    # shard's capacity share plus slack — shrinking the fleet by 1/N may
    # slow admission proportionally, but must not let the queue diverge.
    bound = (baseline["queue_delay_p99"]
             * args.devices / (args.devices - 1) + args.drain_slack)
    return {
        "config": {
            "requests": args.requests, "slots": args.slots,
            "chains_per_slot": args.chains_per_slot,
            "devices": args.devices, "variant": args.variant,
            "method": args.method, "family": args.family,
            "migration_budget": args.migration_budget,
            "seed": args.seed, "arrival_seed": args.arrival_seed,
            "drain_tick": args.drain_tick,
            "drain_load_factor": args.drain_load_factor,
            "drain_slack": args.drain_slack,
            "rate_req_per_tick": rate, "max_ticks": args.max_ticks,
        },
        "baseline": baseline,
        "drain": drained,
        "zero_lost": drained["lost"] == 0 and drained["rejected"] == 0
        and drained["incomplete"] == 0,
        "p99_bound_ticks": bound,
        "p99_bounded": drained["queue_delay_p99"] <= bound,
    }


def run_drain(args):
    doc = bench_drain(args)
    cols = ["run", "completed", "lost", "migrations", "preemptions",
            "shrinks", "devices_final", "drain_completed_tick", "ticks",
            "queue_delay_p50", "queue_delay_p99", "goodput_req_per_tick",
            "occupancy"]
    table = Table(
        f"SA serving engine: {args.devices} -> {args.devices - 1} shard "
        f"drain under load (tick {args.drain_tick}, "
        f"{doc['config']['rate_req_per_tick']:.3f} req/tick, seeded "
        f"Poisson)",
        cols,
        fmt={"queue_delay_p50": ".1f", "queue_delay_p99": ".1f",
             "goodput_req_per_tick": ".3f", "occupancy": ".1%"})
    for name in ("baseline", "drain"):
        table.add(run=name, **{k: doc[name][k] for k in cols[1:]})
    table.show()
    out = write_bench(Path(args.out) if args.out else DEFAULT_DRAIN_OUT,
                      _jsonable(doc), seed=args.seed,
                      arrival_seed=args.arrival_seed)
    print(f"\nwrote {out}")
    d = doc["drain"]
    print(f"drain: {d['completed']}/{d['submitted']} completed, "
          f"{d['lost']} lost, shard retired at tick "
          f"{d['drain_completed_tick']}, p99 queue delay "
          f"{d['queue_delay_p99']:.1f}t vs baseline "
          f"{doc['baseline']['queue_delay_p99']:.1f}t "
          f"(bound {doc['p99_bound_ticks']:.1f}t: "
          f"{'bounded' if doc['p99_bounded'] else 'NOT bounded'})")
    if not doc["zero_lost"]:
        raise SystemExit(
            f"drain lost work: lost={d['lost']} rejected={d['rejected']} "
            f"incomplete={d['incomplete']}")
    return doc


def run_scale_devices(args):
    """Goodput scaling: the same seeded stream over 1..N-shard pools.

    Each device count serves the identical (mix seed, arrival seed)
    Poisson stream with ``--slots`` slots *per shard*, so the comparison
    isolates what sharding buys: more shards admit the backlog sooner,
    queueing delay collapses and goodput rises until the offered load is
    no longer saturating.  Deterministic on the tick clock.
    """
    counts = [int(c) for c in args.scale_devices.split(",")]
    table = Table(
        f"SA serving engine: goodput vs slot-pool shards "
        f"(same seeded stream @ {args.rate:g} req/tick, "
        f"{args.slots} slots/shard)",
        ["devices", "completed", "incomplete", "ticks", "queue_delay_p99",
         "latency_p99", "goodput_req_per_tick", "migrations", "occupancy",
         "wall_s"],
        fmt={"queue_delay_p99": ".1f", "latency_p99": ".1f",
             "goodput_req_per_tick": ".3f", "occupancy": ".1%",
             "wall_s": ".2f"})
    rows = []
    for n in counts:
        row = bench_rate(args.rate, args.requests, args.slots,
                         args.chains_per_slot, args.variant, args.seed,
                         args.arrival_seed, args.max_ticks, n_devices=n,
                         macro_k=args.macro_k, method=args.method,
                         family=args.family)
        rows.append(row)
        table.add(**{k: row[k] for k in table.columns})
    table.show()
    if len(rows) > 1:
        lo, hi = rows[0], rows[-1]
        gain = (hi["goodput_req_per_tick"] / lo["goodput_req_per_tick"]
                if lo["goodput_req_per_tick"] else float("inf"))
        print(f"\n{counts[-1]} shards vs {counts[0]}: goodput x{gain:.2f} "
              f"({lo['goodput_req_per_tick']:.3f} -> "
              f"{hi['goodput_req_per_tick']:.3f} req/tick), p99 queue delay "
              f"{lo['queue_delay_p99']:.1f}t -> {hi['queue_delay_p99']:.1f}t "
              f"on the same seeded stream")
    doc = {
        "config": {
            "requests": args.requests, "slots": args.slots,
            "chains_per_slot": args.chains_per_slot,
            "variant": args.variant, "seed": args.seed,
            "method": args.method, "family": args.family,
            "arrival_seed": args.arrival_seed, "rate": args.rate,
            "scale_devices": counts, "max_ticks": args.max_ticks,
        },
        "rows": rows,
    }
    out = write_bench(Path(args.out) if args.out else DEFAULT_SCALE_OUT,
                      _jsonable(doc), seed=args.seed,
                      arrival_seed=args.arrival_seed)
    print(f"wrote {out}")
    return rows


def bench_wall_point(n_devices: int, args) -> dict:
    """One wall-clock point: the same seeded stream on an n-shard fleet.

    Three runs per point: a *warmup* run (untimed headline-wise; it pays
    every XLA compile the stream will trigger, reported as
    ``warmup_wall_s``), then a *plain* run (telemetry off — the headline
    req/s, now steady-state serving throughput rather than compile
    time), then an *instrumented* run (telemetry on) whose per-phase
    breakdown attributes the tick's wall time.  All three serve the
    identical stream, and the instrumented run is bit-exact with the
    plain one (the engine's telemetry guarantee) — only wall timings
    differ.  The warmup matters: fused macro-tick programs compile
    slower but launch far fewer times, so a cold run measures the
    compiler, not the server.
    """

    def serve(telemetry):
        cfg = EngineConfig(
            n_slots=args.slots, chains_per_slot=args.chains_per_slot,
            n_devices=n_devices, variant=args.variant,
            macro_k=args.macro_k,
            scheduler=SchedulerConfig(policy="priority"))
        engine = SAServeEngine(cfg, telemetry=telemetry)
        reqs = make_mix(args.requests, args.chains_per_slot, seed=args.seed,
                        max_slots_per_req=min(2, args.slots),
                        method=args.method, family=args.family)
        engine.run_stream(
            ArrivalProcess.poisson(reqs, rate=args.rate,
                                   seed=args.arrival_seed),
            max_ticks=args.max_ticks)
        return engine

    warm = serve(None)                  # jit-cache warmup (compiles)
    plain = serve(None)
    tel = Telemetry()
    timed = serve(tel)
    stats = plain.stats()
    tstats = timed.stats()
    phase_hist = tel.m_tick_phase
    phases = {}
    for phase in TICK_PHASES:
        s = phase_hist.summary(phase)
        if s["count"]:
            phases[phase] = {
                "total_s": s["sum"], "mean_s": s["mean"],
                "p50_s": s["p50"], "p90_s": s["p90"], "p99_s": s["p99"],
                "count": s["count"],
            }
    timed_total = sum(p["total_s"] for p in phases.values())
    # Host-thread CPU seconds per phase (the PhaseTimer's second clock):
    # wall spans absorb whatever the OS timesliced in — on hosts where
    # device compute shares cores with the engine loop (CPU backend,
    # small CI runners) that inflates `dispatch` with compute time.
    # thread-CPU counts only cycles the engine loop itself burned, so
    # cpu_share = cpu_s / instrumented wall is the durable "how much of
    # the run is the host busy doing phase p" signal across machines.
    cpu_s = {p: v for (p,), v in tel.m_phase_cpu.series.items()}
    t_wall = tstats["wall_s"] or 1.0
    return {
        "devices": n_devices,
        "completed": stats["completed"],
        "ticks": stats["ticks"],
        "wall_s": stats["wall_s"],
        "requests_per_s": stats["requests_per_s"],
        "sweeps_per_s": stats["sweeps_per_s"],
        "chain_steps_per_s": stats["chain_steps_per_s"],
        "goodput_req_per_tick": (stats["completed"] / stats["ticks"]
                                 if stats["ticks"] else 0.0),
        "tick_wall_ms": (1e3 * stats["wall_s"] / stats["ticks"]
                         if stats["ticks"] else 0.0),
        "phases": phases,                     # from the instrumented run
        "phase_share": {p: v["total_s"] / timed_total
                        for p, v in phases.items()} if timed_total else {},
        "phase_cpu_seconds": cpu_s,
        "phase_cpu_share": {p: v / t_wall for p, v in cpu_s.items()},
        "instrumented_wall_s": tstats["wall_s"],
        "warmup_wall_s": warm.stats()["wall_s"],   # includes XLA compiles
        "per_shard_phase_seconds": tstats["phases"].get("per_shard", {}),
        "group_launches": stats["group_launches"],
    }


def run_wall(args):
    """The ROADMAP-item-1 bench: wall-clock req/s vs shard count, with the
    per-phase tick breakdown that localizes the host-tick bottleneck."""
    counts = [int(c) for c in args.wall_devices.split(",")]
    table = Table(
        f"SA serving engine: wall-clock goodput vs shards "
        f"(same seeded stream @ {args.rate:g} req/tick, "
        f"{args.slots} slots/shard; phase shares from an instrumented "
        "re-run)",
        ["devices", "completed", "ticks", "wall_s", "requests_per_s",
         "tick_wall_ms", "schedule%", "dispatch%", "device_wait%",
         "materialize%", "other%", "dispatch_cpu%"],
        fmt={"wall_s": ".2f", "requests_per_s": ".2f", "tick_wall_ms": ".2f",
             "schedule%": ".1%", "dispatch%": ".1%", "device_wait%": ".1%",
             "materialize%": ".1%", "other%": ".1%", "dispatch_cpu%": ".1%"})
    rows = []
    for n in counts:
        row = bench_wall_point(n, args)
        rows.append(row)
        share = row["phase_share"]
        main_phases = ("schedule", "dispatch", "device_wait", "materialize")
        table.add(**{k: row[k] for k in table.columns if "%" not in k},
                  **{f"{p}%": share.get(p, 0.0) for p in main_phases},
                  **{"other%": sum(v for p, v in share.items()
                                   if p not in main_phases)},
                  **{"dispatch_cpu%":
                     row["phase_cpu_share"].get("dispatch", 0.0)})
    table.show()
    if len(rows) > 1:
        lo, hi = rows[0], rows[-1]
        print(f"\n{hi['devices']} shards vs {lo['devices']}: "
              f"{lo['requests_per_s']:.2f} -> {hi['requests_per_s']:.2f} "
              f"req/s wall-clock; dominant phase at {hi['devices']} shards: "
              + max(rows[-1]["phase_share"],
                    key=rows[-1]["phase_share"].get, default="n/a"))
    doc = {
        "config": {
            "requests": args.requests, "slots": args.slots,
            "chains_per_slot": args.chains_per_slot,
            "variant": args.variant, "seed": args.seed,
            "method": args.method, "family": args.family,
            "arrival_seed": args.arrival_seed, "rate": args.rate,
            "wall_devices": counts, "max_ticks": args.max_ticks,
            "macro_k": args.macro_k,
        },
        "note": ("requests_per_s/wall_s are from the telemetry-off run "
                 "after an untimed warmup run paid every XLA compile "
                 "(warmup_wall_s) — steady-state serving throughput; "
                 "phases/phase_share from a bit-exact instrumented re-run "
                 "(block_until_ready fencing separates dispatch from "
                 "device_wait). Wall *spans* absorb whatever the OS "
                 "timesliced into them — on hosts where device compute "
                 "shares cores with the engine loop (CPU backend) they "
                 "overstate dispatch; phase_cpu_share (host thread-CPU "
                 "seconds / instrumented wall) is the machine-durable "
                 "host-cost signal and the one the regression gate uses."),
        "rows": rows,
    }
    out = write_bench(Path(args.out) if args.out else DEFAULT_WALL_OUT,
                      _jsonable(doc), seed=args.seed,
                      arrival_seed=args.arrival_seed)
    print(f"wrote {out}")
    return rows


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--rates", default="0.2,0.5,1.0",
                    help="comma-separated offered loads, requests/tick")
    ap.add_argument("--requests", type=int, default=24,
                    help="requests per rate point")
    ap.add_argument("--slots", type=int, default=4,
                    help="slots per shard")
    ap.add_argument("--chains-per-slot", type=int, default=16)
    ap.add_argument("--devices", type=int, default=1,
                    help="engine shards on the (pool,) mesh; CPU-testable "
                         "via XLA_FLAGS=--xla_force_host_platform_"
                         "device_count=N")
    ap.add_argument("--scale-devices", default=None,
                    help="comma-separated device counts (e.g. 1,2,4): "
                         "serve the SAME seeded stream once per count at "
                         "a fixed --rate and report goodput scaling")
    ap.add_argument("--rate", type=float, default=1.0,
                    help="offered load for --scale-devices, requests/tick")
    ap.add_argument("--variant", default="delta", choices=["delta", "full"])
    ap.add_argument("--method", default="sa",
                    choices=["sa", "pt", "pa", "mixed"],
                    help="workload class of the synthetic mix (plain SA, "
                         "parallel tempering, population annealing, or a "
                         "deterministic sa/pt/pa rotation) — every bench "
                         "mode streams the class through the same engine")
    ap.add_argument("--family", default="continuous",
                    choices=["continuous", "qap", "mixed"],
                    help="problem family of the mix: continuous registry "
                         "objectives (float32 states), QAP permutations "
                         "(int32 states; --method must stay sa), or both "
                         "alternating in one pool")
    ap.add_argument("--seed", type=int, default=0,
                    help="request-mix seed")
    ap.add_argument("--arrival-seed", type=int, default=0,
                    help="Poisson timeline seed")
    ap.add_argument("--max-ticks", type=int, default=5000,
                    help="safety tick budget per rate point")
    ap.add_argument("--overload", action="store_true",
                    help="compare overload policies at --overload-factor x "
                         "saturating load and write --out")
    ap.add_argument("--overload-factor", type=float, default=3.0,
                    help="offered load as a multiple of saturating load")
    ap.add_argument("--deadline", type=float, default=25.0,
                    help="queueing-delay SLO (ticks) for reject/degrade")
    ap.add_argument("--preemption-budget", type=int, default=1)
    ap.add_argument("--migration-budget", type=int, default=2,
                    help="cross-shard moves per tick (drain evacuation, "
                         "defrag and rebalancing share it)")
    ap.add_argument("--wall", action="store_true",
                    help="wall-clock goodput bench: req/s (not req/tick) "
                         "vs shard count with the per-phase tick "
                         "breakdown; writes BENCH_serve_wall.json")
    ap.add_argument("--wall-devices", default="1,2,4",
                    help="comma-separated shard counts for --wall")
    ap.add_argument("--macro-k", type=int, default=1,
                    help="temperature levels fused per device dispatch "
                         "(engine macro_k; amortizes the host launch cost "
                         "the --wall bench measures)")
    ap.add_argument("--drain", action="store_true",
                    help="elastic-fleet acceptance: drain one of "
                         "--devices shards at --drain-tick under load; "
                         "exit 1 if any request is lost")
    ap.add_argument("--drain-tick", type=int, default=12,
                    help="tick at which the drain begins")
    ap.add_argument("--drain-load-factor", type=float, default=0.6,
                    help="offered load as a multiple of the full fleet's "
                         "saturating load — sized so the N-1 survivors "
                         "stay under saturation (0.6 x N/(N-1) = 0.8 at "
                         "N=4), else the post-drain queue diverges by "
                         "construction")
    ap.add_argument("--drain-slack", type=float, default=20.0,
                    help="extra p99 queue-delay ticks tolerated beyond "
                         "the capacity-proportional bound (the transient "
                         "of one shard's worth of evacuated work "
                         "re-queueing)")
    ap.add_argument("--out", default=None,
                    help="JSON artifact path (default: per-mode file "
                         "under artifacts/bench/)")
    args = ap.parse_args(argv)
    if args.family == "qap" and args.method != "sa":
        ap.error("--family qap serves plain SA only; drop --method "
                 + args.method)

    if args.overload:
        return run_overload(args)

    if args.wall:
        return run_wall(args)

    if args.drain:
        return run_drain(args)

    if args.scale_devices:
        return run_scale_devices(args)

    table = Table(
        "SA serving engine: open-loop latency vs offered load "
        "(seeded Poisson arrivals)",
        ["rate", "completed", "ticks", "queue_delay_p50", "queue_delay_p99",
         "ttft_p50", "ttft_p99", "latency_p50", "latency_p99",
         "goodput_req_per_tick", "occupancy", "wall_s"],
        fmt={"rate": ".2f", "queue_delay_p50": ".1f",
             "queue_delay_p99": ".1f", "ttft_p50": ".1f", "ttft_p99": ".1f",
             "latency_p50": ".1f", "latency_p99": ".1f",
             "goodput_req_per_tick": ".3f", "occupancy": ".1%",
             "wall_s": ".2f"})
    rows = []
    for rate in [float(r) for r in args.rates.split(",")]:
        row = bench_rate(rate, args.requests, args.slots,
                         args.chains_per_slot, args.variant, args.seed,
                         args.arrival_seed, args.max_ticks,
                         n_devices=args.devices, macro_k=args.macro_k,
                         method=args.method, family=args.family)
        rows.append(row)
        table.add(**{k: row[k] for k in table.columns})
    table.show()
    done = all(r["completed"] == args.requests for r in rows)
    print(f"\n{'PASS' if done else 'INCOMPLETE'}: "
          f"{sum(r['completed'] for r in rows)}/"
          f"{args.requests * len(rows)} requests completed across "
          f"{len(rows)} rate points (deterministic for fixed "
          f"--seed/--arrival-seed)")
    return rows


if __name__ == "__main__":
    main()
