"""Open-loop latency benchmark for the SA serving engine.

Streams the synthetic heterogeneous mix through the engine on a seeded
Poisson timeline and sweeps the offered load, reporting per rate:

  p50/p99 queueing delay (arrival -> admission, ticks),
  p50/p99 time-to-first-tick (arrival -> first temperature level done),
  p50/p99 end-to-end latency, goodput (completed requests/tick) and slot
  occupancy.

The tick clock makes the whole table deterministic for fixed seeds — the
classic open-loop serving curve (latency vs offered load) without wall-
clock noise.  Wall-clock medians are printed alongside for scale.

  PYTHONPATH=src python benchmarks/serve_sa_latency.py \
      --rates 0.2,0.5,1.0 --requests 24 --slots 4 --chains-per-slot 16
"""
from __future__ import annotations

import argparse

try:
    from .common import Table
except ImportError:  # run as a plain script: python benchmarks/serve_sa_latency.py
    import sys
    from pathlib import Path
    sys.path.insert(0, str(Path(__file__).resolve().parent))
    from common import Table

from repro.service.arrivals import ArrivalProcess, latency_summary
from repro.service.engine import EngineConfig, SAServeEngine
from repro.service.scheduler import SchedulerConfig
from repro.service.serve_sa import make_mix


def bench_rate(rate: float, n_requests: int, n_slots: int,
               chains_per_slot: int, variant: str, seed: int,
               arrival_seed: int, max_ticks: int) -> dict:
    cfg = EngineConfig(n_slots=n_slots, chains_per_slot=chains_per_slot,
                       variant=variant,
                       scheduler=SchedulerConfig(policy="priority"))
    engine = SAServeEngine(cfg)
    reqs = make_mix(n_requests, chains_per_slot, seed=seed,
                    max_slots_per_req=min(2, n_slots))
    arrivals = ArrivalProcess.poisson(reqs, rate=rate, seed=arrival_seed)
    engine.run_stream(arrivals, max_ticks=max_ticks)
    stats = engine.stats()
    row = latency_summary(engine.results, ticks=engine.tick_count)
    row.update(rate=rate, ticks=engine.tick_count,
               occupancy=stats["occupancy"], wall_s=stats["wall_s"])
    return row


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--rates", default="0.2,0.5,1.0",
                    help="comma-separated offered loads, requests/tick")
    ap.add_argument("--requests", type=int, default=24,
                    help="requests per rate point")
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--chains-per-slot", type=int, default=16)
    ap.add_argument("--variant", default="delta", choices=["delta", "full"])
    ap.add_argument("--seed", type=int, default=0,
                    help="request-mix seed")
    ap.add_argument("--arrival-seed", type=int, default=0,
                    help="Poisson timeline seed")
    ap.add_argument("--max-ticks", type=int, default=5000,
                    help="safety tick budget per rate point")
    args = ap.parse_args(argv)

    table = Table(
        "SA serving engine: open-loop latency vs offered load "
        "(seeded Poisson arrivals)",
        ["rate", "completed", "ticks", "queue_delay_p50", "queue_delay_p99",
         "ttft_p50", "ttft_p99", "latency_p50", "latency_p99",
         "goodput_req_per_tick", "occupancy", "wall_s"],
        fmt={"rate": ".2f", "queue_delay_p50": ".1f",
             "queue_delay_p99": ".1f", "ttft_p50": ".1f", "ttft_p99": ".1f",
             "latency_p50": ".1f", "latency_p99": ".1f",
             "goodput_req_per_tick": ".3f", "occupancy": ".1%",
             "wall_s": ".2f"})
    rows = []
    for rate in [float(r) for r in args.rates.split(",")]:
        row = bench_rate(rate, args.requests, args.slots,
                         args.chains_per_slot, args.variant, args.seed,
                         args.arrival_seed, args.max_ticks)
        rows.append(row)
        table.add(**{k: row[k] for k in table.columns})
    table.show()
    done = all(r["completed"] == args.requests for r in rows)
    print(f"\n{'PASS' if done else 'INCOMPLETE'}: "
          f"{sum(r['completed'] for r in rows)}/"
          f"{args.requests * len(rows)} requests completed across "
          f"{len(rows)} rate points (deterministic for fixed "
          f"--seed/--arrival-seed)")
    return rows


if __name__ == "__main__":
    main()
