"""Benchmark harness: one module per paper table + kernel + roofline.

  PYTHONPATH=src python -m benchmarks.run            # quick (CPU, ~5-10 min)
  PYTHONPATH=src python -m benchmarks.run --full     # paper-scale budgets
  PYTHONPATH=src python -m benchmarks.run --only table1,table9
"""
from __future__ import annotations

import argparse
import time
import traceback

from .common import Budget

REGISTRY = {}


def _reg(name):
    def deco(fn):
        REGISTRY[name] = fn
        return fn
    return deco


@_reg("table1")
def _t1(b):
    from . import table1_accuracy as m
    return m.run(b)


@_reg("table2")
def _t2(b):
    from . import table2_speedup as m
    return m.run(b)


@_reg("table3")
def _t3(b):
    from . import table3_chains_error as m
    return m.run(b)


@_reg("table456")
def _t456(b):
    from . import table456_scaling as m
    return m.run(b)


@_reg("table7")
def _t7(b):
    from . import table7_precision as m
    return m.run(b)


@_reg("table9")
def _t9(b):
    from . import table9_suite as m
    return m.run(b)


@_reg("table10")
def _t10(b):
    from . import table10_hybrid as m
    return m.run(b)


@_reg("kernels")
def _tk(b):
    from . import kernels_bench as m
    return m.run(b)


@_reg("autotune")
def _ta(b):
    from . import autotune_bench as m
    return m.run(b)


@_reg("roofline")
def _tr(b):
    from . import roofline as m
    return m.run(b)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="paper-scale budgets (hours)")
    ap.add_argument("--only", default=None,
                    help="comma-separated subset of: " + ",".join(REGISTRY))
    args = ap.parse_args()
    budget = Budget(quick=not args.full)

    names = (args.only.split(",") if args.only else list(REGISTRY))
    failures = []
    t_start = time.time()
    for name in names:
        print(f"\n{'=' * 70}\n[bench] {name}  ({budget.label})\n{'=' * 70}")
        t0 = time.time()
        try:
            REGISTRY[name](budget)
            print(f"[bench] {name} done in {time.time() - t0:.1f}s")
        except Exception as e:  # noqa: BLE001
            failures.append((name, repr(e)))
            traceback.print_exc()
    print(f"\n[bench] total {time.time() - t_start:.1f}s; "
          f"{len(names) - len(failures)}/{len(names)} benchmarks OK")
    if failures:
        for name, err in failures:
            print(f"  FAIL {name}: {err}")
        raise SystemExit(1)


if __name__ == "__main__":
    main()
