"""Paper Table 10: hybrid SA -> Nelder-Mead vs pure (premature) SA.

Paper rows: F0_g Schwefel-512, F1_d Ackley-400, F8_c Griewank-400,
F13_b Rastrigin-400 — SA stopped early (5.4e7..3.5e8 evals), then NM
polishes to ~1e-12 errors in ~1-2s.  Quick mode uses the mid-size siblings
(dims 32..100) with proportionally reduced SA budgets; the claim asserted
is the paper's: hybrid error orders of magnitude below the premature-SA
error, at small extra cost.
"""
from __future__ import annotations

import time

import jax

from repro.core import SAConfig, hybrid_minimize
from repro.objectives import SUITE

from .common import Budget, Table

_ROWS_QUICK = [("F0_c", dict(T0=50.0, T_min=0.05, rho=0.8, N=40,
                             n_chains=2048)),
               ("F1_a", dict(T0=20.0, T_min=0.05, rho=0.8, N=40,
                             n_chains=2048)),
               ("F8_a", dict(T0=50.0, T_min=0.05, rho=0.8, N=40,
                             n_chains=2048)),
               ("F13_a", dict(T0=20.0, T_min=0.01, rho=0.8, N=60,
                              n_chains=4096))]
_ROWS_FULL = [("F0_g", dict(T0=1000.0, T_min=1.0, rho=0.99, N=33,
                            n_chains=16384)),
              ("F1_d", dict(T0=1000.0, T_min=1.0, rho=0.99, N=50,
                            n_chains=16384)),
              ("F8_c", dict(T0=1000.0, T_min=1.0, rho=0.99, N=55,
                            n_chains=16384)),
              ("F13_b", dict(T0=1000.0, T_min=0.1, rho=0.99, N=100,
                             n_chains=16384))]


def run(budget: Budget) -> Table:
    rows = _ROWS_QUICK if budget.quick else _ROWS_FULL
    t = Table(f"Table 10 — hybrid SA->NM ({budget.label})",
              ["f", "n", "SA |f-f*|", "hybrid |f-f*|", "gain", "SA s",
               "NM s", "NM iters"],
              fmt={"SA |f-f*|": ".3e", "hybrid |f-f*|": ".3e",
                   "gain": ".1e", "SA s": ".1f", "NM s": ".1f"})
    improved = 0
    for ref, over in rows:
        obj = SUITE[ref]()
        cfg = SAConfig(**over, exchange="sync", seed=0, record_history=False)
        t0 = time.time()
        hyb = hybrid_minimize(obj, cfg, key=jax.random.PRNGKey(0),
                              nm_max_iters=30000, nm_fatol=1e-14,
                              nm_xatol=1e-14)
        wall = time.time() - t0
        e_sa = abs(hyb.sa.f_best - obj.f_opt)
        e_h = abs(hyb.f_best - obj.f_opt)
        improved += e_h < e_sa
        t.add(f=ref, n=obj.dim, **{"SA |f-f*|": e_sa, "hybrid |f-f*|": e_h,
                                   "gain": e_sa / max(e_h, 1e-300),
                                   "SA s": wall, "NM s": 0.0,
                                   "NM iters": hyb.nm.n_iters})
    t.show()
    print(f"[claim] hybrid improves on premature SA: {improved}/{len(rows)} "
          f"(paper: all, by orders of magnitude)")
    t.save("table10_hybrid")
    return t


if __name__ == "__main__":
    run(Budget(quick=True))
