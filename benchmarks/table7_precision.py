"""Paper Table 7: single vs double precision — time and accuracy.

Paper: fp64 ~2x slower on Fermi, ~100x lower error; fp32 "enough for SA's
purpose".  We reproduce both directions.  x64 is enabled in a subprocess so
the global jax config of the benchmark process is untouched.
"""
from __future__ import annotations

import json
import subprocess
import sys
from pathlib import Path

from .common import Budget, Table

_CHILD = r"""
import json, sys, time
import jax
if sys.argv[1] == "float64":
    jax.config.update("jax_enable_x64", True)
from repro.core import SAConfig, sa_minimize
from repro.objectives import functions as F

dtype = sys.argv[1]
quick = sys.argv[2] == "quick"
obj = F.schwefel(16)
if quick:
    cfg = SAConfig(T0=100.0, T_min=0.05, rho=0.9, N=30, n_chains=1024,
                   dtype=dtype, record_history=False)
else:
    cfg = SAConfig(T0=1000.0, T_min=0.01, rho=0.99, N=100, n_chains=16384,
                   dtype=dtype, record_history=False)
res = sa_minimize(obj, cfg, key=jax.random.PRNGKey(0))  # warm compile
t0 = time.time()
res = sa_minimize(obj, cfg, key=jax.random.PRNGKey(1))
dt = time.time() - t0
df, dx = obj.error_to_opt(res.x_best, res.f_best)
print(json.dumps({"dtype": dtype, "time_s": dt,
                  "f_err": float(df), "x_err": float(dx)}))
"""


def run(budget: Budget) -> Table:
    t = Table(f"Table 7 — fp32 vs fp64 ({budget.label})",
              ["precision", "time_s", "|f-f*|", "rel-x err"],
              fmt={"time_s": ".2f", "|f-f*|": ".3e", "rel-x err": ".3e"})
    rows = {}
    src = Path(__file__).resolve().parent.parent / "src"
    for dtype in ("float32", "float64"):
        out = subprocess.run(
            [sys.executable, "-c", _CHILD, dtype, budget.label],
            capture_output=True, text=True,
            env={"PYTHONPATH": str(src), "PATH": "/usr/bin:/bin"},
            check=True)
        r = json.loads(out.stdout.strip().splitlines()[-1])
        rows[dtype] = r
        t.add(precision=dtype, time_s=r["time_s"], **{"|f-f*|": r["f_err"],
                                                      "rel-x err": r["x_err"]})
    t.show()
    f32, f64 = rows["float32"], rows["float64"]
    print(f"[claim] fp64 slower (paper ~2x on GPU): "
          f"{f64['time_s']/max(f32['time_s'],1e-9):.2f}x; "
          f"fp64 more accurate: "
          f"{'OK' if f64['x_err'] <= f32['x_err'] * 2 else 'NOT SEEN'}")
    t.save("table7_precision")
    return t


if __name__ == "__main__":
    run(Budget(quick=True))
