"""Paper Table 9: the 41-problem benchmark suite, V1 vs V2.

Quick mode runs every problem with a reduced common budget — enough to
reproduce the *structure* of Table 9 (V2 error <= V1 error on nearly every
problem; both solve the easy low-dim problems to ~1e-5).  Full mode uses
per-problem paper-scale budgets (minutes-to-hours).
"""
from __future__ import annotations

import jax
import numpy as np

from repro.core import SAConfig, sa_minimize
from repro.objectives import SUITE

from .common import Budget, Table

# problems whose paper budgets are huge; quick mode trims dims via the
# smaller siblings already in the suite, so we just cap runtime per problem.
_QUICK = dict(T0=50.0, T_min=0.1, rho=0.85, N=25, n_chains=512)
_FULL = dict(T0=1000.0, T_min=0.01, rho=0.99, N=100, n_chains=16384)


def run(budget: Budget) -> Table:
    base = _QUICK if budget.quick else _FULL
    t = Table(f"Table 9 — 41-problem suite ({budget.label})",
              ["f", "name", "n", "V1 |f-f*|", "V2 |f-f*|", "V2<=V1"],
              fmt={"V1 |f-f*|": ".3e", "V2 |f-f*|": ".3e"})
    wins = total = 0
    solved = 0
    for ref, factory in SUITE.items():
        obj = factory()
        errs = {}
        for tag, ex in [("V1", "async"), ("V2", "sync")]:
            cfg = SAConfig(**base, exchange=ex, seed=0, record_history=False)
            res = sa_minimize(obj, cfg, key=jax.random.PRNGKey(0))
            if obj.f_opt is not None:
                errs[tag] = abs(res.f_best - obj.f_opt)
            else:  # unknown optimum (paper marks '-'): record raw f
                errs[tag] = float("nan")
        ok = errs["V2"] <= errs["V1"] * 1.05 + 1e-9 \
            if np.isfinite(errs["V2"]) else None
        if ok is not None:
            total += 1
            wins += bool(ok)
            if errs["V2"] < 1e-2:
                solved += 1
        t.add(f=ref, name=obj.name, n=obj.dim,
              **{"V1 |f-f*|": errs["V1"], "V2 |f-f*|": errs["V2"],
                 "V2<=V1": {True: "y", False: "n", None: "-"}[ok]})
    t.show()
    print(f"[claim] V2 <= V1 on {wins}/{total} problems with known optima "
          f"(paper: all); V2 reaches <1e-2 on {solved}/{total}")
    t.save("table9_suite")
    return t


if __name__ == "__main__":
    run(Budget(quick=True))
