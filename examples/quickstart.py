"""Quickstart: minimize the paper's flagship benchmark (normalized Schwefel)
with the three SA variants — sequential V0, asynchronous V1, synchronous V2.

This is the paper's §4.1 experiment at a CPU-friendly budget.  On a TPU pod
the same call distributes chains over the mesh (pass ``mesh=``).

Run:  PYTHONPATH=src python examples/quickstart.py [--dim 16] [--full]
"""
import argparse
import time

import jax

from repro.core import SAConfig, sa_minimize
from repro.objectives import functions as F


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dim", type=int, default=16)
    ap.add_argument("--full", action="store_true",
                    help="paper-scale config (T0=1000, rho=0.99, N=100, "
                         "16384 chains) — minutes on CPU")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    obj = F.schwefel(args.dim)
    print(f"objective: normalized Schwefel, n={args.dim}, "
          f"f(x*)={obj.f_opt:.6f} at x_i*={obj.x_opt[0]:.6f}")

    if args.full:  # paper §4.1 configuration
        base = dict(T0=1000.0, T_min=0.01, rho=0.99, N=100, n_chains=16384)
    else:          # CPU-friendly: same structure, smaller budget
        base = dict(T0=100.0, T_min=0.05, rho=0.92, N=40, n_chains=2048)

    for name, over in [
        ("V0 sequential (1 chain)", dict(exchange="async", n_chains=1)),
        ("V1 asynchronous", dict(exchange="async")),
        ("V2 synchronous", dict(exchange="sync")),
    ]:
        cfg = SAConfig(**{**base, **over}, seed=args.seed)
        t0 = time.time()
        res = sa_minimize(obj, cfg, key=jax.random.PRNGKey(args.seed))
        dt = time.time() - t0
        err_f = abs(res.f_best - obj.f_opt)
        print(f"{name:28s} f={res.f_best:12.6f}  |f-f*|={err_f:.3e}  "
              f"evals={res.n_evals:.2e}  {dt:6.2f}s")

    print("\nexpected ordering (paper Table 1): V2 error << V1 <= V0")


if __name__ == "__main__":
    main()
