"""Quickstart: serve heterogeneous annealing requests with continuous batching.

Three tenants — different objectives, dimensionalities, cooling schedules
and priorities — share one 4-slot engine.  The scheduler packs them into
chain-block slots, every tick advances all active slots one temperature
level (each at its own temperature), and finished ladders free their slots
immediately for queued work.

  PYTHONPATH=src python examples/serve_sa_quickstart.py
"""
from repro.service import EngineConfig, SARequest, SAServeEngine

engine = SAServeEngine(EngineConfig(n_slots=4, chains_per_slot=32))

engine.submit(SARequest(req_id=0, objective="rastrigin", dim=8, n_chains=64,
                        T0=100.0, T_min=0.5, rho=0.85, N=40, seed=1))
engine.submit(SARequest(req_id=1, objective="ackley", dim=16, n_chains=32,
                        T0=50.0, T_min=0.2, rho=0.90, N=25, seed=2,
                        priority=2))                      # served first
engine.submit(SARequest(req_id=2, objective="schwefel", dim=8, n_chains=32,
                        T0=200.0, T_min=1.0, rho=0.80, N=60, seed=3,
                        target_error=1.0))                # early-stop target

results = engine.run()

for r in sorted(results, key=lambda r: r.req_id):
    print(f"req{r.req_id} {r.objective:<10} dim={r.dim:<3} "
          f"f_best={r.f_best:+.5f}  levels={r.levels_run} "
          f"evals={r.n_evals}  finished: {r.finish_reason}")
stats = engine.stats()
print(f"\n{stats['completed']} requests in {stats['ticks']} ticks "
      f"({stats['wall_s']:.2f}s), slot occupancy {stats['occupancy']:.1%}")
