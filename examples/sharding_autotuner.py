"""SA tunes the framework's own sharding (DESIGN.md §4.3).

The paper's synchronous parallel SA searches the discrete distribution
space (DP/TP split, remat policy, expert parallelism, microbatching,
gradient-compression payload) for an assigned architecture, minimizing the
same analytic three-term roofline objective the dry-run extracts from HLO.

We validate the SA answer against exhaustive search (the space is small
enough to brute-force — the demonstration is that the paper's algorithm
lands on the optimum through Metropolis dynamics, not enumeration).

Run:  PYTHONPATH=src python examples/sharding_autotuner.py \
          [--arch deepseek-v2-lite-16b] [--chips 256]
"""
import argparse
import time

from repro.configs import get_arch
from repro.distributed.autotune import (TuneProblem, autotune,
                                        exhaustive_best)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="deepseek-v2-lite-16b")
    ap.add_argument("--chips", type=int, default=256)
    ap.add_argument("--seq", type=int, default=4096)
    ap.add_argument("--batch", type=int, default=256)
    ap.add_argument("--chains", type=int, default=256)
    args = ap.parse_args()

    spec = get_arch(args.arch)
    prob = TuneProblem(cfg=spec.model, seq=args.seq, batch=args.batch,
                       chips=args.chips)
    print(f"[autotune] {args.arch} on {args.chips} chips, "
          f"train {args.batch}x{args.seq}; space = "
          f"{dict(prob.space())} -> "
          f"{1}".replace("-> 1", ""))

    t0 = time.time()
    sa_choice, sa_cost = autotune(prob, n_chains=args.chains)
    t_sa = time.time() - t0

    t0 = time.time()
    ex_choice, ex_cost = exhaustive_best(prob)
    t_ex = time.time() - t0

    print(f"[autotune] SA       : {sa_cost*1e3:8.3f} ms/step  {sa_choice} "
          f"({t_sa:.1f}s)")
    print(f"[autotune] exhaustive: {ex_cost*1e3:8.3f} ms/step  {ex_choice} "
          f"({t_ex:.1f}s)")
    gap = (sa_cost - ex_cost) / ex_cost
    print(f"[autotune] SA-vs-optimal gap: {gap*100:.2f}%")
    assert gap < 0.02, "SA should match the exhaustive optimum (<2%)"
    print("[example] OK: SA found the optimal sharding configuration")


if __name__ == "__main__":
    main()
