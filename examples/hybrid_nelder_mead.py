"""Hybrid SA -> Nelder-Mead (paper §4.2, Table 10).

SA is stopped *prematurely* (small eval budget) and its champion seeds a
local simplex minimization.  The paper shows this dominates pure SA by
orders of magnitude in both error and time; we reproduce that ordering here
on the paper's own Table-10 problems (CPU-reduced budget).

Run:  PYTHONPATH=src python examples/hybrid_nelder_mead.py
"""
import time

import jax

from repro.core import SAConfig, hybrid_minimize, sa_minimize
from repro.objectives import SUITE

# Table 10 rows (paper): F0_g Schwefel-512, F1_d Ackley-400, F8_c
# Griewank-400, F13_b Rastrigin-400.  CPU-reduced dims keep runtimes short;
# benchmarks/table10.py runs the as-published dims.
PROBLEMS = ["F0_b", "F1_a", "F8_a", "F13_a"]


def main():
    print(f"{'problem':8s} {'pure-SA |f-f*|':>16s} {'hybrid |f-f*|':>16s} "
          f"{'SA time':>8s} {'hyb time':>9s}")
    for ref in PROBLEMS:
        obj = SUITE[ref]()
        # Premature SA: enough budget to land in the global basin (paper
        # Table 10 stops SA "prematurely" but inside the funnel), far less
        # than a converged pure-SA run would need.
        cfg = SAConfig(T0=50.0, T_min=0.05, rho=0.82, N=40, n_chains=2048,
                       exchange="sync", seed=0)
        t0 = time.time()
        sa_res = sa_minimize(obj, cfg, key=jax.random.PRNGKey(0))
        t_sa = time.time() - t0

        t0 = time.time()
        hyb = hybrid_minimize(obj, cfg, key=jax.random.PRNGKey(0),
                              nm_max_iters=30000, nm_fatol=1e-14,
                              nm_xatol=1e-14)
        t_h = time.time() - t0

        e_sa = abs(sa_res.f_best - obj.f_opt)
        e_h = abs(hyb.f_best - obj.f_opt)
        print(f"{ref:8s} {e_sa:16.3e} {e_h:16.3e} {t_sa:7.2f}s {t_h:8.2f}s"
              f"   ({obj.name})")
    print("\nexpected (paper Table 10): hybrid error orders of magnitude "
          "below premature pure SA")
    print("note: Rastrigin's +-1 lattice needs a larger SA budget to land "
          "every coordinate in the central cell before NM can polish "
          "(benchmarks/table10.py runs the paper-scale budget)")


if __name__ == "__main__":
    main()
