"""SA-driven hyperparameter search with the training run INSIDE the
objective — everything jitted end to end.

The paper's algorithm is a black-box global optimizer; a production use in
an LM framework is hyperparameter search.  Here each SA "energy evaluation"
is *an entire (tiny) training run*: f(hp) = final training loss after K
steps.  Chains vectorize over hyperparameter candidates via ``vmap``, so a
single Metropolis step trains ``n_chains`` models in parallel — the TPU
adaptation of one-thread-per-chain, at the outer loop level.

Search space (4-d box, the paper's coordinate-wise proposals apply as-is):
  x0: log10(lr)        in [-4.0, -1.0]
  x1: warmup fraction  in [0.0, 0.5]
  x2: weight decay     in [0.0, 0.2]
  x3: beta2            in [0.90, 0.999]

Run:  PYTHONPATH=src python examples/sa_hparam_search.py
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import SAConfig, sa_minimize
from repro.objectives.base import Objective

# ----- tiny transformer trained inside the objective ------------------------
VOCAB, DM, SEQ, BATCH, STEPS = 64, 32, 32, 4, 12


def _init(key):
    k = jax.random.split(key, 4)
    s = 0.02
    return {
        "emb": jax.random.normal(k[0], (VOCAB, DM)) * s,
        "w1": jax.random.normal(k[1], (DM, 4 * DM)) * s,
        "w2": jax.random.normal(k[2], (4 * DM, DM)) * s,
        "wq": jax.random.normal(k[3], (DM, DM)) * s,
    }


def _fwd(p, toks):
    x = p["emb"][toks]                      # (B, S, D)
    q = x @ p["wq"]
    a = jax.nn.softmax(
        (q @ jnp.swapaxes(x, -1, -2)) / np.sqrt(DM)
        + jnp.triu(jnp.full((SEQ, SEQ), -1e9), 1), axis=-1)
    x = x + a @ x
    x = x + jax.nn.gelu(x @ p["w1"]) @ p["w2"]
    return x @ p["emb"].T                   # tied head


def _loss(p, toks):
    logits = _fwd(p, toks[:, :-1])
    tgt = toks[:, 1:]
    lse = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, tgt[..., None], -1)[..., 0]
    return jnp.mean(lse - ll)


def make_objective(seed: int = 0) -> Objective:
    rng = np.random.default_rng(seed)
    data = jnp.asarray(
        rng.integers(0, VOCAB, size=(STEPS, BATCH, SEQ + 1)), jnp.int32)
    p0 = _init(jax.random.PRNGKey(seed))

    def train_once(hp):
        """hp = (log10_lr, warmup_frac, wd, b2) -> final loss (scalar)."""
        lr0 = 10.0 ** hp[0]
        warm = jnp.maximum(hp[1] * STEPS, 1.0)
        wd, b2 = hp[2], hp[3]

        def adam_step(i, carry):
            p, m, v = carry
            g = jax.grad(_loss)(p, data[i])
            lr = lr0 * jnp.minimum(1.0, (i + 1.0) / warm)
            m = jax.tree.map(lambda m_, g_: 0.9 * m_ + 0.1 * g_, m, g)
            v = jax.tree.map(lambda v_, g_: b2 * v_ + (1 - b2) * g_ ** 2, v, g)
            p = jax.tree.map(
                lambda p_, m_, v_: p_ - lr * (m_ / (jnp.sqrt(v_) + 1e-8)
                                              + wd * p_), p, m, v)
            return p, m, v

        zeros = jax.tree.map(jnp.zeros_like, p0)
        p, _, _ = jax.lax.fori_loop(0, STEPS, adam_step, (p0, zeros, zeros))
        return _loss(p, data[-1])

    def fn(x):
        flat = x.reshape((-1, 4))
        out = jax.vmap(train_once)(flat)
        return out.reshape(x.shape[:-1])

    lo = np.array([-4.0, 0.0, 0.0, 0.90])
    hi = np.array([-1.0, 0.5, 0.2, 0.999])
    return Objective(name="lm-hparam", dim=4, lower=lo, upper=hi, fn=fn)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--chains", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    obj = make_objective(args.seed)
    cfg = SAConfig(T0=0.5, T_min=0.02, rho=0.7, N=6, n_chains=args.chains,
                   exchange="sync", seed=args.seed, record_history=True)
    print(f"[hparam] {cfg.n_levels} levels x N={cfg.N} x "
          f"{cfg.n_chains} chains = {cfg.n_evals} tiny training runs")
    t0 = time.time()
    res = sa_minimize(obj, cfg, key=jax.random.PRNGKey(args.seed))
    dt = time.time() - t0

    # Reference: the default practitioner guess.
    default = jnp.asarray([-3.0, 0.1, 0.01, 0.999])
    f_default = float(obj(default[None, :])[0])
    lr, warm, wd, b2 = res.x_best
    print(f"[hparam] default hp loss  = {f_default:.4f}")
    print(f"[hparam] SA best loss     = {res.f_best:.4f}  ({dt:.1f}s)")
    print(f"[hparam] lr=10^{lr:.2f}={10**lr:.2e} warmup={warm:.2f} "
          f"wd={wd:.3f} beta2={b2:.4f}")
    assert res.f_best <= f_default + 1e-6, "SA should not lose to the default"
    print("[example] OK: SA hyperparameters beat the default guess")


if __name__ == "__main__":
    main()
