"""End-to-end LM training example (deliverable (b): the e2e driver).

Trains a decoder LM on the synthetic deterministic corpus with the full
production substrate: sharded train step, checkpointing + resume, straggler
timing.  Defaults are CPU-sized; ``--preset 100m --steps 300`` is the
paper-prompt-sized run for real hardware (same code path).

This is a thin veneer over ``repro.launch.train`` — the point is that the
framework's driver *is* the example.

Run:  PYTHONPATH=src python examples/train_lm.py [--steps 60]
"""
import argparse
import sys

from repro.launch.train import main as train_main


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--preset", default="20m",
                    help="smoke | 20m | 100m (100m = the ~100M-param run)")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    args = ap.parse_args()

    losses = train_main([
        "--preset", args.preset,
        "--steps", str(args.steps),
        "--ckpt-dir", args.ckpt_dir,
        "--ckpt-every", "25",
        "--log-every", "5",
        "--resume",
    ])
    assert losses[-1] < losses[0], "loss must decrease"
    print(f"[example] OK: loss {losses[0]:.3f} -> {losses[-1]:.3f} "
          f"({args.preset}, {len(losses)} steps)")


if __name__ == "__main__":
    sys.exit(main())
