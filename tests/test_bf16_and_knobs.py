"""bf16-compute smoke for every arch family (the dry-run runs bf16; fp32
smoke alone missed a mamba dtype bug) + layout-knob code paths."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch, shrink
from repro.models import model as M

# one representative per family keeps runtime low; mamba/moe/mla/encdec and
# a windowed dense arch are the distinct numeric paths.
_BF16_ARCHS = ["gemma3-4b", "falcon-mamba-7b", "jamba-v0.1-52b",
               "deepseek-v2-lite-16b", "whisper-base", "internvl2-2b"]


def _batch(cfg, key, batch=2, seq=16):
    ks = jax.random.split(key, 3)
    b = {"tokens": jax.random.randint(ks[0], (batch, seq + 1), 0,
                                      cfg.vocab_size)}
    if cfg.frontend == "vision_stub":
        b["patch_embeds"] = jax.random.normal(
            ks[1], (batch, cfg.frontend_len, cfg.d_model), jnp.bfloat16)
    if cfg.kind == "encdec":
        b["audio_frames"] = jax.random.normal(
            ks[2], (batch, 8, cfg.d_model), jnp.bfloat16)
    return b


@pytest.mark.parametrize("arch_id", _BF16_ARCHS)
def test_bf16_train_step(arch_id):
    cfg = shrink(get_arch(arch_id).model, param_dtype="bfloat16",
                 compute_dtype="bfloat16")
    key = jax.random.PRNGKey(0)
    params = M.init_params(key, cfg)
    loss, grads = jax.value_and_grad(M.lm_loss)(params, cfg, _batch(cfg, key))
    assert np.isfinite(float(loss)), f"{arch_id}: bf16 loss not finite"
    gn = sum(float(jnp.sum(jnp.abs(g.astype(jnp.float32))))
             for g in jax.tree.leaves(grads))
    assert np.isfinite(gn) and gn > 0


@pytest.mark.parametrize("knobs", [
    {"seq_parallel": True},
    {"seq_shard_kv": True, "serve_params_tp_only": True},
])
def test_layout_knob_paths_run_on_cpu(knobs):
    """The §Perf knobs must be inert-correct without a mesh policy."""
    import dataclasses
    cfg = shrink(get_arch("internlm2-20b").model)
    cfg = dataclasses.replace(cfg, **{k: v for k, v in knobs.items()
                                      if hasattr(cfg, k)})
    key = jax.random.PRNGKey(1)
    params = M.init_params(key, cfg)
    loss = M.lm_loss(params, cfg, _batch(cfg, key))
    assert np.isfinite(float(loss))

    # decode path with the flash-decode constraints active (identity on CPU)
    caches = M.init_cache(cfg, 2, 32, dtype=jnp.float32)
    tok = jnp.zeros((2, 1), jnp.int32)
    pos = jnp.zeros((2, 1), jnp.int32)
    logits, caches = M.forward(params, cfg, tok, positions=pos,
                               caches=caches, mode="decode")
    assert bool(jnp.isfinite(logits).all())


def test_knob_cells_build_on_production_mesh():
    """Sharding specs for the knob variants are constructible (no compile)."""
    from repro.launch.steps import cache_specs, param_specs
    import dataclasses

    class FakeMesh:  # spec construction only consults shape/axis_names
        shape = {"data": 16, "model": 16}
        axis_names = ("data", "model")

    cfg = dataclasses.replace(get_arch("internlm2-20b").model,
                              seq_shard_kv=True)
    specs = cache_specs(cfg, FakeMesh(), batch=128)
    k_spec = specs[0][0]["k"]  # P(reps=None, batch, seq, kv_heads, head_dim)
    assert k_spec[2] == "model", "cache seq axis must shard over model"


def test_adamw_second_moment_is_sharded_like_param():
    """Regression: state_specs must shard AdamW's v exactly like its param
    (a replicated-v bug cost 100+ GiB/device on 20B-class train cells)."""
    from functools import partial
    from repro.launch.steps import param_specs, state_specs
    from repro.optim import OptConfig, init_opt_state

    class FakeMesh:
        shape = {"data": 16, "model": 16}
        axis_names = ("data", "model")

    cfg = get_arch("granite-20b").model
    pshapes = jax.eval_shape(partial(M.init_params, cfg=cfg),
                             jax.random.PRNGKey(0))
    pspecs = param_specs(pshapes, cfg, FakeMesh())
    ss = jax.eval_shape(
        lambda p: {"params": p, "opt": init_opt_state(p, OptConfig())},
        pshapes)
    sspecs = state_specs(ss, pspecs)
    # v and m mirror the param tree: compare leaf-by-leaf
    pv = jax.tree_util.tree_leaves(sspecs["opt"]["v"],
        is_leaf=lambda x: type(x).__name__ == "PartitionSpec")
    pm = jax.tree_util.tree_leaves(sspecs["opt"]["m"],
        is_leaf=lambda x: type(x).__name__ == "PartitionSpec")
    pp = jax.tree_util.tree_leaves(pspecs,
        is_leaf=lambda x: type(x).__name__ == "PartitionSpec")
    assert pv == pp and pm == pp
