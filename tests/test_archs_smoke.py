"""Per-architecture smoke tests: a REDUCED config of the same family runs a
forward pass + one train step on CPU; output shapes verified, no NaNs.

The FULL configs are exercised via the dry-run only (no allocation here).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_arch, shrink
from repro.models import model as M


def _smoke_batch(cfg, key, batch=2, seq=16):
    ks = jax.random.split(key, 3)
    b = {"tokens": jax.random.randint(ks[0], (batch, seq + 1), 0, cfg.vocab_size)}
    if cfg.frontend == "vision_stub":
        b["patch_embeds"] = jax.random.normal(
            ks[1], (batch, cfg.frontend_len, cfg.d_model), jnp.float32)
    if cfg.kind == "encdec":
        b["audio_frames"] = jax.random.normal(
            ks[2], (batch, 8, cfg.d_model), jnp.float32)
    return b


@pytest.mark.parametrize("arch_id", ARCH_IDS)
def test_smoke_forward_and_train_step(arch_id):
    spec = get_arch(arch_id)
    cfg = shrink(spec.model)
    key = jax.random.PRNGKey(0)
    params = M.init_params(key, cfg)
    batch = _smoke_batch(cfg, key)

    loss, grads = jax.value_and_grad(M.lm_loss)(params, cfg, batch)
    assert np.isfinite(float(loss)), f"{arch_id}: non-finite loss"
    # simple SGD step must keep loss finite
    params2 = jax.tree.map(lambda p, g: p - 1e-3 * g, params, grads)
    loss2 = M.lm_loss(params2, cfg, batch)
    assert np.isfinite(float(loss2))

    # forward logits shape
    logits = M.forward(params, cfg, batch["tokens"][:, :-1],
                       embeds=batch.get("patch_embeds"),
                       enc_frames=batch.get("audio_frames"))
    S = batch["tokens"].shape[1] - 1 + (cfg.frontend_len if cfg.frontend == "vision_stub" else 0)
    assert logits.shape == (2, S, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all())


@pytest.mark.parametrize("arch_id", [a for a in ARCH_IDS
                                     if get_arch(a).decode_ok])
def test_smoke_prefill_then_decode(arch_id):
    spec = get_arch(arch_id)
    cfg = shrink(spec.model)
    key = jax.random.PRNGKey(1)
    params = M.init_params(key, cfg)
    B, S, s_max = 2, 8, 32
    tokens = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    enc_len = 8 if cfg.kind == "encdec" else 0
    caches = M.init_cache(cfg, B, s_max, dtype=jnp.float32, enc_len=enc_len)
    kw = {}
    if cfg.frontend == "vision_stub":
        kw["embeds"] = jax.random.normal(key, (B, cfg.frontend_len, cfg.d_model))
    if cfg.kind == "encdec":
        kw["enc_frames"] = jax.random.normal(key, (B, enc_len, cfg.d_model))
    logits, caches = M.forward(params, cfg, tokens, caches=caches,
                               mode="prefill", **kw)
    assert bool(jnp.isfinite(logits).all())

    # decode 3 tokens greedily
    pos0 = S + (cfg.frontend_len if cfg.frontend == "vision_stub" else 0)
    tok = jnp.argmax(logits[:, -1], axis=-1)[:, None]
    for i in range(3):
        positions = jnp.full((B, 1), pos0 + i, jnp.int32)
        logits, caches = M.forward(params, cfg, tok, positions=positions,
                                   caches=caches, mode="decode")
        assert logits.shape == (B, 1, cfg.vocab_size)
        assert bool(jnp.isfinite(logits).all())
        tok = jnp.argmax(logits[:, -1], axis=-1)[:, None]


def test_decode_matches_prefill_logits():
    """Teacher-forced decode must reproduce prefill logits (dense arch)."""
    cfg = shrink(get_arch("stablelm-1.6b").model)
    key = jax.random.PRNGKey(2)
    params = M.init_params(key, cfg)
    B, S, s_max = 1, 12, 16
    tokens = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    full = M.forward(params, cfg, tokens)

    caches = M.init_cache(cfg, B, s_max, dtype=jnp.float32)
    pre_S = 6
    logits_p, caches = M.forward(params, cfg, tokens[:, :pre_S], caches=caches,
                                 mode="prefill")
    np.testing.assert_allclose(np.asarray(logits_p), np.asarray(full[:, :pre_S]),
                               rtol=2e-4, atol=2e-4)
    for i in range(pre_S, S):
        positions = jnp.full((B, 1), i, jnp.int32)
        logits_d, caches = M.forward(params, cfg, tokens[:, i:i + 1],
                                     positions=positions, caches=caches,
                                     mode="decode")
        np.testing.assert_allclose(np.asarray(logits_d[:, 0]),
                                   np.asarray(full[:, i]),
                                   rtol=2e-4, atol=2e-4)
