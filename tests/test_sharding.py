"""Sharded slot pool: home-shard placement, Russkov-style migration, and
the serving-clock / terminal-accounting / target-error satellite fixes.

Tentpole guarantees (PR 4):

* **home-shard placement invariance**: a request is bit-exact versus its
  standalone single-device run no matter which shard the scheduler homed
  it on;
* **migration == uninterrupted run**: a request checkpointed off one
  shard and restored on another — at *every* temperature level of its
  ladder — produces the same best value, best x and per-level champion
  trajectory as never having moved;
* **scheduler rebalance**: when the queue head fits on no single shard
  but the pool as a whole has room, bounded cross-shard migration defrags
  the pool and seats the head, with no slot leaks or double-placements;
* **capacity scales**: the same seeded stream completes strictly more
  work by a fixed horizon on a 4-shard pool than on 1 shard.

The shards are *logical* on a single-device host (round-robin over
``jax.devices()``), so every test here runs in tier-1; the CI
multi-device job re-runs the file under
``XLA_FLAGS=--xla_force_host_platform_device_count=4`` where each shard
owns a real XLA host device.
"""
import dataclasses
import types
import time as _time

import numpy as np
import pytest

from repro.service import (ArrivalProcess, EngineConfig, RequestResult,
                           SARequest, SAServeEngine, SchedulerConfig,
                           latency_summary, run_standalone)

CPS = 8


def _req(req_id, **kw):
    kw.setdefault("objective", "rastrigin")
    kw.setdefault("dim", 4)
    kw.setdefault("n_chains", CPS)
    kw.setdefault("T0", 50.0)
    kw.setdefault("T_min", 1.0)
    kw.setdefault("rho", 0.55)   # 7-level ladder
    kw.setdefault("N", 10)
    return SARequest(req_id=req_id, seed=100 + req_id, **kw)


def _cfg(n_slots=2, n_devices=2, **kw):
    return EngineConfig(n_slots=n_slots, chains_per_slot=CPS,
                        n_devices=n_devices, use_pallas=False, **kw)


def _assert_bit_exact(res, solo):
    assert res.f_best == solo.f_best
    np.testing.assert_array_equal(res.x_best, solo.x_best)
    assert res.levels_run == solo.levels_run
    assert res.champion_history == solo.champion_history


# ------------------------------------------------------ home-shard placement
def test_requests_spread_across_shards_and_stay_bit_exact():
    """Placement invariance: requests homed on different shards are each
    bit-exact vs their standalone single-device run."""
    cfg = _cfg(n_slots=1, n_devices=3)
    engine = SAServeEngine(cfg)
    reqs = [_req(i, objective=obj)
            for i, obj in enumerate(
                ["rastrigin", "ackley", "schwefel", "griewank", "rastrigin"])]
    for r in reqs:
        engine.submit(r)
    results = {r.req_id: r for r in engine.run(max_ticks=300)}
    assert len(results) == 5
    homes = {results[i].home_shard for i in range(5)}
    assert homes == {0, 1, 2}, "placement never used some shard"
    for req in reqs:
        _assert_bit_exact(results[req.req_id], run_standalone(req, cfg))


def test_same_request_bit_exact_on_every_home_shard():
    """Force one request onto each shard in turn (by pre-filling the
    others) — its champion trajectory is identical everywhere."""
    cfg = _cfg(n_slots=1, n_devices=3)
    probe = _req(0)
    runs = []
    for target in range(3):
        engine = SAServeEngine(cfg)
        # `target` higher-priority fillers claim shards 0..target-1 first
        # (deterministic least-loaded placement), homing the probe on
        # shard `target`.
        for j in range(target):
            engine.submit(_req(10 + j, priority=9, rho=0.5, T0=8.0))
        engine.submit(probe)
        results = {r.req_id: r for r in engine.run(max_ticks=300)}
        assert results[0].home_shard == target
        runs.append(results[0])
    solo = run_standalone(probe, cfg)
    for res in runs:
        _assert_bit_exact(res, solo)


def test_placement_prefers_least_loaded_shard():
    """A request admitted while one shard is busy homes on the free one."""
    engine = SAServeEngine(_cfg(n_slots=2, n_devices=2))
    engine.submit(_req(0, rho=0.9))          # long ladder, -> shard 0
    engine.tick()
    engine.submit(_req(1, rho=0.5, T0=8.0))
    engine.tick()
    jobs = {j.req.req_id: j for _, j in engine._iter_jobs()}
    assert jobs[0].home_shard == 0
    assert jobs[1].home_shard == 1           # emptier shard scanned first


# ------------------------------------------------------------- migration
def test_migration_bit_exact_at_every_level():
    """Acceptance criterion: checkpoint-on-A/restore-on-B at every
    temperature level of the ladder; the migrated result (best value,
    best x, champion trajectory) is bit-exact with the single-device
    uninterrupted run."""
    cfg = _cfg(n_slots=1, n_devices=2)
    victim = _req(0)
    solo = run_standalone(victim, cfg)
    assert solo.levels_run == victim.n_levels > 2
    for level in range(1, victim.n_levels):
        engine = SAServeEngine(cfg)
        engine.submit(victim)
        for _ in range(level):
            engine.tick()
        assert engine.migrate(victim.req_id, to_shard=1)
        res = engine.run(max_ticks=200)[0]
        assert res.migrated_ticks == [level]
        assert res.home_shard == 1
        assert res.preempted_ticks == []     # migration is not preemption
        _assert_bit_exact(res, solo)


def test_migrate_refuses_bad_targets():
    engine = SAServeEngine(_cfg(n_slots=1, n_devices=2))
    assert not engine.migrate(123, 1)        # never submitted
    engine.submit(_req(0))
    assert not engine.migrate(0, 1)          # queued, not active
    engine.tick()                            # -> shard 0
    assert not engine.migrate(0, 0)          # already home
    with pytest.raises(ValueError):
        engine.migrate(0, 7)                 # no such shard
    engine.submit(_req(1, priority=9))       # fills shard 1
    engine.tick()
    assert not engine.migrate(0, 1)          # target full
    assert engine.migrations == 0


def test_migration_then_preemption_compose():
    """A migrated job can still be preempted and resumes bit-exactly."""
    cfg = _cfg(n_slots=1, n_devices=2)
    victim = _req(0)
    engine = SAServeEngine(cfg)
    engine.submit(victim)
    engine.tick()
    assert engine.migrate(0, 1)
    engine.tick()
    assert engine.preempt(0)
    engine.submit(_req(1, priority=50, rho=0.5, T0=8.0))  # steals a slot
    results = {r.req_id: r for r in engine.run(max_ticks=300)}
    res = results[0]
    assert res.n_migrations == 1 and res.n_preemptions == 1
    _assert_bit_exact(res, run_standalone(victim, cfg))


# ----------------------------------------------------- scheduler rebalance
def test_rebalance_defrags_pool_for_wide_request():
    """Fragmented free slots (1 per shard) cannot seat a 2-slot request;
    the planner migrates a narrow job across so the donor shard can."""
    cfg = _cfg(n_slots=2, n_devices=2)
    A, B = _req(0, T0=8.0, rho=0.9), _req(1, T0=8.0, rho=0.9)  # 20 levels
    D = _req(3, T0=8.0, rho=0.9, n_chains=2 * CPS)
    engine = SAServeEngine(cfg)
    engine.submit(A)
    engine.submit(B)
    engine.tick()                  # per-entry least-loaded: A -> 0, B -> 1
    jobs = {j.req.req_id: j for _, j in engine._iter_jobs()}
    assert jobs[0].home_shard != jobs[1].home_shard
    engine.submit(D)               # needs 2; each shard has only 1 free
    engine.tick()
    assert engine.migrations == 1, "rebalance did not fire"
    jobs = {j.req.req_id: j for _, j in engine._iter_jobs()}
    assert 3 in jobs, "wide request was not seated after the migration"
    # No double placement: every live request is resident on exactly one
    # shard, and slot accounting is consistent.
    rids_per_req = [j.req.req_id for _, j in engine._iter_jobs()]
    assert len(rids_per_req) == len(set(rids_per_req))
    results = {r.req_id: r for r in engine.run(max_ticks=400)}
    for req in (A, B, D):
        _assert_bit_exact(results[req.req_id], run_standalone(req, cfg))
    # Drained: no slot leaked on any shard.
    for shard in engine.shards:
        assert shard.pool.n_free == cfg.n_slots
        assert not shard.rids.jobs


def test_migration_budget_zero_disables_rebalance():
    cfg = _cfg(n_slots=2, n_devices=2, migration_budget=0)
    engine = SAServeEngine(cfg)
    engine.submit(_req(0, T0=8.0, rho=0.9))
    engine.submit(_req(1, T0=8.0, rho=0.9))
    engine.tick()                  # one 1-slot job per shard
    engine.submit(_req(3, T0=8.0, rho=0.9, n_chains=2 * CPS))
    engine.tick()
    assert engine.migrations == 0
    assert all(j.req.req_id != 3 for _, j in engine._iter_jobs())
    # It still completes eventually (a whole shard frees up).
    results = {r.req_id: r for r in engine.run(max_ticks=400)}
    assert results[3].completed


def test_overload_fallbacks_fire_only_when_no_shard_fits_full_width():
    """A degrade-class request must not be shrunk by the first-scanned
    shard while another shard could seat it whole — and a preempt-class
    request must not evict while a shard has room."""
    cfg = _cfg(n_slots=2, n_devices=2, scheduler=SchedulerConfig(
        overload="degrade", default_deadline=10.0))
    engine = SAServeEngine(cfg)
    engine.submit(_req(0, priority=5))                     # 1 slot
    engine.submit(_req(1, priority=1, n_chains=2 * CPS))   # 2 slots
    engine.tick()
    jobs = {j.req.req_id: j for _, j in engine._iter_jobs()}
    assert jobs[1].granted_chains == 2 * CPS, \
        "degraded despite full-width room on the other shard"
    assert jobs[0].home_shard != jobs[1].home_shard
    # Preempt flavour: the urgent arrival takes the free shard instead of
    # evicting the resident tenant.
    cfg = _cfg(n_slots=1, n_devices=2,
               scheduler=SchedulerConfig(aging=0.0))
    engine = SAServeEngine(cfg)
    engine.submit(_req(0, priority=0))
    engine.tick()
    engine.submit(_req(1, priority=9, on_overload="preempt"))
    engine.tick()
    assert engine.preemptions == 0 and engine.n_active == 2


def test_preemption_budget_is_per_tick_not_per_shard():
    """The scheduler scans the queue once per shard each tick; the
    preemption budget must bound swap-outs per TICK across all shards,
    not reset per scan."""
    cfg = _cfg(n_slots=1, n_devices=2,
               scheduler=SchedulerConfig(preemption_budget=1, aging=0.0))
    engine = SAServeEngine(cfg)
    engine.submit(_req(0, priority=0))
    engine.submit(_req(1, priority=0))
    engine.tick()                            # one low-prio job per shard
    assert engine.n_active == 2
    engine.submit(_req(2, priority=9, on_overload="preempt"))
    engine.submit(_req(3, priority=9, on_overload="preempt"))
    engine.tick()
    assert engine.preemptions == 1, "budget leaked across shard scans"
    engine.tick()
    assert engine.preemptions == 2           # next tick's budget


# ---------------------------------------------------------- capacity scaling
def test_goodput_scales_with_devices():
    """Acceptance criterion: the same seeded stream completes strictly
    more requests by a fixed horizon on 4 shards than on 1."""
    reqs = [_req(i, T0=8.0, rho=0.5) for i in range(24)]

    def completed_by(n_devices):
        engine = SAServeEngine(_cfg(n_slots=1, n_devices=n_devices))
        engine.run_stream(
            ArrivalProcess.poisson(
                [dataclasses.replace(r) for r in reqs], rate=1.0, seed=7),
            max_ticks=40)
        summary = latency_summary(engine.results, ticks=engine.tick_count,
                                  n_submitted=engine.n_submitted)
        return summary, engine.n_submitted

    (one, n1), (four, n4) = completed_by(1), completed_by(4)
    assert four["completed"] > one["completed"]
    assert four["goodput_req_per_tick"] > one["goodput_req_per_tick"]
    # Terminal accounting stays honest under the horizon cutoff: nothing
    # in flight is counted as rejected.
    assert one["rejected"] == 0 and four["rejected"] == 0
    assert one["completed"] + one["incomplete"] == n1
    assert four["completed"] + four["incomplete"] == n4


def test_sharded_stream_deterministic_and_json_fields():
    """Tick-clock results of a sharded open-loop run reproduce bit-for-bit
    and carry the shard lifecycle fields."""
    def one_run():
        engine = SAServeEngine(_cfg(n_slots=1, n_devices=3))
        reqs = [_req(i, T0=8.0, rho=0.5) for i in range(9)]
        engine.run_stream(ArrivalProcess.poisson(reqs, rate=0.8, seed=3),
                          max_ticks=500)
        return sorted((r.req_id, r.home_shard, tuple(r.migrated_ticks),
                       r.start_tick, r.finish_tick, r.f_best)
                      for r in engine.results)

    r1, r2 = one_run(), one_run()
    assert r1 == r2
    engine = SAServeEngine(_cfg(n_slots=1, n_devices=2))
    engine.submit(_req(0, T0=8.0, rho=0.5))
    d = engine.run(max_ticks=50)[0].to_dict()
    assert {"home_shard", "migrated_ticks", "n_migrations"} <= set(d)


def test_shard_stats_and_run_standalone_single_device():
    engine = SAServeEngine(_cfg(n_slots=1, n_devices=2))
    for i in range(4):
        engine.submit(_req(i, T0=8.0, rho=0.5))
    engine.run(max_ticks=100)
    stats = engine.stats()
    assert stats["devices"] == 2
    assert len(stats["shard_occupancy"]) == 2
    assert all(0.0 <= u <= 1.0 for u in stats["shard_occupancy"])
    # occupancy is the shard mean, so it can never exceed 1 either.
    assert 0.0 < stats["occupancy"] <= 1.0
    # Multi-shard engines have no single pool/rid table.
    with pytest.raises(AttributeError):
        engine.pool
    with pytest.raises(AttributeError):
        engine.rids


def test_oversubscribed_logical_shards_on_one_device():
    """More shards than physical devices round-robin instead of failing
    (the CPU-test path without XLA_FLAGS)."""
    import jax
    n_phys = len(jax.devices())
    engine = SAServeEngine(_cfg(n_slots=1, n_devices=n_phys + 2))
    assert len(engine.shards) == n_phys + 2
    devs = [s.device for s in engine.shards]
    assert devs[0] == devs[n_phys]           # wrapped around
    engine.submit(_req(0, T0=8.0, rho=0.5))
    res = engine.run(max_ticks=50)
    assert res[0].completed


@pytest.mark.skipif(
    len(__import__("jax").devices()) < 2,
    reason="needs >= 2 XLA devices (CI multi-device job sets XLA_FLAGS)")
def test_shards_map_to_distinct_physical_devices():
    """With real devices available, shards 0/1 own different devices and
    cross-device migration stays bit-exact."""
    cfg = _cfg(n_slots=1, n_devices=2)
    engine = SAServeEngine(cfg)
    assert engine.shards[0].device != engine.shards[1].device
    victim = _req(0)
    engine.submit(victim)
    engine.tick()
    engine.tick()
    assert engine.migrate(0, 1)
    res = engine.run(max_ticks=200)[0]
    assert res.home_shard == 1
    _assert_bit_exact(res, run_standalone(victim, cfg))


# ----------------------------------------------------- satellite regressions
def test_run_stream_never_reads_the_wall_clock(monkeypatch):
    """Satellite: run_stream's wall_s once mixed time.time() with the
    perf_counter lifecycle epoch, so a wall-clock adjustment mid-run
    skewed wall_s and every per-second throughput rate.  The engine must
    now draw every wall stamp from the monotonic epoch — i.e. never call
    time.time() at all."""
    import repro.service.engine as eng_mod

    def bomb():
        raise AssertionError("engine consulted the adjustable wall clock")

    monkeypatch.setattr(
        eng_mod, "time",
        types.SimpleNamespace(perf_counter=_time.perf_counter, time=bomb))
    engine = SAServeEngine(_cfg(n_slots=2, n_devices=1))
    results = engine.run_stream(
        ArrivalProcess.batch([_req(0, T0=8.0, rho=0.5)]))
    assert results[0].completed
    assert 0.0 <= engine.wall_s < 600.0
    stats = engine.stats()
    assert stats["sweeps_per_s"] > 0.0
    # wall_s and the lifecycle stamps share one epoch, so the run can
    # never be shorter than the span of events inside it.
    assert engine.wall_s >= results[0].finish_wall - results[0].submit_wall


def test_latency_summary_typed_terminal_accounting():
    """Satellite: 'rejected' counts only the typed 'rejected' terminal;
    work cut off by a --max-ticks horizon surfaces as 'incomplete', and
    preemption counts include evicted-then-rejected requests."""
    done = RequestResult(
        req_id=0, objective="rastrigin", dim=4, x_best=np.zeros(4),
        f_best=1.0, levels_run=3, n_evals=30, submit_tick=0, start_tick=0,
        finish_tick=3, finish_reason="ladder", first_tick=0,
        preempted_ticks=[1], migrated_ticks=[2])
    rejected = RequestResult(
        req_id=1, objective="rastrigin", dim=4, x_best=None,
        f_best=float("inf"), levels_run=1, n_evals=10, submit_tick=0,
        start_tick=-1, finish_tick=5, finish_reason="rejected",
        preempted_ticks=[2, 4], home_shard=-1)
    s = latency_summary([done, rejected], ticks=10, n_submitted=5)
    assert s["completed"] == 1
    assert s["rejected"] == 1                # typed, not a complement
    assert s["incomplete"] == 3              # submitted but no terminal
    assert s["preemptions"] == 3             # includes the rejected one's 2
    assert s["migrations"] == 1
    # Without n_submitted the field is present and zero (closed-loop runs).
    assert latency_summary([done, rejected], ticks=10)["incomplete"] == 0


def test_max_ticks_cutoff_reports_incomplete_not_rejected():
    """End-to-end: a truncated overloaded stream leaves in-flight/queued
    requests as 'incomplete'; 'rejected' stays 0 without a reject policy."""
    engine = SAServeEngine(_cfg(n_slots=1, n_devices=1))
    reqs = [_req(i, T0=8.0, rho=0.9) for i in range(6)]   # 20-level ladders
    engine.run_stream(ArrivalProcess.batch(reqs), max_ticks=5)
    s = latency_summary(engine.results, ticks=engine.tick_count,
                        n_submitted=engine.n_submitted)
    assert s["completed"] == 0 and s["rejected"] == 0
    assert s["incomplete"] == 6
    assert engine.rejections == 0


def test_target_error_requires_registered_optimum():
    """Satellite: target_error on an objective without a known optimum is
    a typed submit-time error, not a mid-tick KeyError that wedges the
    slot."""
    import repro.service.engine as eng_mod
    from repro.kernels import objective_math as om

    engine = SAServeEngine(_cfg(n_slots=2, n_devices=1))
    saved = eng_mod.F_OPT.pop(om.KID_ACKLEY)
    try:
        with pytest.raises(ValueError, match="target_error"):
            engine.submit(_req(0, objective="ackley", target_error=0.5))
        # The engine is not wedged: other work (and the same objective
        # without a target) still serves.
        engine.submit(_req(1, objective="ackley", T0=8.0, rho=0.5))
        engine.submit(_req(2, objective="rastrigin", T0=8.0, rho=0.5,
                           target_error=1000.0))
        results = {r.req_id: r for r in engine.run(max_ticks=100)}
        assert results[1].completed
        assert results[2].finish_reason == "target"
    finally:
        eng_mod.F_OPT[om.KID_ACKLEY] = saved


def test_every_registry_objective_has_an_optimum():
    """The guard can only fire if registry growth forgets F_OPT; today the
    two must agree exactly."""
    from repro.kernels import objective_math as om
    from repro.service.engine import F_OPT
    assert set(F_OPT) == set(om.KID_BY_NAME.values())
