"""Registry growth: exponential + salomon join `SERVABLE` (PR 5).

Pure kernel-registry growth — both objectives are separable into the
radial sum accumulator S0 = sum(x_i^2), so the delta variant evaluates
single-coordinate moves in O(1), and runtime `kid` dispatch means the
widened registry adds ZERO new compiled programs (compile-count test).

Parity ladder per new objective:

  host suite fn (objectives/functions.py)
    == kernel-side full_eval (objective_math.py)          [values]
    == Pallas kernel, interpret mode (metropolis_sweep)   [vs ref oracle]
  and delta variant == full variant accumulators,
  and engine co-batch == run_standalone (bit-exact champions).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import objective_math as om
from repro.kernels import ref
from repro.kernels.metropolis_sweep import metropolis_sweep_pallas
from repro.objectives import functions as F
from repro.service import (
    EngineConfig,
    F_OPT,
    SARequest,
    SAServeEngine,
    SERVABLE,
    run_standalone,
)

CPS = 8

NEW_KIDS = {om.KID_EXPONENTIAL: F.exponential, om.KID_SALOMON: F.salomon}
NEW_NAMES = ("exponential", "salomon")
ALL_NAMES = ["schwefel", "rastrigin", "ackley", "griewank", "exponential", "salomon"]


def _x0(kid, chains, dim, seed=0):
    lo, hi = om.BOX[kid]
    u = jax.random.uniform(jax.random.PRNGKey(seed), (chains, dim))
    return (lo + u * (hi - lo)).astype(jnp.float32)


def _req(req_id, objective, **kw):
    kw.setdefault("dim", 4)
    kw.setdefault("n_chains", CPS)
    kw.setdefault("T0", 50.0)
    kw.setdefault("T_min", 1.0)
    kw.setdefault("rho", 0.7)
    kw.setdefault("N", 10)
    return SARequest(req_id=req_id, objective=objective, seed=100 + req_id, **kw)


def _cfg(**kw):
    kw.setdefault("n_slots", 4)
    return EngineConfig(chains_per_slot=CPS, use_pallas=False, **kw)


def test_registry_is_widened_consistently():
    """Every registry surface agrees on the two new objectives: names,
    kids, boxes, host-suite kernel_id backlinks and F_OPT optima."""
    assert set(NEW_NAMES) <= set(SERVABLE)
    assert om.N_KIDS == 6
    assert set(F_OPT) == set(om.KID_BY_NAME.values())
    assert set(om.BOX) == set(om.KID_BY_NAME.values())
    for kid, maker in NEW_KIDS.items():
        obj = maker(8)
        assert obj.kernel_id == kid
        assert obj.f_opt == F_OPT[kid]
        lo, hi = om.BOX[kid]
        assert (obj.lower[0], obj.upper[0]) == (lo, hi)


@pytest.mark.parametrize("kid", sorted(NEW_KIDS))
def test_full_eval_matches_host_objective(kid):
    obj = NEW_KIDS[kid](16)
    x = _x0(kid, 8, 16, seed=kid)
    f_k = np.asarray(om.full_eval(kid, x, 16)[:, 0])
    np.testing.assert_allclose(f_k, np.asarray(obj(x)), rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("kid", sorted(NEW_KIDS))
def test_accumulator_decomposition_matches_full_eval(kid):
    """init_acc + combine (the delta-variant bookkeeping) reproduces the
    direct evaluation — the separability claim for the new objectives."""
    x = _x0(kid, 8, 12, seed=3 + kid)
    S, logP, sgnP = om.init_acc(kid, x)
    f_acc = np.asarray(om.combine(kid, S, logP, sgnP, 12))
    f_dir = np.asarray(om.full_eval(kid, x, 12))
    np.testing.assert_allclose(f_acc, f_dir, rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("kid", sorted(NEW_KIDS))
@pytest.mark.parametrize("variant", ["full", "delta"])
def test_kernel_matches_oracle_for_new_objectives(kid, variant):
    """Kernel-vs-oracle parity (the satellite requirement), both
    evaluation variants, interpret mode."""
    chains, dim, n_steps = 16, 8, 12
    x = _x0(kid, chains, dim)
    kw = dict(kid=kid, n_steps=n_steps, variant=variant)
    xk, fk = metropolis_sweep_pallas(x, 3.0, 42, 0, blk=8, interpret=True, **kw)
    xr, fr = ref.metropolis_sweep_ref(x, 3.0, 42, 0, **kw)
    np.testing.assert_allclose(np.asarray(xk), np.asarray(xr), rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(fk), np.asarray(fr), rtol=2e-3, atol=2e-3)


@pytest.mark.parametrize("kid", sorted(NEW_KIDS))
def test_runtime_dispatch_matches_static_trajectory(kid):
    """Runtime-kid lowering follows the identical state trajectory as the
    static single-branch specialization for the new objectives."""
    x = _x0(kid, 8, 4, seed=7)
    kids = jnp.asarray([kid], jnp.int32)
    kw = dict(n_steps=8, blk=8, variant="delta", interpret=True)
    xa, _ = metropolis_sweep_pallas(x, 2.0, 7, 0, kid=kids, **kw)
    xs, _ = metropolis_sweep_pallas(x, 2.0, 7, 0, kid=kid, **kw)
    np.testing.assert_array_equal(np.asarray(xa), np.asarray(xs))


def test_new_objectives_serve_bit_exact_and_share_one_program():
    """The engine co-batches all six registry objectives in ONE compiled
    sweep program per (dim, N) — widening `SERVABLE` costs zero new
    lowerings — and every champion is bit-exact versus standalone."""
    from repro.service.engine import _group_tick

    cfg = _cfg(n_slots=6)
    engine = SAServeEngine(cfg)
    reqs = [_req(i, obj) for i, obj in enumerate(ALL_NAMES)]
    for r in reqs:
        engine.submit(r)
    has_cc = hasattr(_group_tick, "clear_cache")
    can_count = has_cc and hasattr(_group_tick, "_cache_size")
    if can_count:
        _group_tick.clear_cache()
    results = {r.req_id: r for r in engine.run(max_ticks=200)}
    assert len(results) == 6
    if can_count:
        assert _group_tick._cache_size() == 1
    for r in reqs:
        solo = run_standalone(r, cfg)
        assert results[r.req_id].f_best == solo.f_best
        np.testing.assert_array_equal(results[r.req_id].x_best, solo.x_best)
        assert results[r.req_id].champion_history == solo.champion_history


@pytest.mark.parametrize("dim,n_steps,macro_k", [(4, 10, 4), (8, 10, 4), (4, 10, 2)])
def test_fused_macro_tick_compiles_one_program_per_shape(dim, n_steps, macro_k):
    """Compile stability under macro-tick fusion: co-batching all six
    SERVABLE objectives at one (dim, N, K) traces exactly ONE fused
    program — the K-level loop keeps the objective id a runtime input —
    and every champion stays bit-exact vs standalone."""
    from repro.service.engine import _group_tick_fused

    can_count = hasattr(_group_tick_fused, "clear_cache") and hasattr(
        _group_tick_fused, "_cache_size"
    )
    if can_count:
        _group_tick_fused.clear_cache()
    cfg = _cfg(n_slots=6, macro_k=macro_k)
    engine = SAServeEngine(cfg)
    reqs = [_req(i, obj, dim=dim, N=n_steps) for i, obj in enumerate(ALL_NAMES)]
    for r in reqs:
        engine.submit(r)
    results = {r.req_id: r for r in engine.run(max_ticks=200)}
    assert len(results) == 6
    if can_count:
        # One fused lowering serves the whole registry at this shape.
        assert _group_tick_fused._cache_size() == 1
    for r in reqs:
        solo = run_standalone(r, cfg)
        assert results[r.req_id].f_best == solo.f_best
        assert results[r.req_id].champion_history == solo.champion_history


@pytest.mark.parametrize("name", NEW_NAMES)
def test_new_objectives_anneal_toward_their_optimum(name):
    """Sanity: a short ladder makes real progress toward the registered
    optimum (loose bound — this is an anneal, not a solve)."""
    req = _req(0, name, dim=4, T0=10.0, T_min=0.05, rho=0.6, N=40)
    res = run_standalone(req, _cfg())
    x0_best = float(np.min(om.full_eval(req.kid, _x0(req.kid, CPS, 4), 4)))
    assert res.f_best <= x0_best + 1e-6, "annealing never improved"
    assert res.f_best >= F_OPT[req.kid] - 1e-5, "beat the global optimum?!"


def test_target_error_supported_on_new_objectives():
    """F_OPT registration makes accuracy-target stopping legal for the
    new objectives (the submit-time guard must not fire)."""
    engine = SAServeEngine(_cfg())
    engine.submit(_req(0, "exponential", target_error=10.0, T0=10.0, rho=0.5))
    engine.submit(_req(1, "salomon", target_error=50.0, T0=10.0, rho=0.5))
    results = {r.req_id: r for r in engine.run(max_ticks=100)}
    assert results[0].finish_reason == "target"
    assert results[1].finish_reason == "target"
