"""Expert-parallel MoE dispatch == local MoE (values and gradients), on 8
fake devices in a subprocess.  This is the correctness guarantee behind the
EP cells of the dry-run (deepseek, kimi, jamba)."""
import json
import subprocess
import sys
from pathlib import Path

SRC = str(Path(__file__).resolve().parent.parent / "src")


def _run8(code: str) -> dict:
    pre = ("import os\n"
           "os.environ['XLA_FLAGS'] = "
           "'--xla_force_host_platform_device_count=8'\n")
    out = subprocess.run(
        [sys.executable, "-c", pre + code], capture_output=True, text=True,
        env={"PYTHONPATH": SRC, "PATH": "/usr/bin:/bin"}, timeout=600)
    assert out.returncode == 0, f"stderr:\n{out.stderr[-4000:]}"
    return json.loads(out.stdout.strip().splitlines()[-1])


def test_ep_matches_local_forward_and_grad():
    r = _run8("""
import json
from functools import partial
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.models import layers as L
from repro.launch.mesh import make_mesh

mesh = make_mesh((8,), ("model",))
E, D, F, top_k = 16, 8, 16, 2
key = jax.random.PRNGKey(0)
p = L.init_moe(key, D, F, E, 0, F, jnp.float32)
routed = {k: p[k] for k in ("router", "w_gate", "w_up", "w_down")}
x = jax.random.normal(jax.random.PRNGKey(1), (4, 16, D), jnp.float32)

# generous capacity so EP and local keep identical token sets
kw = dict(top_k=top_k, capacity_factor=8.0)

def local_loss(rp, x):
    return jnp.sum(L.moe_apply(rp, x, **kw) ** 2)

def ep_loss(rp, x):
    fn = partial(L.moe_apply, **kw, ep_axis="model", ep_size=8)
    from repro.launch.mesh import shard_map
    y = shard_map(fn, mesh=mesh,
                  in_specs=({"router": P(), "w_gate": P("model"),
                             "w_up": P("model"), "w_down": P("model")},
                            P()),
                  out_specs=P(), check_vma=False)(rp, x)
    return jnp.sum(y ** 2)

l0, g0 = jax.value_and_grad(local_loss)(routed, x)
l1, g1 = jax.value_and_grad(ep_loss)(routed, x)
gerr = max(float(jnp.max(jnp.abs(g0[k] - g1[k]))) for k in g0)
gmag = max(float(jnp.max(jnp.abs(g0[k]))) for k in g0)
print(json.dumps({"l0": float(l0), "l1": float(l1),
                  "gerr_rel": gerr / (gmag + 1e-9)}))
""")
    assert abs(r["l0"] - r["l1"]) / (abs(r["l0"]) + 1e-9) < 1e-5, r
    assert r["gerr_rel"] < 1e-5, r
