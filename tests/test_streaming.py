"""Open-loop (streaming) serving: seeded arrival processes, lifecycle
timestamp consistency, latency-metric determinism, and the serve_sa --json
surface."""
import json

import numpy as np
import pytest

from repro.service import (ArrivalProcess, EngineConfig, SAServeEngine,
                           SARequest, latency_summary)
from repro.service.serve_sa import main as serve_main, make_mix

CPS = 8


def _req(req_id, **kw):
    kw.setdefault("objective", "rastrigin")
    kw.setdefault("dim", 4)
    kw.setdefault("n_chains", CPS)
    kw.setdefault("T0", 50.0)
    kw.setdefault("T_min", 1.0)
    kw.setdefault("rho", 0.8)
    kw.setdefault("N", 10)
    return SARequest(req_id=req_id, seed=100 + req_id, **kw)


def _cfg(n_slots=4, **kw):
    return EngineConfig(n_slots=n_slots, chains_per_slot=CPS,
                        use_pallas=False, **kw)


# ------------------------------------------------------------ arrival process
def test_poisson_arrivals_deterministic_and_sorted():
    reqs = [_req(i) for i in range(16)]
    a = ArrivalProcess.poisson(reqs, rate=0.5, seed=7)
    b = ArrivalProcess.poisson(reqs, rate=0.5, seed=7)
    ta = [t for t, _ in a.due(float("inf"))]
    tb = [t for t, _ in b.due(float("inf"))]
    assert ta == tb                      # bit-identical timeline per seed
    assert ta == sorted(ta)
    assert all(t > 0 for t in ta)
    c = ArrivalProcess.poisson(reqs, rate=0.5, seed=8)
    assert [t for t, _ in c.due(float("inf"))] != ta


def test_arrivals_due_pops_in_time_order():
    reqs = [_req(i) for i in range(3)]
    a = ArrivalProcess.trace(reqs, [5.0, 0.5, 2.0])
    assert a.next_time == 0.5
    first = a.due(2.0)
    assert [t for t, _ in first] == [0.5, 2.0]
    assert [r.req_id for _, r in first] == [1, 2]
    assert not a.exhausted and a.next_time == 5.0
    assert a.due(4.0) == []
    assert [r.req_id for _, r in a.due(5.0)] == [0]
    assert a.exhausted and a.next_time == float("inf")


def test_arrival_process_validates_lengths_and_rate():
    with pytest.raises(ValueError):
        ArrivalProcess([_req(0)], [0.0, 1.0])
    with pytest.raises(ValueError):
        ArrivalProcess.poisson([_req(0)], rate=0.0)


# ----------------------------------------------------------- open-loop engine
def test_run_stream_serves_all_and_stamps_lifecycle():
    reqs = [_req(i) for i in range(6)]
    engine = SAServeEngine(_cfg(n_slots=2))
    arrivals = ArrivalProcess.poisson(reqs, rate=0.3, seed=1)
    results = engine.run_stream(arrivals, max_ticks=2000)
    assert {r.req_id for r in results} == set(range(6))
    for r in results:
        # tick clock: arrival -> admission -> first sweep -> completion
        assert r.arrival_time > 0.0
        assert r.start_tick >= r.arrival_time - 1  # admitted at tick >= t
        assert r.first_tick == r.start_tick        # sweep runs on admit tick
        assert r.finish_tick > r.first_tick
        assert r.queue_delay_ticks >= 0.0
        assert r.ttft_ticks >= r.queue_delay_ticks
        assert r.latency_ticks >= r.ttft_ticks  # same end-of-tick convention
        # wall clock: monotone through the lifecycle
        assert 0.0 <= r.submit_wall <= r.admit_wall
        assert r.admit_wall <= r.first_tick_wall <= r.finish_wall


def test_run_stream_idles_until_late_arrival():
    """Light load: the engine ticks through idle time, so a request arriving
    at t=10 is admitted at tick >= 10, not at tick 0."""
    engine = SAServeEngine(_cfg(n_slots=2))
    arrivals = ArrivalProcess.trace([_req(0)], [10.0])
    results = engine.run_stream(arrivals, max_ticks=500)
    assert len(results) == 1
    assert results[0].start_tick >= 10
    assert results[0].queue_delay_ticks < 2.0  # empty pool: admitted at once


def test_run_stream_tick_metrics_deterministic():
    """The whole tick-clock latency distribution reproduces bit-for-bit for
    a fixed (mix seed, arrival seed) — the acceptance criterion."""
    def one_run():
        reqs = make_mix(8, CPS, seed=0, max_slots_per_req=2)
        engine = SAServeEngine(_cfg(n_slots=4))
        engine.run_stream(ArrivalProcess.poisson(reqs, rate=0.5, seed=3),
                          max_ticks=3000)
        summary = latency_summary(engine.results, ticks=engine.tick_count)
        per_req = sorted((r.req_id, r.arrival_time, r.start_tick,
                          r.first_tick, r.finish_tick, r.f_best)
                         for r in engine.results)
        return summary, per_req

    (s1, p1), (s2, p2) = one_run(), one_run()
    assert p1 == p2
    for k in ("queue_delay_p50", "queue_delay_p99", "ttft_p50", "ttft_p99",
              "latency_p50", "latency_p99", "goodput_req_per_tick"):
        assert s1[k] == s2[k], k


def test_latency_summary_empty_and_basic():
    s = latency_summary([], ticks=10)
    assert s["completed"] == 0 and np.isnan(s["queue_delay_p50"])
    engine = SAServeEngine(_cfg(n_slots=2))
    engine.run_stream(ArrivalProcess.batch([_req(0), _req(1)]))
    s = latency_summary(engine.results, ticks=engine.tick_count)
    assert s["completed"] == 2
    assert s["queue_delay_p50"] == 0.0     # batch arrivals, empty pool
    assert s["ttft_p50"] == 1.0            # first level done at end of tick 0
    assert s["goodput_req_per_tick"] > 0


# ------------------------------------------------------------------ CLI JSON
def test_serve_sa_json_deterministic(capsys):
    """--json emits one parseable document whose tick-clock content is
    identical across runs with the same seeds (wall fields excluded)."""
    argv = ["--requests", "4", "--slots", "2", "--chains-per-slot", str(CPS),
            "--arrivals", "poisson", "--rate", "1.0", "--arrival-seed", "5",
            "--no-check", "--json"]

    def strip_wall(doc):
        doc["stats"] = {k: v for k, v in doc["stats"].items()
                        if "wall" not in k and not k.endswith("_per_s")}
        doc["latency"] = {k: v for k, v in doc["latency"].items()
                          if "wall" not in k}
        for r in doc["results"]:
            for k in list(r):
                if k.endswith("_wall_s"):
                    del r[k]
        return doc

    docs = []
    for _ in range(2):
        serve_main(argv)
        docs.append(strip_wall(json.loads(capsys.readouterr().out)))
    assert docs[0] == docs[1]
    assert docs[0]["latency"]["completed"] == 4
    assert [r["req_id"] for r in docs[0]["results"]] == [0, 1, 2, 3]
    for r in docs[0]["results"]:
        assert r["queue_delay_ticks"] >= 0.0
        assert r["ttft_ticks"] >= 1.0


def test_serve_sa_check_fails_on_truncated_coverage(capsys):
    """--check must not pass vacuously: a --max-ticks run that leaves
    requests unserved exits 1 even though every served champion matched."""
    with pytest.raises(SystemExit):
        serve_main(["--requests", "6", "--slots", "2",
                    "--chains-per-slot", str(CPS), "--max-ticks", "3",
                    "--check"])
    assert "never served" in capsys.readouterr().out


def test_serve_sa_check_passes_under_streaming(capsys):
    """Placement invariance holds under open-loop admission: --check exits
    cleanly (bit-exact packed vs standalone champions)."""
    serve_main(["--requests", "3", "--slots", "2",
                "--chains-per-slot", str(CPS), "--arrivals", "poisson",
                "--rate", "0.7", "--check"])
    out = capsys.readouterr().out
    assert "3/3 champions bit-exact" in out
