"""Sharding autotuner (SA-on-the-framework) + HLO roofline parser."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch
from repro.distributed.autotune import (TuneProblem, autotune, decode_point,
                                        exhaustive_best, make_objective)
from repro.launch.hloparse import parse_hlo_costs


def test_autotune_matches_exhaustive():
    prob = TuneProblem(cfg=get_arch("stablelm-1.6b").model, seq=4096,
                       batch=256, chips=64)
    choice, cost = autotune(prob, n_chains=128, seed=0)
    _, best = exhaustive_best(prob)
    assert cost <= best * 1.02, (cost, best)


def test_cost_model_penalizes_oom():
    """kimi-k2 (1T params) pure-DP must be penalized (doesn't fit HBM)."""
    prob = TuneProblem(cfg=get_arch("kimi-k2-1t-a32b").model, seq=4096,
                       batch=256, chips=256)
    obj = make_objective(prob)
    dps = prob.dp_choices()
    x_dp_only = np.array([(dps.index(256) + 0.5) / len(dps), 0.1, 0.1,
                          0.1, 0.1])  # dp=256, no remat
    x_mixed = np.array([(dps.index(16) + 0.5) / len(dps), 0.5, 0.9,
                        0.9, 0.5])    # dp=16/tp=16, dots remat, ep, mb8
    f_dp = float(obj(jnp.asarray(x_dp_only)[None])[0])
    f_mix = float(obj(jnp.asarray(x_mixed)[None])[0])
    assert f_mix < f_dp, "OOM penalty must dominate the pure-DP point"


def test_decode_point_roundtrip():
    prob = TuneProblem(cfg=get_arch("deepseek-v2-lite-16b").model, seq=4096,
                       batch=256, chips=256)
    d = decode_point(prob, np.array([0.0, 0.99, 0.99, 0.99, 0.0]))
    assert d["dp"] == prob.dp_choices()[0]
    assert d["remat"] == "full" and d["ep"] is True
    assert d["microbatch"] == 8 and d["compress"] == "fp32"
    assert d["dp"] * d["tp"] == 256


_HLO = """
HloModule test

ENTRY %main (p0: f32[128,256], p1: f32[256,256]) -> f32[128,256] {
  %p0 = f32[128,256] parameter(0)
  %p1 = f32[256,256] parameter(1)
  %dot = f32[128,256] dot(%p0, %p1), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %ag = f32[256,256] all-gather(%p1), replica_groups={{0,1,2,3}}, dimensions={0}
  %ar = f32[128,256] all-reduce(%dot), replica_groups={{0,1,2,3}}, to_apply=%add
  ROOT %copy = f32[128,256] copy(%ar)
}
"""


def test_hloparse_wire_model():
    out = parse_hlo_costs(_HLO)
    wire = out["wire"]
    # all-gather: output 256*256*4 bytes * (n-1)/n with n=4
    assert wire["all-gather"] == pytest.approx(256 * 256 * 4 * 3 / 4)
    # all-reduce: 2 * in * (n-1)/n
    assert wire["all-reduce"] == pytest.approx(2 * 128 * 256 * 4 * 3 / 4)
    assert out["hbm_bytes"] > 0


def test_hloparse_skips_fused_elementwise():
    hlo = """
HloModule t

ENTRY %main (p0: f32[64]) -> f32[64] {
  %p0 = f32[64] parameter(0)
  %add = f32[64] add(%p0, %p0)
  ROOT %copy = f32[64] copy(%add)
}
"""
    out = parse_hlo_costs(hlo)
    # elementwise add is fusible: only the copy materializes (read + write)
    assert out["hbm_bytes"] == 64 * 4 * 2
