"""Pallas kernel validation: interpret=True vs the pure-jnp oracle, swept
over objectives x dims x chain counts x variants x dtypes (assignment
requirement: per-kernel shape/dtype sweep vs ref.py)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import objective_math as om
from repro.kernels import ops, ref, rng
from repro.kernels.metropolis_sweep import metropolis_sweep_pallas
from repro.objectives import functions as F

_MAKERS = {om.KID_SCHWEFEL: F.schwefel, om.KID_RASTRIGIN: F.rastrigin,
           om.KID_ACKLEY: F.ackley, om.KID_GRIEWANK: F.griewank}


def _x0(kid, chains, dim, seed=0):
    lo, hi = om.BOX[kid]
    u = jax.random.uniform(jax.random.PRNGKey(seed), (chains, dim))
    return (lo + u * (hi - lo)).astype(jnp.float32)


@pytest.mark.parametrize("kid", sorted(_MAKERS))
@pytest.mark.parametrize("variant", ["full", "delta"])
def test_kernel_matches_oracle(kid, variant):
    chains, dim, n_steps = 16, 8, 12
    x = _x0(kid, chains, dim)
    xk, fk = metropolis_sweep_pallas(x, 3.0, 42, 0, kid=kid,
                                     n_steps=n_steps, blk=8,
                                     variant=variant, interpret=True)
    xr, fr = ref.metropolis_sweep_ref(x, 3.0, 42, 0, kid=kid,
                                      n_steps=n_steps, variant=variant)
    np.testing.assert_allclose(np.asarray(xk), np.asarray(xr),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(fk), np.asarray(fr),
                               rtol=2e-3, atol=2e-3)


@pytest.mark.parametrize("chains,blk,dim", [(8, 8, 4), (32, 8, 16),
                                            (32, 16, 33), (64, 64, 128)])
def test_kernel_shape_sweep(chains, blk, dim):
    """Blocking must not change results (counter-based RNG on global chain
    index) — including non-lane-aligned dims."""
    kid = om.KID_SCHWEFEL
    x = _x0(kid, chains, dim, seed=dim)
    xk, fk = metropolis_sweep_pallas(x, 1.0, 7, 5, kid=kid, n_steps=6,
                                     blk=blk, variant="full", interpret=True)
    xr, fr = ref.metropolis_sweep_ref(x, 1.0, 7, 5, kid=kid, n_steps=6,
                                      variant="full")
    np.testing.assert_allclose(np.asarray(xk), np.asarray(xr),
                               rtol=2e-4, atol=2e-4)


def test_kernel_blocking_invariance():
    """Same chains, different block sizes => identical output."""
    kid = om.KID_RASTRIGIN
    x = _x0(kid, 32, 8)
    outs = []
    for blk in (8, 16, 32):
        xk, fk = metropolis_sweep_pallas(x, 2.0, 3, 0, kid=kid, n_steps=10,
                                         blk=blk, variant="delta",
                                         interpret=True)
        outs.append((np.asarray(xk), np.asarray(fk)))
    for xb, fb in outs[1:]:
        np.testing.assert_array_equal(outs[0][0], xb)
        np.testing.assert_array_equal(outs[0][1], fb)


def test_ops_dispatcher_selects_reference_on_cpu():
    kid = om.KID_ACKLEY
    x = _x0(kid, 8, 4)
    xo, fo = ops.metropolis_sweep(x, 1.0, 0, 0, kid=kid, n_steps=4,
                                  use_pallas=False)
    xr, fr = ref.metropolis_sweep_ref(x, 1.0, 0, 0, kid=kid, n_steps=4)
    np.testing.assert_array_equal(np.asarray(xo), np.asarray(xr))
    assert ops.resolve_use_pallas("auto") == (jax.default_backend() == "tpu")


def test_full_eval_matches_objectives():
    """Kernel-side objective math == the suite objectives."""
    for kid, maker in _MAKERS.items():
        obj = maker(16)
        x = _x0(kid, 8, 16, seed=kid)
        f_k = om.full_eval(kid, x, 16)
        np.testing.assert_allclose(np.asarray(f_k[:, 0]),
                                   np.asarray(obj(x)), rtol=2e-4, atol=2e-4)


# ------------------------------------------------------------------ RNG
def test_threefry_reference_vectors():
    """threefry2x32 against the published test vector (Random123)."""
    # zero key / zero counter and ff..f vectors from the Random123 suite
    x0, x1 = rng.threefry2x32(jnp.uint32(0), jnp.uint32(0),
                              jnp.uint32(0), jnp.uint32(0))
    assert (int(x0), int(x1)) == (0x6B200159, 0x99BA4EFE)
    x0, x1 = rng.threefry2x32(jnp.uint32(0xFFFFFFFF), jnp.uint32(0xFFFFFFFF),
                              jnp.uint32(0xFFFFFFFF), jnp.uint32(0xFFFFFFFF))
    assert (int(x0), int(x1)) == (0x1CB996FC, 0xBB002BE7)


def test_rng_uniformity_and_determinism():
    bits, u1, u2 = rng.draws3(123, jnp.arange(4096, dtype=jnp.uint32), 9)
    assert bool(jnp.all((u1 >= 0) & (u1 < 1)))
    # crude uniformity: mean within 3 sigma of 0.5
    m = float(jnp.mean(u1))
    assert abs(m - 0.5) < 3 * (1 / np.sqrt(12 * 4096))
    # determinism
    bits2, u1b, _ = rng.draws3(123, jnp.arange(4096, dtype=jnp.uint32), 9)
    np.testing.assert_array_equal(np.asarray(u1), np.asarray(u1b))
    # distinct streams per step and per chain
    _, u1c, _ = rng.draws3(123, jnp.arange(4096, dtype=jnp.uint32), 10)
    assert float(jnp.mean(u1 == u1c)) < 0.01
