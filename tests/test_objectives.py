"""Objective-suite correctness: known optima, batching, decomposable specs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")  # property tests need the dev extra
from hypothesis import given, settings, strategies as st

from repro.objectives import SUITE, get

_REFS = list(SUITE.keys())


# x_opt is quoted to low precision in the paper/ICEO dataset for these
# (pole location vs true minimizer): allow a looser band there.
_APPROX_XOPT = {"F19_a": 5e-3, "F19_b": 5e-3, "F11_a": 5e-3, "F11_b": 5e-3}


@pytest.mark.parametrize("ref", _REFS)
def test_known_minimum_value(ref):
    """f(x*) == f_opt (paper's reference values) where both are known."""
    obj = get(ref)
    if obj.x_opt is None or obj.f_opt is None:
        pytest.skip("optimum location unknown (paper marks '-')")
    fx = float(obj(jnp.asarray(obj.x_opt, jnp.float64 if False else jnp.float32)))
    # paper reference values are quoted to ~6 significant digits
    tol = _APPROX_XOPT.get(ref, max(1e-3, 5e-5 * abs(obj.f_opt)))
    assert abs(fx - obj.f_opt) < tol, \
        f"{ref}: f(x*)={fx} vs reference {obj.f_opt}"


@pytest.mark.parametrize("ref", _REFS)
def test_optimum_not_improvable_nearby(ref):
    """Random box samples never beat the known optimum (sanity of f_opt)."""
    obj = get(ref)
    if obj.f_opt is None:
        pytest.skip("f_opt unknown")
    x = obj.sample_uniform(jax.random.PRNGKey(0), (256,))
    fx = obj(x)
    assert float(jnp.min(fx)) >= obj.f_opt - max(1e-4, 1e-6 * abs(obj.f_opt)), ref


@pytest.mark.parametrize("ref", _REFS)
def test_batch_shapes(ref):
    obj = get(ref)
    x = obj.sample_uniform(jax.random.PRNGKey(1), (3, 5))
    fx = obj(x)
    assert fx.shape == (3, 5)
    # batched eval equals row-wise eval
    f_rows = jnp.stack([obj(x[i]) for i in range(3)])
    np.testing.assert_allclose(np.asarray(fx), np.asarray(f_rows),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("ref", [r for r in _REFS
                                 if get(r).decomposable is not None])
def test_decomposable_matches_full(ref):
    """init_acc + value == direct fn for decomposable objectives."""
    obj = get(ref)
    spec = obj.decomposable
    x = obj.sample_uniform(jax.random.PRNGKey(2), (64,)).astype(jnp.float32)
    S, P = spec.init_acc(x)
    f_acc = spec.value(S, P, obj.dim)
    f_dir = obj(x)
    np.testing.assert_allclose(np.asarray(f_acc), np.asarray(f_dir),
                               rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("ref", [r for r in _REFS
                                 if get(r).decomposable is not None])
def test_decomposable_single_coordinate_update(ref):
    """O(1) accumulator update after changing one coordinate equals a full
    recomputation — the delta-eval correctness property."""
    obj = get(ref)
    spec = obj.decomposable
    key = jax.random.PRNGKey(3)
    x = obj.sample_uniform(key, (8,)).astype(jnp.float32)
    S, (logP, sgnP) = spec.init_acc(x)
    d = 0
    newval = jnp.asarray(obj.lower[d] + 0.37 * (obj.upper[d] - obj.lower[d]),
                         jnp.float32)
    idx = jnp.full((8, 1), d)
    s_old, p_old = spec.terms(x[:, d:d + 1], idx.astype(x.dtype))
    s_new, p_new = spec.terms(jnp.broadcast_to(newval, (8, 1)),
                              idx.astype(x.dtype))
    S1 = S - s_old.sum(-2) + s_new.sum(-2)
    logP1 = (logP - jnp.log(jnp.maximum(jnp.abs(p_old), 1e-30)).sum(-2)
             + jnp.log(jnp.maximum(jnp.abs(p_new), 1e-30)).sum(-2))
    sgnP1 = sgnP * jnp.prod(jnp.sign(p_old) * jnp.sign(p_new), -2)
    f_delta = spec.value(S1, (logP1, sgnP1), obj.dim)

    x2 = x.at[:, d].set(newval)
    f_full = obj(x2)
    np.testing.assert_allclose(np.asarray(f_delta), np.asarray(f_full),
                               rtol=3e-4, atol=3e-4)


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2 ** 31 - 1),
       ref=st.sampled_from(["F0_b", "F1_a", "F8_a", "F13_a", "F15", "F14"]))
def test_property_bounds_and_finiteness(seed, ref):
    """Any in-box point evaluates finite; out-of-box clamping of samples."""
    obj = get(ref)
    x = obj.sample_uniform(jax.random.PRNGKey(seed), (16,))
    assert bool(jnp.all(x >= jnp.asarray(obj.lower) - 1e-6))
    assert bool(jnp.all(x <= jnp.asarray(obj.upper) + 1e-6))
    assert bool(jnp.all(jnp.isfinite(obj(x))))


def test_suite_is_paper_table8():
    """41 problems, 19 families, dims as listed in paper Table 8."""
    assert len(SUITE) == 41
    dims = {ref: get(ref).dim for ref in SUITE}
    expected = {"F0_a": 8, "F0_g": 512, "F1_d": 400, "F2": 2, "F8_c": 400,
                "F13_b": 400, "F15": 10, "F18_c": 4, "F19_b": 5}
    for ref, n in expected.items():
        assert dims[ref] == n, f"{ref}: dim {dims[ref]} != paper {n}"
