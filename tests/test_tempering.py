"""Replica-exchange workload classes: parallel tempering + population
annealing (tentpole gate for the PT/PA co-batching PR).

Three layers of differential evidence, mirroring test_macro_tick.py:

* operator units — the even/odd PT partner maps, the deterministic
  direction of the Metropolis swap test, and PA's integer-quantized
  Boltzmann resampling (champion weight is exact, off-class rows are
  untouched bit-for-bit);
* serving differentials — PT and PA tenants co-batched with plain SA
  (sync and SOS exchange) in ONE fused device program must be bit-equal
  across macro-tick K, across preemption/drain/resize, and against the
  ``run_standalone`` oracle (placement invariance: all class RNG draws
  key on logical chain / pair indices, never packed rows);
* policy — PT jobs are never width-shrunk mid-flight (a PT job's width
  IS its temperature-ladder resolution), while PA jobs self-shrink on
  ESS collapse and the oracle re-derives those shrinks from the same fx
  stream rather than replaying them as an external schedule.
"""
import dataclasses
from types import SimpleNamespace

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import exchange as exch
from repro.service import (EngineConfig, SARequest, SAServeEngine,
                           run_standalone)
from repro.service.engine import _pa_dbeta, _pt_partners
from repro.service.scheduler import (AdmissionScheduler,
                                     SchedulerConfig)

CPS = 8


def _req(req_id, objective="rastrigin", **kw):
    kw.setdefault("dim", 4)
    kw.setdefault("n_chains", CPS)
    kw.setdefault("T0", 50.0)
    kw.setdefault("T_min", 1.0)
    kw.setdefault("rho", 0.8)      # 18-level ladder
    kw.setdefault("N", 10)
    return SARequest(req_id=req_id, objective=objective,
                     seed=100 + req_id, **kw)


def _cfg(k=1, n_devices=1, **kw):
    kw.setdefault("n_slots", 4)
    return EngineConfig(chains_per_slot=CPS, n_devices=n_devices,
                        macro_k=k, use_pallas=False, **kw)


#: All three workload classes plus both SA exchange flavours in one pool:
#: a 2-slot PT tenant (16-rung ladder spanning two blocks), a PA tenant,
#: an SOS tenant and a plain sync tenant — 5 blocks, so the fused path
#: also sees a pad block.
MIX = [
    dict(objective="rastrigin", method="pt"),
    dict(objective="ackley", dim=8, method="pa"),
    dict(objective="schwefel", exchange="sos"),
    dict(objective="griewank", n_chains=2 * CPS, method="pt"),
    dict(objective="rastrigin", dim=8),
]


def _mix(**extra):
    return [_req(i, **{**kw, **extra}) for i, kw in enumerate(MIX)]


def _serve(reqs, k, n_devices=2, ops=None, **cfg_kw):
    cfg = _cfg(k=k, n_devices=n_devices, **cfg_kw)
    engine = SAServeEngine(cfg)
    for r in reqs:
        engine.submit(r)
    if ops is not None:
        ops(engine)
    results = {r.req_id: r for r in engine.run(max_ticks=2000)}
    return results, engine, cfg


def _assert_bit_equal(a, b, *, ticks=True):
    assert a.keys() == b.keys()
    for rid in a:
        ra, rb = a[rid], b[rid]
        assert ra.champion_history == rb.champion_history, rid
        assert ra.f_best == rb.f_best, rid
        np.testing.assert_array_equal(ra.x_best, rb.x_best)
        assert ra.finish_reason == rb.finish_reason, rid
        assert ra.levels_run == rb.levels_run, rid
        assert ra.n_evals == rb.n_evals, rid
        if ticks:
            assert ra.finish_tick == rb.finish_tick, rid
            assert ra.first_tick == rb.first_tick, rid


# ------------------------------------------------------- operator units
def test_pt_partner_maps():
    """Even/odd alternation: parity 0 pairs (0,1)(2,3)…; parity 1 leaves
    rung 0 alone and pairs (1,2)(3,4)…; out-of-range partners are self."""
    p0, lo0 = _pt_partners(8, 0)
    assert p0.tolist() == [1, 0, 3, 2, 5, 4, 7, 6]
    assert lo0.tolist() == [0, 0, 2, 2, 4, 4, 6, 6]
    p1, lo1 = _pt_partners(8, 1)
    assert p1.tolist() == [0, 2, 1, 4, 3, 6, 5, 7]
    assert lo1.tolist() == [0, 1, 1, 3, 3, 5, 5, 7]
    # odd ladder: the dangling top rung is its own partner at parity 0
    p0o, _ = _pt_partners(5, 0)
    assert p0o.tolist() == [1, 0, 3, 2, 4]
    # the map is an involution (partner of my partner is me)
    for p in (p0, p1, p0o):
        assert p[p].tolist() == list(range(len(p)))


def test_pt_swap_deterministic_directions_and_symmetry():
    """log_a >= 0 (lower energy sitting at the hotter rung) accepts with
    probability exactly 1; a huge unfavourable gap clips to exp(-80) and
    rejects under the fixed counter-based draw.  Accepted pairs exchange
    states symmetrically — both rows gather from the pre-swap arrays."""
    n = 8
    t_rung = jnp.asarray(np.geomspace(50.0, 1.0, n), jnp.float32)
    partner, pairlo = _pt_partners(n, 0)
    seed_c = jnp.full((n,), 7, jnp.uint32)
    lvl = jnp.full((n,), 3, jnp.uint32)
    is_pt = jnp.ones((n,), bool)
    x = jnp.arange(n, dtype=jnp.float32)[:, None] * jnp.ones((1, 3))

    fx_up = jnp.arange(n, dtype=jnp.float32)       # colder rung is worse
    x2, f2 = exch.pt_swap_segmented(x, fx_up, t_rung, jnp.asarray(partner),
                                    jnp.asarray(pairlo), seed_c, lvl, is_pt)
    np.testing.assert_array_equal(np.asarray(f2), np.asarray(fx_up)[partner])
    np.testing.assert_array_equal(np.asarray(x2), np.asarray(x)[partner])

    fx_dn = jnp.asarray([1e6, 0.0] * (n // 2), jnp.float32)  # hopeless swap
    x3, f3 = exch.pt_swap_segmented(x, fx_dn, t_rung, jnp.asarray(partner),
                                    jnp.asarray(pairlo), seed_c, lvl, is_pt)
    np.testing.assert_array_equal(np.asarray(f3), np.asarray(fx_dn))
    np.testing.assert_array_equal(np.asarray(x3), np.asarray(x))

    # masked off: bitwise identity even for the favourable configuration
    x4, f4 = exch.pt_swap_segmented(x, fx_up, t_rung, jnp.asarray(partner),
                                    jnp.asarray(pairlo), seed_c, lvl,
                                    jnp.zeros((n,), bool))
    np.testing.assert_array_equal(np.asarray(f4), np.asarray(fx_up))


def test_pa_resample_concentrates_and_masks():
    """A dbeta large enough that every non-champion weight quantizes to 0
    makes resampling deterministic: all PA rows adopt the champion.  Rows
    outside the PA mask pass through bit-exactly."""
    n = 8
    seg = jnp.asarray([0] * 4 + [1] * 4, jnp.int32)
    fx = jnp.asarray([5.0, 1.0, 9.0, 7.0, 3.0, 2.0, 8.0, 4.0], jnp.float32)
    fb_seg = jnp.asarray([1.0, 2.0, np.inf], jnp.float32)
    x = jnp.arange(n, dtype=jnp.float32)[:, None] * jnp.ones((1, 2))
    seg_lo = jnp.asarray([0] * 4 + [4] * 4, jnp.int32)
    seg_hi = jnp.asarray([4] * 4 + [8] * 4, jnp.int32)
    dbeta = jnp.full((n,), 50.0, jnp.float32)
    seed_c = jnp.full((n,), 3, jnp.uint32)
    cidx = jnp.arange(n, dtype=jnp.uint32)
    lvl = jnp.full((n,), 2, jnp.uint32)
    is_pa = seg == 0
    x2, f2 = exch.pa_resample_segmented(x, fx, fb_seg, seg, seg_lo, seg_hi,
                                        dbeta, seed_c, cidx, lvl, is_pa)
    np.testing.assert_array_equal(np.asarray(f2)[:4], np.full(4, 1.0))
    np.testing.assert_array_equal(np.asarray(x2)[:4],
                                  np.broadcast_to(np.asarray(x)[1], (4, 2)))
    np.testing.assert_array_equal(np.asarray(f2)[4:], np.asarray(fx)[4:])
    np.testing.assert_array_equal(np.asarray(x2)[4:], np.asarray(x)[4:])


def test_pa_dbeta_and_rungs():
    """dbeta is the inverse-temperature increment of one cooling step
    (beta' - beta at T' = rho*T), computed in float64; pt_rungs spans
    [T0, T_min] geometrically with the endpoints exact."""
    assert _pa_dbeta(2.0, 0.8) == pytest.approx(1 / 1.6 - 1 / 2.0)
    r = _req(0, method="pt").pt_rungs(16)
    assert r.shape == (16,) and r.dtype == np.float32
    assert r[0] == np.float32(50.0) and r[-1] == np.float32(1.0)
    assert np.all(np.diff(r) < 0)
    assert _req(0).pt_rungs(1).tolist() == [np.float32(1.0)]


def test_per_chain_temperature_sweep_paths_agree():
    """The per-chain temperature column (PT's rung layout) must be
    bitwise inert when it merely repeats the per-block schedule, and the
    Pallas kernel (interpret mode) must match the jnp oracle when the
    column carries a real ladder."""
    from repro.kernels import ops
    rng = np.random.default_rng(0)
    blk, n_blocks = 8, 3
    x = rng.standard_normal((n_blocks * blk, 5)).astype(np.float32)
    kids = np.asarray([0, 1, 2], np.int32)
    T_blocks = np.asarray([5.0, 2.0, 1.0], np.float32)
    seeds = np.asarray([11, 22, 33], np.uint32)
    step0s = np.zeros(3, np.uint32)
    base = np.asarray([0, 0, 8], np.uint32)
    kw = dict(n_steps=4, blk=blk)
    a = ops.metropolis_sweep_slots(x, kids, T_blocks, seeds, step0s, base,
                                   use_pallas=False, **kw)
    b = ops.metropolis_sweep_slots(x, kids, T_blocks, seeds, step0s, base,
                                   use_pallas=False,
                                   T_chain=np.repeat(T_blocks, blk), **kw)
    np.testing.assert_array_equal(np.asarray(a[0]), np.asarray(b[0]))
    np.testing.assert_array_equal(np.asarray(a[1]), np.asarray(b[1]))
    ladder = np.geomspace(5.0, 0.5, n_blocks * blk).astype(np.float32)
    c = ops.metropolis_sweep_slots(x, kids, T_blocks, seeds, step0s, base,
                                   use_pallas=False, T_chain=ladder, **kw)
    d = ops.metropolis_sweep_slots(x, kids, T_blocks, seeds, step0s, base,
                                   use_pallas=True, interpret=True,
                                   T_chain=ladder, **kw)
    np.testing.assert_array_equal(np.asarray(c[0]), np.asarray(d[0]))
    np.testing.assert_array_equal(np.asarray(c[1]), np.asarray(d[1]))
    assert not np.array_equal(np.asarray(c[1]), np.asarray(a[1]))


# ------------------------------------------------------ request plumbing
def test_request_validation():
    with pytest.raises(ValueError, match="exchange"):
        _req(0, exchange="bogus")
    with pytest.raises(ValueError, match="method"):
        _req(0, method="tempering")
    with pytest.raises(ValueError, match="pa_ess_ratio"):
        _req(0, pa_ess_ratio=0.5)          # needs method='pa'
    with pytest.raises(ValueError):
        _req(0, method="pa", pa_ess_ratio=1.0)
    assert sorted(exch.EXCHANGES) == ["async", "sos", "sync"]


def test_pt_jobs_are_not_degradable_mid_flight():
    """The scheduler's shrink planners must skip PT tenants even under a
    degrade overload policy; PA and plain SA stay shrinkable."""
    sched = AdmissionScheduler(SchedulerConfig(overload="degrade"))
    job = lambda m: SimpleNamespace(req=_req(0, method=m))  # noqa: E731
    assert not sched._degradable(job("pt"))
    assert sched._degradable(job("pa"))
    assert sched._degradable(job("sa"))


# --------------------------------------------------- serving differentials
@pytest.mark.parametrize("k", (1, 4))
def test_cobatched_classes_bit_exact_vs_standalone(k):
    """The headline gate: PT + PA + SOS + sync tenants in one fused
    program, every champion bit-equal to its standalone single-tenant
    run, at K=1 and K=4."""
    served, _, cfg = _serve(_mix(), k=k)
    for req in _mix():
        solo = run_standalone(req, cfg)
        assert served[req.req_id].f_best == solo.f_best, req.req_id
        assert served[req.req_id].champion_history == \
            solo.champion_history, req.req_id
        np.testing.assert_array_equal(served[req.req_id].x_best, solo.x_best)


def test_fused_k_matches_k1():
    base, _, _ = _serve(_mix(), k=1)
    fused, _, _ = _serve(_mix(), k=4)
    _assert_bit_equal(base, fused)


@pytest.mark.parametrize("k", (1, 4))
def test_classes_survive_preempt_resize_drain(k):
    """Operator actions at K-aligned ticks: the preempted tenant is a PT
    job (checkpoint must carry rung states), the fleet resizes and a
    shard drains mid-stream — still bit-equal to K=1 and the oracle."""
    def ops(engine):
        engine.schedule_op(8, lambda: engine.preempt(0))
        engine.schedule_op(8, lambda: engine.resize(3))
        engine.schedule_op(16, lambda: engine.drain(1))

    base, _, _ = _serve(_mix(), k=1, ops=ops)
    fused, _, cfg = _serve(_mix(), k=k, ops=ops)
    _assert_bit_equal(base, fused)
    for req in _mix():
        res = fused[req.req_id]
        sched = [(lvl, to) for lvl, _frm, to in res.shrink_events]
        solo = run_standalone(req, cfg, shrink_schedule=sched)
        assert res.champion_history == solo.champion_history, req.req_id


def test_sos_serving_bit_exact_vs_standalone():
    """Satellite gate: exchange='sos' requests served in a shared pool
    reproduce the standalone SOS trajectory exactly (the adoption draw
    keys on logical chain indices, not packed rows)."""
    reqs = [_req(0, exchange="sos"),
            _req(1, objective="ackley", exchange="sos", n_chains=2 * CPS),
            _req(2, objective="schwefel")]
    served, _, cfg = _serve(reqs, k=1)
    for req in reqs:
        solo = run_standalone(req, cfg)
        assert served[req.req_id].f_best == solo.f_best, req.req_id
        assert served[req.req_id].champion_history == solo.champion_history


def test_pa_ess_self_shrink_rederived_by_oracle():
    """A PA tenant whose ESS collapses halves its own width; the events
    land in pa_shrink_events (not shrink_events) and the standalone
    oracle re-derives them from the identical fx stream — no external
    shrink schedule may be fed back in."""
    req = _req(0, method="pa", n_chains=2 * CPS, pa_ess_ratio=0.9)
    served, _, cfg = _serve([req], k=1, n_devices=1)
    res = served[0]
    assert res.pa_shrink_events, "ESS shrink never fired"
    assert not res.shrink_events
    lvl, frm, to = res.pa_shrink_events[0]
    assert (frm, to) == (2 * CPS, CPS)
    solo = run_standalone(req, cfg)           # deliberately no schedule
    assert res.f_best == solo.f_best
    assert res.champion_history == solo.champion_history
    assert solo.pa_shrink_events == res.pa_shrink_events


def test_pa_ess_off_means_no_self_shrink():
    req = _req(0, method="pa", n_chains=2 * CPS)
    served, _, _ = _serve([req], k=1, n_devices=1)
    assert not served[0].pa_shrink_events


def test_degraded_pt_admission_builds_coarser_ladder():
    """Admission-time degrade is allowed for PT: a request granted fewer
    chains anneals a coarser ladder from level 0, bit-equal to a
    standalone run at the granted width."""
    reqs = [_req(0, method="pt", n_chains=4 * CPS, min_chains=CPS,
                 on_overload="degrade", deadline=0.0, priority=0),
            _req(1, objective="ackley", priority=5),     # admitted first,
            _req(2, objective="schwefel", priority=5)]   # squeeze the pool
    cfg = _cfg(k=1, n_devices=1, n_slots=4,
               scheduler=SchedulerConfig(overload="degrade",
                                         default_deadline=0.0))
    engine = SAServeEngine(cfg)
    for r in reqs:
        engine.submit(r)
    served = {r.req_id: r for r in engine.run(max_ticks=2000)}
    res = served[0]
    assert res.completed and res.granted_chains < 4 * CPS
    solo = run_standalone(
        dataclasses.replace(reqs[0], n_chains=res.granted_chains), cfg)
    assert res.f_best == solo.f_best
    assert res.champion_history == solo.champion_history
