"""Closed-loop autoscaler + completion-deadline SLOs.

Deterministic tests: ladder truncation fires under tight finish
deadlines and replays bit-exactly through ``run_standalone`` (at K=1 and
fused K=4), the ``min_levels`` floor holds, the controller grows the
fleet under a burst and drains it in the trough without losing work, and
``run_stream``'s idle fast-forward never jumps past a controller
sampling tick (the sparse-trace regression).

Property suite (skipped when hypothesis is absent): no resize thrash
under cooldown, no lost/duplicated requests across autoscaler drains,
truncation never below ``min_levels``, truncated trajectories bit-exact
vs ``run_standalone`` at every ladder level.
"""
import dataclasses

import pytest

from repro.service import (ArrivalProcess, Autoscaler, AutoscalerConfig,
                           EngineConfig, SARequest, SAServeEngine,
                           run_standalone)

CPS = 8


def _req(req_id, **kw):
    kw.setdefault("objective", "rastrigin")
    kw.setdefault("dim", 4)
    kw.setdefault("n_chains", CPS)
    kw.setdefault("T0", 50.0)
    kw.setdefault("T_min", 1.0)
    kw.setdefault("rho", 0.8)
    kw.setdefault("N", 10)
    return SARequest(req_id=req_id, seed=100 + req_id, **kw)


def _cfg(n_slots=4, **kw):
    return EngineConfig(n_slots=n_slots, chains_per_slot=CPS,
                        use_pallas=False, **kw)


def _ctl(**kw):
    kw.setdefault("min_shards", 1)
    kw.setdefault("max_shards", 3)
    kw.setdefault("sample_every", 4)
    kw.setdefault("low_util", 0.5)
    kw.setdefault("window", 2)
    kw.setdefault("cooldown", 8)
    return Autoscaler(AutoscalerConfig(**kw))


# ----------------------------------------------------------- SLO schema
def test_finish_deadline_and_min_levels_validated():
    _req(0, finish_deadline=50.0, min_levels=3)          # valid
    with pytest.raises(ValueError):
        _req(1, finish_deadline=0.0)
    with pytest.raises(ValueError):
        _req(2, min_levels=0)
    with pytest.raises(ValueError):
        _req(3, min_levels=100)          # > n_levels (ladder is ~36)


# ---------------------------------------------------- ladder truncation
@pytest.mark.parametrize("macro_k", [1, 4])
def test_truncation_fires_and_replays_bit_exact(macro_k):
    # Deadline far below the ladder length: the planner must cut the
    # ladder, and the truncated trajectory must replay bit-for-bit.
    eng = SAServeEngine(_cfg(macro_k=macro_k))
    req = _req(0, finish_deadline=12.0, min_levels=2)
    eng.submit(req)
    results = eng.run()
    (res,) = results
    assert res.completed and res.finish_reason == "truncated"
    assert res.truncated
    assert res.n_truncations >= 1
    final_levels = res.truncate_events[-1][2]
    assert final_levels < req.n_levels
    assert res.levels_run == final_levels
    assert eng.stats()["truncations"] == res.n_truncations
    cuts = [(lvl, to) for lvl, _frm, to in res.truncate_events]
    alone = run_standalone(req, eng.cfg, truncate_schedule=cuts)
    assert alone.f_best == res.f_best
    assert (alone.x_best == res.x_best).all()
    assert alone.levels_run == res.levels_run


def test_truncation_respects_min_levels_floor():
    eng = SAServeEngine(_cfg())
    req = _req(0, finish_deadline=1.0, min_levels=7)     # hopeless deadline
    eng.submit(req)
    (res,) = eng.run()
    assert res.completed
    assert res.levels_run >= 7
    for _lvl, frm, to in res.truncate_events:
        assert 7 <= to < frm


def test_no_deadline_means_no_truncation():
    eng = SAServeEngine(_cfg())
    eng.submit(_req(0))
    (res,) = eng.run()
    assert not res.truncated and res.truncate_events == []
    assert res.finish_reason == "ladder"


# ------------------------------------------------------ controller loop
def _diurnal(reqs, rate=0.4, period=60.0, seed=3):
    return ArrivalProcess.diurnal(reqs, rate=rate, period=period,
                                  amplitude=0.9, seed=seed)


def test_autoscaler_grows_under_burst_and_drains_after():
    # The trace must span more than one diurnal cycle so the trough
    # falls *inside* the run (arrivals still pending): the first peak's
    # jobs drain, the controller sees idle samples, and shrinks before
    # the second peak grows the fleet again.
    reqs = [_req(i) for i in range(40)]
    ctl = _ctl()
    eng = SAServeEngine(_cfg(n_slots=2))
    eng.attach_controller(ctl)
    results = eng.run_stream(_diurnal(reqs, rate=0.2, period=120.0),
                             max_ticks=5000)
    assert len(results) == len(reqs)
    assert {r.req_id for r in results} == {q.req_id for q in reqs}
    kinds = [k for _, k, _, _ in ctl.decisions]
    assert "grow" in kinds               # peak forced a scale-up
    assert "shrink" in kinds             # trough drained it back
    assert ctl.samples > 0
    for tick, _k, frm, to in ctl.decisions:
        assert 1 <= to <= ctl.cfg.max_shards and to != frm


def test_autoscaler_decisions_deterministic():
    def history():
        reqs = [_req(i) for i in range(16)]
        ctl = _ctl()
        eng = SAServeEngine(_cfg(n_slots=2))
        eng.attach_controller(ctl)
        res = eng.run_stream(_diurnal(reqs), max_ticks=5000)
        return ctl.decisions, sorted((r.req_id, r.f_best) for r in res)

    d1, r1 = history()
    d2, r2 = history()
    assert d1 == d2                      # identical scaling history
    assert r1 == r2                      # identical champions


def test_autoscaler_respects_fleet_bounds():
    reqs = [_req(i) for i in range(20)]
    ctl = _ctl(max_shards=2)
    eng = SAServeEngine(_cfg(n_slots=2))
    eng.attach_controller(ctl)
    eng.run_stream(ArrivalProcess.trace(reqs, [1.0] * len(reqs)),
                   max_ticks=5000)
    assert all(to <= 2 for _, _, _, to in ctl.decisions)
    assert len(eng.live_shards) >= 1


# ----------------------------------- idle fast-forward regression (#4)
def test_run_stream_idle_jump_capped_at_sampling_tick():
    # Sparse trace: a long idle gap between two arrivals.  Without the
    # cap, run_stream fast-forwards over the gap in one jump and the
    # controller never sees the idle fleet — the scale-down decision
    # that must land *inside* the gap is lost.
    reqs = [_req(i) for i in range(4)]
    times = [1.0, 2.0, 3.0, 400.0]
    ctl = _ctl(sample_every=16)
    eng = SAServeEngine(_cfg(n_slots=2))
    eng.attach_controller(ctl)
    results = eng.run_stream(ArrivalProcess.trace(reqs, times),
                             max_ticks=5000)
    assert len(results) == 4
    first_busy = max(r.finish_tick for r in results[:3])
    shrinks = [t for t, k, _, _ in ctl.decisions if k == "shrink"]
    assert any(first_busy < t < 400 for t in shrinks), (
        "no scale-down decision inside the idle gap", ctl.decisions)
    # Samples kept their cadence across the gap: every sample tick is a
    # multiple of the cadence grid, none skipped between busy and 400.
    assert ctl.samples >= (400 - first_busy) // 16


# ----------------------------------------------------- property suite
# Guarded import (not module-level importorskip: the deterministic tests
# above must run even without hypothesis installed).
try:
    from hypothesis import HealthCheck, given, settings
    from hypothesis import strategies as st
    _HAS_HYPOTHESIS = True
except ImportError:                              # pragma: no cover
    _HAS_HYPOTHESIS = False

if _HAS_HYPOTHESIS:
    _slow = settings(max_examples=8, deadline=None,
                     suppress_health_check=[HealthCheck.too_slow])

    @pytest.mark.slow
    @_slow
    @given(cooldown=st.integers(4, 40), rate=st.floats(0.2, 0.8),
           seed=st.integers(0, 5))
    def test_property_no_resize_thrash_under_cooldown(cooldown, rate,
                                                      seed):
        reqs = [_req(i) for i in range(12)]
        ctl = _ctl(cooldown=cooldown)
        eng = SAServeEngine(_cfg(n_slots=2))
        eng.attach_controller(ctl)
        eng.run_stream(_diurnal(reqs, rate=rate, seed=seed),
                       max_ticks=5000)
        ticks = [t for t, _, _, _ in ctl.decisions]
        assert all(b - a >= cooldown
                   for a, b in zip(ticks, ticks[1:])), (
            "fleet-size changes closer than the cooldown", ctl.decisions)

    @pytest.mark.slow
    @_slow
    @given(rate=st.floats(0.2, 1.0), seed=st.integers(0, 5),
           n=st.integers(6, 18))
    def test_property_no_lost_or_duplicated_requests(rate, seed, n):
        reqs = [_req(i) for i in range(n)]
        ctl = _ctl()
        eng = SAServeEngine(_cfg(n_slots=2))
        eng.attach_controller(ctl)
        results = eng.run_stream(_diurnal(reqs, rate=rate, seed=seed),
                                 max_ticks=8000)
        ids = [r.req_id for r in results]
        assert sorted(ids) == sorted(q.req_id for q in reqs)
        assert len(ids) == len(set(ids))

    @pytest.mark.slow
    @_slow
    @given(deadline=st.floats(1.0, 30.0), min_levels=st.integers(1, 10),
           seed=st.integers(0, 5))
    def test_property_truncation_floor_and_bit_exact_replay(deadline,
                                                            min_levels,
                                                            seed):
        base = _req(0)
        req = dataclasses.replace(base, seed=200 + seed,
                                  finish_deadline=deadline,
                                  min_levels=min(min_levels,
                                                 base.n_levels))
        eng = SAServeEngine(_cfg())
        eng.submit(req)
        (res,) = eng.run()
        assert res.completed
        assert res.levels_run >= req.min_levels
        for _lvl, frm, to in res.truncate_events:
            assert req.min_levels <= to < frm <= req.n_levels
        cuts = [(lvl, to) for lvl, _frm, to in res.truncate_events]
        alone = run_standalone(req, eng.cfg, truncate_schedule=cuts)
        assert alone.f_best == res.f_best
        assert alone.levels_run == res.levels_run
