"""Preemptive scheduling + SLO-aware admission control.

Covers the PR-3 guarantees end to end:

* swap-out/swap-in is **bit-exact**: a request preempted (at *every*
  temperature level of its ladder) and later resumed produces the same
  best value, best x and per-level champion trajectory as an
  uninterrupted standalone run;
* scheduler-driven preemption: an urgent 'preempt'-class arrival evicts
  the lowest-effective-priority tenant(s), bounded by the preemption
  budget, and the victim resumes and completes bit-exactly;
* under a seeded 3x-saturating Poisson load the 'reject' and 'degrade'
  policies keep p99 queueing delay bounded by the deadline SLO and the
  queue itself bounded, while the no-policy baseline grows without bound;
* swap-out/swap-in adds **no dispatch groups**: the PR-2 compile-count
  guarantee extends to a preempt/resume schedule.
"""
import dataclasses

import numpy as np
import pytest

from repro.service import (ArrivalProcess, EngineConfig, SARequest,
                           SAServeEngine, SchedulerConfig, run_standalone)

CPS = 8


def _req(req_id, **kw):
    kw.setdefault("objective", "rastrigin")
    kw.setdefault("dim", 4)
    kw.setdefault("n_chains", CPS)
    kw.setdefault("T0", 50.0)
    kw.setdefault("T_min", 1.0)
    kw.setdefault("rho", 0.55)   # 7-level ladder
    kw.setdefault("N", 10)
    return SARequest(req_id=req_id, seed=100 + req_id, **kw)


def _cfg(n_slots=4, **kw):
    return EngineConfig(n_slots=n_slots, chains_per_slot=CPS,
                        use_pallas=False, **kw)


def _assert_bit_exact(res, solo):
    assert res.f_best == solo.f_best
    np.testing.assert_array_equal(res.x_best, solo.x_best)
    assert res.levels_run == solo.levels_run
    assert res.champion_history == solo.champion_history


# ------------------------------------------------------- bit-exact resume
def test_preempt_resume_bit_exact_at_every_level():
    """Acceptance criterion: preempt at every temperature level of a short
    ladder; the resumed result (best value, best x, champion trajectory)
    is bit-exact with the uninterrupted standalone run.  A high-priority
    filler occupies the single slot while the victim is swapped out, so
    the resume really happens later and on re-assigned slots."""
    cfg = _cfg(n_slots=1)
    victim = _req(0)
    solo = run_standalone(victim, cfg)
    assert solo.levels_run == victim.n_levels > 2
    for level in range(1, victim.n_levels):
        engine = SAServeEngine(cfg)
        engine.submit(victim)
        for _ in range(level):
            engine.tick()
        assert engine.preempt(victim.req_id)
        # Filler takes the freed slot (higher priority than the aged
        # victim), forcing a real swap gap before resume.
        engine.submit(_req(1, priority=50, rho=0.5, T0=8.0))
        results = {r.req_id: r for r in engine.run(max_ticks=200)}
        res = results[victim.req_id]
        assert res.preempted_ticks == [level]
        assert len(res.resumed_ticks) == 1
        assert res.resumed_ticks[0] > level  # sat out at least one tick
        _assert_bit_exact(res, solo)
        # The filler is untouched by hosting a swapped neighbour.
        _assert_bit_exact(results[1], run_standalone(
            _req(1, priority=50, rho=0.5, T0=8.0), cfg))


def test_preempt_noop_for_unknown_or_queued_request():
    engine = SAServeEngine(_cfg(n_slots=1))
    assert not engine.preempt(123)           # never submitted
    engine.submit(_req(0))
    assert not engine.preempt(0)             # queued, not yet active
    engine.tick()
    assert engine.preempt(0)                 # now active -> swapped
    assert not engine.preempt(0)             # already swapped out


def test_double_preempt_same_request_resumes_twice():
    cfg = _cfg(n_slots=1)
    victim = _req(0)
    engine = SAServeEngine(cfg)
    engine.submit(victim)
    engine.tick()
    assert engine.preempt(0)
    engine.tick()                            # resumes (pool free)
    engine.tick()
    assert engine.preempt(0)
    res = engine.run(max_ticks=100)[0]
    assert len(res.preempted_ticks) == 2
    assert len(res.resumed_ticks) == 2
    _assert_bit_exact(res, run_standalone(victim, cfg))


# ------------------------------------------- scheduler-driven preemption
def test_urgent_request_preempts_lowest_priority_tenant():
    """'preempt'-class arrival evicts the cheapest active job, runs at
    once, and the victim resumes bit-exactly after it."""
    cfg = _cfg(n_slots=2)
    low = _req(0, n_chains=2 * CPS, priority=0)      # fills the pool
    urgent = _req(1, priority=9, on_overload="preempt",
                  rho=0.5, T0=8.0)                   # 3-level ladder
    engine = SAServeEngine(cfg)
    engine.submit(low)
    engine.tick()
    engine.tick()
    engine.submit(urgent)
    results = {r.req_id: r for r in engine.run(max_ticks=200)}
    assert results[0].preempted_ticks == [2]
    assert len(results[0].resumed_ticks) == 1
    # The urgent request never queued behind the low-priority ladder.
    assert results[1].queue_delay_ticks <= 1.0
    assert results[1].preempted_ticks == []
    _assert_bit_exact(results[0], run_standalone(low, cfg))
    _assert_bit_exact(results[1], run_standalone(urgent, cfg))


def test_preemption_budget_bounds_evictions_per_tick():
    """budget=1: an urgent two-slot request facing two one-slot tenants
    must not evict both in one tick — it waits until eviction + a free
    slot suffice, and never evicts uselessly."""
    cfg = _cfg(n_slots=2,
               scheduler=SchedulerConfig(preemption_budget=1, aging=0.0))
    engine = SAServeEngine(cfg)
    engine.submit(_req(0, priority=0))
    engine.submit(_req(1, priority=0))
    engine.tick()                                    # both active
    engine.submit(_req(2, priority=9, n_chains=2 * CPS,
                       on_overload="preempt"))
    engine.tick()
    # All-or-nothing: one eviction cannot seat a two-slot request, so
    # nothing was preempted and both tenants still run.
    assert engine.preemptions == 0
    assert engine.n_active == 2
    results = {r.req_id: r for r in engine.run(max_ticks=300)}
    assert set(results) == {0, 1, 2}
    budget2 = _cfg(n_slots=2,
                   scheduler=SchedulerConfig(preemption_budget=2, aging=0.0))
    engine = SAServeEngine(budget2)
    engine.submit(_req(0, priority=0))
    engine.submit(_req(1, priority=0))
    engine.tick()
    engine.submit(_req(2, priority=9, n_chains=2 * CPS,
                       on_overload="preempt"))
    engine.tick()
    assert engine.preemptions == 2                   # budget allows the pair
    results = {r.req_id: r for r in engine.run(max_ticks=300)}
    for i, req in enumerate([_req(0, priority=0), _req(1, priority=0)]):
        assert results[i].n_preemptions == 1
        _assert_bit_exact(results[i], run_standalone(req, budget2))


def test_eviction_surplus_never_seats_lower_priority_work_same_tick():
    """Evicting a 2-slot mid-priority job to seat a 1-slot urgent request
    frees one surplus slot; handing it to a *lower*-priority queued
    request in the same pass would invert priority against the victim, so
    it must idle that tick instead."""
    cfg = _cfg(n_slots=2, scheduler=SchedulerConfig(aging=0.0))
    engine = SAServeEngine(cfg)
    victim = _req(0, n_chains=2 * CPS, priority=5)
    engine.submit(victim)
    engine.tick()
    engine.submit(_req(1, priority=9, on_overload="preempt",
                       rho=0.5, T0=8.0))              # urgent, 1 slot
    engine.submit(_req(2, priority=0, rho=0.5, T0=8.0))  # low, 1 slot
    engine.tick()
    active = {j.req.req_id for j in engine.rids.jobs.values()}
    assert active == {1}, "surplus eviction slot leaked to lower priority"
    assert engine.preemptions == 1
    results = {r.req_id: r for r in engine.run(max_ticks=300)}
    assert set(results) == {0, 1, 2}
    _assert_bit_exact(results[0], run_standalone(victim, cfg))


def test_preempt_requires_strictly_lower_effective_priority():
    """Equal-priority arrivals never evict each other (no thrash)."""
    cfg = _cfg(n_slots=1, scheduler=SchedulerConfig(aging=0.0))
    engine = SAServeEngine(cfg)
    engine.submit(_req(0, priority=5))
    engine.tick()
    engine.submit(_req(1, priority=5, on_overload="preempt"))
    for _ in range(3):
        engine.tick()
    assert engine.preemptions == 0


# ------------------------------------------------- SLO admission control
def _overload_mix(n, w=1, **kw):
    """Uniform short-ladder requests: width w slots, 3 levels each."""
    return [SARequest(req_id=i, objective="rastrigin", dim=4,
                      n_chains=w * CPS, T0=8.0, T_min=1.0, rho=0.5, N=10,
                      seed=50 + i, **kw) for i in range(n)]


def _run_overloaded(overload, deadline, n_slots=4, w=1, ticks=60,
                    factor=3.0, **req_kw):
    """Seeded Poisson stream at ``factor`` x the pool's saturating load."""
    levels = 3                      # rho=0.5: 8 -> 4 -> 2 -> 1
    rate = factor * n_slots / (w * levels)
    cfg = _cfg(n_slots=n_slots, scheduler=SchedulerConfig(
        overload=overload, default_deadline=deadline))
    engine = SAServeEngine(cfg)
    reqs = _overload_mix(int(rate * ticks), w=w, **req_kw)
    engine.run_stream(ArrivalProcess.poisson(reqs, rate=rate, seed=11),
                      max_ticks=ticks)
    return engine, reqs


def test_reject_policy_bounds_queue_and_p99_baseline_does_not():
    """Acceptance criterion: at 3x saturating load the 'reject' policy
    bounds both p99 queueing delay (by the deadline SLO) and the queue
    itself, while the no-policy baseline's queue and delays grow with the
    horizon."""
    deadline = 6.0
    base, _ = _run_overloaded("none", None)
    rej, _ = _run_overloaded("reject", deadline)
    base_done = [r for r in base.results if r.completed]
    rej_done = [r for r in rej.results if r.completed]
    assert base.rejections == 0 and rej.rejections > 0
    # Unbounded baseline: a backlog of the order of the excess offered
    # load (2/3 of arrivals), and queueing delay that keeps growing.
    assert len(base.scheduler) > 50
    base_qd = [r.queue_delay_ticks for r in base_done]
    assert max(base_qd) > 5 * deadline
    # Bounded under reject: every admitted request met its SLO (the +1 is
    # the arrival->submit-tick quantization), and the queue holds at most
    # the arrivals of one deadline window.
    rej_qd = [r.queue_delay_ticks for r in rej_done]
    assert max(rej_qd) <= deadline + 1
    assert float(np.percentile(rej_qd, 99)) <= deadline + 1
    assert len(rej.scheduler) < 50   # ~rate * (deadline + 1) worst case
    # Load shedding, not collapse: goodput is no worse than the baseline.
    assert len(rej_done) >= len(base_done)
    # Rejected results are typed terminals with no solution.
    rejected = [r for r in rej.results if not r.completed]
    assert rejected and all(r.finish_reason == "rejected" for r in rejected)
    assert all(r.x_best is None and r.granted_chains == 0 for r in rejected)
    assert all(np.isnan(r.queue_delay_ticks) for r in rejected)


def test_degrade_policy_grants_fewer_chains_and_bounds_queue():
    """'degrade' admits at reduced width (down to min_chains) when the
    pool is short: degraded requests exist, match a standalone run at the
    granted chain count bit-exactly, and the deadline backstop keeps the
    queue bounded at 3x saturating load."""
    deadline = 6.0
    engine, reqs = _run_overloaded("degrade", deadline, n_slots=5, w=2,
                                   min_chains=CPS)
    done = [r for r in engine.results if r.completed]
    degraded = [r for r in done if r.degraded]
    assert degraded, "overload never triggered a degraded admission"
    cfg = _cfg(n_slots=5, scheduler=SchedulerConfig(
        overload="degrade", default_deadline=deadline))
    by_id = {q.req_id: q for q in reqs}
    for res in degraded[:3]:
        req = by_id[res.req_id]
        assert CPS <= res.granted_chains < req.n_chains  # floor respected
        solo = run_standalone(
            dataclasses.replace(req, n_chains=res.granted_chains), cfg)
        _assert_bit_exact(res, solo)
    qd = [r.queue_delay_ticks for r in done]
    assert max(qd) <= deadline + 1
    assert len(engine.scheduler) < 50


def test_deadline_zero_is_admit_now_or_never():
    """deadline=0 under 'reject': a request either takes a free slot on
    its first admit scan or fast-fails on the next."""
    cfg = _cfg(n_slots=1, scheduler=SchedulerConfig(
        overload="reject", default_deadline=0.0))
    engine = SAServeEngine(cfg)
    engine.submit(_req(0))
    engine.tick()                    # admitted into the empty pool
    engine.submit(_req(1))
    engine.tick()                    # pool full: still queued (delay == 0)
    engine.tick()                    # delay 1 > 0 -> rejected
    assert engine.rejections == 1
    res = {r.req_id: r for r in engine.run(max_ticks=100)}
    assert res[0].completed
    assert res[1].status == "rejected" and res[1].finish_tick == 2


def test_swapped_jobs_are_never_rejected():
    """A preempted job is admitted work: even under a strict deadline it
    resumes (late) instead of being dropped."""
    cfg = _cfg(n_slots=1, scheduler=SchedulerConfig(
        overload="reject", default_deadline=0.0, aging=0.0))
    victim = _req(0, priority=1)
    engine = SAServeEngine(cfg)
    engine.submit(victim)
    engine.tick()
    engine.preempt(0)
    engine.submit(_req(1, priority=9, rho=0.5, T0=8.0))  # occupies the slot
    results = {r.req_id: r for r in engine.run(max_ticks=200)}
    assert results[0].completed and results[0].n_preemptions == 1
    _assert_bit_exact(results[0], run_standalone(victim, cfg))


def test_serve_sa_reject_without_deadline_is_an_error(capsys):
    """--overload-policy reject/degrade without --deadline would silently
    behave like 'none'; the CLI refuses instead."""
    from repro.service.serve_sa import main as serve_main
    for policy in ("reject", "degrade"):
        with pytest.raises(SystemExit):
            serve_main(["--overload-policy", policy, "--requests", "2",
                        "--slots", "2", "--chains-per-slot", str(CPS)])
        assert "--deadline" in capsys.readouterr().err


# ------------------------------------------------------ compile stability
def test_preempt_resume_adds_no_dispatch_groups():
    """PR-2 compile-count guarantee under preemption: a swap-out/swap-in
    schedule (4 -> 3 -> 4 active blocks at one (dim, N)) reuses the single
    compiled sweep program — checkpoint/restore must not perturb shapes,
    dtypes or the power-of-two block padding."""
    from repro.service.engine import _group_tick
    if not (hasattr(_group_tick, "clear_cache")
            and hasattr(_group_tick, "_cache_size")):
        pytest.skip("jax jit cache introspection API unavailable")
    cfg = _cfg(n_slots=4)
    engine = SAServeEngine(cfg)
    # The victim's ladder is one level shorter (10 vs 11), so after sitting
    # out one tick it retires on the same tick as its peers — the group
    # stays at 4 (or pad-4) blocks for the whole schedule.
    reqs = [_req(0, objective="schwefel", rho=0.65)] + [
        _req(i, objective=obj, rho=0.7)
        for i, obj in enumerate(["rastrigin", "ackley", "griewank"], 1)]
    for r in reqs:
        engine.submit(r)
    _group_tick.clear_cache()
    engine.tick()
    engine.tick()
    assert engine.preempt(0)         # 3 active blocks, padded back to 4
    engine.tick()
    results = {r.req_id: r for r in engine.run(max_ticks=300)}
    compiled = _group_tick._cache_size()   # before standalone re-runs below
    assert len(results) == 4
    assert engine.preemptions == 1 and results[0].n_preemptions == 1
    assert compiled == 1
    for req in reqs:
        _assert_bit_exact(results[req.req_id], run_standalone(req, cfg))
