"""Test tiering: tier-1 (`python -m pytest -x -q`) stays fast by skipping
tests marked ``slow``; the nightly CI tier runs them with ``--runslow``
(or ``RUN_SLOW=1`` in the environment)."""
import os

import pytest


def pytest_addoption(parser):
    parser.addoption(
        "--runslow", action="store_true", default=False,
        help="run tests marked 'slow' (the nightly serving/property tier)")


def pytest_configure(config):
    config.addinivalue_line(
        "markers",
        "slow: long-running serving/property tests, run nightly with "
        "--runslow (skipped in tier-1)")


def pytest_collection_modifyitems(config, items):
    if config.getoption("--runslow") or os.environ.get("RUN_SLOW"):
        return
    skip_slow = pytest.mark.skip(reason="slow tier: pass --runslow to run")
    for item in items:
        if "slow" in item.keywords:
            item.add_marker(skip_slow)
