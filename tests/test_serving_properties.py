"""Property-based serving tests (hypothesis).

Random arrival traces x random preemption points x random overload
policies, asserting the serving invariants that every concrete test in
test_service.py / test_preemption.py instantiates by hand:

* the scheduler never over-commits, never exceeds the preemption budget,
  and admits in effective-priority order (satellite: ordering respected);
* no slot is ever leaked: when the engine drains, the pool is empty and
  every rid is back in the free list;
* every submitted request reaches **exactly one** terminal status
  (completed or rejected);
* a resumed (and possibly degraded) request is bit-exact with
  ``run_standalone`` at its granted chain count.

The scheduler-level property is pure host Python and runs in tier-1; the
engine-level property drives real device programs and is marked slow
(nightly tier, ``--runslow``).
"""
import dataclasses

import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.service import (ArrivalProcess, EngineConfig, SARequest,
                           SAServeEngine, SchedulerConfig, ShardView,
                           run_standalone)
from repro.service.slots import ActiveJob

CPS = 8


def _req(req_id, **kw):
    kw.setdefault("objective", "rastrigin")
    kw.setdefault("dim", 4)
    kw.setdefault("n_chains", CPS)
    kw.setdefault("T0", 8.0)
    kw.setdefault("T_min", 1.0)
    kw.setdefault("rho", 0.5)    # 3-level ladders keep examples fast
    kw.setdefault("N", 10)
    return SARequest(req_id=req_id, seed=100 + req_id, **kw)


# ------------------------------------------------------ scheduler properties
@st.composite
def scheduler_scenarios(draw):
    cfg = SchedulerConfig(
        policy="priority",
        aging=draw(st.sampled_from([0.0, 0.05, 1.0])),
        hol_patience=draw(st.integers(0, 8)),
        overload=draw(st.sampled_from(["none", "reject", "degrade",
                                       "preempt"])),
        default_deadline=draw(st.sampled_from([None, 0.0, 3.0, 10.0])),
        preemption_budget=draw(st.integers(0, 3)))
    n_queued = draw(st.integers(0, 8))
    queued = []
    for i in range(n_queued):
        queued.append((
            _req(i,
                 n_chains=draw(st.integers(1, 3)) * CPS,
                 min_chains=CPS,
                 priority=draw(st.integers(0, 5)),
                 on_overload=draw(st.sampled_from(
                     [None, "none", "reject", "degrade", "preempt"])),
                 deadline=draw(st.sampled_from([None, 0.0, 5.0]))),
            draw(st.integers(0, 10))))       # submit tick
    n_active = draw(st.integers(0, 4))
    active = []
    for j in range(n_active):
        width = draw(st.integers(1, 2))
        job = ActiveJob(req=_req(100 + j,
                                 n_chains=width * CPS,
                                 priority=draw(st.integers(0, 5))),
                        rid=j, slots=list(range(j * 2, j * 2 + width)),
                        submit_tick=draw(st.integers(0, 10)),
                        start_tick=draw(st.integers(0, 12)))
        active.append(job)
    free = draw(st.integers(0, 6))
    tick = draw(st.integers(10, 30))
    return cfg, queued, active, free, tick


@given(scheduler_scenarios())
@settings(max_examples=150, deadline=None)
def test_scheduler_plan_invariants(scenario):
    from repro.service.scheduler import AdmissionScheduler
    cfg, queued, active, free, tick = scenario
    sch = AdmissionScheduler(cfg)
    for req, sub in queued:
        sch.submit(req, sub)
    order_before = {id(e): i for i, e in enumerate(sch._ordered(tick))}
    plan = sch.admit(free, CPS, tick, active=active)

    # 1. Never over-commit: granted <= free + slots released by evictions.
    width_of = {j.rid: len(j.slots) for j in active}
    evicted_slots = sum(width_of[rid] for rid in plan.evict)
    assert sum(g for _, g in plan.admitted) <= free + evicted_slots
    # 2. Preemption budget respected; victims are distinct active rids.
    assert len(plan.evict) <= cfg.preemption_budget
    assert len(set(plan.evict)) == len(plan.evict)
    assert set(plan.evict) <= set(width_of)
    # 3. Grants are sane: full width, or degrade-class shrink >= floor.
    for entry, granted in plan.admitted:
        need = entry.req.slots_needed(CPS)
        assert 0 < granted <= need
        if granted < need:
            assert sch.overload_policy(entry.req) == "degrade"
            assert granted >= entry.req.slots_floor(CPS)
    # 4. Effective-priority ordering respected: the admitted sequence is a
    #    subsequence of the eff-priority scan order.
    positions = [order_before[id(e)] for e, _ in plan.admitted]
    assert positions == sorted(positions)
    # 5. Rejections only ever hit expired reject/degrade-class requests.
    for entry in plan.rejected:
        assert sch.overload_policy(entry.req) in ("reject", "degrade")
        deadline = sch.deadline_of(entry.req)
        assert deadline is not None
        assert tick - entry.submit_tick > deadline
    # 6. Eviction-freed capacity only seats work outranking every victim
    #    (no same-tick priority inversion against a preempted job).
    if plan.evict:
        vmax = max(sch.effective_priority(j.req, j.submit_tick, tick)
                   for j in active if j.rid in plan.evict)
        spent = 0
        for entry, granted in plan.admitted:
            spent += granted
            if spent > free:     # dipped into eviction-freed slots
                assert sch.effective_priority(
                    entry.req, entry.submit_tick, tick) >= vmax
    # 7. Queue bookkeeping: planned entries left the queue, others remain.
    remaining = {id(e) for e in sch._queue}
    planned = {id(e) for e, _ in plan.admitted} | {id(e)
                                                   for e in plan.rejected}
    assert not (remaining & planned)
    assert len(remaining) + len(planned) == len(queued)


# ----------------------------------------------------- placement properties
@st.composite
def shard_scenarios(draw):
    """Random shard snapshots + queue for the placement layer."""
    n_shards = draw(st.integers(1, 4))
    n_slots = draw(st.integers(1, 4))       # capacity per shard
    shards = []
    rid_counter = 0
    for i in range(n_shards):
        used = 0
        jobs = []
        while used < n_slots and draw(st.booleans()):
            width = draw(st.integers(1, min(2, n_slots - used)))
            jobs.append(ActiveJob(
                req=_req(100 + rid_counter, n_chains=width * CPS,
                         priority=draw(st.integers(0, 5))),
                rid=rid_counter, slots=list(range(used, used + width)),
                submit_tick=draw(st.integers(0, 10))))
            rid_counter += 1
            used += width
        shards.append(ShardView(
            index=i, free_slots=n_slots - used, active=tuple(jobs),
            shapes=frozenset((j.req.dim, j.req.N) for j in jobs)))
    n_queued = draw(st.integers(0, 4))
    queued = [(_req(i, n_chains=draw(st.integers(1, n_slots)) * CPS,
                    priority=draw(st.integers(0, 5))),
               draw(st.integers(0, 10)))
              for i in range(n_queued)]
    budget = draw(st.integers(0, 3))
    tick = draw(st.integers(10, 30))
    return shards, n_slots, queued, budget, tick


@given(shard_scenarios())
@settings(max_examples=150, deadline=None)
def test_placement_and_migration_plan_invariants(scenario):
    """Satellite/tentpole: the placement layer's outputs are sane for any
    shard snapshot — place() is a least-loaded permutation, and a
    migration plan is bounded, single-donor, capacity-respecting, and
    only produced when it actually seats the queue head."""
    from repro.service.scheduler import AdmissionScheduler
    shards, n_slots, queued, budget, tick = scenario
    sch = AdmissionScheduler(SchedulerConfig())
    for req, sub in queued:
        sch.submit(req, sub)

    # place(): a permutation of the inputs, free counts non-increasing.
    order = sch.place(shards, tick)
    assert sorted(s.index for s in order) == sorted(s.index for s in shards)
    frees = [s.free_slots for s in order]
    assert frees == sorted(frees, reverse=True)

    moves = sch.plan_migrations(shards, CPS, tick, budget)
    assert len(moves) <= budget
    assert len({rid for rid, _, _ in moves}) == len(moves)
    if not queued or budget == 0:
        assert moves == []
        return
    head = sch._head(tick)
    need = head.req.slots_needed(CPS)
    by_index = {s.index: s for s in shards}
    if max(s.free_slots for s in shards) >= need:
        assert moves == [], "migrated although the head already fits"
    if moves:
        donors = {src for _, src, _ in moves}
        assert len(donors) == 1              # single-donor defrag
        donor = by_index[donors.pop()]
        donor_rids = {j.rid for j in donor.active}
        rec_free = {s.index: s.free_slots for s in shards
                    if s.index != donor.index}
        freed = donor.free_slots
        width_of = {j.rid: len(j.slots) for j in donor.active}
        for rid, src, dst in moves:
            assert rid in donor_rids and src == donor.index != dst
            assert rec_free[dst] >= width_of[rid]  # recipient really fits it
            rec_free[dst] -= width_of[rid]
            freed += width_of[rid]
        assert freed >= need                 # the plan seats the head


# -------------------------------------------------------- engine properties
@pytest.mark.slow
@given(st.data())
@settings(max_examples=12, deadline=None)
def test_engine_invariants_under_random_preemption(data):
    """Random arrivals x random preemption/migration points x random shard
    counts: no slot leaks on any shard, no double placement, exactly one
    terminal status per request, and every completed request — preempted,
    migrated, degraded or neither — is bit-exact vs run_standalone."""
    n_slots = 3
    n_devices = data.draw(st.integers(1, 3))
    cfg = EngineConfig(n_slots=n_slots, chains_per_slot=CPS,
                       n_devices=n_devices, use_pallas=False,
                       migration_budget=data.draw(st.integers(0, 2)),
                       scheduler=SchedulerConfig(
                           overload=data.draw(st.sampled_from(
                               ["none", "reject", "degrade", "preempt"])),
                           default_deadline=data.draw(
                               st.sampled_from([None, 12.0])),
                           preemption_budget=data.draw(st.integers(0, 2))))
    n_reqs = data.draw(st.integers(1, 5))
    reqs = [_req(i,
                 n_chains=data.draw(st.integers(1, 2)) * CPS,
                 min_chains=CPS,
                 priority=data.draw(st.integers(0, 3)))
            for i in range(n_reqs)]
    times = [data.draw(st.floats(0, 15, allow_nan=False,
                                 allow_infinity=False))
             for _ in reqs]
    engine = SAServeEngine(cfg)
    arrivals = ArrivalProcess.trace(reqs, times)

    def live_req_ids():
        return [job.req.req_id for _, job in engine._iter_jobs()]

    guard = 0
    while not (engine.done and arrivals.exhausted):
        guard += 1
        assert guard < 300, "engine failed to drain (livelock?)"
        for t, r in arrivals.due(engine.tick_count):
            engine.submit(r, t)
        live = live_req_ids()
        if live and data.draw(st.booleans()):
            engine.preempt(data.draw(st.sampled_from(sorted(live))))
        live = live_req_ids()
        if n_devices > 1 and live and data.draw(st.booleans()):
            # Random operator migration; may no-op (full target / home).
            engine.migrate(data.draw(st.sampled_from(sorted(live))),
                           data.draw(st.integers(0, n_devices - 1)))
        engine.tick()
        # Never double-placed: a request is resident on <= 1 shard.
        resident = live_req_ids()
        assert len(resident) == len(set(resident))

    # No slot leaked on any shard; every rid recycled.
    for shard in engine.shards:
        assert shard.pool.n_free == n_slots
        assert np.all(shard.pool.owner == -1)
        assert not shard.rids.jobs and len(shard.rids._free) == n_slots
    # Exactly one terminal status per submitted request.
    ids = sorted(r.req_id for r in engine.results)
    assert ids == list(range(n_reqs))
    # Bit-exact vs standalone at the granted width (skip rejected).
    for res in engine.results:
        if not res.completed:
            assert res.x_best is None and res.granted_chains == 0
            continue
        req = reqs[res.req_id]
        if res.degraded:
            req = dataclasses.replace(req, n_chains=res.granted_chains)
        solo = run_standalone(req, cfg)
        assert res.f_best == solo.f_best
        np.testing.assert_array_equal(res.x_best, solo.x_best)
        assert res.champion_history == solo.champion_history


@pytest.mark.slow
@given(st.data())
@settings(max_examples=10, deadline=None)
def test_macro_tick_fusion_bit_exact_under_random_ops(data):
    """Macro-tick tentpole property: random K x random width-preserving
    op schedules (preempt / resize / drain) on a 2-shard fleet => every
    request's champion history is bit-equal to the K=1 engine's and to
    ``run_standalone`` — fusing K levels into one dispatch perturbs no
    trajectory regardless of where the fleet is reshaped."""
    k = data.draw(st.sampled_from([2, 4, 8]))
    n_reqs = data.draw(st.integers(2, 5))
    reqs = [_req(i,
                 n_chains=data.draw(st.integers(1, 2)) * CPS,
                 rho=0.7,                # 7-level ladders: K spans several
                 priority=data.draw(st.integers(0, 3)))
            for i in range(n_reqs)]
    ops = []
    for _ in range(data.draw(st.integers(0, 4))):
        tick = data.draw(st.integers(0, 20))
        kind = data.draw(st.sampled_from(["preempt", "resize", "drain"]))
        arg = (data.draw(st.integers(0, n_reqs - 1)) if kind == "preempt"
               else data.draw(st.integers(1, 3)))
        ops.append((tick, kind, arg))

    def serve(macro_k):
        cfg = EngineConfig(n_slots=3, chains_per_slot=CPS, n_devices=2,
                           use_pallas=False, macro_k=macro_k,
                           migration_budget=2)
        engine = SAServeEngine(cfg)
        for tick, kind, arg in ops:
            if kind == "preempt":
                engine.schedule_op(tick,
                                   lambda a=arg: engine.preempt(a))
            elif kind == "resize":
                engine.schedule_op(tick,
                                   lambda a=arg: engine.resize(a))
            else:                        # drain the highest live shard
                engine.schedule_op(
                    tick,
                    lambda e=engine: e.drain(
                        max(s.index for s in e.live_shards))
                    if len(e.live_shards) > 1 else None)
        for r in reqs:
            engine.submit(r)
        return {r.req_id: r for r in engine.run(max_ticks=3000)}, cfg

    base, _ = serve(1)
    fused, cfg = serve(k)
    assert base.keys() == fused.keys() == set(range(n_reqs))
    for req in reqs:
        a, b = base[req.req_id], fused[req.req_id]
        assert a.champion_history == b.champion_history
        assert a.f_best == b.f_best
        np.testing.assert_array_equal(a.x_best, b.x_best)
        assert a.finish_reason == b.finish_reason
        solo = run_standalone(req, cfg)
        assert b.champion_history == solo.champion_history


@pytest.mark.slow
@given(st.data())
@settings(max_examples=12, deadline=None)
def test_engine_invariants_under_random_drain_resize(data):
    """Elastic-fleet property (PR 5): random arrivals x random
    drain/resize/proactive-degrade/watermark points => no slot leaks on
    any surviving shard, exactly one terminal status per request, no job
    lost or duplicated across shard retirement, retired shard indices
    never reused, and every completed request bit-exact vs a standalone
    replay of its width schedule."""
    n_slots = 2
    n0 = data.draw(st.integers(2, 3))
    watermarks = data.draw(st.booleans())
    cfg = EngineConfig(
        n_slots=n_slots, chains_per_slot=CPS, n_devices=n0,
        use_pallas=False,
        migration_budget=data.draw(st.integers(1, 2)),
        scheduler=SchedulerConfig(
            overload="degrade", default_deadline=40.0,
            proactive_degrade=data.draw(st.booleans()),
            high_watermark=0.75 if watermarks else 1.0,
            low_watermark=0.25 if watermarks else 0.0))
    n_reqs = data.draw(st.integers(2, 6))
    reqs = [_req(i,
                 n_chains=data.draw(st.integers(1, 2)) * CPS,
                 min_chains=CPS,
                 rho=0.7,
                 priority=data.draw(st.integers(0, 3)))
            for i in range(n_reqs)]
    times = [data.draw(st.floats(0, 10, allow_nan=False,
                                 allow_infinity=False))
             for _ in reqs]
    engine = SAServeEngine(cfg)
    arrivals = ArrivalProcess.trace(reqs, times)

    guard = 0
    while not (engine.done and arrivals.exhausted):
        guard += 1
        assert guard < 500, "engine failed to drain (livelock?)"
        for t, r in arrivals.due(engine.tick_count):
            engine.submit(r, t)
        live = engine.live_shards
        roll = data.draw(st.integers(0, 9))
        if roll == 0 and len(live) > 1:
            engine.drain(data.draw(st.sampled_from(
                sorted(s.index for s in live))))
        elif roll == 1:
            engine.resize(data.draw(st.integers(1, 4)))
        elif roll == 2:
            active = sorted(j.req.req_id for _, j in engine._iter_jobs())
            if active:
                engine.degrade_active(data.draw(st.sampled_from(active)),
                                      CPS)
        engine.tick()
        resident = [j.req.req_id for _, j in engine._iter_jobs()]
        assert len(resident) == len(set(resident)), "double placement"
        retired = [i for i, _ in engine.retired_shards]
        assert len(retired) == len(set(retired)), "shard index reused"
        assert not (set(retired)
                    & {s.index for s in engine.shards}), "zombie shard"

    # No slot leaked on any surviving shard; every rid recycled.
    for shard in engine.shards:
        assert shard.pool.n_free == n_slots
        assert np.all(shard.pool.owner == -1)
        assert not shard.rids.jobs and len(shard.rids._free) == n_slots
    # Exactly one terminal status per submitted request: nothing lost in
    # a retired shard, nothing duplicated by evacuation.
    ids = sorted(r.req_id for r in engine.results)
    assert ids == list(range(n_reqs))
    for res in engine.results:
        if not res.completed:
            continue
        req = reqs[res.req_id]
        if res.admitted_chains < req.n_chains:
            req = dataclasses.replace(req, n_chains=res.admitted_chains)
        sched = [(lvl, to) for lvl, _frm, to in res.shrink_events]
        solo = run_standalone(req, cfg, shrink_schedule=sched)
        assert res.f_best == solo.f_best
        np.testing.assert_array_equal(res.x_best, solo.x_best)
        assert res.champion_history == solo.champion_history
