"""reduce_min Pallas kernel vs jnp oracle: shape/dtype/tie sweeps."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")  # property tests need the dev extra
from hypothesis import given, settings, strategies as st

from repro.kernels.reduce_min import argmin_reduce


@pytest.mark.parametrize("n,blk", [(64, 8), (256, 64), (1024, 128),
                                   (4096, 1024)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_block_argmin_matches_oracle(n, blk, dtype):
    f = jax.random.normal(jax.random.PRNGKey(n + blk), (n,)).astype(dtype)
    m, i = argmin_reduce(f, blk=blk, use_pallas=True, interpret=True)
    m0, i0 = argmin_reduce(f, use_pallas=False)
    assert int(i) == int(i0)
    assert float(m) == float(m0)


def test_ties_pick_first_index():
    f = jnp.asarray([3.0, 1.0, 1.0, 2.0, 1.0, 5.0, 7.0, 8.0])
    m, i = argmin_reduce(f, blk=4, use_pallas=True, interpret=True)
    assert int(i) == 1 and float(m) == 1.0


def test_cross_block_ties_pick_first_block():
    f = jnp.full((32,), 2.0).at[20].set(1.0).at[28].set(1.0)
    m, i = argmin_reduce(f, blk=8, use_pallas=True, interpret=True)
    assert int(i) == 20


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_property_random_vectors(seed):
    f = jax.random.uniform(jax.random.PRNGKey(seed), (512,))
    m, i = argmin_reduce(f, blk=64, use_pallas=True, interpret=True)
    assert int(i) == int(jnp.argmin(f))
    assert float(f[i]) == float(m)
