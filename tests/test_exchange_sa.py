"""Exchange operators + end-to-end SA behaviour (paper §2.2, §4.1)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import SAConfig, sa_minimize
from repro.core import exchange as exch
from repro.objectives import functions as F


def test_local_and_global_champion():
    x = jnp.asarray([[1.0, 2.0], [3.0, 4.0], [0.0, 1.0]])
    fx = jnp.asarray([5.0, 2.0, 9.0])
    xb, fb = exch.local_champion(x, fx)
    assert float(fb) == 2.0 and xb.tolist() == [3.0, 4.0]
    xg, fg = exch.global_champion(x, fx, axis_names=None)
    assert float(fg) == 2.0


def test_sync_exchange_broadcasts_champion():
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (16, 4))
    fx = jnp.arange(16.0)
    x2, f2 = exch.exchange_sync(key, x, fx, 1.0)
    assert bool(jnp.all(f2 == fx[0]))
    assert bool(jnp.all(x2 == x[0]))


def test_sos_adopt_prob_three_regimes():
    """The SOS acceptance formula (Salazar & Toral's stochastic-on-
    stochastic rule) has three regimes, pinned exactly:

    * tie with the champion -> adopt with probability exactly 1/2;
    * worse by more than T  -> adopt with probability exactly 1;
    * worse by 0 < d <= T   -> interpolated, 1 - exp(-d/T)/2 in
      (1/2, 1 - 1/(2e)], strictly increasing in d.

    The pre-fix formula collapsed the middle regime onto the endpoints,
    so a chain marginally worse than the champion adopted far too often.
    """
    fb = jnp.asarray(3.0)
    T = 2.0
    tie = exch.sos_adopt_prob(jnp.asarray(3.0), fb, T)
    assert float(tie) == 0.5
    far = exch.sos_adopt_prob(jnp.asarray(3.0 + 2.001), fb, T)
    assert float(far) == 1.0
    at_T = exch.sos_adopt_prob(jnp.asarray(3.0 + 2.0), fb, T)
    assert float(at_T) == pytest.approx(1.0 - 0.5 / np.e)  # boundary inclusive
    # better-than-champion clamps d to 0 -> the tie probability
    better = exch.sos_adopt_prob(jnp.asarray(-10.0), fb, T)
    assert float(better) == 0.5
    d = jnp.linspace(1e-4, 2.0, 64)
    mid = exch.sos_adopt_prob(fb + d, fb, T)
    assert float(mid.min()) > 0.5
    assert float(mid.max()) <= 1.0 - 0.5 / np.e + 1e-7
    assert np.all(np.diff(np.asarray(mid)) > 0), "not monotone in d"
    np.testing.assert_allclose(np.asarray(mid),
                               1.0 - 0.5 * np.exp(-np.asarray(d) / T),
                               rtol=1e-6)


def test_sos_exchange_preserves_diversity():
    """SOS adopts stochastically: some chains keep their own state."""
    key = jax.random.PRNGKey(1)
    x = jax.random.normal(key, (256, 4))
    fx = jnp.linspace(0.0, 10.0, 256)
    x2, f2 = exch.exchange_sos(key, x, fx, T=1.0)
    adopted = jnp.mean((f2 == fx[0]).astype(jnp.float32))
    assert 0.05 < float(adopted) < 1.0, "SOS should adopt some but not all"
    # adopted chains only ever improve
    assert bool(jnp.all(f2 <= fx + 1e-6))


def test_sa_converges_schwefel8():
    obj = F.schwefel(8)
    cfg = SAConfig(T0=100.0, T_min=0.05, rho=0.9, N=30, n_chains=512,
                   exchange="sync", seed=0, record_history=True)
    res = sa_minimize(obj, cfg, key=jax.random.PRNGKey(0))
    assert abs(res.f_best - obj.f_opt) < 0.5
    # champion history is non-increasing (best-so-far tracking)
    h = res.history_f
    assert h is not None and np.all(np.diff(h) <= 1e-5)


def test_sync_beats_async_at_equal_budget():
    """The paper's headline claim (Table 1) at reduced scale, 3 seeds."""
    obj = F.schwefel(16)
    errs = {}
    for ex in ("async", "sync"):
        e = []
        for seed in range(3):
            cfg = SAConfig(T0=100.0, T_min=0.1, rho=0.88, N=25, n_chains=512,
                           exchange=ex, seed=seed, record_history=False)
            res = sa_minimize(obj, cfg, key=jax.random.PRNGKey(seed))
            e.append(abs(res.f_best - obj.f_opt))
        errs[ex] = np.mean(e)
    assert errs["sync"] < errs["async"], errs


def test_exchange_period():
    """period>1 must still improve over async and run correctly."""
    obj = F.schwefel(8)
    cfg = SAConfig(T0=50.0, T_min=0.5, rho=0.85, N=20, n_chains=256,
                   exchange="sync", exchange_period=4, seed=0,
                   record_history=False)
    res = sa_minimize(obj, cfg, key=jax.random.PRNGKey(0))
    assert abs(res.f_best - obj.f_opt) < 20.0


def test_x0_broadcast_start():
    """Explicit x0: all chains start from the given point (paper Listing 2:
    d_points[tid] = bestPoint)."""
    obj = F.rastrigin(4)
    x0 = np.zeros(4, np.float32) + 2.0
    cfg = SAConfig(T0=0.001, T_min=0.0009, rho=0.9, N=1, n_chains=8,
                   exchange="async", record_history=False)
    res = sa_minimize(obj, cfg, x0=x0, key=jax.random.PRNGKey(0))
    # one cold step from x0: best must be within one coordinate flip of x0
    assert abs(res.f_best - float(obj(jnp.asarray(x0)))) < 25.0


def test_result_metadata():
    obj = F.schwefel(8)
    cfg = SAConfig(T0=10.0, T_min=1.0, rho=0.5, N=5, n_chains=32)
    res = sa_minimize(obj, cfg, key=jax.random.PRNGKey(0))
    assert res.n_evals == cfg.n_evals == cfg.n_levels * cfg.N * cfg.n_chains
    assert res.objective_name == obj.name
    assert res.x_best.shape == (8,)


def test_dtype_float32_default():
    obj = F.schwefel(8)
    cfg = SAConfig(T0=10.0, T_min=1.0, rho=0.5, N=5, n_chains=32)
    res = sa_minimize(obj, cfg, key=jax.random.PRNGKey(0))
    assert res.x_best.dtype == np.float32  # paper Table 7 default
