"""Data-pipeline determinism/elasticity + checkpoint fault-tolerance."""
import json

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.manager import (CheckpointManager, all_steps,
                                      restore_state, save_state)
from repro.data.pipeline import (DataConfig, TokenDataset, make_batches,
                                 synthetic_dataset)


def _cfg(**kw):
    base = dict(seq_len=16, global_batch=8, vocab_size=97, seed=3)
    base.update(kw)
    return DataConfig(**base)


def test_batches_deterministic_in_step():
    ds = synthetic_dataset(_cfg(), n_tokens=1 << 12)
    b1 = ds.batch_at(17)
    b2 = ds.batch_at(17)
    np.testing.assert_array_equal(b1, b2)
    assert b1.shape == (8, 17)
    assert not np.array_equal(ds.batch_at(18), b1)


def test_elastic_host_resharding():
    """Global batch content is identical regardless of host_count — node
    failures / elastic rescale never change the data stream."""
    full = synthetic_dataset(_cfg(host_index=0, host_count=1), 1 << 12)
    g = full.batch_at(5)
    parts = []
    for h in range(4):
        ds_h = TokenDataset(full.tokens, _cfg(host_index=h, host_count=4))
        parts.append(ds_h.batch_at(5))
    np.testing.assert_array_equal(np.concatenate(parts, 0), g)


def test_resume_identical_stream():
    ds = synthetic_dataset(_cfg(), 1 << 12)
    full = [(s, b.copy()) for s, b in make_batches(ds, 0, 6)]
    resumed = [(s, b.copy()) for s, b in make_batches(ds, 3, 6)]
    for (s1, b1), (s2, b2) in zip(full[3:], resumed):
        assert s1 == s2
        np.testing.assert_array_equal(b1, b2)


def test_checkpoint_roundtrip(tmp_path):
    state = {"params": {"w": jnp.arange(6.0).reshape(2, 3)},
             "opt": {"step": jnp.asarray(7)}}
    save_state(tmp_path, 7, state, extras={"data_step": 7})
    like = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), state)
    restored, extras = restore_state(tmp_path, 7, like)
    np.testing.assert_array_equal(np.asarray(restored["params"]["w"]),
                                  np.asarray(state["params"]["w"]))
    assert extras["data_step"] == 7


def test_checkpoint_atomicity(tmp_path):
    """A .tmp directory (crash mid-write) is never listed as a checkpoint."""
    state = {"w": jnp.zeros(3)}
    save_state(tmp_path, 1, state)
    (tmp_path / "step_000000002.tmp").mkdir()
    (tmp_path / "step_000000002.tmp" / "manifest.json").write_text("{}")
    assert all_steps(tmp_path) == [1]


def test_manager_retention_and_async(tmp_path):
    mgr = CheckpointManager(tmp_path, keep_last=2, keep_every=4)
    state = {"w": jnp.zeros(4)}
    for s in range(1, 7):
        mgr.save_async(s, state, extras={"data_step": s})
    mgr.wait()
    kept = sorted(all_steps(tmp_path))
    assert kept == [4, 5, 6]  # last 2 + multiple-of-4 survivor
    step = mgr.latest_step()
    assert step == 6


def test_manager_restore_latest(tmp_path):
    mgr = CheckpointManager(tmp_path, keep_last=3)
    state = {"w": jnp.asarray([1.0, 2.0])}
    mgr.save(3, state, extras={"data_step": 3})
    mgr.save(9, jax.tree.map(lambda x: x * 2, state), extras={"data_step": 9})
    like = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), state)
    restored, extras = mgr.restore(like)
    assert extras["data_step"] == 9
    np.testing.assert_allclose(np.asarray(restored["w"]), [2.0, 4.0])


def test_elastic_restore_changes_sharding(tmp_path):
    """Restore places global arrays onto a new mesh/sharding (elastic)."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.launch.mesh import local_test_mesh

    state = {"w": jnp.arange(8.0)}
    save_state(tmp_path, 1, state)
    mesh = local_test_mesh()
    sh = {"w": NamedSharding(mesh, P(None))}
    like = {"w": jax.ShapeDtypeStruct((8,), jnp.float32)}
    restored, _ = restore_state(tmp_path, 1, like, shardings=sh)
    assert restored["w"].sharding.is_equivalent_to(sh["w"], 1)
    np.testing.assert_array_equal(np.asarray(restored["w"]),
                                  np.arange(8.0))
