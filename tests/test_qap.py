"""QAP / permutation-family differentials (PR 9).

The combinatorial path's correctness ladder, bottom-up:

* **instance data integrity**: the built-in QAP instances carry witness
  permutations whose host-side int64 cost equals the recorded best_known;
* **exact arithmetic**: instance entries are small integers, so every
  float32 product/sum in the kernel is exact — the device full cost, the
  delta-carried fx and the host int64 cost agree *bitwise*, not just
  approximately;
* **kernel parity**: the Pallas swap-sweep kernel (interpret mode) is
  bit-identical to the jittable reference oracle, per-block controls and
  packed per-block F/D operands included;
* **serving differentials**: engine == run_standalone for QAP requests at
  macro-K 1 and 4, through preemption, cross-shard migration, drain and
  fleet resize, and when co-batched with continuous tenants in one pool;
* **compile stability**: a mixed continuous+QAP fleet compiles exactly
  one sweep program per family per shape;
* **eager validation** (satellite): family-incompatible request fields
  (pa_ess_ratio, pt/pa methods, wrong dim) raise typed ValueErrors at
  construction;
* **int32 checkpoint/restore** (satellite): the slot pool's
  checkpoint -> restore round-trip is bitwise for permutation blocks.
"""
import dataclasses

import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.qap_sweep import qap_full_cost, qap_sweep_pallas
from repro.objectives import families as fam_mod
from repro.objectives import qap
from repro.service import (EngineConfig, SARequest, SAServeEngine,
                           run_standalone)
from repro.service.slots import SlotPool

CPS = 8


def _req(req_id, instance="syn10", **kw):
    inst = qap.get(instance)
    kw.setdefault("dim", inst.n)
    kw.setdefault("n_chains", CPS)
    kw.setdefault("T0", 30.0)
    kw.setdefault("T_min", 0.5)
    kw.setdefault("rho", 0.55)   # short ladder, like the continuous tests
    kw.setdefault("N", 10)
    kw.setdefault("seed", 100 + req_id)
    return SARequest(req_id=req_id, objective=instance,
                     family="permutation", **kw)


def _creq(req_id, **kw):
    kw.setdefault("objective", "rastrigin")
    kw.setdefault("dim", 4)
    kw.setdefault("n_chains", CPS)
    kw.setdefault("T0", 50.0)
    kw.setdefault("T_min", 1.0)
    kw.setdefault("rho", 0.55)
    kw.setdefault("N", 10)
    return SARequest(req_id=req_id, seed=100 + req_id, **kw)


def _cfg(n_slots=4, **kw):
    return EngineConfig(n_slots=n_slots, chains_per_slot=CPS,
                        use_pallas=False, **kw)


def _assert_bit_exact(res, solo):
    assert res.f_best == solo.f_best
    np.testing.assert_array_equal(res.x_best, solo.x_best)
    assert res.levels_run == solo.levels_run
    assert res.champion_history == solo.champion_history


def _assert_valid_perms(p, n):
    p = np.asarray(p)
    assert p.dtype == np.int32
    np.testing.assert_array_equal(np.sort(p, axis=-1),
                                  np.broadcast_to(np.arange(n, dtype=p.dtype),
                                                  p.shape))


def _rand_perms(n_chains, n, seed):
    r = np.random.default_rng(seed)
    return np.stack([r.permutation(n) for _ in range(n_chains)]
                    ).astype(np.int32)


# ---------------------------------------------------- instance integrity
@pytest.mark.parametrize("name", sorted(qap.INSTANCES))
def test_instance_witness_cost_matches_best_known(name):
    """Each built-in instance's witness permutation reproduces its
    recorded best_known cost under the host int64 evaluator — the data-
    integrity anchor every other test leans on."""
    inst = qap.get(name)
    _assert_valid_perms(np.asarray(inst.p_best, np.int32)[None, :], inst.n)
    assert inst.cost(np.asarray(inst.p_best)) == inst.best_known
    # Zero self-flow / self-distance: the delta formula's diagonal terms
    # vanish, and cost is a pure inter-facility sum.
    assert np.all(np.diag(inst.F) == 0) and np.all(np.diag(inst.D) == 0)
    # Small-integer entries: all products/sums stay exact in float32.
    assert float(np.abs(inst.F).max() * np.abs(inst.D).max() * inst.n ** 2) \
        < 2.0 ** 24
    # A random-permutation cohort never beats the witness.
    costs = inst.cost(_rand_perms(64, inst.n, seed=7))
    assert np.all(costs >= inst.best_known)


@pytest.mark.parametrize("name", sorted(qap.INSTANCES))
def test_device_full_cost_matches_host_bitwise(name):
    """qap_full_cost (the one-hot matmul evaluator chains are seeded
    with) equals the host int64 cost exactly, not approximately."""
    inst = qap.get(name)
    p = _rand_perms(16, inst.n, seed=3)
    f_dev = np.asarray(qap_full_cost(p, inst.F, inst.D))[:, 0]
    np.testing.assert_array_equal(f_dev, inst.cost(p).astype(np.float32))


# ------------------------------------------------------- kernel parity
@pytest.mark.parametrize("name", sorted(qap.INSTANCES))
def test_ref_sweep_delta_fx_is_exact(name):
    """After a reference sweep the delta-carried fx equals a from-scratch
    full recompute AND the host int64 cost, bitwise — the O(n) pairwise-
    exchange delta (arXiv:1208.2675) drifts by exactly nothing."""
    inst = qap.get(name)
    p0 = _rand_perms(16, inst.n, seed=11)
    p1, fx = ref.qap_sweep_ref(p0, inst.F, inst.D, T=5.0, seed=42, step0=0,
                               n_steps=25)
    p1, fx = np.asarray(p1), np.asarray(fx)
    _assert_valid_perms(p1, inst.n)
    np.testing.assert_array_equal(
        fx, np.asarray(qap_full_cost(p1, inst.F, inst.D))[:, 0])
    np.testing.assert_array_equal(fx, inst.cost(p1).astype(np.float32))
    assert not np.array_equal(p0, p1), "sweep accepted no moves at T=5"


@pytest.mark.parametrize("name", sorted(qap.INSTANCES))
def test_pallas_interpret_matches_ref_bitwise(name):
    """The Pallas swap-sweep kernel (interpret mode) is bit-identical to
    the reference oracle under per-block SMEM controls — different T,
    seed and chain_base per block — and per-block packed F/D operands."""
    inst = qap.get(name)
    n_blocks, blk = 2, 8
    p0 = _rand_perms(n_blocks * blk, inst.n, seed=5)
    T = np.asarray([4.0, 1.5], np.float32)
    seeds = np.asarray([9, 9], np.uint32)          # one request, two slots
    step0 = np.asarray([30, 30], np.uint32)
    base = np.asarray([0, blk], np.uint32)         # placement-invariant RNG
    pk, fk = qap_sweep_pallas(p0, inst.F, inst.D, T, seeds, step0,
                              n_steps=20, blk=blk, interpret=True,
                              chain_base=base)
    cidx = (np.repeat(base, blk)
            + np.tile(np.arange(blk, dtype=np.uint32), n_blocks))[:, None]
    pr, fr = ref.qap_sweep_ref(
        p0, inst.F, inst.D, T=np.repeat(T, blk), seed=np.repeat(seeds, blk),
        step0=np.repeat(step0, blk), n_steps=20, cidx=cidx)
    np.testing.assert_array_equal(np.asarray(pk), np.asarray(pr))
    np.testing.assert_array_equal(np.asarray(fk), np.asarray(fr))
    _assert_valid_perms(np.asarray(pk), inst.n)


# ------------------------------------------------- serving differentials
@pytest.mark.parametrize("macro_k", [1, 4])
@pytest.mark.parametrize("name", sorted(qap.INSTANCES))
def test_engine_matches_standalone(name, macro_k):
    """Acceptance criterion: a served QAP request is bit-exact versus its
    single-tenant standalone run at macro-K 1 and 4 — f_best, the int32
    champion permutation, and the per-level champion history."""
    cfg = _cfg(macro_k=macro_k)
    req = _req(0, name)
    engine = SAServeEngine(cfg)
    engine.submit(req)
    res = engine.run(max_ticks=200)[0]
    solo = run_standalone(req, cfg)
    _assert_bit_exact(res, solo)
    _assert_valid_perms(res.x_best[None, :], req.dim)
    assert res.x_best.dtype == np.int32


def test_macro_k_is_bit_exact_against_k1():
    """K=4 fused macro-ticks replay the identical trajectory as K=1
    per-level launches for permutation chains (donated int32 buffers)."""
    req = _req(0, "grid12", n_chains=2 * CPS)
    res = {}
    for k in (1, 4):
        engine = SAServeEngine(_cfg(macro_k=k))
        engine.submit(req)
        res[k] = engine.run(max_ticks=200)[0]
    _assert_bit_exact(res[4], res[1])


@pytest.mark.parametrize("macro_k", [1, 4])
def test_mixed_family_cobatch_bit_exact(macro_k):
    """Continuous and QAP tenants share one slot pool and one engine run;
    every champion (float32 and int32 alike) stays bit-exact versus
    standalone."""
    cfg = _cfg(n_slots=6, macro_k=macro_k)
    reqs = [_creq(0), _req(1, "syn10"), _creq(2, objective="ackley"),
            _req(3, "grid12"), _creq(4, objective="schwefel"),
            _req(5, "syn10", seed=321)]
    engine = SAServeEngine(cfg)
    for r in reqs:
        engine.submit(r)
    results = {r.req_id: r for r in engine.run(max_ticks=300)}
    assert len(results) == len(reqs)
    for r in reqs:
        _assert_bit_exact(results[r.req_id], run_standalone(r, cfg))
    assert results[1].x_best.dtype == np.int32
    assert results[0].x_best.dtype == np.float32


def test_preempt_resume_bit_exact_at_every_level():
    """Preempt a QAP tenant at every level of its ladder; the resumed
    trajectory is bit-exact with the uninterrupted run (int32 checkpoint
    blocks + counter-based RNG on logical chain indices)."""
    cfg = _cfg(n_slots=1)
    victim = _req(0, "syn10")
    solo = run_standalone(victim, cfg)
    assert solo.levels_run == victim.n_levels > 2
    for level in range(1, victim.n_levels):
        engine = SAServeEngine(cfg)
        engine.submit(victim)
        for _ in range(level):
            engine.tick()
        assert engine.preempt(victim.req_id)
        filler = _creq(1, priority=50, rho=0.5, T0=8.0)
        engine.submit(filler)    # cross-family filler occupies the slot
        results = {r.req_id: r for r in engine.run(max_ticks=200)}
        assert results[0].preempted_ticks == [level]
        _assert_bit_exact(results[0], solo)
        _assert_bit_exact(results[1], run_standalone(filler, cfg))


def test_drain_and_resize_bit_exact():
    """Drain a QAP tenant's home shard mid-ladder, then (separately)
    resize the fleet under it: the evacuated int32 trajectory matches the
    uninterrupted standalone run bitwise."""
    cfg = _cfg(n_slots=1, n_devices=2, migration_budget=2)
    victim = _req(0, "grid12")
    solo = run_standalone(victim, cfg)

    engine = SAServeEngine(cfg)
    engine.submit(victim)
    engine.tick()
    engine.tick()
    jobs = {j.req.req_id: j for _, j in engine._iter_jobs()}
    home = jobs[0].home_shard
    engine.drain(home)
    res = engine.run(max_ticks=200)[0]
    assert res.migrated_ticks == [2] and res.home_shard != home
    _assert_bit_exact(res, solo)

    engine = SAServeEngine(cfg)
    engine.submit(victim)
    engine.schedule_op(2, lambda: engine.resize(1))
    res = engine.run(max_ticks=200)[0]
    _assert_bit_exact(res, solo)


def test_forced_migration_bit_exact():
    """An operator-forced cross-shard move (checkpoint on A, restore on
    B) leaves the permutation trajectory bit-identical."""
    cfg = _cfg(n_slots=2, n_devices=2, migration_budget=2)
    req = _req(0, "syn10")
    engine = SAServeEngine(cfg)
    engine.submit(req)
    engine.tick()
    jobs = {j.req.req_id: j for _, j in engine._iter_jobs()}
    home = jobs[0].home_shard
    dest = next(s.index for s in engine.live_shards if s.index != home)
    assert engine.migrate(0, dest)
    res = engine.run(max_ticks=200)[0]
    assert res.n_migrations == 1
    _assert_bit_exact(res, run_standalone(req, cfg))


# ---------------------------------------------------- compile stability
def test_one_compiled_program_per_family():
    """A mixed continuous+QAP fleet compiles exactly one sweep program
    per family: the continuous group keeps its runtime-kid dispatch, the
    QAP group types on int32 states — neither family's tenants retrace
    the other's program."""
    from repro.service.engine import _group_tick, _group_tick_qap
    can_count = all(
        hasattr(f, a) for f in (_group_tick, _group_tick_qap)
        for a in ("clear_cache", "_cache_size"))
    if not can_count:
        pytest.skip("jax jit cache introspection API unavailable")
    cfg = _cfg(n_slots=6)
    engine = SAServeEngine(cfg)
    # Both QAP tenants on one instance (one (family, dim, N) group); three
    # continuous objectives at one (dim, N).
    reqs = [_req(0, "syn10"), _req(1, "syn10", seed=222, T0=20.0),
            _creq(2), _creq(3, objective="ackley"),
            _creq(4, objective="griewank")]
    for r in reqs:
        engine.submit(r)
    _group_tick.clear_cache()
    _group_tick_qap.clear_cache()
    results = {r.req_id: r for r in engine.run(max_ticks=200)}
    assert len(results) == len(reqs)
    assert _group_tick._cache_size() == 1
    assert _group_tick_qap._cache_size() == 1
    for r in reqs:
        _assert_bit_exact(results[r.req_id], run_standalone(r, cfg))


def test_one_fused_program_per_family():
    """Same pin under macro-tick fusion (K=4, donated buffers)."""
    from repro.service.engine import (_group_tick_fused,
                                      _group_tick_qap_fused)
    can_count = all(
        hasattr(f, a) for f in (_group_tick_fused, _group_tick_qap_fused)
        for a in ("clear_cache", "_cache_size"))
    if not can_count:
        pytest.skip("jax jit cache introspection API unavailable")
    cfg = _cfg(n_slots=6, macro_k=4)
    engine = SAServeEngine(cfg)
    reqs = [_req(0, "syn10"), _req(1, "syn10", seed=222, T0=20.0),
            _creq(2), _creq(3, objective="ackley")]
    for r in reqs:
        engine.submit(r)
    _group_tick_fused.clear_cache()
    _group_tick_qap_fused.clear_cache()
    results = {r.req_id: r for r in engine.run(max_ticks=200)}
    assert len(results) == len(reqs)
    assert _group_tick_fused._cache_size() == 1
    assert _group_tick_qap_fused._cache_size() == 1
    for r in reqs:
        _assert_bit_exact(results[r.req_id], run_standalone(r, cfg))


# ------------------------------------------------- eager validation (sat)
def test_family_incompatible_fields_fail_at_construction():
    """Satellite: family-incompatible request fields raise typed
    ValueErrors from SARequest.__post_init__, never mid-tick."""
    # Generic coupling check still fires first (sa + ess is wrong in any
    # family); the family-typed error covers the pa-method case.
    with pytest.raises(ValueError, match="pa_ess_ratio"):
        _req(0, pa_ess_ratio=0.5)
    with pytest.raises(ValueError, match="population-annealing control"):
        _req(0, method="pa", pa_ess_ratio=0.5)
    with pytest.raises(ValueError, match="no temperature-rung replica"):
        _req(0, method="pt")
    with pytest.raises(ValueError, match="no temperature-rung replica"):
        _req(0, method="pa")
    with pytest.raises(ValueError, match="does not match QAP instance"):
        _req(0, dim=7)
    with pytest.raises(ValueError, match="not servable by the permutation"):
        SARequest(req_id=0, objective="rastrigin", dim=4, n_chains=CPS,
                  T0=10.0, T_min=1.0, rho=0.5, N=5, family="permutation")
    with pytest.raises(ValueError, match="unknown problem family"):
        dataclasses.replace(_creq(0), family="tsp")
    # Continuous requests reject QAP instance names symmetrically.
    with pytest.raises(ValueError, match="not servable"):
        _creq(0, objective="syn10")


def test_family_accessors_are_consistent():
    """The request's family-derived surface (dtype, kid, f_opt, sampler)
    matches the registered family singletons."""
    q, c = _req(0, "grid12"), _creq(1)
    assert q.prob_family is fam_mod.PERMUTATION
    assert c.prob_family is fam_mod.CONTINUOUS
    assert q.state_dtype == np.int32 and c.state_dtype == np.float32
    assert q.kid == qap.INSTANCE_ID["grid12"]
    assert q.f_opt == qap.get("grid12").best_known
    x0 = q.sample_x0(CPS)
    _assert_valid_perms(x0, q.dim)
    np.testing.assert_array_equal(x0, q.sample_x0(CPS))  # deterministic


# --------------------------------------- int32 checkpoint/restore (sat)
@pytest.mark.parametrize("seed", [0, 1, 2, 3])
def test_slot_checkpoint_restore_roundtrip_is_bitwise_int32(seed):
    """Satellite property test: checkpoint -> release -> restore through
    the slot pool is a bitwise identity for int32 permutation blocks,
    with dtype and chain order preserved and no aliasing between the
    checkpoint and the pool."""
    r = np.random.default_rng(seed)
    n_slots = int(r.integers(2, 5))
    pool = SlotPool(n_slots=4, chains_per_slot=CPS)
    req = _req(0, "grid12", n_chains=n_slots * CPS,
               seed=int(r.integers(0, 2 ** 31)))
    pool.assign(rid=0, req=req)
    before = [b.copy() for b in pool.checkpoint(0)]
    assert all(b.dtype == np.int32 for b in before)
    blocks = pool.checkpoint(0)
    pool.release(0)
    pool.restore(rid=1, blocks=blocks)
    after = pool.checkpoint(1)
    assert len(after) == len(before) == n_slots
    for b0, b1 in zip(before, after):
        assert b1.dtype == np.int32
        np.testing.assert_array_equal(b0, b1)
    # chain_base re-derivation: slot j carries base j*CPS in chain order.
    slots = sorted(pool.slots_of(1), key=lambda s: pool.chain_base[s])
    assert [int(pool.chain_base[s]) for s in slots] == \
        [j * CPS for j in range(n_slots)]


def test_restore_does_not_alias_caller_blocks():
    """restore() defensively copies: mutating the caller's arrays after
    restore must not corrupt pool state (int32 path)."""
    pool = SlotPool(n_slots=2, chains_per_slot=CPS)
    blocks = [_rand_perms(CPS, 12, seed=9)]
    pool.restore(rid=0, blocks=blocks)
    snap = pool.get_block(pool.slots_of(0)[0]).copy()
    blocks[0][:] = -1
    np.testing.assert_array_equal(pool.get_block(pool.slots_of(0)[0]), snap)
