"""Multi-tenant SA serving engine: scheduler packing/refill invariants,
per-slot temperature correctness (bit-exact vs standalone), and tenant
isolation in the masked (segmented) champion exchange."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import exchange as exch
from repro.kernels.metropolis_sweep import metropolis_sweep_pallas
from repro.service import (AdmissionScheduler, EngineConfig, SARequest,
                           SAServeEngine, SchedulerConfig, run_standalone)
from repro.service.serve_sa import make_mix

CPS = 8  # small slot blocks keep CPU tests fast


def _req(req_id, objective="rastrigin", dim=4, n_chains=CPS, T0=50.0,
         T_min=1.0, rho=0.8, N=10, **kw):
    return SARequest(req_id=req_id, objective=objective, dim=dim,
                     n_chains=n_chains, T0=T0, T_min=T_min, rho=rho, N=N,
                     seed=100 + req_id, **kw)


def _cfg(n_slots=4, **kw):
    return EngineConfig(n_slots=n_slots, chains_per_slot=CPS,
                        use_pallas=False, **kw)


# ----------------------------------------------------------------- scheduler
def _admitted_ids(plan):
    return [e.req.req_id for e, _ in plan.admitted]


def test_scheduler_never_overcommits():
    sch = AdmissionScheduler(SchedulerConfig())
    for i in range(6):
        sch.submit(_req(i, n_chains=2 * CPS), tick=0)
    plan = sch.admit(free_slots=5, chains_per_slot=CPS, tick=1)
    assert sum(granted for _, granted in plan.admitted) <= 5
    assert len(sch) == 6 - len(plan.admitted)


def test_scheduler_priority_order_and_backfill():
    sch = AdmissionScheduler(SchedulerConfig(policy="priority"))
    sch.submit(_req(0, priority=0, n_chains=CPS), tick=0)
    sch.submit(_req(1, priority=5, n_chains=4 * CPS), tick=0)   # big, urgent
    sch.submit(_req(2, priority=3, n_chains=CPS), tick=0)
    # Only 2 slots free: the urgent request can't fit; backfill admits the
    # smaller ones in priority order instead of idling the pool.
    assert _admitted_ids(sch.admit(2, CPS, tick=1)) == [2, 0]
    assert sch.pending[0].req_id == 1


def test_scheduler_aging_promotes_starved_request():
    sch = AdmissionScheduler(SchedulerConfig(policy="priority", aging=1.0))
    sch.submit(_req(0, priority=0), tick=0)
    sch.submit(_req(1, priority=3), tick=10)
    # At tick 20: req0 aged to 20, req1 to 13 -> the old request wins.
    assert _admitted_ids(sch.admit(1, CPS, tick=20)) == [0]


def test_scheduler_hol_patience_stops_backfill():
    sch = AdmissionScheduler(SchedulerConfig(policy="priority", aging=10.0,
                                             hol_patience=3))
    sch.submit(_req(0, priority=9, n_chains=4 * CPS), tick=0)  # starving head
    sch.submit(_req(1, priority=0, n_chains=CPS), tick=7)
    # Head has waited > patience: backfill past it must stop so freed slots
    # can accumulate for it.
    assert sch.admit(2, CPS, tick=8).admitted == []
    # Once enough slots free up, the head finally goes (and backfill resumes).
    assert _admitted_ids(sch.admit(5, CPS, tick=9)) == [0, 1]


def test_config_defaults_never_alias_between_instances():
    """Default-constructed engines/schedulers share no mutable state: the
    classic shared-default-argument hazard (one EngineConfig()/
    SchedulerConfig() evaluated at def time) must not alias pools, queues
    or result lists across instances."""
    a, b = SAServeEngine(), SAServeEngine()
    assert a.cfg is not None and b.cfg is not None
    assert a.scheduler is not b.scheduler
    assert a.scheduler._queue is not b.scheduler._queue
    assert a.pool is not b.pool and a.pool.owner is not b.pool.owner
    assert a.rids is not b.rids and a.results is not b.results
    a.submit(_req(0))
    assert len(a.scheduler) == 1 and len(b.scheduler) == 0
    # EngineConfig's nested scheduler config must come from a per-instance
    # factory, not one shared literal.
    assert (EngineConfig().scheduler is not EngineConfig().scheduler)
    s1, s2 = AdmissionScheduler(), AdmissionScheduler()
    s1.submit(_req(1), tick=0)
    assert len(s2) == 0


def test_engine_refills_freed_slots():
    """More requests than slots: finished ladders hand slots to the queue."""
    engine = SAServeEngine(_cfg(n_slots=2))
    reqs = [_req(i, rho=0.5, T_min=10.0) for i in range(5)]  # short ladders
    for r in reqs:
        engine.submit(r)
    results = engine.run(max_ticks=500)
    assert {r.req_id for r in results} == set(range(5))
    stats = engine.stats()
    assert stats["occupancy"] > 0.5
    assert engine.pool.n_free == 2


def test_request_validation():
    with pytest.raises(ValueError):
        _req(0, objective="branin")          # not in the kernel registry
    with pytest.raises(ValueError):
        _req(0, rho=1.5)
    engine = SAServeEngine(_cfg(n_slots=2))
    with pytest.raises(ValueError):
        engine.submit(_req(0, n_chains=3 * CPS))  # larger than the pool


# ------------------------------------------------- per-slot T / bit-exactness
@pytest.mark.parametrize("variant", ["delta", "full"])
def test_packed_engine_matches_standalone(variant):
    """Per-slot temperature + placement-invariant RNG: a request co-batched
    with different tenants yields the *same* champion as served alone."""
    cfg = _cfg(n_slots=4, variant=variant)
    engine = SAServeEngine(cfg)
    reqs = [
        _req(0, objective="rastrigin", dim=4, T0=50.0, rho=0.7),
        _req(1, objective="ackley", dim=8, T0=20.0, rho=0.8, N=7),
        _req(2, objective="schwefel", dim=4, T0=100.0, rho=0.75,
             n_chains=2 * CPS),
        _req(3, objective="griewank", dim=8, T0=80.0, rho=0.85, N=12),
    ]
    for r in reqs:
        engine.submit(r)
    packed = {r.req_id: r for r in engine.run(max_ticks=300)}
    assert len(packed) == 4
    for req in reqs:
        solo = run_standalone(req, cfg)
        assert packed[req.req_id].f_best == solo.f_best, req
        np.testing.assert_array_equal(packed[req.req_id].x_best, solo.x_best)
        assert packed[req.req_id].levels_run == solo.levels_run


def test_mixed_schedules_advance_independent_ladders():
    """Two tenants sharing one group anneal at their own temperatures."""
    engine = SAServeEngine(_cfg(n_slots=2))
    fast = _req(0, rho=0.5, T0=50.0, T_min=1.0)    # 6 levels
    slow = _req(1, rho=0.9, T0=50.0, T_min=1.0)    # 38 levels
    engine.submit(fast)
    engine.submit(slow)
    results = {r.req_id: r for r in engine.run(max_ticks=200)}
    assert results[0].levels_run == fast.n_levels
    assert results[1].levels_run == slow.n_levels
    assert results[0].finish_tick < results[1].finish_tick


def test_early_stop_on_target_and_budget():
    tgt = _req(0, objective="rastrigin", dim=2, T0=10.0, rho=0.95,
               T_min=0.001, target_error=5.0)
    bud = _req(1, objective="ackley", dim=4, T0=10.0, rho=0.95, T_min=0.001,
               max_evals=3 * 10 * CPS)  # 3 levels' worth
    engine = SAServeEngine(_cfg(n_slots=2))
    engine.submit(tgt)
    engine.submit(bud)
    results = {r.req_id: r for r in engine.run(max_ticks=500)}
    assert results[0].finish_reason == "target"
    assert results[0].levels_run < tgt.n_levels
    assert results[1].finish_reason == "budget"
    assert results[1].n_evals <= bud.max_evals + 10 * CPS


# ------------------------------------------------------------ tenant isolation
def test_segment_champion_masks_tenants():
    fx = jnp.asarray([5.0, 1.0, 7.0, 3.0])
    x = jnp.arange(8.0).reshape(4, 2)
    seg = jnp.asarray([0, 0, 1, 1], jnp.int32)
    xb, fb, ib = exch.segment_champion(x, fx, seg, num_segments=3)
    assert fb[0] == 1.0 and ib[0] == 1
    assert fb[1] == 3.0 and ib[1] == 3
    assert fb[2] == jnp.inf and ib[2] == 4  # empty segment flagged, not aliased


def test_segmented_exchange_never_crosses_tenants():
    """Tenant B's global-best state must not leak into tenant A's chains."""
    x = jnp.stack([jnp.full((2,), float(i)) for i in range(6)])
    fx = jnp.asarray([9.0, 4.0, 9.0, 0.5, 9.0, 9.0])  # global best in seg 1
    seg = jnp.asarray([0, 0, 0, 1, 1, 1], jnp.int32)
    x2, f2, xb, fb = exch.exchange_sync_segmented(x, fx, seg, num_segments=2)
    assert bool(jnp.all(f2[:3] == 4.0)) and bool(jnp.all(x2[:3] == 1.0))
    assert bool(jnp.all(f2[3:] == 0.5)) and bool(jnp.all(x2[3:] == 3.0))
    assert fb.tolist() == [4.0, 0.5]
    # adopt_mask=False leaves chains untouched (async tenants / free slots)
    x3, f3, _, _ = exch.exchange_sync_segmented(
        x, fx, seg, 2, adopt_mask=jnp.asarray([False] * 6))
    assert bool(jnp.all(x3 == x)) and bool(jnp.all(f3 == fx))


def test_engine_isolates_tenants_end_to_end():
    """A tenant with a far-better objective never contaminates the other:
    the other tenant's states stay inside its own box bounds."""
    engine = SAServeEngine(_cfg(n_slots=2))
    # rastrigin box is [-5.12, 5.12]; schwefel's is [-512, 512] and its
    # champion values are ~-418 — any cross-tenant adoption is detectable.
    engine.submit(_req(0, objective="schwefel", dim=4, T0=100.0, rho=0.7))
    engine.submit(_req(1, objective="rastrigin", dim=4, T0=50.0, rho=0.7))
    results = {r.req_id: r for r in engine.run(max_ticks=200)}
    assert np.all(np.abs(results[1].x_best) <= 5.12 + 1e-6)
    assert results[1].f_best >= 0.0  # rastrigin is nonnegative
    assert results[0].f_best < -300.0


# ------------------------------------------------------- kernel-level pieces
def test_kernel_per_block_temperature_matches_scalar_calls():
    """(blk0 at T1, blk1 at T2) in ONE launch == two scalar-T launches."""
    from repro.kernels import objective_math as om
    lo, hi = om.BOX[om.KID_RASTRIGIN]
    rng = np.random.default_rng(0)
    x = (lo + rng.random((16, 4), dtype=np.float32) * (hi - lo))
    xa, fa = metropolis_sweep_pallas(
        jnp.asarray(x), jnp.asarray([3.0, 0.05], jnp.float32), 7, 0,
        kid=om.KID_RASTRIGIN, n_steps=8, blk=8, variant="delta",
        interpret=True)
    x1, f1 = metropolis_sweep_pallas(jnp.asarray(x[:8]), 3.0, 7, 0,
                                     kid=om.KID_RASTRIGIN, n_steps=8, blk=8,
                                     variant="delta", interpret=True)
    x2, f2 = metropolis_sweep_pallas(jnp.asarray(x[8:]), 0.05, 7, 0,
                                     kid=om.KID_RASTRIGIN, n_steps=8, blk=8,
                                     variant="delta", interpret=True,
                                     chain_base=jnp.asarray([8], jnp.uint32))
    np.testing.assert_array_equal(np.asarray(xa[:8]), np.asarray(x1))
    np.testing.assert_array_equal(np.asarray(xa[8:]), np.asarray(x2))
    np.testing.assert_array_equal(np.asarray(fa), np.asarray(jnp.concatenate([f1, f2])))


def test_kernel_pads_ragged_chain_axis():
    """chains % blk != 0 pads instead of raising, and matches the oracle."""
    from repro.kernels import objective_math as om, ref
    lo, hi = om.BOX[om.KID_ACKLEY]
    rng = np.random.default_rng(1)
    x = jnp.asarray(lo + rng.random((12, 4), dtype=np.float32) * (hi - lo))
    xk, fk = metropolis_sweep_pallas(x, 2.0, 3, 0, kid=om.KID_ACKLEY,
                                     n_steps=6, blk=8, variant="full",
                                     interpret=True)
    xr, fr = ref.metropolis_sweep_ref(x, 2.0, 3, 0, kid=om.KID_ACKLEY,
                                      n_steps=6, variant="full")
    assert xk.shape == (12, 4) and fk.shape == (12,)
    np.testing.assert_allclose(np.asarray(xk), np.asarray(xr),
                               rtol=2e-4, atol=2e-4)


def test_core_sweep_accepts_per_chain_temperature():
    """core/metropolis.py sweeps broadcast (chains,) temperature arrays."""
    import jax
    from repro.core import metropolis
    from repro.objectives import functions as F
    obj = F.rastrigin(4)
    key = jax.random.PRNGKey(0)
    x = obj.sample_uniform(key, (16,)).astype(jnp.float32)
    fx = obj(x)
    T = jnp.concatenate([jnp.full((8,), 1e-9), jnp.full((8,), 1e9)])
    _, x1, f1 = metropolis.sweep_full(jax.random.PRNGKey(1), x, fx, T,
                                      objective=obj, n_steps=20)
    # Cold half is greedy (never worsens); hot half accepts essentially all.
    assert bool(jnp.all(f1[:8] <= fx[:8] + 1e-5))
    np.testing.assert_allclose(np.asarray(f1), np.asarray(obj(x1)),
                               rtol=1e-5, atol=1e-5)


# -------------------------------------------------------------- CLI mix sanity
def test_make_mix_is_heterogeneous():
    reqs = make_mix(8, CPS, seed=0)
    assert len({r.objective for r in reqs}) >= 3
    assert len({r.dim for r in reqs}) >= 2
    assert len({(r.T0, r.rho, r.N) for r in reqs}) >= 2


# ------------------------------------------------------ runtime kid dispatch
def test_kernel_per_block_kid_matches_scalar_calls():
    """(blk0 on rastrigin, blk1 on ackley) in ONE launch == two scalar-kid
    launches — mixed-objective co-batches follow the identical trajectory.

    The *states* (and therefore every Metropolis accept/reject decision)
    must be bit-equal.  The returned objective value is the delta-variant's
    running accumulator, and the runtime-dispatch and static-kid programs
    are two different XLA lowerings — their fusion clusters may contract
    floats differently, so the cached f is held to ULP scale rather than
    bitwise.  (The serving bit-exactness oracle — engine vs run_standalone
    — compares runtime-vs-runtime, the same program, and stays bitwise;
    test_mixed_objective_cobatch_matches_standalone asserts that.)
    """
    from repro.kernels import objective_math as om
    rng = np.random.default_rng(3)
    x = np.empty((16, 4), np.float32)
    for half, kid in ((slice(0, 8), om.KID_RASTRIGIN),
                      (slice(8, 16), om.KID_ACKLEY)):
        lo, hi = om.BOX[kid]
        x[half] = lo + rng.random((8, 4), dtype=np.float32) * (hi - lo)
    kids = jnp.asarray([om.KID_RASTRIGIN, om.KID_ACKLEY], jnp.int32)
    xa, fa = metropolis_sweep_pallas(jnp.asarray(x), 2.0, 7, 0, kid=kids,
                                     n_steps=8, blk=8, variant="delta",
                                     interpret=True)
    x1, f1 = metropolis_sweep_pallas(jnp.asarray(x[:8]), 2.0, 7, 0,
                                     kid=om.KID_RASTRIGIN, n_steps=8, blk=8,
                                     variant="delta", interpret=True)
    x2, f2 = metropolis_sweep_pallas(jnp.asarray(x[8:]), 2.0, 7, 0,
                                     kid=om.KID_ACKLEY, n_steps=8, blk=8,
                                     variant="delta", interpret=True,
                                     chain_base=jnp.asarray([8], jnp.uint32))
    np.testing.assert_array_equal(np.asarray(xa[:8]), np.asarray(x1))
    np.testing.assert_array_equal(np.asarray(xa[8:]), np.asarray(x2))
    np.testing.assert_allclose(np.asarray(fa),
                               np.asarray(jnp.concatenate([f1, f2])),
                               rtol=1e-6, atol=1e-5)


@pytest.mark.parametrize("variant", ["delta", "full"])
def test_mixed_objective_cobatch_matches_standalone(variant):
    """All four registry objectives at the SAME (dim, N) share one dispatch
    group each tick — and every champion is still bit-exact vs standalone."""
    cfg = _cfg(n_slots=4, variant=variant)
    engine = SAServeEngine(cfg)
    reqs = [_req(i, objective=obj, dim=4, N=10, T0=50.0, rho=0.7)
            for i, obj in enumerate(
                ["schwefel", "rastrigin", "ackley", "griewank"])]
    for r in reqs:
        engine.submit(r)
    packed = {r.req_id: r for r in engine.run(max_ticks=200)}
    assert len(packed) == 4
    # identical (dim, N) and simultaneous admission => exactly one group
    # launch per tick, even with four different objectives in flight.
    assert engine.group_launches == reqs[0].n_levels
    for req in reqs:
        solo = run_standalone(req, cfg)
        assert packed[req.req_id].f_best == solo.f_best, req
        np.testing.assert_array_equal(packed[req.req_id].x_best, solo.x_best)


def test_out_of_range_kid_rejected():
    """Runtime dispatch must not silently fall through to kid 0: concrete
    out-of-registry ids raise at the kernel and oracle entry points."""
    from repro.kernels import objective_math as om, ref
    x = jnp.zeros((8, 4), jnp.float32)
    for bad in (om.N_KIDS, -1, jnp.asarray([0, om.N_KIDS], jnp.int32)):
        with pytest.raises(ValueError, match="registry"):
            metropolis_sweep_pallas(x, 1.0, 0, 0, kid=bad, n_steps=2, blk=4,
                                    interpret=True)
    with pytest.raises(ValueError, match="registry"):
        ref.metropolis_sweep_ref(x, 1.0, 0, 0, kid=om.N_KIDS, n_steps=2)


def test_one_lowering_serves_all_objectives():
    """Compile-count assertion: at a fixed (dim, N) the engine compiles ONE
    sweep program no matter how many registry objectives are in flight —
    kid is runtime SMEM data, not a lowering constant."""
    from repro.service.engine import _group_tick
    if not (hasattr(_group_tick, "clear_cache")
            and hasattr(_group_tick, "_cache_size")):
        pytest.skip("jax jit cache introspection API unavailable")
    engine = SAServeEngine(_cfg(n_slots=4))
    for i, obj in enumerate(["schwefel", "rastrigin", "ackley", "griewank"]):
        engine.submit(_req(i, objective=obj, dim=4, N=10, T0=50.0, rho=0.7))
    _group_tick.clear_cache()
    engine.run(max_ticks=200)
    assert len(engine.results) == 4
    assert _group_tick._cache_size() == 1
