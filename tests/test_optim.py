"""Optimizer unit tests: AdamW reference math, Adafactor factored stats."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.optim import (OptConfig, apply_updates, init_opt_state,
                         opt_update)
from repro.optim.optimizers import schedule_lr


def _tree():
    return {"w": jnp.asarray([[1.0, -2.0], [0.5, 3.0]]),
            "b": jnp.asarray([0.1, -0.1])}


def test_adamw_matches_reference_step():
    cfg = OptConfig(kind="adamw", lr=1e-2, b1=0.9, b2=0.999, eps=1e-8,
                    weight_decay=0.0, grad_clip=0.0, warmup_steps=0,
                    total_steps=1, min_lr_ratio=1.0)
    p = _tree()
    g = jax.tree.map(lambda x: jnp.ones_like(x) * 0.5, p)
    opt = init_opt_state(p, cfg)
    upd, opt2 = opt_update(g, p, opt, cfg)
    p2 = apply_updates(p, upd)

    # reference: bias-corrected adam, step 1
    m_hat = 0.5  # (0.1*0.5)/(1-0.9)
    v_hat = 0.25  # (0.001*0.25)/(1-0.999)
    expect = -1e-2 * m_hat / (np.sqrt(v_hat) + 1e-8)
    np.testing.assert_allclose(np.asarray(upd["w"]),
                               np.full((2, 2), expect), rtol=1e-5)
    np.testing.assert_allclose(np.asarray(p2["w"]),
                               np.asarray(p["w"]) + expect, rtol=1e-5)


def test_weight_decay_is_decoupled():
    cfg = OptConfig(kind="adamw", lr=1e-2, weight_decay=0.1, grad_clip=0.0,
                    warmup_steps=0, total_steps=1, min_lr_ratio=1.0)
    p = _tree()
    g = jax.tree.map(jnp.zeros_like, p)
    opt = init_opt_state(p, cfg)
    upd, _ = opt_update(g, p, opt, cfg)
    # zero grad => update is pure decay: -lr * wd * p
    np.testing.assert_allclose(np.asarray(upd["w"]),
                               -1e-2 * 0.1 * np.asarray(p["w"]), rtol=1e-5)


def test_grad_clip_applies():
    cfg = OptConfig(kind="adamw", lr=1.0, grad_clip=1.0, weight_decay=0.0,
                    warmup_steps=0, total_steps=1, min_lr_ratio=1.0)
    p = _tree()
    g = jax.tree.map(lambda x: jnp.full_like(x, 100.0), p)
    opt = init_opt_state(p, cfg)
    upd, _ = opt_update(g, p, opt, cfg)
    # after clipping to norm 1, |update| bounded by lr/(sqrt(v_hat)) ~ 1
    assert float(jnp.max(jnp.abs(upd["w"]))) < 2.0


def test_adafactor_state_is_factored():
    cfg = OptConfig(kind="adafactor")
    p = {"w": jnp.zeros((8, 4)), "b": jnp.zeros((4,))}
    opt = init_opt_state(p, cfg)
    v = opt["v"]["w"]
    assert set(v.keys()) == {"vr", "vc"}
    assert v["vr"].shape == (8,) and v["vc"].shape == (4,)
    # vector params keep full second moment
    assert opt["v"]["b"]["v"].shape == (4,)


def test_adafactor_descends():
    cfg = OptConfig(kind="adafactor", lr=0.1, weight_decay=0.0,
                    warmup_steps=0, total_steps=1, min_lr_ratio=1.0,
                    grad_clip=0.0)
    p = {"w": jnp.asarray([[2.0, -3.0], [1.0, 4.0]])}
    opt = init_opt_state(p, cfg)

    def loss(p):
        return jnp.sum(p["w"] ** 2)

    for _ in range(50):
        g = jax.grad(loss)(p)
        upd, opt = opt_update(g, p, opt, cfg)
        p = apply_updates(p, upd)
    assert float(loss(p)) < 1.0


def test_lr_schedule_shape():
    cfg = OptConfig(lr=1e-3, warmup_steps=10, total_steps=100,
                    min_lr_ratio=0.1)
    lrs = [float(schedule_lr(cfg, jnp.asarray(s))) for s in range(100)]
    assert lrs[0] < lrs[9] <= 1e-3 + 1e-9          # warmup rises
    assert abs(lrs[10] - 1e-3) < 1e-4              # peak after warmup
    assert lrs[-1] < lrs[50] < lrs[11]             # cosine decays
    assert lrs[-1] >= 0.1 * 1e-3 - 1e-9            # floor respected
