"""Observability subsystem: telemetry, tracing, event log (PR 6).

Tentpole guarantees:

* **zero overhead off**: with telemetry disabled (the default) the
  engine allocates no span objects and compiles no extra programs;
* **bit-exact on**: enabling metrics + tracing + the event log perturbs
  no trajectory — champion histories match the disabled run and the
  standalone oracle at every ladder level;
* **trace contract**: ``--trace`` output validates against the
  checked-in schema (trace_schema.json) and uses only the tick-phase
  taxonomy;
* **metrics survive the elastic fleet**: a retired shard's per-shard
  series are still present after drain/resize;
* **decision log is a regression oracle**: the same seeded run produces
  a byte-identical JSONL stream, replayable against a fresh run.

Everything runs on logical shards (tier-1); the CI multi-device job
re-runs the CLI smoke with 4 real XLA host devices.
"""

import json

import pytest

from repro.service import (
    ArrivalProcess,
    EngineConfig,
    EventLog,
    PhaseTimer,
    SARequest,
    SAServeEngine,
    SchedulerConfig,
    Telemetry,
    TICK_PHASES,
    TraceBuilder,
    compile_events,
    run_standalone,
    validate_trace,
)
from repro.service.engine import _group_tick
from repro.service.telemetry import Histogram, MetricsRegistry

CPS = 8


def _cfg(n_slots=4, n_devices=1, **kw):
    return EngineConfig(n_slots=n_slots, chains_per_slot=CPS,
                        n_devices=n_devices, **kw)


def _req(req_id, objective="rastrigin", dim=4, n_chains=CPS, seed=None,
         **kw):
    kw.setdefault("T0", 10.0)
    kw.setdefault("T_min", 1.0)
    kw.setdefault("rho", 0.7)
    kw.setdefault("N", 10)
    return SARequest(req_id=req_id, objective=objective, dim=dim,
                     n_chains=n_chains,
                     seed=100 + req_id if seed is None else seed, **kw)


def _mix(n=4):
    objs = ["rastrigin", "ackley", "griewank", "schwefel"]
    return [_req(i, objective=objs[i % len(objs)], priority=i % 2)
            for i in range(n)]


def _serve(telemetry=None, n=4, n_devices=1, **cfg_kw):
    engine = SAServeEngine(_cfg(n_devices=n_devices, **cfg_kw),
                           telemetry=telemetry)
    for r in _mix(n):
        engine.submit(r)
    results = engine.run(max_ticks=400)
    return engine, {r.req_id: r for r in results}


# ------------------------------------------------------------ disabled path
def test_disabled_allocates_no_spans_and_compiles_nothing_extra():
    compile_before = compile_events()
    spans_before = PhaseTimer.spans_entered
    engine, results = _serve()
    assert len(results) == 4
    # The zero-overhead witness: the class-wide span counter never moved.
    assert PhaseTimer.spans_entered == spans_before
    # And the engine defaults hold: no registry, no trace, no events.
    assert engine.telemetry.enabled is False
    assert engine.telemetry.registry is None
    compile_disabled = compile_events() - compile_before

    # Enabled run: identical config => no *additional* backend programs
    # beyond what the disabled run compiled (telemetry adds zero).
    before = compile_events()
    _serve(Telemetry(trace=TraceBuilder(), events=EventLog()))
    assert compile_events() - before <= compile_disabled


def test_enabled_compiles_no_extra_group_programs():
    if not (hasattr(_group_tick, "clear_cache")
            and hasattr(_group_tick, "_cache_size")):
        pytest.skip("kernel cache introspection unavailable")
    _group_tick.clear_cache()
    _serve()
    baseline = _group_tick._cache_size()
    _group_tick.clear_cache()
    _serve(Telemetry(trace=TraceBuilder(), events=EventLog()))
    assert _group_tick._cache_size() == baseline


# ------------------------------------------------------------- bit-exactness
def test_enabled_is_bit_exact_at_every_level():
    _, plain = _serve()
    tel = Telemetry(trace=TraceBuilder(), events=EventLog())
    _, traced = _serve(tel)
    assert plain.keys() == traced.keys()
    for rid in plain:
        a, b = plain[rid], traced[rid]
        # Whole champion trajectory, level by level — not just the final f.
        assert a.champion_history == b.champion_history
        assert a.f_best == b.f_best
        assert a.finish_tick == b.finish_tick
        assert a.finish_reason == b.finish_reason
    # And against the standalone oracle (the --check invariant).
    cfg = _cfg()
    for req in _mix(4):
        solo = run_standalone(req, cfg)
        assert traced[req.req_id].f_best == solo.f_best
        assert traced[req.req_id].champion_history == solo.champion_history


def test_enabled_is_bit_exact_under_preemption_and_shards():
    def serve(tel):
        cfg = _cfg(n_slots=2, n_devices=2, scheduler=SchedulerConfig(
            policy="priority", overload="preempt", preemption_budget=1))
        engine = SAServeEngine(cfg, telemetry=tel)
        reqs = [_req(i, priority=i % 3, on_overload="preempt")
                for i in range(6)]
        arrivals = ArrivalProcess.poisson(reqs, rate=0.7, seed=7)
        res = {r.req_id: r for r in
               engine.run_stream(arrivals, max_ticks=400)}
        return engine, res

    _, plain = serve(None)
    engine, traced = serve(Telemetry(trace=TraceBuilder(),
                                     events=EventLog()))
    assert plain.keys() == traced.keys()
    for rid in plain:
        assert plain[rid].champion_history == traced[rid].champion_history
        assert plain[rid].finish_tick == traced[rid].finish_tick


# ------------------------------------------------------------------ tracing
def test_trace_validates_against_checked_in_schema():
    tel = Telemetry(trace=TraceBuilder())
    engine, results = _serve(tel, n_devices=2)
    doc = tel.trace.to_json()
    assert validate_trace(doc) == []
    phs = {e["ph"] for e in doc["traceEvents"]}
    assert {"X", "M", "b", "e"} <= phs
    # Per-shard phase spans landed on per-shard tracks (tid shard+1).
    tick_spans = [e for e in doc["traceEvents"] if e.get("cat") == "tick"]
    assert {e["name"] for e in tick_spans} <= set(TICK_PHASES)
    assert {e["tid"] for e in tick_spans} >= {0, 1, 2}
    # Every request has a begin and a terminal end on its async track.
    for rid in results:
        evs = [e for e in doc["traceEvents"]
               if e.get("cat") == "request" and e.get("id") == rid]
        assert [e["ph"] for e in evs][0] == "b"
        assert [e["ph"] for e in evs][-1] == "e"
    # The document round-trips through real JSON.
    assert validate_trace(json.loads(tel.trace.dumps())) == []


def test_trace_schema_rejects_malformed_events():
    assert validate_trace({"traceEvents": "nope"}) != []
    bad_ph = {"traceEvents": [
        {"ph": "Z", "name": "x", "pid": 0, "tid": 0}],
        "displayTimeUnit": "ms"}
    assert any("not in" in e for e in validate_trace(bad_ph))
    bad_phase = {"traceEvents": [
        {"ph": "X", "name": "warp", "cat": "tick", "pid": 0, "tid": 0,
         "ts": 0, "dur": 1}], "displayTimeUnit": "ms"}
    assert any("unknown tick phase" in e for e in validate_trace(bad_phase))


# -------------------------------------------------------------- metrics
def test_phase_metrics_cover_the_taxonomy():
    tel = Telemetry()
    engine, _ = _serve(tel)
    snap = tel.registry.snapshot()
    phases = {k.split("=", 1)[1]
              for k in snap["sa_tick_phase_seconds"]["series"]}
    assert phases == set(TICK_PHASES)
    for summary in snap["sa_tick_phase_seconds"]["series"].values():
        assert summary["count"] > 0
        assert summary["p50"] <= summary["p90"] <= summary["p99"]
    assert snap["sa_ticks_total"]["series"][""] == engine.tick_count
    # stats() mirrors the same data for humans.
    st = engine.stats()
    assert set(st["phases"]["aggregate"]) == set(TICK_PHASES)
    assert st["phases"]["per_shard"]["0"]["dispatch"] > 0


def test_phase_timer_tracks_host_cpu_alongside_wall():
    import time

    t = PhaseTimer(time.perf_counter)
    with t("dispatch", shard=0):
        sum(range(50_000))        # burn host CPU: cpu time must register
    acc, shard_acc, raw, cpu = t.drain()
    assert set(cpu) == {"dispatch"}
    # One thread's CPU time can never exceed the span's wall time.
    assert 0.0 <= cpu["dispatch"] <= acc["dispatch"] + 1e-3
    # drain() resets both clocks.
    assert t.drain() == ({}, {}, [], {})


def test_phase_cpu_metric_covers_host_phases_and_stats():
    tel = Telemetry()
    engine, _ = _serve(tel)
    cpu = engine.stats()["phases"]["cpu_seconds"]
    wall = {p: s["sum"]
            for p, s in engine.stats()["phases"]["aggregate"].items()}
    # The launch path burned host CPU, and the registry mirrors stats().
    assert cpu["dispatch"] > 0
    assert cpu == {p: secs for (p,), secs
                   in tel.registry["sa_tick_phase_cpu_seconds_total"]
                   .series.items()}
    # Run-total host CPU per phase is bounded by the wall spans it ran in
    # (thread_time of one thread cannot exceed elapsed wall).
    for phase, secs in cpu.items():
        assert secs <= wall[phase] + 1e-2


def test_metrics_survive_drain_and_resize():
    tel = Telemetry(events=EventLog())
    cfg = _cfg(n_slots=2, n_devices=3, migration_budget=2)
    engine = SAServeEngine(cfg, telemetry=tel)
    for r in _mix(6):
        engine.submit(r)
    for _ in range(3):
        engine.tick()
    victim = max(s.index for s in engine.live_shards)
    engine.drain(victim)
    engine.run(max_ticks=400)
    assert any(i == victim for i, _ in engine.retired_shards)
    # The retired shard's per-shard series are still in the registry...
    used = tel.registry["sa_shard_slots_used"]
    assert (str(victim),) in used.series
    phase_keys = {k for k in tel.registry["sa_shard_phase_seconds_total"]
                  .series if k[0] == str(victim)}
    assert phase_keys
    # ...and its lifecycle shows up in decisions + events.
    decisions = tel.registry["sa_scheduler_decisions_total"]
    assert decisions.value("drain") == 1
    assert decisions.value("shard_retired") == 1
    kinds = {r["event"] for r in tel.events.records}
    assert {"admit", "drain", "shard_retired"} <= kinds
    # Growing again afterwards keeps old series and adds new ones.
    engine.add_shards(1)
    assert decisions.value("shard_added") == 1


def test_prometheus_exposition_and_histogram_quantiles():
    reg = MetricsRegistry()
    c = reg.counter("requests_total", "Requests", ("status",))
    c.inc(3, "ok")
    c.inc(1, "err")
    h = reg.histogram("latency_seconds", "Latency")
    for ms in range(1, 101):
        h.observe(ms / 1000.0)
    # Exponential-bucket quantile error is bounded by the growth factor.
    assert h.quantile(0.5) == pytest.approx(0.050, rel=0.15)
    assert h.quantile(0.99) == pytest.approx(0.099, rel=0.15)
    assert h.summary()["count"] == 100
    text = reg.exposition()
    assert '# TYPE requests_total counter' in text
    assert 'requests_total{status="ok"} 3' in text
    assert 'latency_seconds{quantile="0.5"}' in text
    assert 'latency_seconds_count 100' in text
    # Idempotent re-registration returns the same series; conflicts raise.
    assert reg.counter("requests_total", labels=("status",)) is c
    with pytest.raises(ValueError):
        reg.gauge("requests_total")
    with pytest.raises(ValueError):
        c.inc(-1, "ok")


# ------------------------------------------------------------- event log
def test_event_log_is_deterministic_and_replayable():
    def serve():
        tel = Telemetry(events=EventLog())
        cfg = _cfg(n_slots=2, n_devices=2, scheduler=SchedulerConfig(
            policy="priority", overload="preempt"))
        engine = SAServeEngine(cfg, telemetry=tel)
        reqs = [_req(i, priority=i % 3, on_overload="preempt")
                for i in range(5)]
        engine.run_stream(ArrivalProcess.poisson(reqs, rate=0.8, seed=3),
                          max_ticks=400)
        return tel.events

    log_a, log_b = serve(), serve()
    # Byte-identical run-to-run: the scheduler-decision regression oracle.
    assert log_a.dumps() == log_b.dumps()
    records = EventLog.loads(log_a.dumps())
    assert records == log_a.records
    # Tick-clock fields only: no wall-clock key may leak in.
    for rec in records:
        assert "wall" not in json.dumps(rec)
        assert rec["tick"] >= 0
    kinds = {r["event"] for r in records}
    assert "admit" in kinds and "retire" in kinds


# ----------------------------------------------------- macro-tick fusion
def test_macro_tick_disabled_telemetry_allocates_zero_spans():
    """The zero-overhead guarantee survives fusion: a K=4 run with
    telemetry off never enters a span."""
    spans_before = PhaseTimer.spans_entered
    engine, results = _serve(macro_k=4)
    assert len(results) == 4
    assert PhaseTimer.spans_entered == spans_before
    assert engine.telemetry.enabled is False


def test_macro_tick_phases_cover_taxonomy_and_level_clock():
    """At K>1 the per-tick spans still cover the whole phase taxonomy
    (device_wait fences the fused K-level program; dispatch is the host
    pack+launch), and sa_ticks_total stays on the ladder-level clock —
    equal to tick_count, which counts levels, not launches."""
    tel = Telemetry()
    engine, _ = _serve(tel, macro_k=4)
    snap = tel.registry.snapshot()
    phases = {k.split("=", 1)[1]
              for k in snap["sa_tick_phase_seconds"]["series"]}
    assert phases == set(TICK_PHASES)
    for summary in snap["sa_tick_phase_seconds"]["series"].values():
        assert summary["count"] > 0
    assert snap["sa_ticks_total"]["series"][""] == engine.tick_count
    # Far fewer launches than levels: the fusion actually engaged.
    assert engine.group_launches < engine.tick_count


def test_macro_tick_event_log_deterministic_and_boundary_stamped():
    """The decision log stays byte-identical run-to-run at K=4, and every
    decision is stamped with the macro-tick-boundary tick clock (this
    closed-loop mix runs uncontended, so boundaries sit at multiples of
    K until the final partial macro-tick — no decision may carry an
    intra-macro-tick timestamp)."""
    def serve():
        tel = Telemetry(events=EventLog())
        engine, _ = _serve(tel, macro_k=4)
        return tel.events

    log_a, log_b = serve(), serve()
    assert log_a.dumps() == log_b.dumps()
    records = EventLog.loads(log_a.dumps())
    assert {r["event"] for r in records} >= {"admit", "retire"}
    for rec in records:
        assert rec["tick"] % 4 == 0, "decision stamped off a boundary"


def test_macro_tick_trace_validates_and_is_bit_exact():
    tel = Telemetry(trace=TraceBuilder(), events=EventLog())
    _, plain = _serve(macro_k=4)
    engine, traced = _serve(tel, macro_k=4)
    assert plain.keys() == traced.keys()
    for rid in plain:
        assert plain[rid].champion_history == traced[rid].champion_history
        assert plain[rid].finish_tick == traced[rid].finish_tick
    doc = tel.trace.to_json()
    assert validate_trace(doc) == []
    tick_spans = [e for e in doc["traceEvents"] if e.get("cat") == "tick"]
    assert {e["name"] for e in tick_spans} <= set(TICK_PHASES)


# ------------------------------------------------------------------ CLI
def test_serve_sa_cli_trace_events_metrics(tmp_path, capsys):
    from repro.service import serve_sa
    trace_p = tmp_path / "trace.json"
    events_p = tmp_path / "events.jsonl"
    metrics_p = tmp_path / "metrics.prom"
    serve_sa.main([
        "--requests", "3", "--slots", "2", "--chains-per-slot", "8",
        "--max-ticks", "200", "--json",
        "--trace", str(trace_p), "--events", str(events_p),
        "--metrics", str(metrics_p)])
    doc = json.loads(capsys.readouterr().out)
    # --check ran (default) and passed bit-exact with telemetry on.
    assert doc["check"]["bit_exact"] == doc["check"]["served"] == 3
    assert "sa_tick_phase_seconds" in doc["metrics"]
    trace = json.loads(trace_p.read_text())
    assert validate_trace(trace) == []
    assert len(EventLog.loads(events_p.read_text())) > 0
    assert "# TYPE sa_ticks_total counter" in metrics_p.read_text()
