"""Macro-tick fusion differential suite (tentpole gate).

The engine's ``macro_k`` fuses K temperature levels into ONE device
dispatch: an on-device ``fori_loop`` over [masked sweep -> segmented
champion exchange], with per-slot level cursors (dead blocks pass state
through bit-exactly), per-level temperatures threaded as SMEM rows, and
the chain state kept device-resident between launches via donated
ping-pong buffers.  Scheduling decisions (admission, preemption,
migration, drain/resize, retirement) land only on macro-tick boundaries,
and the tick clock stays in LADDER-LEVEL units (one macro-tick advances
it by K), so latency percentiles are comparable across K.

The gate is differential: for every K the engine must be *bit-equal* —
champion history, f_best, x_best, finish reason, evals, and (for aligned
decision schedules) finish tick — to the K=1 engine and to the
``run_standalone`` oracle.  The counter-based RNG keys on logical
(chain, step) coordinates, so fusing levels must not perturb a single
draw; any drift is a correctness bug, not noise.
"""
import numpy as np
import pytest

from repro.service import (ArrivalProcess, EngineConfig, SARequest,
                           SAServeEngine, Telemetry, latency_summary,
                           run_standalone)
from repro.service.engine import _group_tick_fused

CPS = 8
K_VALUES = (2, 4, 8)


def _req(req_id, objective="rastrigin", **kw):
    kw.setdefault("dim", 4)
    kw.setdefault("n_chains", CPS)
    kw.setdefault("T0", 50.0)
    kw.setdefault("T_min", 1.0)
    kw.setdefault("rho", 0.8)      # 18-level ladder
    kw.setdefault("N", 10)
    return SARequest(req_id=req_id, objective=objective,
                     seed=100 + req_id, **kw)


def _cfg(k=1, n_devices=1, **kw):
    kw.setdefault("n_slots", 4)
    return EngineConfig(chains_per_slot=CPS, n_devices=n_devices,
                        macro_k=k, use_pallas=False, **kw)


#: Mixed objectives, dims and footprints — one 2-slot request so the
#: fused path sees multi-block tenants and a pad block (5 blocks -> 8).
MIX = [
    dict(objective="rastrigin"),
    dict(objective="ackley", dim=8),
    dict(objective="griewank", n_chains=2 * CPS),
    dict(objective="schwefel"),
]


def _mix(**extra):
    return [_req(i, **{**kw, **extra}) for i, kw in enumerate(MIX)]


def _serve(reqs, k, n_devices=2, ops=None, telemetry=None, **cfg_kw):
    cfg = _cfg(k=k, n_devices=n_devices, **cfg_kw)
    engine = SAServeEngine(cfg, telemetry=telemetry)
    for r in reqs:
        engine.submit(r)
    if ops is not None:
        ops(engine)
    results = {r.req_id: r for r in engine.run(max_ticks=2000)}
    return results, engine, cfg


def _assert_bit_equal(a, b, *, ticks=True):
    assert a.keys() == b.keys()
    for rid in a:
        ra, rb = a[rid], b[rid]
        assert ra.champion_history == rb.champion_history, rid
        assert ra.f_best == rb.f_best, rid
        np.testing.assert_array_equal(ra.x_best, rb.x_best)
        assert ra.finish_reason == rb.finish_reason, rid
        assert ra.levels_run == rb.levels_run, rid
        assert ra.n_evals == rb.n_evals, rid
        if ticks:
            assert ra.finish_tick == rb.finish_tick, rid
            assert ra.first_tick == rb.first_tick, rid


# ------------------------------------------------------------ equivalence
@pytest.mark.parametrize("k", K_VALUES)
def test_fused_engine_bit_equal_to_k1_and_standalone(k):
    """The headline differential: mixed objectives/dims/footprints on a
    2-shard fleet — every K produces the identical result set, including
    ladder-level finish ticks, and matches the standalone oracle."""
    base, _, _ = _serve(_mix(), k=1)
    fused, _, cfg = _serve(_mix(), k=k)
    _assert_bit_equal(base, fused)
    for req in _mix():
        solo = run_standalone(req, cfg)
        assert fused[req.req_id].f_best == solo.f_best
        assert fused[req.req_id].champion_history == solo.champion_history


def test_k_exceeding_remaining_levels_truncates_cleanly():
    """K larger than the whole ladder: the fused program still runs K
    slots of work on device but only `n_levels` are live — results and
    the ladder-level clock are identical to K=1."""
    short = [_req(0, T0=4.0, T_min=1.0, rho=0.5),       # 2-level ladder
             _req(1, objective="ackley", T0=4.0, T_min=1.0, rho=0.5)]
    base, eng1, _ = _serve(short, k=1)
    fused, eng8, cfg = _serve(short, k=8)
    _assert_bit_equal(base, fused)
    assert fused[0].levels_run == short[0].n_levels == 2
    assert eng8.tick_count == eng1.tick_count
    for req in short:
        solo = run_standalone(req, cfg)
        assert fused[req.req_id].champion_history == solo.champion_history


def test_k1_degenerate_path_compiles_no_fused_programs():
    """macro_k=1 must keep the classic per-level launch path exactly: no
    fused program is traced, no device-resident block refs are created,
    and the dispatch cache stays empty."""
    if not (hasattr(_group_tick_fused, "clear_cache")
            and hasattr(_group_tick_fused, "_cache_size")):
        pytest.skip("kernel cache introspection unavailable")
    _group_tick_fused.clear_cache()
    _, engine, _ = _serve(_mix(), k=1)
    assert _group_tick_fused._cache_size() == 0
    assert all(not s.group_cache for s in engine.shards)
    _, engine, _ = _serve(_mix(), k=4)
    assert _group_tick_fused._cache_size() >= 1
    assert any(s.group_cache for s in engine.shards)


# ----------------------------------------------------- boundary decisions
@pytest.mark.parametrize("k", K_VALUES)
def test_preemption_resize_drain_at_macro_boundaries(k):
    """Operator actions scripted at K-aligned ticks land on the same
    macro-tick boundary at every K, so even lifecycle tick stamps match
    the K=1 engine bit-for-bit."""
    def ops(engine):
        engine.schedule_op(8, lambda: engine.preempt(0))
        engine.schedule_op(8, lambda: engine.resize(3))
        engine.schedule_op(16, lambda: engine.drain(1))

    base, _, _ = _serve(_mix(), k=1, ops=ops)
    fused, engine, cfg = _serve(_mix(), k=k, ops=ops)
    _assert_bit_equal(base, fused)
    for rid in fused:
        for t in fused[rid].preempted_ticks + fused[rid].migrated_ticks:
            assert t % k == 0, "decision off a macro-tick boundary"
    for req in _mix():
        sched = [(lvl, to) for lvl, _frm, to
                 in fused[req.req_id].shrink_events]
        solo = run_standalone(req, cfg, shrink_schedule=sched)
        assert fused[req.req_id].champion_history == solo.champion_history


@pytest.mark.parametrize("k", K_VALUES)
def test_budget_and_target_stops_mid_macro_tick(k):
    """Terminal reasons that fire *inside* a macro-tick: a max_evals
    budget whose level count is not a multiple of K, and a target-error
    stop at an unpredictable level.  The host truncates retroactively —
    counted levels, evals and the ladder-level finish tick must all
    match K=1 exactly."""
    reqs = [
        _req(0, max_evals=3 * 10 * CPS),              # 3 levels by budget
        _req(1, objective="ackley", target_error=10.0),  # fires at level 9
        _req(2, n_chains=2 * CPS,
             max_evals=5 * 10 * 2 * CPS + 1),          # 6 levels by budget
    ]
    base, _, _ = _serve(reqs, k=1)
    fused, _, _ = _serve(reqs, k=k)
    _assert_bit_equal(base, fused)
    assert fused[0].finish_reason == "budget"
    assert fused[0].levels_run == 3
    assert fused[1].finish_reason == "target"
    assert fused[1].levels_run == 9        # not K-aligned for any tested K


def test_open_loop_stream_bit_exact_at_k4():
    """Open-loop Poisson arrivals admit on macro-tick boundaries; the
    trajectories (placement- and timing-invariant by construction) still
    match the standalone oracle for every completed request."""
    reqs = _mix()
    cfg = _cfg(k=4, n_devices=2, n_slots=2)
    engine = SAServeEngine(cfg)
    results = {r.req_id: r for r in engine.run_stream(
        ArrivalProcess.poisson(reqs, rate=0.5, seed=3), max_ticks=2000)}
    assert sorted(results) == [r.req_id for r in reqs]
    for req in reqs:
        solo = run_standalone(req, cfg)
        assert results[req.req_id].f_best == solo.f_best
        assert results[req.req_id].champion_history == solo.champion_history


# ------------------------------------------------- double-buffer dispatch
def test_double_buffer_flips_and_cache_hits_on_stable_membership():
    """Steady state: each launch donates the previous output buffer back
    in (ping-pong), so the cached buffer identity changes every macro-
    tick and every slot ref points into the *current* cache buffer."""
    reqs = [_req(0), _req(1, objective="ackley")]
    cfg = _cfg(k=4, n_slots=2)
    engine = SAServeEngine(cfg)
    for r in reqs:
        engine.submit(r)
    bufs = []
    for _ in range(3):
        engine.tick()
        shard = engine.shards[0]
        (key,) = shard.group_cache
        entry = shard.group_cache[key]
        bufs.append(id(entry["buf"]))
        for s in range(cfg.n_slots):
            ref = shard.pool.device_ref(s)
            assert ref is not None and ref.buf is entry["buf"]
    assert len(set(bufs)) == 3, "output buffer never flipped"
    results = {r.req_id: r for r in engine.run(max_ticks=2000)}
    for req in reqs:
        solo = run_standalone(req, cfg)
        assert results[req.req_id].champion_history == solo.champion_history


def test_membership_change_invalidates_dispatch_cache():
    """A preemption between macro-ticks repacks from host (the checkpoint
    materialized the device ref); the resumed trajectory is still
    bit-exact, so the cache-miss path reads back exactly the state the
    donated buffer held."""
    reqs = [_req(0), _req(1, objective="griewank")]
    cfg = _cfg(k=4, n_slots=2)
    engine = SAServeEngine(cfg)
    for r in reqs:
        engine.submit(r)
    engine.tick()
    engine.preempt(0)            # materializes + frees slot 0's ref
    assert engine.shards[0].pool.device_ref(0) is None
    results = {r.req_id: r for r in engine.run(max_ticks=2000)}
    for req in reqs:
        solo = run_standalone(req, cfg)
        assert results[req.req_id].champion_history == solo.champion_history
        assert results[req.req_id].f_best == solo.f_best


# --------------------------------------------------- ladder-level latency
def test_latency_summary_units_invariant_across_k():
    """Satellite: the tick clock is measured in ladder levels at any K,
    so p50/p99 queueing delay, TTFT and end-to-end latency of the same
    seeded closed-loop batch are *identical* numbers at K=1 and K=4 —
    fusing levels is a wall-clock optimization, never a unit change."""
    def summarize(k):
        results, engine, _ = _serve(_mix(), k=k, n_devices=1)
        return latency_summary(list(results.values()),
                               ticks=engine.tick_count,
                               n_submitted=engine.n_submitted)

    s1, s4 = summarize(1), summarize(4)
    for key in ("completed", "rejected", "incomplete",
                "queue_delay_p50", "queue_delay_p99",
                "ttft_p50", "ttft_p99", "latency_p50", "latency_p99",
                "goodput_req_per_tick"):
        assert s1[key] == pytest.approx(s4[key], nan_ok=True), key


def test_tick_clock_advances_by_k_only_when_active():
    """tick_count counts ladder levels: K per active macro-tick, 1 per
    idle tick — so sa_ticks_total and goodput denominators stay on the
    same axis as the K=1 engine."""
    engine = SAServeEngine(_cfg(k=4, n_slots=2))
    engine.tick()                              # idle: no active slots
    assert engine.tick_count == 1
    engine.submit(_req(0))
    engine.tick()
    assert engine.tick_count == 5              # 1 idle + 4 fused levels


# ------------------------------------------------------------- telemetry
def test_telemetry_on_is_bit_exact_at_k4():
    tel = Telemetry()
    plain, _, _ = _serve(_mix(), k=4)
    traced, engine, _ = _serve(_mix(), k=4, telemetry=tel)
    _assert_bit_equal(plain, traced)
    snap = tel.registry.snapshot()
    assert snap["sa_ticks_total"]["series"][""] == engine.tick_count


# ----------------------------------------------------------------- config
def test_macro_k_validation():
    with pytest.raises(ValueError):
        EngineConfig(n_slots=2, chains_per_slot=CPS, macro_k=0)


def test_run_standalone_uses_engine_macro_k():
    """run_standalone inherits cfg.macro_k, so the oracle itself runs the
    fused path — and still matches a K=1 standalone run bit-for-bit."""
    req = _req(0, n_chains=2 * CPS)
    # Shrink schedules replay at macro-tick boundaries, so the level must
    # be K-aligned — which engine-recorded shrink_events always are.
    sched = [(8, CPS)]
    solo_1 = run_standalone(req, _cfg(k=1), shrink_schedule=sched)
    solo_4 = run_standalone(req, _cfg(k=4), shrink_schedule=sched)
    assert solo_1.champion_history == solo_4.champion_history
    assert solo_1.f_best == solo_4.f_best
