"""Nelder-Mead local minimizer + hybrid SA->NM (paper §4.2)."""
import jax
import numpy as np

from repro.core import SAConfig, hybrid_minimize, nelder_mead
from repro.objectives import functions as F


def test_nm_quadratic_bowl():
    obj = F.exponential(4)  # smooth unimodal, min at origin
    x0 = np.full(4, 0.4, np.float32)
    res = nelder_mead(obj, x0, max_iters=2000)
    assert abs(res.f_best - obj.f_opt) < 1e-6
    assert np.linalg.norm(res.x_best) < 1e-3


def test_nm_rosenbrock_valley():
    obj = F.rosenbrock(4)
    x0 = np.full(4, 0.5, np.float32)
    res = nelder_mead(obj, x0, max_iters=8000)
    assert res.f_best < 1e-3


def test_nm_himmelblau_reaches_a_global_minimum():
    obj = F.himmelblau()
    res = nelder_mead(obj, np.array([2.5, 2.5], np.float32), max_iters=2000)
    assert res.f_best < 1e-8


def test_nm_respects_box():
    obj = F.schwefel(2)
    res = nelder_mead(obj, np.array([500.0, 500.0], np.float32),
                      max_iters=500)
    assert np.all(res.x_best >= obj.lower - 1e-6)
    assert np.all(res.x_best <= obj.upper + 1e-6)


def test_nm_converged_flag():
    obj = F.exponential(4)
    res = nelder_mead(obj, np.full(4, 0.1, np.float32), max_iters=5000,
                      fatol=1e-8, xatol=1e-8)
    assert res.converged
    assert res.n_iters < 5000


def test_hybrid_result_coherent_when_nm_ends_worse():
    """Regression: NM can terminate on a worse simplex than its SA seed
    (iteration cap, degenerate geometry).  HybridResult must then report
    BOTH x_best and f_best from the SA stage — never SA's f with NM's x.
    """
    from repro.core.annealing import SAResult
    from repro.core.hybrid import HybridResult
    from repro.core.neldermead import NMResult

    x_sa = np.array([1.0, 2.0], np.float32)
    x_nm = np.array([9.0, 9.0], np.float32)
    sa = SAResult(x_best=x_sa, f_best=0.5, history_f=None, n_evals=10,
                  config=SAConfig(T0=1.0, T_min=0.5, rho=0.5, N=1),
                  objective_name="t")
    nm = NMResult(x_best=x_nm, f_best=0.7, n_iters=3, converged=False)
    hyb = HybridResult(sa=sa, nm=nm)
    assert hyb.f_best == 0.5
    np.testing.assert_array_equal(hyb.x_best, x_sa)
    # NM at least as good (the normal case, ties go to NM's polish)
    nm2 = NMResult(x_best=x_nm, f_best=0.5, n_iters=3, converged=True)
    hyb2 = HybridResult(sa=sa, nm=nm2)
    assert hyb2.f_best == 0.5
    np.testing.assert_array_equal(hyb2.x_best, x_nm)


def test_hybrid_improves_on_premature_sa():
    """Paper Table 10's claim at reduced scale."""
    obj = F.schwefel(16)
    cfg = SAConfig(T0=50.0, T_min=2.0, rho=0.8, N=20, n_chains=256,
                   exchange="sync", seed=0, record_history=False)
    hyb = hybrid_minimize(obj, cfg, key=jax.random.PRNGKey(0),
                          nm_max_iters=5000)
    e_sa = abs(hyb.sa.f_best - obj.f_opt)
    e_h = abs(hyb.f_best - obj.f_opt)
    assert e_h <= e_sa
    assert e_h < 1e-2, (e_sa, e_h)
