"""Nelder-Mead local minimizer + hybrid SA->NM (paper §4.2)."""
import jax
import numpy as np

from repro.core import SAConfig, hybrid_minimize, nelder_mead
from repro.objectives import functions as F


def test_nm_quadratic_bowl():
    obj = F.exponential(4)  # smooth unimodal, min at origin
    x0 = np.full(4, 0.4, np.float32)
    res = nelder_mead(obj, x0, max_iters=2000)
    assert abs(res.f_best - obj.f_opt) < 1e-6
    assert np.linalg.norm(res.x_best) < 1e-3


def test_nm_rosenbrock_valley():
    obj = F.rosenbrock(4)
    x0 = np.full(4, 0.5, np.float32)
    res = nelder_mead(obj, x0, max_iters=8000)
    assert res.f_best < 1e-3


def test_nm_himmelblau_reaches_a_global_minimum():
    obj = F.himmelblau()
    res = nelder_mead(obj, np.array([2.5, 2.5], np.float32), max_iters=2000)
    assert res.f_best < 1e-8


def test_nm_respects_box():
    obj = F.schwefel(2)
    res = nelder_mead(obj, np.array([500.0, 500.0], np.float32),
                      max_iters=500)
    assert np.all(res.x_best >= obj.lower - 1e-6)
    assert np.all(res.x_best <= obj.upper + 1e-6)


def test_nm_converged_flag():
    obj = F.exponential(4)
    res = nelder_mead(obj, np.full(4, 0.1, np.float32), max_iters=5000,
                      fatol=1e-8, xatol=1e-8)
    assert res.converged
    assert res.n_iters < 5000


def test_hybrid_improves_on_premature_sa():
    """Paper Table 10's claim at reduced scale."""
    obj = F.schwefel(16)
    cfg = SAConfig(T0=50.0, T_min=2.0, rho=0.8, N=20, n_chains=256,
                   exchange="sync", seed=0, record_history=False)
    hyb = hybrid_minimize(obj, cfg, key=jax.random.PRNGKey(0),
                          nm_max_iters=5000)
    e_sa = abs(hyb.sa.f_best - obj.f_opt)
    e_h = abs(hyb.f_best - obj.f_opt)
    assert e_h <= e_sa
    assert e_h < 1e-2, (e_sa, e_h)
