"""Distributed substrate tests on 8 fake CPU devices (subprocess so the
XLA device-count flag never leaks into this process — smoke tests must see
one device)."""
import json
import subprocess
import sys
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro.distributed.compression import (dequantize_int8, quantize_int8)

SRC = str(Path(__file__).resolve().parent.parent / "src")


def _run8(code: str) -> dict:
    """Run ``code`` in a subprocess with 8 fake devices; return its JSON."""
    pre = ("import os\n"
           "os.environ['XLA_FLAGS'] = "
           "'--xla_force_host_platform_device_count=8'\n")
    out = subprocess.run(
        [sys.executable, "-c", pre + code], capture_output=True, text=True,
        env={"PYTHONPATH": SRC, "PATH": "/usr/bin:/bin"}, timeout=600)
    assert out.returncode == 0, f"stderr:\n{out.stderr[-4000:]}"
    return json.loads(out.stdout.strip().splitlines()[-1])


def test_quantize_roundtrip_error_bounded():
    x = jnp.asarray(np.random.default_rng(0).normal(size=(64,)) * 3)
    q, s = quantize_int8(x)
    err = jnp.abs(dequantize_int8(q, s) - x)
    assert float(jnp.max(err)) <= float(s) * 0.5 + 1e-6


def test_sharded_sa_ladder_8dev():
    """The multi-device SA program: champion identical on all shards, and
    the sharded champion is <= every shard's local best (sync exchange)."""
    r = _run8("""
import json, jax, jax.numpy as jnp, numpy as np
from repro.core import SAConfig, sa_minimize
from repro.objectives import functions as F
from repro.launch.mesh import make_mesh
mesh = make_mesh((4, 2), ("data", "model"))
obj = F.schwefel(8)
cfg = SAConfig(T0=50.0, T_min=0.5, rho=0.8, N=10, n_chains=256,
               exchange="sync", record_history=False)
res = sa_minimize(obj, cfg, key=jax.random.PRNGKey(0), mesh=mesh)
res1 = sa_minimize(obj, cfg, key=jax.random.PRNGKey(0), mesh=mesh)
print(json.dumps({
    "f": float(res.f_best),
    "deterministic": float(res.f_best) == float(res1.f_best),
    "err": abs(float(res.f_best) - obj.f_opt),
    "n_dev": len(jax.devices()),
}))
""")
    assert r["n_dev"] == 8
    assert r["deterministic"]
    assert r["err"] < 30.0


def test_compressed_psum_8dev():
    """int8 error-feedback psum: result close to exact psum; residual
    carries the quantization error."""
    r = _run8("""
import json, jax, jax.numpy as jnp, numpy as np
from functools import partial
from jax.sharding import PartitionSpec as P
from repro.distributed.compression import compressed_psum
from repro.launch.mesh import make_mesh, shard_map
mesh = make_mesh((8,), ("data",))
g = jnp.asarray(np.random.default_rng(0).normal(size=(8, 32)).astype(np.float32))

def body(gl):
    s, resid = compressed_psum(gl, ("data",))
    return s, resid

f = jax.jit(shard_map(body, mesh=mesh, in_specs=P("data"),
                      out_specs=(P("data"), P("data"))))
s, resid = f(g)
exact = jnp.sum(g, axis=0)
rel = float(jnp.max(jnp.abs(s[0] - exact)) / (jnp.max(jnp.abs(exact)) + 1e-9))
print(json.dumps({"rel_err": rel,
                  "resid_nonzero": bool(jnp.any(resid != 0))}))
""")
    assert r["rel_err"] < 0.05, r


def test_pipeline_2stage_matches_sequential():
    r = _run8("""
import json, jax, jax.numpy as jnp, numpy as np
from repro.distributed.pipeline import make_pipelined_fn, bubble_fraction
from repro.launch.mesh import make_mesh
mesh = make_mesh((2, 4), ("pod", "data"))
L, D, M, mb = 4, 8, 4, 2   # 4 layers, 2 stages x 2 layers
rng = np.random.default_rng(0)
Ws = jnp.asarray(rng.normal(size=(L, D, D)).astype(np.float32) * 0.3)
x = jnp.asarray(rng.normal(size=(M, mb, D)).astype(np.float32))

def layer_fn(stage_ws, h):
    # stage_ws: this stage's (L/stages, D, D) slice
    for i in range(stage_ws.shape[0]):
        h = jnp.tanh(h @ stage_ws[i])
    return h

def seq_apply(x):
    h = x
    for i in range(L):
        h = jnp.tanh(h @ Ws[i])
    return h

pipe = make_pipelined_fn(layer_fn, mesh, axis="pod")
y_pipe = pipe(Ws, x)
y_seq = jax.vmap(seq_apply)(x)
err = float(jnp.max(jnp.abs(y_pipe - y_seq)))
print(json.dumps({"err": err, "bubble": bubble_fraction(2, M)}))
""")
    assert r["err"] < 1e-5, r
    assert abs(r["bubble"] - (2 - 1) / (4 + 2 - 1)) < 1e-9


def test_straggler_monitor_detects_outlier():
    from repro.distributed.monitor import StragglerMonitor
    mon = StragglerMonitor(zscore=2.0)
    for h in range(8):
        for _ in range(16):
            mon.record(h, 0.1 if h != 5 else 0.5, now=1000.0)
    assert mon.stragglers() == [5]
    assert mon.dead(now=2000.0) == list(range(8))
    assert mon.dead(now=1001.0) == []
