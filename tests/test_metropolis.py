"""Metropolis-sweep invariants (paper §2.1 semantics)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")  # property tests need the dev extra
from hypothesis import given, settings, strategies as st

from repro.core import metropolis
from repro.objectives import functions as F


def _setup(obj, chains, seed=0):
    key = jax.random.PRNGKey(seed)
    x = obj.sample_uniform(key, (chains,)).astype(jnp.float32)
    return jax.random.PRNGKey(seed + 1), x, obj(x)


def test_fx_consistent_with_x():
    """After any sweep, carried fx equals objective(x)."""
    obj = F.schwefel(8)
    key, x, fx = _setup(obj, 32)
    key, x1, fx1 = metropolis.sweep_full(key, x, fx, 5.0,
                                         objective=obj, n_steps=50)
    np.testing.assert_allclose(np.asarray(fx1), np.asarray(obj(x1)),
                               rtol=1e-5, atol=1e-5)


def test_bounds_respected():
    obj = F.rastrigin(6)
    key, x, fx = _setup(obj, 64)
    key, x1, _ = metropolis.sweep_full(key, x, fx, 100.0,
                                       objective=obj, n_steps=200)
    lo, hi = obj.bounds
    assert bool(jnp.all(x1 >= lo - 1e-6)) and bool(jnp.all(x1 <= hi + 1e-6))


def test_greedy_at_zero_temperature():
    """T -> 0: only downhill moves accepted => fx non-increasing."""
    obj = F.schwefel(8)
    key, x, fx = _setup(obj, 64)
    cur = fx
    k = key
    for _ in range(5):
        k, x, f_new = metropolis.sweep_full(k, x, cur, 1e-12,
                                            objective=obj, n_steps=10)
        assert bool(jnp.all(f_new <= cur + 1e-4)), "uphill move at T=0"
        cur = f_new


def test_hot_temperature_accepts_everything():
    """T -> inf: acceptance ratio ~1 (every proposal taken)."""
    obj = F.schwefel(8)
    key, x, fx = _setup(obj, 256)
    key, x1, _ = metropolis.sweep_full(key, x, fx, 1e12,
                                       objective=obj, n_steps=1)
    # with 1 step and certain acceptance, exactly one coordinate changed
    changed = jnp.sum(x1 != x, axis=1)
    frac = float(jnp.mean((changed == 1).astype(jnp.float32)))
    assert frac > 0.95, f"only {frac:.2%} chains moved at T=inf"


@pytest.mark.parametrize("maker,dim", [(F.schwefel, 8), (F.rastrigin, 16),
                                       (F.ackley, 8), (F.griewank, 16),
                                       (F.cosine_mixture, 4),
                                       (F.exponential, 4)])
def test_delta_equals_full_trajectory(maker, dim):
    """Identical random stream => identical accepted trajectory for the
    O(1) delta-eval and the paper-faithful full evaluation."""
    obj = maker(dim)
    if obj.decomposable is None:
        pytest.skip("not decomposable")
    key, x, fx = _setup(obj, 16, seed=7)
    k1, xa, fa = metropolis.sweep_full(key, x, fx, 2.0,
                                       objective=obj, n_steps=60)
    k2, xb, fb = metropolis.sweep_delta(key, x, fx, 2.0,
                                        objective=obj, n_steps=60)
    np.testing.assert_allclose(np.asarray(xa), np.asarray(xb),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(fa), np.asarray(fb),
                               rtol=2e-3, atol=2e-3)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 1000), temp=st.floats(0.01, 100.0),
       steps=st.integers(1, 30))
def test_property_detailed_balance_monotone_stats(seed, temp, steps):
    """Statistical property: mean energy after a sweep at low T is <= mean
    energy at very high T (the Boltzmann ordering), and fx stays consistent."""
    obj = F.schwefel(4)
    key, x, fx = _setup(obj, 128, seed=seed)
    _, x_cold, f_cold = metropolis.sweep_full(key, x, fx, 0.01,
                                              objective=obj, n_steps=steps)
    _, x_hot, f_hot = metropolis.sweep_full(key, x, fx, 1e6,
                                            objective=obj, n_steps=steps)
    assert float(jnp.mean(f_cold)) <= float(jnp.mean(f_hot)) + 1e-3
    np.testing.assert_allclose(np.asarray(f_cold), np.asarray(obj(x_cold)),
                               rtol=1e-4, atol=1e-4)


def test_unroll_matches_fori_loop():
    obj = F.ackley(8)
    key, x, fx = _setup(obj, 8)
    _, xa, fa = metropolis.sweep_full(key, x, fx, 1.0, objective=obj,
                                      n_steps=7, unroll=False)
    _, xb, fb = metropolis.sweep_full(key, x, fx, 1.0, objective=obj,
                                      n_steps=7, unroll=True)
    np.testing.assert_allclose(np.asarray(xa), np.asarray(xb), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(fa), np.asarray(fb), rtol=1e-6)
