"""End-to-end integration: training driver (loss decreases, resume is
bit-identical) and the continuous-batching serve driver."""
import numpy as np
import pytest

from repro.launch.serve import main as serve_main
from repro.launch.train import main as train_main


@pytest.fixture(scope="module")
def ckpt_dir(tmp_path_factory):
    return str(tmp_path_factory.mktemp("ckpt"))


def test_train_loss_decreases_and_resume_identical(ckpt_dir):
    losses = train_main(["--preset", "smoke", "--steps", "12",
                         "--ckpt-dir", ckpt_dir, "--ckpt-every", "5",
                         "--log-every", "100"])
    assert len(losses) == 12
    assert losses[-1] < losses[0], "loss must decrease"

    # resume from step 10 checkpoint: overlapping steps must match exactly
    losses2 = train_main(["--preset", "smoke", "--steps", "12",
                          "--ckpt-dir", ckpt_dir, "--resume",
                          "--ckpt-every", "100", "--log-every", "100"])
    np.testing.assert_allclose(losses2, losses[10:], rtol=1e-6,
                               err_msg="resumed stream must be identical")


def test_serve_continuous_batching():
    outs = serve_main(["--preset", "smoke", "--requests", "5", "--batch", "2",
                       "--prompt-len", "8", "--max-new", "6",
                       "--s-max", "32"])
    assert len(outs) == 5
    assert all(len(o) == 6 for o in outs), [len(o) for o in outs]
    # deterministic greedy decode: same request prompt -> same output
    outs2 = serve_main(["--preset", "smoke", "--requests", "5", "--batch",
                        "3", "--prompt-len", "8", "--max-new", "6",
                        "--s-max", "32"])
    assert outs[0] == outs2[0], "batch size must not change greedy output"
